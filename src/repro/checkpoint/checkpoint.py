"""Checkpointing: sharded-safe save/restore with atomic commit, async
save thread, and elastic restore (re-shard to a different mesh).

Format: one ``.npz`` per pytree leaf group + a JSON manifest holding the
tree structure, shapes, dtypes and the step. Writes go to a temp dir
that is atomically renamed on completion, so a crash mid-save never
corrupts the latest checkpoint (restart scans for the newest *committed*
step directory).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any
_SEP = "/"


def _flatten_with_paths(tree: Pytree) -> dict[str, jax.Array]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Pytree, *, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    dtypes = {k: str(a.dtype) for k, a in arrays.items()}
    # numpy's npz cannot round-trip ml_dtypes (bfloat16 etc.); store such
    # arrays as uint16 bit patterns and record the logical dtype.
    stored = {
        k: (a.view(np.uint16) if a.dtype.itemsize == 2 and "float" in str(a.dtype) and a.dtype != np.float16 else a)
        for k, a in arrays.items()
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in-flight save)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Pytree, *, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.device_get(tree)  # snapshot before training mutates
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree), kwargs={"extra": extra}
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, d, "manifest.json")
        ):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    step: int,
    like: Pytree,
    *,
    shardings: Pytree | None = None,
) -> tuple[Pytree, dict]:
    """Restore into the structure of ``like``.

    ``shardings`` (a pytree of NamedSharding matching ``like``) enables
    *elastic* restore: arrays saved under one mesh are placed onto a
    different mesh — the knapsack of the new mesh decides the slices, the
    checkpoint stores only logical arrays (mesh-agnostic by design).
    """
    final = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat_like)
    )
    for (path, proto), sh in zip(flat_like, shard_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        expect = tuple(proto.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {expect}")
        if arr.dtype == np.uint16 and manifest["dtypes"][key] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        out = jnp.asarray(arr, dtype=proto.dtype)
        if sh is not None:
            out = jax.device_put(out, sh)
        leaves.append(out)
    tree = jax.tree_util.tree_unflatten(jax.tree.structure(like), leaves)
    return tree, manifest["extra"]
