"""Coupled particle-mesh (PIC) step on ONE shared partition.

Mesh cells and particles register in a single
`state.ParticleEngine` (cells as the static anchor prefix, particles
behind them), so one knapsack slice owns both entity kinds, ONE
`halo.build_halo_plan` over the union row set compiles both the
stencil halo and the pairwise interaction exchange, and ONE
`interact.move_rows` migration carries the combined state matrix
``[u | pos | vel | mass]`` between partitions.

The union (n_u, K) table concatenates each row's lanes by entity kind:
cell rows carry their `mesh.amr.face_neighbors` lanes (with heat-flux
coefficients), particle rows their `interact.cutoff_neighbors` lanes
(offset by the cell count). A per-row particle flag splits the lane
masks on device — cell rows run the fused stencil update on column 0,
particle rows the fused pair acceleration on the position columns, and
both phases share the routed ghost matrix, the interior/boundary
overlap and the traced-substep ``fori_loop``.

Deposit (particle -> containing cell, ``u += kappa * mass``) and
interpolate (cell -> particle, a drag ``vel *= 1 - gamma * u``) are
host-side transfer maps applied at event boundaries on both backends
in the same deterministic order — `np.add.at` in global particle row
order — so the coupled trajectory stays bitwise comparable.

Honest scope notes: the mesh is static and uniform (no refine/coarsen
during the coupled run — AMR rebirth of *cell* slots composes with
particle re-registration but is not exercised here), and coupling
happens at event boundaries, not per substep.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat as _compat
from repro.kernels import ops as _ops
from repro.mesh import amr as _amr
from repro.mesh import halo as _halo
from repro.mesh import stencil as _st
from repro.mesh.halo import _roundup
from repro.particles import interact as _ia
from repro.particles import state as _ps
from repro.particles.simulate import ParticleSimStats, _degree_weights


@dataclass(frozen=True)
class PICSimConfig:
    d: int = 2
    n: int = 256                # particles
    mesh_level: int = 3         # static uniform mesh: 2**(d*level) cells
    events: int = 8
    substeps: int = 2
    dt: float = 0.01            # particle kick-drift step
    radius: float = 0.15
    seed: int = 0
    v0: float = 0.8
    margin: float = 0.1
    kappa: float = 0.05         # deposit strength (mass -> cell field)
    gamma: float = 0.2          # interpolate strength (field -> drag)
    couple_every: int = 2       # deposit/interp every k-th event
    reregister_every: int = 2
    dt_safety: float = 0.25     # mesh stencil stability factor
    bucket_size: int = 8
    engine_max_depth: int = 10
    node_threshold: float = 1.20


# ---------------------------------------------------------------------------
# union tables + transfer maps
# ---------------------------------------------------------------------------

def union_tables(
    mesh_nbr: np.ndarray, mesh_coeff: np.ndarray, pair_nbr: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate cell face lanes and particle pair lanes into one
    (n_u, K) neighbor/coefficient table over union row order
    ``[cells; particles]`` (particle targets offset by the cell count)."""
    nc, Km = mesh_nbr.shape
    npart, Kp = pair_nbr.shape
    K = _roundup(max(Km, Kp), 8)
    nbr = np.full((nc + npart, K), -1, np.int32)
    nbr[:nc, :Km] = mesh_nbr
    nbr[nc:, :Kp] = np.where(pair_nbr >= 0, pair_nbr + nc, -1)
    coeff = np.zeros((nc + npart, K), np.float32)
    coeff[:nc, :Km] = mesh_coeff
    return nbr, coeff


def cell_lookup(mesh: _amr.AMRMesh):
    """Position -> containing-cell map for a static uniform mesh."""
    level = int(mesh.level[0])
    assert (mesh.level == level).all(), "cell_lookup requires a uniform mesh"
    side = 1 << level
    lut = np.full((side,) * mesh.d, -1, np.int64)
    lut[tuple(mesh.ij.T)] = np.arange(mesh.n, dtype=np.int64)

    def locate(pos: np.ndarray) -> np.ndarray:
        ip = np.clip(
            (np.asarray(pos, np.float64) * side).astype(np.int64), 0, side - 1
        )
        return lut[tuple(ip.T)]

    return locate


def apply_coupling(
    u: np.ndarray,
    vel: np.ndarray,
    mass: np.ndarray,
    cell_of: np.ndarray,
    kappa: float,
    gamma: float,
) -> tuple[np.ndarray, np.ndarray]:
    """Deposit then interpolate, in one deterministic host pass.

    ``np.add.at`` accumulates sequentially in particle row order, so
    both backends (which call this on bit-identical inputs) produce
    bit-identical fields; the drag reads the POST-deposit field.
    """
    dep = np.zeros_like(u)
    np.add.at(dep, cell_of, np.float32(kappa) * mass)
    u2 = u + dep
    f = np.float32(1.0) - np.float32(gamma) * u2[cell_of]
    return u2, vel * f[:, None]


def initial_field(mesh: _amr.AMRMesh) -> np.ndarray:
    """A heat blob at the domain center."""
    c = np.full((mesh.d,), 0.5)
    d2 = np.sum((mesh.centers().astype(np.float64) - c[None, :]) ** 2, axis=1)
    return np.exp(-d2 / 0.02).astype(np.float32)


# ---------------------------------------------------------------------------
# the fused coupled substep (stencil + pair accel share one exchange)
# ---------------------------------------------------------------------------

def _pic_body(U, isp, nbr, valid, coeff, rc2, dt, d, ghosts, interior, boundary,
              use_pallas):
    """One coupled substep given the routed ghost matrix. Shared by the
    reference twin (``ghosts=None``: every row interior, global order)
    and the distributed executor — the same expressions, so identical
    bits per row."""
    u = U[:, 0]
    x = U[:, 1:1 + d]
    v = U[:, 1 + d:1 + 2 * d]
    m = U[:, 1 + 2 * d]
    cval = valid & (~isp)[:, None]
    pval = valid & isp[:, None]
    if ghosts is None:
        u_new = _ops.stencil_update(u, u, nbr, cval, coeff, use_pallas=use_pallas)
        acc = _ops.pair_accel(x, m, x, nbr, pval, rc2, use_pallas=use_pallas)
    else:
        # interior rows first (owned-only reads, exchange in flight)
        u_new = _st._rows_update(u, u, u, nbr, cval, coeff, interior, use_pallas)
        acc = jnp.zeros_like(x)
        acc = _ia._rows_accel(acc, x, m, x, nbr, pval, interior, rc2, use_pallas)
        A = jnp.concatenate([U, ghosts], axis=0)
        u_new = _st._rows_update(
            u_new, u, A[:, 0], nbr, cval, coeff, boundary, use_pallas
        )
        acc = _ia._rows_accel(
            acc, A[:, 1:1 + d], A[:, 1 + 2 * d], x, nbr, pval, boundary, rc2,
            use_pallas,
        )
    x2, v2 = _ia._integrate(x, v, acc, dt)
    return jnp.concatenate([u_new[:, None], x2, v2, m[:, None]], axis=1)


@functools.lru_cache(maxsize=4)
def _pic_reference_fn(d: int, use_pallas: bool):
    @jax.jit
    def fn(steps, dt, rc2, U, isp, nbr, valid, coeff):
        def body(_, U):
            return _pic_body(
                U, isp, nbr, valid, coeff, rc2, dt, d, None, None, None,
                use_pallas,
            )
        return jax.lax.fori_loop(0, steps, body, U)
    return fn


def reference_pic_steps(U, isp, nbr, coeff, steps, dt, radius,
                        *, use_pallas=False):
    """``steps`` coupled substeps on one device, union row order."""
    d = (U.shape[1] - 2) // 2
    nbr = jnp.asarray(nbr)
    return _pic_reference_fn(int(d), bool(use_pallas))(
        jnp.int32(steps), jnp.float32(dt), jnp.float32(float(radius) ** 2),
        jnp.asarray(U, jnp.float32), jnp.asarray(isp), nbr, nbr >= 0,
        jnp.asarray(coeff, jnp.float32),
    )


@functools.lru_cache(maxsize=64)
def _pic_fn(
    mesh: jax.sharding.Mesh,
    axes: tuple,
    stage_meta: tuple,
    d: int,
    use_pallas: bool,
):
    """Jitted coupled executor: ONE ghost exchange of the full state
    matrix per substep feeds both the stencil and the pair phase."""

    def kernel(steps, dt, rc2, U, isp, nbr, valid, coeff, fetch,
               interior, boundary, *stage_idx):
        def body(_, U):
            recv = _ia._route_cols(U, stage_meta, stage_idx, jnp.float32(0.0))
            ghosts = jnp.where(
                (fetch >= 0)[:, None],
                recv[jnp.clip(fetch, 0, recv.shape[0] - 1)],
                jnp.float32(0.0),
            )
            return _pic_body(
                U, isp, nbr, valid, coeff, rc2, dt, d, ghosts,
                interior, boundary, use_pallas,
            )
        return jax.lax.fori_loop(0, steps, body, U)

    spec = P(axes)
    in_specs = (P(), P(), P()) + (spec,) * (8 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=spec, check_vma=False,
    ))


def pic_steps(jax_mesh, plan, U_dev, isp_dev, hargs: _st.HaloArgs,
              steps: int, dt: float, radius: float, *, use_pallas=False):
    """Run ``steps`` distributed coupled substeps over the plan's layout."""
    d = (int(U_dev.shape[-1]) - 2) // 2
    fn = _pic_fn(jax_mesh, plan.axes, plan.stage_meta, d, bool(use_pallas))
    return fn(
        jnp.int32(steps), jnp.float32(dt), jnp.float32(float(radius) ** 2),
        U_dev, isp_dev, *hargs.core, *hargs.split, *hargs.stages,
    )


# ---------------------------------------------------------------------------
# closed-loop coupled drivers
# ---------------------------------------------------------------------------

def _setup(cfg: PICSimConfig):
    mesh = _amr.uniform_mesh(cfg.d, cfg.mesh_level, cfg.mesh_level)
    dt_mesh = _amr.stable_dt(mesh, cfg.dt_safety)
    mesh_nbr = _amr.face_neighbors(mesh)
    mesh_coeff = _amr.stencil_coeffs(mesh, mesh_nbr, dt_mesh)
    ps = _ps.random_particles(
        cfg.n, cfg.d, seed=cfg.seed, v0=cfg.v0, margin=cfg.margin
    )
    u0 = initial_field(mesh)
    return mesh, mesh_nbr, mesh_coeff, ps, u0


def _host_state(u, pos, vel, mass, nc, n, d):
    """Union-row state matrix [u | pos | vel | mass] (cells zero-pad the
    particle columns and vice versa)."""
    C = 2 * d + 2
    U = np.zeros((nc + n, C), np.float32)
    U[:nc, 0] = u
    U[nc:, 1:1 + d] = pos
    U[nc:, 1 + d:1 + 2 * d] = vel
    U[nc:, 1 + 2 * d] = mass
    return U


def run_reference_coupled(
    cfg: PICSimConfig, *, use_pallas: bool = False
) -> tuple[np.ndarray, _ps.ParticleSet]:
    """Single-device coupled integration (the bitwise oracle). Returns
    the final cell field and particle state."""
    mesh, mesh_nbr, mesh_coeff, ps, u = _setup(cfg)
    locate = cell_lookup(mesh)
    nc, n, d = mesh.n, cfg.n, cfg.d
    pos, vel = ps.pos, ps.vel
    for t in range(cfg.events):
        if cfg.couple_every and t % cfg.couple_every == 0 and t > 0:
            u, vel = apply_coupling(
                u, vel, ps.mass, locate(pos), cfg.kappa, cfg.gamma
            )
        pair = _ia.cutoff_neighbors(pos, cfg.radius)
        nbr, coeff = union_tables(mesh_nbr, mesh_coeff, pair)
        isp = np.arange(nc + n) >= nc
        U = _host_state(u, pos, vel, ps.mass, nc, n, d)
        U = np.asarray(reference_pic_steps(
            U, isp, nbr, coeff, cfg.substeps, cfg.dt, cfg.radius,
            use_pallas=use_pallas,
        ))
        u, pos, vel = U[:nc, 0], U[nc:, 1:1 + d], U[nc:, 1 + d:1 + 2 * d]
    return u, _ps.ParticleSet(pos=pos, vel=vel, mass=ps.mass)


def run_distributed_coupled(
    cfg: PICSimConfig,
    jax_mesh,
    hplan,
    *,
    driver: str = "incremental",
    use_pallas: bool = False,
) -> tuple[np.ndarray, _ps.ParticleSet, ParticleSimStats]:
    """Coupled integration on a device mesh: cells + particles in ONE
    engine, one plan, one migration for the combined state matrix."""
    if driver not in ("incremental", "rebuild"):
        raise ValueError(f"unknown driver {driver!r}")
    mesh, mesh_nbr, mesh_coeff, ps, u = _setup(cfg)
    locate = cell_lookup(mesh)
    nc, n, d = mesh.n, cfg.n, cfg.d
    n_u = nc + n
    eng = _ps.ParticleEngine(
        np.concatenate([mesh.centers(), ps.pos], axis=0),
        np.ones((n_u,), np.float32),
        plan=hplan,
        n_anchor=nc,
        node_threshold=cfg.node_threshold,
        capacity=2 * n_u,
        bucket_size=cfg.bucket_size,
        max_depth=cfg.engine_max_depth,
    )
    plan_cache = _halo.PlanCache()
    sh_put = None

    st = ParticleSimStats()
    st.n_cells = nc
    pos, vel, mass = ps.pos, ps.vel, ps.mass
    U_dev = None
    prev_plan = None
    quality_args = None
    part_by_slot = np.full((eng.rp.capacity,), -1, np.int64)

    for t in range(cfg.events):
        st.events += 1
        if U_dev is not None:
            host_U = _ia.unpack_rows(prev_plan, U_dev, n_u)
            u = host_U[:nc, 0]
            pos = host_U[nc:, 1:1 + d]
            vel = host_U[nc:, 1 + d:1 + 2 * d]
        coupled_event = bool(cfg.couple_every and t % cfg.couple_every == 0 and t > 0)
        if coupled_event:
            u, vel = apply_coupling(u, vel, mass, locate(pos), cfg.kappa, cfg.gamma)

        t0 = time.perf_counter()
        pair = _ia.cutoff_neighbors(pos, cfg.radius)
        st.neighbor_s += time.perf_counter() - t0
        nbr, coeff = union_tables(mesh_nbr, mesh_coeff, pair)
        st.k_max = max(st.k_max, nbr.shape[1])
        w_p = _degree_weights(pair)
        w = np.concatenate([np.ones((nc,), np.float32), w_p])

        t0 = time.perf_counter()
        ncross = 0
        if cfg.reregister_every and t % cfg.reregister_every == 0 and t > 0:
            ncross = eng.reregister(pos, w_p)
        eng.update_weights(w)
        if driver == "incremental":
            eng.step()
        else:
            eng.rebuild()
        st.engine_s += time.perf_counter() - t0

        part = eng.partition()
        had_prev = part_by_slot[eng.slots] >= 0
        changed = bool((part_by_slot[eng.slots][had_prev] != part[had_prev]).any())
        if changed:
            st.repartition_events += 1
        part_by_slot[:] = -1
        part_by_slot[eng.slots] = part

        plan = _halo.build_halo_plan(
            eng.slots, part, nbr, coeff,
            hierarchy=hplan, weights=w, with_metrics=False,
            cache=plan_cache, topo_token=(eng.rp.topology_version, t),
        )
        st.plan_build_s += plan.metrics["PlanBuildSeconds"]
        quality_args = (part, nbr, w)
        hargs = _st.halo_args(jax_mesh, plan)
        isp = np.arange(n_u) >= nc
        if sh_put is None:
            sh_put = NamedSharding(jax_mesh, P(plan.axes))
        isp_dev = jax.device_put(
            jnp.asarray(_ia.pack_rows(plan, isp, fill=False)), sh_put
        )

        host_U = _host_state(u, pos, vel, mass, nc, n, d)
        if U_dev is None or ncross or coupled_event:
            U_dev = _ia.put_rows(jax_mesh, plan, host_U)
        elif changed or driver == "rebuild":
            mv = _halo.build_move_plan(
                prev_plan, plan, hierarchy=hplan, full=driver == "rebuild",
                cache=plan_cache,
            )
            st.plan_build_s += mv.metrics["PlanBuildSeconds"]
            t0 = time.perf_counter()
            U_dev = jax.block_until_ready(
                _ia.move_rows(jax_mesh, mv, prev_plan, U_dev)
            )
            st.move_s += time.perf_counter() - t0
            mig = mv.migration
            st.moved_total += int(mig.total_moved)
            st.moved_inter_node += int(getattr(mig, "inter_moved", 0))
            if mv.kind == "device":
                st.node_local_moves += 1
        elif plan.cap != prev_plan.cap:
            U_dev = _ia.put_rows(jax_mesh, plan, host_U)

        t0 = time.perf_counter()
        U_dev = jax.block_until_ready(pic_steps(
            jax_mesh, plan, U_dev, isp_dev, hargs,
            cfg.substeps, cfg.dt, cfg.radius, use_pallas=use_pallas,
        ))
        st.force_s += time.perf_counter() - t0
        prev_plan = plan

    st.registration_events = eng.registrations
    st.crossers_total = eng.crossers_total
    st.intra_reslices = eng.rp.stats.intra_reslices
    st.inter_reslices = eng.rp.stats.inter_reslices
    st.rebuilds = eng.rp.stats.rebuilds
    st.plan_cache_hits = plan_cache.stats.halo_hits + plan_cache.stats.move_hits
    st.plan_cache_misses = (
        plan_cache.stats.halo_misses + plan_cache.stats.move_misses
    )
    st.halo_metrics = dict(prev_plan.metrics)
    if quality_args is not None:
        qp, qn, qw = quality_args
        st.halo_metrics.update(
            _halo.plan_quality_metrics(qp, qn, prev_plan.num_parts, weights=qw)
        )
    host_U = _ia.unpack_rows(prev_plan, U_dev, n_u)
    out = _ps.ParticleSet(
        pos=host_U[nc:, 1:1 + d], vel=host_U[nc:, 1 + d:1 + 2 * d], mass=mass
    )
    return host_U[:nc, 0], out, st
