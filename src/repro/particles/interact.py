"""Cutoff-radius interaction plans + distributed leapfrog executors.

The mesh halo machinery, generalized from topology-based to
distance-based neighbor structure: :func:`cutoff_neighbors` resolves
candidate interaction sets through CurveIndex bucket lookups within
radius ``r`` (the 3^d probe-cell walk below) and emits the same padded
``(n, K)`` neighbor-table shape `repro.mesh.amr.face_neighbors`
produces — so :func:`build_interact_plan` is `halo.build_halo_plan`
wholesale (ghost dedup, interior/boundary split, flat and two-hop node
routing, `PlanCache` reuse where the topology tier applies), compiled
ONCE per partition event into fixed-shape interaction/exchange plans.

Executors mirror `repro.mesh.stencil`: jitted ``shard_map`` closures
memoized per static shape signature, an overlapped sweep (launch the
ghost position exchange, compute the plan's *interior* rows while the
collective is in flight, apply *boundary* rows after the recv lands),
and a ``fori_loop`` over a traced substep count so ONE compiled program
serves every sweep length. The row update is the fused
`kernels.ops.pair_accel` (Pallas + bit-equal jnp fallback).

Bit-equality contract: :func:`reference_leapfrog` (single device,
global row order) and :func:`leapfrog_steps` (sharded, owned+ghost
layout) evaluate the SAME per-particle expressions — identical padded
(n, K) tables, identical fixed-order reductions, identical float32
integration (:func:`_integrate`) — so a distributed trajectory is
bitwise equal to the reference trajectory, which is what
``bench_particles`` gates across repartition events.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat as _compat
from repro.core import curve_index as _ci
from repro.kernels import ops as _ops
from repro.mesh import halo as _halo
from repro.mesh.halo import GID_SENTINEL, HaloPlan, MovePlan, _roundup
from repro.mesh.stencil import _route


# ---------------------------------------------------------------------------
# cutoff neighbor lists via CurveIndex cell probes
# ---------------------------------------------------------------------------

def cutoff_neighbors(pos: np.ndarray, radius: float) -> np.ndarray:
    """(n, K) int32 interaction table: every pair within ``radius``.

    A coarse Morton CurveIndex over the unit frame buckets the particles
    into grid cells of width ``2**-bits >= radius``; each particle
    probes the 3^d cells at ``x + o * radius`` (o in {-1, 0, 1}^d,
    clipped to the frame). Because the quantizer is monotone and the
    cell width is at least the radius, the three per-dimension probes
    cover every cell intersecting ``[x - r, x + r]`` — so the candidate
    union provably contains every in-range pair. Candidates are resolved
    by equal-key runs on the index's sorted key array, filtered by a
    float64 distance check with conservative slack (extra at-cutoff
    candidates are harmless: the force law weights them exactly 0.0),
    and emitted in deterministic ascending (row, neighbor-id) lane
    order with -1 pads — the same table contract as
    `mesh.amr.face_neighbors`, which is what lets `build_halo_plan`
    consume it unchanged.
    """
    pos = np.asarray(pos, np.float32)
    n, d = pos.shape
    r = float(radius)
    if not (0.0 < r <= 0.5):
        raise ValueError(f"cutoff radius must be in (0, 0.5], got {r}")
    bits = max(1, int(np.floor(np.log2(1.0 / r))))
    idx = _ci.build(
        jnp.asarray(pos),
        bits=bits,
        curve="morton",
        frame=(jnp.zeros((d,), jnp.float32), jnp.ones((d,), jnp.float32)),
        bucket_size=8,
    )
    keys_sorted = np.asarray(idx.keys)[:n].astype(np.uint64)
    ids_sorted = np.asarray(idx.ids)[:n].astype(np.int64)

    offs = np.stack(
        np.meshgrid(*([np.array([-1.0, 0.0, 1.0], np.float32)] * d), indexing="ij"),
        axis=-1,
    ).reshape(-1, d)
    probes = np.clip(pos[:, None, :] + offs[None, :, :] * np.float32(r), 0.0, 1.0)
    pk = np.asarray(
        _ci.query_keys(idx, jnp.asarray(probes.reshape(-1, d)))
    ).astype(np.uint64)
    row = np.repeat(np.arange(n, dtype=np.uint64), offs.shape[0])
    # dedup (row, cell): clipping and sub-radius offsets collide probes
    code = np.unique((row << np.uint64(32)) | pk)
    crow = (code >> np.uint64(32)).astype(np.int64)
    ckey = code & np.uint64(0xFFFFFFFF)
    lo = np.searchsorted(keys_sorted, ckey, side="left")
    hi = np.searchsorted(keys_sorted, ckey, side="right")
    lens = hi - lo
    occupied = lens > 0
    lo, lens, crow = lo[occupied], lens[occupied], crow[occupied]
    # ragged run expansion without a Python loop
    tot = int(lens.sum())
    base = np.repeat(lo, lens)
    starts = np.cumsum(lens) - lens
    within = np.arange(tot, dtype=np.int64) - np.repeat(starts, lens)
    cand = ids_sorted[base + within]
    prow = np.repeat(crow, lens)

    diff = pos[prow].astype(np.float64) - pos[cand].astype(np.float64)
    d2 = np.einsum("ij,ij->i", diff, diff)
    keep = (cand != prow) & (d2 <= (r * r) * (1.0 + 1e-5))
    prow, cand = prow[keep], cand[keep]

    order = np.argsort(prow * np.int64(n) + cand, kind="stable")
    prow, cand = prow[order], cand[order]
    counts = np.bincount(prow, minlength=n)
    K = _roundup(max(int(counts.max()) if counts.size else 0, 1), 8)
    nbr = np.full((n, K), -1, np.int32)
    starts = np.cumsum(counts) - counts
    within = np.arange(prow.shape[0], dtype=np.int64) - starts[prow]
    nbr[prow, within] = cand.astype(np.int32)
    return nbr


def build_interact_plan(
    slot: np.ndarray,
    part: np.ndarray,
    nbr: np.ndarray,
    *,
    hierarchy=None,
    num_parts: int | None = None,
    device_axis: str = "device",
    weights: np.ndarray | None = None,
    with_metrics: bool = True,
    cache=None,
    topo_token=None,
) -> HaloPlan:
    """Compile a cutoff interaction/exchange plan for one partition.

    Exactly `halo.build_halo_plan` over the distance-based table (the
    stencil coefficient lanes carry zeros — the pair executors never
    read them): ghost sets, local index remapping, interior/boundary
    split and the flat/two-hop routing stages all come from the shared
    builder, so everything the mesh application proved (bit-identity to
    the legacy builder, `PlanCache` delta patching keyed on
    ``topo_token``) holds here unchanged.
    """
    coeff = np.zeros(nbr.shape, np.float32)
    return _halo.build_halo_plan(
        slot, part, nbr, coeff,
        hierarchy=hierarchy, num_parts=num_parts, device_axis=device_axis,
        weights=weights, with_metrics=with_metrics, cache=cache,
        topo_token=topo_token,
    )


# ---------------------------------------------------------------------------
# device layout helpers (row-keyed, any column count)
# ---------------------------------------------------------------------------

def pack_rows(plan: HaloPlan, arr: np.ndarray, fill=0.0) -> np.ndarray:
    """Global row-order array (n,) or (n, C) -> (S*cap, ...) owned layout."""
    a = np.asarray(arr)
    S = plan.owned_idx.shape[0]
    out = np.full((S, plan.cap) + a.shape[1:], fill, a.dtype)
    m = plan.owned_idx >= 0
    out[m] = a[plan.owned_idx[m]]
    return out.reshape((S * plan.cap,) + a.shape[1:])


def unpack_rows(plan: HaloPlan, dev, n: int) -> np.ndarray:
    """(S*cap, ...) owned layout -> global row-order array."""
    a = np.asarray(dev)
    S = plan.owned_idx.shape[0]
    a = a.reshape((S, plan.cap) + a.shape[1:])
    out = np.zeros((n,) + a.shape[2:], a.dtype)
    m = plan.owned_idx >= 0
    out[plan.owned_idx[m]] = a[m]
    return out


def put_rows(jax_mesh, plan: HaloPlan, arr: np.ndarray):
    """Host global row-order array -> sharded device owned layout."""
    sh = NamedSharding(jax_mesh, P(plan.axes))
    return jax.device_put(jnp.asarray(pack_rows(plan, arr)), sh)


@dataclass(frozen=True)
class InteractArgs:
    """Device-resident executor arguments for one interaction plan."""

    core: tuple     # (nbr, valid, fetch)
    split: tuple    # (interior, boundary)
    stages: tuple   # one flat lane-index array per hop


def interact_args(jax_mesh, plan: HaloPlan) -> InteractArgs:
    """Device-resident executor arguments (placed once per plan, outside
    the timed substep loop) — `stencil.halo_args` minus the coefficient
    table the pair kernel has no use for."""
    sh = NamedSharding(jax_mesh, P(plan.axes))
    S = plan.owned_idx.shape[0]
    put = lambda a: jax.device_put(jnp.asarray(a), sh)
    core = (
        put(plan.nbr_local.reshape(S * plan.cap, plan.K)),
        put(plan.nbr_valid.reshape(S * plan.cap, plan.K)),
        put(plan.ghost_fetch.reshape(S * plan.gcap)),
    )
    split = (
        put(plan.interior_idx.reshape(-1)),
        put(plan.boundary_idx.reshape(-1)),
    )
    stages = tuple(put(s.idx.reshape(S * s.lanes * s.cap)) for s in plan.stages)
    return InteractArgs(core=core, split=split, stages=stages)


# ---------------------------------------------------------------------------
# the shared physics (single definition, both backends)
# ---------------------------------------------------------------------------

def _reflect_walls(x, v):
    """Reflect at the unit-box walls — elementwise float32, so identical
    bits in any layout."""
    lo = x < jnp.float32(0.0)
    x = jnp.where(lo, -x, x)
    v = jnp.where(lo, -v, v)
    hi = x > jnp.float32(1.0)
    x = jnp.where(hi, jnp.float32(2.0) - x, x)
    v = jnp.where(hi, -v, v)
    return x, v


def _integrate(x, v, acc, dt):
    """Kick-drift step + wall reflection (the one integrator)."""
    v2 = v + dt * acc
    x2 = x + dt * v2
    return _reflect_walls(x2, v2)


def _rows_accel(acc, pos_all, mass_all, x_own, nbr, valid, rows, rc2, use_pallas):
    """Accelerations for the subset ``rows`` of owned particles (-1 pads
    drop): gather the row tables, run the fused kernel, scatter back."""
    r = jnp.maximum(rows, 0)
    a_rows = _ops.pair_accel(
        pos_all, mass_all, x_own[r], nbr[r], valid[r], rc2, use_pallas=use_pallas
    )
    safe = jnp.where(rows >= 0, r, x_own.shape[0])  # out of range -> dropped
    return acc.at[safe].set(a_rows, mode="drop")


def _route_cols(prev, stage_meta, stage_idx, fill):
    """Replay the plan's hops for a (rows, C) matrix payload — the value
    routing of `stencil._route` with every column riding one
    ``all_to_all``."""
    C = prev.shape[-1]
    for (ax, lanes, scap), idx in zip(stage_meta, stage_idx):
        src = jnp.clip(idx, 0, prev.shape[0] - 1)
        buf = jnp.where((idx >= 0)[:, None], prev[src], fill).reshape(lanes, scap, C)
        r = jax.lax.all_to_all(buf, ax, split_axis=0, concat_axis=0, tiled=False)
        prev = r.reshape(-1, C)
    return prev


# ---------------------------------------------------------------------------
# reference integrator (the bitwise oracle)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4)
def _reference_fn(use_pallas: bool):
    @jax.jit
    def fn(steps, dt, rc2, x, v, m, nbr, valid):
        def body(_, carry):
            x, v = carry
            acc = _ops.pair_accel(x, m, x, nbr, valid, rc2, use_pallas=use_pallas)
            return _integrate(x, v, acc, dt)
        return jax.lax.fori_loop(0, steps, body, (x, v))
    return fn


def reference_leapfrog(x, v, m, nbr, steps: int, dt: float, radius: float,
                       *, use_pallas: bool = False):
    """``steps`` kick-drift substeps on one device, global row order.
    Consumes the SAME padded (n, K) table as the distributed executor —
    the precondition of their bit-equality."""
    nbr = jnp.asarray(nbr)
    return _reference_fn(bool(use_pallas))(
        jnp.int32(steps), jnp.float32(dt), jnp.float32(float(radius) ** 2),
        jnp.asarray(x, jnp.float32), jnp.asarray(v, jnp.float32),
        jnp.asarray(m, jnp.float32), nbr, nbr >= 0,
    )


# ---------------------------------------------------------------------------
# distributed leapfrog (overlapped ghost-position exchange)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _leapfrog_fn(
    mesh: jax.sharding.Mesh,
    axes: tuple,
    stage_meta: tuple,
    use_pallas: bool,
):
    """Jitted overlapped exchange + fused pair-accel + integrate executor,
    memoized per static (mesh, axes, hop shapes) — ``steps`` is traced,
    so one compiled program serves any substep count."""

    def kernel(steps, dt, rc2, x, v, m, m_gh, nbr, valid, fetch,
               interior, boundary, *stage_idx):
        mass_all = jnp.concatenate([m, m_gh])

        def body(_, carry):
            x, v = carry
            # launch the ghost position exchange; nothing below depends
            # on it until the boundary rows, so XLA can run the interior
            # accelerations inside the collective's async window
            recv = _route_cols(x, stage_meta, stage_idx, jnp.float32(0.0))
            acc = jnp.zeros_like(x)
            # interior rows: every valid neighbor is owned locally
            acc = _rows_accel(acc, x, m, x, nbr, valid, interior, rc2, use_pallas)
            ghosts = jnp.where(
                (fetch >= 0)[:, None],
                recv[jnp.clip(fetch, 0, recv.shape[0] - 1)],
                jnp.float32(0.0),
            )
            pos_all = jnp.concatenate([x, ghosts], axis=0)
            acc = _rows_accel(
                acc, pos_all, mass_all, x, nbr, valid, boundary, rc2, use_pallas
            )
            return _integrate(x, v, acc, dt)

        return jax.lax.fori_loop(0, steps, body, (x, v))

    spec = P(axes)
    in_specs = (P(), P(), P()) + (spec,) * (9 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=(spec, spec),
        check_vma=False,
    ))


def leapfrog_steps(
    jax_mesh,
    plan: HaloPlan,
    x_dev,
    v_dev,
    m_dev,
    mgh_dev,
    args: InteractArgs,
    steps: int,
    dt: float,
    radius: float,
    *,
    use_pallas: bool = False,
):
    """Run ``steps`` distributed kick-drift substeps over the plan's
    layout. ``x_dev``/``v_dev`` are (S*cap, d), ``m_dev`` (S*cap,) and
    ``mgh_dev`` the (S*gcap,) ghost masses from :func:`exchange_rows`
    (masses are constant between migrations — fetched once per plan,
    positions every substep)."""
    fn = _leapfrog_fn(jax_mesh, plan.axes, plan.stage_meta, bool(use_pallas))
    return fn(
        jnp.int32(steps), jnp.float32(dt), jnp.float32(float(radius) ** 2),
        x_dev, v_dev, m_dev, mgh_dev, *args.core, *args.split, *args.stages,
    )


@functools.lru_cache(maxsize=64)
def _exchange_fn(mesh: jax.sharding.Mesh, axes: tuple, stage_meta: tuple):
    """Jitted one-shot ghost fetch of a per-row scalar (the mass vector)."""

    def kernel(m, fetch, *stage_idx):
        recv = _route(m, stage_meta, stage_idx, jnp.float32(0.0))
        return jnp.where(fetch >= 0, recv[jnp.clip(fetch, 0, recv.shape[0] - 1)], 0.0)

    spec = P(axes)
    in_specs = (spec,) * (2 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=spec, check_vma=False,
    ))


def exchange_rows(jax_mesh, plan: HaloPlan, m_dev, args: InteractArgs):
    """Fetch the (S*gcap,) ghost copies of a per-row scalar along the
    plan's hops (once per plan for quantities that only change at
    migrations)."""
    fn = _exchange_fn(jax_mesh, plan.axes, plan.stage_meta)
    return fn(m_dev, args.core[2], *args.stages)


# ---------------------------------------------------------------------------
# multi-payload state migration (one plan, every column travels together)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _move_cols_fn(
    mesh: jax.sharding.Mesh,
    axes: tuple,
    stage_meta: tuple,
    cap_new: int,
    C: int,
):
    """`stencil._move_fn` generalized to a (cap, C) matrix payload: the
    slot ids route once and every state column rides the same hops, so
    position/velocity/mass (and the mesh field in the coupled run)
    migrate under ONE plan."""

    def kernel(u, gid, keep, *stage_idx):
        prev_u, prev_g = u, gid
        for (ax, lanes, scap), idx in zip(stage_meta, stage_idx):
            src = jnp.clip(idx, 0, prev_u.shape[0] - 1)
            sel = idx >= 0
            buf_u = jnp.where(sel[:, None], prev_u[src], 0.0).reshape(lanes, scap, C)
            buf_g = jnp.where(sel, prev_g[src], GID_SENTINEL).reshape(lanes, scap)
            prev_u = jax.lax.all_to_all(
                buf_u, ax, split_axis=0, concat_axis=0, tiled=False
            ).reshape(-1, C)
            prev_g = jax.lax.all_to_all(
                buf_g, ax, split_axis=0, concat_axis=0, tiled=False
            ).reshape(-1)
        kept_g = jnp.where(keep, gid, GID_SENTINEL)
        if stage_meta:
            all_g = jnp.concatenate([kept_g, prev_g])
            all_u = jnp.concatenate([u, prev_u], axis=0)
        else:
            all_g, all_u = kept_g, u
        order = jnp.argsort(all_g, stable=True)[:cap_new]
        out_g = all_g[order]
        return jnp.where((out_g != GID_SENTINEL)[:, None], all_u[order], 0.0)

    spec = P(axes)
    in_specs = (spec,) * (3 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=spec, check_vma=False,
    ))


def move_rows(jax_mesh, mv: MovePlan, old: HaloPlan, u_dev):
    """Execute a compiled multi-column state move: ``u_dev`` (S*cap_old,
    C) in ``old``'s layout -> the new plan's layout (values
    bit-preserved; rows only travel)."""
    sh = NamedSharding(jax_mesh, P(mv.axes))
    S = old.owned_idx.shape[0]
    put = lambda a: jax.device_put(jnp.asarray(a), sh)
    gid = put(old.owned_slot.astype(np.int32).reshape(S * old.cap))
    keep = put(mv.keep.reshape(S * mv.cap_old))
    stages = tuple(put(s.idx.reshape(S * s.lanes * s.cap)) for s in mv.stages)
    fn = _move_cols_fn(
        jax_mesh, mv.axes, mv.stage_meta, int(mv.cap_new), int(u_dev.shape[-1])
    )
    return fn(u_dev, gid, keep, *stages)
