"""Particle application (paper §V-C): N-body short-range forces and
coupled particle-mesh (PIC) on the shared partition core.

`interact` generalizes the mesh halo machinery to cutoff-radius
interaction plans; `state` keys moving particles through the
repartitioning engines with per-event re-registration as they cross
partition boundaries; `pic` couples particles and a `repro.mesh.amr`
mesh under ONE partition with deposit/interpolate transfers and a
single migration carrying both payloads; `simulate` closes the loop
like `repro.mesh.simulate`, gated bit-equal against a single-device
reference.
"""
