"""Slot-tracked particle state keyed through the repartitioning engines.

Particles are host-global arrays (position/velocity/mass in stable row
order — the order both backends integrate, so rows never renumber) plus
a per-row storage slot inside a `HierarchicalRepartitioner`. The engine
partitions its *registered* positions; as particles move, a row's
current position can drift into a region owned by another part. The
:meth:`ParticleEngine.reregister` pass detects those crossers through
the engine's own CurveIndex directory (`halo.owners_from_index` — the
O(B) routing view, never an O(n) scan) and re-registers them with a
``delete`` + ``insert`` round trip, which is the engine's native
per-step insert/delete path: freed slots are reused, summaries update
by delta scatters, and ``topology_version`` bumps so plan caches
observe the population change.

The coupled (PIC) run registers the mesh cells as a static *anchor
prefix* ahead of the particles: anchor rows are inserted first (slots
``0..n_anchor-1``), never re-registered, and never deleted — so freed
slots always belong to particles and the slot space stays cleanly
split, which is what lets one partition, one interaction plan and one
migration carry both entity kinds.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import partitioner as _pt
from repro.core.repartition import HierarchicalRepartitioner
from repro.mesh import halo as _halo


@dataclass
class ParticleSet:
    """Host-global particle state (stable row order, float32)."""

    pos: np.ndarray    # (n, d)
    vel: np.ndarray    # (n, d)
    mass: np.ndarray   # (n,)

    @property
    def n(self) -> int:
        return self.pos.shape[0]


def random_particles(
    n: int, d: int, *, seed: int = 0, v0: float = 0.8, margin: float = 0.1
) -> ParticleSet:
    """Deterministic initial condition: positions away from the walls,
    centered velocities, masses in [0.5, 1.5)."""
    rng = np.random.default_rng(seed)
    pos = (margin + (1.0 - 2.0 * margin) * rng.random((n, d))).astype(np.float32)
    vel = (v0 * (rng.random((n, d)) - 0.5)).astype(np.float32)
    mass = (0.5 + rng.random((n,))).astype(np.float32)
    return ParticleSet(pos=pos, vel=vel, mass=mass)


class ParticleEngine:
    """A particle population (plus optional anchor prefix) registered in
    a hierarchical repartitioning engine, tracked by storage slot."""

    def __init__(
        self,
        points: np.ndarray,
        weights: np.ndarray,
        *,
        plan,
        n_anchor: int = 0,
        cfg: "_pt.PartitionerConfig | None" = None,
        node_threshold: float = 1.20,
        capacity: int | None = None,
        bucket_size: int = 8,
        max_depth: int = 10,
    ):
        n = points.shape[0]
        self.n_anchor = int(n_anchor)
        self.bucket_size = int(bucket_size)
        self.rp = HierarchicalRepartitioner(
            jnp.asarray(points, jnp.float32),
            jnp.asarray(weights, jnp.float32),
            plan=plan,
            cfg=cfg or _pt.PartitionerConfig(use_tree=True, curve="hilbert"),
            node_threshold=node_threshold,
            capacity=capacity or 2 * n,
            bucket_size=bucket_size,
            max_depth=max_depth,
        )
        # from_points fills slots 0..n-1 in row order: anchors first
        self.slots = np.arange(n, dtype=np.int64)
        self.registrations = 0      # events where >= 1 particle crossed
        self.crossers_total = 0

    @property
    def particle_slots(self) -> np.ndarray:
        return self.slots[self.n_anchor:]

    def reregister(self, pos: np.ndarray, weights: np.ndarray) -> int:
        """Re-register the particles whose CURRENT position is owned by a
        different part than their registered slot. ``pos``/``weights``
        are per-particle (anchor rows excluded), in particle row order.
        Returns the crosser count; their slot ids change in-place."""
        pslots = self.particle_slots
        index = self.rp.curve_index(self.bucket_size)
        owner = _halo.owners_from_index(
            index, np.asarray(self.rp.part), np.asarray(pos, np.float32)
        )
        assigned = self.rp.partition_of(pslots)
        cross = np.nonzero(owner != assigned)[0]
        if cross.size:
            self.rp.delete(jnp.asarray(pslots[cross]))
            got = self.rp.insert(
                jnp.asarray(pos[cross], jnp.float32),
                jnp.asarray(weights[cross], jnp.float32),
            )
            self.slots[self.n_anchor + cross] = np.asarray(got)
            assert self.slots[self.n_anchor:].min() >= self.n_anchor, (
                "anchor slots must never be recycled into particles"
            )
            self.registrations += 1
            self.crossers_total += int(cross.size)
        return int(cross.size)

    def update_weights(self, weights: np.ndarray) -> None:
        """Drift the per-row load (all rows, anchor included)."""
        self.rp.update_weights(
            jnp.asarray(weights, jnp.float32), slot_ids=jnp.asarray(self.slots)
        )

    def step(self):
        """One Alg. 3 engine step (incremental re-slice or rebuild)."""
        return self.rp.step()

    def rebuild(self):
        return self.rp.rebuild()

    def partition(self) -> np.ndarray:
        """(n,) current part id per row (anchor + particles)."""
        return self.rp.partition_of(self.slots)
