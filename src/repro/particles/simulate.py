"""End-to-end N-body simulation: the particle consumer that closes the
loop partitioner -> repartition -> migration -> sharding -> metrics.

A short-range force loop (paper §V-C) driven exactly like
`repro.mesh.simulate.run_distributed`: per event the cutoff interaction
table is rebuilt from the current positions, crossers re-register
through the engine's insert/delete path, the `HierarchicalRepartitioner`
answers load drift through the Alg. 3 trigger, a compiled interaction
plan replaces the halo plan, and a multi-column move plan migrates
position+velocity+mass under ONE routing (``interact.move_rows``).
Between events the overlapped leapfrog executor runs ``substeps``
kick-drift sweeps with the ghost-position exchange in flight.

Bit-equality: :func:`run_reference` and :func:`run_distributed` start
from the same `state.random_particles` draw and rebuild the interaction
table with the same :func:`interact.cutoff_neighbors` call per event —
positions agree bitwise by induction, so the tables agree, so the
trajectories agree (``np.array_equal`` on final position AND velocity),
across registration, re-slice and rebuild events alike. That gate is
what ``bench_particles`` holds.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.mesh import halo as _halo
from repro.particles import interact as _ia
from repro.particles import state as _ps


@dataclass(frozen=True)
class ParticleSimConfig:
    d: int = 2
    n: int = 512
    events: int = 12            # outer timesteps (table + partition refresh)
    substeps: int = 4           # kick-drift sweeps per event
    dt: float = 0.01
    radius: float = 0.12        # interaction cutoff (unit box)
    seed: int = 0
    v0: float = 0.8             # initial velocity scale
    margin: float = 0.1         # initial wall clearance
    # crossers re-register every k-th event: the off events exercise the
    # pure device-side migration path (slot sets unchanged), the on
    # events the engine's insert/delete registration path
    reregister_every: int = 2
    # engine knobs
    bucket_size: int = 8
    engine_max_depth: int = 10
    node_threshold: float = 1.20


def initial_particles(cfg: ParticleSimConfig) -> _ps.ParticleSet:
    return _ps.random_particles(
        cfg.n, cfg.d, seed=cfg.seed, v0=cfg.v0, margin=cfg.margin
    )


def _degree_weights(nbr: np.ndarray) -> np.ndarray:
    """Per-particle cost model: 1 + interaction degree (the pair loop's
    actual work), the load the Alg. 3 trigger meters."""
    return (1.0 + (nbr >= 0).sum(axis=1)).astype(np.float32)


def run_reference(
    cfg: ParticleSimConfig, *, use_pallas: bool = False
) -> _ps.ParticleSet:
    """Single-device integration of the schedule (the bitwise oracle)."""
    ps = initial_particles(cfg)
    pos, vel = ps.pos, ps.vel
    for _ in range(cfg.events):
        nbr = _ia.cutoff_neighbors(pos, cfg.radius)
        x, v = _ia.reference_leapfrog(
            pos, vel, ps.mass, nbr, cfg.substeps, cfg.dt, cfg.radius,
            use_pallas=use_pallas,
        )
        pos, vel = np.asarray(x), np.asarray(v)
    return _ps.ParticleSet(pos=pos, vel=vel, mass=ps.mass)


@dataclass
class ParticleSimStats:
    events: int = 0
    repartition_events: int = 0     # events whose assignment changed
    registration_events: int = 0    # events with >= 1 crosser re-registered
    crossers_total: int = 0
    intra_reslices: int = 0
    inter_reslices: int = 0
    rebuilds: int = 0
    moved_total: int = 0
    moved_inter_node: int = 0
    node_local_moves: int = 0
    engine_s: float = 0.0
    move_s: float = 0.0
    force_s: float = 0.0            # leapfrog substep walltime
    neighbor_s: float = 0.0         # host cutoff-table construction
    plan_build_s: float = 0.0
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    k_max: int = 0                  # widest interaction table seen
    n_cells: int = 0                # coupled runs: anchor mesh cells
    halo_metrics: dict = field(default_factory=dict)


def run_distributed(
    cfg: ParticleSimConfig,
    jax_mesh,
    hplan,
    *,
    driver: str = "incremental",
    use_pallas: bool = False,
) -> tuple[_ps.ParticleSet, ParticleSimStats]:
    """Integrate the schedule on a device mesh under one driver.

    ``driver="incremental"`` answers drift with Alg. 3 re-slices and
    moved-rows-only migrations; ``driver="rebuild"`` forces a full
    engine rebuild plus a full redistribute every event — the cold
    baseline the incremental economics are gated against.
    """
    import jax
    import jax.numpy as jnp

    if driver not in ("incremental", "rebuild"):
        raise ValueError(f"unknown driver {driver!r}")
    ps = initial_particles(cfg)
    n, d = ps.n, cfg.d
    eng = _ps.ParticleEngine(
        ps.pos, np.ones((n,), np.float32),
        plan=hplan,
        node_threshold=cfg.node_threshold,
        capacity=2 * n,
        bucket_size=cfg.bucket_size,
        max_depth=cfg.engine_max_depth,
    )
    plan_cache = _halo.PlanCache()

    st = ParticleSimStats()
    pos, vel, mass = ps.pos, ps.vel, ps.mass
    U_dev = None                    # (S*cap, 2d+1) device state [x | v | m]
    prev_plan: "_halo.HaloPlan | None" = None
    quality_args = None
    part_by_slot = np.full((eng.rp.capacity,), -1, np.int64)

    for t in range(cfg.events):
        st.events += 1
        if U_dev is not None:
            # host mirror of the device state (same bits) for the table
            # build, crossing detection and any relayout below
            host_U = _ia.unpack_rows(prev_plan, U_dev, n)
            pos, vel = host_U[:, :d], host_U[:, d:2 * d]

        t0 = time.perf_counter()
        nbr = _ia.cutoff_neighbors(pos, cfg.radius)
        st.neighbor_s += time.perf_counter() - t0
        st.k_max = max(st.k_max, nbr.shape[1])
        w = _degree_weights(nbr)

        # --- engine: re-register crossers, drift weights, Alg. 3 -----------
        t0 = time.perf_counter()
        ncross = 0
        if cfg.reregister_every and t % cfg.reregister_every == 0 and t > 0:
            ncross = eng.reregister(pos, w)
        eng.update_weights(w)
        if driver == "incremental":
            eng.step()
        else:
            eng.rebuild()
        st.engine_s += time.perf_counter() - t0

        part = eng.partition()
        had_prev = part_by_slot[eng.slots] >= 0
        changed = bool(
            (part_by_slot[eng.slots][had_prev] != part[had_prev]).any()
        )
        if changed:
            st.repartition_events += 1
        part_by_slot[:] = -1
        part_by_slot[eng.slots] = part

        # the table changes every event (particles moved), so the plan is
        # rebuilt per event; the per-event token keeps the cache's
        # topology tier honest while move plans share its owner gather
        plan = _ia.build_interact_plan(
            eng.slots, part, nbr,
            hierarchy=hplan, weights=w, with_metrics=False,
            cache=plan_cache, topo_token=(eng.rp.topology_version, t),
        )
        st.plan_build_s += plan.metrics["PlanBuildSeconds"]
        quality_args = (part, nbr, w)
        args = _ia.interact_args(jax_mesh, plan)

        # --- state placement: one migration carries every payload ----------
        host_U = np.concatenate(
            [pos, vel, mass[:, None]], axis=1
        ).astype(np.float32)
        if U_dev is None or ncross:
            # registration events change slot ids — relayout from the host
            # mirror (bit-identical values, rows only re-home)
            U_dev = _ia.put_rows(jax_mesh, plan, host_U)
        elif changed or driver == "rebuild":
            mv = _halo.build_move_plan(
                prev_plan, plan, hierarchy=hplan, full=driver == "rebuild",
                cache=plan_cache,
            )
            st.plan_build_s += mv.metrics["PlanBuildSeconds"]
            t0 = time.perf_counter()
            U_dev = jax.block_until_ready(
                _ia.move_rows(jax_mesh, mv, prev_plan, U_dev)
            )
            st.move_s += time.perf_counter() - t0
            mig = mv.migration
            st.moved_total += int(mig.total_moved)
            st.moved_inter_node += int(getattr(mig, "inter_moved", 0))
            if mv.kind == "device":
                st.node_local_moves += 1
        elif plan.cap != prev_plan.cap:
            U_dev = _ia.put_rows(jax_mesh, plan, host_U)

        # --- leapfrog substeps ---------------------------------------------
        x_dev = U_dev[:, :d]
        v_dev = U_dev[:, d:2 * d]
        m_dev = U_dev[:, 2 * d]
        mgh_dev = _ia.exchange_rows(jax_mesh, plan, m_dev, args)
        t0 = time.perf_counter()
        x_dev, v_dev = jax.block_until_ready(_ia.leapfrog_steps(
            jax_mesh, plan, x_dev, v_dev, m_dev, mgh_dev, args,
            cfg.substeps, cfg.dt, cfg.radius, use_pallas=use_pallas,
        ))
        st.force_s += time.perf_counter() - t0
        U_dev = jnp.concatenate([x_dev, v_dev, m_dev[:, None]], axis=1)
        prev_plan = plan

    st.registration_events = eng.registrations
    st.crossers_total = eng.crossers_total
    st.intra_reslices = eng.rp.stats.intra_reslices
    st.inter_reslices = eng.rp.stats.inter_reslices
    st.rebuilds = eng.rp.stats.rebuilds
    st.plan_cache_hits = plan_cache.stats.halo_hits + plan_cache.stats.move_hits
    st.plan_cache_misses = (
        plan_cache.stats.halo_misses + plan_cache.stats.move_misses
    )
    st.halo_metrics = dict(prev_plan.metrics)
    if quality_args is not None:
        qp, qn, qw = quality_args
        st.halo_metrics.update(
            _halo.plan_quality_metrics(qp, qn, prev_plan.num_parts, weights=qw)
        )
    host_U = _ia.unpack_rows(prev_plan, U_dev, n)
    out = _ps.ParticleSet(
        pos=host_U[:, :d], vel=host_U[:, d:2 * d], mass=mass
    )
    return out, st
