"""AdamW in pure JAX: bf16 params + fp32 master copies/moments,
global-norm clipping, decoupled weight decay.

State layout (a pytree mirroring params):
  {"master": fp32 params, "m": fp32, "v": fp32, "step": int32 scalar}
The bf16 working params are derived from the master copy each step, so
FSDP sharding rules apply uniformly to params and state.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


def init(params: Pytree) -> Pytree:
    f32 = lambda p: p.astype(jnp.float32)  # noqa: E731
    # m and v come from the SAME tree.map structure but must be distinct
    # buffers: identical zeros constants can be deduplicated by the
    # runtime, and donating an aliased buffer twice aborts Execute().
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    zeros2 = lambda p: jnp.tile(jnp.zeros((), jnp.float32), p.shape)  # noqa: E731
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros2, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _is_matrix(p: jax.Array) -> bool:
    return p.ndim >= 2


def update(
    grads: Pytree,
    state: Pytree,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    param_dtype=jnp.bfloat16,
) -> tuple[Pytree, Pytree]:
    """One AdamW step. Returns (new bf16 params, new state).

    Weight decay applies only to >=2-D tensors (norms/biases exempt,
    standard practice).
    """
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, master, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay and _is_matrix(master):
            delta = delta + weight_decay * master
        master = master - lr * delta
        return master, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = treedef.flatten_up_to(state["master"])
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new = [upd(g, ma, m, v) for g, ma, m, v in zip(flat_g, flat_ma, flat_m, flat_v)]
    master = treedef.unflatten([t[0] for t in new])
    m = treedef.unflatten([t[1] for t in new])
    v = treedef.unflatten([t[2] for t in new])
    params = jax.tree.map(
        lambda p, proto: p.astype(proto.dtype if hasattr(proto, "dtype") else param_dtype),
        master,
        grads,
    )
    return params, {"master": master, "m": m, "v": v, "step": step}
