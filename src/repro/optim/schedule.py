"""LR schedules: cosine and WSD (warmup-stable-decay, minicpm
arXiv:2404.06395 — warmup, long stable plateau, sharp exponential decay)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int, decay_frac: float = 0.1,
        floor: float = 0.01):
    """Warmup -> Stable plateau -> exponential Decay over the last
    ``decay_frac`` of training (the minicpm schedule)."""
    step = jnp.asarray(step, jnp.float32)
    decay_steps = jnp.maximum(decay_frac * total, 1.0)
    decay_start = total - decay_steps
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    stable = jnp.asarray(peak_lr, jnp.float32)
    t = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
    decay = peak_lr * jnp.power(floor, t)  # exponential to floor*peak
    out = jnp.where(step < warmup, warm, jnp.where(step < decay_start, stable, decay))
    return out


def get(name: str):
    return {"cosine": cosine, "wsd": wsd}[name]
