from repro.optim import adamw, compression, schedule  # noqa: F401
