"""Gradient compression for cross-pod (DCN) all-reduce.

At 2+ pods the gradient all-reduce crosses the data-center network, which
is an order of magnitude slower than ICI. Two standard compressors with
error feedback (the residual of the compression is carried to the next
step so the expectation is unbiased over time):

* ``topk``: keep the largest-|g| fraction per tensor (sparse, 32x+ at 3%)
* ``int8``: per-tensor symmetric quantization (4x vs fp32, 2x vs bf16)

The compressors are pure functions usable inside jit; the training step
applies them to the *cross-pod* partial sum only (the in-pod ICI
reduce-scatter stays exact), matching hierarchical gradient reduction.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def topk_compress(g: jax.Array, frac: float) -> tuple[jax.Array, jax.Array]:
    """Zero all but the top-|frac| entries. Returns (compressed, residual)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(frac * flat.shape[0]))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    kept = jnp.where(mask, flat, 0.0)
    resid = flat - kept
    return kept.reshape(g.shape), resid.reshape(g.shape)


def int8_compress(g: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Symmetric int8 quantization. Returns (q, scale, residual)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def ef_apply(
    grads: Pytree, residuals: Pytree | None, *, mode: str = "int8", topk_frac: float = 0.03
) -> tuple[Pytree, Pytree, dict]:
    """Error-feedback compression over a gradient pytree.

    grads_in + residual -> compress -> (compressed grads to reduce,
    new residual). ``mode``: "int8" | "topk" | "none".
    """
    if mode == "none":
        return grads, residuals, {"compression_ratio": 1.0}
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    comp_bits = {"int8": 8, "topk": 32}[mode]
    ratios = []

    def one(g, r):
        gin = g.astype(jnp.float32) + r
        if mode == "topk":
            kept, resid = topk_compress(gin, topk_frac)
            ratios.append(topk_frac)
            return kept.astype(g.dtype), resid
        q, scale, resid = int8_compress(gin)
        ratios.append(comp_bits / 32.0)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), resid

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_r = treedef.unflatten([o[1] for o in outs])
    return new_g, new_r, {"compression_ratio": sum(ratios) / max(len(ratios), 1)}
