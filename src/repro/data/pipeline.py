"""Deterministic sharded data pipeline with knapsack sequence packing.

Two layers:

* ``TokenStream`` — a pure function of (step, shard) -> token batch, so a
  restarted/resharded job replays *exactly* the same data (the fault-
  tolerance contract; see runtime/fault_tolerance.py). Synthetic corpus:
  a hash-mixed integer stream with a document-length distribution
  (lognormal) so packing actually matters.

* ``pack_documents`` — the paper's greedy knapsack applied to sequence
  packing: documents laid on a length-weighted curve, sliced into bins of
  ``seq_len`` capacity; intra-bin boundaries produce attention-reset
  positions (returned as segment ids). The same slice guarantees as the
  partitioner: bin loads differ by at most one document (tested).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 1
    seed: int = 0
    mean_doc_len: float = 600.0


def _mix(x: np.ndarray) -> np.ndarray:
    """splitmix64-style hash (vectorized, deterministic)."""
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


def synthetic_tokens(cfg: DataConfig, step: int, shard: int) -> dict[str, np.ndarray]:
    """Pure function of (cfg, step, shard) -> one shard's batch."""
    per_shard = cfg.global_batch // cfg.num_shards
    n = per_shard * cfg.seq_len
    base = np.uint64(cfg.seed) * np.uint64(0x9E3779B97F4A7C15)
    idx = (
        base
        + np.uint64(step) * np.uint64(1_000_003)
        + np.uint64(shard) * np.uint64(777_767_777)
        + np.arange(n, dtype=np.uint64)
    )
    toks = (_mix(idx) % np.uint64(cfg.vocab_size)).astype(np.int32)
    toks = toks.reshape(per_shard, cfg.seq_len)
    labels = np.roll(toks, -1, axis=1)
    mask = np.ones_like(toks, dtype=np.float32)
    mask[:, -1] = 0.0
    return {"tokens": toks, "labels": labels, "mask": mask}


def sample_doc_lengths(cfg: DataConfig, step: int, count: int) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    lens = rng.lognormal(mean=np.log(cfg.mean_doc_len), sigma=0.8, size=count)
    return np.clip(lens.astype(np.int64), 16, cfg.seq_len)


def pack_documents(doc_lens: np.ndarray, seq_len: int) -> list[list[int]]:
    """Greedy knapsack packing of documents into seq_len bins.

    Documents are laid on the curve in decreasing-length order (first-fit-
    decreasing on a weighted segment); each bin's load <= seq_len. Returns
    list of bins, each a list of document indices.
    """
    order = np.argsort(-doc_lens, kind="stable")
    bins: list[list[int]] = []
    loads: list[int] = []
    for i in order:
        l = int(doc_lens[i])
        placed = False
        # first fit over existing bins (greedy knapsack with capacity)
        for b in range(len(bins)):
            if loads[b] + l <= seq_len:
                bins[b].append(int(i))
                loads[b] += l
                placed = True
                break
        if not placed:
            bins.append([int(i)])
            loads.append(l)
    return bins


def packing_efficiency(doc_lens: np.ndarray, bins: list[list[int]], seq_len: int) -> float:
    used = sum(int(doc_lens[i]) for b in bins for i in b)
    return used / max(len(bins) * seq_len, 1)


def padded_baseline_efficiency(doc_lens: np.ndarray, seq_len: int) -> float:
    """One document per row, padded — the no-packing baseline."""
    return float(doc_lens.sum()) / max(len(doc_lens) * seq_len, 1)


class ShardedLoader:
    """Iterator facade used by the train launcher."""

    def __init__(self, cfg: DataConfig, shard: int, start_step: int = 0):
        self.cfg = cfg
        self.shard = shard
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        out = synthetic_tokens(self.cfg, self.step, self.shard)
        self.step += 1
        return out

    def state(self) -> dict:
        return {"step": self.step, "shard": self.shard}
