"""Sharding rules: logical model axes -> mesh axes.

Rules live in config (``ShardingRules``), not in model code, so the perf
hillclimb can move axes without touching models. Conventions:

* params are 2-D sharded FSDP x TP: the "d_model-ish" dim over
  ``rules.fsdp`` (usually "data"), the "wide" dim (heads/ffn/vocab/
  experts) over ``rules.tp`` (usually "model"). Optimizer state mirrors
  params. The "pod" axis is pure DCN data parallel (batch only).
* activations are constrained at block boundaries to
  P(batch=rules.batch, seq=rules.seq) — sequence parallelism keeps the
  remat stash per device O(S/model) for long sequences.
* decode caches shard batch over ``rules.cache_batch`` and KV heads /
  SSM heads over ``rules.cache_heads``.

GSPMD handles non-divisible dims by padding (e.g. 56 heads on 16-way TP);
the roofline notes where that costs real FLOPs.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat as _compat
from repro.configs.base import ModelConfig, ShardingRules

_CTX = threading.local()


@contextlib.contextmanager
def activation_mesh(mesh: Mesh, rules: ShardingRules):
    """Enable activation sharding constraints inside model code."""
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules)
    try:
        yield
    finally:
        _CTX.val = prev


def _current() -> tuple[Mesh, ShardingRules] | None:
    return getattr(_CTX, "val", None)


def _axes_in(mesh: Mesh, axes) -> Any:
    """Filter a spec entry to axes that exist in the mesh."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    got = tuple(a for a in axes if a in mesh.axis_names)
    return got if got else None


def constrain(x: jax.Array, *spec_entries) -> jax.Array:
    """with_sharding_constraint if an activation mesh is active, else no-op."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, _ = ctx
    entries = tuple(_axes_in(mesh, e) for e in spec_entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


def constrain_activations(x: jax.Array) -> jax.Array:
    """(B, S, D) block-boundary constraint: batch x seq sharding."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim == 3:
        return constrain(x, rules.batch, rules.seq, None)
    return x


def constrain_blocked_attention(
    qb: jax.Array, kb: jax.Array, vb: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Constraints for the blocked flash-attention tensors.

    qb (nq, B, KV, G, bq, hd), kb/vb (nk, B, KV, bk, hd). Without these,
    GSPMD shards the stacked-block dim and the per-block dynamic_slice
    triggers 'involuntary full rematerialization' (replicate + repartition
    of the whole q tensor per block — an XLA SPMD warning and a large
    collective term). Pin: block dim replicated, batch on rules.batch,
    KV heads on rules.tp when divisible.
    """
    ctx = _current()
    if ctx is None:
        return qb, kb, vb
    mesh, rules = ctx
    if not rules.blocked_attn:
        return qb, kb, vb
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = _axes_in(mesh, rules.tp)
    kv = qb.shape[2]
    heads_ax = tp if (tp is not None and kv % ax_size.get(tp, 1) == 0) else None
    qb = constrain(qb, None, rules.batch, heads_ax, None, None, None)
    kb = constrain(kb, None, rules.batch, heads_ax, None, None)
    vb = constrain(vb, None, rules.batch, heads_ax, None, None)
    return qb, kb, vb


def constrain_moe(x: jax.Array, kind: str, num_experts: int) -> jax.Array:
    """Sharding constraints for MoE dispatch intermediates.

    GSPMD loses propagation through the per-row sort/scatter chain and
    falls back to full replication (measured 320 GiB for the (B, E, C,
    2F) expert activation at mixtral train_4k). Layouts:
      'tokens'  (B, TK, D)      -> (batch, None, None)
      'buf'     (B, E, C, D)    -> (batch, expert?, None, None)
      'h'       (B, E, C, F)    -> (batch, expert?, None, tp-if-no-EP)
    Expert axis is used only when E divides it (qwen3 128e); otherwise
    the FFN dim takes the TP axis (mixtral 8e).
    """
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = _axes_in(mesh, rules.expert)
    ep_ok = ep is not None and num_experts % ax_size.get(ep, 1) == 0
    e_ax = ep if ep_ok else None
    f_ax = None if ep_ok else rules.tp
    if kind == "tokens":
        return constrain(x, rules.batch, None, None)
    if kind == "buf":
        return constrain(x, rules.batch, e_ax, None, None)
    if kind == "h":
        return constrain(x, rules.batch, e_ax, None, f_ax)
    return x


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _sanitize(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim.

    pjit argument shardings are strict (unlike internal GSPMD propagation,
    which pads); replication on the offending dim is always legal and the
    roofline reports the cost (e.g. minicpm's odd 122,753 vocab).
    """
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        total = 1
        for a in axes:
            total *= ax_size.get(a, 1)
        out.append(entry if total and shape[i] % total == 0 else None)
    return P(*out)


def _param_spec(path: str, leaf, cfg: ModelConfig, rules: ShardingRules) -> P:
    """PartitionSpec for one parameter, keyed by its tree path."""
    fsdp, tp, ep = rules.fsdp, rules.tp, rules.expert
    nd = len(leaf.shape)
    stacked = path.startswith("blocks") or path.startswith("enc_blocks") or path.startswith("dec_blocks")
    lead = (None,) if stacked else ()

    name = path.split("/")[-1]
    # MoE stacked experts (L, E, D, F) — must match before the generic
    # wi/wo rules below
    if "moe" in path and nd - len(lead) == 3:
        if ep is not None and cfg.num_experts % 16 == 0:
            return P(*lead, ep, fsdp, None)     # expert parallelism
        return P(*lead, None, fsdp, tp)         # TP within experts (mixtral)
    if name in ("embed",):
        return P(tp, fsdp)                      # (V, D)
    if name in ("lm_head",):
        return P(fsdp, tp)                      # (D, V)
    if name in ("wq", "wk", "wv", "wi", "w_in", "w_z", "w_x", "w_b", "w_c", "w_dt"):
        return P(*lead, fsdp, tp)               # (D, wide)
    if name in ("wo", "w_out"):
        return P(*lead, tp, fsdp)               # (wide, D)
    if name == "router":
        return P(*lead, fsdp, None)             # (D, E) — replicate experts dim
    # norms / scalars / vectors: replicate (tiny)
    return P(*([None] * nd))


def param_shardings(
    mesh: Mesh, cfg: ModelConfig, rules: ShardingRules, params_shapes: Any
) -> Any:
    """Pytree of NamedSharding matching a params (shape) pytree."""

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec = _param_spec(pstr, leaf, cfg, rules)
        spec = P(*(_axes_in(mesh, e) for e in spec))
        spec = _sanitize(spec, tuple(leaf.shape), mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(mesh: Mesh, cfg, rules, opt_shapes: Any, param_sh: Any) -> Any:
    """Optimizer state mirrors param shardings (master/m/v); step replicated."""
    rep = NamedSharding(mesh, P())
    return {
        "master": param_sh,
        "m": param_sh,
        "v": param_sh,
        "step": rep,
    }


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, cfg: ModelConfig, rules: ShardingRules, batch_shapes: dict) -> dict:
    b = _axes_in(mesh, rules.batch)
    out = {}
    for k, v in batch_shapes.items():
        if k == "cache":
            out[k] = cache_shardings(mesh, cfg, rules, v)
            continue
        if k in ("token", "pos"):
            spec = P(b)
        elif hasattr(v, "ndim") and v.ndim == 3:  # frames / patches (B, T, D)
            spec = P(b, None, None)
        else:  # tokens / labels / mask (B, S)
            spec = P(b, None)
        spec = _sanitize(spec, tuple(v.shape), mesh)  # long_500k has B=1
        out[k] = NamedSharding(mesh, spec)
    return out


def logits_sharding(mesh: Mesh, cfg: ModelConfig, rules: ShardingRules, shape: tuple) -> NamedSharding:
    """(B, S, V) prefill logits: batch x vocab sharded, sanitized for odd
    vocab sizes (whisper 51,865; minicpm 122,753)."""
    b = _axes_in(mesh, rules.batch)
    tp = _axes_in(mesh, rules.tp)
    spec = _sanitize(P(b, None, tp), shape, mesh)
    return NamedSharding(mesh, spec)


def cache_shardings(mesh: Mesh, cfg: ModelConfig, rules: ShardingRules, cache_shapes: Any) -> Any:
    cb = _axes_in(mesh, rules.cache_batch)
    ch = _axes_in(mesh, rules.cache_heads)
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _fits(axes, dim) -> bool:
        if axes is None:
            return False
        alist = (axes,) if isinstance(axes, str) else tuple(axes)
        total = 1
        for a in alist:
            total *= ax_size.get(a, 1)
        return dim % total == 0

    def one(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = len(leaf.shape)
        if name in ("k", "v") and nd == 5:      # (L, B, S, KV, hd)
            L, B, S, KV, hd = leaf.shape
            b = cb if _fits(cb, B) else None
            # prefer KV-head sharding; fall back to sequence sharding when
            # heads don't divide (GQA kv=1..4) or batch can't shard (B=1)
            if _fits(ch, KV):
                spec = P(None, b, None, ch, None)
            elif _fits(ch, S):
                spec = P(None, b, ch, None, None)
            else:
                spec = P(None, b, None, None, None)
            return NamedSharding(mesh, spec)
        if name == "state" and nd == 5:          # (L, B, nh, hp, N)
            L, B, nh, hp, N = leaf.shape
            b = cb if _fits(cb, B) else None
            h = ch if _fits(ch, nh) else None
            return NamedSharding(mesh, P(None, b, h, None, None))
        if name == "memory" and nd == 3:         # (B, T_enc, D)
            B = leaf.shape[0]
            b = cb if _fits(cb, B) else None
            return NamedSharding(mesh, P(b, None, None))
        return NamedSharding(mesh, P(*([None] * nd)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(treedef, [one(p, l) for p, l in flat])


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Hierarchical (node -> device) mesh helpers + two-stage summary exchange
# ---------------------------------------------------------------------------

def make_node_device_mesh(
    num_nodes: int,
    devices_per_node: int,
    node_axis: str = "node",
    device_axis: str = "device",
) -> Mesh:
    """2-D ``(node, device)`` mesh over the available devices — the JAX
    rendering of the paper's hybrid model (MPI across nodes, threads
    within one). Axis order is node-major so ``P((node, device))`` shards
    a curve-ordered array into node-contiguous chunks."""
    from repro.launch.mesh import make_mesh

    return make_mesh((num_nodes, devices_per_node), (node_axis, device_axis))


def two_stage_bucket_slice(
    w_leaf: jax.Array,
    node_keys: jax.Array,
    *,
    plan,
    num_dev_shards: int,
) -> jax.Array:
    """Two-level global knapsack over bucket summaries; part id per LOCAL
    tree node. Runs inside ``shard_map``; ``plan`` is a
    `partitioner.HierarchyPlan`.

    Stage 1 (intra-node): ``all_gather`` of the raw (M,) per-shard
    summaries over the device axis only — full within-node detail, never
    crossing the node boundary. Stage 2 (inter-node): each node compacts
    its sorted records into ``plan.summary_bins`` (default M) equal-count
    bins and ONE ``all_gather`` over the node axis exchanges those — the
    inter-node payload is O(B * nodes), not O(B * devices); see
    `summary_exchange_bytes` for the exact accounting. The nested
    knapsack (`knapsack.two_level_slice`) then slices the bins into node
    slices and per-node device parts, and local buckets map into the
    result by bin boundary key. Granularity note: because a node's curve
    slice can contain buckets resident on every other node, BOTH levels
    slice the aggregated bins — balance granularity on this path is one
    bin (up to ``num_dev_shards`` merged bucket records) at the node
    and device level alike.

    With ``plan.num_nodes == 1`` stage 2 vanishes and the fine knapsack
    runs on the full stage-1 records — bit-identical to the historical
    flat ``distributed_bucket_partition`` math, at full bucket
    granularity.
    """
    from repro.core import knapsack as _knapsack

    M = node_keys.shape[0]
    N, D = plan.num_nodes, plan.devices_per_node
    all_k = jax.lax.all_gather(node_keys, plan.device_axis).reshape(-1)
    all_w = jax.lax.all_gather(w_leaf, plan.device_axis).reshape(-1)
    order = jnp.argsort(all_k, stable=True)
    k_sorted, w_sorted = all_k[order], all_w[order]

    if N == 1:
        _, _, part_rank = _knapsack.two_level_slice(w_sorted, 1, D)
        part_flat = (
            jnp.zeros((num_dev_shards * M,), jnp.int32).at[order].set(part_rank)
        )
        me = jax.lax.axis_index(plan.device_axis)
        return jax.lax.dynamic_slice(part_flat, (me * M,), (M,))

    # node-aggregate: A equal-count bins over the node-sorted records
    # (sentinel-keyed empty records carry 0 weight and pool at the tail)
    R = num_dev_shards * M
    A = plan.summary_bins or M
    bin_id = (jnp.arange(R, dtype=jnp.int32) * A) // R
    bin_w = jax.ops.segment_sum(w_sorted, bin_id, num_segments=A)
    # bin b's FIRST record is the smallest i with (i*A)//R == b, i.e.
    # ceil(b*R/A) — floor lands on the last record of bin b-1 whenever A
    # does not divide R, mis-keying the boundary
    bin_first = (jnp.arange(A, dtype=jnp.int32) * R + A - 1) // A
    bin_k = k_sorted[bin_first]
    gk = jax.lax.all_gather(bin_k, plan.node_axis).reshape(-1)     # (N*A,)
    gw = jax.lax.all_gather(bin_w, plan.node_axis).reshape(-1)
    gorder = jnp.argsort(gk, stable=True)
    gk_s = gk[gorder]
    _, _, part_bin = _knapsack.two_level_slice(gw[gorder], N, D)
    # local buckets inherit the part of the last bin whose first key is
    # <= their key (parts are non-decreasing along the sorted bins)
    idx = jnp.clip(
        jnp.searchsorted(gk_s, node_keys, side="right").astype(jnp.int32) - 1,
        0, N * A - 1,
    )
    return part_bin[idx]


def summary_exchange_bytes(
    plan,
    buckets_per_shard: int,
    *,
    bytes_per_record: int = 8,
) -> dict:
    """Exact inter-node byte accounting of one summary exchange (the
    reslice hot loop's only communication). A record is one bucket's
    (uint32 key, float32 weight).

    * **flat** — one all_gather over all ``N*D`` shards: every device
      ingests every remote shard's raw records.
    * **two_level** — stage 1 is intra-node (0 inter-node bytes); stage 2
      ingests the remote nodes' aggregated bins only.

    This is the closed-form *model*; the benchmark gate
    (`benchmarks/bench_hierarchy.py --smoke`) measures the same quantity
    from the compiled programs' replica groups
    (`launch.dryrun.parse_inter_node_bytes`) and holds
    ``two_level < flat`` against that measurement, with this model
    reported alongside for drift visibility.
    """
    N, D = plan.num_nodes, plan.devices_per_node
    M = int(buckets_per_shard)
    A = plan.summary_bins or M
    # per-device delivery convention — the one parse_inter_node_bytes
    # measures: every device of a gather's replica group receives each
    # remote member's operand. Flat: all N*D devices each ingest the
    # (N-1)*D remote shards' M records. Two-level: the node-axis gather
    # runs once per device column, so all N*D devices each ingest the
    # (N-1) remote nodes' A bins. Ratio: D*M/A (= D at the default A=M).
    flat = N * D * (N - 1) * D * M * bytes_per_record
    two_level = N * D * (N - 1) * A * bytes_per_record
    return {
        "flat_inter_node_bytes": int(flat),
        "two_level_inter_node_bytes": int(two_level),
        "intra_node_bytes": int(N * D * (D - 1) * M * bytes_per_record),
        "records_per_shard": M,
        "bins_per_node": int(A),
    }


# ---------------------------------------------------------------------------
# dynamic element placement (repartitioning engine integration)
# ---------------------------------------------------------------------------

def curve_sharding(mesh: Mesh, axis: str) -> NamedSharding:
    """Sharding for curve-ordered element arrays: shard i of ``axis`` holds
    the i-th contiguous chunk of the global SFC order (the layout produced
    by `repro.core.partitioner.distributed_partition`)."""
    return NamedSharding(mesh, P(axis))


def apply_repartition(
    mesh: Mesh,
    axis: str,
    payload: jax.Array,
    part: jax.Array,
    *,
    capacity: int | None = None,
    fill_value=0,
):
    """Move rows of ``payload`` (sharded on dim 0 over ``axis``) to the
    shard given by ``part`` — the output of a `Repartitioner` step,
    `distributed_reslice`, or the bucket-summary path
    (`distributed_bucket_partition` / `DistributedBucketRepartitioner`,
    whose assignments are already in this original row layout: the
    bucket path never moves rows to *compute* the partition, so this
    exchange is the only data motion in the whole cycle). Invalid rows
    (part < 0) are parked on their current shard and masked out of the
    result.

    Returns (received, valid_mask) in the fixed-capacity layout of
    `migration.execute_shard_exchange`. ``capacity`` is per (src, dst)
    pair *including* stay-home rows; the default — one shard's full row
    count — is the smallest value that can never drop a row (a pair
    cannot carry more than its source shard holds). Pass something
    smaller only with a migration plan proving the worst pair is small.
    """
    from repro.core import migration as _migration

    nshards = mesh.shape[axis]
    n_rows = payload.shape[0]
    if capacity is None:
        capacity = max(1, int(np.ceil(n_rows / nshards)))
    # P(axis) = contiguous chunks: row r lives on shard r*S//n
    me_rows = (jnp.arange(n_rows) * nshards) // n_rows  # park invalid rows locally
    dest = jnp.where(part >= 0, part, me_rows).astype(jnp.int32)
    recv, valid = _migration.execute_shard_exchange(
        mesh, axis, payload, dest, capacity, fill_value=fill_value
    )
    return recv, valid


# ---------------------------------------------------------------------------
# Distributed query serving (paper §V-A over a sharded CurveIndex)
# ---------------------------------------------------------------------------
#
# The serving layout: the CurveIndex's sorted arrays are split into
# contiguous chunks over the mesh axis (shard rank = curve rank, the same
# layout `distributed_partition` produces), with chunk boundaries cut at
# KEY-RUN boundaries (a run of equal keys never spans two chunks — the
# DistributedQueryEngine places chunks this way). A query batch arriving
# sharded P(axis), with its curve keys precomputed by the caller
# (`curve_index.query_keys` — coordinate quantization for point-keyed
# indexes, the kd-tree root→leaf walk for tree-backed ones), is answered
# with exactly two all_to_all exchanges:
#
#   1. find each local query's *owner* shard by binary search over the
#      shards' first keys (one tiny all_gather) and exchange query
#      coordinates + keys to owners;
#   2. owners answer locally against their chunk (point location: exact
#      key-run scan; kNN: curve-window candidate scan, distances + ids
#      bit-packed into one reply buffer) and the answers ride the reverse
#      all_to_all back in the mirrored lane layout — each source shard
#      gathers its results at [owner, staged position] locally, so no
#      slot ids are ever exchanged.
#
# Because queries arrive pre-keyed, the kernels never touch the
# quantization frame: tree-backed indexes (bucket keys addressed by a
# tree walk the kernel could not run) shard into exactly the same layout
# as point-keyed ones.
#
# Per-(src,dst) lane capacity is a static parameter. At the default
# (``lane_cap=None`` → the local query count) routing can never drop a
# query regardless of skew. A production engine provisions smaller lanes
# (memory ∝ nshards * lane_cap): the kernels then also return each row's
# staged lane position so the caller can detect overflow (``pos >= cap``
# means the row was dropped at the hot owner's lane) and re-dispatch only
# the dropped rows next round — skew degrades into extra rounds, never
# into wrong answers. Run-aligned chunking makes the key-run scan exact
# (a miss is certified iff the run fits ``bucket_cap``, identical to the
# single-host semantics); kNN windows clipped at a chunk seam cost a
# little recall there — the same CUTOFF economics as the local path.


def _exchange(x, axis):
    """Lane s of my buffer -> shard s (flattened on receive)."""
    r = jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)
    return r.reshape((-1,) + r.shape[2:])


def _answer_pl(pts_loc, ids_loc, keys_loc, rq, rqk, bucket_cap):
    """Exact point location of routed queries against the local chunk;
    (r, 3) int32 columns (found, id, ok). Shared by the flat and
    two-level serving kernels."""
    n_loc = keys_loc.shape[0]
    lo_i = jnp.searchsorted(keys_loc, rqk, side="left").astype(jnp.int32)
    hi_i = jnp.searchsorted(keys_loc, rqk, side="right").astype(jnp.int32)
    offs = jnp.arange(bucket_cap, dtype=jnp.int32)
    pos = lo_i[:, None] + offs[None, :]
    cand = jnp.clip(pos, 0, n_loc - 1)
    hit = jnp.all(pts_loc[cand] == rq[:, None, :], axis=-1) & (pos < hi_i[:, None])
    found = jnp.any(hit, axis=1)
    slot = jnp.argmax(hit, axis=1)
    gid = ids_loc[cand[jnp.arange(rq.shape[0]), slot]]
    # run-aligned chunking guarantees the whole key-equal run lives in
    # this chunk, so [lo_i, hi_i) is the query's GLOBAL run and the miss
    # certificate is identical to queries._point_location's
    ok = found | ((hi_i - lo_i) <= bucket_cap)
    return jnp.stack(
        [found.astype(jnp.int32), jnp.where(found, gid, -1), ok.astype(jnp.int32)],
        axis=-1,
    )


def _answer_knn(pts_loc, ids_loc, keys_loc, rq, rqk, k, win):
    """kNN candidate-window scan of routed queries against the local
    chunk; distances + bit-cast ids packed into one (r, 2k) reply buffer
    so each serving round stays at one reply exchange per routing hop."""
    from repro.core import curve_index as _ci

    n_loc = keys_loc.shape[0]
    pos0 = jnp.searchsorted(keys_loc, rqk, side="left").astype(jnp.int32)
    start = jnp.clip(pos0 - win // 2, 0, jnp.maximum(n_loc - win, 0))
    offs = jnp.arange(win, dtype=jnp.int32)
    pos = start[:, None] + offs[None, :]
    cand = jnp.clip(pos, 0, n_loc - 1)
    # pos < n_loc: when win exceeds the chunk, clipped indices repeat —
    # without the bound one point could fill several of the k slots
    valid = (pos < n_loc) & (keys_loc[cand] != jnp.uint32(_ci.KEY_SENTINEL))
    d2 = jnp.sum((pts_loc[cand] - rq[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg_top, top_i = jax.lax.top_k(-d2, k)
    gids = ids_loc[jnp.take_along_axis(cand, top_i, axis=1)]
    gids = jnp.where(jnp.isfinite(-neg_top), gids, -1)
    dist = jnp.sqrt(jnp.maximum(-neg_top, 0.0))
    return jnp.concatenate(
        [dist, jax.lax.bitcast_convert_type(gids, jnp.float32)], axis=1
    )


@functools.lru_cache(maxsize=32)
def _query_serve_fn(
    mesh: Mesh,
    axis: str,
    mode: str,          # "pl" | "knn"
    k: int,
    bucket_cap: int,
    win: int,
    cap: int,           # per-(src,dst) lane capacity (rows)
):
    """Jitted two-all_to_all query-serving executor, memoized per static
    config (shard_map must run under jit — see partitioner._reslice_fn)."""
    from repro.core import curve_index as _ci
    from repro.core import migration as _migration

    nshards = mesh.shape[axis]

    def kernel(pts_loc, ids_loc, keys_loc, q_loc, qk):
        # owner shard: last shard whose first key <= qk
        firsts = jax.lax.all_gather(keys_loc[0], axis)          # (nshards,)
        owner = _ci.owner_from_firsts(firsts, qk)
        (buf_q, buf_k), pos_of = _migration.stage_rows_by_dest(
            owner, (q_loc, qk), nshards, cap, (0.0, _ci.KEY_SENTINEL)
        )
        rq = _exchange(buf_q, axis)                              # (nshards*cap, d)
        rqk = _exchange(buf_k, axis)
        # answers come back in the mirrored lane layout, so each source
        # shard gathers its own results at [owner, pos] locally — no slot
        # ids travel in either direction. Rows with pos_of >= cap were
        # dropped at staging (lane overflow): the gather is clamped and
        # the caller masks them out and re-dispatches.

        def reply(ans):                                          # (r, c) -> (q_loc, c)
            back = jax.lax.all_to_all(
                ans.reshape(nshards, cap, -1), axis,
                split_axis=0, concat_axis=0, tiled=False,
            )
            return back[owner, jnp.minimum(pos_of, cap - 1)]

        if mode == "pl":
            return reply(_answer_pl(pts_loc, ids_loc, keys_loc, rq, rqk, bucket_cap)), pos_of
        got = reply(_answer_knn(pts_loc, ids_loc, keys_loc, rq, rqk, k, win))
        return got[:, :k], jax.lax.bitcast_convert_type(got[:, k:], jnp.int32), pos_of

    out_specs = (P(axis), P(axis)) if mode == "pl" else (P(axis), P(axis), P(axis))
    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=out_specs,
        check_vma=False,
    ))


@functools.lru_cache(maxsize=32)
def _query_serve_fn_2d(
    mesh: Mesh,
    node_axis: str,
    device_axis: str,
    mode: str,          # "pl" | "knn"
    k: int,
    bucket_cap: int,
    win: int,
    cap: int,           # per-(src,dst) inter-node lane capacity (rows)
):
    """Two-level (key -> node -> device) query-serving executor.

    The flat kernel routes every query through one all_to_all whose lanes
    span all ``N*D`` shards — every mis-owned query may cross the node
    boundary. Here routing is hierarchical, mirroring the directory:

      1. **inter-node hop** — owner *node* by binary search over the N
         node first-keys; one all_to_all over the node axis (N lanes).
         Queries already on their owner node ride the self-lane, which
         never leaves the node.
      2. **node-local lookup** — ON the owner node, the owner *device*
         by search over the node's D device first-keys; one all_to_all
         over the device axis only. This stage (and its reply) is pure
         intra-node traffic.

    Answers retrace both hops through the mirrored-lane gathers, so slot
    ids never travel. Owner shards are identical to the flat kernel's
    (`curve_index.owner_from_firsts` applied per level over globally
    sorted firsts), hence so are the answers.

    Lane overflow can only happen at hop 1 (``cap`` rows per node lane):
    hop 2 sizes its device lanes at ``s_node * cap`` — the whole incoming
    buffer — so a staged query is never dropped intra-node. The returned
    positions are therefore hop-1 positions, interpreted exactly like the
    flat kernel's (``pos >= cap`` → dropped, re-dispatch).
    """
    from repro.core import curve_index as _ci
    from repro.core import migration as _migration

    s_node = mesh.shape[node_axis]
    s_dev = mesh.shape[device_axis]
    axes = (node_axis, device_axis)

    def kernel(pts_loc, ids_loc, keys_loc, q_loc, qk):
        firsts_dev = jax.lax.all_gather(keys_loc[0], device_axis)   # (S_d,) my node
        node_firsts = jax.lax.all_gather(firsts_dev[0], node_axis)  # (S_n,)
        # --- hop 1: inter-node (N lanes; self-lane stays on-node) ---------
        owner_node = _ci.owner_from_firsts(node_firsts, qk)
        (buf_q, buf_k), pos_a = _migration.stage_rows_by_dest(
            owner_node, (q_loc, qk), s_node, cap, (0.0, _ci.KEY_SENTINEL)
        )
        rq1 = _exchange(buf_q, node_axis)                   # (S_n*cap, d)
        rqk1 = _exchange(buf_k, node_axis)
        # --- hop 2: node-local device lookup (intra-node only) ------------
        owner_dev = _ci.owner_from_firsts(firsts_dev, rqk1)
        cap2 = s_node * cap
        (buf2, buf2k), pos_b = _migration.stage_rows_by_dest(
            owner_dev, (rq1, rqk1), s_dev, cap2, (0.0, _ci.KEY_SENTINEL)
        )
        rq = _exchange(buf2, device_axis)                   # (S_d*cap2, d)
        rqk = _exchange(buf2k, device_axis)

        def reply(ans):                                     # (S_d*cap2, c) -> (q_loc, c)
            back_b = jax.lax.all_to_all(
                ans.reshape(s_dev, cap2, -1), device_axis,
                split_axis=0, concat_axis=0, tiled=False,
            )[owner_dev, pos_b]                             # (cap2, c) on owner node
            back_a = jax.lax.all_to_all(
                back_b.reshape(s_node, cap, -1), node_axis,
                split_axis=0, concat_axis=0, tiled=False,
            )
            return back_a[owner_node, jnp.minimum(pos_a, cap - 1)]

        if mode == "pl":
            return reply(_answer_pl(pts_loc, ids_loc, keys_loc, rq, rqk, bucket_cap)), pos_a
        got = reply(_answer_knn(pts_loc, ids_loc, keys_loc, rq, rqk, k, win))
        return got[:, :k], jax.lax.bitcast_convert_type(got[:, k:], jnp.int32), pos_a

    spec = P(axes)
    out_specs = (spec, spec) if mode == "pl" else (spec, spec, spec)
    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec),
        out_specs=out_specs,
        check_vma=False,
    ))


def _serve_cap(mesh: Mesh, axis, n_rows: int, lane_cap: "int | None") -> int:
    """Effective per-lane capacity: the local query count (no-drop
    worst-case sizing) clipped to the caller's provisioned ``lane_cap``."""
    axes = axis if isinstance(axis, tuple) else (axis,)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    qcap = max(1, n_rows // nshards)
    return qcap if lane_cap is None else max(1, min(int(lane_cap), qcap))


def serve_point_location(
    mesh: Mesh,
    axis: "str | tuple[str, str]",
    pts_s: jax.Array,
    ids_s: jax.Array,
    keys_s: jax.Array,
    queries: jax.Array,
    qkeys: jax.Array,
    *,
    bucket_cap: int = 64,
    lane_cap: "int | None" = None,
) -> tuple[jax.Array, jax.Array, int]:
    """Distributed exact point location. ``queries`` (Q, d) and their
    precomputed curve keys ``qkeys`` (Q,) uint32 sharded over ``axis``,
    Q divisible by the shard count; returns ((Q, 3) int32 columns
    (found, id, ok), (Q,) staged lane positions, effective lane cap).
    Rows with ``pos >= cap`` overflowed their owner's lane and carry
    garbage — re-dispatch them. A ``(node_axis, device_axis)`` tuple
    routes hierarchically (key -> node -> device; see
    `_query_serve_fn_2d`) — answers are identical to the flat routing on
    the same chunk layout."""
    cap = _serve_cap(mesh, axis, queries.shape[0], lane_cap)
    if isinstance(axis, tuple):
        fn = _query_serve_fn_2d(mesh, *axis, "pl", 0, bucket_cap, 0, cap)
    else:
        fn = _query_serve_fn(mesh, axis, "pl", 0, bucket_cap, 0, cap)
    res, pos = fn(pts_s, ids_s, keys_s, queries, qkeys)
    return res, pos, cap


def serve_knn(
    mesh: Mesh,
    axis: "str | tuple[str, str]",
    pts_s: jax.Array,
    ids_s: jax.Array,
    keys_s: jax.Array,
    queries: jax.Array,
    qkeys: jax.Array,
    *,
    k: int = 3,
    win: int = 192,
    lane_cap: "int | None" = None,
) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """Distributed approximate kNN over the sharded curve. Returns
    ((Q, k) distances, (Q, k) ids, (Q,) lane positions, effective lane
    cap); invalid slots inf/-1, rows with ``pos >= cap`` dropped at the
    owner lane (re-dispatch). A ``(node_axis, device_axis)`` tuple routes
    hierarchically, as in `serve_point_location`."""
    cap = _serve_cap(mesh, axis, queries.shape[0], lane_cap)
    if isinstance(axis, tuple):
        fn = _query_serve_fn_2d(mesh, *axis, "knn", k, 0, win, cap)
    else:
        fn = _query_serve_fn(mesh, axis, "knn", k, 0, win, cap)
    d, g, pos = fn(pts_s, ids_s, keys_s, queries, qkeys)
    return d, g, pos, cap
