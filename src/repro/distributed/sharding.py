"""Sharding rules: logical model axes -> mesh axes.

Rules live in config (``ShardingRules``), not in model code, so the perf
hillclimb can move axes without touching models. Conventions:

* params are 2-D sharded FSDP x TP: the "d_model-ish" dim over
  ``rules.fsdp`` (usually "data"), the "wide" dim (heads/ffn/vocab/
  experts) over ``rules.tp`` (usually "model"). Optimizer state mirrors
  params. The "pod" axis is pure DCN data parallel (batch only).
* activations are constrained at block boundaries to
  P(batch=rules.batch, seq=rules.seq) — sequence parallelism keeps the
  remat stash per device O(S/model) for long sequences.
* decode caches shard batch over ``rules.cache_batch`` and KV heads /
  SSM heads over ``rules.cache_heads``.

GSPMD handles non-divisible dims by padding (e.g. 56 heads on 16-way TP);
the roofline notes where that costs real FLOPs.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat as _compat
from repro.configs.base import ModelConfig, ShardingRules

_CTX = threading.local()


@contextlib.contextmanager
def activation_mesh(mesh: Mesh, rules: ShardingRules):
    """Enable activation sharding constraints inside model code."""
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules)
    try:
        yield
    finally:
        _CTX.val = prev


def _current() -> tuple[Mesh, ShardingRules] | None:
    return getattr(_CTX, "val", None)


def _axes_in(mesh: Mesh, axes) -> Any:
    """Filter a spec entry to axes that exist in the mesh."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    got = tuple(a for a in axes if a in mesh.axis_names)
    return got if got else None


def constrain(x: jax.Array, *spec_entries) -> jax.Array:
    """with_sharding_constraint if an activation mesh is active, else no-op."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, _ = ctx
    entries = tuple(_axes_in(mesh, e) for e in spec_entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


def constrain_activations(x: jax.Array) -> jax.Array:
    """(B, S, D) block-boundary constraint: batch x seq sharding."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim == 3:
        return constrain(x, rules.batch, rules.seq, None)
    return x


def constrain_blocked_attention(
    qb: jax.Array, kb: jax.Array, vb: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Constraints for the blocked flash-attention tensors.

    qb (nq, B, KV, G, bq, hd), kb/vb (nk, B, KV, bk, hd). Without these,
    GSPMD shards the stacked-block dim and the per-block dynamic_slice
    triggers 'involuntary full rematerialization' (replicate + repartition
    of the whole q tensor per block — an XLA SPMD warning and a large
    collective term). Pin: block dim replicated, batch on rules.batch,
    KV heads on rules.tp when divisible.
    """
    ctx = _current()
    if ctx is None:
        return qb, kb, vb
    mesh, rules = ctx
    if not rules.blocked_attn:
        return qb, kb, vb
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = _axes_in(mesh, rules.tp)
    kv = qb.shape[2]
    heads_ax = tp if (tp is not None and kv % ax_size.get(tp, 1) == 0) else None
    qb = constrain(qb, None, rules.batch, heads_ax, None, None, None)
    kb = constrain(kb, None, rules.batch, heads_ax, None, None)
    vb = constrain(vb, None, rules.batch, heads_ax, None, None)
    return qb, kb, vb


def constrain_moe(x: jax.Array, kind: str, num_experts: int) -> jax.Array:
    """Sharding constraints for MoE dispatch intermediates.

    GSPMD loses propagation through the per-row sort/scatter chain and
    falls back to full replication (measured 320 GiB for the (B, E, C,
    2F) expert activation at mixtral train_4k). Layouts:
      'tokens'  (B, TK, D)      -> (batch, None, None)
      'buf'     (B, E, C, D)    -> (batch, expert?, None, None)
      'h'       (B, E, C, F)    -> (batch, expert?, None, tp-if-no-EP)
    Expert axis is used only when E divides it (qwen3 128e); otherwise
    the FFN dim takes the TP axis (mixtral 8e).
    """
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = _axes_in(mesh, rules.expert)
    ep_ok = ep is not None and num_experts % ax_size.get(ep, 1) == 0
    e_ax = ep if ep_ok else None
    f_ax = None if ep_ok else rules.tp
    if kind == "tokens":
        return constrain(x, rules.batch, None, None)
    if kind == "buf":
        return constrain(x, rules.batch, e_ax, None, None)
    if kind == "h":
        return constrain(x, rules.batch, e_ax, None, f_ax)
    return x


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _sanitize(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim.

    pjit argument shardings are strict (unlike internal GSPMD propagation,
    which pads); replication on the offending dim is always legal and the
    roofline reports the cost (e.g. minicpm's odd 122,753 vocab).
    """
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        total = 1
        for a in axes:
            total *= ax_size.get(a, 1)
        out.append(entry if total and shape[i] % total == 0 else None)
    return P(*out)


def _param_spec(path: str, leaf, cfg: ModelConfig, rules: ShardingRules) -> P:
    """PartitionSpec for one parameter, keyed by its tree path."""
    fsdp, tp, ep = rules.fsdp, rules.tp, rules.expert
    nd = len(leaf.shape)
    stacked = path.startswith("blocks") or path.startswith("enc_blocks") or path.startswith("dec_blocks")
    lead = (None,) if stacked else ()

    name = path.split("/")[-1]
    # MoE stacked experts (L, E, D, F) — must match before the generic
    # wi/wo rules below
    if "moe" in path and nd - len(lead) == 3:
        if ep is not None and cfg.num_experts % 16 == 0:
            return P(*lead, ep, fsdp, None)     # expert parallelism
        return P(*lead, None, fsdp, tp)         # TP within experts (mixtral)
    if name in ("embed",):
        return P(tp, fsdp)                      # (V, D)
    if name in ("lm_head",):
        return P(fsdp, tp)                      # (D, V)
    if name in ("wq", "wk", "wv", "wi", "w_in", "w_z", "w_x", "w_b", "w_c", "w_dt"):
        return P(*lead, fsdp, tp)               # (D, wide)
    if name in ("wo", "w_out"):
        return P(*lead, tp, fsdp)               # (wide, D)
    if name == "router":
        return P(*lead, fsdp, None)             # (D, E) — replicate experts dim
    # norms / scalars / vectors: replicate (tiny)
    return P(*([None] * nd))


def param_shardings(
    mesh: Mesh, cfg: ModelConfig, rules: ShardingRules, params_shapes: Any
) -> Any:
    """Pytree of NamedSharding matching a params (shape) pytree."""

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec = _param_spec(pstr, leaf, cfg, rules)
        spec = P(*(_axes_in(mesh, e) for e in spec))
        spec = _sanitize(spec, tuple(leaf.shape), mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(mesh: Mesh, cfg, rules, opt_shapes: Any, param_sh: Any) -> Any:
    """Optimizer state mirrors param shardings (master/m/v); step replicated."""
    rep = NamedSharding(mesh, P())
    return {
        "master": param_sh,
        "m": param_sh,
        "v": param_sh,
        "step": rep,
    }


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, cfg: ModelConfig, rules: ShardingRules, batch_shapes: dict) -> dict:
    b = _axes_in(mesh, rules.batch)
    out = {}
    for k, v in batch_shapes.items():
        if k == "cache":
            out[k] = cache_shardings(mesh, cfg, rules, v)
            continue
        if k in ("token", "pos"):
            spec = P(b)
        elif hasattr(v, "ndim") and v.ndim == 3:  # frames / patches (B, T, D)
            spec = P(b, None, None)
        else:  # tokens / labels / mask (B, S)
            spec = P(b, None)
        spec = _sanitize(spec, tuple(v.shape), mesh)  # long_500k has B=1
        out[k] = NamedSharding(mesh, spec)
    return out


def logits_sharding(mesh: Mesh, cfg: ModelConfig, rules: ShardingRules, shape: tuple) -> NamedSharding:
    """(B, S, V) prefill logits: batch x vocab sharded, sanitized for odd
    vocab sizes (whisper 51,865; minicpm 122,753)."""
    b = _axes_in(mesh, rules.batch)
    tp = _axes_in(mesh, rules.tp)
    spec = _sanitize(P(b, None, tp), shape, mesh)
    return NamedSharding(mesh, spec)


def cache_shardings(mesh: Mesh, cfg: ModelConfig, rules: ShardingRules, cache_shapes: Any) -> Any:
    cb = _axes_in(mesh, rules.cache_batch)
    ch = _axes_in(mesh, rules.cache_heads)
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _fits(axes, dim) -> bool:
        if axes is None:
            return False
        alist = (axes,) if isinstance(axes, str) else tuple(axes)
        total = 1
        for a in alist:
            total *= ax_size.get(a, 1)
        return dim % total == 0

    def one(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = len(leaf.shape)
        if name in ("k", "v") and nd == 5:      # (L, B, S, KV, hd)
            L, B, S, KV, hd = leaf.shape
            b = cb if _fits(cb, B) else None
            # prefer KV-head sharding; fall back to sequence sharding when
            # heads don't divide (GQA kv=1..4) or batch can't shard (B=1)
            if _fits(ch, KV):
                spec = P(None, b, None, ch, None)
            elif _fits(ch, S):
                spec = P(None, b, ch, None, None)
            else:
                spec = P(None, b, None, None, None)
            return NamedSharding(mesh, spec)
        if name == "state" and nd == 5:          # (L, B, nh, hp, N)
            L, B, nh, hp, N = leaf.shape
            b = cb if _fits(cb, B) else None
            h = ch if _fits(ch, nh) else None
            return NamedSharding(mesh, P(None, b, h, None, None))
        if name == "memory" and nd == 3:         # (B, T_enc, D)
            B = leaf.shape[0]
            b = cb if _fits(cb, B) else None
            return NamedSharding(mesh, P(b, None, None))
        return NamedSharding(mesh, P(*([None] * nd)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(treedef, [one(p, l) for p, l in flat])


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# dynamic element placement (repartitioning engine integration)
# ---------------------------------------------------------------------------

def curve_sharding(mesh: Mesh, axis: str) -> NamedSharding:
    """Sharding for curve-ordered element arrays: shard i of ``axis`` holds
    the i-th contiguous chunk of the global SFC order (the layout produced
    by `repro.core.partitioner.distributed_partition`)."""
    return NamedSharding(mesh, P(axis))


def apply_repartition(
    mesh: Mesh,
    axis: str,
    payload: jax.Array,
    part: jax.Array,
    *,
    capacity: int | None = None,
    fill_value=0,
):
    """Move rows of ``payload`` (sharded on dim 0 over ``axis``) to the
    shard given by ``part`` — the output of a `Repartitioner` step,
    `distributed_reslice`, or the bucket-summary path
    (`distributed_bucket_partition` / `DistributedBucketRepartitioner`,
    whose assignments are already in this original row layout: the
    bucket path never moves rows to *compute* the partition, so this
    exchange is the only data motion in the whole cycle). Invalid rows
    (part < 0) are parked on their current shard and masked out of the
    result.

    Returns (received, valid_mask) in the fixed-capacity layout of
    `migration.execute_shard_exchange`. ``capacity`` is per (src, dst)
    pair *including* stay-home rows; the default — one shard's full row
    count — is the smallest value that can never drop a row (a pair
    cannot carry more than its source shard holds). Pass something
    smaller only with a migration plan proving the worst pair is small.
    """
    from repro.core import migration as _migration

    nshards = mesh.shape[axis]
    n_rows = payload.shape[0]
    if capacity is None:
        capacity = max(1, int(np.ceil(n_rows / nshards)))
    # P(axis) = contiguous chunks: row r lives on shard r*S//n
    me_rows = (jnp.arange(n_rows) * nshards) // n_rows  # park invalid rows locally
    dest = jnp.where(part >= 0, part, me_rows).astype(jnp.int32)
    recv, valid = _migration.execute_shard_exchange(
        mesh, axis, payload, dest, capacity, fill_value=fill_value
    )
    return recv, valid


# ---------------------------------------------------------------------------
# Distributed query serving (paper §V-A over a sharded CurveIndex)
# ---------------------------------------------------------------------------
#
# The serving layout: the CurveIndex's sorted arrays are split into
# contiguous chunks over the mesh axis (shard rank = curve rank, the same
# layout `distributed_partition` produces), the quantization frame is
# replicated. A query batch arriving sharded P(axis) is answered with
# exactly two all_to_all exchanges:
#
#   1. key each local query against the frame, find its *owner* shard by
#      binary search over the shards' first keys (one tiny all_gather),
#      and exchange query coordinates to owners;
#   2. owners answer locally against their chunk (point location: exact
#      key-run scan; kNN: curve-window candidate scan, distances + ids
#      bit-packed into one reply buffer) and the answers ride the reverse
#      all_to_all back in the mirrored lane layout — each source shard
#      gathers its results at [owner, staged position] locally, so no
#      slot ids are ever exchanged.
#
# Per-(src,dst) lane capacity equals the local query count, so routing can
# never drop a query regardless of skew. Key-run / kNN windows clipped at
# a chunk edge are reported via the `ok` flag (point location) or cost a
# little recall at chunk seams (kNN) — the same CUTOFF economics as the
# single-host path.
#
# Serving requires a POINT-KEYED index: queries are keyed from their
# coordinates inside the kernel (`_ci.keys_in_frame`), so tree-backed
# indexes — whose stored keys are bucket keys addressed by a kd-tree
# walk — cannot shard into this layout (DistributedQueryEngine.swap
# rejects them; they serve locally through repro.core.queries).


def _exchange(x, axis):
    """Lane s of my buffer -> shard s (flattened on receive)."""
    r = jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)
    return r.reshape((-1,) + r.shape[2:])


@functools.lru_cache(maxsize=32)
def _query_serve_fn(
    mesh: Mesh,
    axis: str,
    mode: str,          # "pl" | "knn"
    k: int,
    bucket_cap: int,
    win: int,
    bits: int,
    curve: str,
):
    """Jitted two-all_to_all query-serving executor, memoized per static
    config (shard_map must run under jit — see partitioner._reslice_fn)."""
    from repro.core import curve_index as _ci
    from repro.core import migration as _migration

    nshards = mesh.shape[axis]

    def kernel(pts_loc, ids_loc, keys_loc, q_loc, flo, fhi):
        n_loc = keys_loc.shape[0]
        qcap = q_loc.shape[0]
        qk = _ci.keys_in_frame(q_loc, flo, fhi, bits=bits, curve=curve)
        # owner shard: last shard whose first key <= qk
        firsts = jax.lax.all_gather(keys_loc[0], axis)          # (nshards,)
        owner = jnp.clip(
            jnp.searchsorted(firsts, qk, side="right").astype(jnp.int32) - 1,
            0,
            nshards - 1,
        )
        (buf_q,), pos_of = _migration.stage_rows_by_dest(
            owner, (q_loc,), nshards, qcap, (0.0,)
        )
        rq = _exchange(buf_q, axis)                              # (nshards*qcap, d)
        rqk = _ci.keys_in_frame(rq, flo, fhi, bits=bits, curve=curve)
        # answers come back in the mirrored lane layout, so each source
        # shard gathers its own results at [owner, pos] locally — no slot
        # ids travel in either direction

        def reply(ans):                                          # (r, c) -> (qcap, c)
            back = jax.lax.all_to_all(
                ans.reshape(nshards, qcap, -1), axis,
                split_axis=0, concat_axis=0, tiled=False,
            )
            return back[owner, pos_of]

        if mode == "pl":
            lo_i = jnp.searchsorted(keys_loc, rqk, side="left").astype(jnp.int32)
            hi_i = jnp.searchsorted(keys_loc, rqk, side="right").astype(jnp.int32)
            offs = jnp.arange(bucket_cap, dtype=jnp.int32)
            pos = lo_i[:, None] + offs[None, :]
            cand = jnp.clip(pos, 0, n_loc - 1)
            hit = jnp.all(pts_loc[cand] == rq[:, None, :], axis=-1) & (pos < hi_i[:, None])
            found = jnp.any(hit, axis=1)
            slot = jnp.argmax(hit, axis=1)
            gid = ids_loc[cand[jnp.arange(rq.shape[0]), slot]]
            # a key-run can extend backwards into the previous shard (the
            # owner is the LAST shard whose first key <= qk, so forward
            # extension is impossible): flag those misses as uncertified
            edge = (lo_i == 0) & (keys_loc[0] == rqk)
            ok = found | (((hi_i - lo_i) <= bucket_cap) & ~edge)
            ans = jnp.stack(
                [found.astype(jnp.int32), jnp.where(found, gid, -1), ok.astype(jnp.int32)],
                axis=-1,
            )                                                    # (r, 3)
            return reply(ans)

        # kNN: candidate window around the insertion point on the chunk
        pos0 = jnp.searchsorted(keys_loc, rqk, side="left").astype(jnp.int32)
        start = jnp.clip(pos0 - win // 2, 0, jnp.maximum(n_loc - win, 0))
        offs = jnp.arange(win, dtype=jnp.int32)
        pos = start[:, None] + offs[None, :]
        cand = jnp.clip(pos, 0, n_loc - 1)
        # pos < n_loc: when win exceeds the chunk, clipped indices repeat —
        # without the bound one point could fill several of the k slots
        valid = (pos < n_loc) & (keys_loc[cand] != jnp.uint32(_ci.KEY_SENTINEL))
        d2 = jnp.sum((pts_loc[cand] - rq[:, None, :]) ** 2, axis=-1)
        d2 = jnp.where(valid, d2, jnp.inf)
        neg_top, top_i = jax.lax.top_k(-d2, k)
        gids = ids_loc[jnp.take_along_axis(cand, top_i, axis=1)]
        gids = jnp.where(jnp.isfinite(-neg_top), gids, -1)
        dist = jnp.sqrt(jnp.maximum(-neg_top, 0.0))
        # distances + bit-cast ids share one (r, 2k) reply buffer: the
        # whole kNN round stays at two all_to_all exchanges
        packed = jnp.concatenate(
            [dist, jax.lax.bitcast_convert_type(gids, jnp.float32)], axis=1
        )
        got = reply(packed)                                      # (qcap, 2k)
        return got[:, :k], jax.lax.bitcast_convert_type(got[:, k:], jnp.int32)

    out_specs = P(axis) if mode == "pl" else (P(axis), P(axis))
    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), P()),
        out_specs=out_specs,
        check_vma=False,
    ))


def serve_point_location(
    mesh: Mesh,
    axis: str,
    pts_s: jax.Array,
    ids_s: jax.Array,
    keys_s: jax.Array,
    queries: jax.Array,
    frame_lo: jax.Array,
    frame_hi: jax.Array,
    *,
    bits: int,
    curve: str = "morton",
    bucket_cap: int = 64,
) -> jax.Array:
    """Distributed exact point location. ``queries`` (Q, d) sharded
    P(axis), Q divisible by the axis size; returns (Q, 3) int32 columns
    (found, id, ok)."""
    fn = _query_serve_fn(mesh, axis, "pl", 0, bucket_cap, 0, bits, curve)
    return fn(pts_s, ids_s, keys_s, queries, frame_lo, frame_hi)


def serve_knn(
    mesh: Mesh,
    axis: str,
    pts_s: jax.Array,
    ids_s: jax.Array,
    keys_s: jax.Array,
    queries: jax.Array,
    frame_lo: jax.Array,
    frame_hi: jax.Array,
    *,
    bits: int,
    curve: str = "morton",
    k: int = 3,
    win: int = 192,
) -> tuple[jax.Array, jax.Array]:
    """Distributed approximate kNN over the sharded curve. Returns
    ((Q, k) distances, (Q, k) ids), invalid slots inf/-1."""
    fn = _query_serve_fn(mesh, axis, "knn", k, 0, win, bits, curve)
    return fn(pts_s, ids_s, keys_s, queries, frame_lo, frame_hi)
