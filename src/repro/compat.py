"""Small jax version-compat layer.

The repo targets current jax but must degrade gracefully on the 0.4.x
runtime baked into the CPU CI container:

* ``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
  and renamed ``check_rep`` -> ``check_vma``.
* ``jax.sharding.AxisType`` (explicit-sharding axis types) does not exist
  on 0.4.x; `repro.launch.mesh` handles that one locally.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )

else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
        )
