"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only (the
harness contract: kernels target TPU, validate in interpret mode). On a
real TPU runtime set ``repro.kernels.ops.INTERPRET = False`` (or the
REPRO_PALLAS_COMPILE=1 env) to compile the kernels natively.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import sfc as _sfc
from repro.kernels import bucket_search as _bs
from repro.kernels import hilbert as _hil
from repro.kernels import knapsack_scan as _ks
from repro.kernels import morton as _mor

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def morton_key(points: jax.Array, bits: int | None = None, *, stats: str = "geometric") -> jax.Array:
    n, d = points.shape
    if bits is None:
        bits = _sfc.max_bits_per_dim(d)
    cells = _sfc.quantize(points, bits, stats)
    return _mor.morton_from_cells(cells, bits, interpret=INTERPRET)


def hilbert_key(points: jax.Array, bits: int | None = None, *, stats: str = "geometric") -> jax.Array:
    n, d = points.shape
    if bits is None:
        bits = _sfc.max_bits_per_dim(d)
    cells = _sfc.quantize(points, bits, stats)
    return _hil.hilbert_from_cells(cells, bits, interpret=INTERPRET)


def knapsack_parts(weights: jax.Array, num_parts: int) -> jax.Array:
    return _ks.knapsack_parts(weights, num_parts, interpret=INTERPRET)


def bucket_search(qkeys: jax.Array, boundary_keys: jax.Array) -> jax.Array:
    return _bs.bucket_search(qkeys, boundary_keys, interpret=INTERPRET)
