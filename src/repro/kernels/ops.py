"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only (the
harness contract: kernels target TPU, validate in interpret mode). On a
real TPU runtime set ``repro.kernels.ops.INTERPRET = False`` (or the
REPRO_PALLAS_COMPILE=1 env) to compile the kernels natively.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import sfc as _sfc
from repro.kernels import bucket_search as _bs
from repro.kernels import hilbert as _hil
from repro.kernels import knapsack_scan as _ks
from repro.kernels import morton as _mor
from repro.kernels import pair_force as _pf
from repro.kernels import stencil_update as _su

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


# ---------------------------------------------------------------------------
# SFC key cache (repartitioning hot path)
#
# The incremental repartitioner re-slices the weighted curve many times
# between geometry changes; key generation is the dominant cost it can
# skip. Callers tag a key batch with an explicit ``token`` (bumped by the
# owner whenever the underlying points or quantization frame change) and
# the cache returns the stored keys for (token, curve, bits, stats,
# shape). Invalidation is explicit — there is no content hashing, so a
# caller that mutates points without bumping its token gets stale keys.
# ---------------------------------------------------------------------------

_KEY_CACHE: dict[tuple, jax.Array] = {}
_KEY_CACHE_STATS = {"hits": 0, "misses": 0}


def invalidate_key_cache(token=None) -> int:
    """Drop cached keys. ``token=None`` clears everything; otherwise only
    entries generated under that token. Returns the number of entries
    dropped. Called automatically by ``set_interpret`` (a backend switch
    may change key bit layouts in interpret-vs-compiled edge cases)."""
    if token is None:
        n = len(_KEY_CACHE)
        _KEY_CACHE.clear()
        return n
    drop = [k for k in _KEY_CACHE if k[0] == token]
    for k in drop:
        del _KEY_CACHE[k]
    return len(drop)


def key_cache_stats() -> dict:
    return dict(_KEY_CACHE_STATS, entries=len(_KEY_CACHE))


def set_interpret(flag: bool) -> None:
    """Toggle Pallas interpret mode; invalidates the key cache."""
    global INTERPRET
    INTERPRET = bool(flag)
    invalidate_key_cache()


def cached_sfc_key(
    points: jax.Array,
    *,
    token,
    curve: str = "hilbert",
    bits: int | None = None,
    stats: str = "geometric",
    use_pallas: bool = False,
    lo: jax.Array | None = None,
    hi: jax.Array | None = None,
) -> jax.Array:
    """Key generation with token-based caching (see module note above).

    ``lo``/``hi`` quantize against a *fixed frame* instead of the data's
    own bounding box — the repartitioning engine's frozen-frame path,
    where the frame (and hence the cached keys) only changes when the
    owner bumps ``token``. The frame arrays are deliberately NOT part of
    the cache key: they are a function of the token by contract.
    """
    ck = (token, curve, bits, stats, points.shape, bool(use_pallas), lo is not None)
    hit = _KEY_CACHE.get(ck)
    if hit is not None:
        _KEY_CACHE_STATS["hits"] += 1
        return hit
    _KEY_CACHE_STATS["misses"] += 1
    if lo is not None:
        b = bits if bits is not None else _sfc.max_bits_per_dim(points.shape[1])
        # the ONE frozen-frame quantization convention (sfc.cells_in_frame)
        cells = _sfc.cells_in_frame(points, lo, hi, b)
        if use_pallas:
            fn = _mor.morton_from_cells if curve == "morton" else _hil.hilbert_from_cells
            keys = fn(cells, b, interpret=INTERPRET)
        else:
            fn = (
                _sfc.morton_key_from_cells
                if curve == "morton"
                else _sfc.hilbert_key_from_cells
            )
            keys = fn(cells, b)
    elif use_pallas:
        fn = morton_key if curve == "morton" else hilbert_key
        keys = fn(points, bits, stats=stats)
    else:
        fn = _sfc.morton_key if curve == "morton" else _sfc.hilbert_key
        keys = fn(points, bits, stats=stats)
    _KEY_CACHE[ck] = keys
    return keys


def morton_key(points: jax.Array, bits: int | None = None, *, stats: str = "geometric") -> jax.Array:
    n, d = points.shape
    if bits is None:
        bits = _sfc.max_bits_per_dim(d)
    cells = _sfc.quantize(points, bits, stats)
    return _mor.morton_from_cells(cells, bits, interpret=INTERPRET)


def hilbert_key(points: jax.Array, bits: int | None = None, *, stats: str = "geometric") -> jax.Array:
    n, d = points.shape
    if bits is None:
        bits = _sfc.max_bits_per_dim(d)
    cells = _sfc.quantize(points, bits, stats)
    return _hil.hilbert_from_cells(cells, bits, interpret=INTERPRET)


def knapsack_parts(weights: jax.Array, num_parts: int) -> jax.Array:
    return _ks.knapsack_parts(weights, num_parts, interpret=INTERPRET)


def bucket_search(qkeys: jax.Array, boundary_keys: jax.Array) -> jax.Array:
    return _bs.bucket_search(qkeys, boundary_keys, interpret=INTERPRET)


def fused_locate(
    queries: jax.Array,
    boundary_keys: jax.Array,
    frame_lo: jax.Array,
    frame_hi: jax.Array,
    bits: int,
) -> jax.Array:
    """Fused Morton key-gen + directory binary search (one kernel
    dispatch): per query point, the index of the last boundary key <= its
    key. The query-serving hot loop — point location and kNN bucket
    lookup both ride on it when compiled kernels are enabled."""
    return _bs.fused_locate(
        queries, boundary_keys, frame_lo, frame_hi, bits, interpret=INTERPRET
    )


def stencil_update(
    vals_all: jax.Array,
    u_rows: jax.Array,
    nbr: jax.Array,
    valid: jax.Array,
    coeff: jax.Array,
    *,
    use_pallas: bool = False,
) -> jax.Array:
    """Fused stencil row update (gather + mask + coeff*(v-u) + K-reduce).

    The mesh stencil executors' inner loop. ``use_pallas`` dispatches the
    Pallas kernel (REPRO_PALLAS_COMPILE-respecting via ``INTERPRET``);
    the default jnp fallback is bit-equal by construction — both
    evaluate `kernels.stencil_update.stencil_update_ref`'s expression.
    """
    if use_pallas:
        return _su.fused_stencil_update(
            vals_all, u_rows, nbr, valid, coeff, interpret=INTERPRET
        )
    return _su.stencil_update_ref(vals_all, u_rows, nbr, valid, coeff)


def pair_accel(
    pos_all: jax.Array,
    mass_all: jax.Array,
    x_rows: jax.Array,
    nbr: jax.Array,
    valid: jax.Array,
    rc2,
    *,
    use_pallas: bool = False,
) -> jax.Array:
    """Fused pairwise short-range acceleration (gather + cutoff weight +
    K-reduce) — the particle executors' inner loop. ``use_pallas``
    dispatches the Pallas kernel (REPRO_PALLAS_COMPILE-respecting via
    ``INTERPRET``); the default jnp fallback is bit-equal by
    construction — both evaluate `kernels.pair_force.pair_accel_ref`'s
    expression.
    """
    if use_pallas:
        return _pf.fused_pair_accel(
            pos_all, mass_all, x_rows, nbr, valid,
            jnp.asarray(rc2, jnp.float32), interpret=INTERPRET,
        )
    return _pf.pair_accel_ref(
        pos_all, mass_all, x_rows, nbr, valid, jnp.asarray(rc2, jnp.float32)
    )
