"""Pallas TPU kernel: blocked weighted prefix-scan + greedy knapsack slice.

The load-balancing step (paper §III-C) ranks every element on the
weighted curve and slices it into P parts. Two-pass blocked scan:

  pass 1 (jnp): per-block weight sums -> exclusive block offsets
                (a tiny (n/BLOCK,) cumsum, negligible next to the data).
  pass 2 (Pallas): each block loads its weights into VMEM, computes the
                in-block inclusive scan on the VPU, adds its offset and
                emits part ids  floor((prefix - w/2) / ideal).

The sequential dependency between blocks is carried through the
precomputed offsets, so pass 2 is embarrassingly parallel over the grid —
the TPU form of the paper's 'parallel prefix computation'.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 4096


def _scan_slice_kernel(w_ref, off_ref, scal_ref, out_ref):
    w = w_ref[...]                       # (BLOCK_N,) f32
    off = off_ref[0]                     # scalar: exclusive offset of this block
    ideal = scal_ref[0]                  # total / num_parts
    maxp = scal_ref[1]                   # num_parts - 1
    incl = jnp.cumsum(w)
    center = off + incl - 0.5 * w        # prefix_exclusive + w/2
    part = jnp.floor(center / ideal)
    part = jnp.clip(part, 0.0, maxp)
    out_ref[...] = part.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_parts", "interpret"))
def knapsack_parts(
    weights: jax.Array, num_parts: int, *, interpret: bool = True
) -> jax.Array:
    """(n,) float32 weights in curve order -> (n,) int32 part ids."""
    n = weights.shape[0]
    n_pad = pl.cdiv(n, BLOCK_N) * BLOCK_N
    w = jnp.zeros((n_pad,), jnp.float32).at[:n].set(weights.astype(jnp.float32))
    nb = n_pad // BLOCK_N
    blocks = w.reshape(nb, BLOCK_N)
    bsums = jnp.sum(blocks, axis=1)
    offsets = jnp.cumsum(bsums) - bsums          # exclusive
    total = jnp.sum(bsums)
    ideal = jnp.maximum(total / num_parts, 1e-9)
    scal = jnp.stack([ideal, jnp.float32(num_parts - 1)])
    out = pl.pallas_call(
        _scan_slice_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(w, offsets, scal)
    return out[:n]
