"""Pallas TPU kernel: fused pairwise short-range acceleration (paper
§V-C particle hot loop).

One pass over (rows, K) interaction tiles fuses the neighbor
position/mass gather, the cutoff weight ``max(rc2 - |r|^2, 0) * m_j``
and the K-reduction into per-row accelerations — the unfused jnp path
materializes the (n, K, d) displacement and contribution intermediates
in HBM between separate ops; here each grid block stages the FULL
owned+ghost position matrix and mass vector into VMEM once ((V, d) +
(V,) float32 — the same in-VMEM-directory regime as `stencil_update`)
and streams the (BLOCK_R, K) index/mask tiles past it.

The force law is the bounded short-range attraction

    a_i = sum_j m_j * max(rc2 - |x_j - x_i|^2, 0) * (x_j - x_i)

smooth and exactly zero at the cutoff boundary, so an interaction table
may safely include candidates at or beyond the cutoff — their weight is
exactly ``0.0`` and a padded lane contributes a signed zero, identical
on every execution path that consumes the SAME (n, K) table.

Bit-equality contract: :func:`pair_accel_ref` is THE definition — both
the per-lane squared distance (dimension sum) and the K-reduction are
*explicit unrolled chains* of elementwise adds in ascending order, the
same discipline `kernels.stencil_update` established: a ``jnp.sum``
lowers to an XLA Reduce whose accumulation order is chosen per fusion
context, while a fixed add chain is ordinary float arithmetic XLA must
not reassociate. Every caller — single-device reference integrator,
interior/boundary distributed executor, Pallas kernel — produces
identical bits by construction, which is what the particle drivers gate
on (``np.array_equal`` across repartition events).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 1024
VALS_MAX = 1 << 20  # owned+ghost rows whose (V, d) positions fit VMEM


def pair_accel_ref(
    pos_all: jax.Array,
    mass_all: jax.Array,
    x_rows: jax.Array,
    nbr: jax.Array,
    valid: jax.Array,
    rc2: jax.Array,
) -> jax.Array:
    """The one definition of the fused pair acceleration (jnp fallback).

    ``pos_all`` (V, d) owned+ghost positions, ``mass_all`` (V,) their
    masses, ``x_rows`` (R, d) the positions of the rows being updated,
    ``nbr``/``valid`` (R, K) the row-local interaction table, ``rc2``
    the squared cutoff radius. Returns the (R, d) accelerations.
    """
    pj = pos_all[nbr]                       # (R, K, d)
    mj = mass_all[nbr]                      # (R, K)
    diff = pj - x_rows[:, None, :]
    # fixed-order dimension accumulation (see module docstring)
    d2 = diff[..., 0] * diff[..., 0]
    for a in range(1, diff.shape[-1]):
        d2 = d2 + diff[..., a] * diff[..., a]
    w = jnp.where(valid, jnp.maximum(rc2 - d2, jnp.float32(0.0)) * mj,
                  jnp.float32(0.0))
    contrib = w[..., None] * diff           # (R, K, d)
    # fixed-order K accumulation (NOT jnp.sum)
    acc = contrib[:, 0, :]
    for k in range(1, contrib.shape[1]):
        acc = acc + contrib[:, k, :]
    return acc


def _accel_kernel(rc2_ref, pos_ref, mass_ref, x_ref, nbr_ref, valid_ref, out_ref):
    # same jnp expression as pair_accel_ref, on one (BLOCK_R, K) tile
    pos_all = pos_ref[...]
    mass_all = mass_ref[...]
    x = x_ref[...]
    rc2 = rc2_ref[0]
    pj = pos_all[nbr_ref[...]]
    mj = mass_all[nbr_ref[...]]
    diff = pj - x[:, None, :]
    d2 = diff[..., 0] * diff[..., 0]
    for a in range(1, diff.shape[-1]):
        d2 = d2 + diff[..., a] * diff[..., a]
    w = jnp.where(valid_ref[...], jnp.maximum(rc2 - d2, jnp.float32(0.0)) * mj,
                  jnp.float32(0.0))
    contrib = w[..., None] * diff
    acc = contrib[:, 0, :]
    for k in range(1, contrib.shape[1]):
        acc = acc + contrib[:, k, :]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_pair_accel(
    pos_all: jax.Array,
    mass_all: jax.Array,
    x_rows: jax.Array,
    nbr: jax.Array,
    valid: jax.Array,
    rc2: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Fused gather + cutoff weight + contribution + K-reduce, one kernel
    dispatch. Pad rows (``valid`` all False) come out exactly zero —
    exactly what the unfused path computes for them."""
    R, K = nbr.shape
    V, d = pos_all.shape
    assert V <= VALS_MAX, "owned+ghost positions must fit VMEM (tile beyond)"
    r_pad = pl.cdiv(R, BLOCK_R) * BLOCK_R

    def pad(a, fill):
        return jnp.full((r_pad,) + a.shape[1:], fill, a.dtype).at[:R].set(a)

    out = pl.pallas_call(
        _accel_kernel,
        grid=(r_pad // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((V, d), lambda i: (0, 0)),
            pl.BlockSpec((V,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_R, d), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, K), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, d), jnp.float32),
        interpret=interpret,
    )(
        jnp.asarray(rc2, jnp.float32).reshape(1),
        pos_all,
        mass_all,
        pad(x_rows, 0.0),
        pad(nbr, 0),
        pad(valid, False),
    )
    return out[:R]
