"""Pallas TPU kernel: Hilbert-like SFC key generation (Skilling transform).

Same VPU-bound structure as the Morton kernel plus the Gray-code
transpose (paper's Hilbert-like look-ahead — a static O(bits * d) chain of
shifts/xors/selects per block, still branch-free and fully vectorized).
The kernel fuses transform + interleave so cells are read from VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048


def _hilbert_kernel(cells_ref, out_ref, *, bits: int, d: int):
    cells = cells_ref[...]  # (BLOCK_N, d) uint32
    X = [cells[:, i] for i in range(d)]

    # Skilling inverse-undo (static loops -> straight-line vector code)
    Q = 1 << (bits - 1)
    while Q > 1:
        Pm = jnp.uint32(Q - 1)
        Qm = jnp.uint32(Q)
        for i in range(d):
            cond = (X[i] & Qm) != 0
            t = (X[0] ^ X[i]) & Pm
            x0_if = X[0] ^ Pm
            x0_else = X[0] ^ t
            xi_else = X[i] ^ t
            X[0] = jnp.where(cond, x0_if, x0_else)
            if i != 0:
                X[i] = jnp.where(cond, X[i], xi_else)
        Q >>= 1

    # Gray encode
    for i in range(1, d):
        X[i] = X[i] ^ X[i - 1]
    t = jnp.zeros_like(X[0])
    Q = 1 << (bits - 1)
    while Q > 1:
        Qm = jnp.uint32(Q)
        t = jnp.where((X[d - 1] & Qm) != 0, t ^ jnp.uint32(Q - 1), t)
        Q >>= 1
    for i in range(d):
        X[i] = X[i] ^ t

    # interleave (same layout as the Morton kernel)
    key = jnp.zeros_like(X[0])
    total = bits * d
    offset = 32 - total
    for k in range(bits):
        src_bit = bits - 1 - k
        for i in range(d):
            g = k * d + i
            bit_in_word = 31 - (offset + g)
            comp = (X[i] >> jnp.uint32(src_bit)) & jnp.uint32(1)
            key = key | (comp << jnp.uint32(bit_in_word))
    out_ref[...] = key


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def hilbert_from_cells(cells: jax.Array, bits: int, *, interpret: bool = True) -> jax.Array:
    """(n, d) uint32 cells -> (n,) uint32 Hilbert-like keys via Pallas."""
    n, d = cells.shape
    assert bits * d <= 32
    n_pad = pl.cdiv(n, BLOCK_N) * BLOCK_N
    cells_p = jnp.zeros((n_pad, d), dtype=jnp.uint32).at[:n].set(cells)
    out = pl.pallas_call(
        functools.partial(_hilbert_kernel, bits=bits, d=d),
        grid=(n_pad // BLOCK_N,),
        in_specs=[pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(cells_p)
    return out[:n]
