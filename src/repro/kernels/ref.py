"""Pure-jnp oracles for every Pallas kernel (allclose-tested in
tests/test_kernels.py across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sfc as _sfc


def morton_from_cells(cells: jax.Array, bits: int) -> jax.Array:
    return _sfc.morton_key_from_cells(cells, bits)


def hilbert_from_cells(cells: jax.Array, bits: int) -> jax.Array:
    return _sfc.hilbert_key_from_cells(cells, bits)


def knapsack_parts(weights: jax.Array, num_parts: int) -> jax.Array:
    from repro.core import knapsack as _knap

    return _knap.slice_weighted_curve(weights, num_parts)


def bucket_search(qkeys: jax.Array, boundary_keys: jax.Array) -> jax.Array:
    idx = jnp.searchsorted(boundary_keys, qkeys, side="right") - 1
    return jnp.clip(idx, 0, boundary_keys.shape[0] - 1).astype(jnp.int32)
