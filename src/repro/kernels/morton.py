"""Pallas TPU kernel: Morton (bit-interleave) SFC key generation.

The partitioner's hottest loop is key generation over every element
(paper §III-B: traversals over 10M–8B points). On TPU this is a pure
VPU integer workload: each block of quantized cells is staged into VMEM,
bit-planes are extracted with shifts/masks and OR-combined into the key
word — no MXU, no cross-element communication, perfectly parallel over
the 8x128 vector lanes.

Block shape: (BLOCK_N, d) uint32 in / (BLOCK_N,) uint32 out. BLOCK_N=2048
keeps the working set (2048 * (d+1) * 4B <= ~90 KiB for d=10) far inside
the ~16 MiB VMEM budget while staying lane-aligned (2048 = 16 * 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048


def _morton_kernel(cells_ref, out_ref, *, bits: int, d: int):
    cells = cells_ref[...]  # (BLOCK_N, d) uint32
    key = jnp.zeros((cells.shape[0],), dtype=jnp.uint32)
    total = bits * d
    offset = 32 - total  # left-align payload inside the 32-bit key
    for k in range(bits):
        src_bit = bits - 1 - k
        for i in range(d):
            g = k * d + i
            bit_in_word = 31 - (offset + g)
            comp = (cells[:, i] >> jnp.uint32(src_bit)) & jnp.uint32(1)
            key = key | (comp << jnp.uint32(bit_in_word))
    out_ref[...] = key


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def morton_from_cells(cells: jax.Array, bits: int, *, interpret: bool = True) -> jax.Array:
    """(n, d) uint32 cells -> (n,) uint32 Morton keys via Pallas."""
    n, d = cells.shape
    assert bits * d <= 32, "single-word kernel: bits*d must fit 32 bits"
    n_pad = pl.cdiv(n, BLOCK_N) * BLOCK_N
    cells_p = jnp.zeros((n_pad, d), dtype=jnp.uint32).at[:n].set(cells)
    out = pl.pallas_call(
        functools.partial(_morton_kernel, bits=bits, d=d),
        grid=(n_pad // BLOCK_N,),
        in_specs=[pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(cells_p)
    return out[:n]
