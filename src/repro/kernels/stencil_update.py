"""Pallas TPU kernel: fused stencil row update (paper §I mesh hot loop).

One pass over (rows, K) tiles fuses the neighbor-value gather, the
validity mask, the ``coeff * (u_nbr - u)`` contribution and the
K-reduction — the unfused jnp path materializes the (n, K) ``vals`` and
``contrib`` intermediates in HBM between four separate ops; here each
grid block stages the FULL owned+ghost value vector into VMEM once
(cap + gcap float32 — a few KB to low MB for every mesh in the paper's
experiments, same in-VMEM-directory regime as `bucket_search`) and
streams the (BLOCK_R, K) index/mask/coefficient tiles past it.

Bit-equality contract: :func:`stencil_update_ref` is THE definition of
the update — ``u_r + sum_k where(valid, coeff * (vals_all[nbr] - u_r),
0)`` with the K-reduction spelled as an *explicit unrolled chain* of
elementwise adds in ascending k. The unroll is load-bearing: a
``jnp.sum(axis=-1)`` lowers to an XLA Reduce whose accumulation order
is an implementation choice made per fusion context, so two programs
computing "the same" row can disagree in the last ulp (observed on
CPU: a standalone reduce vectorizes, the same reduce inside the
overlapped stencil executor runs sequentially). A fixed add chain is
ordinary float arithmetic XLA must not reassociate, so every caller —
reference executor, pre-split baseline, overlapped executor, Pallas
kernel — produces identical bits by construction. The distributed
stencil gates on this (``np.array_equal`` against the single-device
reference across repartition events).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 1024
VALS_MAX = 1 << 20  # 1M owned+ghost values * 4B = 4 MiB of VMEM


def stencil_update_ref(
    vals_all: jax.Array,
    u_rows: jax.Array,
    nbr: jax.Array,
    valid: jax.Array,
    coeff: jax.Array,
) -> jax.Array:
    """The one definition of the fused row update (jnp fallback).

    ``vals_all`` (V,) owned+ghost values, ``u_rows`` (R,) the center
    value of each row being updated, ``nbr``/``valid``/``coeff`` (R, K)
    the row-local stencil tables. Returns the (R,) updated centers.
    """
    vals = vals_all[nbr]
    contrib = jnp.where(valid, coeff * (vals - u_rows[:, None]), jnp.float32(0.0))
    # fixed-order K accumulation (see module docstring: NOT jnp.sum)
    acc = contrib[:, 0]
    for k in range(1, contrib.shape[1]):
        acc = acc + contrib[:, k]
    return u_rows + acc


def _update_kernel(vals_ref, u_ref, nbr_ref, valid_ref, coeff_ref, out_ref):
    # same jnp expression as stencil_update_ref, on one (BLOCK_R, K) tile
    vals_all = vals_ref[...]
    u = u_ref[...]
    vals = vals_all[nbr_ref[...]]
    contrib = jnp.where(
        valid_ref[...], coeff_ref[...] * (vals - u[:, None]), jnp.float32(0.0)
    )
    acc = contrib[:, 0]
    for k in range(1, contrib.shape[1]):
        acc = acc + contrib[:, k]
    out_ref[...] = u + acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_stencil_update(
    vals_all: jax.Array,
    u_rows: jax.Array,
    nbr: jax.Array,
    valid: jax.Array,
    coeff: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Fused gather + mask + contribution + K-reduce, one kernel dispatch.

    Pad rows (``valid`` all False) pass their center value through
    unchanged up to ``+0.0`` — exactly what the unfused path computes.
    """
    R, K = nbr.shape
    V = vals_all.shape[0]
    assert V <= VALS_MAX, "owned+ghost vector must fit VMEM (tile vals_all beyond)"
    r_pad = pl.cdiv(R, BLOCK_R) * BLOCK_R

    def pad(a, fill):
        return jnp.full((r_pad,) + a.shape[1:], fill, a.dtype).at[:R].set(a)

    out = pl.pallas_call(
        _update_kernel,
        grid=(r_pad // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((V,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_R,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_R, K), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, K), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r_pad,), jnp.float32),
        interpret=interpret,
    )(
        vals_all,
        pad(u_rows, 0.0),
        pad(nbr, 0),
        pad(valid, False),
        pad(coeff, 0.0),
    )
    return out[:R]
