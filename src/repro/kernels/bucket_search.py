"""Pallas TPU kernel: batched binary search of query keys over sorted
bucket boundary keys — the point-location inner loop (paper §V-A).

Each grid cell stages a block of query keys plus the *entire* boundary
directory into VMEM (the directory is n/BUCKETSIZE entries — 250M points
at BUCKETSIZE=32 is 7.8M boundaries, so production use tiles a two-level
directory; this kernel handles directories up to DIR_MAX that fit VMEM,
which covers every in-memory case in the paper's experiments).

The search is branch-free: log2(B) rounds of midpoint probes with
vectorized gathers, identical control flow for every lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 2048
DIR_MAX = 1 << 20  # 1M boundaries * 4B = 4 MiB of VMEM


def _search_kernel(q_ref, dir_ref, out_ref, *, steps: int, nb: int):
    q = q_ref[...]          # (BLOCK_Q,) uint32 query keys
    d = dir_ref[...]        # (NB,) uint32 sorted boundary keys
    lo = jnp.zeros_like(q, dtype=jnp.int32)
    step = jnp.int32(1 << (steps - 1))
    for _ in range(steps):
        mid = lo + step
        mid_c = jnp.minimum(mid, nb - 1)
        probe = d[mid_c]
        go = (probe <= q) & (mid <= nb - 1)
        lo = jnp.where(go, mid, lo)
        step = step // 2
    out_ref[...] = lo


@functools.partial(jax.jit, static_argnames=("interpret",))
def bucket_search(qkeys: jax.Array, boundary_keys: jax.Array, *, interpret: bool = True) -> jax.Array:
    """For each query key, index of the last boundary <= key (uint32)."""
    q = qkeys.shape[0]
    nb = boundary_keys.shape[0]
    assert nb <= DIR_MAX, "two-level directory required beyond DIR_MAX"
    steps = max(1, (nb - 1).bit_length())
    q_pad = pl.cdiv(q, BLOCK_Q) * BLOCK_Q
    qk = jnp.zeros((q_pad,), jnp.uint32).at[:q].set(qkeys)
    out = pl.pallas_call(
        functools.partial(_search_kernel, steps=steps, nb=nb),
        grid=(q_pad // BLOCK_Q,),
        in_specs=[
            pl.BlockSpec((BLOCK_Q,), lambda i: (i,)),
            pl.BlockSpec((nb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q_pad,), jnp.int32),
        interpret=interpret,
    )(qk, boundary_keys)
    return out[:q]
