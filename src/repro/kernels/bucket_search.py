"""Pallas TPU kernel: batched binary search of query keys over sorted
bucket boundary keys — the point-location inner loop (paper §V-A).

Each grid cell stages a block of query keys plus the *entire* boundary
directory into VMEM (the directory is n/BUCKETSIZE entries — 250M points
at BUCKETSIZE=32 is 7.8M boundaries, so production use tiles a two-level
directory; this kernel handles directories up to DIR_MAX that fit VMEM,
which covers every in-memory case in the paper's experiments).

The search is branch-free: log2(B) rounds of midpoint probes with
vectorized gathers, identical control flow for every lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 2048
DIR_MAX = 1 << 20  # 1M boundaries * 4B = 4 MiB of VMEM


def _search_kernel(q_ref, dir_ref, out_ref, *, steps: int, nb: int):
    q = q_ref[...]          # (BLOCK_Q,) uint32 query keys
    d = dir_ref[...]        # (NB,) uint32 sorted boundary keys
    lo = jnp.zeros_like(q, dtype=jnp.int32)
    step = jnp.int32(1 << (steps - 1))
    for _ in range(steps):
        mid = lo + step
        mid_c = jnp.minimum(mid, nb - 1)
        probe = d[mid_c]
        go = (probe <= q) & (mid <= nb - 1)
        lo = jnp.where(go, mid, lo)
        step = step // 2
    out_ref[...] = lo


def _fused_kernel(
    pts_ref, lo_ref, span_ref, dir_ref, out_ref, *, bits: int, d: int, steps: int, nb: int
):
    """Fused key-gen + search: quantize a query block against the frame,
    Morton-interleave, and binary-search the directory — one VMEM stage,
    no intermediate key round-trip to HBM."""
    pts = pts_ref[...]        # (BLOCK_Q, d) float32 query coordinates
    flo = lo_ref[...]         # (1, d) frame lo
    span = span_ref[...]      # (1, d) frame span (hi - lo, degenerate -> 1)
    # op-for-op identical to curve_index.keys_in_frame (divide, clip to
    # 1-1e-7, then scale by the exact power of two): a reciprocal-multiply
    # here would disagree with the jnp path by 1 ulp on ~1e-5 of queries,
    # i.e. route them to a different bucket than their stored key
    unit = jnp.clip((pts - flo) / span, 0.0, jnp.float32(1.0 - 1e-7))
    cells = (unit * jnp.float32(2**bits)).astype(jnp.uint32)
    key = jnp.zeros((cells.shape[0],), dtype=jnp.uint32)
    offset = 32 - bits * d    # left-align payload (same layout as sfc/morton)
    for k in range(bits):
        src_bit = bits - 1 - k
        for i in range(d):
            bit_in_word = 31 - (offset + k * d + i)
            comp = (cells[:, i] >> jnp.uint32(src_bit)) & jnp.uint32(1)
            key = key | (comp << jnp.uint32(bit_in_word))
    dirk = dir_ref[...]       # (NB,) uint32 sorted boundary keys
    lo = jnp.zeros_like(key, dtype=jnp.int32)
    step = jnp.int32(1 << (steps - 1))
    for _ in range(steps):
        mid = lo + step
        mid_c = jnp.minimum(mid, nb - 1)
        probe = dirk[mid_c]
        go = (probe <= key) & (mid <= nb - 1)
        lo = jnp.where(go, mid, lo)
        step = step // 2
    out_ref[...] = lo


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def fused_locate(
    queries: jax.Array,
    boundary_keys: jax.Array,
    frame_lo: jax.Array,
    frame_hi: jax.Array,
    bits: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Morton key-gen + directory search fused into one kernel.

    Returns, per query point, the index of the last boundary key <= its
    Morton key (clamped to 0) — i.e. its directory bucket.
    """
    q, d = queries.shape
    assert bits * d <= 32, "single-word fused kernel: bits*d must fit 32 bits"
    nb = boundary_keys.shape[0]
    assert nb <= DIR_MAX, "two-level directory required beyond DIR_MAX"
    steps = max(1, (nb - 1).bit_length())
    span = jnp.where(frame_hi > frame_lo, frame_hi - frame_lo, 1.0)
    span = span.astype(jnp.float32)[None, :]
    flo = frame_lo.astype(jnp.float32)[None, :]
    q_pad = pl.cdiv(q, BLOCK_Q) * BLOCK_Q
    qp = jnp.zeros((q_pad, d), jnp.float32).at[:q].set(queries)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, bits=bits, d=d, steps=steps, nb=nb),
        grid=(q_pad // BLOCK_Q,),
        in_specs=[
            pl.BlockSpec((BLOCK_Q, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((nb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q_pad,), jnp.int32),
        interpret=interpret,
    )(qp, flo, span, boundary_keys)
    return out[:q]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bucket_search(qkeys: jax.Array, boundary_keys: jax.Array, *, interpret: bool = True) -> jax.Array:
    """For each query key, index of the last boundary <= key (uint32)."""
    q = qkeys.shape[0]
    nb = boundary_keys.shape[0]
    assert nb <= DIR_MAX, "two-level directory required beyond DIR_MAX"
    steps = max(1, (nb - 1).bit_length())
    q_pad = pl.cdiv(q, BLOCK_Q) * BLOCK_Q
    qk = jnp.zeros((q_pad,), jnp.uint32).at[:q].set(qkeys)
    out = pl.pallas_call(
        functools.partial(_search_kernel, steps=steps, nb=nb),
        grid=(q_pad // BLOCK_Q,),
        in_specs=[
            pl.BlockSpec((BLOCK_Q,), lambda i: (i,)),
            pl.BlockSpec((nb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_Q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q_pad,), jnp.int32),
        interpret=interpret,
    )(qk, boundary_keys)
    return out[:q]
