"""Training step: loss -> grads -> clip -> schedule -> AdamW, with
optional microbatch gradient accumulation (scan) and cross-pod
error-feedback gradient compression.

The step is a pure function jitted with explicit in/out shardings by the
launcher / dry-run; under GSPMD the gradient reduction over the batch
axes is generated automatically (reduce-scatter for FSDP params).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models import model as M
from repro.optim import adamw, schedule

Pytree = Any


def make_loss_fn(run: RunConfig):
    mdl = M.get_model(run.model)

    def loss_fn(params, batch):
        return mdl.loss_fn(params, batch, run.model)

    return loss_fn


def make_train_step(run: RunConfig, total_steps: int = 10_000):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(run)
    sched = schedule.get(run.schedule)
    mb = run.microbatch

    def compute_grads(params, batch):
        B = batch["tokens"].shape[0]
        if mb is None or mb >= B:  # no accumulation (incl. reduced smoke configs)
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        # microbatch accumulation: reshape leading batch dim to (k, mb, ...)
        k = B // mb
        mbatch = jax.tree.map(lambda x: x.reshape((k, mb) + x.shape[1:]), batch)

        def acc(carry, mb_batch):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb_batch
            )
            gsum, lsum = carry
            gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), metrics = jax.lax.scan(acc, (g0, jnp.float32(0.0)), mbatch)
        grads = jax.tree.map(lambda g: (g / k).astype(jnp.float32), gsum)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return lsum / k, metrics, grads

    def step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        # step+1: the schedule must be nonzero on the very first update
        lr = sched(
            opt_state["step"] + 1, peak_lr=run.learning_rate,
            warmup=run.warmup_steps, total=total_steps,
        )
        params, opt_state = adamw.update(
            grads, opt_state, lr, weight_decay=run.weight_decay
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return step


def init_all(run: RunConfig, rng) -> tuple[Pytree, Pytree]:
    mdl = M.get_model(run.model)
    params = mdl.init_params(run.model, rng)
    opt_state = adamw.init(params)
    return params, opt_state
