"""Unified versioned curve index (paper §V-A, the 'sorted list of buckets').

One structure, three consumers. Before this module, the SFC key/bucket
machinery existed in three private copies: ``queries.QueryIndex`` rebuilt
keys and a bucket table from scratch, ``repartition.Repartitioner`` kept
its own cached keys + frozen quantization frame, and the partitioner
expressed slice boundaries against yet another sorted order. A
``CurveIndex`` is the single source of truth they now share:

* **keys** — the sorted SFC keys (uint32, sentinel ``0xFFFFFFFF`` tail
  for inactive storage slots when built from an engine).
* **bucket directory** — equal-count bucket starts + first-key-per-bucket,
  the binary-search target of point location.
* **quantization frame** — the (lo, hi) box queries are keyed against.
  Frozen at build/refresh time; identical to the owner's frame so cached
  point keys and fresh query keys live on the same curve.

Versioning: ``version`` is bumped by the owner on every refresh (geometry
change, migration, rebuild) and ``token`` ties the index to the
``repro.kernels.ops`` key cache. Both are *data* fields (traced scalars),
not pytree metadata — a version bump must not retrace jitted query
functions. Consumers holding an index compare ``int(index.version)``
against the owner's live version to decide whether to swap.

Construction paths:

* :func:`build` — cold: key-gen + sort + carve (what a fresh serving
  replica pays).
* :func:`from_sorted` — incremental refresh: wrap already-sorted arrays
  (an engine's cached keys/order) and carve the directory only. No
  key generation, no sort — this is why a refresh after a weight-only
  repartition step is an order of magnitude cheaper than :func:`build`.
* :func:`from_partition` — reuse a ``PartitionResult``'s keys and
  permutation; the partition's slice boundaries can then be expressed
  against the directory with :func:`bucket_parts`.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sfc as _sfc

KEY_SENTINEL = _sfc.KEY_SENTINEL  # canonical definition lives in sfc


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "points",
        "ids",
        "keys",
        "bucket_starts",
        "bucket_keys",
        "frame_lo",
        "frame_hi",
        "version",
        "token",
        "tree",
        "node_keys",
    ),
    meta_fields=("bits", "curve", "max_bucket_len"),
)
@dataclasses.dataclass(frozen=True)
class CurveIndex:
    """SFC-sorted point store + bucket directory + quantization frame.

    Two addressing modes share the structure:

    * **point-keyed** (``tree is None``) — each stored point carries its
      own coordinate key; queries are keyed by coordinates.
    * **tree-backed** (``tree`` set) — the directory IS the kd-tree's
      leaf buckets: stored keys are *bucket* keys (every member of a
      bucket shares one key) and queries are keyed by walking the tree
      root→leaf and gathering ``node_keys`` — the paper's own
      point-location path. Built by the bucket-statistics pipeline with
      O(B) key generation.
    """

    points: jax.Array         # (n, d) in curve order (tail slots may be stale)
    ids: jax.Array            # (n,) global/storage-slot id per sorted position
    keys: jax.Array           # (n,) uint32 sorted SFC keys (sentinel tail)
    bucket_starts: jax.Array  # (B+1,) start offset per bucket; [-1] == n_valid
    bucket_keys: jax.Array    # (B,) first key of each bucket (sorted)
    frame_lo: jax.Array       # (d,) quantization frame
    frame_hi: jax.Array       # (d,)
    version: jax.Array        # () int32 — bumped by the owner per refresh
    token: jax.Array          # () int32 — kernels.ops key-cache token (-1: none)
    bits: int
    curve: str                # "morton" | "hilbert"
    max_bucket_len: int       # static max bucket extent (query window sizing)
    tree: object | None = None       # LinearKdTree for tree-backed indexes
    node_keys: jax.Array | None = None  # (M,) uint32 bucket key per tree node

    @property
    def num_buckets(self) -> int:
        return self.bucket_keys.shape[0]

    @property
    def capacity(self) -> int:
        return self.points.shape[0]

    def valid_count(self) -> jax.Array:
        """Number of live (non-sentinel) entries, as a device scalar."""
        return self.bucket_starts[-1]


def _carve(n_valid: int, bucket_size: int) -> tuple[np.ndarray, int]:
    """Equal-count bucket starts over the live prefix (host-side).

    Returns (starts incl. final n_valid, max bucket extent). int64
    intermediate: ``arange(nb) * n`` overflows int32 beyond ~430k points.
    """
    nb = max(1, int(n_valid) // max(1, bucket_size))
    starts = (np.arange(nb + 1, dtype=np.int64) * int(n_valid)) // nb
    max_len = int(np.diff(starts).max()) if n_valid else 1
    return starts.astype(np.int32), max(1, max_len)


def from_sorted(
    points_sorted: jax.Array,
    ids_sorted: jax.Array,
    keys_sorted: jax.Array,
    *,
    n_valid: int,
    frame_lo: jax.Array,
    frame_hi: jax.Array,
    bits: int,
    curve: str = "morton",
    bucket_size: int = 32,
    version: int = 0,
    token: int = -1,
) -> CurveIndex:
    """Incremental-refresh constructor: carve the directory over arrays
    already in curve order. No key generation, no sort."""
    assert keys_sorted.ndim == 1, "CurveIndex requires single-word keys"
    starts, max_len = _carve(n_valid, bucket_size)
    starts_d = jnp.asarray(starts)
    bucket_keys = keys_sorted[starts_d[:-1]]
    return CurveIndex(
        points=points_sorted,
        ids=ids_sorted,
        keys=keys_sorted,
        bucket_starts=starts_d,
        bucket_keys=bucket_keys,
        frame_lo=jnp.asarray(frame_lo, jnp.float32),
        frame_hi=jnp.asarray(frame_hi, jnp.float32),
        version=jnp.asarray(version, jnp.int32),
        token=jnp.asarray(token, jnp.int32),
        bits=int(bits),
        curve=curve,
        max_bucket_len=max_len,
    )


def build(
    points: jax.Array,
    ids: jax.Array | None = None,
    *,
    bucket_size: int = 32,
    bits: int | None = None,
    curve: str = "morton",
    frame: tuple[jax.Array, jax.Array] | None = None,
    version: int = 0,
    token: int | None = None,
    use_pallas: bool = False,
) -> CurveIndex:
    """Cold build: key-gen + sort + carve.

    ``frame`` quantizes against a fixed box (an engine's frozen frame);
    default is the data's own bounding box. ``token`` routes key-gen
    through the ``kernels.ops`` token cache — pass it only when you own
    the token's invalidation (never share token 0 across point sets).
    """
    n, d = points.shape
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)
    if bits is None:
        bits = _sfc.max_bits_per_dim(d)
    if frame is None:
        lo = jnp.min(points, axis=0)
        hi = jnp.max(points, axis=0)
    else:
        lo, hi = frame
    if token is not None:
        from repro.kernels import ops as _kops

        keys = _kops.cached_sfc_key(
            points, token=token, curve=curve, bits=bits,
            use_pallas=use_pallas, lo=lo, hi=hi,
        )
    else:
        keys = keys_in_frame(points, lo, hi, bits=bits, curve=curve)
    order = jnp.argsort(keys, stable=True)
    return from_sorted(
        points[order],
        ids[order],
        keys[order],
        n_valid=n,
        frame_lo=lo,
        frame_hi=hi,
        bits=bits,
        curve=curve,
        bucket_size=bucket_size,
        version=version,
        token=-1 if token is None else token,
    )


def from_buckets(
    points_sorted: jax.Array,
    ids_sorted: jax.Array,
    keys_sorted: jax.Array,
    bucket_starts,
    bucket_keys: jax.Array,
    *,
    frame_lo: jax.Array,
    frame_hi: jax.Array,
    bits: int,
    curve: str = "hilbert",
    version: int = 0,
    token: int = -1,
    tree: object | None = None,
    node_keys: jax.Array | None = None,
) -> CurveIndex:
    """Tree-backed constructor: the directory is given *explicitly* —
    the kd-tree's leaf buckets in curve order — instead of equal-count
    carving. ``bucket_starts`` (host ints or array, B+1 entries ending at
    the valid count) and ``bucket_keys`` (B,) come straight from a
    ``kdtree.BucketOrder``; stored keys are bucket keys, and ``tree`` +
    ``node_keys`` give queries the root→leaf addressing path."""
    starts = np.asarray(bucket_starts, dtype=np.int64)
    max_len = int(np.diff(starts).max()) if starts.shape[0] > 1 else 1
    return CurveIndex(
        points=points_sorted,
        ids=ids_sorted.astype(jnp.int32),
        keys=keys_sorted,
        bucket_starts=jnp.asarray(starts.astype(np.int32)),
        bucket_keys=bucket_keys,
        frame_lo=jnp.asarray(frame_lo, jnp.float32),
        frame_hi=jnp.asarray(frame_hi, jnp.float32),
        version=jnp.asarray(version, jnp.int32),
        token=jnp.asarray(token, jnp.int32),
        bits=int(bits),
        curve=curve,
        max_bucket_len=max(1, max_len),
        tree=tree,
        node_keys=node_keys,
    )


def from_partition(
    points: jax.Array,
    perm: jax.Array,
    keys: jax.Array,
    *,
    curve: str = "morton",
    bits: int | None = None,
    bucket_size: int = 32,
    version: int = 0,
) -> CurveIndex:
    """Wrap a ``PartitionResult``'s keys + permutation — the partitioner
    and the query layer then share one key array and one sorted order.

    Only geometric-stats keys are addressable by query coordinates (rank
    stats re-key by data order; a query point has no rank) — callers must
    pass keys produced with ``stats='geometric'``.
    """
    assert keys.ndim == 1, "CurveIndex requires single-word keys"
    if bits is None:
        bits = _sfc.max_bits_per_dim(points.shape[1])
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    return from_sorted(
        points[perm],
        perm.astype(jnp.int32),
        keys[perm],
        n_valid=points.shape[0],
        frame_lo=lo,
        frame_hi=hi,
        bits=bits,
        curve=curve,
        bucket_size=bucket_size,
        version=version,
    )


# ---------------------------------------------------------------------------
# Keying queries onto the index's curve
# ---------------------------------------------------------------------------

def keys_in_frame(
    pts: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    bits: int,
    curve: str = "morton",
) -> jax.Array:
    """SFC keys against a fixed quantization frame — delegates to the one
    shared convention in :func:`repro.core.sfc.keys_in_frame` (kept as a
    re-export so existing jitted query kernels don't move)."""
    return _sfc.keys_in_frame(pts, lo, hi, bits=bits, curve=curve)


def owner_from_firsts(firsts: jax.Array, query_keys: jax.Array) -> jax.Array:
    """Owner chunk of each query key: the LAST chunk whose first key is
    <= the key. ``firsts`` (C,) are the first sorted keys of contiguous
    curve chunks (shards, nodes, or a node's devices); keys below the
    first chunk clamp to chunk 0, exactly like a too-small key clamps
    into the first curve cell.

    This is the ONE routing convention: the flat serving kernel applies
    it once over all shard firsts; the two-level kernel applies it twice
    (key -> node over node firsts, then key -> device over the owner
    node's device firsts) and lands on the same shard because the firsts
    are globally sorted.
    """
    n = firsts.shape[0]
    idx = jnp.searchsorted(firsts, query_keys, side="right").astype(jnp.int32) - 1
    return jnp.clip(idx, 0, n - 1)


def query_keys(index: CurveIndex, queries: jax.Array) -> jax.Array:
    """Key a query batch onto the index's curve.

    Point-keyed indexes quantize the coordinates against the frame;
    tree-backed indexes walk the tree root→leaf and gather the bucket
    key — the paper's point-location path, and the only addressing under
    which bucket-granular stored keys are exact."""
    if index.tree is not None:
        from repro.core import dynamic as _dyn

        leaf = _dyn.locate(index.tree, queries, index.tree.max_depth)
        return index.node_keys[leaf]
    return keys_in_frame(
        queries, index.frame_lo, index.frame_hi, bits=index.bits, curve=index.curve
    )


def bucket_lookup(index: CurveIndex, keys: jax.Array) -> jax.Array:
    """Directory bucket holding each key: the LAST bucket whose first key
    is <= the key (the same convention as `owner_from_firsts`, applied to
    the index's own B-entry directory instead of shard firsts).

    This is the O(log B) directory hop every consumer of the index
    shares: point location scans the bucket this returns, and the mesh
    halo layer resolves a face-neighbor's *owning part* by looking its
    key up here and reading the bucket's part — neither ever touches the
    O(n) sorted store to route.
    """
    return owner_from_firsts(index.bucket_keys, keys)


def replicable_buckets(index: CurveIndex, *, bucket_cap: int) -> np.ndarray:
    """(B,) bool — directory buckets whose rows may be replicated onto
    every shard as "exceptions to the partition" (hot-bucket serving)
    with *bit-identical* point-location answers.

    Bucket b is eligible iff every query key that ``bucket_lookup`` maps
    to b has its ENTIRE key-equal run inside b's rows, and that run fits
    the ``bucket_cap`` scan window. Then the annex scan sees exactly the
    rows the routed owner-shard scan sees, in the same sorted order —
    found / first-match id / miss certificate all coincide. Host-side
    checks over the sorted keys:

    * non-empty and no larger than ``bucket_cap`` rows;
    * the first key does not continue a run from the previous bucket
      (else a query mapping here may have matches before ``start_b``);
    * the last key does not continue into the next bucket (else matches
      after ``end_b``).
    """
    keys = np.asarray(index.keys)
    starts = np.asarray(index.bucket_starts).astype(np.int64)
    n_valid = int(starts[-1])
    lo, hi = starts[:-1], starts[1:]
    size = hi - lo
    ok = (size >= 1) & (size <= int(bucket_cap))
    if n_valid == 0:
        return np.zeros(lo.shape[0], dtype=bool)
    li = np.clip(lo, 0, n_valid - 1)       # clipped reads are only used
    hc = np.clip(hi, 0, n_valid - 1)       # where the guard bit is live
    cross_in = (lo > 0) & (keys[np.maximum(li - 1, 0)] == keys[li])
    cross_out = (hi < n_valid) & (keys[np.maximum(hc - 1, 0)] == keys[hc])
    return np.asarray(ok & ~cross_in & ~cross_out, dtype=bool)


# ---------------------------------------------------------------------------
# Slice boundaries against the directory
# ---------------------------------------------------------------------------

@jax.jit
def bucket_parts(index: CurveIndex, boundaries: jax.Array) -> jax.Array:
    """Part id owning each directory bucket.

    ``boundaries`` is the knapsack slice (P+1 starts into the sorted
    order, as in ``PartitionResult.boundaries``). Bucket b belongs to the
    part whose slice contains its first element — the directory and the
    partition live on the same curve, so this is a single searchsorted.
    """
    num_parts = boundaries.shape[0] - 1
    p = jnp.searchsorted(boundaries[1:], index.bucket_starts[:-1], side="right")
    return jnp.clip(p, 0, num_parts - 1).astype(jnp.int32)
