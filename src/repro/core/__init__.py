"""Core library: the paper's geometric partitioner as composable JAX modules."""
from repro.core import (  # noqa: F401
    curve_index,
    dynamic,
    kdtree,
    knapsack,
    metrics,
    migration,
    partitioner,
    queries,
    repartition,
    sfc,
    spmv,
)
from repro.core.curve_index import CurveIndex  # noqa: F401
from repro.core.partitioner import (  # noqa: F401
    PartitionerConfig,
    PartitionResult,
    distributed_partition,
    distributed_reslice,
    partition,
    partition_with_index,
)
from repro.core.repartition import (  # noqa: F401
    DistributedRepartitioner,
    Repartitioner,
    RepartitionStep,
)
