"""Core library: the paper's geometric partitioner as composable JAX modules."""
from repro.core import (  # noqa: F401
    curve_index,
    dynamic,
    kdtree,
    knapsack,
    metrics,
    migration,
    partitioner,
    queries,
    repartition,
    sfc,
    spmv,
)
from repro.core.curve_index import CurveIndex  # noqa: F401
from repro.core.kdtree import BucketOrder, BucketSummary  # noqa: F401
from repro.core.partitioner import (  # noqa: F401
    HierarchicalResult,
    HierarchyPlan,
    PartitionerConfig,
    PartitionResult,
    distributed_bucket_partition,
    distributed_bucket_reslice,
    distributed_partition,
    distributed_reslice,
    hierarchical_bucket_partition,
    hierarchical_bucket_reslice,
    hierarchical_partition,
    hierarchical_reslice,
    materialize_perm,
    partition,
    partition_buckets,
    partition_with_index,
)
from repro.core.repartition import (  # noqa: F401
    DistributedBucketRepartitioner,
    DistributedRepartitioner,
    HierarchicalRepartitioner,
    Repartitioner,
    RepartitionStep,
)
