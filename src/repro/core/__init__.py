"""Core library: the paper's geometric partitioner as composable JAX modules."""
from repro.core import (  # noqa: F401
    dynamic,
    kdtree,
    knapsack,
    metrics,
    migration,
    partitioner,
    queries,
    sfc,
    spmv,
)
from repro.core.partitioner import (  # noqa: F401
    PartitionerConfig,
    PartitionResult,
    distributed_partition,
    partition,
)
