"""Core library: the paper's geometric partitioner as composable JAX modules."""
from repro.core import (  # noqa: F401
    dynamic,
    kdtree,
    knapsack,
    metrics,
    migration,
    partitioner,
    queries,
    repartition,
    sfc,
    spmv,
)
from repro.core.partitioner import (  # noqa: F401
    PartitionerConfig,
    PartitionResult,
    distributed_partition,
    distributed_reslice,
    partition,
)
from repro.core.repartition import (  # noqa: F401
    DistributedRepartitioner,
    Repartitioner,
    RepartitionStep,
)
