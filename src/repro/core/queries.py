"""Parallel query processing on SFC-partitioned point data (paper §V-A).

* Exact point location — queries are keyed by bit-interleaving their
  coordinates and binary-searched against the sorted bucket boundaries;
  a final in-bucket scan finds the exact match. O(log N_buckets) per
  query, vectorized over the whole query batch.
* k-nearest neighbors — locate the query's bucket, then search the
  CUTOFF-neighborhood of buckets along the curve (the paper restricts
  CUTOFF to one bucket before/after) and select the k smallest distances.

Both run against a ``QueryIndex`` built from the partitioner output and
both have Pallas fast paths (``repro.kernels.bucket_search``) for the key
search — the innermost hot loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sfc as _sfc


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("points", "ids", "keys", "bucket_starts", "bucket_keys", "bbox_lo", "bbox_hi"),
    meta_fields=("bits",),
)
@dataclasses.dataclass(frozen=True)
class QueryIndex:
    """SFC-sorted point store with bucket directory (the paper's
    'sorted list of buckets' for fast point location)."""

    points: jax.Array         # (n, d) in SFC order
    ids: jax.Array            # (n,) original global ids
    keys: jax.Array           # (n,) uint32 SFC key per point (sorted)
    bucket_starts: jax.Array  # (B+1,) start offset of each bucket
    bucket_keys: jax.Array    # (B,) first key in each bucket (sorted)
    bbox_lo: jax.Array        # (d,)
    bbox_hi: jax.Array        # (d,)
    bits: int


def build_index(
    points: jax.Array,
    ids: jax.Array | None = None,
    *,
    bucket_size: int = 32,
    bits: int | None = None,
) -> QueryIndex:
    """Pre-sort points by Morton key and carve equal-count buckets.

    Uses Morton (the paper's point-location fast path works 'only with
    Morton SFC': key search needs key order == curve order, which the
    closed-form Morton keys give directly).
    """
    n, d = points.shape
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)
    if bits is None:
        bits = _sfc.max_bits_per_dim(d)
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    keys = _sfc.morton_key(points, bits)
    order = jnp.argsort(keys, stable=True)
    pts_s, ids_s, keys_s = points[order], ids[order], keys[order]
    nb = max(1, n // bucket_size)
    # host-side int64: arange(nb)*n overflows int32 beyond ~430k points
    import numpy as _np

    starts = jnp.asarray(
        (_np.arange(nb, dtype=_np.int64) * n) // nb, dtype=jnp.int32
    )
    bucket_keys = keys_s[starts]
    starts_full = jnp.concatenate([starts, jnp.array([n], dtype=jnp.int32)])
    return QueryIndex(
        points=pts_s,
        ids=ids_s,
        keys=keys_s,
        bucket_starts=starts_full,
        bucket_keys=bucket_keys,
        bbox_lo=lo,
        bbox_hi=hi,
        bits=bits,
    )


def _query_keys(index: QueryIndex, queries: jax.Array) -> jax.Array:
    span = jnp.where(index.bbox_hi > index.bbox_lo, index.bbox_hi - index.bbox_lo, 1.0)
    unit = jnp.clip((queries - index.bbox_lo) / span, 0.0, 1.0 - 1e-7)
    cells = (unit * (2**index.bits)).astype(jnp.uint32)
    return _sfc.morton_key_from_cells(cells, index.bits)


@jax.jit
def locate_bucket(index: QueryIndex, queries: jax.Array) -> jax.Array:
    """Bucket id per query via binary search on sorted bucket keys."""
    qk = _query_keys(index, queries)
    b = jnp.searchsorted(index.bucket_keys, qk, side="right") - 1
    return jnp.clip(b, 0, index.bucket_keys.shape[0] - 1)


@functools.partial(jax.jit, static_argnames=("bucket_cap",))
def point_location(
    index: QueryIndex, queries: jax.Array, *, bucket_cap: int = 64
) -> tuple[jax.Array, jax.Array]:
    """Exact point location. Returns (found_mask, global_id or -1).

    Vectorized: binary search to the bucket, then scan up to ``bucket_cap``
    candidate slots for an exact coordinate match.
    """
    b = locate_bucket(index, queries)
    start = index.bucket_starts[b]
    n = index.points.shape[0]
    # gather bucket_cap candidates per query (clipped at the end)
    offs = jnp.arange(bucket_cap, dtype=jnp.int32)
    cand = jnp.minimum(start[:, None] + offs[None, :], n - 1)  # (q, cap)
    cpts = index.points[cand]                                   # (q, cap, d)
    eq = jnp.all(cpts == queries[:, None, :], axis=-1)          # (q, cap)
    within = (start[:, None] + offs[None, :]) < index.bucket_starts[jnp.minimum(b + 1, index.bucket_keys.shape[0])][:, None]
    hit = eq & within
    found = jnp.any(hit, axis=1)
    slot = jnp.argmax(hit, axis=1)
    gid = index.ids[cand[jnp.arange(queries.shape[0]), slot]]
    return found, jnp.where(found, gid, -1)


@functools.partial(jax.jit, static_argnames=("k", "cutoff_buckets", "bucket_cap"))
def knn(
    index: QueryIndex,
    queries: jax.Array,
    *,
    k: int = 3,
    cutoff_buckets: int = 1,
    bucket_cap: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Approximate k-NN: search the query's bucket ± cutoff_buckets along
    the curve (paper: 'CUTOFF restricted to one bucket before and after').

    Returns (distances (q, k), global ids (q, k)).
    """
    nb = index.bucket_keys.shape[0]
    n = index.points.shape[0]
    b = locate_bucket(index, queries)
    b0 = jnp.clip(b - cutoff_buckets, 0, nb - 1)
    b1 = jnp.clip(b + cutoff_buckets, 0, nb - 1)
    start = index.bucket_starts[b0]
    end = index.bucket_starts[b1 + 1]
    win = bucket_cap * (2 * cutoff_buckets + 1)
    offs = jnp.arange(win, dtype=jnp.int32)
    cand = jnp.minimum(start[:, None] + offs[None, :], n - 1)
    valid = (start[:, None] + offs[None, :]) < end[:, None]
    cpts = index.points[cand]
    d2 = jnp.sum((cpts - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg_top, idx = jax.lax.top_k(-d2, k)
    gids = index.ids[jnp.take_along_axis(cand, idx, axis=1)]
    return jnp.sqrt(-neg_top), gids


def knn_bruteforce(points: jax.Array, queries: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Oracle for tests (O(nq) memory — small inputs only)."""
    d2 = jnp.sum((queries[:, None, :] - points[None, :, :]) ** 2, axis=-1)
    neg_top, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(-neg_top), idx
