"""Parallel query processing on SFC-partitioned point data (paper §V-A).

* Exact point location — queries are keyed by bit-interleaving their
  coordinates and binary-searched against the sorted keys; an in-run scan
  finds the exact match. O(log N) per query, vectorized over the batch.
* k-nearest neighbors — locate the query's bucket, then search the
  CUTOFF-neighborhood of buckets along the curve (the paper restricts
  CUTOFF to one bucket before/after) and select the k smallest distances.

Both run against a shared :class:`repro.core.curve_index.CurveIndex`
(built cold here, or refreshed incrementally from a ``Repartitioner``'s
cached keys) and both route the key search through the Pallas
``bucket_search`` kernel when compiled kernels are enabled
(``REPRO_PALLAS_COMPILE=1`` / ``kernels.ops.set_interpret(False)``),
falling back to ``jnp.searchsorted`` in interpret mode where the pure-jnp
path is the faster one.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import curve_index as _ci

# The index type is shared with the repartitioning engine and the
# partitioner; ``QueryIndex`` remains as a compatibility alias.
CurveIndex = _ci.CurveIndex
QueryIndex = _ci.CurveIndex


def _pallas_default() -> bool:
    """Pallas fast path by default only when kernels compile natively
    (on CPU/interpret mode jnp.searchsorted wins)."""
    from repro.kernels import ops as _kops

    return not _kops.INTERPRET


def build_index(
    points: jax.Array,
    ids: jax.Array | None = None,
    *,
    bucket_size: int = 32,
    bits: int | None = None,
) -> CurveIndex:
    """Cold-build a query index: Morton key-gen + sort + bucket carve.

    Uses Morton (the paper's point-location fast path works 'only with
    Morton SFC': key search needs key order == curve order, which the
    closed-form Morton keys give directly). Incremental consumers should
    prefer ``Repartitioner.curve_index()``, which reuses cached keys.
    """
    return _ci.build(points, ids, bucket_size=bucket_size, bits=bits, curve="morton")


def _searchsorted_u32(
    sorted_keys: jax.Array, qk: jax.Array, side: str, use_pallas: bool
) -> jax.Array:
    """searchsorted over sorted uint32 keys, routed through the Pallas
    ``bucket_search`` kernel (last-boundary<=key probe) when enabled.

    Exact for integer keys: right(q) = last_le(q)+1 (0 when q < keys[0]);
    left(q) = right(q-1) for q > 0, else 0.
    """
    from repro.kernels import bucket_search as _bsk
    from repro.kernels import ops as _kops

    if not use_pallas or sorted_keys.shape[0] > _bsk.DIR_MAX:
        return jnp.searchsorted(sorted_keys, qk, side=side).astype(jnp.int32)
    if side == "right":
        last_le = _kops.bucket_search(qk, sorted_keys)
        return jnp.where(sorted_keys[0] <= qk, last_le + 1, 0).astype(jnp.int32)
    qm = qk - jnp.uint32(1)
    last_lt = _kops.bucket_search(qm, sorted_keys)
    cnt = jnp.where(sorted_keys[0] <= qm, last_lt + 1, 0)
    return jnp.where(qk > jnp.uint32(0), cnt, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _locate_bucket(index: CurveIndex, queries: jax.Array, use_pallas: bool) -> jax.Array:
    from repro.kernels import bucket_search as _bsk

    if (
        use_pallas
        and index.curve == "morton"
        and index.tree is None  # tree-backed keys come from a tree walk,
        #                         not from query coordinates
        and index.num_buckets <= _bsk.DIR_MAX
    ):
        from repro.kernels import ops as _kops

        # fused key-gen + directory search in one kernel dispatch (beyond
        # DIR_MAX the directory doesn't fit VMEM: degrade to the exact
        # jnp path below rather than assert)
        return _kops.fused_locate(
            queries, index.bucket_keys, index.frame_lo, index.frame_hi, index.bits
        )
    qk = _ci.query_keys(index, queries)
    b = _searchsorted_u32(index.bucket_keys, qk, "right", use_pallas) - 1
    return jnp.clip(b, 0, index.num_buckets - 1)


def locate_bucket(
    index: CurveIndex, queries: jax.Array, *, use_pallas: bool | None = None
) -> jax.Array:
    """Bucket id per query via binary search on the sorted directory."""
    if use_pallas is None:
        use_pallas = _pallas_default()
    return _locate_bucket(index, queries, use_pallas)


class PointLocation(NamedTuple):
    found: jax.Array  # (q,) bool — exact coordinate match located
    ids: jax.Array    # (q,) int32 global/slot id, -1 when not found
    ok: jax.Array     # (q,) bool — False iff the key-equal run exceeded
    #                   bucket_cap without a hit, i.e. the miss is not
    #                   certified (raise bucket_cap to resolve)


@functools.partial(jax.jit, static_argnames=("bucket_cap", "use_pallas"))
def _point_location(
    index: CurveIndex, queries: jax.Array, bucket_cap: int, use_pallas: bool
) -> PointLocation:
    qk = _ci.query_keys(index, queries)
    # Exact extent of the key-equal run in the sorted key array. Equal
    # coordinates imply equal keys, so every possible match lies in
    # [lo_i, hi_i) — unlike a single-bucket scan, this cannot silently
    # miss when duplicates spill a bucket (runs spanning bucket or even
    # partition boundaries are covered).
    lo_i = _searchsorted_u32(index.keys, qk, "left", use_pallas)
    hi_i = _searchsorted_u32(index.keys, qk, "right", use_pallas)
    run = hi_i - lo_i
    n = index.capacity
    offs = jnp.arange(bucket_cap, dtype=jnp.int32)
    pos = lo_i[:, None] + offs[None, :]
    cand = jnp.clip(pos, 0, n - 1)                              # (q, cap)
    cpts = index.points[cand]                                    # (q, cap, d)
    hit = jnp.all(cpts == queries[:, None, :], axis=-1) & (pos < hi_i[:, None])
    found = jnp.any(hit, axis=1)
    slot = jnp.argmax(hit, axis=1)
    gid = index.ids[cand[jnp.arange(queries.shape[0]), slot]].astype(jnp.int32)
    ok = found | (run <= bucket_cap)
    return PointLocation(found, jnp.where(found, gid, -1), ok)


def point_location(
    index: CurveIndex,
    queries: jax.Array,
    *,
    bucket_cap: int = 64,
    use_pallas: bool | None = None,
) -> PointLocation:
    """Exact point location: (found, id or -1, ok).

    ``ok[i]`` is False only when query i missed *and* more than
    ``bucket_cap`` stored points share its SFC key (duplicate-heavy
    distributions) — the scan window was exhausted, so the miss is not a
    certificate of absence.
    """
    if use_pallas is None:
        use_pallas = _pallas_default()
    return _point_location(index, queries, bucket_cap, use_pallas)


@functools.partial(
    jax.jit, static_argnames=("k", "cutoff_buckets", "use_pallas", "max_window")
)
def _knn(
    index: CurveIndex,
    queries: jax.Array,
    k: int,
    cutoff_buckets: int,
    use_pallas: bool,
    max_window: int,
) -> tuple[jax.Array, jax.Array]:
    nb = index.num_buckets
    n = index.capacity
    b = _locate_bucket(index, queries, use_pallas)
    b0 = jnp.clip(b - cutoff_buckets, 0, nb - 1)
    b1 = jnp.clip(b + cutoff_buckets, 0, nb - 1)
    start = index.bucket_starts[b0]
    end = index.bucket_starts[b1 + 1]
    # Candidate window sized from the directory's true maximum bucket
    # extent (static metadata) — a fixed per-bucket cap undercovers
    # whenever carving produces buckets larger than the cap. max_window
    # bounds the (q, win, d) candidate tensor: one degenerate bucket
    # (duplicate-heavy cell) must not OOM the whole batch.
    win = max(k, min(n, index.max_bucket_len * (2 * cutoff_buckets + 1), max_window))
    offs = jnp.arange(win, dtype=jnp.int32)
    pos = start[:, None] + offs[None, :]
    cand = jnp.clip(pos, 0, n - 1)
    valid = pos < end[:, None]
    cpts = index.points[cand]
    d2 = jnp.sum((cpts - queries[:, None, :]) ** 2, axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    neg_top, idx = jax.lax.top_k(-d2, k)
    gids = index.ids[jnp.take_along_axis(cand, idx, axis=1)].astype(jnp.int32)
    return jnp.sqrt(-neg_top), gids


def knn(
    index: CurveIndex,
    queries: jax.Array,
    *,
    k: int = 3,
    cutoff_buckets: int = 1,
    use_pallas: bool | None = None,
    max_window: int = 1024,
) -> tuple[jax.Array, jax.Array]:
    """Approximate k-NN: search the query's bucket ± cutoff_buckets along
    the curve (paper: 'CUTOFF restricted to one bucket before and after').

    The candidate window covers the true bucket extents up to
    ``max_window`` slots per query — raise it for duplicate-heavy data
    where one bucket exceeds that (at (q, max_window, d) memory cost).

    Returns (distances (q, k), global ids (q, k)).
    """
    if use_pallas is None:
        use_pallas = _pallas_default()
    return _knn(index, queries, k, cutoff_buckets, use_pallas, max_window)


def knn_bruteforce(points: jax.Array, queries: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Oracle for tests (O(nq) memory — small inputs only)."""
    d2 = jnp.sum((queries[:, None, :] - points[None, :, :]) ** 2, axis=-1)
    neg_top, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(-neg_top), idx
