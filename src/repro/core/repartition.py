"""Incremental repartitioning engine (paper §IV, wired end-to-end).

The paper's headline economics: a *repeated* repartition of a drifting
load distribution must cost far less than the initial one. The static
pipeline (``partitioner.partition``) pays key generation + sort + slice
every call. This module keeps the expensive artifacts alive across
timesteps and only recomputes what a delta invalidates:

===========================  =========================================
change                       work done
===========================  =========================================
weights only                 re-slice the cached curve (no key-gen,
                             no sort, no tree work)
insert / delete points       key-gen for the delta batch only, re-sort
                             cached keys, re-slice; kd-tree updated via
                             ``dynamic.insert``/``delete`` bumps
credit exhaustion            full rebuild: ``dynamic.adjustments``
                             (Alg. 1), fresh quantization frame, fresh
                             keys (Alg. 3 decides *when*)
===========================  =========================================

Keys are generated against a **frozen quantization frame** (the bounding
box captured at the last rebuild, with margin). This is what makes
cached keys reusable at all — the static path re-fits the box every
call, so old keys would silently shift. Points drifting outside the
frame are clipped into the boundary cells until the next rebuild
refreshes the frame.

Every step emits a ``migration.MigrationPlan`` so the application can
move payloads with the bounded-message exchange. Storage-slot ids are
the stable element identity across steps.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from dataclasses import dataclass, field
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import curve_index as _ci
from repro.core import dynamic as _dyn
from repro.core import kdtree as _kdtree
from repro.core import knapsack as _knapsack
from repro.core import migration as _migration
from repro.core import partitioner as _pt
from repro.core import sfc as _sfc

KEY_SENTINEL = _ci.KEY_SENTINEL  # inactive-slot key: sorts to the tail

# Process-global token source for the kernels.ops key cache. Tokens must
# be unique across engine *instances*, not just monotonic within one: the
# cache is keyed (token, curve, bits, shape, ...), so two engines with
# same-shaped point stores and private counters both starting at 0 would
# silently read each other's (stale) keys.
_TOKEN_SOURCE = itertools.count(1)


@functools.partial(jax.jit, static_argnames=("num_parts",))
def _slice_kernel(order, active, weights, num_parts):
    """Fused incremental re-slice: gather weights into curve order,
    knapsack-slice, scatter part ids back to slots. One dispatch per
    step — this IS the incremental path's entire device work."""
    act_sorted = active[order]
    w_sorted = jnp.where(act_sorted, weights[order], 0.0)
    part_sorted = _knapsack.slice_weighted_curve(w_sorted, num_parts)
    part_sorted = jnp.where(act_sorted, part_sorted, -1)
    part = jnp.full(order.shape, -1, jnp.int32).at[order].set(part_sorted)
    loads = _knapsack.part_loads(w_sorted, jnp.maximum(part_sorted, 0), num_parts)
    return part, loads


@functools.partial(jax.jit, static_argnames=("num_parts",))
def _bucket_slice_kernel(leaf_id, active, weights, order, num_parts):
    """Tree-mode incremental re-slice: aggregate live weights onto the
    buckets (one segment_sum), knapsack the O(B) bucket weights in the
    cached curve order, gather part ids back through leaf_id. No
    per-point sort exists anywhere in this path — inserts and deletes
    never trigger a resort, unlike the cached-key path."""
    M = order.shape[0]
    w_leaf = jax.ops.segment_sum(
        jnp.where(active, weights, 0.0), leaf_id, num_segments=M
    )
    w_rank = w_leaf[order]
    part_rank = _knapsack.slice_weighted_curve(w_rank, num_parts)
    part_by_node = jnp.zeros((M,), jnp.int32).at[order].set(part_rank)
    part = jnp.where(active, part_by_node[leaf_id], -1)
    loads = _knapsack.part_loads(w_rank, part_rank, num_parts)
    return part, loads


@functools.partial(jax.jit, static_argnames=("num_nodes", "devices_per_node"))
def _hier_bucket_slice_kernel(
    leaf_id, active, weights, order, num_nodes, devices_per_node
):
    """Two-level tree-mode re-slice: one segment_sum onto the buckets,
    nested node->device knapsack over the O(B) bucket weights in cached
    curve order, gathers back through leaf_id. The full (inter-node)
    level: node slices move too."""
    M = order.shape[0]
    w_leaf = jax.ops.segment_sum(
        jnp.where(active, weights, 0.0), leaf_id, num_segments=M
    )
    w_rank = w_leaf[order]
    node_rank, _, part_rank = _knapsack.two_level_slice(
        w_rank, num_nodes, devices_per_node
    )
    part_by_node = jnp.zeros((M,), jnp.int32).at[order].set(part_rank)
    node_by_node = jnp.zeros((M,), jnp.int32).at[order].set(node_rank)
    part = jnp.where(active, part_by_node[leaf_id], -1)
    loads = _knapsack.part_loads(w_rank, part_rank, num_nodes * devices_per_node)
    node_loads = _knapsack.part_loads(w_rank, node_rank, num_nodes)
    return part, loads, node_loads, node_by_node


@functools.partial(jax.jit, static_argnames=("num_nodes", "devices_per_node"))
def _hier_intra_slice_kernel(
    leaf_id, active, weights, order, bucket_node, num_nodes, devices_per_node
):
    """Intra-node-only re-slice: the bucket->node assignment is FROZEN
    (``bucket_node``), only each node's device slices are re-knapsacked —
    every migration this step produces is node-local by construction."""
    M = order.shape[0]
    w_leaf = jax.ops.segment_sum(
        jnp.where(active, weights, 0.0), leaf_id, num_segments=M
    )
    w_rank = w_leaf[order]
    node_rank = bucket_node[order]
    dev_rank = _knapsack.device_slice_within_nodes(
        w_rank, node_rank, num_nodes, devices_per_node
    )
    part_rank = node_rank * devices_per_node + dev_rank
    part_by_node = jnp.zeros((M,), jnp.int32).at[order].set(part_rank)
    part = jnp.where(active, part_by_node[leaf_id], -1)
    loads = _knapsack.part_loads(w_rank, part_rank, num_nodes * devices_per_node)
    node_loads = _knapsack.part_loads(w_rank, node_rank, num_nodes)
    return part, loads, node_loads


@functools.partial(jax.jit, static_argnames=("num_parts",))
def _send_counts_kernel(old_part, new_part, num_parts):
    """(P, P) migration count matrix, reduced on device (elements active
    in both assignments only)."""
    both = (old_part >= 0) & (new_part >= 0)
    idx = jnp.where(both, old_part * num_parts + new_part, num_parts * num_parts)
    counts = jax.ops.segment_sum(
        jnp.ones_like(idx), idx, num_segments=num_parts * num_parts + 1
    )
    return counts[:-1].reshape(num_parts, num_parts)


@dataclass(frozen=True)
class RepartitionStep:
    """One engine step: the new assignment plus how we got it."""

    kind: Literal["incremental", "rebuild"]
    part: jax.Array            # (C,) int32 part per storage slot, -1 inactive
    plan: _migration.MigrationPlan
    loads: np.ndarray          # (P,) weight per part
    imbalance: float           # max load / mean load
    reused_keys: bool          # True iff no key generation ran this step
    # hierarchical engines only (None on flat engines):
    level: Literal["intra", "inter"] | None = None  # which re-slice level ran
    node_loads: np.ndarray | None = None            # (N,) weight per node
    node_imbalance: float | None = None


@dataclass
class RepartitionStats:
    rebuilds: int = 0
    incremental_steps: int = 0
    # storage slots run through key generation; rebuilds are
    # capacity-shaped (fixed-shape kernels), inserts count the delta batch
    keygen_points: int = 0
    # tree mode: buckets run through (O(B)) key generation at rebuilds,
    # and summary entries refreshed by delta scatters between rebuilds
    keygen_buckets: int = 0
    summary_refreshes: int = 0
    # hierarchical engines: how often each re-slice level fired (an
    # intra-node step never moves an element across nodes; an inter-node
    # step re-slices both levels)
    intra_reslices: int = 0
    inter_reslices: int = 0
    # elastic part-count changes (device loss / growth): re-slices of the
    # CACHED curve onto a new part count — never a rebuild
    resizes: int = 0
    history: list = field(default_factory=list)


class Repartitioner:
    """Stateful incremental repartitioner over a dynamic point set.

    >>> rp = Repartitioner(points, weights, num_parts=16)
    >>> rp.update_weights(new_weights)      # drift the load
    >>> step = rp.step()                    # incremental or full rebuild
    >>> step.plan.total_moved, step.kind

    The amortized controller (paper Alg. 3) decides incremental-vs-rebuild
    inside ``step``; ``rebalance()`` / ``rebuild()`` force one or the
    other. ``insert``/``delete`` apply geometry deltas through the cached
    linearized kd-tree (``dynamic.locate``), so point location for the
    delta batch is a root→leaf walk, not a build.

    Two substrates, selected by ``cfg.use_tree``:

    * **cached-key mode** (default) — per-point SFC keys against the
      frozen frame; inserts/deletes re-sort the cached n-length key
      array, weight drift re-slices the cached order.
    * **tree mode** — the kd-tree's leaf buckets are the statistics
      substrate: rebuilds key the O(B) bucket centroids only (never the
      points), inserts/deletes update the dirtied bucket summaries by
      delta scatters (``dynamic.locate`` + Alg. 1 adjustments at
      rebuild), and every re-slice is a knapsack over bucket weights —
      **no per-point key array exists and no per-point sort ever runs**.
      Balance granularity is one bucket instead of one element.
    """

    def __init__(
        self,
        points: jax.Array,
        weights: jax.Array | None = None,
        num_parts: int = 8,
        cfg: _pt.PartitionerConfig = _pt.PartitionerConfig(),
        *,
        capacity: int | None = None,
        max_depth: int = 12,
        bucket_size: int = 32,
        controller: _dyn.AmortizedController | None = None,
        rebuild_cost: float | None = None,
        frame_margin: float = 0.25,
    ):
        n, d = points.shape
        if weights is None:
            weights = jnp.ones((n,), dtype=jnp.float32)
        self.num_parts = int(num_parts)
        self.cfg = cfg
        self.tree_mode = bool(cfg.use_tree)
        self.bits = cfg.bits if cfg.bits is not None else _sfc.max_bits_per_dim(d)
        self.frame_margin = float(frame_margin)
        self.controller = controller or _dyn.AmortizedController()
        # modeled cost of one full rebuild in controller units; default is
        # calibrated in rebuild() from the live imbalance baseline
        self._rebuild_cost = rebuild_cost
        self.stats = RepartitionStats()
        self._cache_token = next(_TOKEN_SOURCE)
        # versioned query-index state: bumped on every geometry / frame /
        # order change (insert, delete, rebuild) so serving layers holding
        # a CurveIndex can detect staleness and refresh incrementally
        self._index_version = 0
        self._index_cache: tuple[tuple[int, int], _ci.CurveIndex] | None = None
        # bumped only when the tracked POINT POPULATION changes (insert /
        # delete) — never on re-slices or rebuilds, which move ownership
        # of the same points. Plan caches (repro.mesh.plan_cache) key
        # their topology tier on this: AMR-free events can reuse every
        # adjacency-derived structure.
        self.topology_version = 0

        self.dps = _dyn.from_points(
            points,
            weights,
            capacity=capacity,
            max_depth=max_depth,
            bucket_size=bucket_size,
            splitter=cfg.splitter,
        )
        self._part = jnp.full((self.capacity,), -1, dtype=jnp.int32)
        self.rebuild()

    # -- basic accessors ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.dps.capacity

    @property
    def part(self) -> jax.Array:
        """(C,) int32 part id per storage slot (-1 for inactive slots)."""
        return self._part

    @property
    def cache_token(self) -> int:
        """Bumped whenever cached keys are invalidated (geometry/frame
        change); `repro.kernels.ops.cached_sfc_key` uses it as the cache
        key for the Pallas key-gen path."""
        return self._cache_token

    def num_active(self) -> int:
        return int(self.dps.active.sum())

    def partition_of(self, slot_ids) -> np.ndarray:
        """Current part id per given storage slot, validated.

        The slot-keyed consumer's accessor (the mesh application tracks
        its cells by slot): raises if any queried slot is inactive —
        silently reading a -1 part for a live-looking element is exactly
        the class of bug a stale slot array produces.
        """
        ids = np.asarray(slot_ids)
        if ids.size and (ids.min() < 0 or ids.max() >= self.capacity):
            # numpy would silently wrap negative ids to the tail slots —
            # the exact stale-slot read this accessor exists to catch
            raise ValueError(
                f"slot ids out of range [0, {self.capacity}): "
                f"min={ids.min()}, max={ids.max()}"
            )
        part = np.asarray(self._part)[ids]
        if (part < 0).any():
            bad = ids[part < 0][:8]
            raise ValueError(f"inactive slots queried: {bad.tolist()}...")
        return part

    @property
    def index_version(self) -> int:
        """Bumped whenever the cached curve (keys/order/frame) changes —
        i.e. whenever a ``curve_index()`` held elsewhere went stale."""
        return self._index_version

    def curve_index(self, bucket_size: int = 32) -> _ci.CurveIndex:
        """The engine's cached curve as a shared, versioned ``CurveIndex``.

        Incremental refresh: reuses the cached keys, sorted order and
        frozen quantization frame — no key generation, no sort. Only the
        bucket directory is (re)carved, so refreshing after a weight-only
        step or a delta insert costs a gather + a tiny carve instead of a
        cold ``build``. Memoized per (index_version, bucket_size); ids in
        the returned index are storage-slot ids (stable across steps).
        """
        key = (self._index_version, bucket_size)
        if self._index_cache is not None and self._index_cache[0] == key:
            return self._index_cache[1]
        if self.tree_mode:
            idx = self._tree_curve_index()
        else:
            order = self._order
            idx = _ci.from_sorted(
                self.dps.points[order],
                order.astype(jnp.int32),
                self._keys[order],
                n_valid=self.num_active(),
                frame_lo=self._frame_lo,
                frame_hi=self._frame_hi,
                bits=self.bits,
                curve=self.cfg.curve,
                bucket_size=bucket_size,
                version=self._index_version,
                token=self._cache_token,
            )
        self._index_cache = (key, idx)
        return idx

    def _tree_curve_index(self) -> _ci.CurveIndex:
        """Materialize the tree-backed index: slots in bucket-major order,
        directory = the tree's buckets, queries addressed by root→leaf
        walk. The rank argsort here is the only per-slot sort in all of
        tree mode, paid once per index version (memoized by the caller),
        never by the partitioning steps themselves."""
        border = self._border
        act = self.dps.active
        M = border.rank.shape[0]
        rank_pp = border.rank[self.dps.leaf_id]
        key_pp = border.node_keys[self.dps.leaf_id]
        # inactive slots after everything; live slots in leaves that were
        # empty at the last rebuild keep their (tail) rank — the final
        # directory bucket is widened to cover them
        rank_eff = jnp.where(act, rank_pp, M + 1)
        order = jnp.argsort(rank_eff, stable=True).astype(jnp.int32)
        keys_sorted = jnp.where(act, key_pp, jnp.uint32(KEY_SENTINEL))[order]
        nb = max(1, int(border.num_buckets))
        cnt_leaf = jax.ops.segment_sum(
            act.astype(jnp.int32), self.dps.leaf_id, num_segments=M
        )
        cnt_rank = np.asarray(cnt_leaf[border.order])
        starts = np.zeros((nb + 1,), np.int64)
        starts[1:] = np.cumsum(cnt_rank[:nb])
        starts[nb] = self.num_active()  # widen the tail bucket (see above)
        return _ci.from_buckets(
            self.dps.points[order],
            order,
            keys_sorted,
            starts,
            border.node_keys[border.order[:nb]],
            frame_lo=self._frame_lo,
            frame_hi=self._frame_hi,
            bits=self.bits,
            curve=self.cfg.curve,
            version=self._index_version,
            token=self._cache_token,
            tree=self.dps.tree,
            node_keys=border.node_keys,
        )

    # -- key generation against the frozen frame ----------------------------

    def _freeze_frame(self) -> None:
        pts = np.asarray(self.dps.points)
        act = np.asarray(self.dps.active)
        live = pts[act] if act.any() else np.zeros((1, pts.shape[1]), np.float32)
        lo, hi = live.min(axis=0), live.max(axis=0)
        span = np.where(hi > lo, hi - lo, 1.0)
        self._frame_lo = jnp.asarray(lo - self.frame_margin * span, jnp.float32)
        self._frame_hi = jnp.asarray(hi + self.frame_margin * span, jnp.float32)

    def _keys_in_frame(self, pts: jax.Array, *, cache: bool = False) -> jax.Array:
        """SFC keys against the frozen quantization frame (clipped).

        ``cache=True`` (the full-capacity rebuild path) routes through
        `kernels.ops.cached_sfc_key` under this engine's token, so the
        key batch is shared with any other consumer of the same token and
        dropped by `_invalidate_keys` on the next rebuild. Delta batches
        (inserts) compute directly — tiny, shape-varied, not worth cache
        entries.
        """
        if cache:
            from repro.kernels import ops as _kops

            keys = _kops.cached_sfc_key(
                pts,
                token=self._cache_token,
                curve=self.cfg.curve,
                bits=self.bits,
                use_pallas=self.cfg.use_pallas,
                lo=self._frame_lo,
                hi=self._frame_hi,
            )
        else:
            # the ONE keying convention: engine keys and query keys must
            # come from the same function or queries go to wrong buckets
            keys = _ci.keys_in_frame(
                pts, self._frame_lo, self._frame_hi,
                bits=self.bits, curve=self.cfg.curve,
            )
        self.stats.keygen_points += int(pts.shape[0])
        return keys

    def _invalidate_keys(self) -> None:
        old = self._cache_token
        self._cache_token = next(_TOKEN_SOURCE)
        try:  # notify the kernel-level cache (best effort: optional dep)
            from repro.kernels import ops as _kops

            _kops.invalidate_key_cache(old)
        except ImportError:  # pragma: no cover
            pass

    # -- delta operations ----------------------------------------------------

    def update_weights(self, weights: jax.Array, slot_ids: jax.Array | None = None) -> None:
        """Replace weights (full (C,)/(n_active,) vector, or a sparse batch
        at ``slot_ids``). Weight changes never invalidate cached keys."""
        if slot_ids is not None:
            new_w = self.dps.weights.at[jnp.asarray(slot_ids)].set(weights)
        else:
            weights = jnp.asarray(weights, jnp.float32)
            k = weights.shape[0]
            if k == self.capacity:
                new_w = weights
            elif k == self.num_active():  # aligned with active slots in slot order
                act_slots = jnp.nonzero(self.dps.active, size=k)[0]
                new_w = self.dps.weights.at[act_slots].set(weights)
            else:
                # any other length would silently scatter the tail into
                # slot 0 (fixed-shape nonzero pads with 0)
                raise ValueError(
                    f"weights length {k} matches neither capacity "
                    f"({self.capacity}) nor active count ({self.num_active()})"
                )
        self.dps = self.dps._replace(weights=new_w)
        if self.tree_mode:
            # keep the exposed summary truthful under weight drift: one
            # segment_sum re-aggregates live weights onto the buckets
            # (count/centroid/bbox/keys are untouched — weight drift
            # moves nothing on the curve)
            w_leaf = jax.ops.segment_sum(
                jnp.where(self.dps.active, new_w, 0.0),
                self.dps.leaf_id,
                num_segments=self._summary.num_nodes,
            )
            self._summary = dataclasses.replace(self._summary, weight=w_leaf)

    def insert(self, points: jax.Array, weights: jax.Array) -> jax.Array:
        """Insert a point batch; returns their storage slot ids. Keys are
        generated for the delta batch only (frozen frame); the cached
        curve order is re-sorted but not re-keyed."""
        k = points.shape[0]
        n_free = self.capacity - self.num_active()
        if k > n_free:
            # without this check the overflow scatters into one slot and
            # silently drops points (fixed-shape nonzero fill semantics)
            raise ValueError(
                f"insert of {k} points exceeds free capacity {n_free}; "
                f"grow the Repartitioner (capacity={self.capacity})"
            )
        free = jnp.nonzero(~self.dps.active, size=k, fill_value=self.capacity - 1)[0]
        self.dps = _dyn.insert(self.dps, points, weights)
        if self.tree_mode:
            # bucket substrate: the located leaves are the only dirtied
            # summaries — refresh them by delta scatter; no key-gen, no
            # resort (there is no per-point key array to maintain)
            self._summary_apply_delta(
                points, jnp.asarray(weights, jnp.float32),
                self.dps.leaf_id[free], sign=+1,
            )
            self._index_version += 1
        else:
            self._keys = self._keys.at[free].set(self._keys_in_frame(points))
            self._resort()
        self.topology_version += 1
        return free

    def delete(self, slot_ids: jax.Array) -> None:
        slot_ids = jnp.asarray(slot_ids)
        # first-occurrence live slots only — the exact mask dynamic.delete
        # applies, so summary deltas track tree counters; computed once
        # and handed down
        removed = self.dps.active[slot_ids] & _dyn.first_occurrence_mask(slot_ids)
        self.dps = _dyn.delete(self.dps, slot_ids, removed=removed)
        if self.tree_mode:
            w = jnp.where(removed, self.dps.weights[slot_ids], 0.0)
            self._summary_apply_delta(
                self.dps.points[slot_ids], w, self.dps.leaf_id[slot_ids],
                sign=-1, counts=removed.astype(jnp.int32),
            )
            self._index_version += 1
        else:
            self._keys = self._keys.at[slot_ids].set(jnp.uint32(KEY_SENTINEL))
            self._resort()
        self.topology_version += 1

    # -- tree-mode bucket statistics -----------------------------------------

    def _refresh_bucket_stats(self) -> None:
        """Full O(B) refresh: recollect summaries over the (possibly
        adjusted) tree and re-key the bucket centroids on the frozen
        frame. This — not an O(n) point key-gen — is what a tree-mode
        rebuild pays."""
        self._summary = _kdtree.bucket_summary(
            self.dps.tree,
            self.dps.points,
            self.dps.weights,
            leaf_id=self.dps.leaf_id,
            active=self.dps.active,
        )
        self._border = _kdtree.bucket_order(
            self._summary,
            frame_lo=self._frame_lo,
            frame_hi=self._frame_hi,
            bits=self.bits,
            curve=self.cfg.curve,
        )
        self.stats.keygen_buckets += int(self._border.num_buckets)

    def _summary_apply_delta(
        self,
        pts: jax.Array,
        wts: jax.Array,
        leaf_ids: jax.Array,
        sign: int,
        counts: jax.Array | None = None,
    ) -> None:
        """Refresh ONLY the dirtied bucket summaries (O(delta) scatters).

        Count/weight/centroid are exact; bboxes grow on insert and are
        only re-tightened at the next rebuild (a stale-loose bbox never
        mis-keys a bucket — keys are regenerated from centroids at
        rebuild time). Bucket keys and the curve order are untouched:
        membership deltas do not move buckets on the curve.
        """
        s = self._summary
        ones = (jnp.ones_like(leaf_ids) if counts is None else counts) * sign
        cnt = s.count.at[leaf_ids].add(ones)
        wsum = s.weight.at[leaf_ids].add(jnp.float32(sign) * wts)
        csum = s.centroid * s.count[:, None].astype(jnp.float32)
        csum = csum.at[leaf_ids].add(
            jnp.float32(sign) * pts * (jnp.abs(ones))[:, None].astype(jnp.float32)
        )
        centroid = csum / jnp.maximum(cnt[:, None].astype(jnp.float32), 1.0)
        lo, hi = s.bbox_lo, s.bbox_hi
        if sign > 0:
            lo = lo.at[leaf_ids].min(pts)
            hi = hi.at[leaf_ids].max(pts)
        self._summary = _kdtree.BucketSummary(
            count=cnt,
            weight=wsum,
            centroid=centroid,
            bbox_lo=lo,
            bbox_hi=hi,
            is_bucket=self.dps.tree.is_leaf & (cnt > 0),
        )
        # count entries actually applied (masked no-ops excluded), so the
        # counter reflects dirtied work, not batch size
        self.stats.summary_refreshes += int(jnp.sum(jnp.abs(ones)))

    def summary(self) -> "_kdtree.BucketSummary":
        """Tree mode: the live per-bucket statistics."""
        if not self.tree_mode:
            raise ValueError("bucket summaries exist only with cfg.use_tree=True")
        return self._summary

    def _resort(self) -> None:
        # sentinel keys (inactive slots) sort to the end; no key-gen here.
        # Every resort changes the curve order, so any CurveIndex snapshot
        # out there is now stale: bump the version (insert/delete/rebuild
        # all funnel through here; weight-only steps never do).
        self._order = jnp.argsort(self._keys, stable=True)
        self._index_version += 1

    # -- slicing -------------------------------------------------------------

    def _slice_current(self) -> tuple[jax.Array, np.ndarray, float]:
        """Knapsack-slice the cached curve; returns (part_per_slot, loads,
        imbalance). Tree mode slices the O(B) bucket weights; key mode
        slices the cached per-point order."""
        if self.tree_mode:
            part, loads_d = _bucket_slice_kernel(
                self.dps.leaf_id, self.dps.active, self.dps.weights,
                self._border.order, self.num_parts,
            )
        else:
            part, loads_d = _slice_kernel(
                self._order, self.dps.active, self.dps.weights, self.num_parts
            )
        loads = np.asarray(loads_d)
        mean = max(float(loads.mean()), 1e-12)
        return part, loads, float(loads.max()) / mean

    def _make_plan(self, counts: np.ndarray) -> _migration.MigrationPlan:
        """Exchange-plan hook: hierarchical engines override this to emit
        level-aware plans from the same count matrix."""
        return _migration.plan_from_counts(counts)

    def _emit(self, kind: str, part: jax.Array, loads, imbalance, reused: bool,
              **extra) -> RepartitionStep:
        # stable elements only (active in both assignments) migrate
        counts = _send_counts_kernel(self._part, part, self.num_parts)
        plan = self._make_plan(np.asarray(counts))
        self._part = part
        self.stats.history.append((kind, float(imbalance), int(plan.total_moved)))
        return RepartitionStep(
            kind=kind, part=part, plan=plan, loads=loads,
            imbalance=imbalance, reused_keys=reused, **extra,
        )

    # -- public stepping ------------------------------------------------------

    def rebalance(self) -> RepartitionStep:
        """Force an incremental re-slice of the cached curve (no key-gen,
        no tree adjustment)."""
        part, loads, imb = self._slice_current()
        self.stats.incremental_steps += 1
        return self._emit("incremental", part, loads, imb, reused=True)

    def rebuild(self) -> RepartitionStep:
        """Force a full rebuild: tree adjustments, fresh frame, fresh keys
        (bucket keys in tree mode — O(B), never the points)."""
        if self.stats.rebuilds or self.stats.incremental_steps:
            # skip Alg. 1 on the pristine initial build
            self.dps = _dyn.adjustments(self.dps)
        self._freeze_frame()
        self._invalidate_keys()
        if self.tree_mode:
            self._refresh_bucket_stats()
            self._index_version += 1
        else:
            act = self.dps.active
            keys = self._keys_in_frame(self.dps.points, cache=True)
            self._keys = jnp.where(act, keys, jnp.uint32(KEY_SENTINEL))
            self._resort()
        part, loads, imb = self._slice_current()
        self.stats.rebuilds += 1
        cost = self._rebuild_cost if self._rebuild_cost is not None else float(self.num_active())
        self.controller.balanced(
            lb_cost=cost, num_buckets=int(_dyn.num_buckets(self.dps)), timeop=imb
        )
        return self._emit("rebuild", part, loads, imb, reused=False)

    def resize(self, num_parts: int) -> RepartitionStep:
        """Elastic part-count change (device loss / growth): re-slice the
        CACHED curve onto ``num_parts`` parts. No tree adjustment, no
        key generation, no sort — the paper's incremental-LB machinery IS
        the elastic-scaling mechanism. The migration count matrix spans
        ``max(old, new)`` parts so shrink paths account for units leaving
        vanished parts (the `elastic.replacement_plan` sizing convention).

        Bumps ``index_version``: the re-slice is a partition-geometry
        event serving layers must observe (a ``maybe_refresh`` picks up
        the same curve re-carved, never a cold rebuild)."""
        old_part, old_parts_n = self._part, self.num_parts
        self.num_parts = int(num_parts)
        part, loads, imb = self._slice_current()
        union = max(old_parts_n, self.num_parts)
        counts = np.asarray(_send_counts_kernel(old_part, part, union))
        plan = _migration.plan_from_counts(counts)
        self._part = part
        self._index_version += 1
        self.stats.incremental_steps += 1
        self.stats.resizes += 1
        self.stats.history.append(("resize", float(imb), int(plan.total_moved)))
        return RepartitionStep(
            kind="incremental", part=part, plan=plan, loads=loads,
            imbalance=imb, reused_keys=True,
        )

    def step(self, timeop: float | None = None) -> RepartitionStep:
        """One engine step: consult the amortized controller (Alg. 3) and
        either re-slice incrementally or run a full rebuild.

        ``timeop`` is the measured per-op cost this iteration; when absent
        the live load imbalance (max/mean) of the *current* assignment
        under the *new* weights stands in for it — a hot part means slow
        ops, which is exactly the drift the credit scheme meters.
        """
        if timeop is None:
            loads = np.zeros(self.num_parts, np.float64)
            part = np.asarray(self._part)
            w = np.asarray(self.dps.weights) * np.asarray(self.dps.active)
            np.add.at(loads, np.maximum(part, 0), np.where(part >= 0, w, 0.0))
            timeop = float(loads.max() / max(loads.mean(), 1e-12))
        fire = self.controller.observe(timeop, int(_dyn.num_buckets(self.dps)))
        return self.rebuild() if fire else self.rebalance()


class HierarchicalRepartitioner(Repartitioner):
    """Two-level (node -> device) incremental engine with a two-level
    Algorithm-3 trigger.

    The flat engine answers every drift with one knapsack over the whole
    curve — any element may move to any part, so even tiny drift can
    cross the expensive node boundary. This engine nests the response:

    * **intra-node re-slice** (the default incremental step) — the
      bucket->node assignment is frozen; only each node's device slices
      are re-knapsacked. Every move is node-local by construction.
    * **inter-node re-slice** — fires only when the *node-level*
      imbalance (max/mean node load under the frozen assignment) crosses
      ``node_threshold``; both knapsack levels re-run and node slices
      shift.
    * **rebuild** — the amortized controller (paper Alg. 3) meters drift
      exactly as in the flat engine and still decides when the tree +
      frame must be rebuilt.

    ``stats.intra_reslices`` / ``stats.inter_reslices`` count how often
    each level fires; steps carry ``level`` / ``node_loads`` /
    ``node_imbalance``, and migration plans are level-aware
    (`migration.HierarchicalMigrationPlan`: per-level round capping,
    inter-node bytes cost ``plan.inter_node_cost`` times more,
    per-level stay fractions). Runs on the bucket substrate
    (``cfg.use_tree`` is forced True: the hierarchy slices O(B) bucket
    weights).
    """

    def __init__(
        self,
        points: jax.Array,
        weights: jax.Array | None = None,
        plan: _pt.HierarchyPlan = _pt.HierarchyPlan(),
        cfg: _pt.PartitionerConfig | None = None,
        *,
        node_threshold: float = 1.10,
        **kw,
    ):
        self.plan = plan
        self.node_threshold = float(node_threshold)
        self._bucket_node: jax.Array | None = None
        self._node_loads: np.ndarray | None = None
        cfg = cfg or _pt.PartitionerConfig(use_tree=True)
        if not cfg.use_tree:
            cfg = dataclasses.replace(cfg, use_tree=True)
        super().__init__(points, weights, plan.num_parts, cfg, **kw)

    # -- hierarchy accessors -------------------------------------------------

    @property
    def node_part(self) -> jax.Array:
        """(C,) int32 node id per storage slot (-1 inactive)."""
        return jnp.where(
            self._part >= 0, self._part // self.plan.devices_per_node, -1
        )

    def node_imbalance(self) -> float:
        """Node-level max/mean load of the FROZEN node assignment under
        the live weights — the inter-node trigger's input."""
        return self._node_state()[0]

    def _node_state(self) -> tuple[float, np.ndarray]:
        # O(B), not O(n): the live bucket weights (kept current by
        # update_weights' re-aggregation and the insert/delete delta
        # scatters) already hold the active point mass per bucket —
        # aggregating them through the frozen bucket->node map costs two
        # (M,) transfers, never a point-length one
        w_leaf = np.asarray(self._summary.weight)
        node_b = np.asarray(self._bucket_node)
        loads = np.zeros(self.plan.num_nodes)
        np.add.at(loads, node_b, w_leaf)
        return float(loads.max() / max(loads.mean(), 1e-12)), loads

    # -- level-aware slicing hooks -------------------------------------------

    def _slice_current(self) -> tuple[jax.Array, np.ndarray, float]:
        """Full two-level slice (rebuilds and inter-node re-slices):
        refreshes the frozen bucket->node assignment."""
        part, loads_d, node_loads_d, bucket_node = _hier_bucket_slice_kernel(
            self.dps.leaf_id, self.dps.active, self.dps.weights,
            self._border.order, self.plan.num_nodes, self.plan.devices_per_node,
        )
        self._bucket_node = bucket_node
        self._node_loads = np.asarray(node_loads_d)
        loads = np.asarray(loads_d)
        return part, loads, float(loads.max()) / max(float(loads.mean()), 1e-12)

    def _slice_intra(self) -> tuple[jax.Array, np.ndarray, float]:
        part, loads_d, node_loads_d = _hier_intra_slice_kernel(
            self.dps.leaf_id, self.dps.active, self.dps.weights,
            self._border.order, self._bucket_node,
            self.plan.num_nodes, self.plan.devices_per_node,
        )
        self._node_loads = np.asarray(node_loads_d)
        loads = np.asarray(loads_d)
        return part, loads, float(loads.max()) / max(float(loads.mean()), 1e-12)

    def _make_plan(self, counts: np.ndarray) -> _migration.MigrationPlan:
        return _migration.plan_from_counts(counts, hierarchy=self.plan)

    def _emit(self, kind, part, loads, imbalance, reused, **extra) -> RepartitionStep:
        if "node_loads" not in extra and self._node_loads is not None:
            nl = self._node_loads
            extra["node_loads"] = nl
            extra["node_imbalance"] = float(nl.max() / max(nl.mean(), 1e-12))
        return super()._emit(kind, part, loads, imbalance, reused, **extra)

    # -- public stepping -----------------------------------------------------

    def resize(self, plan: _pt.HierarchyPlan) -> RepartitionStep:  # type: ignore[override]
        """Elastic mesh-shape change: re-slice the cached bucket curve
        onto a new ``HierarchyPlan`` (node count and/or device fan-out).
        Hierarchy-aware: the full two-level knapsack re-runs (a device
        pool change is by definition an inter-node event), the frozen
        bucket->node assignment refreshes, and ``index_version`` bumps so
        serving layers swap live — tree, frame, keys and bucket summaries
        are all reused (no rebuild).

        The migration count matrix spans ``max(old, new)`` part ids; the
        level-aware round schedule only applies when the union matches
        the new hierarchy (pure growth) — a shrink emits a flat plan over
        the union, since vanished parts have no (node, device) address in
        the new plan."""
        old_part, old_parts_n = self._part, self.num_parts
        self.plan = plan
        self.num_parts = int(plan.num_parts)
        part, loads, imb = self._slice_current()   # refreshes _bucket_node
        union = max(old_parts_n, self.num_parts)
        counts = np.asarray(_send_counts_kernel(old_part, part, union))
        mplan = _migration.plan_from_counts(
            counts, hierarchy=plan if union == self.num_parts else None
        )
        self._part = part
        self._index_version += 1
        self.stats.incremental_steps += 1
        self.stats.inter_reslices += 1
        self.stats.resizes += 1
        self.stats.history.append(("resize", float(imb), int(mplan.total_moved)))
        nl = self._node_loads
        return RepartitionStep(
            kind="incremental", part=part, plan=mplan, loads=loads,
            imbalance=imb, reused_keys=True, level="inter",
            node_loads=nl,
            node_imbalance=float(nl.max() / max(nl.mean(), 1e-12)),
        )

    def rebalance(self, level: str | None = None) -> RepartitionStep:
        """Incremental re-slice; ``level`` forces "intra"/"inter", default
        consults the node-level trigger."""
        if level is None:
            nimb, _ = self._node_state()
            level = "inter" if nimb > self.node_threshold else "intra"
        if level == "inter":
            part, loads, imb = self._slice_current()
            self.stats.inter_reslices += 1
        elif level == "intra":
            part, loads, imb = self._slice_intra()
            self.stats.intra_reslices += 1
        else:
            raise ValueError(f"unknown re-slice level {level!r}")
        self.stats.incremental_steps += 1
        return self._emit(
            "incremental", part, loads, imb, reused=True, level=level,
        )


# ---------------------------------------------------------------------------
# Distributed engine: cached per-shard keys over `distributed_partition`
# ---------------------------------------------------------------------------

class DistributedRepartitioner:
    """Incremental repartitioning over a device mesh.

    ``partition(points, weights)`` runs the full distributed pipeline
    (key-gen → sample-sort all_to_all → global knapsack) and caches the
    per-shard sorted keys + validity mask. ``rebalance(weights_sorted)``
    then answers weight-only load changes with a single
    `partitioner.distributed_reslice` — one P-scalar all_gather plus a
    local scan, with the cached keys never touched. Geometry changes
    require a fresh ``partition``.
    """

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        axis: str,
        num_parts: int,
        cfg: _pt.PartitionerConfig = _pt.PartitionerConfig(),
        oversample: int = 8,
    ):
        self.mesh, self.axis = mesh, axis
        self.num_parts = int(num_parts)
        self.cfg, self.oversample = cfg, oversample
        self.keys_sorted: jax.Array | None = None
        self.valid: jax.Array | None = None
        self._part_sorted: jax.Array | None = None
        self.full_partitions = 0
        self.reslices = 0
        # bumped on every full partition (fresh keys => any serving index
        # built on the previous curve is stale and must be swapped)
        self.index_version = 0

    def partition(self, points: jax.Array, weights: jax.Array):
        keys, wts, part = _pt.distributed_partition(
            self.mesh, self.axis, points, weights, self.num_parts,
            cfg=self.cfg, oversample=self.oversample,
        )
        self.keys_sorted = keys
        self.valid = wts >= 0
        self._part_sorted = part
        self.full_partitions += 1
        self.index_version += 1
        return keys, wts, part

    def rebalance(self, weights_sorted: jax.Array) -> jax.Array:
        """Weight-only rebalance; ``weights_sorted`` is laid out like the
        weights returned by ``partition`` (the cached curve order)."""
        if self.valid is None:
            raise RuntimeError("rebalance() before the first partition()")
        part = _pt.distributed_reslice(
            self.mesh, self.axis, weights_sorted, self.valid, self.num_parts
        )
        self._part_sorted = part
        self.reslices += 1
        return part

    def migration_between(self, old_part: jax.Array, new_part: jax.Array) -> _migration.MigrationPlan:
        """Bounded-message exchange plan between two sorted-layout
        assignments (invalid slots excluded)."""
        valid = np.asarray(self.valid)
        return _migration.migration_plan(
            np.asarray(old_part)[valid], np.asarray(new_part)[valid], self.num_parts
        )


class DistributedBucketRepartitioner:
    """Incremental distributed repartitioning over bucket summaries.

    The sample-sort engine above physically re-sorts the points across
    shards and caches the sorted keys. This engine never moves a point
    for the *computation*: ``partition`` builds one local kd-tree per
    shard (keyed on a global shared frame) and caches ``(leaf_id,
    node_keys)``; every ``rebalance`` then exchanges O(B) bucket
    summaries (one all_gather) and gathers part ids home — the
    partition-recompute hot loop costs neither key generation nor an
    O(n) sort nor an all_to_all. Assignments stay in the ORIGINAL
    element layout, ready for ``sharding.apply_repartition``.
    """

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        axis: str | None = None,
        num_parts: int | None = None,
        cfg: _pt.PartitionerConfig | None = None,
        *,
        plan: _pt.HierarchyPlan | None = None,
    ):
        """Flat usage: ``(mesh, axis, num_parts)`` — internally the
        trivial ``HierarchyPlan(1, num_parts, device_axis=axis)``.
        Hierarchical usage: ``(mesh, plan=HierarchyPlan(N, D))`` on a 2-D
        (node, device) mesh — the reslice hot loop then exchanges
        node-aggregated summaries across nodes (O(B * nodes) inter-node
        bytes instead of O(B * devices))."""
        if plan is None:
            if axis is None or num_parts is None:
                raise ValueError("flat engine needs (mesh, axis, num_parts)")
            plan = _pt.HierarchyPlan(
                num_nodes=1, devices_per_node=int(num_parts), device_axis=axis
            )
        self.mesh, self.plan = mesh, plan
        self.axis = plan.device_axis if axis is None else axis
        self.num_parts = plan.num_parts
        # distributed trees default shallower than local ones: B buckets
        # per shard is the exchanged payload
        self.cfg = cfg or _pt.PartitionerConfig(use_tree=True, max_depth=8)
        self.leaf_id: jax.Array | None = None
        self.node_keys: jax.Array | None = None
        self._part: jax.Array | None = None
        self.full_partitions = 0
        self.reslices = 0
        self.index_version = 0

    def partition(self, points: jax.Array, weights: jax.Array) -> jax.Array:
        """Cold path: local trees + summary exchange. Caches the per-shard
        tree state for the reslice hot loop."""
        part, leaf_id, node_keys = _pt.hierarchical_bucket_partition(
            self.mesh, self.plan, points, weights, cfg=self.cfg
        )
        self.leaf_id, self.node_keys = leaf_id, node_keys
        self._part = part
        self.full_partitions += 1
        self.index_version += 1
        return part

    def rebalance(self, weights: jax.Array) -> jax.Array:
        """Hot path: new weights (original layout), same geometry — one
        two-stage summary exchange, no key-gen, no sort, no all_to_all."""
        if self.leaf_id is None:
            raise RuntimeError("rebalance() before the first partition()")
        part = _pt.hierarchical_bucket_reslice(
            self.mesh, self.plan, self.leaf_id, weights, self.node_keys
        )
        self._part = part
        self.reslices += 1
        return part

    def migration_between(self, old_part, new_part) -> _migration.MigrationPlan:
        """Exchange plan between two original-layout assignments —
        level-aware when the engine's hierarchy is non-trivial."""
        return _migration.migration_plan(
            np.asarray(old_part), np.asarray(new_part), self.num_parts,
            hierarchy=self.plan if self.plan.num_nodes > 1 else None,
        )
