"""Incremental repartitioning engine (paper §IV, wired end-to-end).

The paper's headline economics: a *repeated* repartition of a drifting
load distribution must cost far less than the initial one. The static
pipeline (``partitioner.partition``) pays key generation + sort + slice
every call. This module keeps the expensive artifacts alive across
timesteps and only recomputes what a delta invalidates:

===========================  =========================================
change                       work done
===========================  =========================================
weights only                 re-slice the cached curve (no key-gen,
                             no sort, no tree work)
insert / delete points       key-gen for the delta batch only, re-sort
                             cached keys, re-slice; kd-tree updated via
                             ``dynamic.insert``/``delete`` bumps
credit exhaustion            full rebuild: ``dynamic.adjustments``
                             (Alg. 1), fresh quantization frame, fresh
                             keys (Alg. 3 decides *when*)
===========================  =========================================

Keys are generated against a **frozen quantization frame** (the bounding
box captured at the last rebuild, with margin). This is what makes
cached keys reusable at all — the static path re-fits the box every
call, so old keys would silently shift. Points drifting outside the
frame are clipped into the boundary cells until the next rebuild
refreshes the frame.

Every step emits a ``migration.MigrationPlan`` so the application can
move payloads with the bounded-message exchange. Storage-slot ids are
the stable element identity across steps.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import curve_index as _ci
from repro.core import dynamic as _dyn
from repro.core import knapsack as _knapsack
from repro.core import migration as _migration
from repro.core import partitioner as _pt
from repro.core import sfc as _sfc

KEY_SENTINEL = _ci.KEY_SENTINEL  # inactive-slot key: sorts to the tail

# Process-global token source for the kernels.ops key cache. Tokens must
# be unique across engine *instances*, not just monotonic within one: the
# cache is keyed (token, curve, bits, shape, ...), so two engines with
# same-shaped point stores and private counters both starting at 0 would
# silently read each other's (stale) keys.
_TOKEN_SOURCE = itertools.count(1)


@functools.partial(jax.jit, static_argnames=("num_parts",))
def _slice_kernel(order, active, weights, num_parts):
    """Fused incremental re-slice: gather weights into curve order,
    knapsack-slice, scatter part ids back to slots. One dispatch per
    step — this IS the incremental path's entire device work."""
    act_sorted = active[order]
    w_sorted = jnp.where(act_sorted, weights[order], 0.0)
    part_sorted = _knapsack.slice_weighted_curve(w_sorted, num_parts)
    part_sorted = jnp.where(act_sorted, part_sorted, -1)
    part = jnp.full(order.shape, -1, jnp.int32).at[order].set(part_sorted)
    loads = _knapsack.part_loads(w_sorted, jnp.maximum(part_sorted, 0), num_parts)
    return part, loads


@functools.partial(jax.jit, static_argnames=("num_parts",))
def _send_counts_kernel(old_part, new_part, num_parts):
    """(P, P) migration count matrix, reduced on device (elements active
    in both assignments only)."""
    both = (old_part >= 0) & (new_part >= 0)
    idx = jnp.where(both, old_part * num_parts + new_part, num_parts * num_parts)
    counts = jax.ops.segment_sum(
        jnp.ones_like(idx), idx, num_segments=num_parts * num_parts + 1
    )
    return counts[:-1].reshape(num_parts, num_parts)


@dataclass(frozen=True)
class RepartitionStep:
    """One engine step: the new assignment plus how we got it."""

    kind: Literal["incremental", "rebuild"]
    part: jax.Array            # (C,) int32 part per storage slot, -1 inactive
    plan: _migration.MigrationPlan
    loads: np.ndarray          # (P,) weight per part
    imbalance: float           # max load / mean load
    reused_keys: bool          # True iff no key generation ran this step


@dataclass
class RepartitionStats:
    rebuilds: int = 0
    incremental_steps: int = 0
    # storage slots run through key generation; rebuilds are
    # capacity-shaped (fixed-shape kernels), inserts count the delta batch
    keygen_points: int = 0
    history: list = field(default_factory=list)


class Repartitioner:
    """Stateful incremental repartitioner over a dynamic point set.

    >>> rp = Repartitioner(points, weights, num_parts=16)
    >>> rp.update_weights(new_weights)      # drift the load
    >>> step = rp.step()                    # incremental or full rebuild
    >>> step.plan.total_moved, step.kind

    The amortized controller (paper Alg. 3) decides incremental-vs-rebuild
    inside ``step``; ``rebalance()`` / ``rebuild()`` force one or the
    other. ``insert``/``delete`` apply geometry deltas through the cached
    linearized kd-tree (``dynamic.locate``), so point location for the
    delta batch is a root→leaf walk, not a build.
    """

    def __init__(
        self,
        points: jax.Array,
        weights: jax.Array | None = None,
        num_parts: int = 8,
        cfg: _pt.PartitionerConfig = _pt.PartitionerConfig(),
        *,
        capacity: int | None = None,
        max_depth: int = 12,
        bucket_size: int = 32,
        controller: _dyn.AmortizedController | None = None,
        rebuild_cost: float | None = None,
        frame_margin: float = 0.25,
    ):
        n, d = points.shape
        if weights is None:
            weights = jnp.ones((n,), dtype=jnp.float32)
        self.num_parts = int(num_parts)
        self.cfg = cfg
        self.bits = cfg.bits if cfg.bits is not None else _sfc.max_bits_per_dim(d)
        self.frame_margin = float(frame_margin)
        self.controller = controller or _dyn.AmortizedController()
        # modeled cost of one full rebuild in controller units; default is
        # calibrated in rebuild() from the live imbalance baseline
        self._rebuild_cost = rebuild_cost
        self.stats = RepartitionStats()
        self._cache_token = next(_TOKEN_SOURCE)
        # versioned query-index state: bumped on every geometry / frame /
        # order change (insert, delete, rebuild) so serving layers holding
        # a CurveIndex can detect staleness and refresh incrementally
        self._index_version = 0
        self._index_cache: tuple[tuple[int, int], _ci.CurveIndex] | None = None

        self.dps = _dyn.from_points(
            points,
            weights,
            capacity=capacity,
            max_depth=max_depth,
            bucket_size=bucket_size,
            splitter=cfg.splitter,
        )
        self._part = jnp.full((self.capacity,), -1, dtype=jnp.int32)
        self.rebuild()

    # -- basic accessors ----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.dps.capacity

    @property
    def part(self) -> jax.Array:
        """(C,) int32 part id per storage slot (-1 for inactive slots)."""
        return self._part

    @property
    def cache_token(self) -> int:
        """Bumped whenever cached keys are invalidated (geometry/frame
        change); `repro.kernels.ops.cached_sfc_key` uses it as the cache
        key for the Pallas key-gen path."""
        return self._cache_token

    def num_active(self) -> int:
        return int(self.dps.active.sum())

    @property
    def index_version(self) -> int:
        """Bumped whenever the cached curve (keys/order/frame) changes —
        i.e. whenever a ``curve_index()`` held elsewhere went stale."""
        return self._index_version

    def curve_index(self, bucket_size: int = 32) -> _ci.CurveIndex:
        """The engine's cached curve as a shared, versioned ``CurveIndex``.

        Incremental refresh: reuses the cached keys, sorted order and
        frozen quantization frame — no key generation, no sort. Only the
        bucket directory is (re)carved, so refreshing after a weight-only
        step or a delta insert costs a gather + a tiny carve instead of a
        cold ``build``. Memoized per (index_version, bucket_size); ids in
        the returned index are storage-slot ids (stable across steps).
        """
        key = (self._index_version, bucket_size)
        if self._index_cache is not None and self._index_cache[0] == key:
            return self._index_cache[1]
        order = self._order
        idx = _ci.from_sorted(
            self.dps.points[order],
            order.astype(jnp.int32),
            self._keys[order],
            n_valid=self.num_active(),
            frame_lo=self._frame_lo,
            frame_hi=self._frame_hi,
            bits=self.bits,
            curve=self.cfg.curve,
            bucket_size=bucket_size,
            version=self._index_version,
            token=self._cache_token,
        )
        self._index_cache = (key, idx)
        return idx

    # -- key generation against the frozen frame ----------------------------

    def _freeze_frame(self) -> None:
        pts = np.asarray(self.dps.points)
        act = np.asarray(self.dps.active)
        live = pts[act] if act.any() else np.zeros((1, pts.shape[1]), np.float32)
        lo, hi = live.min(axis=0), live.max(axis=0)
        span = np.where(hi > lo, hi - lo, 1.0)
        self._frame_lo = jnp.asarray(lo - self.frame_margin * span, jnp.float32)
        self._frame_hi = jnp.asarray(hi + self.frame_margin * span, jnp.float32)

    def _keys_in_frame(self, pts: jax.Array, *, cache: bool = False) -> jax.Array:
        """SFC keys against the frozen quantization frame (clipped).

        ``cache=True`` (the full-capacity rebuild path) routes through
        `kernels.ops.cached_sfc_key` under this engine's token, so the
        key batch is shared with any other consumer of the same token and
        dropped by `_invalidate_keys` on the next rebuild. Delta batches
        (inserts) compute directly — tiny, shape-varied, not worth cache
        entries.
        """
        if cache:
            from repro.kernels import ops as _kops

            keys = _kops.cached_sfc_key(
                pts,
                token=self._cache_token,
                curve=self.cfg.curve,
                bits=self.bits,
                use_pallas=self.cfg.use_pallas,
                lo=self._frame_lo,
                hi=self._frame_hi,
            )
        else:
            # the ONE keying convention: engine keys and query keys must
            # come from the same function or queries go to wrong buckets
            keys = _ci.keys_in_frame(
                pts, self._frame_lo, self._frame_hi,
                bits=self.bits, curve=self.cfg.curve,
            )
        self.stats.keygen_points += int(pts.shape[0])
        return keys

    def _invalidate_keys(self) -> None:
        old = self._cache_token
        self._cache_token = next(_TOKEN_SOURCE)
        try:  # notify the kernel-level cache (best effort: optional dep)
            from repro.kernels import ops as _kops

            _kops.invalidate_key_cache(old)
        except ImportError:  # pragma: no cover
            pass

    # -- delta operations ----------------------------------------------------

    def update_weights(self, weights: jax.Array, slot_ids: jax.Array | None = None) -> None:
        """Replace weights (full (C,)/(n_active,) vector, or a sparse batch
        at ``slot_ids``). Weight changes never invalidate cached keys."""
        if slot_ids is not None:
            new_w = self.dps.weights.at[jnp.asarray(slot_ids)].set(weights)
        else:
            weights = jnp.asarray(weights, jnp.float32)
            k = weights.shape[0]
            if k == self.capacity:
                new_w = weights
            elif k == self.num_active():  # aligned with active slots in slot order
                act_slots = jnp.nonzero(self.dps.active, size=k)[0]
                new_w = self.dps.weights.at[act_slots].set(weights)
            else:
                # any other length would silently scatter the tail into
                # slot 0 (fixed-shape nonzero pads with 0)
                raise ValueError(
                    f"weights length {k} matches neither capacity "
                    f"({self.capacity}) nor active count ({self.num_active()})"
                )
        self.dps = self.dps._replace(weights=new_w)

    def insert(self, points: jax.Array, weights: jax.Array) -> jax.Array:
        """Insert a point batch; returns their storage slot ids. Keys are
        generated for the delta batch only (frozen frame); the cached
        curve order is re-sorted but not re-keyed."""
        k = points.shape[0]
        n_free = self.capacity - self.num_active()
        if k > n_free:
            # without this check the overflow scatters into one slot and
            # silently drops points (fixed-shape nonzero fill semantics)
            raise ValueError(
                f"insert of {k} points exceeds free capacity {n_free}; "
                f"grow the Repartitioner (capacity={self.capacity})"
            )
        free = jnp.nonzero(~self.dps.active, size=k, fill_value=self.capacity - 1)[0]
        self.dps = _dyn.insert(self.dps, points, weights)
        self._keys = self._keys.at[free].set(self._keys_in_frame(points))
        self._resort()
        return free

    def delete(self, slot_ids: jax.Array) -> None:
        slot_ids = jnp.asarray(slot_ids)
        self.dps = _dyn.delete(self.dps, slot_ids)
        self._keys = self._keys.at[slot_ids].set(jnp.uint32(KEY_SENTINEL))
        self._resort()

    def _resort(self) -> None:
        # sentinel keys (inactive slots) sort to the end; no key-gen here.
        # Every resort changes the curve order, so any CurveIndex snapshot
        # out there is now stale: bump the version (insert/delete/rebuild
        # all funnel through here; weight-only steps never do).
        self._order = jnp.argsort(self._keys, stable=True)
        self._index_version += 1

    # -- slicing -------------------------------------------------------------

    def _slice_current(self) -> tuple[jax.Array, np.ndarray, float]:
        """Knapsack-slice the cached curve; returns (part_per_slot, loads,
        imbalance)."""
        part, loads_d = _slice_kernel(
            self._order, self.dps.active, self.dps.weights, self.num_parts
        )
        loads = np.asarray(loads_d)
        mean = max(float(loads.mean()), 1e-12)
        return part, loads, float(loads.max()) / mean

    def _emit(self, kind: str, part: jax.Array, loads, imbalance, reused: bool) -> RepartitionStep:
        # stable elements only (active in both assignments) migrate
        counts = _send_counts_kernel(self._part, part, self.num_parts)
        plan = _migration.plan_from_counts(np.asarray(counts))
        self._part = part
        self.stats.history.append((kind, float(imbalance), int(plan.total_moved)))
        return RepartitionStep(
            kind=kind, part=part, plan=plan, loads=loads,
            imbalance=imbalance, reused_keys=reused,
        )

    # -- public stepping ------------------------------------------------------

    def rebalance(self) -> RepartitionStep:
        """Force an incremental re-slice of the cached curve (no key-gen,
        no tree adjustment)."""
        part, loads, imb = self._slice_current()
        self.stats.incremental_steps += 1
        return self._emit("incremental", part, loads, imb, reused=True)

    def rebuild(self) -> RepartitionStep:
        """Force a full rebuild: tree adjustments, fresh frame, fresh keys."""
        if self.stats.rebuilds or self.stats.incremental_steps:
            # skip Alg. 1 on the pristine initial build
            self.dps = _dyn.adjustments(self.dps)
        self._freeze_frame()
        self._invalidate_keys()
        act = self.dps.active
        keys = self._keys_in_frame(self.dps.points, cache=True)
        self._keys = jnp.where(act, keys, jnp.uint32(KEY_SENTINEL))
        self._resort()
        part, loads, imb = self._slice_current()
        self.stats.rebuilds += 1
        cost = self._rebuild_cost if self._rebuild_cost is not None else float(self.num_active())
        self.controller.balanced(
            lb_cost=cost, num_buckets=int(_dyn.num_buckets(self.dps)), timeop=imb
        )
        return self._emit("rebuild", part, loads, imb, reused=False)

    def step(self, timeop: float | None = None) -> RepartitionStep:
        """One engine step: consult the amortized controller (Alg. 3) and
        either re-slice incrementally or run a full rebuild.

        ``timeop`` is the measured per-op cost this iteration; when absent
        the live load imbalance (max/mean) of the *current* assignment
        under the *new* weights stands in for it — a hot part means slow
        ops, which is exactly the drift the credit scheme meters.
        """
        if timeop is None:
            loads = np.zeros(self.num_parts, np.float64)
            part = np.asarray(self._part)
            w = np.asarray(self.dps.weights) * np.asarray(self.dps.active)
            np.add.at(loads, np.maximum(part, 0), np.where(part >= 0, w, 0.0))
            timeop = float(loads.max() / max(loads.mean(), 1e-12))
        fire = self.controller.observe(timeop, int(_dyn.num_buckets(self.dps)))
        return self.rebuild() if fire else self.rebalance()


# ---------------------------------------------------------------------------
# Distributed engine: cached per-shard keys over `distributed_partition`
# ---------------------------------------------------------------------------

class DistributedRepartitioner:
    """Incremental repartitioning over a device mesh.

    ``partition(points, weights)`` runs the full distributed pipeline
    (key-gen → sample-sort all_to_all → global knapsack) and caches the
    per-shard sorted keys + validity mask. ``rebalance(weights_sorted)``
    then answers weight-only load changes with a single
    `partitioner.distributed_reslice` — one P-scalar all_gather plus a
    local scan, with the cached keys never touched. Geometry changes
    require a fresh ``partition``.
    """

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        axis: str,
        num_parts: int,
        cfg: _pt.PartitionerConfig = _pt.PartitionerConfig(),
        oversample: int = 8,
    ):
        self.mesh, self.axis = mesh, axis
        self.num_parts = int(num_parts)
        self.cfg, self.oversample = cfg, oversample
        self.keys_sorted: jax.Array | None = None
        self.valid: jax.Array | None = None
        self._part_sorted: jax.Array | None = None
        self.full_partitions = 0
        self.reslices = 0
        # bumped on every full partition (fresh keys => any serving index
        # built on the previous curve is stale and must be swapped)
        self.index_version = 0

    def partition(self, points: jax.Array, weights: jax.Array):
        keys, wts, part = _pt.distributed_partition(
            self.mesh, self.axis, points, weights, self.num_parts,
            cfg=self.cfg, oversample=self.oversample,
        )
        self.keys_sorted = keys
        self.valid = wts >= 0
        self._part_sorted = part
        self.full_partitions += 1
        self.index_version += 1
        return keys, wts, part

    def rebalance(self, weights_sorted: jax.Array) -> jax.Array:
        """Weight-only rebalance; ``weights_sorted`` is laid out like the
        weights returned by ``partition`` (the cached curve order)."""
        if self.valid is None:
            raise RuntimeError("rebalance() before the first partition()")
        part = _pt.distributed_reslice(
            self.mesh, self.axis, weights_sorted, self.valid, self.num_parts
        )
        self._part_sorted = part
        self.reslices += 1
        return part

    def migration_between(self, old_part: jax.Array, new_part: jax.Array) -> _migration.MigrationPlan:
        """Bounded-message exchange plan between two sorted-layout
        assignments (invalid slots excluded)."""
        valid = np.asarray(self.valid)
        return _migration.migration_plan(
            np.asarray(old_part)[valid], np.asarray(new_part)[valid], self.num_parts
        )
