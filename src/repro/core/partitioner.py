"""The geometric partitioner — the paper's primary contribution, as a
composable JAX module.

Pipeline (paper §III): hierarchical decomposition → SFC ordering →
greedy-knapsack load balancing. The single-device path is pure jnp; the
distributed path runs under ``shard_map`` with a sample-sort (local sort →
sampled splitters → all_to_all exchange → local merge) and a global
weighted prefix for the knapsack slice — computation cost comparable to a
parallel sort, as the paper claims.

The partitioner requires unique global ids and returns a *permutation* of
those ids plus a part assignment; re-ordering the payload is left to the
application (paper §I), with `repro.core.migration` providing the
bounded-message exchange plan.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp

from repro import compat as _compat
from jax.sharding import PartitionSpec as P

from repro.core import kdtree as _kdtree
from repro.core import knapsack as _knapsack
from repro.core import sfc as _sfc


@dataclass(frozen=True)
class HierarchyPlan:
    """First-class description of the two-level (node -> device) mesh.

    The paper's partitioner is *hybrid*: distributed across nodes,
    multi-threaded within a node. On a JAX mesh that is a 2-D
    ``(node_axis, device_axis)`` decomposition: a coarse knapsack assigns
    curve slices to nodes, then each node independently re-knapsacks its
    slice across ``devices_per_node`` local parts. ``num_nodes == 1`` is
    the flat path — every flat entry point delegates to the hierarchy
    with this trivial top level.

    ``inter_node_cost`` is the migration-cost multiplier for bytes that
    cross the node boundary (DCN vs ICI); ``summary_bins`` bounds the
    records each node contributes to the inter-node summary exchange
    (default: the per-shard bucket count, so the exchange is
    O(B * nodes), not O(B * devices)).

    Coupling to a mesh: ``num_nodes`` MUST equal the node axis size
    (the per-node aggregation happens on that axis — validated), while
    ``devices_per_node`` is the per-node *part* fan-out and is
    deliberately decoupled from the device axis size, exactly as the
    flat path's ``num_parts`` has always been decoupled from its shard
    count (parts are logical curve slices; only `apply_repartition`
    requires part ids to name real shards).
    """

    num_nodes: int = 1
    devices_per_node: int = 1
    node_axis: str = "node"
    device_axis: str = "device"
    inter_node_cost: float = 4.0
    summary_bins: int | None = None

    def __post_init__(self):
        if self.num_nodes < 1 or self.devices_per_node < 1:
            raise ValueError(f"degenerate hierarchy: {self}")

    @property
    def num_parts(self) -> int:
        return self.num_nodes * self.devices_per_node

    def node_of_part(self, part):
        """Node owning a (scalar or array) global part id."""
        return part // self.devices_per_node


class HierarchicalResult(NamedTuple):
    """Two-level partition: everything `PartitionResult` carries, plus the
    node level. ``part = node * devices_per_node + device`` everywhere."""

    part: jax.Array            # (n,) global part per ORIGINAL element
    node: jax.Array            # (n,) node id per ORIGINAL element
    keys: jax.Array            # (n,) SFC key (bucket-granular on the tree path)
    boundaries: jax.Array      # (P+1,) point-level slice starts per part
    node_boundaries: jax.Array  # (N+1,) point-level slice starts per node
    loads: jax.Array           # (P,) weight per part
    node_loads: jax.Array      # (N,) weight per node
    plan: HierarchyPlan
    # tree-path extras (None on the point path), as in PartitionResult:
    perm: jax.Array | None = None
    tree: "_kdtree.LinearKdTree | None" = None
    summary: "_kdtree.BucketSummary | None" = None
    bucket_order: "_kdtree.BucketOrder | None" = None
    bucket_rank: jax.Array | None = None
    bucket_part: jax.Array | None = None   # (M,) part per tree node
    bucket_node: jax.Array | None = None   # (M,) node per tree node


class PartitionResult(NamedTuple):
    perm: jax.Array | None  # (n,) int32 ids in SFC order; None on the tree
    #                         path (no per-point sort ran — see
    #                         ``materialize_perm``)
    part: jax.Array        # (n,) int32: part id per ORIGINAL element index
    keys: jax.Array        # (n,) uint32 (or (n,w)) SFC key per original element
    #                        (bucket-granular on the tree path)
    boundaries: jax.Array  # (P+1,) slice starts into the SFC order
    loads: jax.Array       # (P,) weight per part
    # tree-path extras (None on the point path):
    tree: "_kdtree.LinearKdTree | None" = None
    summary: "_kdtree.BucketSummary | None" = None
    bucket_order: "_kdtree.BucketOrder | None" = None
    bucket_rank: jax.Array | None = None   # (n,) int32 curve rank of each
    #                                        point's bucket
    bucket_part: jax.Array | None = None   # (M,) int32 part per tree node


def materialize_perm(res: PartitionResult) -> jax.Array:
    """Physical curve-order permutation of a ``PartitionResult``.

    The point path carries it already; the tree path deliberately never
    sorts points, so consumers that must reorder a payload (index
    materialization, migration staging) pay the one stable argsort of
    int32 bucket ranks here — outside the partition hot loop."""
    if res.perm is not None:
        return res.perm
    if res.bucket_rank is None:
        raise ValueError("result carries neither a permutation nor bucket ranks")
    return _kdtree.tree_perm(res.bucket_rank).astype(jnp.int32)


@dataclass(frozen=True)
class PartitionerConfig:
    curve: Literal["morton", "hilbert"] = "hilbert"
    stats: Literal["geometric", "rank"] = "geometric"
    bits: int | None = None
    words: int = 1
    splitter: _kdtree.Splitter = "midpoint"
    bucket_size: int = 32
    max_depth: int = 16
    use_tree: bool = False        # order via kd-tree buckets (paper's full path)
    use_pallas: bool = False      # use the Pallas key-gen kernels


def _keys_for(points: jax.Array, cfg: PartitionerConfig) -> jax.Array:
    if cfg.use_pallas:
        from repro.kernels import ops as _kops

        if cfg.curve == "morton":
            return _kops.morton_key(points, cfg.bits, stats=cfg.stats)
        return _kops.hilbert_key(points, cfg.bits, stats=cfg.stats)
    fn = _sfc.morton_key if cfg.curve == "morton" else _sfc.hilbert_key
    return fn(points, cfg.bits, stats=cfg.stats, words=cfg.words)


def _point_order(points: jax.Array, cfg: PartitionerConfig) -> tuple[jax.Array, jax.Array]:
    """Point-path curve order: (perm, keys). The ONE key-gen + sort
    prelude shared by the flat and hierarchical partitions (so the
    (1, D)-is-bit-identical invariant cannot drift)."""
    if cfg.use_pallas and cfg.words == 1:
        # Pallas key-gen kernels (single-word keys); same curve order as
        # the jnp path — asserted by test_pallas_path_matches_jnp
        keys = _keys_for(points, cfg)
        return _sfc.argsort_keys(keys), keys
    return _sfc.sfc_order(
        points, curve=cfg.curve, bits=cfg.bits, stats=cfg.stats, words=cfg.words
    )


def _bucket_stage(
    tree: "_kdtree.LinearKdTree",
    points: jax.Array,
    weights: jax.Array,
    cfg: PartitionerConfig,
    summary: "_kdtree.BucketSummary | None" = None,
    frame: tuple[jax.Array, jax.Array] | None = None,
):
    """Tree-path prelude shared by the flat and hierarchical partitions:
    bucket summaries keyed + SFC-sorted on one frame. Returns
    (summary, border, w_rank, bits) with ``w_rank`` the bucket weights
    in curve order — the knapsack input of every tree-backed slice."""
    bits = cfg.bits if cfg.bits is not None else _sfc.max_bits_per_dim(points.shape[1])
    if summary is None:
        summary = _kdtree.bucket_summary(tree, points, weights)
    if frame is None:
        frame = (tree.bbox_lo[0], tree.bbox_hi[0])
    border = _kdtree.bucket_order(
        summary, frame_lo=frame[0], frame_hi=frame[1], bits=bits, curve=cfg.curve
    )
    return summary, border, summary.weight[border.order], bits


def partition(
    points: jax.Array,
    weights: jax.Array | None = None,
    num_parts: int = 8,
    cfg: PartitionerConfig = PartitionerConfig(),
) -> PartitionResult:
    """Single-process partition of (n, d) points into ``num_parts``.

    ``cfg.use_tree=True`` runs the paper's full pipeline (tree build →
    bucket statistics → bucket SFC order → knapsack over bucket
    weights): the partition is computed entirely from O(B) bucket
    summaries, each point inheriting its bucket's part through a
    ``leaf_id`` gather — **no O(n)-length sort runs** (``res.perm`` is
    None; see ``materialize_perm``). Otherwise the closed-form SFC keys
    order the points directly (per-element balance granularity, at the
    cost of an O(n) key sort every call).
    """
    n, d = points.shape
    if weights is None:
        weights = jnp.ones((n,), dtype=jnp.float32)

    if cfg.use_tree:
        tree = _kdtree.build(
            points,
            weights,
            max_depth=cfg.max_depth,
            bucket_size=cfg.bucket_size,
            splitter=cfg.splitter,
        )
        return partition_buckets(tree, points, weights, num_parts, cfg)

    perm, keys = _point_order(points, cfg)
    w_sorted = weights[perm]
    part_sorted = _knapsack.slice_weighted_curve(w_sorted, num_parts)
    boundaries = _knapsack.part_boundaries(w_sorted, num_parts)
    loads = _knapsack.part_loads(w_sorted, part_sorted, num_parts)
    # scatter part ids back to original element order
    part = jnp.zeros((n,), dtype=jnp.int32).at[perm].set(part_sorted)
    return PartitionResult(perm=perm, part=part, keys=keys, boundaries=boundaries, loads=loads)


def partition_buckets(
    tree: "_kdtree.LinearKdTree",
    points: jax.Array,
    weights: jax.Array | None = None,
    num_parts: int = 8,
    cfg: PartitionerConfig = PartitionerConfig(),
    *,
    summary: "_kdtree.BucketSummary | None" = None,
    frame: tuple[jax.Array, jax.Array] | None = None,
) -> PartitionResult:
    """Knapsack partition over an existing tree's bucket statistics.

    The shared core of every tree-backed layer: the local path builds a
    tree and calls this; the incremental engine calls it on its cached
    tree after a delta; the distributed path runs the same math on
    all_gathered summaries. All device work is O(B) plus gathers.
    """
    n = points.shape[0]
    if weights is None:
        weights = jnp.ones((n,), dtype=jnp.float32)
    summary, border, w_rank, _bits = _bucket_stage(
        tree, points, weights, cfg, summary=summary, frame=frame
    )
    M = summary.num_nodes
    # knapsack over bucket weights in curve order (non-buckets carry 0
    # weight and sentinel keys, so they sit inert at the tail)
    part_rank = _knapsack.slice_weighted_curve(w_rank, num_parts)
    loads = _knapsack.part_loads(w_rank, part_rank, num_parts)
    bucket_part = jnp.zeros((M,), jnp.int32).at[border.order].set(part_rank)
    # points inherit their bucket's rank/part/key — gathers only
    part = bucket_part[tree.leaf_id]
    rank_pp = border.rank[tree.leaf_id]
    keys_pp = border.node_keys[tree.leaf_id]
    # point-level slice starts: first curve index of the first bucket of
    # each part (part_rank is non-decreasing along the rank axis)
    first_rank = jnp.searchsorted(
        part_rank, jnp.arange(num_parts, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    boundaries = jnp.concatenate(
        [border.starts[first_rank], jnp.array([n], dtype=jnp.int32)]
    )
    return PartitionResult(
        perm=None,
        part=part,
        keys=keys_pp,
        boundaries=boundaries,
        loads=loads,
        tree=tree,
        summary=summary,
        bucket_order=border,
        bucket_rank=rank_pp,
        bucket_part=bucket_part,
    )


def hierarchical_partition(
    points: jax.Array,
    weights: jax.Array | None = None,
    plan: HierarchyPlan = HierarchyPlan(),
    cfg: PartitionerConfig = PartitionerConfig(use_tree=True),
) -> HierarchicalResult:
    """Single-process two-level partition of (n, d) points.

    Two nested applications of the flat core over ONE frozen frame and
    ONE curve order: the coarse knapsack assigns curve slices to
    ``plan.num_nodes`` nodes, then each node's slice is independently
    re-knapsacked into ``plan.devices_per_node`` parts
    (`knapsack.two_level_slice`). On the tree path both levels slice the
    same O(B) bucket weights; on the point path, the same sorted element
    weights. With ``num_nodes == 1`` the assignment is bit-identical to
    ``partition(..., num_parts=devices_per_node)`` — the flat partition
    is the trivial hierarchy.
    """
    n, d = points.shape
    if weights is None:
        weights = jnp.ones((n,), dtype=jnp.float32)
    N, D = plan.num_nodes, plan.devices_per_node

    if not cfg.use_tree:
        perm, keys = _point_order(points, cfg)
        w_sorted = weights[perm]
        node_s, _, part_s = _knapsack.two_level_slice(w_sorted, N, D)
        part = jnp.zeros((n,), jnp.int32).at[perm].set(part_s)
        node = jnp.zeros((n,), jnp.int32).at[perm].set(node_s)
        loads = _knapsack.part_loads(w_sorted, part_s, N * D)
        node_loads = _knapsack.part_loads(w_sorted, node_s, N)
        bounds = _level_boundaries(part_s, N * D, n)
        nbounds = _level_boundaries(node_s, N, n)
        return HierarchicalResult(
            part=part, node=node, keys=keys, boundaries=bounds,
            node_boundaries=nbounds, loads=loads, node_loads=node_loads,
            plan=plan, perm=perm,
        )

    tree = _kdtree.build(
        points, weights,
        max_depth=cfg.max_depth, bucket_size=cfg.bucket_size, splitter=cfg.splitter,
    )
    summary, border, w_rank, _bits = _bucket_stage(tree, points, weights, cfg)
    return _assemble_tree_hierarchy(
        tree, summary, border, w_rank,
        *_knapsack.two_level_slice(w_rank, N, D), plan, n,
    )


def hierarchical_reslice(
    res: HierarchicalResult,
    weights: jax.Array,
    *,
    level: Literal["full", "intra"] = "full",
) -> HierarchicalResult:
    """Re-slice an existing two-level partition under new weights, reusing
    the cached curve order (no key generation, no tree work, no sort).

    ``level="full"`` re-runs both knapsack levels; ``level="intra"``
    freezes the node assignment and re-knapsacks only the device slices
    inside each node — the cheap response to small drift, whose
    migrations are node-local by construction. Tree-path results
    re-aggregate live point weights onto the buckets (one segment_sum);
    point-path results re-slice the cached sorted order directly.
    """
    plan = res.plan
    N, D = plan.num_nodes, plan.devices_per_node
    n = res.part.shape[0]
    if res.tree is None:
        w_sorted = weights[res.perm]
        if level == "intra":
            node_s = res.node[res.perm]
            dev_s = _knapsack.device_slice_within_nodes(w_sorted, node_s, N, D)
            part_s = node_s * D + dev_s
        else:
            node_s, _, part_s = _knapsack.two_level_slice(w_sorted, N, D)
        part = jnp.zeros((n,), jnp.int32).at[res.perm].set(part_s)
        node = jnp.zeros((n,), jnp.int32).at[res.perm].set(node_s)
        return res._replace(
            part=part, node=node,
            loads=_knapsack.part_loads(w_sorted, part_s, N * D),
            node_loads=_knapsack.part_loads(w_sorted, node_s, N),
            boundaries=_level_boundaries(part_s, N * D, n),
            node_boundaries=_level_boundaries(node_s, N, n),
        )
    border = res.bucket_order
    M = border.order.shape[0]
    w_leaf = jax.ops.segment_sum(weights, res.tree.leaf_id, num_segments=M)
    w_rank = w_leaf[border.order]
    if level == "intra":
        node_rank = res.bucket_node[border.order]
        dev_rank = _knapsack.device_slice_within_nodes(w_rank, node_rank, N, D)
        part_rank = node_rank * D + dev_rank
    else:
        node_rank, _, part_rank = _knapsack.two_level_slice(w_rank, N, D)
    import dataclasses as _dc

    summary = _dc.replace(res.summary, weight=w_leaf)
    return _assemble_tree_hierarchy(
        res.tree, summary, border, w_rank, node_rank, None, part_rank, plan, n
    )


def _level_boundaries(level_sorted: jax.Array, num: int, n: int) -> jax.Array:
    """(num+1,) first sorted-order index of each slice (last entry = n)."""
    starts = jnp.searchsorted(
        level_sorted, jnp.arange(num, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return jnp.concatenate([starts, jnp.array([n], dtype=jnp.int32)])


def _assemble_tree_hierarchy(
    tree, summary, border, w_rank, node_rank, dev_rank, part_rank, plan, n
) -> HierarchicalResult:
    """Scatter rank-order two-level assignments back to tree nodes and
    points — the shared tail of tree-path hierarchical (re)partitions."""
    del dev_rank  # implied by part_rank
    N, D = plan.num_nodes, plan.devices_per_node
    M = border.order.shape[0]
    loads = _knapsack.part_loads(w_rank, part_rank, N * D)
    node_loads = _knapsack.part_loads(w_rank, node_rank, N)
    bucket_part = jnp.zeros((M,), jnp.int32).at[border.order].set(part_rank)
    bucket_node = jnp.zeros((M,), jnp.int32).at[border.order].set(node_rank)
    part = bucket_part[tree.leaf_id]
    node = bucket_node[tree.leaf_id]
    rank_pp = border.rank[tree.leaf_id]
    keys_pp = border.node_keys[tree.leaf_id]
    first_rank = jnp.searchsorted(
        part_rank, jnp.arange(N * D, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    boundaries = jnp.concatenate(
        [border.starts[first_rank], jnp.array([n], dtype=jnp.int32)]
    )
    first_nrank = jnp.searchsorted(
        node_rank, jnp.arange(N, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    node_boundaries = jnp.concatenate(
        [border.starts[first_nrank], jnp.array([n], dtype=jnp.int32)]
    )
    return HierarchicalResult(
        part=part, node=node, keys=keys_pp, boundaries=boundaries,
        node_boundaries=node_boundaries, loads=loads, node_loads=node_loads,
        plan=plan, perm=None, tree=tree, summary=summary, bucket_order=border,
        bucket_rank=rank_pp, bucket_part=bucket_part, bucket_node=bucket_node,
    )


def partition_with_index(
    points: jax.Array,
    weights: jax.Array | None = None,
    num_parts: int = 8,
    cfg: PartitionerConfig = PartitionerConfig(),
    *,
    bucket_size: int = 32,
) -> tuple[PartitionResult, "object"]:
    """Partition and build the query-serving ``CurveIndex`` from ONE key
    generation: the index wraps the partition's keys and permutation, and
    ``result.boundaries`` indexes the same sorted order the index holds —
    ``curve_index.bucket_parts(index, result.boundaries)`` maps each
    directory bucket to its owning part.

    Returns (PartitionResult, CurveIndex). Point path: restricted to the
    configurations whose keys are addressable by query coordinates —
    geometric stats (rank re-keys by data order; a query point has no
    rank) and single-word keys. Tree path (``cfg.use_tree=True``): the
    index is **tree-backed** — its directory is exactly the tree's leaf
    buckets on the shared quantization frame, the one (O(B)) key
    generation is reused, and queries address it by the root→leaf walk.
    The only per-point costs are the rank argsort and gathers that
    materialize the sorted store.
    """
    from repro.core import curve_index as _ci

    if cfg.use_tree:
        res = partition(points, weights, num_parts, cfg)
        index = tree_index(res, points, cfg=cfg)
        return res, index
    if cfg.stats != "geometric" or cfg.words != 1:
        raise ValueError(
            "partition_with_index requires stats='geometric', words=1 "
            "(keys must be query-addressable)"
        )
    res = partition(points, weights, num_parts, cfg)
    bits = cfg.bits if cfg.bits is not None else _sfc.max_bits_per_dim(points.shape[1])
    index = _ci.from_partition(
        points, res.perm, res.keys, curve=cfg.curve, bits=bits, bucket_size=bucket_size
    )
    return res, index


def tree_index(
    res: PartitionResult,
    points: jax.Array,
    *,
    cfg: PartitionerConfig = PartitionerConfig(use_tree=True),
    version: int = 0,
    token: int = -1,
) -> "object":
    """Materialize the tree-backed ``CurveIndex`` from a tree-path
    ``PartitionResult``: points in bucket-major order, directory = tree
    leaf buckets, no new key generation (the partition's bucket keys ARE
    the index's keys). Bucket granularity is the tree's buckets."""
    from repro.core import curve_index as _ci

    if res.tree is None:
        raise ValueError("tree_index requires a tree-path PartitionResult")
    border = res.bucket_order
    perm = materialize_perm(res)
    nb = int(border.num_buckets)
    bits = cfg.bits if cfg.bits is not None else _sfc.max_bits_per_dim(points.shape[1])
    return _ci.from_buckets(
        points[perm],
        perm,
        res.keys[perm],
        border.starts[: nb + 1],
        border.node_keys[border.order[:nb]],
        frame_lo=res.tree.bbox_lo[0],
        frame_hi=res.tree.bbox_hi[0],
        bits=bits,
        curve=cfg.curve,
        version=version,
        token=token,
        tree=res.tree,
        node_keys=border.node_keys,
    )


# ---------------------------------------------------------------------------
# Distributed partition (shard_map sample-sort + global knapsack)
# ---------------------------------------------------------------------------

def _global_curve_slice(
    w_local: jax.Array,
    valid: jax.Array,
    axis: str,
    me: jax.Array,
    nshards: int,
    num_parts: int,
) -> jax.Array:
    """Greedy-knapsack slice of the *globally ordered* weighted curve.

    Runs inside shard_map: each shard holds a contiguous chunk of the
    curve (shard rank = curve rank). One all_gather of local weight sums
    gives every shard its exclusive global prefix; the slice itself is
    then local. This is the only collective a weight-only rebalance needs
    — the incremental path (`distributed_reslice`) calls it directly on
    cached keys, skipping key-gen and the sample-sort all_to_all.
    """
    w_masked = jnp.where(valid, w_local, 0.0)
    local_sum = jnp.sum(w_masked)
    sums = jax.lax.all_gather(local_sum, axis)  # (nshards,)
    offset = jnp.sum(jnp.where(jnp.arange(nshards) < me, sums, 0.0))
    total = jnp.sum(sums)
    prefix = offset + jnp.cumsum(w_masked) - w_masked
    ideal = jnp.maximum(total / num_parts, 1e-9)
    part = jnp.floor((prefix + 0.5 * w_masked) / ideal).astype(jnp.int32)
    part = jnp.clip(part, 0, num_parts - 1)
    return jnp.where(valid, part, -1)


def distributed_reslice(
    mesh: jax.sharding.Mesh,
    axis: str,
    weights_sorted: jax.Array,
    valid: jax.Array,
    num_parts: int,
) -> jax.Array:
    """Weight-only rebalance over an existing distributed curve order.

    ``weights_sorted``/``valid`` are laid out exactly as returned by
    `distributed_partition` (shard i holds the i-th contiguous chunk of
    the global SFC order; invalid = padding slots). Because the curve
    order is unchanged, no keys are generated and no sample-sort exchange
    runs — the cost is one all_gather of P scalars plus a local scan,
    versus the full partition's key-gen + sort + all_to_all.
    """
    return _reslice_fn(mesh, axis, num_parts)(weights_sorted, valid)


@functools.lru_cache(maxsize=64)
def _reslice_fn(mesh: jax.sharding.Mesh, axis: str, num_parts: int):
    """Jitted reslice executor, memoized per (mesh, axis, P).

    shard_map'd callables must run under jit: executed eagerly, every
    traced op dispatches as its own SPMD program (measured 42 s vs 2 s
    for the full partition kernel on 8 host devices). The lru_cache keeps
    the jitted closure alive so repeat calls hit jit's own cache.
    """
    nshards = mesh.shape[axis]

    def kernel(wts, val):
        me = jax.lax.axis_index(axis)
        return _global_curve_slice(wts, val, axis, me, nshards, num_parts)

    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_vma=False,
    ))


def distributed_partition(
    mesh: jax.sharding.Mesh,
    axis: str,
    points: jax.Array,
    weights: jax.Array,
    num_parts: int,
    cfg: PartitionerConfig = PartitionerConfig(),
    oversample: int = 8,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Distributed SFC partition over mesh axis ``axis``.

    Input ``points`` (n, d) / ``weights`` (n,) are sharded on dim 0 across
    ``axis``. Returns (keys_sorted, weights_sorted, part_sorted) where the
    global concatenation over shards is in non-decreasing key order and
    ``part_sorted`` is the knapsack part id — i.e. shard i holds the i-th
    contiguous chunk of the global space-filling curve.

    Algorithm (the paper's distributed partitioner_init / point_order):
      1. local SFC keys
      2. sampled splitters (all_gather of a per-shard key sample, paper's
         "approximate median" applied across processes)
      3. all_to_all exchange into key ranges (fixed capacity + masking —
         the TPU analogue of MAX_MSG_SIZE rounds)
      4. local sort of received keys
      5. global weighted exclusive prefix (psum over lower-ranked shards)
         feeding the greedy-knapsack slice.
    """
    return _partition_fn(mesh, axis, num_parts, cfg, oversample)(points, weights)


@functools.lru_cache(maxsize=64)
def _partition_fn(
    mesh: jax.sharding.Mesh,
    axis: str,
    num_parts: int,
    cfg: PartitionerConfig,
    oversample: int,
):
    """Jitted sample-sort partition executor, memoized per static config
    (see `_reslice_fn` for why shard_map must run under jit)."""
    nshards = mesh.shape[axis]

    def kernel(pts, wts):
        # pts: (n_loc, d), wts: (n_loc,)
        n_loc = pts.shape[0]
        keys = _keys_for(pts, cfg)
        me = jax.lax.axis_index(axis)

        # --- sampled splitters -------------------------------------------
        samp_n = max(1, min(oversample * nshards, n_loc) // 1)
        stride = max(1, n_loc // samp_n)
        sample = jax.lax.sort(keys[::stride][:samp_n])
        all_samples = jax.lax.all_gather(sample, axis).reshape(-1)
        all_samples = jax.lax.sort(all_samples)
        m = all_samples.shape[0]
        # nshards-1 splitters at even quantiles
        qi = (jnp.arange(1, nshards) * m) // nshards
        splitters = all_samples[qi]

        # --- route to destination shards ---------------------------------
        dest = jnp.searchsorted(splitters, keys, side="right").astype(jnp.int32)
        # capacity per (src -> dst) lane; pad with sentinel keys
        cap = int(n_loc * 2 // nshards) + oversample * 4
        order = jnp.argsort(dest, stable=True)
        keys_s, wts_s, dest_s = keys[order], wts[order], dest[order]
        # position within destination bucket
        ones = jnp.ones_like(dest_s)
        pos_in_bucket = jnp.cumsum(ones) - 1
        bucket_start = jnp.searchsorted(dest_s, jnp.arange(nshards, dtype=jnp.int32))
        pos_in_bucket = pos_in_bucket - bucket_start[dest_s]
        SENT = jnp.uint32(0xFFFFFFFF)
        buf_k = jnp.full((nshards, cap), SENT, dtype=keys.dtype)
        buf_w = jnp.zeros((nshards, cap), dtype=wts.dtype)
        # out-of-capacity entries are dropped by mode="drop"; tests assert
        # the global valid count is conserved (capacity is ~2x fair share)
        idx = (dest_s, pos_in_bucket)
        buf_k = buf_k.at[idx].set(keys_s, mode="drop")
        buf_w = buf_w.at[idx].set(wts_s, mode="drop")

        # all_to_all: lane s of my buffer goes to shard s
        recv_k = jax.lax.all_to_all(buf_k, axis, split_axis=0, concat_axis=0, tiled=False)
        recv_w = jax.lax.all_to_all(buf_w, axis, split_axis=0, concat_axis=0, tiled=False)
        recv_k = recv_k.reshape(-1)
        recv_w = recv_w.reshape(-1)

        # --- local sort (sentinels go last) ------------------------------
        o2 = jnp.argsort(recv_k, stable=True)
        recv_k, recv_w = recv_k[o2], recv_w[o2]
        valid = recv_k != SENT

        # --- global weighted prefix + knapsack slice ----------------------
        part = _global_curve_slice(recv_w, valid, axis, me, nshards, num_parts)
        return recv_k, jnp.where(valid, recv_w, -1.0), part

    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis)),
        check_vma=False,
    ))


# ---------------------------------------------------------------------------
# Distributed bucket-summary exchange (tree path at scale)
#
# The sample-sort above moves O(n) raw points through an all_to_all every
# partition. The bucket path exchanges O(B) *summaries* instead: each
# shard builds a local kd-tree once, and every (re)partition after that
# is a summary gather, a tiny global sort of bucket records, the knapsack
# over bucket weights, and a leaf_id gather. Points never move for the
# computation ("point data follows its bucket" — the part assignment
# comes home, not the points), which is what makes the
# partition-recompute hot loop cheap (Borrell et al.'s aggregated-weights
# argument applied across shards).
#
# The exchange is HIERARCHICAL (paper's hybrid nodes-x-threads model,
# `HierarchyPlan`): the raw (M,) summaries are all_gathered intra-node
# only, and one inter-node exchange moves node-aggregated bins — the
# two-stage body lives in `distributed.sharding.two_stage_bucket_slice`.
# The flat entry points below delegate with the trivial (1, P) plan,
# which reduces bit-exactly to the single-stage gather + flat knapsack.
# ---------------------------------------------------------------------------

def _plan_axes(mesh: jax.sharding.Mesh, plan: HierarchyPlan) -> tuple[str, ...]:
    """Mesh axes a plan's kernels shard over. The node level is
    validated against the mesh (aggregation runs on that axis); the
    device level is a logical part fan-out and intentionally is not —
    see `HierarchyPlan`."""
    if plan.device_axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} lacks device axis {plan.device_axis!r}")
    if plan.num_nodes > 1 or plan.node_axis in mesh.axis_names:
        if mesh.shape.get(plan.node_axis, 1) != plan.num_nodes:
            raise ValueError(
                f"plan expects {plan.num_nodes} nodes on axis {plan.node_axis!r}; "
                f"mesh has {mesh.shape.get(plan.node_axis)}"
            )
        return (plan.node_axis, plan.device_axis)
    return (plan.device_axis,)


def hierarchical_bucket_partition(
    mesh: jax.sharding.Mesh,
    plan: HierarchyPlan,
    points: jax.Array,
    weights: jax.Array,
    cfg: PartitionerConfig = PartitionerConfig(use_tree=True),
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Cold two-level bucket-path distributed partition.

    Builds a local kd-tree per shard, keys its bucket centroids on ONE
    globally shared quantization frame (bbox all-reduced over every mesh
    axis), and runs the nested node->device knapsack over the two-stage
    summary exchange. Inputs are sharded on dim 0 over the plan's mesh
    axes (node-major); returns ``(part, leaf_id, node_keys)`` with
    ``part``/``leaf_id`` in the ORIGINAL element layout (elements do not
    move) and ``part = node * devices_per_node + device``. ``(leaf_id,
    node_keys)`` are the cached state that makes every later
    `hierarchical_bucket_reslice` O(B) in communication — O(B * nodes)
    of it inter-node.
    """
    return _hier_bucket_partition_fn(mesh, plan, cfg)(points, weights)


def hierarchical_bucket_reslice(
    mesh: jax.sharding.Mesh,
    plan: HierarchyPlan,
    leaf_id: jax.Array,
    weights: jax.Array,
    node_keys: jax.Array,
) -> jax.Array:
    """The partition-recompute hot loop: fresh two-level assignment for
    new weights over the cached per-shard trees.

    Local work is one segment_sum (points -> bucket weights) and one
    gather (bucket part -> point part); the communication is the
    two-stage summary exchange — raw summaries intra-node, aggregated
    bins inter-node. No key generation, no point sort, no all_to_all."""
    return _hier_bucket_reslice_fn(mesh, plan)(leaf_id, weights, node_keys)


def distributed_bucket_partition(
    mesh: jax.sharding.Mesh,
    axis: str,
    points: jax.Array,
    weights: jax.Array,
    num_parts: int,
    cfg: PartitionerConfig = PartitionerConfig(use_tree=True),
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Flat bucket-path distributed partition — the hierarchy with a
    trivial top level (``HierarchyPlan(1, num_parts, device_axis=axis)``);
    same contract as before: ``(part, leaf_id, node_keys)`` in the
    ORIGINAL element layout, one single-stage O(B) summary all_gather."""
    plan = HierarchyPlan(num_nodes=1, devices_per_node=num_parts, device_axis=axis)
    return hierarchical_bucket_partition(mesh, plan, points, weights, cfg)


def distributed_bucket_reslice(
    mesh: jax.sharding.Mesh,
    axis: str,
    leaf_id: jax.Array,
    weights: jax.Array,
    node_keys: jax.Array,
    num_parts: int,
) -> jax.Array:
    """Flat recompute hot loop — `hierarchical_bucket_reslice` with the
    trivial (1, P) plan: one O(B) summary all_gather, no key generation,
    no point sort, no all_to_all."""
    plan = HierarchyPlan(num_nodes=1, devices_per_node=num_parts, device_axis=axis)
    return hierarchical_bucket_reslice(mesh, plan, leaf_id, weights, node_keys)


@functools.lru_cache(maxsize=64)
def _hier_bucket_partition_fn(
    mesh: jax.sharding.Mesh, plan: HierarchyPlan, cfg: PartitionerConfig
):
    """Jitted cold bucket-partition executor (see `_reslice_fn` for why
    shard_map must run under jit)."""
    from repro.distributed import sharding as _shd

    axes = _plan_axes(mesh, plan)
    num_dev_shards = mesh.shape[plan.device_axis]

    def kernel(pts, wts):
        bits = cfg.bits if cfg.bits is not None else _sfc.max_bits_per_dim(pts.shape[1])
        # ONE shared quantization frame: the global bbox (reduced over
        # every mesh axis), so every shard's bucket keys live on the
        # same curve
        lo = jnp.min(jax.lax.all_gather(jnp.min(pts, axis=0), axes), axis=0)
        hi = jnp.max(jax.lax.all_gather(jnp.max(pts, axis=0), axes), axis=0)
        tree = _kdtree.build(
            pts,
            wts,
            max_depth=cfg.max_depth,
            bucket_size=cfg.bucket_size,
            splitter=cfg.splitter,
        )
        summary = _kdtree.bucket_summary(tree, pts, wts)
        node_keys = _kdtree.summary_keys(
            summary, frame_lo=lo, frame_hi=hi, bits=bits, curve=cfg.curve
        )
        bucket_part = _shd.two_stage_bucket_slice(
            summary.weight, node_keys, plan=plan, num_dev_shards=num_dev_shards
        )
        return bucket_part[tree.leaf_id], tree.leaf_id.astype(jnp.int32), node_keys

    spec = P(axes)
    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec, spec),
        check_vma=False,
    ))


@functools.lru_cache(maxsize=64)
def _hier_bucket_reslice_fn(mesh: jax.sharding.Mesh, plan: HierarchyPlan):
    """Jitted two-level bucket-reslice executor, memoized per (mesh, plan)."""
    from repro.distributed import sharding as _shd

    axes = _plan_axes(mesh, plan)
    num_dev_shards = mesh.shape[plan.device_axis]

    def kernel(leaf_id, wts, node_keys):
        M = node_keys.shape[0]
        w_leaf = jax.ops.segment_sum(wts, leaf_id, num_segments=M)
        bucket_part = _shd.two_stage_bucket_slice(
            w_leaf, node_keys, plan=plan, num_dev_shards=num_dev_shards
        )
        return bucket_part[leaf_id]

    spec = P(axes)
    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=spec,
        check_vma=False,
    ))
