"""The geometric partitioner — the paper's primary contribution, as a
composable JAX module.

Pipeline (paper §III): hierarchical decomposition → SFC ordering →
greedy-knapsack load balancing. The single-device path is pure jnp; the
distributed path runs under ``shard_map`` with a sample-sort (local sort →
sampled splitters → all_to_all exchange → local merge) and a global
weighted prefix for the knapsack slice — computation cost comparable to a
parallel sort, as the paper claims.

The partitioner requires unique global ids and returns a *permutation* of
those ids plus a part assignment; re-ordering the payload is left to the
application (paper §I), with `repro.core.migration` providing the
bounded-message exchange plan.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp

from repro import compat as _compat
from jax.sharding import PartitionSpec as P

from repro.core import kdtree as _kdtree
from repro.core import knapsack as _knapsack
from repro.core import sfc as _sfc


class PartitionResult(NamedTuple):
    perm: jax.Array        # (n,) int32: global ids in SFC order
    part: jax.Array        # (n,) int32: part id per ORIGINAL element index
    keys: jax.Array        # (n,) uint32 (or (n,w)) SFC key per original element
    boundaries: jax.Array  # (P+1,) slice starts into the SFC order
    loads: jax.Array       # (P,) weight per part


@dataclass(frozen=True)
class PartitionerConfig:
    curve: Literal["morton", "hilbert"] = "hilbert"
    stats: Literal["geometric", "rank"] = "geometric"
    bits: int | None = None
    words: int = 1
    splitter: _kdtree.Splitter = "midpoint"
    bucket_size: int = 32
    max_depth: int = 16
    use_tree: bool = False        # order via kd-tree buckets (paper's full path)
    use_pallas: bool = False      # use the Pallas key-gen kernels


def _keys_for(points: jax.Array, cfg: PartitionerConfig) -> jax.Array:
    if cfg.use_pallas:
        from repro.kernels import ops as _kops

        if cfg.curve == "morton":
            return _kops.morton_key(points, cfg.bits, stats=cfg.stats)
        return _kops.hilbert_key(points, cfg.bits, stats=cfg.stats)
    fn = _sfc.morton_key if cfg.curve == "morton" else _sfc.hilbert_key
    return fn(points, cfg.bits, stats=cfg.stats, words=cfg.words)


def partition(
    points: jax.Array,
    weights: jax.Array | None = None,
    num_parts: int = 8,
    cfg: PartitionerConfig = PartitionerConfig(),
) -> PartitionResult:
    """Single-process partition of (n, d) points into ``num_parts``.

    ``cfg.use_tree=True`` runs the paper's full pipeline (tree build →
    bucket ordering); otherwise the closed-form SFC keys order the points
    directly (equivalent for midpoint/regular decompositions, and the
    rank-stats mode covers the median-splitter behaviour).
    """
    n, d = points.shape
    if weights is None:
        weights = jnp.ones((n,), dtype=jnp.float32)

    if cfg.use_tree:
        tree = _kdtree.build(
            points,
            weights,
            max_depth=cfg.max_depth,
            bucket_size=cfg.bucket_size,
            splitter=cfg.splitter,
        )
        perm, keys = _kdtree.tree_order(tree, points, curve=cfg.curve, bits=cfg.bits)
    else:
        perm, keys = _sfc.sfc_order(
            points, curve=cfg.curve, bits=cfg.bits, stats=cfg.stats, words=cfg.words
        )

    w_sorted = weights[perm]
    part_sorted = _knapsack.slice_weighted_curve(w_sorted, num_parts)
    boundaries = _knapsack.part_boundaries(w_sorted, num_parts)
    loads = _knapsack.part_loads(w_sorted, part_sorted, num_parts)
    # scatter part ids back to original element order
    part = jnp.zeros((n,), dtype=jnp.int32).at[perm].set(part_sorted)
    return PartitionResult(perm=perm, part=part, keys=keys, boundaries=boundaries, loads=loads)


def partition_with_index(
    points: jax.Array,
    weights: jax.Array | None = None,
    num_parts: int = 8,
    cfg: PartitionerConfig = PartitionerConfig(),
    *,
    bucket_size: int = 32,
) -> tuple[PartitionResult, "object"]:
    """Partition and build the query-serving ``CurveIndex`` from ONE key
    generation: the index wraps the partition's keys and permutation, and
    ``result.boundaries`` indexes the same sorted order the index holds —
    ``curve_index.bucket_parts(index, result.boundaries)`` maps each
    directory bucket to its owning part.

    Returns (PartitionResult, CurveIndex). Restricted to the
    configurations whose keys are addressable by query coordinates:
    geometric stats (rank re-keys by data order — a query point has no
    rank), single-word keys, closed-form ordering.
    """
    from repro.core import curve_index as _ci

    if cfg.stats != "geometric" or cfg.words != 1 or cfg.use_tree:
        raise ValueError(
            "partition_with_index requires stats='geometric', words=1, "
            "use_tree=False (keys must be query-addressable)"
        )
    res = partition(points, weights, num_parts, cfg)
    bits = cfg.bits if cfg.bits is not None else _sfc.max_bits_per_dim(points.shape[1])
    index = _ci.from_partition(
        points, res.perm, res.keys, curve=cfg.curve, bits=bits, bucket_size=bucket_size
    )
    return res, index


# ---------------------------------------------------------------------------
# Distributed partition (shard_map sample-sort + global knapsack)
# ---------------------------------------------------------------------------

def _global_curve_slice(
    w_local: jax.Array,
    valid: jax.Array,
    axis: str,
    me: jax.Array,
    nshards: int,
    num_parts: int,
) -> jax.Array:
    """Greedy-knapsack slice of the *globally ordered* weighted curve.

    Runs inside shard_map: each shard holds a contiguous chunk of the
    curve (shard rank = curve rank). One all_gather of local weight sums
    gives every shard its exclusive global prefix; the slice itself is
    then local. This is the only collective a weight-only rebalance needs
    — the incremental path (`distributed_reslice`) calls it directly on
    cached keys, skipping key-gen and the sample-sort all_to_all.
    """
    w_masked = jnp.where(valid, w_local, 0.0)
    local_sum = jnp.sum(w_masked)
    sums = jax.lax.all_gather(local_sum, axis)  # (nshards,)
    offset = jnp.sum(jnp.where(jnp.arange(nshards) < me, sums, 0.0))
    total = jnp.sum(sums)
    prefix = offset + jnp.cumsum(w_masked) - w_masked
    ideal = jnp.maximum(total / num_parts, 1e-9)
    part = jnp.floor((prefix + 0.5 * w_masked) / ideal).astype(jnp.int32)
    part = jnp.clip(part, 0, num_parts - 1)
    return jnp.where(valid, part, -1)


def distributed_reslice(
    mesh: jax.sharding.Mesh,
    axis: str,
    weights_sorted: jax.Array,
    valid: jax.Array,
    num_parts: int,
) -> jax.Array:
    """Weight-only rebalance over an existing distributed curve order.

    ``weights_sorted``/``valid`` are laid out exactly as returned by
    `distributed_partition` (shard i holds the i-th contiguous chunk of
    the global SFC order; invalid = padding slots). Because the curve
    order is unchanged, no keys are generated and no sample-sort exchange
    runs — the cost is one all_gather of P scalars plus a local scan,
    versus the full partition's key-gen + sort + all_to_all.
    """
    return _reslice_fn(mesh, axis, num_parts)(weights_sorted, valid)


@functools.lru_cache(maxsize=64)
def _reslice_fn(mesh: jax.sharding.Mesh, axis: str, num_parts: int):
    """Jitted reslice executor, memoized per (mesh, axis, P).

    shard_map'd callables must run under jit: executed eagerly, every
    traced op dispatches as its own SPMD program (measured 42 s vs 2 s
    for the full partition kernel on 8 host devices). The lru_cache keeps
    the jitted closure alive so repeat calls hit jit's own cache.
    """
    nshards = mesh.shape[axis]

    def kernel(wts, val):
        me = jax.lax.axis_index(axis)
        return _global_curve_slice(wts, val, axis, me, nshards, num_parts)

    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_vma=False,
    ))


def distributed_partition(
    mesh: jax.sharding.Mesh,
    axis: str,
    points: jax.Array,
    weights: jax.Array,
    num_parts: int,
    cfg: PartitionerConfig = PartitionerConfig(),
    oversample: int = 8,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Distributed SFC partition over mesh axis ``axis``.

    Input ``points`` (n, d) / ``weights`` (n,) are sharded on dim 0 across
    ``axis``. Returns (keys_sorted, weights_sorted, part_sorted) where the
    global concatenation over shards is in non-decreasing key order and
    ``part_sorted`` is the knapsack part id — i.e. shard i holds the i-th
    contiguous chunk of the global space-filling curve.

    Algorithm (the paper's distributed partitioner_init / point_order):
      1. local SFC keys
      2. sampled splitters (all_gather of a per-shard key sample, paper's
         "approximate median" applied across processes)
      3. all_to_all exchange into key ranges (fixed capacity + masking —
         the TPU analogue of MAX_MSG_SIZE rounds)
      4. local sort of received keys
      5. global weighted exclusive prefix (psum over lower-ranked shards)
         feeding the greedy-knapsack slice.
    """
    return _partition_fn(mesh, axis, num_parts, cfg, oversample)(points, weights)


@functools.lru_cache(maxsize=64)
def _partition_fn(
    mesh: jax.sharding.Mesh,
    axis: str,
    num_parts: int,
    cfg: PartitionerConfig,
    oversample: int,
):
    """Jitted sample-sort partition executor, memoized per static config
    (see `_reslice_fn` for why shard_map must run under jit)."""
    nshards = mesh.shape[axis]

    def kernel(pts, wts):
        # pts: (n_loc, d), wts: (n_loc,)
        n_loc = pts.shape[0]
        keys = _keys_for(pts, cfg)
        me = jax.lax.axis_index(axis)

        # --- sampled splitters -------------------------------------------
        samp_n = max(1, min(oversample * nshards, n_loc) // 1)
        stride = max(1, n_loc // samp_n)
        sample = jax.lax.sort(keys[::stride][:samp_n])
        all_samples = jax.lax.all_gather(sample, axis).reshape(-1)
        all_samples = jax.lax.sort(all_samples)
        m = all_samples.shape[0]
        # nshards-1 splitters at even quantiles
        qi = (jnp.arange(1, nshards) * m) // nshards
        splitters = all_samples[qi]

        # --- route to destination shards ---------------------------------
        dest = jnp.searchsorted(splitters, keys, side="right").astype(jnp.int32)
        # capacity per (src -> dst) lane; pad with sentinel keys
        cap = int(n_loc * 2 // nshards) + oversample * 4
        order = jnp.argsort(dest, stable=True)
        keys_s, wts_s, dest_s = keys[order], wts[order], dest[order]
        # position within destination bucket
        ones = jnp.ones_like(dest_s)
        pos_in_bucket = jnp.cumsum(ones) - 1
        bucket_start = jnp.searchsorted(dest_s, jnp.arange(nshards, dtype=jnp.int32))
        pos_in_bucket = pos_in_bucket - bucket_start[dest_s]
        SENT = jnp.uint32(0xFFFFFFFF)
        buf_k = jnp.full((nshards, cap), SENT, dtype=keys.dtype)
        buf_w = jnp.zeros((nshards, cap), dtype=wts.dtype)
        # out-of-capacity entries are dropped by mode="drop"; tests assert
        # the global valid count is conserved (capacity is ~2x fair share)
        idx = (dest_s, pos_in_bucket)
        buf_k = buf_k.at[idx].set(keys_s, mode="drop")
        buf_w = buf_w.at[idx].set(wts_s, mode="drop")

        # all_to_all: lane s of my buffer goes to shard s
        recv_k = jax.lax.all_to_all(buf_k, axis, split_axis=0, concat_axis=0, tiled=False)
        recv_w = jax.lax.all_to_all(buf_w, axis, split_axis=0, concat_axis=0, tiled=False)
        recv_k = recv_k.reshape(-1)
        recv_w = recv_w.reshape(-1)

        # --- local sort (sentinels go last) ------------------------------
        o2 = jnp.argsort(recv_k, stable=True)
        recv_k, recv_w = recv_k[o2], recv_w[o2]
        valid = recv_k != SENT

        # --- global weighted prefix + knapsack slice ----------------------
        part = _global_curve_slice(recv_w, valid, axis, me, nshards, num_parts)
        return recv_k, jnp.where(valid, recv_w, -1.0), part

    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis)),
        check_vma=False,
    ))
