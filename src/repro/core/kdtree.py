"""Hierarchical domain decomposition — linearized kd-trees (paper §III-A).

The paper stores the tree as flat vectors (its Fig. 1 "linearized
kd-tree": a vector of indices + a vector of coordinates + node records).
That layout is exactly what XLA wants, so the TPU adaptation keeps it and
replaces the recursive, lock-free construction with a *level-synchronous*
breadth-first build: at each level every active node computes its tight
bounding box, splitting hyperplane and child memberships **in parallel**
via segment reductions. This is the dataflow expression of the paper's
"threads and processes built different sections of the tree in parallel
without any communication".

Node table is in heap order: node k has children 2k+1 / 2k+2. Recursion
terminates when a node holds <= bucket_size points (BUCKETSIZE in the
paper) or at max_depth.

Splitters (paper §III-A, all four):
  * ``midpoint``          — mean of min/max along the widest dimension.
  * ``median``            — exact median via per-segment sort.
  * ``median_sampled``    — median of a hashed subsample (approximate).
  * ``median_selection``  — median by iterative bisection *selection*
                            (rank counting, no sort — Blum et al. style).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

Splitter = Literal["midpoint", "median", "median_sampled", "median_selection"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "split_dim", "split_val", "count", "weight", "is_leaf",
        "bbox_lo", "bbox_hi", "leaf_id",
    ),
    meta_fields=("max_depth", "bucket_size"),
)
@dataclasses.dataclass(frozen=True)
class LinearKdTree:
    """Linearized kd-tree (a pytree of fixed-shape arrays).

    Node arrays have length M = 2^(max_depth+1) - 1 (heap order). Nodes
    that were never materialized have count == 0. ``max_depth`` and
    ``bucket_size`` are static pytree metadata, so jitted functions can
    use them in python control flow.
    """

    split_dim: jax.Array  # (M,) int32, -1 for leaves/empty
    split_val: jax.Array  # (M,) float32
    count: jax.Array      # (M,) int32 points in subtree
    weight: jax.Array     # (M,) float32 sum of point weights in subtree
    is_leaf: jax.Array    # (M,) bool
    bbox_lo: jax.Array    # (M, d) float32 tight bbox
    bbox_hi: jax.Array    # (M, d) float32
    leaf_id: jax.Array    # (n,) int32 heap index of the leaf holding each point
    max_depth: int        # static
    bucket_size: int      # static

    def _replace(self, **kw) -> "LinearKdTree":
        return dataclasses.replace(self, **kw)

    @property
    def num_nodes(self) -> int:
        return self.split_dim.shape[0]

    @property
    def dim(self) -> int:
        return self.bbox_lo.shape[1]

    def leaf_depth(self) -> jax.Array:
        """Depth of each point's leaf (floor(log2(leaf_id+1)))."""
        return jnp.floor(jnp.log2(self.leaf_id.astype(jnp.float32) + 1.0)).astype(jnp.int32)


def _level_slice(level: int) -> tuple[int, int]:
    """[start, end) heap indices of nodes at ``level``."""
    return (1 << level) - 1, (1 << (level + 1)) - 1


def _segment_median_sort(
    vals: jax.Array, seg: jax.Array, include: jax.Array, num_segments: int,
) -> jax.Array:
    """Exact median per segment by sorting (seg, val) pairs.

    ``include`` masks the points that participate (live points, or the
    hashed subsample for the sampled variant). Masked points are routed to
    an overflow segment that sorts after all real segments, so per-segment
    offsets are exactly the cumulative included counts.
    """
    segx = jnp.where(include, seg, num_segments)  # masked -> overflow segment
    counts = jax.ops.segment_sum(
        include.astype(jnp.int32), seg, num_segments=num_segments
    )
    # composite sort: by value then (stable) by segment
    order = jnp.argsort(vals, stable=True)
    order = order[jnp.argsort(segx[order], stable=True)]
    sorted_vals = vals[order]
    starts = jnp.cumsum(counts) - counts
    mid = starts + jnp.maximum(counts - 1, 0) // 2
    mid = jnp.clip(mid, 0, vals.shape[0] - 1)
    return sorted_vals[mid]


def _segment_median_selection(
    vals: jax.Array, seg: jax.Array, include: jax.Array, counts: jax.Array,
    lo: jax.Array, hi: jax.Array, num_segments: int, iters: int = 24,
) -> jax.Array:
    """Median per segment by bisection selection (no sort).

    Binary-search the value domain; count elements <= mid per segment via
    segment_sum. O(iters) passes over the data, each fully parallel.
    """
    target = (counts + 1) // 2  # rank of the lower median (1-based)

    def body(_, carry):
        lo_, hi_ = carry
        mid = 0.5 * (lo_ + hi_)
        below = jax.ops.segment_sum(
            (include & (vals <= mid[seg])).astype(jnp.int32),
            seg,
            num_segments=num_segments,
        )
        go_right = below < target
        lo_ = jnp.where(go_right, mid, lo_)
        hi_ = jnp.where(go_right, hi_, mid)
        return lo_, hi_

    lo_f, hi_f = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi_f


@functools.partial(
    jax.jit,
    static_argnames=("max_depth", "bucket_size", "splitter", "sample_shift", "median_top_levels"),
)
def build(
    points: jax.Array,
    weights: jax.Array | None = None,
    *,
    max_depth: int = 16,
    bucket_size: int = 32,
    splitter: Splitter = "midpoint",
    sample_shift: int = 3,
    median_top_levels: int | None = None,
) -> LinearKdTree:
    """Build a linearized kd-tree over (n, d) points.

    ``median_top_levels``: if set, use the configured (median) splitter for
    the top levels and midpoint below — the paper's hybrid policy ("median
    splitters at the top nodes and midpoint at the lower nodes").
    """
    n, d = points.shape
    if weights is None:
        weights = jnp.ones((n,), dtype=jnp.float32)
    M = (1 << (max_depth + 1)) - 1

    split_dim = jnp.full((M,), -1, dtype=jnp.int32)
    split_val = jnp.zeros((M,), dtype=jnp.float32)
    count = jnp.zeros((M,), dtype=jnp.int32)
    weight = jnp.zeros((M,), dtype=jnp.float32)
    is_leaf = jnp.zeros((M,), dtype=bool)
    bbox_lo = jnp.zeros((M, d), dtype=jnp.float32)
    bbox_hi = jnp.zeros((M, d), dtype=jnp.float32)

    node = jnp.zeros((n,), dtype=jnp.int32)  # heap id of current node per point
    settled = jnp.zeros((n,), dtype=bool)    # point already in a finished leaf

    # hashed subsample mask for the sampled-median splitter (deterministic)
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = (idx * jnp.uint32(2654435761)) >> jnp.uint32(32 - 8)
    sampled = (h & ((1 << sample_shift) - 1)) == 0  # ~ n / 2^sample_shift points

    for level in range(max_depth + 1):
        start, end = _level_slice(level)
        S = end - start  # 2^level segments at this level
        seg = jnp.clip(node - start, 0, S - 1)
        live = ~settled  # points still flowing down

        w_live = jnp.where(live, weights, 0.0)
        cnt = jax.ops.segment_sum(live.astype(jnp.int32), seg, num_segments=S)
        wsum = jax.ops.segment_sum(w_live, seg, num_segments=S)

        big = jnp.float32(3.4e38)
        pts_lo = jnp.where(live[:, None], points, big)
        pts_hi = jnp.where(live[:, None], points, -big)
        lo = jax.ops.segment_min(pts_lo, seg, num_segments=S)
        hi = jax.ops.segment_max(pts_hi, seg, num_segments=S)
        lo = jnp.where(cnt[:, None] > 0, lo, 0.0)
        hi = jnp.where(cnt[:, None] > 0, hi, 0.0)

        count = jax.lax.dynamic_update_slice(count, cnt, (start,))
        weight = jax.lax.dynamic_update_slice(weight, wsum, (start,))
        bbox_lo = jax.lax.dynamic_update_slice(bbox_lo, lo, (start, 0))
        bbox_hi = jax.lax.dynamic_update_slice(bbox_hi, hi, (start, 0))

        # leaf decision for this level
        leaf_here = (cnt > 0) & ((cnt <= bucket_size) | (level == max_depth))
        is_leaf = jax.lax.dynamic_update_slice(is_leaf, leaf_here, (start,))

        if level == max_depth:
            # settle all remaining points at the bottom level
            settled = settled | live
            break

        # splitting hyperplane for active (non-leaf, non-empty) nodes
        active = (cnt > 0) & ~leaf_here
        sdim = jnp.argmax(hi - lo, axis=1).astype(jnp.int32)  # widest dim
        dim_per_pt = sdim[seg]
        coord = jnp.take_along_axis(points, dim_per_pt[:, None], axis=1)[:, 0]
        lo_d = jnp.take_along_axis(lo, sdim[:, None], axis=1)[:, 0]
        hi_d = jnp.take_along_axis(hi, sdim[:, None], axis=1)[:, 0]

        level_splitter = splitter
        if median_top_levels is not None and level >= median_top_levels:
            level_splitter = "midpoint"

        if level_splitter == "midpoint":
            sval = 0.5 * (lo_d + hi_d)
        elif level_splitter == "median":
            sval = _segment_median_sort(coord, seg, live, S)
        elif level_splitter == "median_sampled":
            inc = sampled & live
            scnt = jax.ops.segment_sum(inc.astype(jnp.int32), seg, num_segments=S)
            sval = _segment_median_sort(coord, seg, inc, S)
            # nodes with an empty sample fall back to midpoint
            sval = jnp.where(scnt > 0, sval, 0.5 * (lo_d + hi_d))
        elif level_splitter == "median_selection":
            sval = _segment_median_selection(coord, seg, live, cnt, lo_d, hi_d, S)
        else:  # pragma: no cover
            raise ValueError(f"unknown splitter {splitter!r}")

        # clamp degenerate splits (all points equal along dim): midpoint
        sval = jnp.where(hi_d > lo_d, sval, lo_d)

        split_dim = jax.lax.dynamic_update_slice(
            split_dim, jnp.where(active, sdim, -1), (start,)
        )
        split_val = jax.lax.dynamic_update_slice(
            split_val, jnp.where(active, sval, 0.0), (start,)
        )

        # route live points: side=0 if coord <= split_val (paper: "less than
        # or equal to m ... lower sub cell")
        node_active = active[seg]
        side = (coord > sval[seg]).astype(jnp.int32)
        new_node = 2 * node + 1 + side
        settled_now = live & ~node_active  # reached a leaf at this level
        settled = settled | settled_now
        node = jnp.where(live & node_active, new_node, node)

    return LinearKdTree(
        split_dim=split_dim,
        split_val=split_val,
        count=count,
        weight=weight,
        is_leaf=is_leaf,
        bbox_lo=bbox_lo,
        bbox_hi=bbox_hi,
        leaf_id=node,
        max_depth=max_depth,
        bucket_size=bucket_size,
    )


def leaf_nodes(tree: LinearKdTree) -> jax.Array:
    """Boolean mask (M,) of leaves that actually hold points."""
    return tree.is_leaf & (tree.count > 0)


# ---------------------------------------------------------------------------
# Bucket statistics — the partitioning substrate (paper §III-B/§IV)
#
# Partitions are computed from O(B) per-bucket summaries, never from the
# O(n) raw points: buckets are SFC-ordered by their centroid key, the
# knapsack slices bucket weights, and each point inherits its bucket's
# rank/part through a leaf_id gather. The only O(n) work in the whole
# pipeline is segment reductions and gathers — no per-point sort.
# ---------------------------------------------------------------------------

# non-bucket nodes sort to the tail; the canonical constant lives in sfc
# and is shared with curve_index/repartition — the clamp in summary_keys
# and the inactive-slot keys of tree-mode indexes must agree on it
from repro.core.sfc import KEY_SENTINEL  # noqa: E402


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("count", "weight", "centroid", "bbox_lo", "bbox_hi", "is_bucket"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class BucketSummary:
    """Per-leaf-bucket statistics in the (M,) node table (masked by
    ``is_bucket``). This pytree is what every layer exchanges instead of
    raw points: the local partitioner knapsacks ``weight``, the
    distributed path all_gathers the whole summary (O(B) per shard), and
    the incremental engine refreshes only the entries its delta dirtied.
    """

    count: jax.Array     # (M,) int32 points in the bucket
    weight: jax.Array    # (M,) float32 summed point weight
    centroid: jax.Array  # (M, d) float32 mean member coordinate
    bbox_lo: jax.Array   # (M, d) float32 tight member bbox
    bbox_hi: jax.Array   # (M, d)
    is_bucket: jax.Array  # (M,) bool: leaf holding >= 1 point

    @property
    def num_nodes(self) -> int:
        return self.count.shape[0]


def bucket_summary(
    tree: LinearKdTree,
    points: jax.Array,
    weights: jax.Array | None = None,
    *,
    leaf_id: jax.Array | None = None,
    active: jax.Array | None = None,
) -> BucketSummary:
    """Collect per-bucket statistics with one pass of segment reductions.

    ``leaf_id``/``active`` override the tree's build-time membership (the
    dynamic point-store case, where storage has masked slots)."""
    n = points.shape[0]
    M = tree.num_nodes
    if weights is None:
        weights = jnp.ones((n,), dtype=jnp.float32)
    if leaf_id is None:
        leaf_id = tree.leaf_id
    if active is None:
        active = jnp.ones((n,), dtype=bool)
    w = jnp.where(active, weights, 0.0)
    cnt = jax.ops.segment_sum(active.astype(jnp.int32), leaf_id, num_segments=M)
    wsum = jax.ops.segment_sum(w, leaf_id, num_segments=M)
    csum = jax.ops.segment_sum(
        jnp.where(active[:, None], points, 0.0), leaf_id, num_segments=M
    )
    centroid = csum / jnp.maximum(cnt[:, None].astype(jnp.float32), 1.0)
    big = jnp.float32(3.4e38)
    lo = jax.ops.segment_min(
        jnp.where(active[:, None], points, big), leaf_id, num_segments=M
    )
    hi = jax.ops.segment_max(
        jnp.where(active[:, None], points, -big), leaf_id, num_segments=M
    )
    lo = jnp.where(cnt[:, None] > 0, lo, 0.0)
    hi = jnp.where(cnt[:, None] > 0, hi, 0.0)
    return BucketSummary(
        count=cnt,
        weight=wsum,
        centroid=centroid,
        bbox_lo=lo,
        bbox_hi=hi,
        is_bucket=tree.is_leaf & (cnt > 0),
    )


def summary_keys(
    summary: BucketSummary,
    *,
    frame_lo: jax.Array,
    frame_hi: jax.Array,
    bits: int,
    curve: str = "hilbert",
) -> jax.Array:
    """(M,) SFC key per bucket centroid on the shared quantization frame
    (`sfc.keys_in_frame` — the same convention the engine and the query
    layer key against). Non-bucket nodes get the sentinel key so they
    sort after every real bucket."""
    from repro.core import sfc as _sfc

    keys = _sfc.keys_in_frame(summary.centroid, frame_lo, frame_hi, bits=bits, curve=curve)
    # the sentinel must stay unreachable by real buckets: at bits*d == 32
    # a centroid in the last curve cell keys to 0xFFFFFFFF, which would
    # silently drop the bucket behind the non-bucket tail — clamp it into
    # the previous cell (order-preserving; merges only the two topmost
    # cells at full key width)
    keys = jnp.minimum(keys, KEY_SENTINEL - jnp.uint32(1))
    return jnp.where(summary.is_bucket, keys, KEY_SENTINEL)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("node_keys", "rank", "order", "starts", "num_buckets"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class BucketOrder:
    """SFC ordering of the buckets (all arrays node-table shaped).

    ``rank[node]`` is the curve position of bucket ``node`` (tail ranks
    for non-buckets); ``order[r]`` is the node at curve position ``r``;
    ``starts[r]`` is the cumulative point count of buckets before ``r``
    — i.e. the first point-level curve index of bucket ``order[r]``.
    """

    node_keys: jax.Array   # (M,) uint32, sentinel for non-buckets
    rank: jax.Array        # (M,) int32 curve rank per node
    order: jax.Array       # (M,) int32 node ids in curve order
    starts: jax.Array      # (M+1,) int32 cumulative counts in curve order
    num_buckets: jax.Array  # () int32


def bucket_order(
    summary: BucketSummary,
    *,
    frame_lo: jax.Array,
    frame_hi: jax.Array,
    bits: int,
    curve: str = "hilbert",
) -> BucketOrder:
    """SFC-sort the O(B) bucket summaries (paper §III-B: "nodes are
    re-ordered by their SFC keys"). The sort is over the node table —
    its length is set by the tree depth, independent of n."""
    node_keys = summary_keys(
        summary, frame_lo=frame_lo, frame_hi=frame_hi, bits=bits, curve=curve
    )
    M = summary.num_nodes
    order = jnp.argsort(node_keys, stable=True).astype(jnp.int32)
    rank = jnp.zeros((M,), jnp.int32).at[order].set(jnp.arange(M, dtype=jnp.int32))
    cnt_rank = summary.count[order]
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(cnt_rank).astype(jnp.int32)]
    )
    return BucketOrder(
        node_keys=node_keys,
        rank=rank,
        order=order,
        starts=starts,
        num_buckets=jnp.sum(summary.is_bucket).astype(jnp.int32),
    )


def tree_order(
    tree: LinearKdTree,
    points: jax.Array,
    *,
    curve: str = "hilbert",
    bits: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-point curve placement from bucket statistics (paper §III-B:
    nodes are re-ordered by their SFC keys; point data follows its
    bucket).

    Returns ``(bucket_rank_per_point, bucket_key_per_point)`` — both
    O(n) *gathers* from the O(B) sorted summaries; no per-point sort
    runs (the ordering depends only on bucket centroids, never on
    weights). Callers that need a physical permutation (payload
    reordering, index materialization) pay for it explicitly via
    :func:`tree_perm`.
    """
    from repro.core import sfc as _sfc

    if bits is None:
        bits = _sfc.max_bits_per_dim(tree.dim)
    summary = bucket_summary(tree, points)
    border = bucket_order(
        summary,
        frame_lo=tree.bbox_lo[0],
        frame_hi=tree.bbox_hi[0],
        bits=bits,
        curve=curve,
    )
    return border.rank[tree.leaf_id], border.node_keys[tree.leaf_id]


def tree_perm(bucket_rank_per_point: jax.Array) -> jax.Array:
    """Materialize the bucket-major point permutation from per-point
    bucket ranks. This is the ONLY O(n log n) step of the tree pipeline
    and nothing in ``partitioner.partition(use_tree=True)`` calls it —
    it exists for consumers that must physically reorder a payload."""
    return jnp.argsort(bucket_rank_per_point, stable=True)


def validate(tree: LinearKdTree, points: jax.Array) -> dict:
    """Host-side structural invariants (used by property tests)."""
    import numpy as np

    sd = np.asarray(tree.split_dim)
    sv = np.asarray(tree.split_val)
    cnt = np.asarray(tree.count)
    leaf = np.asarray(tree.is_leaf)
    leaf_id = np.asarray(tree.leaf_id)
    pts = np.asarray(points)
    M = sd.shape[0]
    problems = []
    # every point's leaf is a real leaf
    if not leaf[leaf_id].all():
        problems.append("point assigned to non-leaf")
    # child counts sum to parent count for internal nodes
    internal = (~leaf) & (cnt > 0)
    for k in np.nonzero(internal)[0]:
        l, r = 2 * k + 1, 2 * k + 2
        if r < M and cnt[k] != cnt[l] + cnt[r]:
            problems.append(f"count mismatch at node {k}")
            break
    # bucket occupancy: leaves above max_depth respect bucket_size
    depth = np.floor(np.log2(np.arange(M) + 1)).astype(int)
    over = leaf & (cnt > tree.bucket_size) & (depth < tree.max_depth)
    if over.any():
        problems.append("oversized leaf above max depth")
    # membership consistency: walking the split planes from the root lands
    # each point in its recorded leaf
    rng = np.random.default_rng(0)
    sample = rng.choice(pts.shape[0], size=min(256, pts.shape[0]), replace=False)
    for i in sample:
        k = 0
        while not leaf[k]:
            k = 2 * k + 1 + int(pts[i, sd[k]] > sv[k])
            if k >= M:
                problems.append("walk fell off tree")
                break
        else:
            if k != leaf_id[i]:
                problems.append(f"walk landed at {k}, recorded {leaf_id[i]}")
                break
    return {"ok": not problems, "problems": problems}
