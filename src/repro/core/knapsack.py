"""Greedy knapsack on a weighted space-filling curve (paper §III-C).

The SFC lays the elements on a weighted line segment. A parallel prefix
sum gives each element its global rank/weight offset; slicing the segment
into ``P`` nearly equal weights (without violating the key order) yields
the partitions. The paper's guarantee — *"the load on any two processes
differs by at most the maximum weight of any point"* — is property-tested
in ``tests/test_knapsack.py``.

Everything here is fixed-shape, jit-able jnp; the Pallas kernel
``repro.kernels.knapsack_scan`` implements the blocked prefix-scan +
boundary pick for the hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("num_parts",))
def slice_weighted_curve(weights: jax.Array, num_parts: int) -> jax.Array:
    """Slice a weight sequence (already in SFC order) into contiguous parts.

    Returns part_id (n,) int32, non-decreasing. Part boundaries are the
    greedy choice: element i goes to part floor(prefix_exclusive(i) /
    (total / P)) clipped to P-1 — each part's load misses the ideal by at
    most one element weight.
    """
    w = weights.astype(jnp.float32)
    prefix = jnp.cumsum(w) - w  # exclusive prefix
    total = prefix[-1] + w[-1]
    ideal = total / num_parts
    ideal = jnp.where(ideal > 0, ideal, 1.0)
    # midpoint rule: assign by the element's center of mass on the segment
    part = jnp.floor((prefix + 0.5 * w) / ideal).astype(jnp.int32)
    return jnp.clip(part, 0, num_parts - 1)


@functools.partial(jax.jit, static_argnames=("num_parts",))
def part_boundaries(weights: jax.Array, num_parts: int) -> jax.Array:
    """First element index of each part (P+1 entries, last = n)."""
    part = slice_weighted_curve(weights, num_parts)
    n = weights.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    # boundary[p] = first i with part[i] >= p
    starts = jnp.searchsorted(part, jnp.arange(num_parts, dtype=jnp.int32), side="left")
    del idx
    return jnp.concatenate([starts.astype(jnp.int32), jnp.array([n], dtype=jnp.int32)])


@functools.partial(jax.jit, static_argnames=("num_parts",))
def part_loads(weights: jax.Array, part: jax.Array, num_parts: int) -> jax.Array:
    """Load (sum of weights) per part."""
    return jax.ops.segment_sum(
        weights.astype(jnp.float32), part, num_segments=num_parts
    )


# ---------------------------------------------------------------------------
# Two-level (node -> device) nested knapsack
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_nodes", "devices_per_node"))
def device_slice_within_nodes(
    weights: jax.Array,
    node: jax.Array,
    num_nodes: int,
    devices_per_node: int,
) -> jax.Array:
    """Fine level of the hierarchy: device id within each node's slice.

    ``node`` (n,) int32 must be non-decreasing along the curve — a coarse
    knapsack output, fresh (``slice_weighted_curve(w, num_nodes)``) or
    frozen from an earlier step (the intra-node-only re-slice keeps it).
    Each node's contiguous slice is re-sliced into ``devices_per_node``
    parts with the same midpoint rule as :func:`slice_weighted_curve`:
    node weight offsets are read off the SAME exclusive prefix the flat
    rule uses, so with ``num_nodes == 1`` the result is bit-identical to
    ``slice_weighted_curve(weights, devices_per_node)`` — the flat path
    IS the trivial hierarchy.
    """
    w = weights.astype(jnp.float32)
    prefix = jnp.cumsum(w) - w  # exclusive prefix
    total = prefix[-1] + w[-1]
    # first curve index of each node's slice -> its exclusive weight
    # offset; prefix extended by the total so empty tail nodes (start ==
    # n) read a consistent offset
    starts = jnp.searchsorted(
        node, jnp.arange(num_nodes, dtype=node.dtype), side="left"
    )
    prefix_ext = jnp.concatenate([prefix, total[None]])
    node_off = prefix_ext[starts]                      # (N,)
    node_end = jnp.concatenate([node_off[1:], total[None]])
    node_tot = node_end - node_off                     # (N,)
    local_prefix = prefix - node_off[node]
    ideal = node_tot[node] / devices_per_node
    ideal = jnp.where(ideal > 0, ideal, 1.0)
    dev = jnp.floor((local_prefix + 0.5 * w) / ideal).astype(jnp.int32)
    return jnp.clip(dev, 0, devices_per_node - 1)


@functools.partial(jax.jit, static_argnames=("num_nodes", "devices_per_node"))
def two_level_slice(
    weights: jax.Array, num_nodes: int, devices_per_node: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Nested greedy knapsack of a weighted curve: coarse slices to
    ``num_nodes`` nodes, then each node's slice independently re-sliced
    across its ``devices_per_node`` devices.

    Returns ``(node, device, part)`` with ``part = node * devices_per_node
    + device``, all (n,) int32 and non-decreasing along the curve. The
    paper's balance guarantee nests: node loads differ by at most one max
    element weight, and within every node the device loads do too.
    """
    node = slice_weighted_curve(weights, num_nodes)
    dev = device_slice_within_nodes(weights, node, num_nodes, devices_per_node)
    return node, dev, node * devices_per_node + dev


def greedy_bins(weights: jax.Array, num_bins: int) -> jax.Array:
    """Non-contiguous greedy knapsack: heaviest-first into the lightest bin.

    Used where curve order need not be preserved (e.g. assigning top tree
    nodes to processes in partitioner_init, serving-batch admission).
    Host-side O(n log n + n·B); returns bin id per element.
    """
    import numpy as np

    w = np.asarray(weights, dtype=np.float64)
    order = np.argsort(-w, kind="stable")
    loads = np.zeros(num_bins)
    out = np.zeros(w.shape[0], dtype=np.int32)
    for i in order:
        b = int(np.argmin(loads))
        loads[b] += w[i]
        out[i] = b
    return jnp.asarray(out)


@functools.partial(jax.jit, static_argnames=("num_parts",))
def incremental_reslice(
    weights: jax.Array, old_part: jax.Array, num_parts: int
) -> tuple[jax.Array, jax.Array]:
    """Incremental load balancing (paper §IV): keep the existing curve
    order, recompute ranks on the new weighted segment, re-slice.

    Returns (new_part, moved_mask). Because the order is preserved, an
    element can only move to a rank-adjacent part in the best case —
    migration is restricted to neighbors P±1 for small load deltas (the
    paper's locality claim, asserted in tests).
    """
    new_part = slice_weighted_curve(weights, num_parts)
    return new_part, new_part != old_part
