"""Greedy knapsack on a weighted space-filling curve (paper §III-C).

The SFC lays the elements on a weighted line segment. A parallel prefix
sum gives each element its global rank/weight offset; slicing the segment
into ``P`` nearly equal weights (without violating the key order) yields
the partitions. The paper's guarantee — *"the load on any two processes
differs by at most the maximum weight of any point"* — is property-tested
in ``tests/test_knapsack.py``.

Everything here is fixed-shape, jit-able jnp; the Pallas kernel
``repro.kernels.knapsack_scan`` implements the blocked prefix-scan +
boundary pick for the hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("num_parts",))
def slice_weighted_curve(weights: jax.Array, num_parts: int) -> jax.Array:
    """Slice a weight sequence (already in SFC order) into contiguous parts.

    Returns part_id (n,) int32, non-decreasing. Part boundaries are the
    greedy choice: element i goes to part floor(prefix_exclusive(i) /
    (total / P)) clipped to P-1 — each part's load misses the ideal by at
    most one element weight.
    """
    w = weights.astype(jnp.float32)
    prefix = jnp.cumsum(w) - w  # exclusive prefix
    total = prefix[-1] + w[-1]
    ideal = total / num_parts
    ideal = jnp.where(ideal > 0, ideal, 1.0)
    # midpoint rule: assign by the element's center of mass on the segment
    part = jnp.floor((prefix + 0.5 * w) / ideal).astype(jnp.int32)
    return jnp.clip(part, 0, num_parts - 1)


@functools.partial(jax.jit, static_argnames=("num_parts",))
def part_boundaries(weights: jax.Array, num_parts: int) -> jax.Array:
    """First element index of each part (P+1 entries, last = n)."""
    part = slice_weighted_curve(weights, num_parts)
    n = weights.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    # boundary[p] = first i with part[i] >= p
    starts = jnp.searchsorted(part, jnp.arange(num_parts, dtype=jnp.int32), side="left")
    del idx
    return jnp.concatenate([starts.astype(jnp.int32), jnp.array([n], dtype=jnp.int32)])


@functools.partial(jax.jit, static_argnames=("num_parts",))
def part_loads(weights: jax.Array, part: jax.Array, num_parts: int) -> jax.Array:
    """Load (sum of weights) per part."""
    return jax.ops.segment_sum(
        weights.astype(jnp.float32), part, num_segments=num_parts
    )


def greedy_bins(weights: jax.Array, num_bins: int) -> jax.Array:
    """Non-contiguous greedy knapsack: heaviest-first into the lightest bin.

    Used where curve order need not be preserved (e.g. assigning top tree
    nodes to processes in partitioner_init, serving-batch admission).
    Host-side O(n log n + n·B); returns bin id per element.
    """
    import numpy as np

    w = np.asarray(weights, dtype=np.float64)
    order = np.argsort(-w, kind="stable")
    loads = np.zeros(num_bins)
    out = np.zeros(w.shape[0], dtype=np.int32)
    for i in order:
        b = int(np.argmin(loads))
        loads[b] += w[i]
        out[i] = b
    return jnp.asarray(out)


@functools.partial(jax.jit, static_argnames=("num_parts",))
def incremental_reslice(
    weights: jax.Array, old_part: jax.Array, num_parts: int
) -> tuple[jax.Array, jax.Array]:
    """Incremental load balancing (paper §IV): keep the existing curve
    order, recompute ranks on the new weighted segment, re-slice.

    Returns (new_part, moved_mask). Because the order is preserved, an
    element can only move to a rank-adjacent part in the best case —
    migration is restricted to neighbors P±1 for small load deltas (the
    paper's locality claim, asserted in tests).
    """
    new_part = slice_weighted_curve(weights, num_parts)
    return new_part, new_part != old_part
