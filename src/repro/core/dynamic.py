"""Dynamic partitioning and amortized load balancing (paper §IV).

Implements the paper's three dynamic-data mechanisms on the linearized
kd-tree:

* ``locate``/``insert``/``delete`` — the InsertDelete query path (walk
  split hyperplanes root→leaf, fully vectorized).
* ``adjustments`` — Algorithm 1: split *heavy* buckets (> 2*BUCKETSIZE),
  merge *light* sibling leaves (combined <= BUCKETSIZE), level-synchronous
  bottom-up/top-down passes instead of the paper's recursive DFS.
* ``AmortizedController`` — Algorithm 3's credit scheme: a load-balance
  phase banks credits equal to its cost; each iteration's *excess*
  computation cost (above the post-balance baseline) spends them; the next
  full balance triggers when credits are exhausted. The controller is a
  pure-python object reused by the MoE layer and the serving batcher.

Point storage uses fixed capacity + an ``active`` mask so every operation
is fixed-shape (XLA-friendly); this replaces the paper's concurrent
linked lists (see the hardware-adaptation table in ``DESIGN.md`` at the
repo root, which also documents how these primitives feed the
bucket-statistics partition pipeline: ``locate`` is the delta routing
step, ``adjustments`` repairs the bucket set before summaries are
re-keyed, and the tree counters maintained by insert/delete ARE the
incremental bucket statistics).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import kdtree as _kdtree
from repro.core.kdtree import LinearKdTree


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("points", "weights", "active", "leaf_id", "tree"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class DynamicPointSet:
    points: jax.Array   # (C, d) float32, C = capacity
    weights: jax.Array  # (C,) float32
    active: jax.Array   # (C,) bool
    leaf_id: jax.Array  # (C,) int32 heap id of owning leaf (undefined if !active)
    tree: LinearKdTree

    @property
    def capacity(self) -> int:
        return self.points.shape[0]

    def _replace(self, **kw) -> "DynamicPointSet":
        return dataclasses.replace(self, **kw)


def from_points(
    points: jax.Array,
    weights: jax.Array | None = None,
    *,
    capacity: int | None = None,
    max_depth: int = 14,
    bucket_size: int = 32,
    splitter: _kdtree.Splitter = "midpoint",
) -> DynamicPointSet:
    """Build the initial weighted kd-tree from archived data (paper §IV)."""
    n, d = points.shape
    if weights is None:
        weights = jnp.ones((n,), dtype=jnp.float32)
    capacity = capacity or 2 * n
    tree = _kdtree.build(
        points, weights, max_depth=max_depth, bucket_size=bucket_size, splitter=splitter
    )
    pts = jnp.zeros((capacity, d), dtype=jnp.float32).at[:n].set(points)
    wts = jnp.zeros((capacity,), dtype=jnp.float32).at[:n].set(weights)
    act = jnp.zeros((capacity,), dtype=bool).at[:n].set(True)
    lid = jnp.zeros((capacity,), dtype=jnp.int32).at[:n].set(tree.leaf_id)
    return DynamicPointSet(points=pts, weights=wts, active=act, leaf_id=lid, tree=tree)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def locate(tree: LinearKdTree, pts: jax.Array, max_depth: int) -> jax.Array:
    """Vectorized root→leaf walk along split hyperplanes (InsertDelete /
    point-location path). Returns heap leaf id per query point."""

    def body(_, node):
        dim = tree.split_dim[node]
        val = tree.split_val[node]
        leaf = tree.is_leaf[node] | (tree.split_dim[node] < 0)
        coord = jnp.take_along_axis(pts, jnp.maximum(dim, 0)[:, None], axis=1)[:, 0]
        side = (coord > val).astype(jnp.int32)
        nxt = 2 * node + 1 + side
        return jnp.where(leaf, node, nxt)

    node0 = jnp.zeros((pts.shape[0],), dtype=jnp.int32)
    return jax.lax.fori_loop(0, max_depth, body, node0)


def insert(dps: DynamicPointSet, new_pts: jax.Array, new_wts: jax.Array) -> DynamicPointSet:
    """Insert a batch of points into free slots and locate their buckets."""
    k = new_pts.shape[0]
    free = jnp.nonzero(~dps.active, size=k, fill_value=dps.capacity - 1)[0]
    lid = locate(dps.tree, new_pts, dps.tree.max_depth)
    points = dps.points.at[free].set(new_pts)
    weights = dps.weights.at[free].set(new_wts)
    active = dps.active.at[free].set(True)
    leaf_id = dps.leaf_id.at[free].set(lid)
    # bump subtree weights along the path root→leaf
    tree = _bump_counts(dps.tree, lid, new_wts, sign=+1)
    return DynamicPointSet(points, weights, active, leaf_id, tree)


def first_occurrence_mask(slot_ids: jax.Array) -> jax.Array:
    """(k,) bool: True at the first occurrence of each id in the batch.

    The dedup mask behind delete's no-op guarantee — shared with the
    repartitioning engine so its bucket-summary deltas apply exactly the
    ids the tree counters decrement."""
    order = jnp.argsort(slot_ids, stable=True)
    sorted_ids = slot_ids[order]
    first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    )
    return jnp.zeros_like(first_sorted).at[order].set(first_sorted)


def delete(
    dps: DynamicPointSet,
    slot_ids: jax.Array,
    removed: jax.Array | None = None,
) -> DynamicPointSet:
    """Deactivate points by storage slot id. Already-inactive ids and
    duplicates (within or across calls) are no-ops: the weight and count
    decrements are masked by ``active`` and a first-occurrence filter, so
    tree counters stay consistent with storage. ``removed`` overrides the
    mask (a caller that already computed ``active & first_occurrence``
    passes it to avoid a second argsort of the batch)."""
    act = (
        dps.active[slot_ids] & first_occurrence_mask(slot_ids)
        if removed is None
        else removed
    )
    wts = dps.weights[slot_ids] * act
    tree = _bump_counts(
        dps.tree, dps.leaf_id[slot_ids], wts, sign=-1, counts=act.astype(jnp.int32)
    )
    active = dps.active.at[slot_ids].set(False)
    return dps._replace(active=active, tree=tree)


def _bump_counts(
    tree: LinearKdTree,
    leaf_ids: jax.Array,
    wts: jax.Array,
    sign: int,
    counts: jax.Array | None = None,
) -> LinearKdTree:
    """Add +-(count, weight) along all root→leaf paths (vectorized over the
    batch, one scatter-add per level). ``counts`` overrides the default
    count delta of 1 per id (used to mask no-op deletes)."""
    count, weight = tree.count, tree.weight
    node = leaf_ids
    ones = (jnp.ones_like(leaf_ids) if counts is None else counts) * sign
    swts = wts * sign
    for _ in range(tree.max_depth + 1):
        count = count.at[node].add(ones)
        weight = weight.at[node].add(swts)
        done = node == 0
        node = jnp.where(done, -1, (node - 1) // 2)  # -1 scatters are dropped
        ones = jnp.where(done, 0, ones)
        swts = jnp.where(done, 0.0, swts)
    # after reaching the root, node becomes -1 (wraps to the last node) but
    # the added values are zeroed, so the wrapped scatters are no-ops
    return tree._replace(count=count, weight=weight)


# ---------------------------------------------------------------------------
# Algorithm 1 — Adjustments (split heavy / merge light)
# ---------------------------------------------------------------------------

def _node_depths(M: int) -> jax.Array:
    return jnp.floor(jnp.log2(jnp.arange(M, dtype=jnp.float32) + 1.0)).astype(jnp.int32)


def recount(dps: DynamicPointSet) -> DynamicPointSet:
    """Recompute exact subtree counts/weights bottom-up from the points."""
    tree = dps.tree
    M = tree.num_nodes
    leaf_cnt = jax.ops.segment_sum(
        dps.active.astype(jnp.int32), dps.leaf_id, num_segments=M
    )
    leaf_wt = jax.ops.segment_sum(
        jnp.where(dps.active, dps.weights, 0.0), dps.leaf_id, num_segments=M
    )
    cnt, wt = leaf_cnt, leaf_wt
    for level in range(tree.max_depth - 1, -1, -1):
        start, end = (1 << level) - 1, (1 << (level + 1)) - 1
        child_lo = 2 * jnp.arange(start, end) + 1
        add_c = cnt[child_lo] + cnt[child_lo + 1]
        add_w = wt[child_lo] + wt[child_lo + 1]
        cnt = cnt.at[start:end].add(add_c)
        wt = wt.at[start:end].add(add_w)
    return dps._replace(tree=tree._replace(count=cnt, weight=wt))


@functools.partial(jax.jit, static_argnames=())
def _merge_pass(dps: DynamicPointSet) -> DynamicPointSet:
    """Bottom-up merge of light subtrees (Alg. 1 merge branch).

    A node whose *subtree* count <= BUCKETSIZE becomes a leaf; its
    descendants are cleared and their points re-homed to it. One bottom-up
    sweep fully cascades (lower merges happen before upper checks).
    """
    dps = recount(dps)
    tree = dps.tree
    B = tree.bucket_size
    M = tree.num_nodes
    depths = _node_depths(M)
    is_leaf = tree.is_leaf
    leaf_id = dps.leaf_id
    leaf_depth = jnp.floor(jnp.log2(leaf_id.astype(jnp.float32) + 1.0)).astype(jnp.int32)

    for level in range(tree.max_depth - 1, -1, -1):
        start, end = (1 << level) - 1, (1 << (level + 1)) - 1
        nodes = jnp.arange(start, end)
        internal = (~is_leaf[nodes]) & (tree.count[nodes] > 0)
        mergeable = internal & (tree.count[nodes] <= B)
        # mark node a leaf, clear strict descendants' leaf flags
        is_leaf = is_leaf.at[nodes].set(is_leaf[nodes] | mergeable)
        # re-home points whose leaf ancestor at `level` is a merged node
        shift = jnp.maximum(leaf_depth - level, 0)
        anc = ((leaf_id + 1) >> shift) - 1
        anc_in_level = (anc >= start) & (anc < end) & (leaf_depth > level)
        merged_anc = anc_in_level & mergeable[jnp.clip(anc - start, 0, end - start - 1)]
        leaf_id = jnp.where(merged_anc & dps.active, anc, leaf_id)
        leaf_depth = jnp.where(merged_anc & dps.active, level, leaf_depth)

    # clear leaf flags of nodes that no longer hold any point and are below a merged leaf
    M_ids = jnp.arange(M)
    holds = jax.ops.segment_sum(dps.active.astype(jnp.int32), leaf_id, num_segments=M)
    is_leaf = is_leaf & ((holds > 0) | (tree.count == 0) | (M_ids == 0))
    tree = tree._replace(is_leaf=is_leaf)
    out = dps._replace(tree=tree, leaf_id=leaf_id)
    return recount(out)


@functools.partial(jax.jit, static_argnames=())
def _split_pass(dps: DynamicPointSet) -> DynamicPointSet:
    """Top-down split of heavy buckets (> 2*BUCKETSIZE), SplitLeaf loop.

    Points in heavy leaves flow further down with fresh midpoint split
    planes on tight bounding boxes, exactly like the static build but
    restricted to the heavy subtrees.
    """
    dps = recount(dps)
    tree = dps.tree
    B = tree.bucket_size
    points, active = dps.points, dps.active
    leaf_id = dps.leaf_id
    split_dim, split_val, is_leaf = tree.split_dim, tree.split_val, tree.is_leaf

    for level in range(tree.max_depth):
        start, end = (1 << level) - 1, (1 << (level + 1)) - 1
        S = end - start
        # points currently sitting in a leaf at this level
        here = active & (leaf_id >= start) & (leaf_id < end)
        seg = jnp.clip(leaf_id - start, 0, S - 1)
        cnt = jax.ops.segment_sum(jnp.where(here, 1, 0), seg, num_segments=S)
        leaf_lv = is_leaf[start:end]
        heavy = leaf_lv & (cnt > 2 * B)
        big = jnp.float32(3.4e38)
        plo = jnp.where(here[:, None], points, big)
        phi = jnp.where(here[:, None], points, -big)
        lo = jax.ops.segment_min(plo, seg, num_segments=S)
        hi = jax.ops.segment_max(phi, seg, num_segments=S)
        sdim = jnp.argmax(hi - lo, axis=1).astype(jnp.int32)
        lo_d = jnp.take_along_axis(lo, sdim[:, None], axis=1)[:, 0]
        hi_d = jnp.take_along_axis(hi, sdim[:, None], axis=1)[:, 0]
        sval = 0.5 * (lo_d + hi_d)

        split_dim = split_dim.at[start:end].set(jnp.where(heavy, sdim, split_dim[start:end]))
        split_val = split_val.at[start:end].set(jnp.where(heavy, sval, split_val[start:end]))
        is_leaf = is_leaf.at[start:end].set(jnp.where(heavy, False, is_leaf[start:end]))
        # children of freshly-split nodes become leaves
        heavy_nodes = jnp.arange(start, end)
        ch_lo = 2 * heavy_nodes + 1
        is_leaf = is_leaf.at[ch_lo].set(jnp.where(heavy, True, is_leaf[ch_lo]))
        is_leaf = is_leaf.at[ch_lo + 1].set(jnp.where(heavy, True, is_leaf[ch_lo + 1]))

        # route points of heavy leaves down one level
        pt_heavy = here & heavy[seg]
        dim_pp = sdim[seg]
        coord = jnp.take_along_axis(points, dim_pp[:, None], axis=1)[:, 0]
        side = (coord > sval[seg]).astype(jnp.int32)
        leaf_id = jnp.where(pt_heavy, 2 * leaf_id + 1 + side, leaf_id)

    out = dps._replace(
        tree=tree._replace(split_dim=split_dim, split_val=split_val, is_leaf=is_leaf),
        leaf_id=leaf_id,
    )
    return recount(out)


def adjustments(dps: DynamicPointSet, max_sweeps: int = 4) -> DynamicPointSet:
    """Algorithm 1: adjustment sweeps (split heavy, merge light).

    The paper's SplitLeaf recurses until every bucket fits; a single
    level-synchronous sweep descends each point at most one level per
    level-iteration, so pathological inserts (a dense burst into one
    bucket) may need another sweep. We iterate until occupancy fits or
    ``max_sweeps`` is reached (depth-capped leaves can legally stay heavy).
    """
    B = dps.tree.bucket_size
    for _ in range(max_sweeps):
        dps = _merge_pass(_split_pass(dps))
        if int(max_bucket_occupancy(dps)) <= 2 * B:
            break
    return dps


def num_buckets(dps: DynamicPointSet) -> jax.Array:
    return jnp.sum(dps.tree.is_leaf & (dps.tree.count > 0))


def max_bucket_occupancy(dps: DynamicPointSet) -> jax.Array:
    M = dps.tree.num_nodes
    holds = jax.ops.segment_sum(dps.active.astype(jnp.int32), dps.leaf_id, num_segments=M)
    return jnp.max(holds)


# ---------------------------------------------------------------------------
# Algorithm 3 — amortized load balancing controller
# ---------------------------------------------------------------------------

@dataclass
class AmortizedController:
    """Credit-based rebalance trigger (paper Algorithm 3).

    ``observe(cost_per_op, num_buckets)`` is called every step with the
    measured (or modeled) cost; it returns True when a full load balance
    should run. After running one, call ``balanced(lb_cost, num_buckets)``.

    The generalized cost metric is the paper's query-processing variant:
    cost = (max avg cost per op) * (max #buckets across processes).
    """

    credits: float = 0.0          # lbtime: bank from the last LB phase
    delta: float = 0.0            # spent-so-far excess
    base_cost: float = 0.0        # basebkt: baseline cost after last LB
    base_timeop: float = 0.0
    history: list = field(default_factory=list)

    def balanced(self, lb_cost: float, num_buckets: int, timeop: float | None = None) -> None:
        self.credits = float(lb_cost)
        self.delta = 0.0
        self.base_timeop = 0.0 if timeop is None else float(timeop)
        self.base_cost = self.base_timeop * num_buckets
        self.history.append(("lb", lb_cost))

    def observe(self, timeop: float, num_buckets: int) -> bool:
        cost = float(timeop) * num_buckets
        if self.base_timeop == 0.0:
            self.base_timeop = float(timeop)
            self.base_cost = cost
            self.history.append(("base", cost))
            return False
        if cost > self.base_cost:
            self.delta += cost - self.base_cost
        self.history.append(("obs", cost, self.delta))
        return self.delta > self.credits

    @property
    def exhausted(self) -> bool:
        return self.delta > self.credits
