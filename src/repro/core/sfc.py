"""Space-filling-curve key generation (paper §III-B).

Two curves are supported, as in the paper:

* **Morton** (default) — bit-interleave of quantized coordinates.
* **Hilbert-like** — the paper generalizes the geometric Hilbert
  construction to random point distributions and arbitrary dimension.
  We implement it in closed form with Skilling's transpose algorithm
  (Gray-code sub-cell visiting order — identical to the recursive
  tree-traversal rules for regular midpoint trees), plus the paper's
  "statistics" extension: quantizing coordinates in *rank space*
  (per-dimension empirical CDF) makes the curve adapt to clustered
  distributions exactly like median splitters do for kd-trees.

Keys are uint32 words. ``words=1`` packs ``d * bits <= 32`` bits into a
single word; ``words=2`` returns a ``(n, 2)`` array of (hi, lo) words for
up to 64 bits of resolution. All functions are jit-able and fixed-shape.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

Stats = Literal["geometric", "rank"]

# The one sentinel key: inactive/non-bucket entries sort after every real
# key. Real keys must stay below it (see kdtree.summary_keys's clamp);
# curve_index / kdtree / repartition all alias THIS constant.
KEY_SENTINEL = np.uint32(0xFFFFFFFF)


def max_bits_per_dim(d: int, words: int = 1) -> int:
    """Largest per-dimension resolution that fits the key width."""
    return min(32, (32 * words) // d)


# ---------------------------------------------------------------------------
# The shared quantization frame
#
# Every consumer that keys points against a *fixed* box — the kd-tree's
# bucket keying, the repartitioning engine's frozen frame, the query
# layer's frame-addressed keys, the kernels.ops key cache — must use the
# SAME clip-into-boundary-cells convention, or cached point keys and
# fresh query keys land on different curves. These three functions are
# that single convention; do not hand-roll span/unit/cells anywhere else.
# ---------------------------------------------------------------------------

def bbox_frame(
    points: jax.Array, margin: float = 0.0
) -> tuple[jax.Array, jax.Array]:
    """(lo, hi) quantization frame: the data bbox, optionally widened by
    ``margin`` × span per side (the engine's drift headroom)."""
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    if margin:
        span = jnp.where(hi > lo, hi - lo, 1.0)
        lo = lo - margin * span
        hi = hi + margin * span
    return lo, hi


def cells_in_frame(
    pts: jax.Array, lo: jax.Array, hi: jax.Array, bits: int
) -> jax.Array:
    """Quantize (n, d) points against a fixed frame into uint32 cells in
    [0, 2^bits). Points outside the frame are clipped into the boundary
    cells (drifted data stays addressable until the next frame refresh)."""
    span = jnp.where(hi > lo, hi - lo, 1.0)
    unit = jnp.clip((pts - lo) / span, 0.0, 1.0 - 1e-7)
    return (unit * (2**bits)).astype(jnp.uint32)


def keys_in_frame(
    pts: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    bits: int,
    curve: str = "morton",
    words: int = 1,
) -> jax.Array:
    """SFC keys against a fixed quantization frame (see module note).

    The ONE keying convention shared by the kd-tree bucket pipeline, the
    repartitioning engine and the query layer — keys produced here are
    mutually comparable for any inputs keyed on the same (lo, hi, bits).
    """
    cells = cells_in_frame(pts, lo, hi, bits)
    if curve == "morton":
        return morton_key_from_cells(cells, bits, words=words)
    return hilbert_key_from_cells(cells, bits, words=words)


# ---------------------------------------------------------------------------
# Quantization (geometry or data statistics)
# ---------------------------------------------------------------------------

def quantize(points: jax.Array, bits: int, stats: Stats = "geometric") -> jax.Array:
    """Map (n, d) float points to (n, d) uint32 cell coordinates in [0, 2^bits).

    ``geometric``: affine map from the bounding box (the paper's default
    geometric quantization — equivalent to midpoint splitters).
    ``rank``: per-dimension rank transform (empirical CDF) — equivalent to
    exact-median splitters; robust to clustered distributions.
    """
    n, d = points.shape
    if stats == "geometric":
        lo = jnp.min(points, axis=0)
        hi = jnp.max(points, axis=0)
        span = jnp.where(hi > lo, hi - lo, 1.0)
        unit = (points - lo) / span
        q = jnp.clip((unit * (2**bits)).astype(jnp.uint32), 0, 2**bits - 1)
        return q
    elif stats == "rank":
        order = jnp.argsort(points, axis=0)
        ranks = jnp.zeros((n, d), dtype=jnp.uint32)
        ranks = ranks.at[order, jnp.arange(d)[None, :]].set(
            jnp.broadcast_to(jnp.arange(n, dtype=jnp.uint32)[:, None], (n, d))
        )
        # scale ranks to [0, 2^bits). float32 is exact for n < 2^24; for
        # larger n the rank transform loses a few low bits of resolution,
        # which only perturbs intra-bucket order (harmless for partitioning).
        denom = max(n - 1, 1)
        q = (ranks.astype(jnp.float32) * ((2**bits - 1) / denom)).astype(jnp.uint32)
        return q
    else:  # pragma: no cover - guarded by Literal
        raise ValueError(f"unknown stats mode {stats!r}")


# ---------------------------------------------------------------------------
# Morton (bit interleave)
# ---------------------------------------------------------------------------

def _interleave(q: jax.Array, bits: int, words: int) -> jax.Array:
    """Bit-interleave (n, d) uint32 cells into (n, words) uint32 keys.

    Output bit layout (global bit index g, counting from the MSB of the
    key): g-th bit = bit (bits-1 - g//d) of dimension (g % d). hi word
    first.  Pure jnp; the Pallas kernel in ``repro.kernels.morton``
    implements the same layout.
    """
    n, d = q.shape
    total = bits * d
    width = 32 * words
    # bit b (from MSB of dim i at position bits-1-k) lands at global slot
    # g = k*d + i ; key bit position (from MSB of the key) = g, i.e. from
    # LSB: width-1 - (offset + g) with offset right-aligning the payload.
    offset = width - total
    out = jnp.zeros((n, words), dtype=jnp.uint32)
    for k in range(bits):  # static python loop: bits <= 32
        src_bit = bits - 1 - k
        comp = (q >> src_bit) & 1  # (n, d)
        for i in range(d):
            g = k * d + i
            pos_from_msb = offset + g
            word = pos_from_msb // 32
            bit_in_word = 31 - (pos_from_msb % 32)
            out = out.at[:, word].set(out[:, word] | (comp[:, i] << bit_in_word))
    return out


def morton_key(
    points: jax.Array,
    bits: int | None = None,
    *,
    stats: Stats = "geometric",
    words: int = 1,
) -> jax.Array:
    """Morton SFC keys for (n, d) points. Returns (n,) uint32 if words==1
    else (n, words) uint32 with hi word first."""
    n, d = points.shape
    if bits is None:
        bits = max_bits_per_dim(d, words)
    assert bits * d <= 32 * words, f"{bits} bits x {d} dims > {32*words} bit key"
    q = quantize(points, bits, stats)
    keys = _interleave(q, bits, words)
    return keys[:, 0] if words == 1 else keys


# ---------------------------------------------------------------------------
# Hilbert-like (Skilling transpose algorithm, arbitrary dimension)
# ---------------------------------------------------------------------------

def _hilbert_transpose(q: jax.Array, bits: int) -> jax.Array:
    """Convert (n, d) uint32 cell coords into the Hilbert 'transpose' form.

    Skilling's inverse-undo + Gray-encode. After this, bit-interleaving
    the transposed coords (dim 0 first) yields the Hilbert index. Static
    loops over bits and dims; fully vectorized over points.
    """
    n, d = q.shape
    X = [q[:, i] for i in range(d)]
    M = jnp.uint32(1 << (bits - 1))

    # Inverse undo excess work
    Q = 1 << (bits - 1)
    while Q > 1:
        Pmask = jnp.uint32(Q - 1)
        Qm = jnp.uint32(Q)
        for i in range(d):
            cond = (X[i] & Qm) != 0
            # if bit set: invert low bits of X[0]; else swap low bits X[0]<->X[i]
            t = (X[0] ^ X[i]) & Pmask
            X0_if = X[0] ^ Pmask
            X0_else = X[0] ^ t
            Xi_else = X[i] ^ t
            X[0] = jnp.where(cond, X0_if, X0_else)
            if i != 0:
                X[i] = jnp.where(cond, X[i], Xi_else)
        Q >>= 1

    # Gray encode
    for i in range(1, d):
        X[i] = X[i] ^ X[i - 1]
    t = jnp.zeros((n,), dtype=jnp.uint32)
    Q = 1 << (bits - 1)
    while Q > 1:
        Qm = jnp.uint32(Q)
        t = jnp.where((X[d - 1] & Qm) != 0, t ^ jnp.uint32(Q - 1), t)
        Q >>= 1
    for i in range(d):
        X[i] = X[i] ^ t
    del M
    return jnp.stack(X, axis=1)


def hilbert_key(
    points: jax.Array,
    bits: int | None = None,
    *,
    stats: Stats = "geometric",
    words: int = 1,
) -> jax.Array:
    """Hilbert-like SFC keys for (n, d) points (paper §III-B).

    ``stats='rank'`` gives the paper's distribution-aware variant for
    clustered data / unstructured meshes.
    """
    n, d = points.shape
    if bits is None:
        bits = max_bits_per_dim(d, words)
    assert bits * d <= 32 * words
    q = quantize(points, bits, stats)
    tq = _hilbert_transpose(q, bits)
    keys = _interleave(tq, bits, words)
    return keys[:, 0] if words == 1 else keys


def hilbert_key_from_cells(q: jax.Array, bits: int, *, words: int = 1) -> jax.Array:
    """Hilbert keys directly from pre-quantized uint32 cells (n, d)."""
    tq = _hilbert_transpose(q, bits)
    keys = _interleave(tq, bits, words)
    return keys[:, 0] if words == 1 else keys


def morton_key_from_cells(q: jax.Array, bits: int, *, words: int = 1) -> jax.Array:
    """Morton keys directly from pre-quantized uint32 cells (n, d)."""
    keys = _interleave(q, bits, words)
    return keys[:, 0] if words == 1 else keys


# ---------------------------------------------------------------------------
# Key ordering helpers
# ---------------------------------------------------------------------------

def argsort_keys(keys: jax.Array) -> jax.Array:
    """Stable argsort for single-word (n,) or multi-word (n, w) keys."""
    if keys.ndim == 1:
        return jnp.argsort(keys, stable=True)
    # lexicographic, hi word first: sort by last word, then next, ...
    order = jnp.argsort(keys[:, -1], stable=True)
    for w in range(keys.shape[1] - 2, -1, -1):
        order = order[jnp.argsort(keys[order, w], stable=True)]
    return order


def sfc_order(
    points: jax.Array,
    *,
    curve: Literal["morton", "hilbert"] = "morton",
    bits: int | None = None,
    stats: Stats = "geometric",
    words: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Return (perm, keys): permutation of point ids in SFC order + keys."""
    fn = morton_key if curve == "morton" else hilbert_key
    keys = fn(points, bits, stats=stats, words=words)
    return argsort_keys(keys), keys


@functools.partial(jax.jit, static_argnames=("bits",))
def point_key_morton3d(points: jax.Array, lo: jax.Array, hi: jax.Array, bits: int = 10):
    """Convenience: Morton key of query points against a fixed bbox (used by
    point location, which must quantize queries with the *tree's* bbox)."""
    return keys_in_frame(points, lo, hi, bits=bits, curve="morton")


def locality_score(points: jax.Array, perm: jax.Array) -> jax.Array:
    """Mean Euclidean jump between successive points along the curve.

    Lower is better spatial locality; used to validate Hilbert < Morton
    (paper: 'SFCs produced by Hilbert-like curves have better spatial
    locality').
    """
    p = points[perm]
    return jnp.mean(jnp.linalg.norm(p[1:] - p[:-1], axis=-1))
