"""Data migration with bounded message sizes (paper §III-C, transfer_t_l_t).

The paper exchanges data in *rounds*, capping the largest message at
MAX_MSG_SIZE to bound buffer memory and avoid network congestion. On TPU
the analogue is a sequence of fixed-capacity ``all_to_all`` chunks. This
module computes the plan (who sends how much to whom, in how many rounds)
and provides both a host-side simulator (used by tests/benchmarks to
check conservation and round counts) and a shard_map executor.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro import compat as _compat
import numpy as np


@dataclass(frozen=True)
class MigrationPlan:
    send_counts: np.ndarray   # (P, P) elements moving src -> dst
    rounds: int               # number of bounded all_to_all rounds
    chunk: int                # per-pair element capacity per round
    total_moved: int
    max_pair: int

    @property
    def stay_fraction(self) -> float:
        total = self.send_counts.sum()
        stay = np.trace(self.send_counts)
        return float(stay) / max(float(total), 1.0)


@dataclass(frozen=True)
class HierarchicalMigrationPlan:
    """Level-aware exchange plan over a node -> device hierarchy.

    Parts group into nodes of ``devices_per_node`` consecutive ids
    (``part = node * D + device``, the `partitioner.HierarchyPlan`
    layout). Moves inside a node's diagonal block ride the fast
    intra-node fabric; off-block moves cross the node boundary, where
    every byte costs ``inter_node_cost`` times as much — so the
    MAX_MSG_SIZE round capping is applied per level, with the inter-node
    chunk shrunk by the multiplier (same byte budget on a costlier
    link). The two levels schedule independently (disjoint fabrics):
    ``rounds`` is their max, not their sum.
    """

    send_counts: np.ndarray   # (P, P) elements moving src part -> dst part
    num_nodes: int
    devices_per_node: int
    inter_node_cost: float
    chunk: int                # intra-node per-pair capacity per round
    inter_chunk: int          # inter-node per-pair capacity per round
    intra_rounds: int
    inter_rounds: int
    intra_moved: int          # moved within a node (off-diagonal, same block)
    inter_moved: int          # moved across nodes (off-block)
    max_intra_pair: int
    max_inter_pair: int

    @property
    def rounds(self) -> int:
        return max(self.intra_rounds, self.inter_rounds)

    @property
    def total_moved(self) -> int:
        return self.intra_moved + self.inter_moved

    @property
    def max_pair(self) -> int:
        return max(self.max_intra_pair, self.max_inter_pair)

    @property
    def stay_fraction(self) -> float:
        """Device level: fraction not moving at all (diagonal)."""
        total = self.send_counts.sum()
        return float(np.trace(self.send_counts)) / max(float(total), 1.0)

    @property
    def stay_fraction_node(self) -> float:
        """Node level: fraction staying on its node (diagonal blocks) —
        what a hierarchy-aware re-slice keeps high under small drift."""
        total = self.send_counts.sum()
        stay = total - self.inter_moved
        return float(stay) / max(float(total), 1.0)

    def cost(self, bytes_per_elem: int = 16) -> float:
        """Weighted byte cost: intra bytes + multiplier * inter bytes —
        the objective a level-aware migration minimizes."""
        return bytes_per_elem * (
            self.intra_moved + self.inter_node_cost * self.inter_moved
        )


def _node_block_mask(num_parts: int, devices_per_node: int) -> np.ndarray:
    node_of = np.arange(num_parts) // max(1, devices_per_node)
    return node_of[:, None] == node_of[None, :]


def plan_from_counts(
    send: np.ndarray,
    *,
    max_msg_bytes: int = 4 << 20,
    bytes_per_elem: int = 16,
    hierarchy=None,
    inter_node_cost: float | None = None,
) -> "MigrationPlan | HierarchicalMigrationPlan":
    """Build the round schedule from a precomputed (P, P) count matrix
    (e.g. one reduced on-device by the repartitioning engine).

    With ``hierarchy`` (a `partitioner.HierarchyPlan`, or anything with
    ``num_nodes`` / ``devices_per_node`` / ``inter_node_cost``), the plan
    is level-aware: intra-node and inter-node pairs are capped into
    rounds separately, and the inter-node per-round chunk is divided by
    the cost multiplier (``inter_node_cost`` overrides the hierarchy's)
    so the bounded message honors the same byte budget on the costlier
    link. ``num_parts`` must equal the hierarchy's ``num_nodes *
    devices_per_node``.
    """
    send = np.asarray(send, dtype=np.int64)
    off_diag = send.copy()
    np.fill_diagonal(off_diag, 0)
    chunk = max(1, max_msg_bytes // bytes_per_elem)
    if hierarchy is None:
        max_pair = int(off_diag.max()) if off_diag.size else 0
        rounds = int(np.ceil(max_pair / chunk)) if max_pair else 0
        return MigrationPlan(
            send_counts=send,
            rounds=rounds,
            chunk=chunk,
            total_moved=int(off_diag.sum()),
            max_pair=max_pair,
        )
    N, D = int(hierarchy.num_nodes), int(hierarchy.devices_per_node)
    if send.shape[0] != N * D:
        raise ValueError(
            f"count matrix is {send.shape[0]}x{send.shape[0]}, hierarchy "
            f"expects {N} nodes x {D} devices = {N * D} parts"
        )
    mult = float(
        hierarchy.inter_node_cost if inter_node_cost is None else inter_node_cost
    )
    if mult < 1.0:
        raise ValueError(f"inter_node_cost must be >= 1, got {mult}")
    same_node = _node_block_mask(N * D, D)
    intra = np.where(same_node, off_diag, 0)
    inter = np.where(same_node, 0, off_diag)
    max_intra = int(intra.max()) if intra.size else 0
    max_inter = int(inter.max()) if inter.size else 0
    inter_chunk = max(1, int(max_msg_bytes / (bytes_per_elem * mult)))
    return HierarchicalMigrationPlan(
        send_counts=send,
        num_nodes=N,
        devices_per_node=D,
        inter_node_cost=mult,
        chunk=chunk,
        inter_chunk=inter_chunk,
        intra_rounds=int(np.ceil(max_intra / chunk)) if max_intra else 0,
        inter_rounds=int(np.ceil(max_inter / inter_chunk)) if max_inter else 0,
        intra_moved=int(intra.sum()),
        inter_moved=int(inter.sum()),
        max_intra_pair=max_intra,
        max_inter_pair=max_inter,
    )


def migration_plan(
    old_part: np.ndarray,
    new_part: np.ndarray,
    num_parts: int,
    *,
    max_msg_bytes: int = 4 << 20,
    bytes_per_elem: int = 16,
    hierarchy=None,
) -> "MigrationPlan | HierarchicalMigrationPlan":
    """Count matrix + round schedule honoring MAX_MSG_SIZE — the ONE
    assignment-pair -> count-matrix builder; all schedule semantics
    (including the level-aware ``hierarchy`` mode) live in
    `plan_from_counts`."""
    send = np.zeros((num_parts, num_parts), dtype=np.int64)
    np.add.at(send, (np.asarray(old_part), np.asarray(new_part)), 1)
    return plan_from_counts(
        send,
        max_msg_bytes=max_msg_bytes,
        bytes_per_elem=bytes_per_elem,
        hierarchy=hierarchy,
    )


def neighbor_locality(plan: MigrationPlan) -> float:
    """Fraction of moved elements that travel to a rank-adjacent part.

    The paper's incremental load balancing claims migration is restricted
    to P±1 neighbors for small load deltas; tests assert this is 1.0 after
    an `incremental_reslice` with modest weight changes.
    """
    P = plan.send_counts.shape[0]
    moved = 0
    near = 0
    for s in range(P):
        for d in range(P):
            if s == d:
                continue
            moved += plan.send_counts[s, d]
            if abs(s - d) == 1:
                near += plan.send_counts[s, d]
    return float(near) / max(float(moved), 1.0)


def simulate_rounds(plan: "MigrationPlan | HierarchicalMigrationPlan") -> list[np.ndarray]:
    """Split the send matrix into per-round matrices, each pair <= its
    level's chunk. Hierarchical plans cap intra-node pairs at ``chunk``
    and inter-node pairs at the multiplier-shrunk ``inter_chunk`` — the
    two fabrics schedule independently, so round r carries both levels'
    r-th bounded message."""
    remaining = plan.send_counts.copy()
    np.fill_diagonal(remaining, 0)
    if isinstance(plan, HierarchicalMigrationPlan):
        same_node = _node_block_mask(plan.send_counts.shape[0], plan.devices_per_node)
        cap = np.where(same_node, plan.chunk, plan.inter_chunk)
    else:
        cap = np.full(remaining.shape, plan.chunk, dtype=np.int64)
    out = []
    for _ in range(plan.rounds):
        step = np.minimum(remaining, cap)
        out.append(step)
        remaining -= step
    assert remaining.sum() == 0 or plan.rounds == 0
    return out


def execute_shard_exchange(
    mesh: jax.sharding.Mesh,
    axis: str,
    payload: jax.Array,
    dest: jax.Array,
    capacity: int,
    fill_value=0,
):
    """shard_map executor: move rows of ``payload`` (sharded on dim 0 over
    ``axis``) to the shard given by ``dest`` using one padded all_to_all.

    Returns (received_payload (nshards*capacity, ...), valid_mask). The
    caller picks ``capacity`` from the migration plan (chunk size); calling
    this in a loop over rounds gives the paper's bounded-message exchange.
    """
    return _exchange_fn(mesh, axis, capacity, fill_value)(payload, dest)


def stage_rows_by_dest(
    dest: jax.Array,
    payloads: tuple,
    nshards: int,
    capacity: int,
    fills: tuple,
) -> list:
    """Stage local rows into fixed-capacity (nshards, capacity, ...) lane
    buffers by destination shard — the shared body of every padded
    all_to_all exchange (payload migration, query routing). Must be
    called inside shard_map; rows beyond a lane's capacity are dropped
    (callers size capacity so that cannot happen, or assert conservation).

    Returns (staged buffers, one per payload; per-ORIGINAL-row staging
    position). Row i went to buffer slot [dest[i], pos[i]] — a caller
    exchanging answers back can therefore gather its own results locally
    from the reply buffer instead of round-tripping slot ids."""
    n_loc = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    ds = dest[order]
    pos = jnp.arange(n_loc, dtype=jnp.int32) - jnp.searchsorted(
        ds, jnp.arange(nshards, dtype=ds.dtype)
    ).astype(jnp.int32)[ds]
    out = []
    for x, fill in zip(payloads, fills):
        buf = jnp.full((nshards, capacity) + x.shape[1:], fill, x.dtype)
        out.append(buf.at[ds, pos].set(x[order], mode="drop"))
    pos_of_row = jnp.zeros((n_loc,), jnp.int32).at[order].set(pos)
    return out, pos_of_row


@functools.lru_cache(maxsize=64)
def _exchange_fn(mesh: jax.sharding.Mesh, axis: str, capacity: int, fill_value):
    """Jitted exchange executor, memoized per static config. shard_map'd
    callables must run under jit — eager execution dispatches every traced
    op as its own SPMD program (see partitioner._reslice_fn)."""
    from jax.sharding import PartitionSpec as P

    nshards = mesh.shape[axis]

    def kernel(x, d):
        (buf, val), _ = stage_rows_by_dest(
            d, (x, jnp.ones(d.shape, bool)), nshards, capacity, (fill_value, False)
        )
        rbuf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0)
        rval = jax.lax.all_to_all(val, axis, split_axis=0, concat_axis=0)
        return rbuf.reshape((-1,) + x.shape[1:]), rval.reshape(-1)

    return jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_vma=False,
    ))
