"""Partition-quality metrics (paper §II and §V-B tables).

* load balance: AvgLoad / MaxLoad (paper Tables II-VII columns).
* MaxDegree: max over parts of the number of distinct neighbor parts a
  part communicates with (number of messages).
* MaxEdgeCut: max over parts of the summed weight of its outgoing cut
  edges (communication volume), eq. (1) of the paper.
* load imbalance: max_i,j (w_i - w_j), eq. (2).
* surface-to-volume proxy for point sets: fraction of k-NN edges that
  cross partitions (detects the "misshapen partitions" of §IV).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("num_parts",))
def loads(part: jax.Array, weights: jax.Array, num_parts: int) -> jax.Array:
    return jax.ops.segment_sum(weights.astype(jnp.float32), part, num_segments=num_parts)


@functools.partial(jax.jit, static_argnames=("num_parts",))
def load_imbalance(part: jax.Array, weights: jax.Array, num_parts: int) -> jax.Array:
    """Paper eq. (2): max pairwise load difference."""
    ld = loads(part, weights, num_parts)
    return jnp.max(ld) - jnp.min(ld)


def edge_metrics(
    part: np.ndarray,
    edges_src: np.ndarray,
    edges_dst: np.ndarray,
    num_parts: int,
    edge_weights: np.ndarray | None = None,
) -> dict:
    """MaxDegree / MaxEdgeCut / TotalCut over a directed edge list.

    Host-side numpy (benchmark/reporting path, not the training hot loop).
    """
    ps = part[edges_src]
    pd = part[edges_dst]
    cut = ps != pd
    if edge_weights is None:
        edge_weights = np.ones(edges_src.shape[0], dtype=np.float64)
    # outgoing cut volume per part (paper's e_i)
    e = np.bincount(ps[cut], weights=edge_weights[cut], minlength=num_parts)
    # distinct neighbor parts per part
    pairs = np.unique(np.stack([ps[cut], pd[cut]], axis=1), axis=0)
    deg = np.bincount(pairs[:, 0], minlength=num_parts)
    return {
        "MaxEdgeCut": float(e.max()) if e.size else 0.0,
        "TotalCut": float(e.sum()),
        "MaxDegree": int(deg.max()) if deg.size else 0,
        "AvgDegree": float(deg.mean()) if deg.size else 0.0,
    }


def partition_report(
    part: np.ndarray,
    weights: np.ndarray,
    num_parts: int,
    edges: tuple[np.ndarray, np.ndarray] | None = None,
) -> dict:
    ld = np.bincount(part, weights=weights, minlength=num_parts)
    rep = {
        "AvgLoad": float(ld.mean()),
        "MaxLoad": float(ld.max()),
        "MinLoad": float(ld.min()),
        "Imbalance": float(ld.max() - ld.min()),
    }
    if edges is not None:
        rep.update(edge_metrics(part, edges[0], edges[1], num_parts))
    return rep


def knn_cross_fraction(
    points: np.ndarray, part: np.ndarray, k: int = 6, sample: int = 2048, seed: int = 0
) -> float:
    """Surface-to-volume proxy: fraction of k-NN edges crossing partitions.

    Sampled, brute-force on the host — this is a *diagnostic* (paper §IV:
    detect misshapen partitions and trigger a full rebalance).
    """
    rng = np.random.default_rng(seed)
    n = points.shape[0]
    ids = rng.choice(n, size=min(sample, n), replace=False)
    cross = 0
    total = 0
    for i in ids:
        d2 = np.sum((points - points[i]) ** 2, axis=1)
        nn = np.argpartition(d2, k + 1)[: k + 1]
        nn = nn[nn != i][:k]
        cross += int((part[nn] != part[i]).sum())
        total += len(nn)
    return cross / max(total, 1)
