"""Partition-quality metrics (paper §II and §V-B tables).

* load balance: AvgLoad / MaxLoad (paper Tables II-VII columns).
* MaxDegree: max over parts of the number of distinct neighbor parts a
  part communicates with (number of messages).
* MaxEdgeCut: max over parts of the summed weight of its outgoing cut
  edges (communication volume), eq. (1) of the paper.
* load imbalance: max_i,j (w_i - w_j), eq. (2).
* surface-to-volume proxy for point sets: fraction of k-NN edges that
  cross partitions (detects the "misshapen partitions" of §IV).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("num_parts",))
def loads(part: jax.Array, weights: jax.Array, num_parts: int) -> jax.Array:
    return jax.ops.segment_sum(weights.astype(jnp.float32), part, num_segments=num_parts)


@functools.partial(jax.jit, static_argnames=("num_parts",))
def load_imbalance(part: jax.Array, weights: jax.Array, num_parts: int) -> jax.Array:
    """Paper eq. (2): max pairwise load difference."""
    ld = loads(part, weights, num_parts)
    return jnp.max(ld) - jnp.min(ld)


def edge_metrics(
    part: np.ndarray,
    edges_src: np.ndarray,
    edges_dst: np.ndarray,
    num_parts: int,
    edge_weights: np.ndarray | None = None,
) -> dict:
    """MaxDegree / MaxEdgeCut / TotalCut over a directed edge list.

    Host-side numpy (benchmark/reporting path, not the training hot loop).
    """
    ps = part[edges_src]
    pd = part[edges_dst]
    cut = ps != pd
    if edge_weights is None:
        edge_weights = np.ones(edges_src.shape[0], dtype=np.float64)
    # outgoing cut volume per part (paper's e_i)
    e = np.bincount(ps[cut], weights=edge_weights[cut], minlength=num_parts)
    # distinct neighbor parts per part
    pairs = np.unique(np.stack([ps[cut], pd[cut]], axis=1), axis=0)
    deg = np.bincount(pairs[:, 0], minlength=num_parts)
    return {
        "MaxEdgeCut": float(e.max()) if e.size else 0.0,
        "TotalCut": float(e.sum()),
        "MaxDegree": int(deg.max()) if deg.size else 0,
        "AvgDegree": float(deg.mean()) if deg.size else 0.0,
    }


def partition_report(
    part: np.ndarray,
    weights: np.ndarray,
    num_parts: int,
    edges: tuple[np.ndarray, np.ndarray] | None = None,
) -> dict:
    ld = np.bincount(part, weights=weights, minlength=num_parts)
    rep = {
        "AvgLoad": float(ld.mean()),
        "MaxLoad": float(ld.max()),
        "MinLoad": float(ld.min()),
        "Imbalance": float(ld.max() - ld.min()),
    }
    if edges is not None:
        rep.update(edge_metrics(part, edges[0], edges[1], num_parts))
    return rep


def spanning_communication_metrics(
    part: np.ndarray,
    needs: np.ndarray,
    prod: np.ndarray,
    owner: np.ndarray,
    num_parts: int,
) -> dict:
    """Paper Tables II–VII metrics from a chunked communication structure.

    THE one implementation of the AvgLoad / MaxLoad / MaxDegree /
    MaxEdgeCut table columns — mesh, graph and SpMV all report through
    it (``spmv.communication_metrics`` is a thin wrapper that derives
    ``needs``/``prod``/``owner`` from a nonzero partition first).

    ``needs[p, c]`` / ``prod[p, c]`` count the distinct entries of chunk
    ``c`` that process ``p`` consumes / produces; ``owner[c]`` is the
    process owning chunk ``c`` (the spanning set). Process ``p``
    exchanges with ``owner(c)`` for every chunk it needs or produces and
    does not own; MaxDegree is the max number of distinct partners and
    MaxEdgeCut the max per-process exchanged volume (paper eq. (1)).
    """
    P = int(num_parts)
    vol = np.zeros(P, dtype=np.int64)
    partners: list[set] = [set() for _ in range(P)]
    for c in range(P):
        o = owner[c]
        for p in range(P):
            if p == o:
                continue
            x_vol = needs[p, c]
            y_vol = prod[p, c]
            if x_vol > 0 or y_vol > 0:
                vol[p] += x_vol + y_vol
                partners[p].add(o)
                partners[o].add(p)
    load = np.bincount(part, minlength=P).astype(np.int64)
    deg = np.array([len(s) for s in partners])
    return {
        "AvgLoad": int(load.mean()),
        "MaxLoad": int(load.max()),
        "MaxDegree": int(deg.max()) if P > 0 else 0,
        "MaxEdgeCut": int(vol.max()) if P > 0 else 0,
        "TotalVolume": int(vol.sum()),
        "owner": owner,
    }


def surface_index(owned_counts: np.ndarray, ghost_counts: np.ndarray) -> dict:
    """Surface-to-volume quality of a mesh partition's halo.

    ``owned_counts[p]`` / ``ghost_counts[p]`` are the owned and ghost
    (halo) cell counts of part ``p``. The surface index — ghosts over
    owned, the communication-to-computation ratio of one stencil sweep —
    is the mesh analogue of the kNN cross fraction below: a misshapen
    SFC slice shows up as a part whose halo rivals its interior.
    """
    owned = np.asarray(owned_counts, dtype=np.float64)
    ghost = np.asarray(ghost_counts, dtype=np.float64)
    si = ghost / np.maximum(owned, 1.0)
    return {
        "MaxSurfaceIndex": float(si.max()) if si.size else 0.0,
        "AvgSurfaceIndex": float(si.mean()) if si.size else 0.0,
        "TotalGhosts": int(ghost.sum()),
        "MaxGhosts": int(ghost.max()) if ghost.size else 0,
    }


def knn_cross_fraction(
    points: np.ndarray, part: np.ndarray, k: int = 6, sample: int = 2048, seed: int = 0
) -> float:
    """Surface-to-volume proxy: fraction of k-NN edges crossing partitions.

    Sampled, brute-force on the host — this is a *diagnostic* (paper §IV:
    detect misshapen partitions and trigger a full rebalance).
    """
    rng = np.random.default_rng(seed)
    n = points.shape[0]
    ids = rng.choice(n, size=min(sample, n), replace=False)
    cross = 0
    total = 0
    for i in ids:
        d2 = np.sum((points - points[i]) ** 2, axis=1)
        nn = np.argpartition(d2, k + 1)[: k + 1]
        nn = nn[nn != i][:k]
        cross += int((part[nn] != part[i]).sum())
        total += len(nn)
    return cross / max(total, 1)
