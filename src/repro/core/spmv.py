"""General graph partitioning + distributed SpMV (paper §V-B).

A graph's adjacency matrix is partitioned by treating each nonzero (i, j)
as a 2-D point and running the SFC partitioner; the dense vector is
greedily partitioned into contiguous *owned* chunks. Every process derives
its *dependent* vector intervals from its nonzero set; partial products
are combined with reduce-scatter over per-chunk communication trees. A
one-pass *spanning set* improvement re-assigns chunk ownership to the
process with maximum overlap (ties -> min id), exactly as in the paper.

Reported metrics (paper Tables II–VII): AvgLoad, MaxLoad, MaxDegree (max
messages per process), MaxEdgeCut (max communication volume per process).
Baseline: row-wise decomposition (fixed rows per process).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro import compat as _compat
import numpy as np


@dataclass(frozen=True)
class SparsePartition:
    part_of_nnz: np.ndarray    # (nnz,) process owning each nonzero
    chunk_owner: np.ndarray    # (P,) process owning x-chunk c (spanning set)
    chunk_bounds: np.ndarray   # (P+1,) x index boundaries of chunks
    num_parts: int


# ---------------------------------------------------------------------------
# Partitioning strategies
# ---------------------------------------------------------------------------

def rowwise_partition(rows: np.ndarray, n: int, num_parts: int) -> np.ndarray:
    """Baseline: fixed number of rows per process."""
    rows_per = int(np.ceil(n / num_parts))
    return np.minimum(rows // rows_per, num_parts - 1).astype(np.int32)


def sfc_partition(
    rows: np.ndarray,
    cols: np.ndarray,
    n: int,
    num_parts: int,
    *,
    curve: str | None = None,
    weights: np.ndarray | None = None,
    cfg: "object | None" = None,
) -> np.ndarray:
    """SFC partition of nonzeros as 2-D points (row, col).

    Routed through ``partitioner.partition`` — SpMV rides the shared
    pipeline (Pallas key-gen kernels via ``cfg.use_pallas``, the bucket
    tree path via ``cfg.use_tree``) instead of a private key-gen →
    argsort → knapsack copy. ``cfg`` replaces the default 16-bit
    configuration wholesale (including its curve), so combining it with
    an explicit ``curve=`` is a conflict and raises — pass the curve
    inside the cfg instead. ``curve`` alone defaults to "hilbert"."""
    from repro.core import partitioner as _pt

    if cfg is not None and curve is not None:
        raise ValueError(
            "sfc_partition: pass either curve= or cfg=, not both — cfg "
            f"replaces the whole configuration (cfg.curve={cfg.curve!r} "
            f"would silently win over curve={curve!r})"
        )
    pts = jnp.stack(
        [jnp.asarray(rows, jnp.float32), jnp.asarray(cols, jnp.float32)], axis=1
    )
    if cfg is None:
        cfg = _pt.PartitionerConfig(curve=curve or "hilbert", bits=16)
    w = None if weights is None else jnp.asarray(weights, jnp.float32)
    res = _pt.partition(pts, w, num_parts, cfg)
    return np.asarray(res.part)


def vector_chunks(n: int, num_parts: int) -> np.ndarray:
    """Contiguous, load-balanced owned chunks of the dense vector."""
    return (np.arange(num_parts + 1) * n) // num_parts


# ---------------------------------------------------------------------------
# Communication structure + spanning-set improvement
# ---------------------------------------------------------------------------

def _needs_matrix(
    part: np.ndarray, rows: np.ndarray, cols: np.ndarray, chunk_bounds: np.ndarray,
    num_parts: int,
) -> tuple[np.ndarray, np.ndarray]:
    """needs[p, c] = # distinct x entries of chunk c needed by process p;
    prod[p, c] = # distinct y entries of chunk c produced by process p."""
    chunk_of = lambda idx: np.searchsorted(chunk_bounds, idx, side="right") - 1
    col_chunk = chunk_of(cols)
    row_chunk = chunk_of(rows)
    needs = np.zeros((num_parts, num_parts), dtype=np.int64)
    prod = np.zeros((num_parts, num_parts), dtype=np.int64)
    # distinct (p, chunk, col) triples
    pc = np.unique(np.stack([part, col_chunk, cols], axis=1), axis=0)
    np.add.at(needs, (pc[:, 0], pc[:, 1]), 1)
    pr = np.unique(np.stack([part, row_chunk, rows], axis=1), axis=0)
    np.add.at(prod, (pr[:, 0], pr[:, 1]), 1)
    return needs, prod


def improve_spanning_set(
    needs: np.ndarray, prod: np.ndarray, num_parts: int
) -> np.ndarray:
    """One improvement pass (paper): chunk c is owned by the process with
    maximum overlap (needs + produces); ties broken by minimum id."""
    overlap = needs + prod  # (P, C)
    owner = np.argmax(overlap, axis=0).astype(np.int32)  # argmax → min id on ties
    return owner


def communication_metrics(
    part: np.ndarray,
    rows: np.ndarray,
    cols: np.ndarray,
    n: int,
    num_parts: int,
    *,
    improve: bool = True,
) -> dict:
    """Paper Tables II–VII metrics for a given nonzero partition.

    Thin wrapper: derives the chunked communication structure (needs /
    produces / spanning-set owner) from the nonzero partition, then
    reports through the shared ``metrics.spanning_communication_metrics``
    implementation (one table-metric code path for mesh, graph, SpMV).
    """
    from repro.core import metrics as _metrics

    chunk_bounds = vector_chunks(n, num_parts)
    needs, prod = _needs_matrix(part, rows, cols, chunk_bounds, num_parts)
    owner = (
        improve_spanning_set(needs, prod, num_parts)
        if improve
        else np.arange(num_parts, dtype=np.int32)
    )
    return _metrics.spanning_communication_metrics(part, needs, prod, owner, num_parts)


# ---------------------------------------------------------------------------
# Executable distributed SpMV (shard_map reduce-scatter)
# ---------------------------------------------------------------------------

def spmv_reference(rows, cols, vals, x, n):
    """Dense oracle y = A x."""
    y = jnp.zeros((n,), dtype=jnp.result_type(vals, x))
    return y.at[jnp.asarray(rows)].add(jnp.asarray(vals) * x[jnp.asarray(cols)])


def distributed_spmv(
    mesh: jax.sharding.Mesh,
    axis: str,
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    part: np.ndarray,
    x: jax.Array,
    n: int,
):
    """Execute y = A x with nonzeros distributed per ``part``.

    Each shard computes partial sums for its nonzeros, then a
    reduce-scatter (psum_scatter) combines partials and leaves each shard
    its owned y-chunk — the paper's reduce + scatter of vector
    subintervals. nnz lists are padded to equal length per shard.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    nshards = mesh.shape[axis]
    # pad each shard's nnz to the max count
    counts = np.bincount(part, minlength=nshards)
    cap = int(counts.max())
    r_p = np.zeros((nshards, cap), dtype=np.int32)
    c_p = np.zeros((nshards, cap), dtype=np.int32)
    v_p = np.zeros((nshards, cap), dtype=np.float32)
    for p in range(nshards):
        sel = part == p
        k = int(sel.sum())
        r_p[p, :k] = rows[sel]
        c_p[p, :k] = cols[sel]
        v_p[p, :k] = vals[sel]  # padding has val=0 → no contribution

    n_pad = int(np.ceil(n / nshards)) * nshards
    sh = NamedSharding(mesh, P(axis))
    r_d = jax.device_put(jnp.asarray(r_p).reshape(nshards * cap), sh)
    c_d = jax.device_put(jnp.asarray(c_p).reshape(nshards * cap), sh)
    v_d = jax.device_put(jnp.asarray(v_p).reshape(nshards * cap), sh)
    x_pad = jnp.zeros((n_pad,), jnp.float32).at[:n].set(x)

    def kernel(r, c, v, xf):
        y_partial = jnp.zeros((n_pad,), jnp.float32).at[r].add(v * xf[c])
        mine = jax.lax.psum_scatter(y_partial, axis, scatter_dimension=0, tiled=True)
        return mine

    # shard_map must run under jit: eager execution dispatches every
    # traced op as its own SPMD program (see partitioner._reslice_fn)
    fn = jax.jit(_compat.shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=P(axis),
        check_vma=False,
    ))
    y = fn(r_d, c_d, v_d, x_pad)
    return y[:n]


# ---------------------------------------------------------------------------
# Synthetic power-law graphs (SNAP stand-ins; offline container)
# ---------------------------------------------------------------------------

def powerlaw_graph(
    n: int, avg_degree: int, alpha: float = 2.1, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Directed power-law graph in COO (rows, cols), no self loops.

    Zipf out-degrees (the paper's social-network test cases follow the
    power law [23]); endpoints preferentially attached by degree weight.
    """
    rng = np.random.default_rng(seed)
    raw = rng.zipf(alpha, size=n).astype(np.int64)
    deg = np.minimum(raw * avg_degree // max(int(raw.mean()), 1), n // 2)
    deg = np.maximum(deg, 1)
    src = np.repeat(np.arange(n), deg)
    # preferential attachment for destinations
    w = deg.astype(np.float64) / deg.sum()
    dst = rng.choice(n, size=src.shape[0], p=w)
    keep = src != dst
    return src[keep].astype(np.int32), dst[keep].astype(np.int32)
