"""Adaptive (quadtree / octree) cell meshes — the paper's mesh workload.

The paper's software "was primarily used for partitioning 2 and 3
dimensional meshes in scientific computing" whose load distribution
changes over time. This module is that workload generator: a dyadic cell
mesh over the unit box, represented as *weighted center points* — the
exact input type of the partition core — with vectorized refine /
coarsen steps that track a moving load feature, so cell count and
weights change every timestep.

Cell addressing is purely integer: a cell is ``(level, ij)`` with
``ij in [0, 2**level)^d``; its center and extent follow in closed form,
so the whole mesh is a handful of numpy arrays and every operation
(refinement, neighbor derivation, transfer-map construction) is a
vectorized key lookup — no per-cell Python objects, no pointers.

Invariants maintained by :func:`refine_coarsen`:

* **2:1 balance** — face neighbors differ by at most one level (the
  graded-tree property every AMR halo scheme assumes; enforced by a
  refinement ripple and a conservative coarsening guard).
* **exact tiling** — active cells tile the unit box exactly (cell
  volumes are dyadic, so the conservation check is exact in float64).
* **deterministic transfer** — refine injects the parent value into its
  2^d children, coarsen averages the 2^d children in fixed child order;
  :func:`apply_transfer` is the ONE implementation both the distributed
  simulation and the single-device reference use, which is what makes
  their trajectories bit-comparable.

Cell *identity* across steps is storage-slot ids inside a
`repro.core.repartition.Repartitioner`, tracked by the DRIVER
(`mesh/simulate`), not by the mesh: trajectory meshes are shared,
immutable inputs to every backend, so driver-specific engine state
never lives on them. `Transfer.born`/`died_idx` carry the structural
bookkeeping the driver needs to keep its slot array current.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# bits reserved per dimension in the packed (level, ij) cell key; caps
# max_level at 20 which is far beyond any mesh this module drives
_COORD_BITS = 20


@dataclass(frozen=True)
class AMRMesh:
    """A dyadic cell mesh over the unit box ``[0, 1]^d``."""

    level: np.ndarray   # (n,) int32 refinement level per active cell
    ij: np.ndarray      # (n, d) int64 integer coords in [0, 2**level)^d
    base_level: int     # coarsest allowed level (the initial uniform grid)
    max_level: int      # finest allowed level

    @property
    def n(self) -> int:
        return int(self.level.shape[0])

    @property
    def d(self) -> int:
        return int(self.ij.shape[1])

    def centers(self) -> np.ndarray:
        """(n, d) float32 cell centers — the partitioner's point set."""
        h = (0.5 ** self.level.astype(np.float64))[:, None]
        return ((self.ij.astype(np.float64) + 0.5) * h).astype(np.float32)

    def sizes(self) -> np.ndarray:
        """(n,) float32 cell side lengths."""
        return (0.5 ** self.level.astype(np.float64)).astype(np.float32)

    def volumes(self) -> np.ndarray:
        """(n,) float64 cell volumes (dyadic — exact)."""
        return 0.5 ** (self.d * self.level.astype(np.float64))


def uniform_mesh(d: int = 2, base_level: int = 3, max_level: int = 6) -> AMRMesh:
    """Uniform mesh of ``2**(d*base_level)`` cells at ``base_level``."""
    if not (0 <= base_level <= max_level <= _COORD_BITS):
        raise ValueError(f"bad levels base={base_level} max={max_level}")
    # the packed key shifts level above d * _COORD_BITS bits; a level that
    # does not fit the remaining signed-int64 headroom would alias other
    # cells' keys and make _CellLookup return unrelated neighbors
    if max_level >= 1 << (63 - d * _COORD_BITS):
        raise ValueError(
            f"max_level={max_level} overflows the packed cell key for d={d} "
            f"(limit {(1 << (63 - d * _COORD_BITS)) - 1})"
        )
    side = 1 << base_level
    grids = np.meshgrid(*([np.arange(side, dtype=np.int64)] * d), indexing="ij")
    ij = np.stack([g.reshape(-1) for g in grids], axis=1)
    n = ij.shape[0]
    return AMRMesh(
        level=np.full((n,), base_level, np.int32),
        ij=ij,
        base_level=base_level,
        max_level=max_level,
    )


# ---------------------------------------------------------------------------
# packed-key lookup (the vectorized replacement for a pointer tree)
# ---------------------------------------------------------------------------

def _pack(level: np.ndarray, ij: np.ndarray) -> np.ndarray:
    """Unique int64 key per (level, ij) cell."""
    key = level.astype(np.int64)
    for a in range(ij.shape[1]):
        key = (key << _COORD_BITS) | ij[:, a].astype(np.int64)
    return key


class _CellLookup:
    """Sorted-key index: (level, ij) -> position in the mesh's cell order."""

    def __init__(self, level: np.ndarray, ij: np.ndarray):
        keys = _pack(level, ij)
        self.order = np.argsort(keys)
        self.keys = keys[self.order]

    def find(self, level: np.ndarray, ij: np.ndarray) -> np.ndarray:
        """(k,) int64 cell index per query, -1 where absent."""
        q = _pack(level, ij)
        if self.keys.shape[0] == 0:
            return np.full(q.shape, -1, np.int64)
        pos = np.searchsorted(self.keys, q)
        pos_c = np.minimum(pos, self.keys.shape[0] - 1)
        hit = self.keys[pos_c] == q
        return np.where(hit, self.order[pos_c], -1)


def _child_offsets(d: int) -> np.ndarray:
    """(2**d, d) int64 child coordinate offsets in fixed binary order —
    the deterministic sibling order every transfer map relies on."""
    k = 1 << d
    offs = np.zeros((k, d), np.int64)
    for c in range(k):
        for a in range(d):
            offs[c, a] = (c >> (d - 1 - a)) & 1
    return offs


# ---------------------------------------------------------------------------
# face neighbors (2:1-balanced: same level, one coarser, or 2^(d-1) finer)
# ---------------------------------------------------------------------------

def neighbor_slots_per_cell(d: int) -> int:
    """Static width of the neighbor table: 2d faces x 2^(d-1) sub-slots."""
    return 2 * d * (1 << (d - 1))


def face_neighbors(mesh: AMRMesh) -> np.ndarray:
    """(n, K) int32 face-neighbor table, K = ``neighbor_slots_per_cell``.

    Entries index into the mesh's cell order; -1 marks an empty slot
    (domain boundary, or unused sub-slots when the neighbor is not
    finer). Face f = (axis a, direction s) owns sub-slots
    ``f * 2^(d-1) ... (f+1) * 2^(d-1) - 1``: slot 0 carries a same-level
    or coarser neighbor; a finer neighbor fills all 2^(d-1) sub-slots
    with the face-adjacent children. Under 2:1 balance these cases are
    exclusive. The table is symmetric as an edge set — j appears in i's
    row iff i appears in j's (asserted by tests, relied on by the halo
    plan's send/recv symmetry).
    """
    n, d = mesh.n, mesh.d
    sub = 1 << (d - 1)
    K = neighbor_slots_per_cell(d)
    nbr = np.full((n, K), -1, np.int64)
    look = _CellLookup(mesh.level, mesh.ij)
    lvl = mesh.level.astype(np.int64)
    # offsets of the d-1 non-face dims for finer-neighbor children
    sub_offs = _child_offsets(d - 1) if d > 1 else np.zeros((1, 0), np.int64)
    for a in range(d):
        for si, s in enumerate((-1, +1)):
            f = 2 * a + si
            ij2 = mesh.ij.copy()
            ij2[:, a] += s
            in_dom = (ij2[:, a] >= 0) & (ij2[:, a] < (1 << lvl))
            # same level
            same = np.where(in_dom, look.find(mesh.level, ij2), -1)
            # one coarser (only valid where the same-level cell is absent)
            coarse = np.where(
                in_dom & (same < 0) & (lvl > 0),
                look.find(mesh.level - 1, ij2 >> 1),
                -1,
            )
            nbr[:, f * sub] = np.where(same >= 0, same, coarse)
            # one finer: the 2^(d-1) children of ij2 adjacent to the face.
            # Child a-coord: low side (2*ij2[a]) when we look in +a, high
            # side (2*ij2[a] + 1) when we look in -a.
            need_fine = in_dom & (same < 0) & (coarse < 0) & (lvl < mesh.max_level)
            if not need_fine.any():
                continue
            other = [x for x in range(d) if x != a]
            base = ij2 * 2
            for t in range(sub):
                child = base.copy()
                child[:, a] = base[:, a] + (1 if s < 0 else 0)
                for oi, ax in enumerate(other):
                    child[:, ax] = base[:, ax] + sub_offs[t, oi]
                fine = np.where(need_fine, look.find(mesh.level + 1, child), -1)
                nbr[:, f * sub + t] = np.where(
                    need_fine, fine, nbr[:, f * sub + t]
                )
    return nbr.astype(np.int32)


def neighbor_edges(nbr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Directed (src, dst) edge list of the face-adjacency graph — the
    input `repro.core.metrics.edge_metrics` expects."""
    n, K = nbr.shape
    src = np.repeat(np.arange(n, dtype=np.int64), K)
    dst = nbr.reshape(-1).astype(np.int64)
    keep = dst >= 0
    return src[keep], dst[keep]


def stencil_coeffs(mesh: AMRMesh, nbr: np.ndarray, dt: float) -> np.ndarray:
    """(n, K) float32 explicit finite-volume heat-flux coefficients.

    For face (i, j): flux = area / dist with ``area = min(h_i, h_j)^(d-1)``
    and ``dist = (h_i + h_j) / 2``; the update divides by the cell volume,
    so ``du_i = dt / h_i^d * sum_j area_ij / dist_ij * (u_j - u_i)``.
    Empty slots carry coefficient 0. Computed once per mesh on the host in
    float32 — the distributed and reference stencils consume the SAME
    array, a precondition of their bit-equality.
    """
    h = mesh.sizes().astype(np.float64)
    d = mesh.d
    nb = np.maximum(nbr, 0)
    h_j = h[nb]
    area = np.minimum(h[:, None], h_j) ** (d - 1)
    dist = 0.5 * (h[:, None] + h_j)
    c = dt * area / (dist * (h[:, None] ** d))
    return np.where(nbr >= 0, c, 0.0).astype(np.float32)


def stable_dt(mesh_or_hmin, safety: float = 0.25) -> float:
    """Explicit-stability timestep for the finest cells of the run."""
    h = mesh_or_hmin if np.isscalar(mesh_or_hmin) else float(mesh_or_hmin.sizes().min())
    d = 2 if np.isscalar(mesh_or_hmin) else mesh_or_hmin.d
    return safety * h * h / (2.0 * d)


# ---------------------------------------------------------------------------
# refine / coarsen with 2:1 balance + deterministic transfer maps
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Transfer:
    """State transfer map of one refine/coarsen step.

    ``src[k]`` lists the old-cell indices feeding new cell ``k`` (-1
    pad); ``cnt[k]`` how many. Kept and refined-child cells copy one
    source; a coarsened parent averages its 2^d children (fixed child
    order). ``born`` marks new cells that did not exist before;
    ``died_idx`` are the OLD-order indices of removed cells (refined
    parents, coarsened children). The driver keeps its slot array
    current from these: kept cells inherit ``slots[src[k, 0]]``, died
    indices map to engine deletes, born cells to engine inserts.
    """

    src: np.ndarray       # (n_new, 2^d) int64
    cnt: np.ndarray       # (n_new,) int32
    born: np.ndarray      # (n_new,) bool
    died_idx: np.ndarray  # (k,) int64 old-cell indices of removed cells


def apply_transfer(u_old: np.ndarray, tr: Transfer) -> np.ndarray:
    """Move a cell field across a refine/coarsen step (see `Transfer`).

    The ONE transfer implementation: both the distributed simulation and
    the single-device reference call this (host-side, float32), so their
    fields stay bitwise comparable across mesh changes.
    """
    u = np.asarray(u_old, np.float32)
    vals = np.where(tr.src >= 0, u[np.maximum(tr.src, 0)], np.float32(0.0))
    return (vals.sum(axis=1) / tr.cnt.astype(np.float32)).astype(np.float32)


def refine_coarsen(
    mesh: AMRMesh,
    refine_mask: np.ndarray,
    coarsen_mask: np.ndarray,
) -> tuple[AMRMesh, Transfer]:
    """One adaptation step: split masked cells, merge fully-masked
    sibling groups, keep the 2:1 balance.

    Refinement wins over coarsening; the refinement set is closed under
    the 2:1 ripple (a neighbor of a would-be level-(l+2) cell refines
    too); a sibling group only coarsens when every sibling agrees, none
    refines, and no face neighbor would end up two levels finer than the
    merged parent. New-cell order is deterministic: kept cells first (in
    old order), then children (refined-parent order x fixed child
    order), then merged parents (group order).
    """
    n, d = mesh.n, mesh.d
    k2 = 1 << d
    refine = np.asarray(refine_mask, bool) & (mesh.level < mesh.max_level)
    coarsen = np.asarray(coarsen_mask, bool) & (mesh.level > mesh.base_level)
    nbr = face_neighbors(mesh)

    # --- 2:1 refinement ripple (post-refinement levels) -------------------
    for _ in range(mesh.max_level - mesh.base_level + 1):
        post = mesh.level.astype(np.int64) + refine
        nb_post = np.where(nbr >= 0, post[np.maximum(nbr, 0)], -(10**6))
        viol = (nb_post.max(axis=1) - post) >= 2
        grow = viol & ~refine & (mesh.level < mesh.max_level)
        if not grow.any():
            break
        refine = refine | grow

    # --- coarsenable sibling groups ---------------------------------------
    coarsen = coarsen & ~refine
    post = mesh.level.astype(np.int64) + refine
    # a child may only coarsen if no face neighbor ends deeper than
    # level + 1 == parent_level + 2 - 1 (merged parent keeps 2:1)
    nb_post = np.where(nbr >= 0, post[np.maximum(nbr, 0)], -(10**6))
    safe = nb_post.max(axis=1) <= mesh.level.astype(np.int64)
    cand = coarsen & safe
    parent_key = _pack(mesh.level - 1, mesh.ij >> 1)
    # complete groups: all 2^d siblings present and willing
    cand_idx = np.nonzero(cand)[0]
    merged_parent_ids: np.ndarray
    group_children = np.zeros((0, k2), np.int64)
    if cand_idx.size:
        pk = parent_key[cand_idx]
        order = np.argsort(pk, kind="stable")
        pk_s, idx_s = pk[order], cand_idx[order]
        uniq, starts, counts = np.unique(pk_s, return_index=True, return_counts=True)
        full = counts == k2
        if full.any():
            starts_f = starts[full]
            # children of each full group, sorted by their own cell key =
            # fixed child order (pack sorts ij lexicographically)
            rows = []
            for s in starts_f:
                grp = idx_s[s : s + k2]
                ck = _pack(mesh.level[grp], mesh.ij[grp])
                rows.append(grp[np.argsort(ck)])
            group_children = np.stack(rows, axis=0)
    removed = np.zeros(n, bool)
    if group_children.shape[0]:
        removed[group_children.reshape(-1)] = True

    keep = ~refine & ~removed
    keep_idx = np.nonzero(keep)[0]
    ref_idx = np.nonzero(refine)[0]

    offs = _child_offsets(d)
    # children: (n_ref * 2^d)
    ch_level = np.repeat(mesh.level[ref_idx] + 1, k2)
    ch_ij = (mesh.ij[ref_idx][:, None, :] * 2 + offs[None, :, :]).reshape(-1, d)
    ch_src = np.repeat(ref_idx, k2)
    # merged parents
    g = group_children.shape[0]
    pa_level = (mesh.level[group_children[:, 0]] - 1) if g else np.zeros(0, np.int32)
    pa_ij = (mesh.ij[group_children[:, 0]] >> 1) if g else np.zeros((0, d), np.int64)

    new_level = np.concatenate(
        [mesh.level[keep_idx], ch_level.astype(np.int32), pa_level.astype(np.int32)]
    )
    new_ij = np.concatenate([mesh.ij[keep_idx], ch_ij, pa_ij])
    n_new = new_level.shape[0]

    src = np.full((n_new, k2), -1, np.int64)
    cnt = np.ones((n_new,), np.int32)
    src[: keep_idx.size, 0] = keep_idx
    src[keep_idx.size : keep_idx.size + ch_src.size, 0] = ch_src
    if g:
        src[keep_idx.size + ch_src.size :, :] = group_children
        cnt[keep_idx.size + ch_src.size :] = k2
    born = np.zeros((n_new,), bool)
    born[keep_idx.size :] = True
    died_idx = np.nonzero(~keep)[0]

    out = AMRMesh(
        level=new_level,
        ij=new_ij,
        base_level=mesh.base_level,
        max_level=mesh.max_level,
    )
    return out, Transfer(src=src, cnt=cnt, born=born, died_idx=died_idx)


# ---------------------------------------------------------------------------
# the moving load feature (drives both refinement and weight drift)
# ---------------------------------------------------------------------------

def feature_center(t: float, d: int, *, x0: float = 0.2, x1: float = 0.8) -> np.ndarray:
    """Feature path: a straight walk along dim 0 from x0 to x1 (other
    dims pinned at 0.5). ``t`` in [0, 1]; restrict [x0, x1] to one
    node's span to exercise the node-local regime."""
    c = np.full((d,), 0.5, np.float64)
    c[0] = x0 + (x1 - x0) * float(t)
    return c


def feature_weights(
    centers: np.ndarray, c: np.ndarray, *, amp: float = 4.0, sigma: float = 0.12
) -> np.ndarray:
    """(n,) float32 cell costs: 1 + amp * gaussian(feature) — hot cells
    near the feature cost more per stencil update (finer physics /
    subcycling), which is the weight drift the Alg. 3 trigger meters."""
    d2 = np.sum((np.asarray(centers, np.float64) - c[None, :]) ** 2, axis=1)
    return (1.0 + amp * np.exp(-d2 / (sigma * sigma))).astype(np.float32)


def adapt_masks(
    mesh: AMRMesh,
    c: np.ndarray,
    *,
    r_refine: float = 0.15,
    r_coarsen: float = 0.30,
) -> tuple[np.ndarray, np.ndarray]:
    """Refine inside ``r_refine`` of the feature, coarsen beyond
    ``r_coarsen`` — the classic tracking-AMR policy."""
    dist = np.sqrt(
        np.sum((mesh.centers().astype(np.float64) - c[None, :]) ** 2, axis=1)
    )
    return dist < r_refine, dist > r_coarsen
