"""Distributed stencil execution over compiled halo plans.

The executors here are deliberately dumb: every routing decision was
made on the host when the `repro.mesh.halo` plan was compiled, so the
device programs are pure gathers + fixed-lane ``all_to_all`` hops + one
fused update — jitted ``shard_map`` closures memoized per static shape
signature (the same lru_cache pattern as ``partitioner._reslice_fn``;
shard_map must run under jit or every traced op dispatches as its own
SPMD program).

Bit-equality contract: :func:`reference_stencil` (single device, global
cell order) and :func:`stencil_steps` (sharded, owned+ghost layout)
evaluate the SAME per-cell expression — ``u_i += sum_k where(valid,
coeff_ik * (u_nbr - u_i), 0)`` with identical (n, K) coefficient rows,
identical slot order and identical float32 dtype — so a distributed
sweep is bitwise equal to the reference sweep, which is what the
``bench_mesh`` gate holds after repeated repartition + migration events.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat as _compat
from repro.mesh.halo import GID_SENTINEL, HaloPlan, MovePlan


def _a2a(buf, axis):
    r = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=False)
    return r.reshape(-1)


def _route(prev, stage_meta, stage_idx, fill):
    """Replay the plan's hops: gather into lane buffers, exchange."""
    for (ax, lanes, scap), idx in zip(stage_meta, stage_idx):
        src = jnp.clip(idx, 0, prev.shape[0] - 1)
        buf = jnp.where(idx >= 0, prev[src], fill).reshape(lanes, scap)
        prev = _a2a(buf, ax)
    return prev


# ---------------------------------------------------------------------------
# the stencil sweep
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _reference_fn(steps: int):
    @jax.jit
    def fn(u, nbr, valid, coeff):
        for _ in range(steps):
            vals = u[jnp.clip(nbr, 0, u.shape[0] - 1)]
            contrib = jnp.where(valid, coeff * (vals - u[:, None]), jnp.float32(0.0))
            u = u + jnp.sum(contrib, axis=-1)
        return u
    return fn


def reference_stencil(u, nbr, valid, coeff, steps: int):
    """``steps`` explicit heat sweeps on one device, global cell order."""
    return _reference_fn(int(steps))(
        jnp.asarray(u, jnp.float32), jnp.asarray(nbr), jnp.asarray(valid),
        jnp.asarray(coeff, jnp.float32),
    )


@functools.lru_cache(maxsize=64)
def _stencil_fn(mesh: jax.sharding.Mesh, axes: tuple, stage_meta: tuple, steps: int):
    """Jitted halo-exchange + update executor, memoized per static
    (mesh, axes, hop shapes, steps)."""

    def kernel(u, nbr, valid, coeff, fetch, *stage_idx):
        for _ in range(steps):
            recv = _route(u, stage_meta, stage_idx, jnp.float32(0.0))
            ghosts = jnp.where(
                fetch >= 0, recv[jnp.clip(fetch, 0, recv.shape[0] - 1)], 0.0
            )
            vals_all = jnp.concatenate([u, ghosts])
            vals = vals_all[nbr]
            contrib = jnp.where(valid, coeff * (vals - u[:, None]), jnp.float32(0.0))
            u = u + jnp.sum(contrib, axis=-1)
        return u

    spec = P(axes)
    in_specs = (spec,) * (5 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=spec, check_vma=False,
    ))


def halo_args(jax_mesh: jax.sharding.Mesh, plan: HaloPlan):
    """Device-resident executor arguments for one halo plan (placed once
    per plan, outside the timed sweep loop)."""
    sh = NamedSharding(jax_mesh, P(plan.axes))
    S = plan.owned_idx.shape[0]
    put = lambda a: jax.device_put(jnp.asarray(a), sh)
    args = (
        put(plan.nbr_local.reshape(S * plan.cap, plan.K)),
        put(plan.nbr_valid.reshape(S * plan.cap, plan.K)),
        put(plan.coeff.reshape(S * plan.cap, plan.K)),
        put(plan.ghost_fetch.reshape(S * plan.gcap)),
    )
    stages = tuple(
        put(s.idx.reshape(S * s.lanes * s.cap)) for s in plan.stages
    )
    return args + stages


def stencil_steps(jax_mesh, plan: HaloPlan, u_dev, args, steps: int):
    """Run ``steps`` distributed sweeps over the plan's layout.

    ``u_dev`` is the (S*cap,) owned field (``plan.pack_cells`` layout);
    ``args`` from :func:`halo_args`."""
    fn = _stencil_fn(jax_mesh, plan.axes, plan.stage_meta, int(steps))
    return fn(u_dev, *args)


def put_state(jax_mesh, plan: HaloPlan, u_cells: np.ndarray):
    """Host cell-order field -> device owned layout."""
    sh = NamedSharding(jax_mesh, P(plan.axes))
    return jax.device_put(jnp.asarray(plan.pack_cells(u_cells)), sh)


# ---------------------------------------------------------------------------
# state migration between partitions
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _move_fn(
    mesh: jax.sharding.Mesh,
    axes: tuple,
    stage_meta: tuple,
    cap_new: int,
):
    """Jitted state-move executor: route moved (slot, value) rows along
    the plan's hops, then merge with the kept rows by slot sort — the
    new layout's canonical ascending-slot order falls out of the sort."""

    def kernel(u, gid, keep, *stage_idx):
        prev_u, prev_g = u, gid
        for (ax, lanes, scap), idx in zip(stage_meta, stage_idx):
            src = jnp.clip(idx, 0, prev_u.shape[0] - 1)
            sel = idx >= 0
            buf_u = jnp.where(sel, prev_u[src], 0.0).reshape(lanes, scap)
            buf_g = jnp.where(sel, prev_g[src], GID_SENTINEL).reshape(lanes, scap)
            prev_u = _a2a(buf_u, ax)
            prev_g = _a2a(buf_g, ax)
        kept_g = jnp.where(keep, gid, GID_SENTINEL)
        if stage_meta:
            all_g = jnp.concatenate([kept_g, prev_g])
            all_u = jnp.concatenate([u, prev_u])
        else:
            all_g, all_u = kept_g, u
        order = jnp.argsort(all_g, stable=True)[:cap_new]
        out_g = all_g[order]
        return jnp.where(out_g != GID_SENTINEL, all_u[order], 0.0)

    spec = P(axes)
    in_specs = (spec,) * (3 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=spec, check_vma=False,
    ))


def move_state(jax_mesh, mv: MovePlan, old: HaloPlan, u_dev):
    """Execute a compiled state move: ``u_dev`` in ``old``'s layout ->
    the new plan's layout (values bit-preserved; rows only travel)."""
    sh = NamedSharding(jax_mesh, P(mv.axes))
    S = old.owned_idx.shape[0]
    put = lambda a: jax.device_put(jnp.asarray(a), sh)
    gid = put(old.owned_slot.astype(np.int32).reshape(S * old.cap))
    keep = put(mv.keep.reshape(S * mv.cap_old))
    stages = tuple(put(s.idx.reshape(S * s.lanes * s.cap)) for s in mv.stages)
    fn = _move_fn(jax_mesh, mv.axes, mv.stage_meta, int(mv.cap_new))
    return fn(u_dev, gid, keep, *stages)
