"""Distributed stencil execution over compiled halo plans.

The executors here are deliberately dumb: every routing decision was
made on the host when the `repro.mesh.halo` plan was compiled, so the
device programs are pure gathers + fixed-lane ``all_to_all`` hops + one
fused update — jitted ``shard_map`` closures memoized per static shape
signature (the same lru_cache pattern as ``partitioner._reslice_fn``;
shard_map must run under jit or every traced op dispatches as its own
SPMD program).

The default executor overlaps communication with computation: per
sweep it launches the ghost-exchange hops, updates the plan's
*interior* rows (compiled to be provably independent of the exchange —
no valid neighbor slot reaches into the ghost region) while the
collectives are in flight, and applies the *boundary* rows only after
the recv lands. Under jit the ``all_to_all`` lowers to an async
start/done pair and XLA schedules the interior update between them; the
dataflow admits the overlap by construction, on any backend. The row
update itself is the fused `kernels.ops.stencil_update` (gather + mask
+ coeff*(v-u) + K-reduce in one pass; optional Pallas kernel, bit-equal
jnp fallback). The step loop is a ``fori_loop`` over a *traced* step
count, so ONE compiled executor serves every sweep length — ``steps``
is not part of the cache signature.

Bit-equality contract: :func:`reference_stencil` (single device, global
cell order) and :func:`stencil_steps` (sharded, owned+ghost layout)
evaluate the SAME per-cell expression — ``u_i += sum_k where(valid,
coeff_ik * (u_nbr - u_i), 0)`` with identical (n, K) coefficient rows,
identical slot order and identical float32 dtype — so a distributed
sweep is bitwise equal to the reference sweep, which is what the
``bench_mesh`` gate holds after repeated repartition + migration
events. The interior/boundary split preserves this: each row subset
evaluates the identical expression on the identical values and the
scatters merely reassemble the rows (row-wise K-reduction order does
not depend on the row blocking).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat as _compat
from repro.kernels import ops as _ops
from repro.mesh.halo import GID_SENTINEL, HaloPlan, MovePlan


def _a2a(buf, axis):
    r = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=False)
    return r.reshape(-1)


def _route(prev, stage_meta, stage_idx, fill):
    """Replay the plan's hops: gather into lane buffers, exchange."""
    for (ax, lanes, scap), idx in zip(stage_meta, stage_idx):
        src = jnp.clip(idx, 0, prev.shape[0] - 1)
        buf = jnp.where(idx >= 0, prev[src], fill).reshape(lanes, scap)
        prev = _a2a(buf, ax)
    return prev


def _rows_update(u_out, u, vals_all, nbr, valid, coeff, rows, use_pallas):
    """Update the subset ``rows`` of owned cells (-1 pads drop): gather
    the row tables, run the fused update, scatter the results back."""
    r = jnp.maximum(rows, 0)
    out_rows = _ops.stencil_update(
        vals_all, u[r], nbr[r], valid[r], coeff[r], use_pallas=use_pallas
    )
    safe = jnp.where(rows >= 0, r, u.shape[0])  # out of range -> dropped
    return u_out.at[safe].set(out_rows, mode="drop")


# ---------------------------------------------------------------------------
# the stencil sweep
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4)
def _reference_fn():
    # ONE compile serves every sweep length: steps is a traced scalar
    # driving a fori_loop (per-iteration ops identical to the unrolled
    # loop, so results are bit-identical). The row update is the SAME
    # shared definition every distributed executor runs — its explicit
    # fixed-order K accumulation is what makes cross-program
    # bit-equality hold (see kernels.stencil_update).
    @jax.jit
    def fn(steps, u, nbr, valid, coeff):
        def body(_, u):
            return _ops.stencil_update(u, u, nbr, valid, coeff)
        return jax.lax.fori_loop(0, steps, body, u)
    return fn


def reference_stencil(u, nbr, valid, coeff, steps: int):
    """``steps`` explicit heat sweeps on one device, global cell order."""
    return _reference_fn()(
        jnp.int32(steps), jnp.asarray(u, jnp.float32), jnp.asarray(nbr),
        jnp.asarray(valid), jnp.asarray(coeff, jnp.float32),
    )


@functools.lru_cache(maxsize=64)
def _stencil_fn(
    mesh: jax.sharding.Mesh,
    axes: tuple,
    stage_meta: tuple,
    use_pallas: bool,
):
    """Jitted overlapped halo-exchange + fused-update executor, memoized
    per static (mesh, axes, hop shapes) — NOT per step count: ``steps``
    is a traced argument, so one compiled program serves any sweep
    length."""

    def kernel(steps, u, nbr, valid, coeff, fetch, interior, boundary, *stage_idx):
        def body(_, u):
            # launch the ghost exchange; nothing below depends on it
            # until the boundary update, so XLA is free to run the
            # interior update between the collective's start/done pair
            recv = _route(u, stage_meta, stage_idx, jnp.float32(0.0))
            # interior rows: all reads come from u itself
            u_new = _rows_update(u, u, u, nbr, valid, coeff, interior, use_pallas)
            # boundary rows: wait for the recv, fetch ghosts, update
            ghosts = jnp.where(
                fetch >= 0, recv[jnp.clip(fetch, 0, recv.shape[0] - 1)], 0.0
            )
            vals_all = jnp.concatenate([u, ghosts])
            return _rows_update(
                u_new, u, vals_all, nbr, valid, coeff, boundary, use_pallas
            )
        return jax.lax.fori_loop(0, steps, body, u)

    spec = P(axes)
    in_specs = (P(),) + (spec,) * (7 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=spec, check_vma=False,
    ))


@functools.lru_cache(maxsize=64)
def _stencil_fn_presplit(
    mesh: jax.sharding.Mesh, axes: tuple, stage_meta: tuple, steps: int
):
    """The pre-split executor (serialize-everything: full exchange, then
    one unfused (cap, K) gather+reduce over ALL rows; python-unrolled
    step loop, so the cache is keyed on ``steps`` and every new sweep
    length recompiles). Kept as the benchmark baseline the overlapped
    executor is gated against."""

    def kernel(u, nbr, valid, coeff, fetch, *stage_idx):
        for _ in range(steps):
            recv = _route(u, stage_meta, stage_idx, jnp.float32(0.0))
            ghosts = jnp.where(
                fetch >= 0, recv[jnp.clip(fetch, 0, recv.shape[0] - 1)], 0.0
            )
            vals_all = jnp.concatenate([u, ghosts])
            u = _ops.stencil_update(vals_all, u, nbr, valid, coeff)
        return u

    spec = P(axes)
    in_specs = (spec,) * (5 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=spec, check_vma=False,
    ))


@dataclass(frozen=True)
class HaloArgs:
    """Device-resident executor arguments for one halo plan."""

    core: tuple     # (nbr, valid, coeff, fetch)
    split: tuple    # (interior, boundary)
    stages: tuple   # one flat lane-index array per hop


def halo_args(jax_mesh: jax.sharding.Mesh, plan: HaloPlan) -> HaloArgs:
    """Device-resident executor arguments for one halo plan (placed once
    per plan, outside the timed sweep loop)."""
    sh = NamedSharding(jax_mesh, P(plan.axes))
    S = plan.owned_idx.shape[0]
    put = lambda a: jax.device_put(jnp.asarray(a), sh)
    core = (
        put(plan.nbr_local.reshape(S * plan.cap, plan.K)),
        put(plan.nbr_valid.reshape(S * plan.cap, plan.K)),
        put(plan.coeff.reshape(S * plan.cap, plan.K)),
        put(plan.ghost_fetch.reshape(S * plan.gcap)),
    )
    split = (
        put(plan.interior_idx.reshape(-1)),
        put(plan.boundary_idx.reshape(-1)),
    )
    stages = tuple(
        put(s.idx.reshape(S * s.lanes * s.cap)) for s in plan.stages
    )
    return HaloArgs(core=core, split=split, stages=stages)


def stencil_steps(
    jax_mesh,
    plan: HaloPlan,
    u_dev,
    args: HaloArgs,
    steps: int,
    *,
    overlap: bool = True,
    use_pallas: bool = False,
):
    """Run ``steps`` distributed sweeps over the plan's layout.

    ``u_dev`` is the (S*cap,) owned field (``plan.pack_cells`` layout);
    ``args`` from :func:`halo_args`. The default overlapped executor
    updates interior rows while the exchange is in flight and reuses
    ONE compiled program for every ``steps``; ``overlap=False`` runs the
    pre-split baseline (bit-equal, recompiles per sweep length)."""
    if overlap:
        fn = _stencil_fn(jax_mesh, plan.axes, plan.stage_meta, bool(use_pallas))
        return fn(jnp.int32(steps), u_dev, *args.core, *args.split, *args.stages)
    fn = _stencil_fn_presplit(jax_mesh, plan.axes, plan.stage_meta, int(steps))
    return fn(u_dev, *args.core, *args.stages)


def put_state(jax_mesh, plan: HaloPlan, u_cells: np.ndarray):
    """Host cell-order field -> device owned layout."""
    sh = NamedSharding(jax_mesh, P(plan.axes))
    return jax.device_put(jnp.asarray(plan.pack_cells(u_cells)), sh)


# ---------------------------------------------------------------------------
# per-phase probes (reporting only — the hot loop runs the fused program)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _phase_fns(mesh: jax.sharding.Mesh, axes: tuple, stage_meta: tuple):
    """Three jitted single-phase executors (exchange only / interior only
    / boundary only) used to attribute sweep walltime to its phases.
    They exist for measurement — the production executor fuses all three
    into one program."""
    spec = P(axes)

    def exchange(u, fetch, *stage_idx):
        recv = _route(u, stage_meta, stage_idx, jnp.float32(0.0))
        return jnp.where(fetch >= 0, recv[jnp.clip(fetch, 0, recv.shape[0] - 1)], 0.0)

    def interior(u, nbr, valid, coeff, rows):
        return _rows_update(u, u, u, nbr, valid, coeff, rows, False)

    def boundary(u, ghosts, nbr, valid, coeff, rows):
        vals_all = jnp.concatenate([u, ghosts])
        return _rows_update(u, u, vals_all, nbr, valid, coeff, rows, False)

    wrap = lambda f, n: jax.jit(_compat.shard_map(
        f, mesh=mesh, in_specs=(spec,) * n, out_specs=spec, check_vma=False,
    ))
    return (
        wrap(exchange, 2 + len(stage_meta)),
        wrap(interior, 5),
        wrap(boundary, 6),
    )


def stencil_phase_times(
    jax_mesh, plan: HaloPlan, u_dev, args: HaloArgs, *, repeats: int = 2
) -> dict:
    """Measured walltime of one sweep's phases, each as its own jitted
    program (warm: every probe runs ``repeats + 1`` times and the first
    — the compile — is discarded). Returns seconds per single sweep."""
    ex, it, bd = _phase_fns(jax_mesh, plan.axes, plan.stage_meta)
    nbr, valid, coeff, fetch = args.core
    interior, boundary = args.split
    out = {}
    for name, call in (
        ("exchange", lambda: ex(u_dev, fetch, *args.stages)),
        ("interior", lambda: it(u_dev, nbr, valid, coeff, interior)),
    ):
        best = None
        jax.block_until_ready(call())  # compile
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(call())
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        out[name] = best
    ghosts = jax.block_until_ready(ex(u_dev, fetch, *args.stages))
    call = lambda: bd(u_dev, ghosts, nbr, valid, coeff, boundary)
    jax.block_until_ready(call())
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    out["boundary"] = best
    return out


# ---------------------------------------------------------------------------
# state migration between partitions
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _move_fn(
    mesh: jax.sharding.Mesh,
    axes: tuple,
    stage_meta: tuple,
    cap_new: int,
):
    """Jitted state-move executor: route moved (slot, value) rows along
    the plan's hops, then merge with the kept rows by slot sort — the
    new layout's canonical ascending-slot order falls out of the sort."""

    def kernel(u, gid, keep, *stage_idx):
        prev_u, prev_g = u, gid
        for (ax, lanes, scap), idx in zip(stage_meta, stage_idx):
            src = jnp.clip(idx, 0, prev_u.shape[0] - 1)
            sel = idx >= 0
            buf_u = jnp.where(sel, prev_u[src], 0.0).reshape(lanes, scap)
            buf_g = jnp.where(sel, prev_g[src], GID_SENTINEL).reshape(lanes, scap)
            prev_u = _a2a(buf_u, ax)
            prev_g = _a2a(buf_g, ax)
        kept_g = jnp.where(keep, gid, GID_SENTINEL)
        if stage_meta:
            all_g = jnp.concatenate([kept_g, prev_g])
            all_u = jnp.concatenate([u, prev_u])
        else:
            all_g, all_u = kept_g, u
        order = jnp.argsort(all_g, stable=True)[:cap_new]
        out_g = all_g[order]
        return jnp.where(out_g != GID_SENTINEL, all_u[order], 0.0)

    spec = P(axes)
    in_specs = (spec,) * (3 + len(stage_meta))
    return jax.jit(_compat.shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=spec, check_vma=False,
    ))


def move_state(jax_mesh, mv: MovePlan, old: HaloPlan, u_dev):
    """Execute a compiled state move: ``u_dev`` in ``old``'s layout ->
    the new plan's layout (values bit-preserved; rows only travel)."""
    sh = NamedSharding(jax_mesh, P(mv.axes))
    S = old.owned_idx.shape[0]
    put = lambda a: jax.device_put(jnp.asarray(a), sh)
    gid = put(old.owned_slot.astype(np.int32).reshape(S * old.cap))
    keep = put(mv.keep.reshape(S * mv.cap_old))
    stages = tuple(put(s.idx.reshape(S * s.lanes * s.cap)) for s in mv.stages)
    fn = _move_fn(jax_mesh, mv.axes, mv.stage_meta, int(mv.cap_new))
    return fn(u_dev, gid, keep, *stages)
