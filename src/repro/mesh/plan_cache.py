"""Event-cached plan construction: persist the expensive intermediates
of :func:`repro.mesh.halo.build_halo_plan` across repartition events and
delta-patch instead of rebuilding.

Plan construction after PR 8 is pure segment ops, but every event still
pays the full (n, K) neighbor-owner gather, the global (part, slot)
lexsort, and the global ghost dedup from scratch — even an intra-node
reslice that moves <5% of the cells. This module splits the build state
into two tiers and patches the second:

**Topology tier** (valid while the mesh itself is unchanged — keyed on
an optional ``topo_token`` such as the engine's ``topology_version``,
plus value equality of ``slot``/``nbr``/``coeff``):

* ``srank``/``sorder`` — the slot-rank compression (one global argsort),
* ``valid``/``nbc`` — the clamped (n, K) neighbor table,
* a reverse-CSR *incidence* index: for each cell c, the flat positions
  j into the (n·K) neighbor table with ``nbr.flat[j] == c``. This is
  the "CSR ghost-pair cache": it answers *whose stencil rows mention a
  moved cell* in O(degree) instead of an O(n·K) rescan.

**Partition tier** (patched per event): the (part, slot)-sorted owned
layout (``ocells``/``okey``/``ocounts``/``local_pos``), the
same/other lane flags, the deduped ghost pair lists (``gp``/``gc``/
``gr``), and the compiled stencil tables of the last plan.

Patch rule for a reslice that moves cell set M: let T be the union of
old and new owners of M. Only rows of parts in T can change — a row of
an untouched part keeps its owner, its lane flags (its neighbors'
owners moved only between *other* parts, which flips no same/other
bit... except where a neighbor IS a moved cell, which the incidence
index localizes), its ghost list as a set, and (because ghost keys are
(part, slot-rank) and the owned layout of untouched parts is
unchanged) every compiled index. So the patch: (1) flip same/other at
the incident positions of M plus all lanes of M's own rows; (2) merge
M's rows out of/into the sorted owned layout with one
``searchsorted`` (O(n) memmove instead of an O(n log n) lexsort);
(3) recompute ghost pairs for T's rows only and splice them against
the retained pairs of untouched parts; (4) rewrite the stencil-table
blocks of T's parts with the *same formulas* the scratch builder uses;
(5) re-pack the routing stages (O(G log G) on the small ghost set).
Because every retained array region is provably what the scratch
builder would produce and every rewritten region uses the scratch
formulas on identical inputs, the patched plan is **bit-identical**
(``np.array_equal``, every field) to a fresh vectorized build — which
is itself bit-identical to the per-part legacy builder, a two-deep
oracle chain exercised in ``tests/test_plan_equivalence.py``.

When the owned capacity crosses a roundup quantum the padded table
shapes change; the patch then copies each part block into the
re-padded shape and shifts the ghost-lane offsets (the only
cap-dependent values) — same memcpy cost as the aligned patch.
Fallbacks keep the fast path honest: the cache rebuilds from scratch
(reusing the topology tier) when the moved fraction exceeds
``max_patch_frac`` (default 25% — past that the patch does more work
than the lexsort it replaces) or when the plan shape (hierarchy /
part count) changes. A changed topology token or changed
``slot``/``nbr``/``coeff`` values refresh the topology tier.

The same cache also serves :func:`~repro.mesh.halo.build_move_plan`:
the slot-sorted (old owner, new owner, old row) join that the move
builder needs is exactly the cached layout state of the last two halo
builds, read back through :meth:`PlanCache.move_prologue` — one owner
gather per partition event, shared by both builders.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.mesh import halo as _halo


@dataclass
class PlanCacheStats:
    """Cumulative cache behavior over a run (reported into SimStats)."""

    halo_hits: int = 0       # halo builds served by patch or no-op reuse
    halo_misses: int = 0     # halo builds that fell back to scratch
    move_hits: int = 0       # move prologues served from cached layout
    move_misses: int = 0     # move builds that re-derived the join
    topo_refreshes: int = 0  # topology-tier rebuilds (AMR / first build)
    patched_rows: int = 0    # owned rows rewritten by segment patches


def _expand_segments(ptr: np.ndarray, sel: np.ndarray) -> np.ndarray:
    """Concatenated positions ``ptr[s]:ptr[s+1]`` for every s in ``sel``
    (the vectorized form of ``hstack([arange(ptr[s], ptr[s+1]) ...])``)."""
    sel = np.asarray(sel, np.int64)
    lens = ptr[sel + 1] - ptr[sel]
    total = int(lens.sum())
    if total == 0:
        return np.zeros((0,), np.int64)
    starts = np.repeat(ptr[sel], lens)
    seg_base = np.repeat(np.concatenate(([0], np.cumsum(lens)[:-1])), lens)
    return np.arange(total, dtype=np.int64) - seg_base + starts


class PlanCache:
    """Cross-event cache for halo/move plan construction.

    One instance per simulation run (or per mesh stream). Thread-safe
    only for serial use — plan construction is host-side and serial by
    design. Returned plans never alias mutable cache state: the patch
    path copies before writing, and the cache never mutates arrays it
    has handed out.
    """

    def __init__(self, max_patch_frac: float = 0.25):
        self.max_patch_frac = float(max_patch_frac)
        self.stats = PlanCacheStats()
        self._topo: dict | None = None
        self._state: dict | None = None
        self._last_plan = None
        self._prev_plan = None
        self._prev_part64 = None
        self._prev_local_pos = None

    # -- invalidation -------------------------------------------------------

    def reset(self) -> None:
        """Drop everything (topology + partition tiers)."""
        self._topo = None
        self._drop_partition_state()

    def invalidate_topology(self) -> None:
        """Alias of :meth:`reset`: a topology change invalidates both
        tiers (the partition state indexes cells of the old mesh)."""
        self.reset()

    def _drop_partition_state(self) -> None:
        self._state = None
        self._last_plan = None
        self._prev_plan = None
        self._prev_part64 = None
        self._prev_local_pos = None

    # -- topology tier ------------------------------------------------------

    def _topo_valid(self, slot, nbr, coeff, topo_token) -> bool:
        t = self._topo
        if t is None:
            return False
        if topo_token is not None and t["token"] != topo_token:
            return False
        n, K = nbr.shape
        if t["n"] != n or t["K"] != K or slot.shape[0] != n:
            return False
        # identity is the fast path (trajectories reuse array objects
        # between AMR events); fall back to value equality
        for ref_key, val_key, arr in (
            ("slot_ref", "slot64", slot),
            ("nbr_ref", "nbr", nbr),
            ("coeff_ref", "coeff", coeff),
        ):
            if arr is t[ref_key]:
                continue
            if not np.array_equal(np.asarray(arr), t[val_key]):
                return False
            t[ref_key] = arr
        return True

    def _refresh_topology(self, slot, nbr, coeff, topo_token, cap: dict) -> None:
        n, K = nbr.shape
        valid, nbc = cap["valid"], cap["nbc"]
        # reverse-CSR incidence: flat neighbor-table positions per
        # mentioned cell, grouped by cell id
        jpos = np.flatnonzero(valid.ravel())
        ckey = nbc.ravel()[jpos]
        inc_flat = jpos[np.argsort(ckey, kind="stable")]
        inc_ptr = np.zeros((n + 1,), np.int64)
        inc_ptr[1:] = np.cumsum(np.bincount(ckey, minlength=n))
        self._topo = dict(
            token=topo_token, n=n, K=K,
            slot_ref=slot, slot64=np.asarray(slot, np.int64).copy(),
            nbr_ref=nbr, nbr=np.array(nbr),
            coeff_ref=coeff, coeff=np.array(coeff),
            srank=cap["srank"], sorder=cap["sorder"],
            valid=valid, nbc=nbc,
            inc_ptr=inc_ptr, inc_flat=inc_flat,
        )
        self.stats.topo_refreshes += 1

    # -- partition tier -----------------------------------------------------

    def _stash_prev(self) -> None:
        """Keep one generation of layout state for the move prologue."""
        if self._state is not None:
            self._prev_plan = self._last_plan
            self._prev_part64 = self._state["part64"]
            self._prev_local_pos = self._state["local_pos"]
        else:
            self._prev_plan = None
            self._prev_part64 = None
            self._prev_local_pos = None

    def _install_state(self, plan, shape_key, d: dict) -> None:
        self._state = dict(
            shape_key=shape_key,
            part64=d["part64"], ocells=d["ocells"], okey=d["okey"],
            ocounts=d["ocounts"], local_pos=d["local_pos"],
            same=d["same"], other=d["other"],
            gp=d["gp"], gc=d["gc"], gr=d["gr"], gcounts=d["gcounts"],
            reads_ghost=d["reads_ghost"], cap=d["cap"], gcap=d["gcap"],
        )
        self._last_plan = plan

    # -- move-plan sharing --------------------------------------------------

    def move_prologue(self, old_plan, new_plan):
        """Slot-sorted (old_part, new_part, old_row, slot) join for
        :func:`~repro.mesh.halo.build_move_plan`, read from the cached
        layout of the last two halo builds. Returns None (a miss) when
        ``old``/``new`` are not this cache's plans — the builder then
        re-derives the join from ``owned_slot`` as before."""
        t, st = self._topo, self._state
        if t is None or st is None or new_plan is not self._last_plan:
            self.stats.move_misses += 1
            return None
        if old_plan is self._last_plan:
            old_part64, old_lp = st["part64"], st["local_pos"]
        elif old_plan is self._prev_plan and self._prev_part64 is not None:
            old_part64, old_lp = self._prev_part64, self._prev_local_pos
        else:
            self.stats.move_misses += 1
            return None
        so = t["sorder"]
        self.stats.move_hits += 1
        return old_part64[so], st["part64"][so], old_lp[so], t["slot64"][so]


def cached_build_halo_plan(
    cache: PlanCache, slot, part, nbr, coeff, *,
    hierarchy=None, num_parts=None, device_axis="device", weights=None,
    with_metrics=True, topo_token=None, profile=None,
):
    """:func:`~repro.mesh.halo.build_halo_plan` through a
    :class:`PlanCache` — bit-identical output, patched construction."""
    t_build = time.perf_counter()
    slot_a = np.asarray(slot)
    part_a = np.asarray(part)
    n, K = nbr.shape
    N, D, S, axes = _halo._plan_shape(part_a, hierarchy, num_parts, device_axis)
    shape_key = (N, D, S, axes)

    topo_ok = cache._topo_valid(slot_a, nbr, coeff, topo_token)
    st = cache._state if topo_ok else None
    if st is not None and st["shape_key"] != shape_key:
        st = None
    if st is None:
        return _full_build(
            cache, slot_a, part_a, nbr, coeff, hierarchy, num_parts,
            device_axis, weights, with_metrics, topo_ok, topo_token,
            shape_key, profile,
        )

    part64 = part_a.astype(np.int64)
    if n and (part64.min() < 0 or part64.max() >= S):
        raise ValueError(f"part ids must lie in [0, {S})")
    moved = np.flatnonzero(part64 != st["part64"])
    if moved.size == 0:
        # identical partition -> identical plan; reuse every compiled
        # array (the cache never mutates them) under fresh metrics
        cache.stats.halo_hits += 1
        old = cache._last_plan
        mets = _halo._halo_metrics_vec(
            part_a, nbr, st["ocounts"], st["gcounts"], st["gp"], st["gc"],
            D, old.stages, weights, with_quality=with_metrics,
        )
        mets["InteriorCells"] = old.metrics["InteriorCells"]
        mets["BoundaryCells"] = old.metrics["BoundaryCells"]
        mets["PlanCacheHits"] = cache.stats.halo_hits
        mets["PatchedRows"] = 0
        mets["PlanBuildSeconds"] = time.perf_counter() - t_build
        plan = _halo.HaloPlan(
            axes=old.axes, num_parts=old.num_parts, cap=old.cap,
            gcap=old.gcap, K=old.K, owned_idx=old.owned_idx,
            owned_slot=old.owned_slot, nbr_local=old.nbr_local,
            nbr_valid=old.nbr_valid, coeff=old.coeff, stages=old.stages,
            ghost_fetch=old.ghost_fetch, interior_idx=old.interior_idx,
            boundary_idx=old.boundary_idx, metrics=mets,
        )
        cache._stash_prev()
        cache._last_plan = plan
        return plan
    if moved.size > cache.max_patch_frac * n:
        return _full_build(
            cache, slot_a, part_a, nbr, coeff, hierarchy, num_parts,
            device_axis, weights, with_metrics, topo_ok, topo_token,
            shape_key, profile,
        )
    return _patched_build(
        cache, part_a, part64, moved, nbr, weights, with_metrics,
        shape_key, t_build, profile,
    )


def _full_build(
    cache, slot_a, part_a, nbr, coeff, hierarchy, num_parts, device_axis,
    weights, with_metrics, topo_ok, topo_token, shape_key, profile,
):
    """Scratch build through the cache: reuse the topology tier when it
    is still valid, capture the intermediates for the next event."""
    topo = None
    if topo_ok:
        t = cache._topo
        topo = (t["srank"], t["valid"], t["nbc"])
    cap_d: dict = {}
    plan = _halo.build_halo_plan(
        slot_a, part_a, nbr, coeff, hierarchy=hierarchy, num_parts=num_parts,
        device_axis=device_axis, weights=weights, with_metrics=with_metrics,
        profile=profile, _topo=topo, _capture=cap_d,
    )
    if topo_ok:
        cache._stash_prev()
    else:
        cache._refresh_topology(slot_a, nbr, coeff, topo_token, cap_d)
        # prev layout indexes the old topology — unusable for moves
        cache._prev_plan = None
        cache._prev_part64 = None
        cache._prev_local_pos = None
    cache.stats.halo_misses += 1
    plan.metrics["PlanCacheHits"] = cache.stats.halo_hits
    plan.metrics["PatchedRows"] = 0
    cache._install_state(plan, shape_key, cap_d)
    return plan


def _patched_build(
    cache, part_a, part64, moved, nbr, weights, with_metrics, shape_key,
    t_build, profile,
):
    """Delta-patch the cached build state for a reslice that moved cell
    set ``moved`` (bit-identical to a scratch build, see module doc)."""
    prof = _halo._ProfTimer(profile)
    topo, st = cache._topo, cache._state
    n, K = topo["n"], topo["K"]
    N, D, S, axes = shape_key
    srank, valid, nbc = topo["srank"], topo["valid"], topo["nbc"]
    cap, gcap_old = st["cap"], st["gcap"]
    old_part64 = st["part64"]
    oldp_m = old_part64[moved]
    newp_m = part64[moved]
    in_T = np.zeros((S,), bool)
    in_T[oldp_m] = True
    in_T[newp_m] = True
    T = np.flatnonzero(in_T)

    # (1) same/other flags change only at lanes that mention a moved
    # cell (found via the reverse-CSR incidence) or belong to a moved
    # row; recompute those with the scratch formula
    aff = topo["inc_flat"][_expand_segments(topo["inc_ptr"], moved)]
    own_lanes = (moved[:, None] * K + np.arange(K, dtype=np.int64)[None, :]).ravel()
    aff = np.concatenate([aff, own_lanes])
    same = st["same"].copy()
    other = st["other"].copy()
    va = valid.ravel()[aff]
    nb_aff = nbc.ravel()[aff]
    s_new = va & (part64[nb_aff] == part64[aff // K])
    same.ravel()[aff] = s_new
    other.ravel()[aff] = va & ~s_new
    prof.mark("patch_flags_s")

    # (2) merge the moved rows out of / into the sorted owned layout:
    # one searchsorted over the retained keys replaces the global
    # lexsort. Keys are part*n + srank, the scratch sort order.
    moved_mask = np.zeros((n,), bool)
    moved_mask[moved] = True
    keepm = ~moved_mask[st["ocells"]]
    kept_cells = st["ocells"][keepm]
    kept_keys = st["okey"][keepm]
    mkey = newp_m * n + srank[moved]
    mo = np.argsort(mkey, kind="stable")
    mcells = moved[mo]
    mkeys = mkey[mo]
    posm = np.searchsorted(kept_keys, mkeys) + np.arange(mkeys.size, dtype=np.int64)
    fill = np.ones((n,), bool)
    fill[posm] = False
    ocells = np.empty((n,), np.int64)
    okey = np.empty((n,), np.int64)
    ocells[fill] = kept_cells
    okey[fill] = kept_keys
    ocells[posm] = mcells
    okey[posm] = mkeys
    ocounts = st["ocounts"].copy()
    np.subtract.at(ocounts, oldp_m, 1)
    np.add.at(ocounts, newp_m, 1)
    cap2 = _halo._roundup(int(ocounts.max()) if n else 0)
    ostarts = np.concatenate(([0], np.cumsum(ocounts)))
    orank = np.arange(n, dtype=np.int64) - ostarts[okey // n]
    local_pos = np.empty((n,), np.int64)
    local_pos[ocells] = orank
    prof.mark("patch_merge_s")

    # (3) ghost pairs: recompute for the touched parts' rows only,
    # splice against the retained pairs of untouched parts, and re-sort
    # the (small) concatenation — bit-identical because the deduped
    # pair set and its (part, slot-rank) sort key are unchanged
    cells_T = ocells[_expand_segments(ostarts, T)]
    other_T = other[cells_T]
    rr, cc = np.nonzero(other_T)
    gp_t = part64[cells_T[rr]]
    gc_t = nbc[cells_T[rr], cc]
    gr_t = srank[gc_t]
    keep_old = ~in_T[st["gp"]]
    gp2 = np.concatenate([st["gp"][keep_old], gp_t])
    gc2 = np.concatenate([st["gc"][keep_old], gc_t])
    gr2 = np.concatenate([st["gr"][keep_old], gr_t])
    gord = np.lexsort((gr2, gp2))
    gp2, gc2, gr2 = gp2[gord], gc2[gord], gr2[gord]
    if gp2.size:
        kp = np.ones((gp2.size,), bool)
        kp[1:] = (gp2[1:] != gp2[:-1]) | (gr2[1:] != gr2[:-1])
        gp2, gc2, gr2 = gp2[kp], gc2[kp], gr2[kp]
    gcounts = np.bincount(gp2, minlength=S)
    gstarts = np.concatenate(([0], np.cumsum(gcounts)))
    grank = np.arange(gp2.size, dtype=np.int64) - gstarts[gp2]
    gcap = _halo._roundup(max(int(gcounts.max()) if gcounts.size else 0, 1))
    prof.mark("patch_ghost_s")

    # (4) stencil tables: reset the touched parts' padded blocks and
    # refill them with the scratch formulas; untouched blocks are
    # provably what a scratch build would produce. When the owned
    # capacity crosses a roundup quantum the padded block shapes
    # change: copy each block into the re-padded shape (same memcpy
    # the equal-cap patch pays) and shift the ghost-lane entries —
    # they encode ``cap + ghost_rank``, the only cap-dependent values
    # in an untouched block.
    old = cache._last_plan
    if cap2 == cap:
        owned_idx = old.owned_idx.reshape(-1).copy()
        owned_slot = old.owned_slot.reshape(-1).copy()
        nbr_localf = old.nbr_local.reshape(S * cap, K).copy()
        nbr_validf = old.nbr_valid.reshape(S * cap, K).copy()
        coeff_f = old.coeff.reshape(S * cap, K).copy()
        reads_f = st["reads_ghost"].reshape(-1).copy()
    else:
        c = min(cap, cap2)
        oi = np.full((S, cap2), -1, np.int32)
        osl = np.full((S, cap2), -1, np.int64)
        nl = np.zeros((S, cap2, K), np.int32)
        nv = np.zeros((S, cap2, K), bool)
        cf = np.zeros((S, cap2, K), np.float32)
        rg = np.zeros((S, cap2), bool)
        oi[:, :c] = old.owned_idx[:, :c]
        osl[:, :c] = old.owned_slot[:, :c]
        nl[:, :c] = old.nbr_local[:, :c]
        nv[:, :c] = old.nbr_valid[:, :c]
        cf[:, :c] = old.coeff[:, :c]
        rg[:, :c] = st["reads_ghost"][:, :c]
        nl[nv & (nl >= cap)] += cap2 - cap
        owned_idx = oi.reshape(-1)
        owned_slot = osl.reshape(-1)
        nbr_localf = nl.reshape(S * cap2, K)
        nbr_validf = nv.reshape(S * cap2, K)
        coeff_f = cf.reshape(S * cap2, K)
        reads_f = rg.reshape(-1)
        cap = cap2
    blk = (T[:, None] * cap + np.arange(cap, dtype=np.int64)[None, :]).ravel()
    owned_idx[blk] = -1
    owned_slot[blk] = -1
    nbr_localf[blk] = 0
    nbr_validf[blk] = False
    coeff_f[blk] = 0.0
    reads_f[blk] = False
    drow = part64[cells_T] * cap + local_pos[cells_T]
    owned_idx[drow] = cells_T.astype(np.int32)
    owned_slot[drow] = topo["slot64"][cells_T]
    va_T = valid[cells_T]
    nb_T = nbc[cells_T]
    same_T = same[cells_T]
    loc = np.zeros((cells_T.size, K), np.int64)
    loc[same_T] = local_pos[nb_T[same_T]]
    if gp2.size:
        gkey = gp2 * n + gr2
        qk = part64[cells_T[rr]] * n + srank[nb_T[rr, cc]]
        loc[rr, cc] = cap + grank[np.searchsorted(gkey, qk)]
    nbr_localf[drow] = np.where(va_T, loc, 0)
    nbr_validf[drow] = va_T
    coeff_f[drow] = topo["coeff"][cells_T]
    reads_f[drow] = other_T.any(axis=1)

    owned_idx = owned_idx.reshape(S, cap)
    owned_slot = owned_slot.reshape(S, cap)
    nbr_local = nbr_localf.reshape(S, cap, K)
    nbr_valid = nbr_validf.reshape(S, cap, K)
    coeff_l = coeff_f.reshape(S, cap, K)
    reads_ghost = reads_f.reshape(S, cap)

    # interior/boundary split over the patched reads_ghost (cheap, and
    # its caps depend on global counts — patching blocks would not help)
    real = owned_idx >= 0
    pi, ri = np.nonzero(real & ~reads_ghost)
    pb, rb = np.nonzero(real & reads_ghost)
    icounts = np.bincount(pi, minlength=S)
    bcounts = np.bincount(pb, minlength=S)
    icap = _halo._roundup(max(int(icounts.max()) if icounts.size else 0, 1))
    bcap = _halo._roundup(max(int(bcounts.max()) if bcounts.size else 0, 1))
    istarts = np.concatenate(([0], np.cumsum(icounts)))
    bstarts = np.concatenate(([0], np.cumsum(bcounts)))
    interior_idx = np.full((S, icap), -1, np.int32)
    boundary_idx = np.full((S, bcap), -1, np.int32)
    interior_idx[pi, np.arange(pi.size) - istarts[pi]] = ri
    boundary_idx[pb, np.arange(pb.size) - bstarts[pb]] = rb
    prof.mark("patch_tables_s")

    # (5) routing stages re-pack over the (small) ghost pair lists
    if N == 1:
        stages, ghost_fetch = _halo._flat_stages_vec(
            axes[0], S, n, gp2, gc2, gr2, grank, part64, local_pos, gcap
        )
    else:
        stages, ghost_fetch = _halo._two_hop_stages_vec(
            axes, N, D, n, gp2, gc2, gr2, grank, part64, local_pos, gcap
        )
    prof.mark("stage_pack_s")

    mets = _halo._halo_metrics_vec(
        part_a, nbr, ocounts, gcounts, gp2, gc2, D, stages, weights,
        with_quality=with_metrics,
    )
    mets["InteriorCells"] = int(pi.size)
    mets["BoundaryCells"] = int(pb.size)
    cache.stats.halo_hits += 1
    cache.stats.patched_rows += int(cells_T.size)
    mets["PlanCacheHits"] = cache.stats.halo_hits
    mets["PatchedRows"] = int(cells_T.size)
    mets["PlanBuildSeconds"] = time.perf_counter() - t_build
    prof.mark("metrics_s")
    plan = _halo.HaloPlan(
        axes=axes, num_parts=S, cap=cap, gcap=gcap, K=K,
        owned_idx=owned_idx, owned_slot=owned_slot, nbr_local=nbr_local,
        nbr_valid=nbr_valid, coeff=coeff_l, stages=stages,
        ghost_fetch=ghost_fetch, interior_idx=interior_idx,
        boundary_idx=boundary_idx, metrics=mets,
    )
    cache._stash_prev()
    cache._install_state(plan, shape_key, dict(
        part64=part64, ocells=ocells, okey=okey, ocounts=ocounts,
        local_pos=local_pos, same=same, other=other,
        gp=gp2, gc=gc2, gr=gr2, gcounts=gcounts,
        reads_ghost=reads_ghost, cap=cap, gcap=gcap,
    ))
    return plan
