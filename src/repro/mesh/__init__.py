"""Mesh application layer: adaptive meshes + halo exchange + distributed
stencil on the partition core (the paper's primary workload)."""
from repro.mesh import amr, halo, plan_cache, simulate, stencil  # noqa: F401
from repro.mesh.amr import (  # noqa: F401
    AMRMesh,
    Transfer,
    apply_transfer,
    face_neighbors,
    feature_weights,
    refine_coarsen,
    stencil_coeffs,
    uniform_mesh,
)
from repro.mesh.halo import (  # noqa: F401
    HaloPlan,
    MovePlan,
    build_halo_plan,
    build_halo_plan_legacy,
    build_move_plan,
    build_move_plan_legacy,
    owners_from_index,
    plan_quality_metrics,
)
from repro.mesh.plan_cache import PlanCache, PlanCacheStats  # noqa: F401
from repro.mesh.simulate import (  # noqa: F401
    SimConfig,
    build_trajectory,
    initial_field,
    run_distributed,
    run_reference,
)
from repro.mesh.stencil import reference_stencil, stencil_steps  # noqa: F401
