"""End-to-end AMR simulation: the first consumer that closes the loop
partitioner -> repartition -> migration -> sharding -> metrics.

A moving load feature drives the adaptive mesh (refine/coarsen) and the
per-cell cost field; the `HierarchicalRepartitioner` (paper Alg. 3)
re-slices as the feature moves; `repro.core.migration`-accounted move
plans carry the cell state to its new owners on device; the compiled
halo plans execute the distributed heat stencil between events.

The trajectory (mesh sequence, neighbor tables, coefficients, weights,
transfer maps) is a pure function of the config — built ONCE and shared
by every backend — so the single-device reference and the distributed
runs integrate the *identical* discrete system and their fields are
bitwise comparable at every event boundary.

Two distributed drivers, the benchmark's comparison axis:

* ``driver="incremental"`` — ``engine.step()``: the Alg. 3 credit
  trigger answers drift with (mostly intra-node) re-slices; state moves
  are moved-rows-only, over a single intra-node hop whenever the
  level-aware migration plan certifies zero inter-node movement.
* ``driver="rebuild"`` — ``engine.rebuild()`` every event plus a full
  redistribute (every row staged through the exchange), the cold path
  the paper's incremental economics are measured against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.mesh import amr as _amr
from repro.mesh import halo as _halo


@dataclass(frozen=True)
class SimConfig:
    d: int = 2
    base_level: int = 3
    max_level: int = 5
    events: int = 12            # outer timesteps (weight drift per event)
    amr_every: int = 4          # refine/coarsen every k-th event
    substeps: int = 2           # stencil sweeps per event
    # feature path (dim 0 walk; confine [x0, x1] to one node's curve span
    # to exercise the provably node-local regime)
    x0: float = 0.15
    x1: float = 0.85
    amp: float = 4.0
    sigma: float = 0.12
    r_refine: float = 0.15
    r_coarsen: float = 0.30
    # engine knobs
    bucket_size: int = 8
    engine_max_depth: int = 10
    node_threshold: float = 1.20
    dt_safety: float = 0.2


@dataclass(frozen=True)
class Event:
    t: int
    center: np.ndarray
    mesh: _amr.AMRMesh
    nbr: np.ndarray
    coeff: np.ndarray
    weights: np.ndarray
    transfer: "_amr.Transfer | None"   # None: same cells as previous event


def build_trajectory(cfg: SimConfig) -> list[Event]:
    """The mesh/load schedule both backends integrate (deterministic)."""
    mesh = _amr.uniform_mesh(cfg.d, cfg.base_level, cfg.max_level)
    dt = _amr.stable_dt(0.5 ** cfg.max_level, cfg.dt_safety) / max(cfg.d, 2) * 2
    events: list[Event] = []
    denom = max(cfg.events - 1, 1)
    nbr = coeff = None
    for t in range(cfg.events):
        c = _amr.feature_center(t / denom, cfg.d, x0=cfg.x0, x1=cfg.x1)
        transfer = None
        if t > 0 and cfg.amr_every and t % cfg.amr_every == 0:
            ref, coar = _amr.adapt_masks(
                mesh, c, r_refine=cfg.r_refine, r_coarsen=cfg.r_coarsen
            )
            mesh, transfer = _amr.refine_coarsen(mesh, ref, coar)
        if transfer is not None or nbr is None:
            # the adjacency and coefficients depend only on the mesh —
            # recompute them only when the cells actually changed
            nbr = _amr.face_neighbors(mesh)
            coeff = _amr.stencil_coeffs(mesh, nbr, dt)
        w = _amr.feature_weights(mesh.centers(), c, amp=cfg.amp, sigma=cfg.sigma)
        events.append(Event(t, c, mesh, nbr, coeff, w, transfer))
    return events


def initial_field(mesh: _amr.AMRMesh, cfg: SimConfig) -> np.ndarray:
    """A heat blob at the feature's starting position."""
    c = _amr.feature_center(0.0, cfg.d, x0=cfg.x0, x1=cfg.x1)
    d2 = np.sum((mesh.centers().astype(np.float64) - c[None, :]) ** 2, axis=1)
    return np.exp(-d2 / 0.02).astype(np.float32)


def run_reference(events: list[Event], u0: np.ndarray, substeps: int) -> np.ndarray:
    """Single-device integration of the trajectory (the bitwise oracle)."""
    from repro.mesh import stencil as _st

    u = np.asarray(u0, np.float32)
    for ev in events:
        if ev.transfer is not None:
            u = _amr.apply_transfer(u, ev.transfer)
        u = np.asarray(
            _st.reference_stencil(u, ev.nbr, ev.nbr >= 0, ev.coeff, substeps)
        )
    return u


@dataclass
class SimStats:
    events: int = 0
    amr_events: int = 0
    repartition_events: int = 0     # events whose assignment changed
    intra_reslices: int = 0
    inter_reslices: int = 0
    rebuilds: int = 0
    moved_total: int = 0
    moved_inter_node: int = 0
    node_local_moves: int = 0       # moves executed on the device-axis-only hop
    engine_s: float = 0.0
    move_s: float = 0.0
    stencil_s: float = 0.0
    # host-side plan construction (halo + move), summed from the
    # builders' own PlanBuildSeconds — the cost that bounds how often
    # repartitioning can pay off
    plan_build_s: float = 0.0
    # cross-event plan-cache behavior (repro.mesh.plan_cache): builds
    # served by delta patching / scratch fallbacks / owned rows the
    # patches rewrote (vs n_cells * events a scratch build would touch)
    plan_cache_hits: int = 0
    plan_cache_misses: int = 0
    plan_patched_rows: int = 0
    # per-phase attribution of the sweep, measured once per compiled
    # plan by the single-phase probes (reporting only: the hot loop runs
    # the one fused overlapped program, where interior compute hides
    # behind the in-flight exchange)
    stencil_exchange_s: float = 0.0
    stencil_interior_s: float = 0.0
    stencil_boundary_s: float = 0.0
    cells_final: int = 0
    halo_metrics: dict = field(default_factory=dict)


def run_distributed(
    events: list[Event],
    u0: np.ndarray,
    substeps: int,
    jax_mesh,
    hplan,
    *,
    driver: str = "incremental",
    cfg: SimConfig = SimConfig(),
    phase_probes: bool = False,
) -> tuple[np.ndarray, SimStats]:
    """Integrate the trajectory on a device mesh under one driver.

    ``hplan`` is the `partitioner.HierarchyPlan`; its ``num_parts`` must
    equal the device count of ``jax_mesh`` (parts name shards). Returns
    the final field in global cell order plus phase timings/accounting.
    ``phase_probes`` additionally attributes sweep walltime to its
    exchange/interior/boundary phases via the single-phase probe
    executors (extra per-event probe calls — reporting, not the gate).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import partitioner as _pt
    from repro.core.repartition import HierarchicalRepartitioner
    from repro.mesh import stencil as _st

    if driver not in ("incremental", "rebuild"):
        raise ValueError(f"unknown driver {driver!r}")
    max_n = max(ev.mesh.n for ev in events)
    ev0 = events[0]
    pcfg = _pt.PartitionerConfig(use_tree=True, curve="hilbert")
    rp = HierarchicalRepartitioner(
        jnp.asarray(ev0.mesh.centers()),
        jnp.asarray(ev0.weights),
        plan=hplan,
        cfg=pcfg,
        node_threshold=cfg.node_threshold,
        capacity=2 * max_n,
        bucket_size=cfg.bucket_size,
        max_depth=cfg.engine_max_depth,
    )
    slots = np.arange(ev0.mesh.n, dtype=np.int64)  # from_points fills 0..n-1
    # one plan cache per run: reslice events delta-patch the previous
    # event's construction state instead of rebuilding from scratch;
    # the engine's topology_version keys the AMR-sensitive tier
    plan_cache = _halo.PlanCache()

    st = SimStats()
    u_host = np.asarray(u0, np.float32)
    u_dev = None
    prev_plan: "_halo.HaloPlan | None" = None
    prev_args = None
    prev_n = ev0.mesh.n
    quality_args = None   # (part, nbr, weights) of the last-built plan
    # per-slot view of the previous assignment: slots survive AMR events,
    # so "did the partition change" is answerable across cell rebirths
    part_by_slot = np.full((rp.capacity,), -1, np.int64)

    for ev in events:
        st.events += 1
        if ev.transfer is not None:
            st.amr_events += 1
            # state comes home once per AMR event (cells change identity)
            if u_dev is not None:
                u_host = prev_plan.unpack_cells(np.asarray(u_dev), prev_n)
            u_host = _amr.apply_transfer(u_host, ev.transfer)
            died = slots[ev.transfer.died_idx]
            if died.size:
                rp.delete(jnp.asarray(died))
            slots_new = np.full((ev.mesh.n,), -1, np.int64)
            kept = ~ev.transfer.born
            slots_new[kept] = slots[ev.transfer.src[kept, 0]]
            born_idx = np.nonzero(ev.transfer.born)[0]
            if born_idx.size:
                got = rp.insert(
                    jnp.asarray(ev.mesh.centers()[born_idx]),
                    jnp.asarray(ev.weights[born_idx]),
                )
                slots_new[born_idx] = np.asarray(got)
            slots = slots_new
            u_dev = None  # relayout from host below

        # --- engine: weights drift, Alg. 3 answers ------------------------
        t0 = time.perf_counter()
        rp.update_weights(jnp.asarray(ev.weights), slot_ids=jnp.asarray(slots))
        if driver == "incremental":
            rp.step()
        else:
            rp.rebuild()
        st.engine_s += time.perf_counter() - t0

        part_cells = rp.partition_of(slots)
        # changed = any surviving slot owned by a different part than at
        # the previous event (slots are the stable identity, so this is
        # well-defined across AMR rebirths too)
        had_prev = part_by_slot[slots] >= 0
        changed = bool((part_by_slot[slots][had_prev] != part_cells[had_prev]).any())
        if changed:
            st.repartition_events += 1
        part_by_slot[:] = -1
        part_by_slot[slots] = part_cells
        if ev.transfer is None and not changed and prev_plan is not None:
            # same cells, same assignment: the compiled plan (and its
            # device-resident tables) is identical — reuse it instead of
            # re-running the host-side plan construction. Its quality
            # metrics keep the weights of the event that built it.
            plan, args = prev_plan, prev_args
        else:
            # hot path: skip the O(n*K) quality report — the loop never
            # reads it; the final report is recovered once after the loop
            plan = _halo.build_halo_plan(
                slots, part_cells, ev.nbr, ev.coeff,
                hierarchy=hplan, weights=ev.weights, with_metrics=False,
                cache=plan_cache, topo_token=rp.topology_version,
            )
            st.plan_build_s += plan.metrics["PlanBuildSeconds"]
            quality_args = (part_cells, ev.nbr, ev.weights)
            args = _st.halo_args(jax_mesh, plan)

        # --- state placement ---------------------------------------------
        if u_dev is None:
            u_dev = _st.put_state(jax_mesh, plan, u_host)
        else:
            if changed or driver == "rebuild":
                mv = _halo.build_move_plan(
                    prev_plan, plan, hierarchy=hplan, full=driver == "rebuild",
                    cache=plan_cache,
                )
                st.plan_build_s += mv.metrics["PlanBuildSeconds"]
                t0 = time.perf_counter()
                u_dev = jax.block_until_ready(
                    _st.move_state(jax_mesh, mv, prev_plan, u_dev)
                )
                st.move_s += time.perf_counter() - t0
                mig = mv.migration
                st.moved_total += int(mig.total_moved)
                st.moved_inter_node += int(getattr(mig, "inter_moved", 0))
                if mv.kind == "device":
                    st.node_local_moves += 1
            elif plan.cap != prev_plan.cap:
                # same assignment, rounded capacity drifted: repack locally
                u_dev = _st.put_state(
                    jax_mesh, plan, prev_plan.unpack_cells(np.asarray(u_dev), prev_n)
                )

        # --- stencil sweeps ------------------------------------------------
        if phase_probes:
            ph = _st.stencil_phase_times(jax_mesh, plan, u_dev, args)
            st.stencil_exchange_s += substeps * ph["exchange"]
            st.stencil_interior_s += substeps * ph["interior"]
            st.stencil_boundary_s += substeps * ph["boundary"]
        t0 = time.perf_counter()
        u_dev = jax.block_until_ready(
            _st.stencil_steps(jax_mesh, plan, u_dev, args, substeps)
        )
        st.stencil_s += time.perf_counter() - t0

        prev_plan, prev_args, prev_n = plan, args, ev.mesh.n

    st.intra_reslices = rp.stats.intra_reslices
    st.inter_reslices = rp.stats.inter_reslices
    st.rebuilds = rp.stats.rebuilds
    st.plan_cache_hits = plan_cache.stats.halo_hits + plan_cache.stats.move_hits
    st.plan_cache_misses = plan_cache.stats.halo_misses + plan_cache.stats.move_misses
    st.plan_patched_rows = plan_cache.stats.patched_rows
    st.cells_final = prev_n
    st.halo_metrics = dict(prev_plan.metrics)
    if quality_args is not None:
        # recover the quality report the with_metrics=False builds
        # skipped — once, for the final plan, instead of per event
        qp, qn, qw = quality_args
        st.halo_metrics.update(
            _halo.plan_quality_metrics(qp, qn, prev_plan.num_parts, weights=qw)
        )
    return prev_plan.unpack_cells(np.asarray(u_dev), prev_n), st
