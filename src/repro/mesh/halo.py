"""Halo (ghost-cell) exchange plans over the partition core.

A mesh partition's communication structure is *static between partition
events*: which cells each part must read from its neighbors (the ghost
set) follows entirely from the face-adjacency graph and the part
assignment. This module compiles that structure — once per repartition
event, on the host — into fixed-shape send/recv index tables that the
jitted ``shard_map`` executors in :mod:`repro.mesh.stencil` replay every
stencil step with zero routing logic on device.

Two plan flavors, mirroring PR 4's two-level machinery:

* **flat** (1-D mesh): one all_to_all; lane (o, p) carries the cells of
  owner o that part p ghosts.
* **hierarchical** ((node, device) mesh, `partitioner.HierarchyPlan`):
  two hops. Hop A runs over the NODE axis only and is deduplicated per
  destination node — a cell ghosted by three devices of node m crosses
  the inter-node boundary once. Hop B fans the values out over the
  DEVICE axis inside the destination node. Ghosts whose owner sits on
  the requester's own node ride hop A's self-lane, which never leaves
  the node — node-local ghosts never cross the inter-node boundary, by
  construction.

Each plan also compiles the *interior/boundary split* of the owned
rows (fixed-shape index sets): interior rows have no ghost neighbors,
so the executors can update them while the exchange collectives are in
flight and apply only the boundary rows after the recv lands.

Ghost *ownership* is resolved against the ``CurveIndex`` directory
(:func:`owners_from_index`): a face neighbor's key is looked up in the
O(B) bucket directory and the bucket's part is read off — the same
directory hop the query layer uses, and the lookup a real distributed
mesh would do (no global part array required). ``build_halo_plan``
accepts the resulting (or any) part vector.

Migration rides the same machinery: :func:`build_move_plan` compiles the
state exchange for a partition change — moved-only rows for an
incremental re-slice (a single intra-node hop when the migration plan
certifies zero inter-node movement), or the full redistribute a rebuild
pays — with `repro.core.migration` providing the level-aware accounting.

**Plan cost is a hot-path cost.** Plans are rebuilt on every
repartition event, so host-side construction bounds how *dynamic* a
dynamic workload can be (the paper's "minimal partitioning cost"
requirement). The default builders therefore contain **zero per-part
and zero per-cell Python loops**: every table is produced by numpy
segment operations — one ``lexsort`` over (part, slot) defines the
owned layout, sorted-run ranks fill the lane tables, ``searchsorted``
over a packed (part, slot-rank) key replaces the per-part ghost
position dicts, and the hop-A dedup is a sorted-unique over
(owner, dest-node, cell). The canonical ascending-slot ordering makes
the output a pure function of ``(slot, part, nbr, coeff)``, so the
vectorized builders are **bit-identical** to the straightforward
per-part reference builders (:func:`build_halo_plan_legacy`,
:func:`build_move_plan_legacy`), which are kept as the equivalence-test
oracle and the ``benchmarks/bench_plans.py`` baseline. Every builder
records its own walltime as ``PlanBuildSeconds`` in ``plan.metrics``;
``with_metrics=False`` skips the O(n*K) partition-quality pass
(``partition_report`` over the face-edge list) for hot-loop callers
that do not read it — the returned index tables are identical either
way (:func:`plan_quality_metrics` recovers the skipped report).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import metrics as _metrics
from repro.core import migration as _migration
from repro.mesh import amr as _amr

# merge sentinel: sorts after every real storage-slot id
GID_SENTINEL = np.int32(2**31 - 1)


def _roundup(x: int, q: int = 8) -> int:
    """Round capacities up so nearby plans share compiled executors."""
    return max(q, ((int(x) + q - 1) // q) * q)


class _ProfTimer:
    """Accumulates per-stage build walltime into a caller-owned dict.

    A no-op when ``sink`` is None, so the hot path pays one branch per
    section. Keys accumulate, so patched and scratch sections of one
    bench run can share a sink."""

    __slots__ = ("sink", "t")

    def __init__(self, sink):
        self.sink = sink
        self.t = time.perf_counter() if sink is not None else 0.0

    def mark(self, key):
        if self.sink is None:
            return
        now = time.perf_counter()
        self.sink[key] = self.sink.get(key, 0.0) + (now - self.t)
        self.t = now


@dataclass(frozen=True)
class Stage:
    """One all_to_all hop of a routing plan.

    ``idx`` (S, lanes, cap) int32 holds, per device, the source position
    of each (lane, slot) entry in the device's PREVIOUS buffer (the
    owned value array for hop 0, the previous hop's receive buffer
    after); -1 pads. ``lanes`` equals the mesh extent of ``axis``."""

    axis: str
    lanes: int
    cap: int
    idx: np.ndarray


@dataclass(frozen=True)
class HaloPlan:
    """Compiled ghost-exchange + stencil tables for one partition.

    Per-device canonical order is ascending storage-slot id — for owned
    cells and ghosts alike — so the layout is reproducible from
    ``(slot, part)`` alone and migration merges can realign by sorting
    on slot ids.
    """

    axes: tuple[str, ...]          # mesh axes the executors shard over
    num_parts: int
    cap: int                       # owned cells per device (padded)
    gcap: int                      # ghost cells per device (padded)
    K: int                         # neighbor slots per cell
    owned_idx: np.ndarray          # (S, cap) int32 cell index, -1 pad
    owned_slot: np.ndarray         # (S, cap) int64 slot id, -1 pad
    nbr_local: np.ndarray          # (S, cap, K) int32 into [0, cap+gcap)
    nbr_valid: np.ndarray          # (S, cap, K) bool
    coeff: np.ndarray              # (S, cap, K) float32
    stages: tuple[Stage, ...]      # value-routing hops
    ghost_fetch: np.ndarray        # (S, gcap) int32 into final recv, -1 pad
    # interior/boundary split of the owned rows, compiled into the plan:
    # a row is *interior* iff every valid neighbor slot points below
    # ``cap`` (owned by the same device), so its update is provably
    # independent of the ghost exchange; *boundary* rows read at least
    # one ghost. The sets partition the real owned rows (-1 pads) and
    # let the executor update interior cells while the exchange is in
    # flight, applying boundary rows only after the recv lands.
    interior_idx: np.ndarray = None  # (S, icap) int32 local row, -1 pad
    boundary_idx: np.ndarray = None  # (S, bcap) int32 local row, -1 pad
    metrics: dict = field(default_factory=dict)

    @property
    def stage_meta(self) -> tuple:
        """Static executor signature: ((axis, lanes, cap), ...)."""
        return tuple((s.axis, s.lanes, s.cap) for s in self.stages)

    def pack_cells(self, u_cells: np.ndarray) -> np.ndarray:
        """Global cell-order field -> (S*cap,) owned device layout."""
        out = np.zeros((self.owned_idx.shape[0], self.cap), np.float32)
        m = self.owned_idx >= 0
        out[m] = np.asarray(u_cells, np.float32)[self.owned_idx[m]]
        return out.reshape(-1)

    def unpack_cells(self, u_dev: np.ndarray, n_cells: int) -> np.ndarray:
        """(S*cap,) owned device layout -> global cell-order field."""
        u = np.asarray(u_dev, np.float32).reshape(self.owned_idx.shape)
        out = np.zeros((n_cells,), np.float32)
        m = self.owned_idx >= 0
        out[self.owned_idx[m]] = u[m]
        return out


def owners_from_index(index, part_by_slot: np.ndarray, centers) -> np.ndarray:
    """Owning part of each query center, resolved through the
    ``CurveIndex`` directory (key -> bucket -> part).

    ``part_by_slot`` is the engine's per-slot assignment; parts are
    constant within a directory bucket on the tree-backed path (buckets
    are the knapsack units), so the bucket's first sorted entry carries
    its part. This is the halo layer's routing view of the partition —
    O(B) directory state instead of an O(n) global part array — and
    tests hold it equal to the direct per-cell lookup.
    """
    import jax.numpy as jnp

    from repro.core import curve_index as _ci

    part_sorted = np.asarray(part_by_slot)[np.asarray(index.ids)]
    bucket_part = part_sorted[np.asarray(index.bucket_starts)[:-1]]
    qk = _ci.query_keys(index, jnp.asarray(centers, jnp.float32))
    b = np.asarray(_ci.bucket_lookup(index, qk))
    return bucket_part[b].astype(np.int32)


# ---------------------------------------------------------------------------
# shared plan geometry
# ---------------------------------------------------------------------------

def _plan_shape(part, hierarchy, num_parts, device_axis):
    """Resolve (N, D, S, axes) — shared by both builder implementations."""
    if hierarchy is not None and hierarchy.num_nodes > 1:
        N, D = int(hierarchy.num_nodes), int(hierarchy.devices_per_node)
        axes = (hierarchy.node_axis, hierarchy.device_axis)
    else:
        N = 1
        if hierarchy is not None:
            D = int(hierarchy.num_parts)
            device_axis = hierarchy.device_axis
        else:
            D = int(num_parts) if num_parts is not None else int(part.max()) + 1
        axes = (device_axis,)
    return N, D, N * D, axes


def _run_ranks(keys_sorted: np.ndarray) -> np.ndarray:
    """Rank of each element within its run of equal keys (keys sorted)."""
    m = keys_sorted.shape[0]
    if m == 0:
        return np.zeros((0,), np.int64)
    start = np.ones((m,), bool)
    start[1:] = keys_sorted[1:] != keys_sorted[:-1]
    starts = np.nonzero(start)[0]
    run_id = np.cumsum(start) - 1
    return np.arange(m, dtype=np.int64) - starts[run_id]


def plan_quality_metrics(part, nbr, num_parts, weights=None) -> dict:
    """The O(n*K) partition-quality report a ``with_metrics=False`` plan
    skipped: the paper's table columns (`metrics.partition_report`) over
    the face-edge list. Callers that build plans on the hot loop run it
    once for reporting instead of on every repartition event."""
    part = np.asarray(part)
    n = part.shape[0]
    w = np.ones((n,), np.float64) if weights is None else np.asarray(weights, np.float64)
    return _metrics.partition_report(
        part, w, int(num_parts), edges=_amr.neighbor_edges(nbr)
    )


# ---------------------------------------------------------------------------
# plan construction — vectorized (the default builder)
# ---------------------------------------------------------------------------

def build_halo_plan(
    slot: np.ndarray,
    part: np.ndarray,
    nbr: np.ndarray,
    coeff: np.ndarray,
    *,
    hierarchy=None,
    num_parts: int | None = None,
    device_axis: str = "device",
    weights: np.ndarray | None = None,
    with_metrics: bool = True,
    cache=None,
    topo_token=None,
    profile: dict | None = None,
    _topo=None,
    _capture: dict | None = None,
) -> HaloPlan:
    """Compile the ghost exchange + local stencil tables for one
    partition of one mesh.

    ``slot`` (n,) storage-slot ids (stable identity), ``part`` (n,) the
    owning part per cell (parts name shards), ``nbr``/``coeff`` the
    (n, K) face tables from :mod:`repro.mesh.amr`. ``hierarchy`` (a
    `partitioner.HierarchyPlan` with num_nodes > 1) selects the two-hop
    node-aware exchange; otherwise the plan is flat over
    ``device_axis``. ``weights`` feed the load columns of the quality
    metrics (default: unit cell cost). ``with_metrics=False`` skips the
    O(n*K) `partition_report` quality pass (recoverable later via
    :func:`plan_quality_metrics`); every other output — including the
    cheap segment-sum halo metrics — is identical.

    ``cache`` (a :class:`repro.mesh.plan_cache.PlanCache`) persists the
    construction intermediates across repartition events and
    delta-patches only the part segments whose owner set changed — the
    output is bit-identical to the from-scratch build (see
    ``plan_cache``). ``topo_token`` keys the cached topology state (pass
    the engine's ``topology_version``); a changed token forces a
    topology refresh. ``profile`` (a dict) accumulates per-stage build
    seconds. ``_topo``/``_capture`` are the cache's private handshake
    with the scratch builder.

    The construction is pure numpy segment ops (no per-part or per-cell
    Python loops) and is bit-identical to
    :func:`build_halo_plan_legacy`, the per-part reference builder.
    """
    if cache is not None:
        from repro.mesh import plan_cache as _plan_cache

        return _plan_cache.cached_build_halo_plan(
            cache, slot, part, nbr, coeff, hierarchy=hierarchy,
            num_parts=num_parts, device_axis=device_axis, weights=weights,
            with_metrics=with_metrics, topo_token=topo_token, profile=profile,
        )
    t_build = time.perf_counter()
    prof = _ProfTimer(profile)
    slot = np.asarray(slot, np.int64)
    part = np.asarray(part)
    n, K = nbr.shape
    N, D, S, axes = _plan_shape(part, hierarchy, num_parts, device_axis)
    part64 = part.astype(np.int64)
    if n and (part64.min() < 0 or part64.max() >= S):
        raise ValueError(f"part ids must lie in [0, {S})")

    # slot-rank compression: ordering by slot == ordering by rank, and
    # ranks stay < n so packed (part, rank) keys cannot overflow int64.
    # All three arrays are pure functions of the topology (slot, nbr) —
    # the cache hands them back via ``_topo`` on AMR-free events.
    if _topo is not None:
        srank, valid, nbc = _topo
    else:
        sorder = np.argsort(slot, kind="stable")
        srank = np.empty((n,), np.int64)
        srank[sorder] = np.arange(n, dtype=np.int64)
        valid = nbr >= 0
        nbc = np.where(valid, nbr, 0).astype(np.int64)
        if _capture is not None:
            _capture["sorder"] = sorder
    prof.mark("slot_sort_s")

    # --- owned layout: one lexsort over (part, slot) -----------------------
    ocells = np.lexsort((slot, part64))            # cells by (part, slot)
    oprow = part64[ocells]                          # owning part per row
    ocounts = np.bincount(oprow, minlength=S)
    ostarts = np.concatenate(([0], np.cumsum(ocounts)))
    orank = np.arange(n, dtype=np.int64) - ostarts[oprow]
    local_pos = np.empty((n,), np.int64)
    local_pos[ocells] = orank
    prof.mark("owned_lexsort_s")

    # one (n, K) gather of the neighbor's owner, shared by the ghost
    # pass and the stencil tables (the dominant cost at ~1M cells)
    pn = part64[nbc]                                # neighbor's owner
    same = valid & (pn == part64[:, None])
    other = valid & ~same                           # ghost-reading lanes
    prof.mark("gather_s")

    # --- ghost sets: cross-part face pairs, deduped per (part, slot) ------
    grow, gcol = np.nonzero(other)
    gp, gc = part64[grow], nbc[grow, gcol]
    gr = srank[gc]
    gord = np.lexsort((gr, gp))
    gp, gc, gr = gp[gord], gc[gord], gr[gord]
    if gp.size:
        keep = np.ones((gp.size,), bool)
        keep[1:] = (gp[1:] != gp[:-1]) | (gr[1:] != gr[:-1])
        gp, gc, gr = gp[keep], gc[keep], gr[keep]
    gcounts = np.bincount(gp, minlength=S)
    gstarts = np.concatenate(([0], np.cumsum(gcounts)))
    grank = np.arange(gp.size, dtype=np.int64) - gstarts[gp]
    prof.mark("ghost_dedup_s")

    cap = _roundup(int(ocounts.max()) if n else 0)
    gcap = _roundup(max(int(gcounts.max()) if gcounts.size else 0, 1))

    # flat destination row of every cell in its owner's (cap-padded) block
    drow = part64 * cap + local_pos
    owned_idx = np.full((S * cap,), -1, np.int32)
    owned_slot = np.full((S * cap,), -1, np.int64)
    owned_idx[drow] = np.arange(n, dtype=np.int32)
    owned_slot[drow] = slot

    # --- local stencil tables: one global (part, slot-rank) ghost lookup --
    loc = np.zeros((n, K), np.int64)
    loc[same] = local_pos[nbc[same]]
    if gp.size:
        gkey = gp * n + gr                          # ascending by build order
        pos = np.searchsorted(gkey, part64[grow] * n + srank[nbc[grow, gcol]])
        loc[grow, gcol] = cap + grank[pos]
    nbr_local = np.zeros((S * cap, K), np.int32)
    nbr_valid = np.zeros((S * cap, K), bool)
    coeff_l = np.zeros((S * cap, K), np.float32)
    nbr_local[drow] = np.where(valid, loc, 0)
    nbr_valid[drow] = valid
    coeff_l[drow] = coeff
    owned_idx = owned_idx.reshape(S, cap)
    owned_slot = owned_slot.reshape(S, cap)
    nbr_local = nbr_local.reshape(S, cap, K)
    nbr_valid = nbr_valid.reshape(S, cap, K)
    coeff_l = coeff_l.reshape(S, cap, K)

    # --- interior/boundary split -------------------------------------------
    # a row reads a ghost iff any of its lanes is an `other` lane (valid
    # neighbor owned elsewhere — exactly the lanes with loc >= cap);
    # rows beyond the owned count belong to neither set
    reads_ghost = np.zeros((S * cap,), bool)
    reads_ghost[drow] = other.any(axis=1)
    reads_ghost = reads_ghost.reshape(S, cap)
    real = owned_idx >= 0
    pi, ri = np.nonzero(real & ~reads_ghost)        # row-major: part, then row
    pb, rb = np.nonzero(real & reads_ghost)
    icounts = np.bincount(pi, minlength=S)
    bcounts = np.bincount(pb, minlength=S)
    icap = _roundup(max(int(icounts.max()) if icounts.size else 0, 1))
    bcap = _roundup(max(int(bcounts.max()) if bcounts.size else 0, 1))
    istarts = np.concatenate(([0], np.cumsum(icounts)))
    bstarts = np.concatenate(([0], np.cumsum(bcounts)))
    interior_idx = np.full((S, icap), -1, np.int32)
    boundary_idx = np.full((S, bcap), -1, np.int32)
    interior_idx[pi, np.arange(pi.size) - istarts[pi]] = ri
    boundary_idx[pb, np.arange(pb.size) - bstarts[pb]] = rb
    prof.mark("tables_s")

    # --- routing stages ----------------------------------------------------
    if N == 1:
        stages, ghost_fetch = _flat_stages_vec(
            axes[0], S, n, gp, gc, gr, grank, part64, local_pos, gcap
        )
    else:
        stages, ghost_fetch = _two_hop_stages_vec(
            axes, N, D, n, gp, gc, gr, grank, part64, local_pos, gcap
        )
    prof.mark("stage_pack_s")

    mets = _halo_metrics_vec(
        part, nbr, ocounts, gcounts, gp, gc, D, stages, weights,
        with_quality=with_metrics,
    )
    mets["InteriorCells"] = int(pi.size)
    mets["BoundaryCells"] = int(pb.size)
    mets["PlanBuildSeconds"] = time.perf_counter() - t_build
    prof.mark("metrics_s")
    if _capture is not None:
        _capture.update(
            part64=part64, srank=srank, valid=valid, nbc=nbc,
            ocells=ocells, okey=oprow * n + srank[ocells],
            ocounts=ocounts, local_pos=local_pos, same=same, other=other,
            gp=gp, gc=gc, gr=gr, gcounts=gcounts,
            reads_ghost=reads_ghost, cap=cap, gcap=gcap,
        )
    return HaloPlan(
        axes=axes,
        num_parts=S,
        cap=cap,
        gcap=gcap,
        K=K,
        owned_idx=owned_idx,
        owned_slot=owned_slot,
        nbr_local=nbr_local,
        nbr_valid=nbr_valid,
        coeff=coeff_l,
        stages=stages,
        ghost_fetch=ghost_fetch,
        interior_idx=interior_idx,
        boundary_idx=boundary_idx,
        metrics=mets,
    )


def _flat_stages_vec(axis, S, n, gp, gc, gr, grank, part64, local_pos, gcap):
    """One all_to_all, filled by sorted-run ranks: lane (o -> p) carries
    o's cells that p ghosts, in p's ghost order (ascending slot)."""
    gowner = part64[gc]
    counts = np.bincount(gowner * S + gp, minlength=S * S)
    hcap = _roundup(int(counts.max()) if counts.size else 1)
    ord2 = np.lexsort((gr, gowner, gp))             # (p, o, slot) runs
    t = _run_ranks((gp * S + gowner)[ord2])
    idx = np.full((S, S, hcap), -1, np.int32)
    idx[gowner[ord2], gp[ord2], t] = local_pos[gc[ord2]]
    fetch = np.full((S, gcap), -1, np.int32)
    fetch[gp[ord2], grank[ord2]] = gowner[ord2] * hcap + t
    return (Stage(axis=axis, lanes=S, cap=hcap, idx=idx),), fetch


def _two_hop_stages_vec(axes, N, D, n, gp, gc, gr, grank, part64, local_pos, gcap):
    """Node-aware exchange via segment ops: hop A (node axis,
    per-destination-node dedup = sorted-unique over (owner, dest node,
    cell)), hop B (device axis, fan-out inside the node).

    Shard ids are node-major (shard = node * D + device). Hop A: owner
    (n_o, d_o) stages each cell once per destination NODE m; after the
    node-axis all_to_all the value sits on intermediate device (m, d_o)
    at flat position n_o * capA + t. Hop B: (m, d_o) restages into
    device lanes; requester (m, d') fetches at d_o * capB + t2. Ghosts
    with m == n_o use hop A's self-lane — intra-node by construction.
    """
    node_axis, device_axis = axes
    S = N * D
    gowner = part64[gc]
    gnode = gp // D                                  # destination node m
    # hop A dedup: unique (owner, dest node, slot), ranked by slot
    ordA = np.lexsort((gr, gnode, gowner))
    keyA = (gowner * N + gnode) * n + gr             # unique per (o, m, cell)
    kA = keyA[ordA]
    keep = np.ones((kA.size,), bool)
    keep[1:] = kA[1:] != kA[:-1]
    Ao = gowner[ordA][keep]
    Am = gnode[ordA][keep]
    Ac = gc[ordA][keep]
    Akey = kA[keep]
    grpA = Ao * N + Am
    tA = _run_ranks(grpA)
    sizesA = np.bincount(grpA, minlength=S * N)
    capA = _roundup(int(sizesA.max()) if Ao.size else 1)
    idxA = np.full((S, N, capA), -1, np.int32)
    idxA[Ao, Am, tA] = local_pos[Ac]
    # per-ghost hop-A slot via one searchsorted on the dedup keys
    posA = np.searchsorted(Akey, keyA)
    srcA = (gowner // D) * capA + tA[posA]           # position in q's recvA

    # hop B: intermediate (m, d_o) restages recvA entries to device lanes
    d_o = gowner % D
    q = gnode * D + d_o                              # intermediate shard
    d_req = gp % D
    ordB = np.lexsort((gr, d_o, gp))                 # (p, d_o, slot) runs
    t2 = _run_ranks((gp * D + d_o)[ordB])
    capB = _roundup(int(t2.max()) + 1 if t2.size else 1)
    idxB = np.full((S, D, capB), -1, np.int32)
    idxB[q[ordB], d_req[ordB], t2] = srcA[ordB]
    fetch = np.full((S, gcap), -1, np.int32)
    fetch[gp[ordB], grank[ordB]] = d_o[ordB] * capB + t2
    return (
        Stage(axis=node_axis, lanes=N, cap=capA, idx=idxA),
        Stage(axis=device_axis, lanes=D, cap=capB, idx=idxB),
    ), fetch


def _halo_metrics_vec(
    part, nbr, ocounts, gcounts, gp, gc, D, stages, weights, *, with_quality=True
):
    """Halo metrics by masked sums over the ghost arrays and lane
    tables; the O(n*K) `partition_report` pass only when requested."""
    S = ocounts.shape[0]
    rep = {}
    if with_quality:
        rep = plan_quality_metrics(part, nbr, S, weights)
    rep.update(_metrics.surface_index(ocounts, gcounts))
    owner_node = np.asarray(part)[gc] // D
    inter = int((owner_node != gp // D).sum())
    rep["IntraNodeGhosts"] = int(gp.size - inter)
    rep["InterNodeGhosts"] = inter
    # inter-node float32 payload of ONE exchange (hop A lanes leaving the
    # node; the flat plan's lanes crossing nodes)
    st = stages[0]
    cnt = (st.idx >= 0).sum(axis=2)                  # (S, lanes)
    o = np.arange(S, dtype=np.int64)[:, None]
    lane = np.arange(st.lanes, dtype=np.int64)[None, :]
    mask = (lane // D != o // D) if len(stages) == 1 else (lane != o // D)
    ib = int(cnt[mask].sum())
    rep["InterNodeValuesPerExchange"] = ib
    rep["InterNodeBytesPerExchange"] = 4 * ib
    return rep


# ---------------------------------------------------------------------------
# plan construction — per-part reference (oracle + bench baseline)
# ---------------------------------------------------------------------------

def _owned_layout(slot: np.ndarray, part: np.ndarray, num_parts: int):
    """Per-part owned cell lists in ascending-slot order + local position
    of every cell on its owner."""
    n = slot.shape[0]
    owned = []
    local_pos = np.full((n,), -1, np.int64)
    for p in range(num_parts):
        cells = np.nonzero(part == p)[0]
        cells = cells[np.argsort(slot[cells], kind="stable")]
        owned.append(cells)
        local_pos[cells] = np.arange(cells.size)
    return owned, local_pos


def _ghost_sets(owned, part: np.ndarray, nbr: np.ndarray, slot: np.ndarray, num_parts: int):
    """Per-part ghost cell lists (ascending slot): cells owned elsewhere
    that neighbor at least one owned cell."""
    ghosts = []
    for p in range(num_parts):
        nb = nbr[owned[p]]
        cand = np.unique(nb[nb >= 0])
        g = cand[part[cand] != p]
        ghosts.append(g[np.argsort(slot[g], kind="stable")])
    return ghosts


def build_halo_plan_legacy(
    slot: np.ndarray,
    part: np.ndarray,
    nbr: np.ndarray,
    coeff: np.ndarray,
    *,
    hierarchy=None,
    num_parts: int | None = None,
    device_axis: str = "device",
    weights: np.ndarray | None = None,
    with_metrics: bool = True,
) -> HaloPlan:
    """Per-part reference implementation of :func:`build_halo_plan`.

    Straight-line Python loops over parts/cells — O(parts * cells) host
    work per event. Kept as the equivalence-test oracle (the vectorized
    builder must reproduce its output bit-for-bit) and as the
    ``bench_plans`` baseline; do not use on the hot path.
    """
    t_build = time.perf_counter()
    slot = np.asarray(slot, np.int64)
    part = np.asarray(part)
    n, K = nbr.shape
    N, D, S, axes = _plan_shape(part, hierarchy, num_parts, device_axis)

    owned, local_pos = _owned_layout(slot, part, S)
    ghosts = _ghost_sets(owned, part, nbr, slot, S)
    cap = _roundup(max(o.size for o in owned))
    gcap = _roundup(max(max(g.size for g in ghosts), 1))

    owned_idx = np.full((S, cap), -1, np.int32)
    owned_slot = np.full((S, cap), -1, np.int64)
    for p in range(S):
        owned_idx[p, : owned[p].size] = owned[p]
        owned_slot[p, : owned[p].size] = slot[owned[p]]

    # local stencil tables: neighbor j of owned cell -> local position in
    # [u_own (cap) | ghosts (gcap)]
    ghost_pos = [
        {int(c): i for i, c in enumerate(g)} for g in ghosts
    ]
    nbr_local = np.zeros((S, cap, K), np.int32)
    nbr_valid = np.zeros((S, cap, K), bool)
    coeff_l = np.zeros((S, cap, K), np.float32)
    for p in range(S):
        cells = owned[p]
        nb = nbr[cells]
        coeff_l[p, : cells.size] = coeff[cells]
        valid = nb >= 0
        nbr_valid[p, : cells.size] = valid
        loc = np.zeros_like(nb, dtype=np.int64)
        same = valid & (part[np.maximum(nb, 0)] == p)
        loc[same] = local_pos[nb[same]]
        other = valid & ~same
        if other.any():
            gp = ghost_pos[p]
            loc[other] = np.array([cap + gp[int(c)] for c in nb[other]], np.int64)
        nbr_local[p, : cells.size] = np.where(valid, loc, 0)

    # interior/boundary split (see build_halo_plan for the invariant)
    reads_ghost = (nbr_valid & (nbr_local >= cap)).any(axis=2)  # (S, cap)
    real = owned_idx >= 0
    int_lists = [np.flatnonzero(real[p] & ~reads_ghost[p]) for p in range(S)]
    bnd_lists = [np.flatnonzero(real[p] & reads_ghost[p]) for p in range(S)]
    icap = _roundup(max(max(r.size for r in int_lists), 1))
    bcap = _roundup(max(max(r.size for r in bnd_lists), 1))
    interior_idx = np.full((S, icap), -1, np.int32)
    boundary_idx = np.full((S, bcap), -1, np.int32)
    for p in range(S):
        interior_idx[p, : int_lists[p].size] = int_lists[p]
        boundary_idx[p, : bnd_lists[p].size] = bnd_lists[p]

    if N == 1:
        stages, ghost_fetch = _flat_stages(
            axes[0], S, owned, ghosts, part, local_pos, gcap
        )
    else:
        stages, ghost_fetch = _two_hop_stages(
            axes, N, D, owned, ghosts, part, slot, local_pos, gcap
        )

    mets = _halo_metrics(
        part, nbr, owned, ghosts, N, D, stages, weights, with_quality=with_metrics
    )
    mets["InteriorCells"] = int(sum(r.size for r in int_lists))
    mets["BoundaryCells"] = int(sum(r.size for r in bnd_lists))
    mets["PlanBuildSeconds"] = time.perf_counter() - t_build
    return HaloPlan(
        axes=axes,
        num_parts=S,
        cap=cap,
        gcap=gcap,
        K=K,
        owned_idx=owned_idx,
        owned_slot=owned_slot,
        nbr_local=nbr_local,
        nbr_valid=nbr_valid,
        coeff=coeff_l,
        stages=stages,
        ghost_fetch=ghost_fetch,
        interior_idx=interior_idx,
        boundary_idx=boundary_idx,
        metrics=mets,
    )


def _flat_stages(axis, S, owned, ghosts, part, local_pos, gcap):
    """One all_to_all: lane (o -> p) carries o's cells that p ghosts,
    ordered by p's ghost order (ascending slot)."""
    counts = np.zeros((S, S), np.int64)
    for p in range(S):
        for c in ghosts[p]:
            counts[part[c], p] += 1
    hcap = _roundup(int(counts.max()) if counts.size else 1)
    idx = np.full((S, S, hcap), -1, np.int32)
    fetch = np.full((S, gcap), -1, np.int32)
    for p in range(S):
        fill = np.zeros((S,), np.int64)
        for gpos, c in enumerate(ghosts[p]):
            o = int(part[c])
            t = fill[o]
            fill[o] += 1
            idx[o, p, t] = local_pos[c]
            fetch[p, gpos] = o * hcap + t
    return (Stage(axis=axis, lanes=S, cap=hcap, idx=idx),), fetch


def _two_hop_stages(axes, N, D, owned, ghosts, part, slot, local_pos, gcap):
    """Node-aware exchange: hop A (node axis, per-destination-node
    deduplicated), hop B (device axis, fan-out inside the node)."""
    node_axis, device_axis = axes
    S = N * D
    # hop A dedup: (owner shard, dest node) -> ordered cell list
    a_members: dict[tuple[int, int], dict[int, int]] = {}
    for p in range(S):
        m = p // D
        for c in ghosts[p]:
            key = (int(part[c]), m)
            a_members.setdefault(key, {})
            a_members[key].setdefault(int(c), -1)
    for key, cells in a_members.items():
        order = sorted(cells, key=lambda c: int(slot[c]))
        for t, c in enumerate(order):
            cells[c] = t
    capA = _roundup(max((len(v) for v in a_members.values()), default=1))
    idxA = np.full((S, N, capA), -1, np.int32)
    for (o, m), cells in a_members.items():
        for c, t in cells.items():
            idxA[o, m, t] = local_pos[c]

    # hop B: intermediate (m, d_o) restages recvA entries to device lanes
    b_fill = np.zeros((S, D), np.int64)
    b_entries: dict[tuple[int, int], list[tuple[int, int]]] = {}
    fetch = np.full((S, gcap), -1, np.int32)
    capB_needed = 1
    fetch_tmp = []
    for p in range(S):
        m, d_req = p // D, p % D
        for gpos, c in enumerate(ghosts[p]):
            o = int(part[c])
            n_o, d_o = o // D, o % D
            q = m * D + d_o                      # intermediate shard
            tA = a_members[(o, m)][int(c)]
            srcA = n_o * capA + tA               # position in q's recvA
            t2 = b_fill[q, d_req]
            b_fill[q, d_req] += 1
            b_entries.setdefault((q, d_req), []).append((t2, srcA))
            fetch_tmp.append((p, gpos, d_o, t2))
            capB_needed = max(capB_needed, t2 + 1)
    capB = _roundup(capB_needed)
    idxB = np.full((S, D, capB), -1, np.int32)
    for (q, d_req), entries in b_entries.items():
        for t2, srcA in entries:
            idxB[q, d_req, t2] = srcA
    for p, gpos, d_o, t2 in fetch_tmp:
        fetch[p, gpos] = d_o * capB + t2
    return (
        Stage(axis=node_axis, lanes=N, cap=capA, idx=idxA),
        Stage(axis=device_axis, lanes=D, cap=capB, idx=idxB),
    ), fetch


def _halo_metrics(part, nbr, owned, ghosts, N, D, stages, weights, *, with_quality=True):
    """Partition quality of this halo: the paper's table columns through
    the ONE `repro.core.metrics` implementation, plus surface index and
    the per-level ghost/byte split the hierarchy targets."""
    S = N * D
    rep = {}
    if with_quality:
        rep = plan_quality_metrics(part, nbr, S, weights)
    owned_counts = np.array([o.size for o in owned])
    ghost_counts = np.array([g.size for g in ghosts])
    rep.update(_metrics.surface_index(owned_counts, ghost_counts))
    intra = inter = 0
    for p in range(S):
        if ghosts[p].size:
            owner_node = part[ghosts[p]] // D
            inter += int((owner_node != p // D).sum())
            intra += int((owner_node == p // D).sum())
    rep["IntraNodeGhosts"] = intra
    rep["InterNodeGhosts"] = inter
    # inter-node float32 payload of ONE exchange (hop A lanes leaving the
    # node; the flat plan's lanes crossing nodes)
    ib = 0
    st = stages[0]
    for o in range(S):
        for lane in range(st.lanes):
            cnt = int((st.idx[o, lane] >= 0).sum())
            if len(stages) == 1:
                if lane // D != o // D:
                    ib += cnt
            else:
                if lane != o // D:
                    ib += cnt
    rep["InterNodeValuesPerExchange"] = ib
    rep["InterNodeBytesPerExchange"] = 4 * ib
    return rep


# ---------------------------------------------------------------------------
# migration (state-move) plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MovePlan:
    """Compiled state exchange for one partition change.

    ``kind``: "none" (assignments identical), "device" (all moves
    node-local — a single device-axis hop that provably never crosses
    the inter-node boundary), "flat" (one hop on a 1-D mesh), or "hier"
    (two-hop over a (node, device) mesh). ``keep`` marks old-layout rows
    staying put; routed rows merge in by storage-slot sort.
    ``migration`` is the `repro.core.migration` plan (level-aware on
    hierarchies) for round/byte accounting.
    """

    kind: str
    axes: tuple[str, ...]
    cap_old: int
    cap_new: int
    keep: np.ndarray               # (S, cap_old) bool
    stages: tuple[Stage, ...]
    migration: object
    metrics: dict = field(default_factory=dict)

    @property
    def stage_meta(self) -> tuple:
        return tuple((s.axis, s.lanes, s.cap) for s in self.stages)


def build_move_plan(
    old: HaloPlan,
    new: HaloPlan,
    *,
    hierarchy=None,
    full: bool = False,
    cache=None,
) -> MovePlan:
    """Compile the owned-state exchange from ``old``'s layout to
    ``new``'s (same cells, new part assignment).

    Incremental mode (default) routes only the rows whose owner changed
    and, when the level-aware migration plan certifies zero inter-node
    movement on a hierarchy, runs the single intra-node hop. ``full``
    stages EVERY row to its (possibly unchanged) owner — the
    redistribute a cold rebuild pays, carried by the same machinery so
    the walltime comparison is apples-to-apples.

    Vectorized: the old and new layouts are joined on ``owned_slot`` by
    one sort + ``searchsorted`` (no per-slot dicts), and the lane
    tables fill by sorted-run ranks — bit-identical to
    :func:`build_move_plan_legacy`.

    ``cache`` (the same :class:`~repro.mesh.plan_cache.PlanCache` the
    halo builds used) shares the per-event owner gather: when ``old``
    and ``new`` are the cache's last two halo builds, the slot-sorted
    (old owner, new owner, old row, slot) join is read from the cached
    layout state instead of re-deriving it from ``owned_slot`` — one
    gather per partition event, not two. The output is bit-identical
    either way (the join is a pure function of the two layouts).
    """
    t_build = time.perf_counter()
    S = old.owned_idx.shape[0]
    pro = cache.move_prologue(old, new) if cache is not None else None
    if pro is not None:
        old_part, new_part, ot_r, oslot = pro
    else:
        # old layout rows, joined to the new owner by slot sort (slots
        # are unique, so ascending slot is the canonical merge order)
        op_r, ot_r = np.nonzero(old.owned_slot >= 0)
        oslot = old.owned_slot[op_r, ot_r]
        oo = np.argsort(oslot, kind="stable")
        op_r, ot_r, oslot = op_r[oo].astype(np.int64), ot_r[oo].astype(np.int64), oslot[oo]
        np_r, nt_r = np.nonzero(new.owned_slot >= 0)
        nslot = new.owned_slot[np_r, nt_r]
        no = np.argsort(nslot, kind="stable")
        np_r, nslot = np_r[no].astype(np.int64), nslot[no]
        pos = np.searchsorted(nslot, oslot)
        hit = (pos < nslot.size) & (nslot[np.minimum(pos, max(nslot.size - 1, 0))] == oslot)
        if not hit.all():
            raise KeyError(int(oslot[~hit][0]))
        old_part = op_r
        new_part = np_r[pos]
    mig = _migration.migration_plan(
        old_part, new_part, S,
        hierarchy=hierarchy if (hierarchy is not None and hierarchy.num_nodes > 1) else None,
    )
    keep = np.zeros((S, old.cap), bool)
    if full:
        mm = np.ones((oslot.size,), bool)
    else:
        stay = new_part == old_part
        keep[old_part[stay], ot_r[stay]] = True
        mm = ~stay
    msrc, mdst, mt, mslot = old_part[mm], new_part[mm], ot_r[mm], oslot[mm]
    mets_extra = {} if pro is None else {"PlanCacheHits": cache.stats.move_hits}
    if msrc.size == 0:
        return MovePlan(
            kind="none", axes=old.axes, cap_old=old.cap, cap_new=new.cap,
            keep=keep, stages=(), migration=mig,
            metrics={**mets_extra, "PlanBuildSeconds": time.perf_counter() - t_build},
        )

    if hierarchy is not None and hierarchy.num_nodes > 1:
        N, D = int(hierarchy.num_nodes), int(hierarchy.devices_per_node)
        node_local = bool((msrc // D == mdst // D).all())
        if node_local and not full:
            # intra-node only: one device-axis hop, lanes = dest device.
            # The compiled program contains no node-axis collective at
            # all — node-local migration cannot cross the boundary.
            lane = mdst % D
            cap = _roundup(int(np.bincount(msrc * D + lane, minlength=S * D).max()))
            ordm = np.lexsort((mslot, lane, msrc))
            r = _run_ranks((msrc * D + lane)[ordm])
            idx = np.full((S, D, cap), -1, np.int32)
            idx[msrc[ordm], lane[ordm], r] = mt[ordm]
            stages = (Stage(axis=hierarchy.device_axis, lanes=D, cap=cap, idx=idx),)
            kind = "device"
        else:
            # two hops: dest node, then dest device inside it
            m_node = mdst // D
            capA = _roundup(int(np.bincount(msrc * N + m_node, minlength=S * N).max()))
            ordA = np.lexsort((mslot, m_node, msrc))
            tA = _run_ranks((msrc * N + m_node)[ordA])
            idxA = np.full((S, N, capA), -1, np.int32)
            idxA[msrc[ordA], m_node[ordA], tA] = mt[ordA]
            srcA = np.empty((msrc.size,), np.int64)
            srcA[ordA] = (msrc[ordA] // D) * capA + tA
            q = m_node * D + msrc % D            # intermediate shard
            lane = mdst % D
            capB = _roundup(int(np.bincount(q * D + lane, minlength=S * D).max()))
            ordB = np.lexsort((mslot, lane, q))
            t2 = _run_ranks((q * D + lane)[ordB])
            idxB = np.full((S, D, capB), -1, np.int32)
            idxB[q[ordB], lane[ordB], t2] = srcA[ordB]
            stages = (
                Stage(axis=hierarchy.node_axis, lanes=N, cap=capA, idx=idxA),
                Stage(axis=hierarchy.device_axis, lanes=D, cap=capB, idx=idxB),
            )
            kind = "hier"
    else:
        cap = _roundup(int(np.bincount(msrc * S + mdst, minlength=S * S).max()))
        ordm = np.lexsort((mslot, mdst, msrc))
        r = _run_ranks((msrc * S + mdst)[ordm])
        idx = np.full((S, S, cap), -1, np.int32)
        idx[msrc[ordm], mdst[ordm], r] = mt[ordm]
        stages = (Stage(axis=old.axes[-1], lanes=S, cap=cap, idx=idx),)
        kind = "flat"
    return MovePlan(
        kind=kind, axes=old.axes, cap_old=old.cap, cap_new=new.cap,
        keep=keep, stages=stages, migration=mig,
        metrics={**mets_extra, "PlanBuildSeconds": time.perf_counter() - t_build},
    )


def build_move_plan_legacy(
    old: HaloPlan,
    new: HaloPlan,
    *,
    hierarchy=None,
    full: bool = False,
) -> MovePlan:
    """Per-slot dict reference implementation of :func:`build_move_plan`
    (the equivalence-test oracle and ``bench_plans`` baseline)."""
    t_build = time.perf_counter()
    S = old.owned_idx.shape[0]
    # old shard + local position per slot
    slot_old: dict[int, tuple[int, int]] = {}
    for p in range(S):
        for t, s in enumerate(old.owned_slot[p]):
            if s >= 0:
                slot_old[int(s)] = (p, t)
    part_of_slot: dict[int, int] = {}
    for p in range(S):
        for s in new.owned_slot[p]:
            if s >= 0:
                part_of_slot[int(s)] = p
    slots = sorted(slot_old)
    old_part = np.array([slot_old[s][0] for s in slots], np.int64)
    new_part = np.array([part_of_slot[s] for s in slots], np.int64)
    mig = _migration.migration_plan(
        old_part, new_part, S,
        hierarchy=hierarchy if (hierarchy is not None and hierarchy.num_nodes > 1) else None,
    )
    keep = np.zeros((S, old.cap), bool)
    moved: list[tuple[int, int, int, int]] = []  # (slot, src, dst, src_pos)
    for s in slots:
        p_old, t = slot_old[s]
        p_new = part_of_slot[s]
        if p_new == p_old and not full:
            keep[p_old, t] = True
        else:
            moved.append((s, p_old, p_new, t))
    if not moved:
        return MovePlan(
            kind="none", axes=old.axes, cap_old=old.cap, cap_new=new.cap,
            keep=keep, stages=(), migration=mig,
            metrics={"PlanBuildSeconds": time.perf_counter() - t_build},
        )

    if hierarchy is not None and hierarchy.num_nodes > 1:
        N, D = int(hierarchy.num_nodes), int(hierarchy.devices_per_node)
        node_local = all(src // D == dst // D for _, src, dst, _ in moved)
        if node_local and not full:
            counts = np.zeros((S, D), np.int64)
            for _, src, dst, _ in moved:
                counts[src, dst % D] += 1
            cap = _roundup(int(counts.max()))
            idx = np.full((S, D, cap), -1, np.int32)
            fill = np.zeros((S, D), np.int64)
            for _, src, dst, t in sorted(moved):
                lane = dst % D
                idx[src, lane, fill[src, lane]] = t
                fill[src, lane] += 1
            stages = (Stage(axis=hierarchy.device_axis, lanes=D, cap=cap, idx=idx),)
            kind = "device"
        else:
            # two hops: dest node, then dest device inside it
            cntA = np.zeros((S, N), np.int64)
            for _, src, dst, _ in moved:
                cntA[src, dst // D] += 1
            capA = _roundup(int(cntA.max()))
            idxA = np.full((S, N, capA), -1, np.int32)
            fillA = np.zeros((S, N), np.int64)
            posA: dict[int, tuple[int, int, int]] = {}  # slot -> (inter q, srcA, dst)
            for s, src, dst, t in sorted(moved):
                m = dst // D
                tA = fillA[src, m]
                fillA[src, m] += 1
                idxA[src, m, tA] = t
                q = m * D + src % D
                posA[s] = (q, (src // D) * capA + tA, dst)
            cntB = np.zeros((S, D), np.int64)
            for q, _, dst in posA.values():
                cntB[q, dst % D] += 1
            capB = _roundup(int(cntB.max()))
            idxB = np.full((S, D, capB), -1, np.int32)
            fillB = np.zeros((S, D), np.int64)
            for s in sorted(posA):
                q, srcA, dst = posA[s]
                lane = dst % D
                idxB[q, lane, fillB[q, lane]] = srcA
                fillB[q, lane] += 1
            stages = (
                Stage(axis=hierarchy.node_axis, lanes=N, cap=capA, idx=idxA),
                Stage(axis=hierarchy.device_axis, lanes=D, cap=capB, idx=idxB),
            )
            kind = "hier"
    else:
        counts = np.zeros((S, S), np.int64)
        for _, src, dst, _ in moved:
            counts[src, dst] += 1
        cap = _roundup(int(counts.max()))
        idx = np.full((S, S, cap), -1, np.int32)
        fill = np.zeros((S, S), np.int64)
        for _, src, dst, t in sorted(moved):
            idx[src, dst, fill[src, dst]] = t
            fill[src, dst] += 1
        stages = (Stage(axis=old.axes[-1], lanes=S, cap=cap, idx=idx),)
        kind = "flat"
    return MovePlan(
        kind=kind, axes=old.axes, cap_old=old.cap, cap_new=new.cap,
        keep=keep, stages=stages, migration=mig,
        metrics={"PlanBuildSeconds": time.perf_counter() - t_build},
    )


# re-export: the cross-event cache lives in its own module but is part
# of this layer's public surface (`build_halo_plan(..., cache=...)`)
from repro.mesh.plan_cache import PlanCache, PlanCacheStats  # noqa: E402,F401
