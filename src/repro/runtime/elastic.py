"""Elastic scaling: reshape the device mesh and re-place sharded state.

On mesh change (node loss / pool growth), parameters are restored from
the mesh-agnostic checkpoint onto the new mesh (checkpoint.restore with
new shardings). Expert placement and data shards are re-sliced with the
paper's knapsack; the expected migration volume is computed from the
migration plan so the launcher can decide between in-place reshard
(cheap, neighbors only) and full restart.

``ElasticServingController`` wires the pieces around a live
``DistributedQueryEngine``: heartbeats from ``fault_tolerance`` detect a
device-count change, the owner ``HierarchicalRepartitioner`` re-slices
its cached curve hierarchy-aware (``resize`` — no rebuild), and the
engine re-places chunks on a mesh over the surviving devices plus a live
index-version swap. A failure therefore costs one re-slice + one
placement pass, never a cold restart.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass

import numpy as np
import jax

from repro.core import knapsack, migration
from repro.runtime.fault_tolerance import HeartbeatMonitor, reslice_for_stragglers
import jax.numpy as jnp


def viable_mesh_shapes(n_devices: int, *, min_model: int = 1) -> list[tuple[int, int]]:
    """(data, model) factorizations of the surviving device count,
    preferring square-ish meshes (ICI locality)."""
    shapes = []
    for m in range(min_model, n_devices + 1):
        if n_devices % m == 0:
            shapes.append((n_devices // m, m))
    shapes.sort(key=lambda dm: abs(np.log(dm[0] / dm[1])))
    return shapes


def replacement_plan(
    old_parts: np.ndarray, weights: np.ndarray, new_num_parts: int
) -> tuple[np.ndarray, migration.MigrationPlan]:
    """Knapsack re-slice of weighted units onto a new part count.

    The count matrix spans ``max(old_parts.max()+1, new_num_parts)`` so
    the shrink path accounts for every unit leaving a vanished part
    (units are conserved: stay + moved == len(old_parts)). An empty
    ``old_parts`` is a fresh placement — every unit materializes in
    place, the plan moves nothing — instead of crashing on ``max()`` of
    an empty array."""
    old_parts = np.asarray(old_parts)
    new = np.asarray(
        knapsack.slice_weighted_curve(jnp.asarray(weights, jnp.float32), new_num_parts)
    )
    old_p = int(old_parts.max()) + 1 if old_parts.size else 0
    P = max(old_p, new_num_parts)
    plan = migration.migration_plan(old_parts if old_parts.size else new, new, P)
    return new, plan


def estimate_reshard_bytes(plan: migration.MigrationPlan, bytes_per_unit: int) -> int:
    return plan.total_moved * bytes_per_unit


# ---------------------------------------------------------------------------
# Live serving elasticity (paper §V-A under a changing device pool)
# ---------------------------------------------------------------------------

def mesh_from_devices(
    devices, shape: tuple[int, ...], axes: tuple[str, ...]
) -> jax.sharding.Mesh:
    """Mesh over an explicit device subset (survivors after a failure, or
    a grown pool) — `launch.mesh.make_mesh` always takes the default
    device order, which a shrunken pool no longer matches."""
    arr = np.asarray(devices, dtype=object).reshape(shape)
    try:  # jax >= 0.5: explicit-sharding axis types
        from jax.sharding import AxisType

        return jax.sharding.Mesh(arr, axes, axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.sharding.Mesh(arr, axes)


@dataclass(frozen=True)
class ReshardEvent:
    """One completed elastic reshard, for the launcher's accounting."""

    n_before: int
    n_after: int
    mesh_shape: tuple[int, int]     # (num_nodes, devices_per_node)
    moved_units: int                # migration-plan volume of the re-slice
    seconds: float
    rebuilds_during: int            # MUST stay 0: elastic != cold restart


class ElasticServingController:
    """Heartbeat-driven elastic reshard around a serving engine.

    >>> ctl = ElasticServingController(hrp, eng, devices=jax.devices())
    >>> ctl.beat(worker=3, now=t)            # workers report liveness
    >>> ctl.check(now=t + 120.0)             # failed workers -> shrink
    >>> ctl.apply_device_change(jax.devices())   # explicit growth

    ``owner`` is a ``HierarchicalRepartitioner`` (hierarchy-aware
    re-slice via ``resize``; its tree-backed index serves on the mesh
    through the engine's host-side keying) or a flat ``Repartitioner``
    (``resize(n)``, 1-D mesh). On a device-count change the controller:

    1. picks the square-ish (nodes, devices_per_node) factorization of
       the survivor count (`viable_mesh_shapes`);
    2. ``owner.resize(...)`` — knapsack re-slice of the cached curve,
       bumping ``index_version`` (no tree/key/sort rebuild);
    3. ``engine.reshard(mesh_from_devices(...))`` + ``maybe_refresh`` —
       chunks re-place on the survivors and the refreshed index swaps in
       live.
    """

    def __init__(
        self,
        owner,
        engine,
        devices=None,
        *,
        heartbeat_timeout: float = 60.0,
        straggler_factor: float = 2.0,
    ):
        self.owner, self.engine = owner, engine
        self.devices = list(devices if devices is not None else jax.devices())
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.straggler_factor = float(straggler_factor)
        self.monitor = self._fresh_monitor()
        self.events: list[ReshardEvent] = []

    def _fresh_monitor(self) -> HeartbeatMonitor:
        return HeartbeatMonitor(
            len(self.devices),
            timeout=self.heartbeat_timeout,
            straggler_factor=self.straggler_factor,
        )

    def beat(self, worker: int, now: float, step_time: float | None = None) -> None:
        self.monitor.beat(worker, now, step_time)

    def throughput(self) -> np.ndarray:
        """(workers,) relative speed from recent heartbeat step times
        (1/mean step time; workers without samples get the median speed)
        — the input `fault_tolerance.reslice_for_stragglers` expects."""
        speed = np.zeros(len(self.devices))
        for w, ts in self.monitor.step_times.items():
            if ts and 0 <= w < speed.shape[0]:
                speed[w] = 1.0 / max(float(np.mean(ts[-5:])), 1e-12)
        default = float(np.median(speed[speed > 0])) if (speed > 0).any() else 1.0
        speed[speed == 0] = default
        return speed

    def check(self, now: float) -> ReshardEvent | None:
        """Shrink to the surviving devices iff the monitor reports
        failures. With every worker alive, slow-but-responsive workers
        (stragglers) instead trigger a weighted re-cut of the serving
        chunk layout (:meth:`mitigate_stragglers`) — no mesh change, no
        ReshardEvent. Returns None when no failure fired."""
        failed = set(self.monitor.failed(now))
        if not failed:
            self.mitigate_stragglers()
            return None
        survivors = [d for i, d in enumerate(self.devices) if i not in failed]
        return self.apply_device_change(survivors)

    def mitigate_stragglers(self) -> np.ndarray | None:
        """Straggler-driven weighted re-slice of the serving layout.

        When the heartbeat monitor reports stragglers, feed the measured
        per-worker speeds (:meth:`throughput`) into
        `fault_tolerance.reslice_for_stragglers` over the index's
        directory buckets — each bucket weighted by its row count plus
        its decayed hit traffic — and re-cut the engine's chunk
        placement at the resulting bucket boundaries
        (``engine.set_chunk_targets``): slow shards hold fewer and
        colder rows, fast shards more, converging to
        proportional-throughput sharding under repeated observations.
        Cuts stay run-aligned inside the engine, so answers are
        bit-equal — only the load shares move. Returns the per-bucket
        shard assignment, or None when there are no stragglers."""
        if not self.monitor.stragglers():
            return None
        tp = self.throughput()
        idx = self.engine.index
        starts = np.asarray(idx.bucket_starts, np.int64)
        w = np.diff(starts).astype(np.float64) + self.engine.bucket_hits
        assignment = reslice_for_stragglers(np.maximum(w, 1e-9), tp)
        # first bucket of each shard s in 1..W-1 marks that shard's cut
        cuts = starts[np.searchsorted(assignment, np.arange(1, tp.shape[0]))]
        self.engine.set_chunk_targets(cuts)
        return assignment

    def apply_device_change(self, devices) -> ReshardEvent:
        """Re-slice + re-place + live swap onto an explicit device list
        (shrink or growth). Proves the no-cold-restart property in the
        returned event: ``rebuilds_during`` is the owner's rebuild-count
        delta across the whole operation."""
        devices = list(devices)
        if not devices:
            raise ValueError("cannot reshard onto zero devices")
        t0 = time.monotonic()
        rebuilds0 = self.owner.stats.rebuilds
        n = len(devices)
        nodes, dpn = viable_mesh_shapes(n)[0]
        plan = getattr(self.owner, "plan", None)
        if plan is not None:  # hierarchical: resize takes a HierarchyPlan
            new_plan = dataclasses.replace(
                plan, num_nodes=nodes, devices_per_node=dpn
            )
            step = self.owner.resize(new_plan)
            mesh = mesh_from_devices(
                devices, (nodes, dpn), (new_plan.node_axis, new_plan.device_axis)
            )
            self.engine.reshard(mesh, (new_plan.node_axis, new_plan.device_axis))
        else:
            step = self.owner.resize(n)
            axis = self.engine.axis if isinstance(self.engine.axis, str) else "data"
            mesh = mesh_from_devices(devices, (n,), (axis,))
            self.engine.reshard(mesh, axis)
        self.engine.maybe_refresh(self.owner)
        event = ReshardEvent(
            n_before=len(self.devices),
            n_after=n,
            mesh_shape=(nodes, dpn),
            moved_units=int(step.plan.total_moved),
            seconds=time.monotonic() - t0,
            rebuilds_during=self.owner.stats.rebuilds - rebuilds0,
        )
        self.devices = devices
        self.monitor = self._fresh_monitor()
        self.events.append(event)
        return event
