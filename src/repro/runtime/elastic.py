"""Elastic scaling: reshape the device mesh and re-place sharded state.

On mesh change (node loss / pool growth), parameters are restored from
the mesh-agnostic checkpoint onto the new mesh (checkpoint.restore with
new shardings). Expert placement and data shards are re-sliced with the
paper's knapsack; the expected migration volume is computed from the
migration plan so the launcher can decide between in-place reshard
(cheap, neighbors only) and full restart.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import knapsack, migration
import jax.numpy as jnp


def viable_mesh_shapes(n_devices: int, *, min_model: int = 1) -> list[tuple[int, int]]:
    """(data, model) factorizations of the surviving device count,
    preferring square-ish meshes (ICI locality)."""
    shapes = []
    for m in range(min_model, n_devices + 1):
        if n_devices % m == 0:
            shapes.append((n_devices // m, m))
    shapes.sort(key=lambda dm: abs(np.log(dm[0] / dm[1])))
    return shapes


def replacement_plan(
    old_parts: np.ndarray, weights: np.ndarray, new_num_parts: int
) -> tuple[np.ndarray, migration.MigrationPlan]:
    """Knapsack re-slice of weighted units onto a new part count."""
    new = np.asarray(
        knapsack.slice_weighted_curve(jnp.asarray(weights, jnp.float32), new_num_parts)
    )
    P = max(int(old_parts.max()) + 1, new_num_parts)
    plan = migration.migration_plan(old_parts, new, P)
    return new, plan


def estimate_reshard_bytes(plan: migration.MigrationPlan, bytes_per_unit: int) -> int:
    return plan.total_moved * bytes_per_unit
