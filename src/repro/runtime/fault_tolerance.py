"""Fault tolerance + straggler mitigation for 1000+ node jobs.

Components (all deterministic and unit-testable on CPU):

* ``HeartbeatMonitor`` — tracks per-worker progress stamps against an
  injected clock; declares failures after ``timeout`` and stragglers at
  ``straggler_factor`` x median step time.
* ``RestartPolicy`` — on failure: restore latest committed checkpoint,
  shrink the mesh to the survivors, and re-slice the data shards with the
  paper's knapsack (incremental: only neighbors of the lost rank move —
  the partitioner IS the elastic-scaling mechanism).
* ``StragglerMitigator`` — shifts work *weights* away from slow workers
  and re-slices the weighted curve; repeated observations converge to
  proportional-throughput sharding.

The training launcher wires these around the step loop; tests inject
synthetic failures/clocks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro.core import knapsack as _knapsack
from repro.core import migration as _migration

import jax.numpy as jnp


@dataclass
class HeartbeatMonitor:
    num_workers: int
    timeout: float = 60.0
    straggler_factor: float = 2.0
    last_seen: dict[int, float] = field(default_factory=dict)
    step_times: dict[int, list] = field(default_factory=dict)

    def beat(self, worker: int, now: float, step_time: float | None = None) -> None:
        self.last_seen[worker] = now
        if step_time is not None:
            self.step_times.setdefault(worker, []).append(step_time)

    def failed(self, now: float) -> list[int]:
        return [
            w
            for w in range(self.num_workers)
            if now - self.last_seen.get(w, now) > self.timeout
        ]

    def stragglers(self) -> list[int]:
        recent = {
            w: float(np.mean(ts[-5:])) for w, ts in self.step_times.items() if ts
        }
        if len(recent) < 2:
            return []
        med = float(np.median(list(recent.values())))
        return [w for w, t in recent.items() if t > self.straggler_factor * med]


@dataclass(frozen=True)
class ReslicePlan:
    assignment: np.ndarray        # (units,) new worker per work unit
    plan: _migration.MigrationPlan
    survivors: list[int]


def reslice_on_failure(
    old_assignment: np.ndarray,
    unit_weights: np.ndarray,
    failed: list[int],
    num_workers: int,
) -> ReslicePlan:
    """Re-slice work units over surviving workers with the knapsack.

    Work units stay in curve order, so migration is concentrated at the
    failed rank's neighborhood (the paper's incremental-LB locality).
    """
    survivors = [w for w in range(num_workers) if w not in failed]
    part = np.asarray(
        _knapsack.slice_weighted_curve(jnp.asarray(unit_weights, jnp.float32), len(survivors))
    )
    new_assignment = np.array([survivors[p] for p in part], dtype=np.int64)
    plan = _migration.migration_plan(old_assignment, new_assignment, num_workers)
    return ReslicePlan(assignment=new_assignment, plan=plan, survivors=survivors)


def reslice_for_stragglers(
    unit_weights: np.ndarray,
    throughput: np.ndarray,  # (workers,) relative speed, higher = faster
) -> np.ndarray:
    """Weighted re-slice: worker w gets a share proportional to its
    throughput. Implemented by stretching the curve with per-worker
    targets instead of equal slices."""
    W = throughput.shape[0]
    cum_w = np.cumsum(unit_weights, dtype=np.float64)
    total = cum_w[-1]
    share = throughput / throughput.sum()
    targets = np.cumsum(share) * total
    assignment = np.searchsorted(targets, cum_w - unit_weights * 0.5, side="right")
    return np.clip(assignment, 0, W - 1).astype(np.int64)


@dataclass
class RestartPolicy:
    """Glue: decides (restore_step, new mesh shape, data reslice) after a
    failure event. The launcher executes the decision."""

    checkpoint_dir: str
    keep_last: int = 3

    def decide(self, available_workers: int, ckpt_latest: int | None) -> dict:
        if ckpt_latest is None:
            return {"action": "cold_start", "step": 0, "workers": available_workers}
        return {
            "action": "restore",
            "step": ckpt_latest,
            "workers": available_workers,
        }
