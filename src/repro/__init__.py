"""repro: distributed geometric partitioning (SFC + kd-tree + knapsack)
integrated into a multi-pod JAX training/serving framework."""
__version__ = "1.0.0"
