"""Distributed query serving over the shared CurveIndex (paper §V-A at
serving scale).

``DistributedQueryEngine`` turns the versioned ``CurveIndex`` into a
query service:

* **Sharded serving** — the index's sorted arrays are split into
  contiguous curve chunks over a mesh axis, with chunk cuts snapped to
  key-run boundaries so the exact-scan miss certificate survives the
  split; a query batch is keyed host-side (``curve_index.query_keys`` —
  coordinate quantization for point-keyed indexes, the kd-tree walk for
  tree-backed ones) and routed to its owner shard by curve key (one
  all_to_all out, answers ride one all_to_all back —
  ``repro.distributed.sharding.serve_point_location`` / ``serve_knn``).
  Host-side keying is what lets tree-backed indexes serve on a mesh:
  the key→bucket→part resolution happens before the collective, so the
  kernels never need the tree. Without a mesh the engine answers locally
  through ``repro.core.queries`` — same index, same semantics.
* **Hot-bucket replication** — the router counts per-bucket hits
  (decayed) on every batch; ``replicate_hot`` installs the hottest
  *eligible* buckets (``curve_index.replicable_buckets`` — buckets whose
  key runs are self-contained) as a replicated annex, "exceptions to the
  partition": point-location queries landing in a replicated bucket are
  answered from the annex before routing, bit-equal to the routed
  answer, so a skewed key range stops saturating one owner shard.
* **Bounded lanes + admission** — ``lane_rows`` provisions the per-lane
  exchange capacity below the worst case; overflowed rows are detected
  (staged position >= capacity) and re-dispatched, so skew degrades into
  extra rounds, never wrong answers. ``submit`` is a bounded admission
  queue (``max_queue_rows``), and ``run`` levels load by adapting the
  per-round row budget to the measured serve rate
  (``target_round_s``), with per-request latencies recorded in
  ``stats.request_latency_s``. Mixed-size requests are grouped into
  balanced rounds with the greedy knapsack
  (``serve.engine.knapsack_batches``); the ``AmortizedController``
  (paper Alg. 3) decides when to re-batch the in-flight queue.
* **Live version swap + elastic reshard** — ``maybe_refresh(owner)``
  swaps in ``owner.curve_index()`` when stale (incremental: cached keys
  and order reused, only the directory re-carved and re-placed);
  ``reshard(mesh, axis)`` re-places the *current* index on a different
  mesh (device loss / growth) without touching the index itself — the
  elastic path is a reshard + version swap, never a cold rebuild.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import curve_index as _ci
from repro.core import queries as _q
from repro.core.dynamic import AmortizedController
from repro.serve.engine import knapsack_batches


@dataclass(eq=False)  # identity semantics: ndarray fields break __eq__,
class QueryRequest:   # and the run() queue removes requests by identity
    """One batched query from one client. ``rid`` keys the result dict —
    use unique rids (duplicates overwrite each other's results)."""

    rid: int
    queries: np.ndarray                 # (m, d) float32
    kind: Literal["pl", "knn"] = "pl"   # point-location | k-nearest
    k: int = 3

    @property
    def rows(self) -> int:
        return int(np.asarray(self.queries).shape[0])


@dataclass
class ServeStats:
    rounds: int = 0
    rebatches: int = 0
    queries_served: int = 0
    index_swaps: int = 0
    # skew-robust serving counters
    route_rounds: int = 0        # sharded dispatches (lane overflow adds rounds)
    annex_served: int = 0        # queries answered from the replicated annex
    replications: int = 0        # replicate_hot installs
    reshards: int = 0            # live mesh changes (elastic)
    weighted_reslices: int = 0   # straggler-driven chunk re-placements
    rejected_requests: int = 0   # admission-queue overflow
    rejected_rows: int = 0
    request_latency_s: list = field(default_factory=list)
    history: list = field(default_factory=list)


@functools.partial(jax.jit, static_argnames=("bucket_cap",))
def _annex_pl(apts, aids, akeys, bucket_keys, hot_mask, q, qk, *, bucket_cap):
    """Point location against the replicated hot-bucket annex.

    Returns (hot, found, id, ok): ``hot`` marks queries whose directory
    bucket is replicated — for those the annex rows contain the query's
    entire key run (the `replicable_buckets` eligibility invariant), so
    found/id/ok are bit-identical to the routed owner-shard answer."""
    hot = hot_mask[_ci.owner_from_firsts(bucket_keys, qk)]
    n_loc = akeys.shape[0]
    lo_i = jnp.searchsorted(akeys, qk, side="left").astype(jnp.int32)
    hi_i = jnp.searchsorted(akeys, qk, side="right").astype(jnp.int32)
    offs = jnp.arange(bucket_cap, dtype=jnp.int32)
    pos = lo_i[:, None] + offs[None, :]
    cand = jnp.clip(pos, 0, n_loc - 1)
    hit = jnp.all(apts[cand] == q[:, None, :], axis=-1) & (pos < hi_i[:, None])
    found = jnp.any(hit, axis=1)
    slot = jnp.argmax(hit, axis=1)
    gid = aids[cand[jnp.arange(q.shape[0]), slot]]
    ok = found | ((hi_i - lo_i) <= bucket_cap)
    return hot, found, jnp.where(found, gid, -1), ok


class DistributedQueryEngine:
    """Point-location / kNN serving over a (possibly sharded) CurveIndex.

    >>> eng = DistributedQueryEngine(rp.curve_index(), mesh, "data")
    >>> found, ids, ok = eng.point_location(q)
    >>> eng.replicate_hot(4)                       # hottest buckets -> annex
    >>> rp.insert(new_pts, new_wts)                # geometry changed
    >>> eng.maybe_refresh(rp)                      # live index swap
    >>> eng.reshard(smaller_mesh, "data")          # elastic device change

    On a 2-D (node, device) mesh, pass ``axis=("node", "device")``: the
    index shards node-major over both axes and queries route through the
    hierarchical two-level directory (key -> node -> device) — the
    inter-node all_to_all carries N lanes instead of N*D, and the
    device-level lookup plus its reply never leave the owner node.
    Answers are identical to flat routing on the same chunk layout.

    Skew knobs: ``lane_rows`` bounds the per-(src,dst) exchange lanes (a
    production memory budget; ``None`` provisions the worst case so one
    round always suffices); under skew, overflowed rows re-dispatch in
    extra rounds (``stats.route_rounds``) unless ``replicate_hot`` has
    annexed their buckets. ``max_queue_rows`` bounds the admission queue
    (``submit`` returns rejected requests); ``target_round_s`` adapts the
    per-round row budget to the measured serve rate within
    [min_batch_rows, max_batch_rows].
    """

    def __init__(
        self,
        index: _ci.CurveIndex,
        mesh: jax.sharding.Mesh | None = None,
        axis: "str | tuple[str, str]" = "data",
        *,
        bucket_cap: int = 64,
        cutoff_buckets: int = 1,
        max_batch_rows: int = 4096,
        max_window: int = 1024,
        lane_rows: int | None = None,
        hit_decay: float = 0.9,
        max_queue_rows: int | None = None,
        min_batch_rows: int = 256,
        target_round_s: float | None = None,
    ):
        self.mesh, self.axis = mesh, axis
        self.bucket_cap = int(bucket_cap)
        self.cutoff_buckets = int(cutoff_buckets)
        self.max_window = int(max_window)
        self.max_batch_rows = int(max_batch_rows)
        self.lane_rows = None if lane_rows is None else int(lane_rows)
        self.hit_decay = float(hit_decay)
        self.max_queue_rows = max_queue_rows
        self.min_batch_rows = int(min_batch_rows)
        self.target_round_s = target_round_s
        self.round_rows = self.max_batch_rows  # live per-round row budget
        self._rate: float | None = None        # EWMA rows/s
        self._hot: dict | None = None          # replicated annex (per version)
        self._row_targets: np.ndarray | None = None  # weighted chunk cuts
        self._enq_t: dict[int, float] = {}     # id(request) -> enqueue stamp
        self.controller = AmortizedController()
        self.stats = ServeStats()
        self.queue: list[QueryRequest] = []
        self.version: int = -1
        self.swap(index)

    # -- index lifecycle -----------------------------------------------------

    def swap(self, index: _ci.CurveIndex) -> None:
        """Install a new index version (live: the next batch served uses
        it). Distributed mode re-places the sorted arrays on shards —
        still far cheaper than a cold build, which also pays key-gen and
        the sort. Both addressing modes shard: point-keyed indexes key
        queries by coordinates, tree-backed ones by the kd-tree walk —
        either way the keys are computed host-side before routing.

        Swapping resets the per-bucket hit counters and drops the
        replicated annex (both are defined against the incoming
        directory); call ``replicate_hot`` again once traffic has
        re-warmed the counters."""
        self.index = index
        self.version = int(index.version)
        # directory granularity of the installed index: maybe_refresh
        # preserves it, so a live swap never silently changes the
        # cutoff-neighborhood geometry the engine was configured with
        self.bucket_size = max(1, int(index.valid_count()) // index.num_buckets)
        # tree-backed runs span whole buckets (every member shares the
        # bucket key): the exact scan must cover the largest bucket
        self._scan_cap = (
            max(self.bucket_cap, index.max_bucket_len)
            if index.tree is not None
            else self.bucket_cap
        )
        self._bucket_keys_h = np.asarray(index.bucket_keys)
        self._hits = np.zeros(index.num_buckets, np.float64)
        self._hot = None
        # weighted chunk cuts are row positions in the OLD sorted order —
        # stale against the incoming index, so revert to equal shares
        self._row_targets = None
        self.stats.index_swaps += 1
        if self.mesh is not None:
            self._place()

    def reshard(
        self,
        mesh: jax.sharding.Mesh | None,
        axis: "str | tuple[str, str] | None" = None,
    ) -> None:
        """Live mesh change (elastic shrink/growth): re-place the CURRENT
        index's chunks over a different mesh. The index, the hit
        counters, and the replicated annex are untouched — only the
        chunk layout moves, so a device-count change costs one placement
        pass, not a rebuild."""
        self.mesh = mesh
        if axis is not None:
            self.axis = axis
        if mesh is not None:
            self._place()
        self.stats.reshards += 1

    def set_chunk_targets(self, row_targets) -> None:
        """Weighted chunk placement: re-cut the sorted arrays at explicit
        row positions instead of equal shares — the straggler-mitigation
        hook (`runtime.elastic.ElasticServingController
        .mitigate_stragglers` derives the cuts from measured per-worker
        throughput via `fault_tolerance.reslice_for_stragglers`). Cuts
        are still snapped to key-run boundaries, so routing and answers
        stay bit-equal to equal-share placement; only the per-shard row
        load changes. Cleared by ``swap`` (cuts are positions in the
        installed index's sorted order)."""
        self._row_targets = np.sort(np.asarray(row_targets, np.int64))
        self.stats.weighted_reslices += 1
        if self.mesh is not None:
            self._place()

    def _place(self) -> None:
        """Run-aligned chunk placement: cut the sorted arrays into
        ``nshards`` contiguous chunks at key-run boundaries nearest the
        equal-row targets, pad every chunk to the max chunk length with
        sentinel rows, and shard P(axis). Runs never span chunks, so the
        owner shard's key-run scan is exact — this is what makes the
        distributed miss certificate (and tree-backed bucket runs) match
        the local path bit for bit. Empty chunks (fewer runs than
        shards) trail with sentinel first-keys, keeping shard firsts
        sorted for `owner_from_firsts`."""
        index = self.index
        nsh = self._num_shards()
        keys_h = np.asarray(index.keys)
        n_valid = int(index.valid_count())
        if n_valid:
            run_starts = np.flatnonzero(np.diff(keys_h[:n_valid]) != 0) + 1
            run_starts = np.concatenate([np.zeros(1, np.int64), run_starts])
        else:
            run_starts = np.zeros(1, np.int64)
        if self._row_targets is not None and self._row_targets.shape[0] == nsh - 1:
            # straggler-weighted cuts (set_chunk_targets); still snapped
            # to run boundaries below, so answers stay bit-equal
            targets = np.clip(self._row_targets, 0, n_valid)
        else:
            targets = (np.arange(1, nsh, dtype=np.int64) * n_valid) // nsh
        snap = np.searchsorted(run_starts, targets, side="right") - 1
        cuts = run_starts[np.maximum(snap, 0)]
        bounds = np.unique(np.concatenate([[0], cuts, [n_valid]]))
        bounds = np.concatenate(
            [bounds, np.full(nsh + 1 - bounds.shape[0], n_valid, np.int64)]
        )
        cap_rows = max(1, int(np.diff(bounds).max()))
        pts_h = np.asarray(index.points)
        ids_h = np.asarray(index.ids)
        d = pts_h.shape[1]
        pl_pts = np.zeros((nsh * cap_rows, d), pts_h.dtype)
        pl_ids = np.full(nsh * cap_rows, -1, np.int32)
        pl_keys = np.full(nsh * cap_rows, _ci.KEY_SENTINEL, np.uint32)
        firsts = np.full(nsh, _ci.KEY_SENTINEL, np.uint32)
        for s in range(nsh):
            b0, b1 = int(bounds[s]), int(bounds[s + 1])
            if b1 > b0:
                o = s * cap_rows
                pl_pts[o : o + b1 - b0] = pts_h[b0:b1]
                pl_ids[o : o + b1 - b0] = ids_h[b0:b1]
                pl_keys[o : o + b1 - b0] = keys_h[b0:b1]
                firsts[s] = keys_h[b0]
        sh = NamedSharding(self.mesh, P(self.axis))
        self._pts_s = jax.device_put(jnp.asarray(pl_pts), sh)
        self._ids_s = jax.device_put(jnp.asarray(pl_ids), sh)
        self._keys_s = jax.device_put(jnp.asarray(pl_keys), sh)
        self._firsts_h = firsts
        self._chunk_bounds = bounds
        # per-lane traffic counters restart with the layout: lane ids are
        # positions in THIS placement's chunk order
        self._lane_hits = np.zeros(nsh, np.float64)
        # a lane-subset annex is addressed by placement lane id — stale
        # against the new layout (an engine-wide annex is not: its rows
        # are index rows, untouched by placement)
        if self._hot is not None and self._hot.get("lanes") is not None:
            self._hot = None

    def maybe_refresh(self, owner, bucket_size: int | None = None) -> bool:
        """Swap in the owner's current index iff ours is stale, keeping
        the installed directory granularity unless ``bucket_size`` says
        otherwise. ``owner`` is anything with ``index_version`` +
        ``curve_index()`` — the single-host ``Repartitioner`` or the
        ``HierarchicalRepartitioner`` (whose tree-backed index serves on
        the mesh through host-side keying). A ``DistributedRepartitioner``
        bumps ``index_version`` but holds no point payload, so no index
        can be derived from it: rebuild the CurveIndex from the migrated
        payload and call ``swap`` directly."""
        if int(owner.index_version) == self.version:
            return False
        self.swap(owner.curve_index(bucket_size or self.bucket_size))
        return True

    # -- hot-bucket replication ----------------------------------------------

    @property
    def bucket_hits(self) -> np.ndarray:
        """Decayed per-bucket hit counts (a copy; mesh mode only counts
        real query rows — padding and fillers are keyed after this)."""
        return self._hits.copy()

    @property
    def lane_hits(self) -> np.ndarray:
        """Decayed per-lane (owner-shard) hit counts for the current
        placement (a copy) — the traffic view ``replicate_hot``'s
        ``shards=k`` uses to pick which lanes deserve an annex copy."""
        return self._lane_hits.copy()

    def _note_hits(self, qk: np.ndarray) -> None:
        b = np.searchsorted(self._bucket_keys_h, qk, side="right").astype(np.int64) - 1
        np.clip(b, 0, self._hits.shape[0] - 1, out=b)
        if self.hit_decay < 1.0:
            self._hits *= self.hit_decay
        self._hits += np.bincount(b, minlength=self._hits.shape[0])
        nsh = self._num_shards()
        lane = np.searchsorted(self._firsts_h, qk, side="right").astype(np.int64) - 1
        np.clip(lane, 0, nsh - 1, out=lane)
        if self.hit_decay < 1.0:
            self._lane_hits *= self.hit_decay
        self._lane_hits += np.bincount(lane, minlength=nsh)

    def _lane_devices(self) -> list:
        """The representative device of each serving lane: transpose the
        mesh so the serving axes lead, then the first device along every
        remaining axis — where a lane-targeted annex copy lives."""
        axes = self.axis if isinstance(self.axis, tuple) else (self.axis,)
        names = list(self.mesh.axis_names)
        order = [names.index(a) for a in axes] + [
            i for i, nm in enumerate(names) if nm not in axes
        ]
        dv = np.transpose(np.asarray(self.mesh.devices), order)
        return dv.reshape(self._num_shards(), -1)[:, 0].tolist()

    def replicate_hot(
        self,
        top_k: int = 8,
        *,
        min_hits: float = 1.0,
        shards=None,
    ) -> list[int]:
        """Install the hottest eligible buckets as a replicated annex —
        the paper's "exceptions to the partition". Point-location queries
        whose key lands in an annexed bucket are answered from the annex
        (bit-equal to routing, see `curve_index.replicable_buckets`)
        before any collective runs, so hot-key traffic stops consuming
        the owner shard's lanes. Returns the replicated bucket ids.

        ``shards`` bounds the replication footprint: ``None`` (default)
        keeps one engine-wide annex serving every query; an int ``k``
        places an annex copy on only the ``k`` hottest serving lanes (by
        the decayed ``lane_hits`` traffic counters); a sequence names
        explicit lane ids. With a lane subset, only queries OWNED by a
        selected lane are annex-served — exactly the traffic that was
        saturating those lanes — and everything else routes as before,
        so answers are bit-equal to both routing and the full annex
        while the annex memory scales with the observed skew instead of
        the shard count.

        kNN is never annex-served: its candidate window spans
        neighboring buckets, which the annex does not hold."""
        if self.mesh is None:
            raise ValueError(
                "hot-bucket replication is a sharded-serving feature; "
                "local engines (mesh=None) already answer from one store"
            )
        elig = _ci.replicable_buckets(self.index, bucket_cap=self._scan_cap)
        score = np.where(elig, self._hits, 0.0)
        hot = np.flatnonzero(score >= float(min_hits))
        if hot.size > int(top_k):
            order = np.argsort(score[hot], kind="stable")[::-1]
            hot = hot[order[: int(top_k)]]
        hot = np.sort(hot)
        if hot.size == 0:
            self._hot = None
            return []
        starts = np.asarray(self.index.bucket_starts).astype(np.int64)
        rows = np.concatenate(
            [np.arange(starts[b], starts[b + 1]) for b in hot]
        )
        mask = np.zeros(self._hits.shape[0], bool)
        mask[hot] = True
        annex = (
            np.asarray(self.index.points)[rows],
            np.asarray(self.index.ids)[rows].astype(np.int32),
            np.asarray(self.index.keys)[rows],
            self._bucket_keys_h,
            mask,
        )
        if shards is None:
            lanes = None
            copies = None
            a = tuple(jnp.asarray(x) for x in annex)
        else:
            nsh = self._num_shards()
            if isinstance(shards, (int, np.integer)):
                if int(shards) < 0:
                    raise ValueError(f"shards must be >= 0, got {shards}")
                order = np.argsort(self._lane_hits, kind="stable")[::-1]
                lanes = np.sort(order[: min(int(shards), nsh)])
            else:
                lanes = np.unique(np.asarray(list(shards), np.int64))
                if lanes.size and (lanes[0] < 0 or lanes[-1] >= nsh):
                    raise ValueError(
                        f"lane ids must be in [0, {nsh}), got {lanes.tolist()}"
                    )
            if lanes.size == 0:
                self._hot = None
                return []
            devs = self._lane_devices()
            copies = {
                int(l): tuple(
                    jax.device_put(jnp.asarray(x), devs[int(l)]) for x in annex
                )
                for l in lanes
            }
            lanes = tuple(int(l) for l in lanes)
            a = None
        self._hot = {"annex": a, "lanes": lanes, "copies": copies}
        self.stats.replications += 1
        return hot.tolist()

    def _serve_annex(self, queries, qk_np, found, ids, okv) -> np.ndarray:
        """Answer hot-bucket point-location queries from the replicated
        annex: fills the output arrays in place and returns the served
        mask. With a lane-subset annex (``replicate_hot(shards=...)``)
        only queries OWNED by a selected lane consult that lane's copy —
        the same `_annex_pl` program over the same annex rows, so the
        answers are bit-identical to the engine-wide annex and to
        routing."""
        h = self._hot
        m = int(queries.shape[0])
        served = np.zeros(m, bool)

        def one(annex, rows):
            pts, aids, keys, bkeys, mask = annex
            hot, f_a, g_a, ok_a = _annex_pl(
                pts, aids, keys, bkeys, mask,
                queries[jnp.asarray(rows)], jnp.asarray(qk_np[rows]),
                bucket_cap=self._scan_cap,
            )
            hot = np.asarray(hot)
            if hot.any():
                sel = rows[hot]
                found[sel] = np.asarray(f_a)[hot]
                ids[sel] = np.asarray(g_a)[hot]
                okv[sel] = np.asarray(ok_a)[hot]
                served[sel] = True

        if h["lanes"] is None:
            one(h["annex"], np.arange(m))
        else:
            nsh = self._num_shards()
            lane = np.clip(
                np.searchsorted(self._firsts_h, qk_np, side="right") - 1,
                0, nsh - 1,
            )
            for lid in h["lanes"]:
                rows = np.flatnonzero(lane == lid)
                if rows.size:
                    one(h["copies"][lid], rows)
        return served

    # -- one-shot serving ----------------------------------------------------

    def point_location(self, queries: jax.Array) -> _q.PointLocation:
        queries = jnp.asarray(queries, jnp.float32)
        m = int(queries.shape[0])
        if self.mesh is None:
            out = _q.point_location(self.index, queries, bucket_cap=self._scan_cap)
            self.stats.queries_served += m
            return out
        q_np = np.asarray(queries)
        qk_np = np.asarray(_ci.query_keys(self.index, queries))
        self._note_hits(qk_np)
        found = np.zeros(m, bool)
        ids = np.full(m, -1, np.int32)
        okv = np.zeros(m, bool)
        pend = np.arange(m)
        if self._hot is not None and m:
            served = self._serve_annex(queries, qk_np, found, ids, okv)
            if served.any():
                self.stats.annex_served += int(served.sum())
                pend = pend[~served]
        if pend.size:
            self._route_pl(q_np, qk_np, pend, found, ids, okv)
        self.stats.queries_served += m
        return _q.PointLocation(jnp.asarray(found), jnp.asarray(ids), jnp.asarray(okv))

    def knn(self, queries: jax.Array, k: int = 3) -> tuple[jax.Array, jax.Array]:
        queries = jnp.asarray(queries, jnp.float32)
        m = int(queries.shape[0])
        if self.mesh is None:
            out = _q.knn(
                self.index, queries, k=k, cutoff_buckets=self.cutoff_buckets,
                max_window=self.max_window,
            )
            self.stats.queries_served += m
            return out
        q_np = np.asarray(queries)
        qk_np = np.asarray(_ci.query_keys(self.index, queries))
        self._note_hits(qk_np)
        win = max(k, min(
            self.index.max_bucket_len * (2 * self.cutoff_buckets + 1),
            self.max_window,
        ))
        d_out = np.full((m, k), np.inf, np.float32)
        g_out = np.full((m, k), -1, np.int32)
        if m:
            self._route_knn(q_np, qk_np, np.arange(m), k, win, d_out, g_out)
        self.stats.queries_served += m
        return jnp.asarray(d_out), jnp.asarray(g_out)

    # -- bounded-lane routing ------------------------------------------------

    def _round_buffers(self, q_np, pend_size: int):
        """Fixed-shape padded batch: real pending rows first, filler rows
        keyed with their OWN shard's first key so they ride the self-lane
        (staged after real rows — stable staging drops fillers first on
        overflow, so padding never evicts a real query). Filler answers
        are sliced off; fillers are keyed after `_note_hits`, so they
        never bias the replication statistics."""
        nsh = self._num_shards()
        n_pad = max(nsh, -(-pend_size // nsh) * nsh)
        shard_of = (np.arange(n_pad) * nsh) // n_pad
        pad_keys = self._firsts_h[shard_of]
        qb = np.zeros((n_pad, q_np.shape[1]), np.float32)
        kb = pad_keys.copy()
        return qb, kb, pad_keys

    def _route_pl(self, q_np, qk_np, pend, found, ids, okv) -> None:
        from repro.distributed import sharding as _shd

        qb, kb, pad_keys = self._round_buffers(q_np, pend.size)
        sh = NamedSharding(self.mesh, P(self.axis))
        while pend.size:
            t = pend
            qb[: t.size] = q_np[t]
            qb[t.size :] = 0.0
            kb[: t.size] = qk_np[t]
            kb[t.size :] = pad_keys[t.size :]
            res, pos, cap = _shd.serve_point_location(
                self.mesh, self.axis, self._pts_s, self._ids_s, self._keys_s,
                jax.device_put(jnp.asarray(qb), sh),
                jax.device_put(jnp.asarray(kb), sh),
                bucket_cap=self._scan_cap, lane_cap=self.lane_rows,
            )
            self.stats.route_rounds += 1
            res_h = np.asarray(res[: t.size])
            served = np.asarray(pos[: t.size]) < cap
            if not served.any():
                raise RuntimeError(
                    "query routing made no progress (lane_rows too small?)"
                )
            srv = t[served]
            found[srv] = res_h[served, 0].astype(bool)
            ids[srv] = res_h[served, 1]
            okv[srv] = res_h[served, 2].astype(bool)
            pend = t[~served]

    def _route_knn(self, q_np, qk_np, pend, k, win, d_out, g_out) -> None:
        from repro.distributed import sharding as _shd

        qb, kb, pad_keys = self._round_buffers(q_np, pend.size)
        sh = NamedSharding(self.mesh, P(self.axis))
        while pend.size:
            t = pend
            qb[: t.size] = q_np[t]
            qb[t.size :] = 0.0
            kb[: t.size] = qk_np[t]
            kb[t.size :] = pad_keys[t.size :]
            d, g, pos, cap = _shd.serve_knn(
                self.mesh, self.axis, self._pts_s, self._ids_s, self._keys_s,
                jax.device_put(jnp.asarray(qb), sh),
                jax.device_put(jnp.asarray(kb), sh),
                k=k, win=win, lane_cap=self.lane_rows,
            )
            self.stats.route_rounds += 1
            served = np.asarray(pos[: t.size]) < cap
            if not served.any():
                raise RuntimeError(
                    "query routing made no progress (lane_rows too small?)"
                )
            srv = t[served]
            d_out[srv] = np.asarray(d[: t.size])[served]
            g_out[srv] = np.asarray(g[: t.size])[served]
            pend = t[~served]

    def _num_shards(self) -> int:
        """Total chunk count: product of the serving axes' sizes (one
        axis flat, node x device hierarchical)."""
        axes = self.axis if isinstance(self.axis, tuple) else (self.axis,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    # -- knapsack-batched serving of mixed request sizes ----------------------

    def run(self, requests: list[QueryRequest]) -> dict[int, object]:
        """Serve a mixed queue: knapsack-slice requests into balanced
        rounds of ~round_rows, answer each round in whole-batch
        dispatches (one per (kind, k) group), and let the amortized
        controller re-batch the remaining queue when round imbalance
        exhausts its credits.

        The engine's own ``self.queue`` is the live queue: ``requests``
        are admitted onto it (subject to ``max_queue_rows`` — rejected
        requests are NOT served and don't appear in the results),
        ``submit`` may append more mid-flight, and anything still queued
        when the current rounds run out is admitted in a fresh knapsack
        pass — nothing admitted is silently dropped. With
        ``target_round_s`` set, the per-round row budget tracks the
        measured serve rate (EWMA), so rounds level toward a constant
        wall-time instead of a constant row count."""
        results: dict[int, object] = {}
        self.submit(requests)
        pending = self.queue
        rounds = self._admit(pending)
        while rounds or pending:
            if not rounds:
                rounds = self._admit(pending)
            batch = rounds.pop(0)
            for r in batch:
                pending.remove(r)
            rows = sum(r.rows for r in batch)
            t0 = time.monotonic()
            self._serve_round(batch, results)
            now = time.monotonic()
            for r in batch:
                self.stats.request_latency_s.append(now - self._enq_t.pop(id(r), t0))
            self.stats.rounds += 1
            dt = now - t0
            if self.target_round_s is not None and dt > 0 and rows:
                rate = rows / dt
                self._rate = rate if self._rate is None else 0.5 * self._rate + 0.5 * rate
                self.round_rows = int(np.clip(
                    self._rate * self.target_round_s,
                    self.min_batch_rows, self.max_batch_rows,
                ))
            # imbalance metered against the ideal round: a round far above
            # target rows means the knapsack's input drifted (requests
            # added/removed) — Alg. 3 decides when re-batching pays
            timeop = rows / max(self.round_rows, 1)
            if self.controller.observe(timeop, max(len(rounds), 1)) and pending:
                # _admit re-banks the credits (controller.balanced) with
                # the fresh round layout's baseline
                rounds = self._admit(pending)
                self.stats.rebatches += 1
        return results

    def submit(self, new: list[QueryRequest]) -> list[QueryRequest]:
        """Admit work onto the engine's live queue — ``run`` drains
        ``self.queue``, so mid-flight appends are picked up at the next
        admission (re-batch or rounds running dry). With
        ``max_queue_rows`` set this is the bounded front: requests that
        would push the queued row count past the bound are returned
        (back-pressure) instead of enqueued."""
        rejected: list[QueryRequest] = []
        queued = sum(r.rows for r in self.queue)
        now = time.monotonic()
        for r in new:
            if (
                self.max_queue_rows is not None
                and queued + r.rows > self.max_queue_rows
            ):
                rejected.append(r)
                self.stats.rejected_requests += 1
                self.stats.rejected_rows += r.rows
                continue
            queued += r.rows
            self._enq_t[id(r)] = now
            self.queue.append(r)
        return rejected

    def _admit(self, pending: list[QueryRequest]) -> list[list[QueryRequest]]:
        if not pending:
            return []
        total = sum(r.rows for r in pending)
        num_rounds = max(1, -(-total // self.round_rows))
        batches = knapsack_batches(
            pending, 0, weight=lambda r: r.rows, num_batches=num_rounds
        )
        self.controller.balanced(
            lb_cost=float(len(pending)), num_buckets=max(len(batches), 1),
            timeop=total / max(num_rounds * self.round_rows, 1),
        )
        return batches

    def _serve_round(self, batch: list[QueryRequest], results: dict) -> None:
        groups: dict[tuple, list[QueryRequest]] = {}
        for r in batch:
            groups.setdefault((r.kind, r.k if r.kind == "knn" else 0), []).append(r)
        for (kind, k), reqs in groups.items():
            q = jnp.concatenate([jnp.asarray(r.queries, jnp.float32) for r in reqs])
            if kind == "pl":
                found, ids, ok = self.point_location(q)
                off = 0
                for r in reqs:
                    results[r.rid] = _q.PointLocation(
                        found[off : off + r.rows],
                        ids[off : off + r.rows],
                        ok[off : off + r.rows],
                    )
                    off += r.rows
            else:
                d, g = self.knn(q, k=k)
                off = 0
                for r in reqs:
                    results[r.rid] = (d[off : off + r.rows], g[off : off + r.rows])
                    off += r.rows
