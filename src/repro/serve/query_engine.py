"""Distributed query serving over the shared CurveIndex (paper §V-A at
serving scale).

``DistributedQueryEngine`` turns the versioned ``CurveIndex`` into a
query service:

* **Sharded serving** — the index's sorted arrays are split into
  contiguous curve chunks over a mesh axis; a query batch is routed to
  its owner shard by curve key (one all_to_all out, answers ride one
  all_to_all back — ``repro.distributed.sharding.serve_point_location`` /
  ``serve_knn``). Without a mesh the engine answers locally through
  ``repro.core.queries`` — same index, same semantics.
* **Knapsack admission** — mixed-size query requests are grouped into
  balanced rounds with the same greedy knapsack the decode engine uses
  (``serve.engine.knapsack_batches``), so one huge batch cannot starve a
  round. The ``AmortizedController`` (paper Alg. 3) meters per-round
  imbalance and triggers re-batching of the in-flight queue when drift
  exhausts the credits banked at admission.
* **Live version swap** — ``maybe_refresh(owner)`` compares the engine's
  index version against the owner's (``Repartitioner.index_version``)
  and swaps in ``owner.curve_index()`` when stale. The refresh is the
  incremental path: cached keys and order are reused, only the bucket
  directory is re-carved and (in distributed mode) re-placed on shards.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import curve_index as _ci
from repro.core import queries as _q
from repro.core.dynamic import AmortizedController
from repro.serve.engine import knapsack_batches


@dataclass(eq=False)  # identity semantics: ndarray fields break __eq__,
class QueryRequest:   # and the run() queue removes requests by identity
    """One batched query from one client. ``rid`` keys the result dict —
    use unique rids (duplicates overwrite each other's results)."""

    rid: int
    queries: np.ndarray                 # (m, d) float32
    kind: Literal["pl", "knn"] = "pl"   # point-location | k-nearest
    k: int = 3

    @property
    def rows(self) -> int:
        return int(np.asarray(self.queries).shape[0])


@dataclass
class ServeStats:
    rounds: int = 0
    rebatches: int = 0
    queries_served: int = 0
    index_swaps: int = 0
    history: list = field(default_factory=list)


class DistributedQueryEngine:
    """Point-location / kNN serving over a (possibly sharded) CurveIndex.

    >>> eng = DistributedQueryEngine(rp.curve_index(), mesh, "data")
    >>> found, ids, ok = eng.point_location(q)
    >>> rp.insert(new_pts, new_wts)                # geometry changed
    >>> eng.maybe_refresh(rp)                      # live index swap

    On a 2-D (node, device) mesh, pass ``axis=("node", "device")``: the
    index shards node-major over both axes and queries route through the
    hierarchical two-level directory (key -> node -> device) — the
    inter-node all_to_all carries N lanes instead of N*D, and the
    device-level lookup plus its reply never leave the owner node.
    Answers are identical to flat routing on the same chunk layout.
    """

    def __init__(
        self,
        index: _ci.CurveIndex,
        mesh: jax.sharding.Mesh | None = None,
        axis: "str | tuple[str, str]" = "data",
        *,
        bucket_cap: int = 64,
        cutoff_buckets: int = 1,
        max_batch_rows: int = 4096,
        max_window: int = 1024,
    ):
        self.mesh, self.axis = mesh, axis
        self.bucket_cap = int(bucket_cap)
        self.cutoff_buckets = int(cutoff_buckets)
        self.max_window = int(max_window)
        self.max_batch_rows = int(max_batch_rows)
        self.controller = AmortizedController()
        self.stats = ServeStats()
        self.queue: list[QueryRequest] = []
        self.version: int = -1
        self.swap(index)

    # -- index lifecycle -----------------------------------------------------

    def swap(self, index: _ci.CurveIndex) -> None:
        """Install a new index version (live: the next batch served uses
        it). Distributed mode re-places the sorted arrays on shards —
        still far cheaper than a cold build, which also pays key-gen and
        the sort.

        Tree-backed indexes (``index.tree`` set — a tree-mode
        ``Repartitioner`` or ``partitioner.tree_index``) are served
        locally: their queries are keyed by the kd-tree walk, which the
        sharded serving kernels cannot run (they key by coordinates
        inside ``shard_map``)."""
        if self.mesh is not None and index.tree is not None:
            raise ValueError(
                "sharded serving requires a point-keyed CurveIndex; "
                "tree-backed indexes serve locally (mesh=None) — use the "
                "engine's cached-key mode for distributed serving"
            )
        self.index = index
        self.version = int(index.version)
        # directory granularity of the installed index: maybe_refresh
        # preserves it, so a live swap never silently changes the
        # cutoff-neighborhood geometry the engine was configured with
        self.bucket_size = max(1, int(index.valid_count()) // index.num_buckets)
        self.stats.index_swaps += 1
        if self.mesh is None:
            return
        nsh = self._num_shards()
        n = index.capacity
        n_pad = -(-n // nsh) * nsh
        pts = index.points
        ids = index.ids.astype(jnp.int32)
        keys = index.keys
        if n_pad != n:
            pad = n_pad - n
            pts = jnp.concatenate([pts, jnp.zeros((pad, pts.shape[1]), pts.dtype)])
            ids = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
            keys = jnp.concatenate(
                [keys, jnp.full((pad,), jnp.uint32(0xFFFFFFFF), jnp.uint32)]
            )
        sh = NamedSharding(self.mesh, P(self.axis))
        rep = NamedSharding(self.mesh, P())
        self._pts_s = jax.device_put(pts, sh)
        self._ids_s = jax.device_put(ids, sh)
        self._keys_s = jax.device_put(keys, sh)
        self._flo = jax.device_put(self.index.frame_lo, rep)
        self._fhi = jax.device_put(self.index.frame_hi, rep)

    def maybe_refresh(self, owner, bucket_size: int | None = None) -> bool:
        """Swap in the owner's current index iff ours is stale, keeping
        the installed directory granularity unless ``bucket_size`` says
        otherwise. ``owner`` is anything with ``index_version`` +
        ``curve_index()`` — today that is the single-host
        ``Repartitioner``. A ``DistributedRepartitioner`` bumps
        ``index_version`` but holds no point payload, so no index can be
        derived from it: rebuild the CurveIndex from the migrated payload
        and call ``swap`` directly."""
        if int(owner.index_version) == self.version:
            return False
        self.swap(owner.curve_index(bucket_size or self.bucket_size))
        return True

    # -- one-shot serving ----------------------------------------------------

    def point_location(self, queries: jax.Array) -> _q.PointLocation:
        queries = jnp.asarray(queries, jnp.float32)
        if self.mesh is None:
            out = _q.point_location(self.index, queries, bucket_cap=self.bucket_cap)
        else:
            from repro.distributed import sharding as _shd

            qp, nq = self._pad_shard(queries)
            res = _shd.serve_point_location(
                self.mesh, self.axis, self._pts_s, self._ids_s, self._keys_s,
                qp, self._flo, self._fhi,
                bits=self.index.bits, curve=self.index.curve,
                bucket_cap=self.bucket_cap,
            )
            res = res[:nq]
            out = _q.PointLocation(
                res[:, 0].astype(bool), res[:, 1], res[:, 2].astype(bool)
            )
        self.stats.queries_served += int(queries.shape[0])
        return out

    def knn(self, queries: jax.Array, k: int = 3) -> tuple[jax.Array, jax.Array]:
        queries = jnp.asarray(queries, jnp.float32)
        if self.mesh is None:
            out = _q.knn(
                self.index, queries, k=k, cutoff_buckets=self.cutoff_buckets,
                max_window=self.max_window,
            )
        else:
            from repro.distributed import sharding as _shd

            win = max(k, min(
                self.index.max_bucket_len * (2 * self.cutoff_buckets + 1),
                self.max_window,
            ))
            qp, nq = self._pad_shard(queries)
            d, g = _shd.serve_knn(
                self.mesh, self.axis, self._pts_s, self._ids_s, self._keys_s,
                qp, self._flo, self._fhi,
                bits=self.index.bits, curve=self.index.curve, k=k, win=win,
            )
            out = (d[:nq], g[:nq])
        self.stats.queries_served += int(queries.shape[0])
        return out

    def _num_shards(self) -> int:
        """Total chunk count: product of the serving axes' sizes (one
        axis flat, node x device hierarchical)."""
        axes = self.axis if isinstance(self.axis, tuple) else (self.axis,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def _pad_shard(self, queries: jax.Array) -> tuple[jax.Array, int]:
        """Pad the batch to a multiple of the shard count and shard it.
        Pad rows route like real queries and are sliced off on return —
        lane capacity equals the local count, so they can't evict one."""
        nsh = self._num_shards()
        nq = queries.shape[0]
        n_pad = -(-nq // nsh) * nsh
        if n_pad != nq:
            queries = jnp.concatenate(
                [queries, jnp.zeros((n_pad - nq, queries.shape[1]), queries.dtype)]
            )
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.device_put(queries, sh), nq

    # -- knapsack-batched serving of mixed request sizes ----------------------

    def run(self, requests: list[QueryRequest]) -> dict[int, object]:
        """Serve a mixed queue: knapsack-slice requests into balanced
        rounds of ~max_batch_rows, answer each round in whole-batch
        dispatches (one per (kind, k) group), and let the amortized
        controller re-batch the remaining queue when round imbalance
        exhausts its credits.

        The engine's own ``self.queue`` is the live queue: ``requests``
        are appended to it, ``submit`` may append more mid-flight, and
        anything still queued when the current rounds run out is admitted
        in a fresh knapsack pass — nothing is silently dropped."""
        results: dict[int, object] = {}
        self.queue.extend(requests)
        pending = self.queue
        rounds = self._admit(pending)
        while rounds or pending:
            if not rounds:
                rounds = self._admit(pending)
            batch = rounds.pop(0)
            for r in batch:
                pending.remove(r)
            rows = sum(r.rows for r in batch)
            self._serve_round(batch, results)
            self.stats.rounds += 1
            # imbalance metered against the ideal round: a round far above
            # target rows means the knapsack's input drifted (requests
            # added/removed) — Alg. 3 decides when re-batching pays
            timeop = rows / max(self.max_batch_rows, 1)
            if self.controller.observe(timeop, max(len(rounds), 1)) and pending:
                # _admit re-banks the credits (controller.balanced) with
                # the fresh round layout's baseline
                rounds = self._admit(pending)
                self.stats.rebatches += 1
        return results

    def submit(self, new: list[QueryRequest]) -> None:
        """Enqueue more work onto the engine's live queue — ``run``
        drains ``self.queue``, so mid-flight appends are picked up at the
        next admission (re-batch or rounds running dry)."""
        self.queue.extend(new)

    def _admit(self, pending: list[QueryRequest]) -> list[list[QueryRequest]]:
        if not pending:
            return []
        total = sum(r.rows for r in pending)
        num_rounds = max(1, -(-total // self.max_batch_rows))
        batches = knapsack_batches(
            pending, 0, weight=lambda r: r.rows, num_batches=num_rounds
        )
        self.controller.balanced(
            lb_cost=float(len(pending)), num_buckets=max(len(batches), 1),
            timeop=total / max(num_rounds * self.max_batch_rows, 1),
        )
        return batches

    def _serve_round(self, batch: list[QueryRequest], results: dict) -> None:
        groups: dict[tuple, list[QueryRequest]] = {}
        for r in batch:
            groups.setdefault((r.kind, r.k if r.kind == "knn" else 0), []).append(r)
        for (kind, k), reqs in groups.items():
            q = jnp.concatenate([jnp.asarray(r.queries, jnp.float32) for r in reqs])
            if kind == "pl":
                found, ids, ok = self.point_location(q)
                off = 0
                for r in reqs:
                    results[r.rid] = _q.PointLocation(
                        found[off : off + r.rows],
                        ids[off : off + r.rows],
                        ok[off : off + r.rows],
                    )
                    off += r.rows
            else:
                d, g = self.knn(q, k=k)
                off = 0
                for r in reqs:
                    results[r.rid] = (d[off : off + r.rows], g[off : off + r.rows])
                    off += r.rows
