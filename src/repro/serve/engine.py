"""Serving engine: batched decode with knapsack admission.

Requests arrive with different prompt lengths; the batcher groups them
with the paper's greedy knapsack over a length-weighted curve so each
decode batch wastes minimal padding (imbalance <= max prompt length —
the partitioner guarantee applied to serving). The AmortizedController
decides when to re-batch in-flight requests (the dynamic-data Algorithm 3
applied to a query workload, which is exactly the paper's §IV test case).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knapsack
from repro.core.dynamic import AmortizedController
from repro.models import model as M


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new_tokens: int = 16
    generated: list = field(default_factory=list)

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.generated)


def knapsack_batches(
    requests: list,
    batch_size: int,
    *,
    weight=None,
    num_batches: int | None = None,
) -> list[list]:
    """Slice weight-sorted requests into balanced batches — the greedy
    knapsack over a weighted curve applied to admission. Default weight
    is decode length; the query engine batches by row count instead."""
    if not requests:
        return []
    wfn = weight if weight is not None else (lambda r: r.length)
    weights = [wfn(r) for r in requests]
    order = np.argsort(weights, kind="stable")
    arranged = [requests[i] for i in order]
    if num_batches is None:
        num_batches = max(1, int(np.ceil(len(requests) / batch_size)))
    w = jnp.asarray([weights[i] for i in order], jnp.float32)
    part = np.asarray(knapsack.slice_weighted_curve(w, num_batches))
    out: list[list] = [[] for _ in range(num_batches)]
    for r, p in zip(arranged, part):
        out[p].append(r)
    return [b for b in out if b]


class Engine:
    """Greedy-decode engine over the model registry (CPU-scale demo +
    integration tests; the dry-run exercises the same serve_step at
    production shapes)."""

    def __init__(self, cfg, params, max_seq: int = 256, batch_size: int = 8):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.mdl = M.get_model(cfg)
        self.controller = AmortizedController()
        self._step = jax.jit(
            lambda p, c, t, pos: self.mdl.decode_step(p, c, t, pos, cfg)
        )

    def _prefill(self, cache, batch: list[Request]):
        """Token-by-token prefill through decode_step (simple + exact)."""
        B = len(batch)
        maxlen = max(r.length for r in batch)
        for t in range(maxlen):
            toks = jnp.asarray(
                [r.prompt[t] if t < len(r.prompt) else 0 for r in batch], jnp.int32
            )
            pos = jnp.full((B,), t, jnp.int32)
            logits, cache = self._step(self.params, cache, toks, pos)
        return cache, logits, maxlen

    def run(self, requests: list[Request]) -> dict[int, list[int]]:
        results: dict[int, list[int]] = {}
        for batch in knapsack_batches(requests, self.batch_size):
            B = len(batch)
            cache = self.mdl.init_cache(self.cfg, B, self.max_seq)
            cache, logits, pos0 = self._prefill(cache, batch)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            steps = max(r.max_new_tokens for r in batch)
            for i in range(steps):
                for b, r in enumerate(batch):
                    if i < r.max_new_tokens:
                        r.generated.append(int(tok[b]))
                pos = jnp.full((B,), pos0 + i, jnp.int32)
                logits, cache = self._step(self.params, cache, tok, pos)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for r in batch:
                results[r.rid] = r.generated
        return results
