"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* attention block
applied every ``cfg.attn_every`` SSM layers (arXiv:2411.15242).

The shared block has one set of parameters reused at every application
site (Zamba's parameter-efficiency trick) but a distinct KV cache per
site. Layer execution scans the SSM segments (homogeneous -> lax.scan)
and interleaves the shared attention applications as an outer python loop
(num_sites ~ L/attn_every ~= 13 for zamba2-7b: HLO stays small).

Simplification vs the released checkpoint (noted in DESIGN.md): the
shared block consumes the current hidden state only (Zamba2 concatenates
the original embeddings; that doubles the shared block's input width
without changing the systems behaviour we study).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import transformer as TF

Params = dict[str, Any]


def _num_sites(cfg) -> int:
    return max(1, cfg.num_layers // cfg.attn_every)


def init_params(cfg, rng) -> Params:
    dtype = L._dtype(cfg.dtype)
    k_emb, k_blocks, k_shared = jax.random.split(rng, 3)
    block_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(lambda k: M2.block_init(k, cfg, dtype))(block_keys)
    return {
        "embed": L.embed_init(k_emb, cfg.padded_vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "shared_attn": TF.block_init(k_shared, cfg, dtype),  # ONE shared block
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }


def _segments(cfg) -> list[tuple[int, int]]:
    """[(start_layer, end_layer)) SSM segments between attention sites."""
    sites = _num_sites(cfg)
    per = cfg.num_layers // sites
    segs = []
    s = 0
    for i in range(sites):
        e = cfg.num_layers if i == sites - 1 else s + per
        segs.append((s, e))
        s = e
    return segs


def forward(params: Params, tokens: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    x = params["embed"][tokens].astype(L._dtype(cfg.dtype))
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def ssm_blk(p, h):
        return h + M2.ssm_block_apply(p["ssm"], L.rmsnorm(h, p["ln"], cfg.norm_eps), cfg)

    if cfg.remat:
        ssm_blk = jax.checkpoint(ssm_blk)

    from repro.distributed import sharding as shd

    for (s, e) in _segments(cfg):
        if cfg.scan_layers:
            seg = jax.tree.map(lambda a: a[s:e], params["blocks"])
            x, _ = jax.lax.scan(
                lambda h, p: (ssm_blk(p, shd.constrain_activations(h)), None), x, seg
            )
        else:  # unrolled for roofline probes
            for i in range(s, e):
                p = jax.tree.map(lambda a: a[i], params["blocks"])
                x = ssm_blk(p, shd.constrain_activations(x))
        x, _ = TF.block_apply(params["shared_attn"], x, cfg, positions=positions)
        x = shd.constrain_activations(x)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"], preferred_element_type=jnp.float32)
    return L.mask_padded_vocab(logits, cfg), jnp.float32(0.0)


def loss_fn(params: Params, batch: dict, cfg) -> tuple[jax.Array, dict]:
    logits, _ = forward(params, batch["tokens"], cfg)
    ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


def init_cache(cfg, batch_size: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    sites = _num_sites(cfg)
    return {
        "state": jnp.zeros(
            (cfg.num_layers, batch_size, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
        "k": jnp.zeros((sites, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((sites, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def decode_step(params: Params, cache: dict, token: jax.Array, pos: jax.Array, cfg):
    x = params["embed"][token][:, None, :].astype(L._dtype(cfg.dtype))

    # caches ride the carries with in-place updates (see transformer
    # decode_step); the KV cache of the shared block is the large buffer
    # at long_500k (sites x 524k keys), so copies matter.
    states, kall, vall = cache["state"], cache["k"], cache["v"]
    for i, (s, e) in enumerate(_segments(cfg)):
        def ssm_body(j, carry, s=s):
            h, sts = carry
            p = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, s + j, 0, keepdims=False),
                params["blocks"],
            )
            st = jax.lax.dynamic_index_in_dim(sts, s + j, 0, keepdims=False)
            y, st2 = M2.ssm_block_decode(
                p["ssm"], L.rmsnorm(h, p["ln"], cfg.norm_eps), st, cfg
            )
            sts = jax.lax.dynamic_update_index_in_dim(sts, st2, s + j, 0)
            return (h + y, sts)

        if cfg.scan_layers:
            x, states = jax.lax.fori_loop(0, e - s, ssm_body, (x, states))
        else:  # unrolled for roofline probes
            carry = (x, states)
            for j in range(e - s):
                carry = ssm_body(j, carry)
            x, states = carry
        x, ck, cv = TF.block_decode(
            params["shared_attn"], x, kall[i], vall[i], pos, cfg
        )
        kall = kall.at[i].set(ck)
        vall = vall.at[i].set(cv)
    cache = {"state": states, "k": kall, "v": vall}
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["embed"], preferred_element_type=jnp.float32)
    return L.mask_padded_vocab(logits, cfg), cache
