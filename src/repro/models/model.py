"""Model registry: family -> implementation module, plus input specs for
the dry-run and synthetic batches for smoke tests."""
from __future__ import annotations

import types
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, mamba2, transformer, vlm

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
}


def get_model(cfg: ModelConfig):
    """Resolve the implementation module for a config's family."""
    return _FAMILY[cfg.family]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation; dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract input shapes for one (arch x shape) cell.

    train/prefill: full-sequence batch. decode: one new token + KV cache
    of seq_len (the harness decode contract).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch: dict[str, Any] = {
            "tokens": sd((B, S), i32),
            "labels": sd((B, S), i32),
            "mask": sd((B, S), f32),
        }
        if cfg.family == "encdec":
            batch["frames"] = sd((B, S, cfg.d_model), f32)
        if cfg.family == "vlm":
            batch["patches"] = sd((B, cfg.num_prefix_tokens, cfg.d_model), f32)
        if shape.kind == "prefill":
            batch.pop("labels")
            batch.pop("mask")
        return batch
    # decode: one token against a seq_len cache
    mdl = get_model(cfg)
    cache = jax.eval_shape(lambda: mdl.init_cache(cfg, B, S))
    return {
        "token": sd((B,), i32),
        "pos": sd((B,), i32),
        "cache": cache,
    }


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, rng: jax.Array) -> dict:
    """Concrete random batch for smoke tests / the quickstart example."""
    k1, k2, k3 = jax.random.split(rng, 3)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size, jnp.int32),
        "mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(k3, (batch, seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            k3, (batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32
        )
    return out


def prefill_fn(cfg: ModelConfig):
    """Inference forward (logits only) for prefill cells."""
    mdl = get_model(cfg)

    def fn(params, batch):
        if cfg.family == "encdec":
            memory = encdec.encode(params, batch["frames"], cfg)
            return encdec.decode_train(params, batch["tokens"], memory, cfg)
        if cfg.family == "vlm":
            logits, _ = vlm.forward(params, batch["tokens"], batch["patches"], cfg)
            return logits
        logits, _ = mdl.forward(params, batch["tokens"], cfg)
        return logits

    return fn


def serve_step_fn(cfg: ModelConfig):
    """One-token decode step (the harness serve_step)."""
    mdl = get_model(cfg)

    def fn(params, batch):
        logits, cache = mdl.decode_step(params, batch["cache"], batch["token"], batch["pos"], cfg)
        return {"logits": logits, "cache": cache}

    return fn
