"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the harness instruction: the model
consumes precomputed frame embeddings (B, T_enc, d_model) from
``input_specs``. Encoder: bidirectional attention + sinusoidal positions.
Decoder: causal self-attention + cross-attention to encoder memory.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict[str, Any]


def _enc_block_init(key, cfg, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(k1, cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.act),
    }


def _dec_block_init(key, cfg, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "self_attn": L.attention_init(k1, cfg, dtype),
        "ln_x": L.rmsnorm_init(cfg.d_model),
        "cross_attn": L.attention_init(k2, cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, dtype, cfg.act),
    }


def init_params(cfg, rng) -> Params:
    dtype = L._dtype(cfg.dtype)
    k_emb, k_enc, k_dec = jax.random.split(rng, 3)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": L.embed_init(k_emb, cfg.padded_vocab_size, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
        "ln_enc": L.rmsnorm_init(cfg.d_model),
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }


def encode(params: Params, frames: jax.Array, cfg) -> jax.Array:
    """frames: (B, T_enc, D) precomputed frame embeddings (frontend stub)."""
    B, T, D = frames.shape
    x = frames.astype(L._dtype(cfg.dtype)) + L.sinusoidal_pos(T, D).astype(
        L._dtype(cfg.dtype)
    )
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def blk(p, h):
        a = L.attention_apply(
            p["attn"], L.rmsnorm(h, p["ln1"], cfg.norm_eps), cfg,
            positions=positions, causal=False, use_rope=False,
        )
        h = h + a
        return h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg.act)

    from repro.distributed import sharding as shd

    if cfg.remat:
        blk = jax.checkpoint(blk)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(
            lambda h, p: (blk(p, shd.constrain_activations(h)), None), x, params["enc_blocks"]
        )
    else:  # unrolled for roofline probes
        for i in range(cfg.encoder_layers):
            p = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            x = blk(p, shd.constrain_activations(x))
    return L.rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def _dec_block_apply(p, x, memory, cfg, positions):
    a = L.attention_apply(
        p["self_attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
        positions=positions, causal=True, use_rope=False,
    )
    x = x + a
    c = L.attention_apply(
        p["cross_attn"], L.rmsnorm(x, p["ln_x"], cfg.norm_eps), cfg,
        positions=positions, causal=False, use_rope=False,
        kv_override=(memory, memory),
    )
    x = x + c
    return x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)


def decode_train(params: Params, tokens: jax.Array, memory: jax.Array, cfg) -> jax.Array:
    B, S = tokens.shape
    D = cfg.d_model
    x = params["embed"][tokens].astype(L._dtype(cfg.dtype))
    x = x + L.sinusoidal_pos(S, D).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    from repro.distributed import sharding as shd

    blk = lambda p, h: _dec_block_apply(p, h, memory, cfg, positions)  # noqa: E731
    if cfg.remat:
        blk = jax.checkpoint(blk)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(
            lambda h, p: (blk(p, shd.constrain_activations(h)), None), x, params["dec_blocks"]
        )
    else:  # unrolled for roofline probes
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            x = blk(p, shd.constrain_activations(x))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"], preferred_element_type=jnp.float32)
    return L.mask_padded_vocab(logits, cfg)


def loss_fn(params: Params, batch: dict, cfg) -> tuple[jax.Array, dict]:
    memory = encode(params, batch["frames"], cfg)
    logits = decode_train(params, batch["tokens"], memory, cfg)
    ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


def init_cache(cfg, batch_size: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    Ldec = cfg.num_layers
    return {
        "k": jnp.zeros((Ldec, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((Ldec, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        # encoder memory is computed once at prefill and carried in the cache
        "memory": jnp.zeros((batch_size, cfg.encoder_seq, cfg.d_model), dtype),
    }


def decode_step(params: Params, cache: dict, token: jax.Array, pos: jax.Array, cfg):
    x = params["embed"][token][:, None, :].astype(L._dtype(cfg.dtype))
    # learned-position stand-in: sinusoidal at pos
    D = cfg.d_model
    pe_table = L.sinusoidal_pos(cache["k"].shape[2], D)
    x = x + pe_table[pos][:, None, :].astype(x.dtype)
    memory = cache["memory"]

    def step(h, layer):
        p, ck, cv = layer
        a, ck2, cv2 = L.attention_decode(
            p["self_attn"], L.rmsnorm(h, p["ln1"], cfg.norm_eps), ck, cv, pos, cfg,
            use_rope=False,
        )
        h = h + a
        c = L.attention_apply(
            p["cross_attn"], L.rmsnorm(h, p["ln_x"], cfg.norm_eps), cfg,
            positions=pos[:, None], causal=False, use_rope=False,
            kv_override=(memory, memory), blockwise=False,
        )
        h = h + c
        h = h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg.act)
        return h, (ck2, cv2)

    x, (ck, cv) = jax.lax.scan(step, x, (params["dec_blocks"], cache["k"], cache["v"]))
    cache = dict(cache, k=ck, v=cv)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["embed"], preferred_element_type=jnp.float32)
    return L.mask_padded_vocab(logits, cfg), cache
