"""PaliGemma-style VLM backbone (arXiv:2407.07726).

The SigLIP vision tower is a STUB per the harness instruction: the model
consumes precomputed patch embeddings (B, N_img, d_model) from
``input_specs``. The language backbone is the gemma-style decoder from
``transformer.py`` with prefix-LM masking: image-prefix positions attend
bidirectionally, text positions causally — implemented via the
``prefix_len`` argument of the attention mask.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as TF

Params = dict[str, Any]


def init_params(cfg, rng) -> Params:
    return TF.init_params(cfg, rng)


def forward(params: Params, tokens: jax.Array, patches: jax.Array, cfg):
    """tokens (B, S_text), patches (B, N_img, D) -> logits over text slots."""
    logits, aux = TF.forward(params, tokens, cfg, prefix_embeds=patches)
    return logits[:, patches.shape[1]:], aux


def loss_fn(params: Params, batch: dict, cfg) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, batch["tokens"], batch["patches"], cfg)
    ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def init_cache(cfg, batch_size: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    return TF.init_cache(cfg, batch_size, max_seq, dtype)


def prefill_prefix(params: Params, patches: jax.Array, cache: dict, cfg) -> dict:
    """Run the image prefix through the decoder once, filling the cache.

    (Serving path; the dry-run decode cell assumes the cache is already
    filled to seq_len and lowers only the steady-state token step.)
    """
    raise NotImplementedError("use decode_step after cache prefill in serve engine")


def decode_step(params: Params, cache: dict, token: jax.Array, pos: jax.Array, cfg):
    return TF.decode_step(params, cache, token, pos, cfg)
