"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD algorithm: within a chunk the recurrence is computed in its
"attention" (quadratic) form on the MXU; across chunks a sequential scan
carries the (heads, head_dim, state) SSM state. Chunk length trades MXU
utilization against scan length (cfg.ssm_chunk; roofline-tuned).

Decode is the pure recurrence: h <- dA * h + dt * x (x) B ; y = C . h —
O(1) per token, which is why mamba2-130m / zamba2-7b run the long_500k
cell (see DESIGN.md).

Reference oracle: ``ssd_reference`` (naive per-token recurrence) —
chunked path is allclose-tested against it.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict[str, Any]


def ssm_init(key, cfg, dtype) -> Params:
    """Input projections are stored *separately* (w_z/w_x/w_b/w_c/w_dt)
    instead of one fused (D, 2di+2N+nh) matrix: the fused width (3352 for
    mamba2-130m) is not divisible by the 16-way TP axis, so the fused
    tensor could not be argument-sharded. Separate tensors shard cleanly
    and XLA fuses the five matmuls back together."""
    D = cfg.d_model
    di = cfg.ssm_d_inner
    N = cfg.ssm_state
    nh = cfg.ssm_heads
    ks = jax.random.split(key, 6)
    return {
        "w_z": L.dense_init(ks[0], D, di, dtype),
        "w_x": L.dense_init(ks[1], D, di, dtype),
        "w_b": L.dense_init(ks[2], D, N, dtype),
        "w_c": L.dense_init(ks[3], D, N, dtype),
        "w_dt": L.dense_init(ks[4], D, nh, dtype),
        "w_out": L.dense_init(ks[5], di, D, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "ln": L.rmsnorm_init(di),
    }


def _project(p: Params, u: jax.Array):
    return u @ p["w_z"], u @ p["w_x"], u @ p["w_b"], u @ p["w_c"], u @ p["w_dt"]


def ssd_chunked(
    x: jax.Array,    # (B, S, nh, hp)
    dt: jax.Array,   # (B, S, nh) post-softplus
    A: jax.Array,    # (nh,) negative
    Bm: jax.Array,   # (B, S, N)
    Cm: jax.Array,   # (B, S, N)
    chunk: int,
    h0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,nh,hp), h_final (B,nh,hp,N))."""
    Bsz, S, nh, hp = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, "seq must be a multiple of ssm_chunk"
    xc = x.reshape(Bsz, nc, chunk, nh, hp)
    dtc = dt.reshape(Bsz, nc, chunk, nh)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, hp, N), jnp.float32)

    # One scan step handles one chunk END TO END (intra + inter + state).
    # Materializing all chunks' (Q, Q, nh) decay tensors at once costs
    # O(S/Q * Q^2 * nh) — terabytes at 32k seq; inside the scan the
    # transient is a single chunk's (B, Q, Q, nh) tile. jax.checkpoint
    # keeps backward from stashing the tile per chunk.
    def step(h, inp):
        xb, dtb, bb, cb = inp  # (B,Q,nh,hp) (B,Q,nh) (B,Q,N) (B,Q,N)
        bb = bb.astype(jnp.float32)
        cb = cb.astype(jnp.float32)
        logd = dtb * A[None, None, :]                    # (B,Q,nh)
        cum = jnp.cumsum(logd, axis=1)
        CB = jnp.einsum("bqs,bks->bqk", cb, bb, preferred_element_type=jnp.float32)
        gap = cum[:, :, None, :] - cum[:, None, :, :]    # (B,Q,Q,nh)
        gap = jnp.where(mask[None, :, :, None], gap, -jnp.inf)
        Smat = CB[..., None] * jnp.exp(gap)              # (B,Q,Q,nh)
        xdt = xb * dtb[..., None]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", Smat, xdt.astype(jnp.float32))
        # inter-chunk: y_i += C_i . h_prev * exp(cum_i)
        y_inter = jnp.einsum("bqs,bhps,bqh->bqhp", cb, h, jnp.exp(cum))
        # state update: h' = exp(cumQ) h + sum_j exp(cumQ - cum_j) B_j xdt_j
        last = cum[:, -1:, :]                            # (B,1,nh)
        tail = jnp.exp(last - cum)                       # (B,Q,nh)
        s_in = jnp.einsum("bks,bkh,bkhp->bhps", bb, tail, xdt.astype(jnp.float32))
        h_new = h * jnp.exp(last[:, 0, :])[..., None, None] + s_in
        return h_new, (y_intra + y_inter).astype(x.dtype)

    scan_in = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2, 3),
    )
    h_fin, yb = jax.lax.scan(jax.checkpoint(step), h0, scan_in)
    y = yb.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, nh, hp)
    return y, h_fin


def ssd_reference(x, dt, A, Bm, Cm) -> jax.Array:
    """Naive per-token recurrence (oracle for tests)."""
    Bsz, S, nh, hp = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,nh,hp) (B,nh) (B,N) (B,N)
        dA = jnp.exp(dtt * A[None, :])                   # (B,nh)
        h = h * dA[..., None, None] + jnp.einsum(
            "bhp,bs,bh->bhps", xt.astype(jnp.float32), bt, dtt
        )
        y = jnp.einsum("bhps,bs->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, nh, hp, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)),
    )
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def ssm_block_apply(p: Params, u: jax.Array, cfg) -> jax.Array:
    """Full mamba2 block: in_proj -> SSD -> gated norm -> out_proj."""
    Bsz, S, D = u.shape
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    z, x, Bm, Cm, dt = _project(p, u)
    x = x.reshape(Bsz, S, nh, hp)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    # B/C stay bf16 on the wire; the chunk step upcasts inside. Keeping
    # the (B, S, *) scan inputs bf16 halves the per-layer stash (zamba2
    # train_4k: 81 layers x 1.75 GiB fp32 residuals dominated the peak).
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(Bsz, S, cfg.ssm_d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["ln"], cfg.norm_eps)
    return y @ p["w_out"]


def ssm_block_decode(
    p: Params, u: jax.Array, state: jax.Array, cfg
) -> tuple[jax.Array, jax.Array]:
    """One-token decode. u (B,1,D), state (B,nh,hp,N)."""
    Bsz = u.shape[0]
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    z, x, Bm, Cm, dt = _project(p, u[:, 0])
    x = x.reshape(Bsz, nh, hp)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                                  # (B,nh)
    state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bs,bh->bhps", x.astype(jnp.float32), Bm.astype(jnp.float32), dt
    )
    y = jnp.einsum("bhps,bs->bhp", state, Cm.astype(jnp.float32))
    y = y.astype(x.dtype) + x * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(Bsz, cfg.ssm_d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["ln"], cfg.norm_eps)
    return (y @ p["w_out"])[:, None, :], state


# ---------------------------------------------------------------------------
# full LM (attention-free stack)
# ---------------------------------------------------------------------------

def block_init(key, cfg, dtype) -> Params:
    return {"ln": L.rmsnorm_init(cfg.d_model), "ssm": ssm_init(key, cfg, dtype)}


def init_params(cfg, rng) -> Params:
    dtype = L._dtype(cfg.dtype)
    k_emb, k_blocks = jax.random.split(rng)
    block_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg, dtype))(block_keys)
    return {
        "embed": L.embed_init(k_emb, cfg.padded_vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }


def forward(params: Params, tokens: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    x = params["embed"][tokens].astype(L._dtype(cfg.dtype))

    def blk(p, h):
        return h + ssm_block_apply(p["ssm"], L.rmsnorm(h, p["ln"], cfg.norm_eps), cfg)

    from repro.distributed import sharding as shd

    if cfg.remat:
        blk = jax.checkpoint(blk)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(
            lambda h, p: (blk(p, shd.constrain_activations(h)), None), x, params["blocks"]
        )
    else:
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["blocks"])
            x = blk(p, shd.constrain_activations(x))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    # embeddings are tied (standard for mamba2 checkpoints)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"], preferred_element_type=jnp.float32)
    return L.mask_padded_vocab(logits, cfg), jnp.float32(0.0)


def loss_fn(params: Params, batch: dict, cfg) -> tuple[jax.Array, dict]:
    logits, _ = forward(params, batch["tokens"], cfg)
    ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


def init_cache(cfg, batch_size: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    del max_seq, dtype  # SSM state is O(1) in sequence length
    return {
        "state": jnp.zeros(
            (cfg.num_layers, batch_size, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        )
    }


def decode_step(params: Params, cache: dict, token: jax.Array, pos: jax.Array, cfg):
    del pos  # recurrence is position-free
    x = params["embed"][token][:, None, :].astype(L._dtype(cfg.dtype))

    # state rides the carry with in-place updates (see transformer.decode_step)
    def body(i, carry):
        h, states = carry
        p = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["blocks"],
        )
        st = jax.lax.dynamic_index_in_dim(states, i, 0, keepdims=False)
        y, st2 = ssm_block_decode(p["ssm"], L.rmsnorm(h, p["ln"], cfg.norm_eps), st, cfg)
        states = jax.lax.dynamic_update_index_in_dim(states, st2, i, 0)
        return (h + y, states)

    if cfg.scan_layers:
        x, states = jax.lax.fori_loop(0, cfg.num_layers, body, (x, cache["state"]))
    else:  # unrolled for roofline probes
        carry = (x, cache["state"])
        for i in range(cfg.num_layers):
            carry = body(i, carry)
        x, states = carry
    cache = {"state": states}
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["embed"], preferred_element_type=jnp.float32)
    return L.mask_padded_vocab(logits, cfg), cache
