"""Mixture-of-Experts layer with partitioner-based dispatch.

This is the paper's technique as a *first-class feature* of the LM stack:
token -> expert dispatch is a partition problem. Tokens are laid on a
1-D curve (sorted by expert assignment — the analogue of SFC order),
positions within each expert come from a parallel prefix (the paper's
"global rank on a weighted line segment"), and capacity slicing is the
greedy knapsack. Overflow beyond capacity is dropped exactly like
bounded-MAX_MSG_SIZE migration rounds; the auxiliary load-balancing loss
plays the paper's incremental-LB role, and ``expert_load`` feeds the
``AmortizedController`` that decides when to re-place experts across EP
shards (see runtime/elastic.py).

Expert weights are stacked (E, D, F): sharding rules put E on the
"model" axis (expert parallelism) for many-expert archs (qwen3: 128e),
or shard F within experts (TP) for few-expert archs (mixtral: 8e).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict[str, Any]


def moe_init(key, cfg, dtype) -> Params:
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(D)
    scale_out = 1.0 / jnp.sqrt(F)
    # gate/up stored separately: a fused (E, D, 2F) tensor needs a
    # jnp.split whose halves lose the TP sharding under GSPMD (measured
    # 10 GiB fp32 all-gathers per half at mixtral train_4k)
    return {
        "router": L.dense_init(k1, D, E, jnp.float32),
        "wg": (jax.random.normal(k2, (E, D, F), jnp.float32) * scale_in).astype(dtype),
        "wu": (jax.random.normal(k4, (E, D, F), jnp.float32) * scale_in).astype(dtype),
        "wo": (jax.random.normal(k3, (E, F, D), jnp.float32) * scale_out).astype(dtype),
    }


def moe_apply(
    p: Params, x: jax.Array, cfg, *, capacity_factor: float = 1.25
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (B, S, D), aux load-balance loss.

    *Grouped* sort-based dispatch (knapsack curve per group): each batch
    row is a dispatch group, so every sort/scatter is local to the row
    and the whole computation stays sharded over the batch axis — no
    global T x K x D gather (an earlier global variant measured 235
    GiB/device at qwen3 train_4k; see EXPERIMENTS.md §Perf).

      1. top-k routing -> (B, S*K) expert choices with combine weights
      2. per-row stable sort by expert id = "curve order"
      3. position-in-expert via prefix ranks (rank on the weighted curve)
      4. capacity-sliced scatter into (B, E, Cr, D); batched expert
         einsum; combine back. Overflow drops (bounded MAX_MSG_SIZE).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    TK = S * K

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"]
    )  # (B, S, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, K)  # (B, S, K)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[tope.reshape(-1)].add(1.0) / (B * TK)
    aux = E * jnp.sum(me * ce)

    # --- per-row curve ordering + prefix ranks ----------------------------
    flat_e = tope.reshape(B, TK)                                  # (B, S*K)
    flat_w = topw.reshape(B, TK)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)[None, :], (B, TK)
    )
    order = jnp.argsort(flat_e, axis=1, stable=True)              # curve order
    e_s = jnp.take_along_axis(flat_e, order, axis=1)
    w_s = jnp.take_along_axis(flat_w, order, axis=1)
    t_s = jnp.take_along_axis(flat_t, order, axis=1)
    # rank within expert: index - start_of_expert (vectorized searchsorted)
    starts = jax.vmap(lambda es: jnp.searchsorted(es, jnp.arange(E, dtype=es.dtype)))(e_s)
    pos_in_e = jnp.arange(TK, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        starts, e_s, axis=1
    )

    from repro.distributed import sharding as shd

    C = int(max(1, capacity_factor * TK / E))
    keep = pos_in_e < C

    # Dispatch is vmapped over the batch row: the per-row gather/scatter
    # then lowers with explicit batching dims, which GSPMD partitions
    # along the batch axis. (A flat formulation with compound 3-D scatter
    # indices defeated the SPMD partitioner and replicated the operand —
    # measured 80 GiB operand-shaped u32 maps at qwen3 train_4k.)
    def _dispatch_row(x_row, t_row, e_row, p_row):
        xg = x_row.at[t_row].get(mode="promise_in_bounds")        # (TK, D)
        buf = jnp.zeros((E, C, D), x.dtype)
        # overflow rides pos >= C and is dropped (bounded MAX_MSG_SIZE);
        # do NOT clip-and-zero: a clipped .set would stomp slot 0.
        return buf.at[e_row, p_row].set(xg, mode="drop")

    buf = jax.vmap(_dispatch_row)(x, t_s, e_s, pos_in_e)          # (B, E, C, D)
    buf = shd.constrain_moe(buf, "buf", E)

    # --- expert computation (groups batched; experts stacked) -------------
    gate = jnp.einsum("becd,edf->becf", buf, p["wg"])
    up = jnp.einsum("becd,edf->becf", buf, p["wu"])
    gate = shd.constrain_moe(gate, "h", E)
    up = shd.constrain_moe(up, "h", E)
    h = shd.constrain_moe(jax.nn.silu(gate) * up, "h", E)
    out_e = jnp.einsum("becf,efd->becd", h, p["wo"])              # (B, E, C, D)
    out_e = shd.constrain_moe(out_e, "buf", E)

    # --- combine back (vmapped like the dispatch) ---------------------------
    pos_c = jnp.minimum(pos_in_e, C - 1)

    def _combine_row(oe_row, e_row, p_row, t_row, w_row, keep_row):
        g = oe_row.at[e_row, p_row].get(mode="promise_in_bounds")  # (TK, D)
        g = jnp.where(keep_row[:, None], g, 0.0)                   # drop overflow
        contrib = g * w_row[:, None].astype(g.dtype)
        y_row = jnp.zeros((S, D), contrib.dtype)
        return y_row.at[t_row].add(contrib, mode="promise_in_bounds")

    y = jax.vmap(_combine_row)(out_e, e_s, pos_c, t_s, w_s, keep)
    return y, aux


def expert_load(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Token count per expert for this batch — the weight vector the
    AmortizedController watches to trigger expert re-placement."""
    B, S, D = x.shape
    logits = x.reshape(-1, D).astype(jnp.float32) @ p["router"]
    _, tope = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.num_experts_per_tok)
    return jnp.zeros((cfg.num_experts,), jnp.int32).at[tope.reshape(-1)].add(1)


def rebalance_expert_placement(load: jax.Array, num_shards: int):
    """Knapsack re-placement of experts onto EP shards (paper §III-C
    applied to expert weights): experts in id order form the curve,
    loads are the weights, the slice gives shard assignments.

    Returns (assignment (E,), migration plan vs round-robin baseline).
    """
    from repro.core import knapsack, migration
    import numpy as np

    E = load.shape[0]
    part = knapsack.slice_weighted_curve(jnp.asarray(load, jnp.float32), num_shards)
    baseline = np.arange(E) % num_shards  # default round-robin placement
    plan = migration.migration_plan(baseline, np.asarray(part), num_shards)
    return part, plan
