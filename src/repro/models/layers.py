"""Neural net layers in pure JAX (no flax): params are nested dicts,
layers are (init, apply) function pairs.

Attention supports:
  * full causal / bidirectional / prefix-LM masking
  * GQA (num_kv_heads < num_heads)
  * sliding-window masking (mixtral)
  * blockwise "flash" execution with online softmax (O(S) memory) —
    the default for long sequences; validated against the naive path.
  * single-token decode against a KV cache.

Compute dtype is bf16 with fp32 softmax/norm accumulation (TPU MXU native
layout; matmul dims padded by the caller's configs to 128 multiples where
it matters — see DESIGN.md roofline notes).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int) -> jax.Array:
    return jnp.ones((dim,), jnp.float32)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # the f32 upcast feeds ONLY the variance reduction; normalizing in the
    # input dtype keeps all full-size tensors bf16 — otherwise XLA fuses
    # the upcast into the layer-scan remat stash and stores it in f32
    # (measured 2x stash: 6.8 GiB vs 3.4 GiB at coder-33b train_4k)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_init(key, cfg, dtype) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], D, H * hd, dtype),
        "wk": dense_init(ks[1], D, KV * hd, dtype),
        "wv": dense_init(ks[2], D, KV * hd, dtype),
        "wo": dense_init(ks[3], H * hd, D, dtype),
    }


def _mask_bias(
    q_pos: jax.Array, k_pos: jax.Array, *, causal: bool, window: int,
    prefix_len: jax.Array | int = 0,
) -> jax.Array:
    """(..., Sq, Sk) additive bias: 0 allowed / -inf masked.

    prefix-LM: positions < prefix_len attend bidirectionally (paligemma).
    """
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        cau = q_pos[..., :, None] >= k_pos[..., None, :]
        if not isinstance(prefix_len, int) or prefix_len != 0:
            bidir = k_pos[..., None, :] < prefix_len
            cau = cau | bidir
        ok = ok & cau
    if window:
        ok = ok & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """q: (B,Sq,H,hd) k/v: (B,Sk,KV,hd) bias: (B?,Sq,Sk) fp32."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_blockwise(q, k, v, *, causal: bool, window: int, prefix_len, block_q: int, block_k: int):
    """Flash-style blockwise attention with online softmax (O(S·block) memory).

    Scan over KV blocks carrying (running max, denom, accum); outer scan
    over Q blocks. Bias recomputed per block from positions — no S x S
    materialization. Matches `_sdpa` to bf16 tolerance (tested).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    nq = (Sq + block_q - 1) // block_q
    nk = (Sk + block_k - 1) // block_k
    # pad to block multiples
    q_pad = jnp.pad(q, ((0, 0), (0, nq * block_q - Sq), (0, 0), (0, 0)))
    k_pad = jnp.pad(k, ((0, 0), (0, nk * block_k - Sk), (0, 0), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (0, nk * block_k - Sk), (0, 0), (0, 0)))
    qb = q_pad.reshape(B, nq, block_q, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)  # (nq,B,KV,G,bq,hd)
    kb = k_pad.reshape(B, nk, block_k, KV, hd).transpose(1, 0, 3, 2, 4)        # (nk,B,KV,bk,hd)
    vb = v_pad.reshape(B, nk, block_k, KV, hd).transpose(1, 0, 3, 2, 4)
    from repro.distributed import sharding as shd

    qb, kb, vb = shd.constrain_blocked_attention(qb, kb, vb)
    scale = 1.0 / math.sqrt(hd)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk  # index + (B,KV,G,bq,hd)
        q_pos = qi * block_q + jnp.arange(block_q)

        def kv_step(carry, kv_blk):
            m, l, acc = carry
            ki, kblk, vblk = kv_blk
            k_pos = ki * block_k + jnp.arange(block_k)
            s = jnp.einsum("bkgqh,bksh->bkgqs", qblk, kblk, preferred_element_type=jnp.float32) * scale
            ok = k_pos[None, :] < Sk  # padding mask
            allow = jnp.ones((block_q, block_k), bool)
            if causal:
                cau = q_pos[:, None] >= k_pos[None, :]
                if not (isinstance(prefix_len, int) and prefix_len == 0):
                    cau = cau | (k_pos[None, :] < prefix_len)
                allow = allow & cau
            if window:
                allow = allow & (q_pos[:, None] - k_pos[None, :] < window)
            allow = allow & ok
            s = jnp.where(allow, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(allow, p, 0.0)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksh->bkgqh", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, block_q, hd), jnp.float32)
        # remat each KV step: without it the scan saves the (bq, bk) prob
        # tiles of EVERY block for backward — measured 9 GiB/device at
        # train_4k. Recomputing the tile in the backward pass keeps the
        # stash at the (m, l, acc) carry only.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(l[..., None], 1e-20)
        return None, out

    # two-level remat: checkpointing the whole q block keeps only qblk per
    # block; the kv-scan residuals (the fp32 acc per kv block — measured
    # 3.5 GiB at coder-33b train_4k) exist only transiently inside the
    # recomputed backward of one q block.
    _, ob = jax.lax.scan(jax.checkpoint(q_step), None, (jnp.arange(nq), qb))
    # ob: (nq, B, KV, G, bq, hd) -> (B, Sq, H, hd)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * block_q, H, hd)
    return out[:, :Sq].astype(q.dtype)


def attention_apply(
    params: Params,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array | None = None,
    causal: bool = True,
    window: int = 0,
    prefix_len: jax.Array | int = 0,
    use_rope: bool = True,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
    blockwise: bool | None = None,
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    """Full-sequence attention (training / prefill). x: (B, S, D)."""
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    if kv_override is None:
        k = (x @ params["wk"]).reshape(B, S, KV, hd)
        v = (x @ params["wv"]).reshape(B, S, KV, hd)
        k_pos = positions
    else:  # cross attention: kv from encoder memory
        mem = kv_override[0]
        k = (mem @ params["wk"]).reshape(B, mem.shape[1], KV, hd)
        v = (mem @ params["wv"]).reshape(B, mem.shape[1], KV, hd)
        k_pos = jnp.broadcast_to(jnp.arange(mem.shape[1], dtype=jnp.int32), (B, mem.shape[1]))
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = apply_rope(k, k_pos, cfg.rope_theta)
    if blockwise is None:
        blockwise = S >= 4096 and kv_override is None
    if blockwise:
        out = _sdpa_blockwise(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len,
            block_q=block_q, block_k=block_k,
        )
    else:
        bias = _mask_bias(positions, k_pos, causal=causal, window=window, prefix_len=prefix_len)
        out = _sdpa(q, k, v, bias)
    return out.reshape(B, S, H * hd) @ params["wo"]


def attention_decode(
    params: Params,
    x: jax.Array,               # (B, 1, D) current token hidden
    cache_k: jax.Array,         # (B, S_max, KV, hd)
    cache_v: jax.Array,
    pos: jax.Array,             # (B,) int32 current position
    cfg,
    *,
    window: int = 0,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode. Returns (out (B,1,D), new_cache_k, new_cache_v).

    With a sliding window the cache is a rolling buffer of size
    min(S_max, window): writes wrap around (position mod window), which
    caps the long_500k KV footprint for SWA archs (mixtral).
    """
    B, _, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    S_max = cache_k.shape[1]
    q = (x @ params["wq"]).reshape(B, 1, H, hd)
    k = (x @ params["wk"]).reshape(B, 1, KV, hd)
    v = (x @ params["wv"]).reshape(B, 1, KV, hd)
    if use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    slot = pos % S_max if window else jnp.minimum(pos, S_max - 1)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])
    # scores over the whole cache; invalid slots masked by position
    slots = jnp.arange(S_max)
    if window:
        # rolling buffer: slot s holds absolute position p iff p = pos - ((slot-s) mod S_max)
        age = (slot[:, None] - slots[None, :]) % S_max   # (B, S_max)
        abs_pos = pos[:, None] - age
        valid = (abs_pos >= 0) & (age < S_max)
    else:
        abs_pos = jnp.broadcast_to(slots[None, :], (B, S_max))
        valid = slots[None, :] <= pos[:, None]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, cache_k, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, cache_v).reshape(B, 1, H * hd)
    return out @ params["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype, act: str = "silu") -> Params:
    k1, k2 = jax.random.split(key)
    if act == "silu":  # gated: fused gate+up
        return {"wi": dense_init(k1, d_model, 2 * d_ff, dtype), "wo": dense_init(k2, d_ff, d_model, dtype)}
    return {"wi": dense_init(k1, d_model, d_ff, dtype), "wo": dense_init(k2, d_ff, d_model, dtype)}


def mlp_apply(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    h = x @ params["wi"]
    if act == "silu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(h)
    return h @ params["wo"]


def mask_padded_vocab(logits: jax.Array, cfg) -> jax.Array:
    """-inf the vocab-padding slots (cfg.padded_vocab_size > vocab_size).

    Padding keeps the vocab dim divisible by the TP axis so logits shard;
    without it odd vocab sizes forced replicated fp32 logits (61.9
    GiB/device at minicpm prefill_32k). The iota-compare fuses into the
    logits einsum epilogue — no extra HBM traffic.
    """
    if cfg.padded_vocab_size == cfg.vocab_size:
        return logits
    vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    # large-finite (not -inf): the CE one-hot contraction would otherwise
    # produce -inf * 0 = NaN at the padded slots
    return jnp.where(vid < cfg.vocab_size, logits, jnp.float32(-1e9))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """Mean CE over valid positions; logits (B,S,V) fp32-accumulated.

    The gold logit is picked with a fused one-hot contraction instead of
    take_along_axis: under GSPMD a gather across the vocab-sharded dim
    would all-gather the full fp32 logits (measured: 12+ GiB/device at
    train_4k); the one-hot reduction keeps the vocab dim sharded and
    reduces to (B, S) with a per-shard partial sum.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
