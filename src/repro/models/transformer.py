"""Decoder-only transformer LM (llama-family: deepseek-coder-33b,
smollm-135m, deepseek-7b, minicpm-2b; also the backbone for mixtral /
qwen3-moe / paligemma).

Layers are stacked along a leading axis and executed with ``lax.scan`` so
HLO size is O(1) in depth — essential for 62-layer configs compiled for a
512-chip mesh. ``cfg.remat`` wraps the block in ``jax.checkpoint``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# one transformer block
# ---------------------------------------------------------------------------

def block_init(key, cfg, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(k1, cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model),
    }
    if cfg.family == "moe":
        from repro.models import moe as M

        p["moe"] = M.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.act)
    return p


def block_apply(
    p: Params, x: jax.Array, cfg, *, positions, prefix_len=0, blockwise=None
) -> tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss). aux_loss is 0 for dense blocks."""
    h = L.attention_apply(
        p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
        positions=positions, causal=True, window=cfg.window,
        prefix_len=prefix_len, blockwise=blockwise,
    )
    x = x + h
    hin = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        from repro.models import moe as M

        h2, aux = M.moe_apply(p["moe"], hin, cfg)
    else:
        h2 = L.mlp_apply(p["mlp"], hin, cfg.act)
        aux = jnp.float32(0.0)
    return x + h2, aux


def block_decode(
    p: Params, x: jax.Array, ck: jax.Array, cv: jax.Array, pos: jax.Array, cfg
) -> tuple[jax.Array, jax.Array, jax.Array]:
    h, ck, cv = L.attention_decode(
        p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), ck, cv, pos, cfg,
        window=cfg.window,
    )
    x = x + h
    hin = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        from repro.models import moe as M

        h2, _ = M.moe_apply(p["moe"], hin, cfg)
    else:
        h2 = L.mlp_apply(p["mlp"], hin, cfg.act)
    return x + h2, ck, cv


# ---------------------------------------------------------------------------
# full LM
# ---------------------------------------------------------------------------

def init_params(cfg, rng) -> Params:
    dtype = L._dtype(cfg.dtype)
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)
    block_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg, dtype))(block_keys)
    params = {
        "embed": L.embed_init(k_emb, cfg.padded_vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.padded_vocab_size, dtype)
    return params


def _scan_blocks(params, x, cfg, positions, prefix_len=0):
    """Run all blocks; scan if cfg.scan_layers else unrolled python loop."""
    base = functools.partial(
        block_apply, cfg=cfg, positions=positions, prefix_len=prefix_len
    )
    if cfg.remat:
        blk = jax.checkpoint(lambda p, h, _b=base: _b(p, h))
    else:
        blk = lambda p, h, _b=base: _b(p, h)  # noqa: E731

    from repro.distributed import sharding as shd

    if cfg.scan_layers:
        def step(h, p):
            h = shd.constrain_activations(h)
            h2, aux = blk(p, h)
            return h2, aux

        x, auxs = jax.lax.scan(step, x, params["blocks"])
        return shd.constrain_activations(x), jnp.sum(auxs)
    aux_total = jnp.float32(0.0)
    for i in range(cfg.num_layers):
        p = jax.tree.map(lambda a: a[i], params["blocks"])
        x, aux = blk(p, shd.constrain_activations(x))
        aux_total = aux_total + aux
    return shd.constrain_activations(x), aux_total


def forward(
    params: Params, tokens: jax.Array, cfg, *, prefix_embeds: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> logits (B, S_total, V). prefix_embeds (B, P, D)
    prepends modality embeddings (vlm stub). Returns (logits, aux_loss)."""
    x = params["embed"][tokens].astype(L._dtype(cfg.dtype))
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux = _scan_blocks(params, x, cfg, positions, prefix_len=prefix_len)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=jnp.float32)
    return L.mask_padded_vocab(logits, cfg), aux


def loss_fn(params: Params, batch: dict, cfg) -> tuple[jax.Array, dict]:
    prefix = batch.get("patches")
    logits, aux = forward(params, batch["tokens"], cfg, prefix_embeds=prefix)
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode path (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    S = min(max_seq, cfg.window) if cfg.window else max_seq
    shape = (cfg.num_layers, batch_size, S, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(
    params: Params, cache: dict, token: jax.Array, pos: jax.Array, cfg
) -> tuple[jax.Array, dict]:
    """One-token decode. token (B,), pos (B,) -> (logits (B, V), cache).

    The cache rides the loop CARRY with dynamic in-place slice updates
    rather than scan xs->ys: scan ys are always freshly allocated, which
    tripled the live KV bytes (measured 34 GiB vs an 8 GiB cache at
    deepseek-7b decode_32k). fori_loop + dynamic_update_index is aliased
    in place by XLA, and the jit-level donation covers input->output.
    """
    x = params["embed"][token][:, None, :].astype(L._dtype(cfg.dtype))

    def body(i, carry):
        h, ck_all, cv_all = carry
        p = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["blocks"],
        )
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        h2, ck2, cv2 = block_decode(p, h, ck, cv, pos, cfg)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck2, i, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv2, i, 0)
        return (h2, ck_all, cv_all)

    if cfg.scan_layers:
        x, ck, cv = jax.lax.fori_loop(
            0, cfg.num_layers, body, (x, cache["k"], cache["v"])
        )
    else:  # unrolled: used by the roofline probes (loop bodies are counted
        #    once by XLA cost analysis, so probes must not loop)
        ck, cv = cache["k"], cache["v"]
        carry = (x, ck, cv)
        for i in range(cfg.num_layers):
            carry = body(i, carry)
        x, ck, cv = carry
    cache = {"k": ck, "v": cv}
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=jnp.float32)
    return L.mask_padded_vocab(logits, cfg)[:, 0], cache
