"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization. Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis is DCN
data parallelism (see DESIGN.md §6).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:  # jax 0.4.x: every axis is implicitly Auto
    def _axis_kw(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh with Auto axis types (tests / small-scale runs)."""
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def host_device_mesh(n_data: int = 1, n_model: int = 1) -> jax.sharding.Mesh:
    """Mesh over however many (possibly fake) devices exist."""
    return make_mesh((n_data, n_model), ("data", "model"))
