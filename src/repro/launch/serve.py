"""Serving launcher: knapsack-batched greedy decoding (see
repro/serve/engine.py). CPU-scale demo entrypoint."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serve.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    rngs = np.random.default_rng(0)
    params = M.get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_seq=128, batch_size=4)
    reqs = [
        Request(
            rid=i,
            prompt=rngs.integers(0, cfg.vocab_size, rngs.integers(3, 40)).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    results = engine.run(reqs)
    for rid in sorted(results):
        print(f"req {rid}: {results[rid]}")
    print(f"[serve] completed {len(results)} requests")


if __name__ == "__main__":
    main()
