"""Training launcher: wires the data pipeline, train step, checkpointing,
fault tolerance and the amortized-LB controller around the step loop.

On the CPU container this runs reduced configs end-to-end (see
examples/train_lm.py); on a real pod the same entrypoint takes
``--arch <id>`` with the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import ARCHS, SHAPES, reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.dynamic import AmortizedController
from repro.data import pipeline as dp
from repro.runtime import fault_tolerance as ft
from repro.train import step as ts


def train_loop(
    run: RunConfig,
    *,
    steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 100,
    log_every: int = 10,
    resume: bool = True,
    data_cfg: dp.DataConfig | None = None,
) -> dict:
    cfg = run.model
    shape = run.shape
    data_cfg = data_cfg or dp.DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=run.seed,
    )
    rng = jax.random.PRNGKey(run.seed)
    params, opt_state = ts.init_all(run, rng)
    start_step = 0
    acp = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and resume:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                {"params": params, "opt": opt_state},
            )
            tree, extra = ckpt.restore(ckpt_dir, latest, like)
            params, opt_state = tree["params"], tree["opt"]
            start_step = int(extra.get("data_step", latest))
            print(f"[train] resumed from step {latest}")

    # no donate_argnums on the runtime path: identical init constants
    # (e.g. the ln1/ln2 ones tables under the vmap'd block init) can be
    # deduplicated into one buffer, and donating an aliased buffer twice
    # aborts Execute(). Production jobs restore params from checkpoints
    # (distinct buffers) and can re-enable donation.
    step_fn = jax.jit(ts.make_train_step(run, total_steps=steps))
    controller = AmortizedController()
    losses = []
    t_loop = time.time()
    for step in range(start_step, steps):
        batch_np = dp.synthetic_tokens(data_cfg, step, shard=0)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if run.model.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(rng, step),
                (shape.global_batch, shape.seq_len, cfg.d_model),
                jnp.float32,
            )
        if run.model.family == "vlm":
            batch["patches"] = jax.random.normal(
                jax.random.fold_in(rng, step),
                (shape.global_batch, cfg.num_prefix_tokens, cfg.d_model),
                jnp.float32,
            )
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        if controller.observe(dt, 1):
            # in a real job this triggers the knapsack re-slice of data
            # shards (ft.reslice_*); single-host: just re-arm the credits
            controller.balanced(lb_cost=dt, num_buckets=1, timeop=dt)
        if step % log_every == 0:
            print(
                f"[train] step={step} loss={loss:.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.2f} "
                f"dt={dt*1e3:.0f}ms"
            )
        if acp and step > 0 and step % ckpt_every == 0:
            acp.save(step, {"params": params, "opt": opt_state}, extra={"data_step": step})
    if acp:
        acp.save(steps, {"params": params, "opt": opt_state}, extra={"data_step": steps})
        acp.wait()
    return {
        "final_loss": losses[-1],
        "first_loss": losses[0],
        "losses": losses,
        "steps": len(losses),
        "wall_s": time.time() - t_loop,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", help="smoke-size model")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("custom", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape, learning_rate=args.lr, schedule=args.schedule)
    out = train_loop(run, steps=args.steps, ckpt_dir=args.ckpt_dir)
    print(
        f"[train] done: loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
        f"in {out['steps']} steps ({out['wall_s']:.0f}s)"
    )


if __name__ == "__main__":
    main()
