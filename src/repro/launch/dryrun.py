"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory / cost / collective analyses.

MUST set the fake-device flag before any other import (jax locks the
device count at first init)."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, ShardingRules
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.train import step as train_step_mod

# ---------------------------------------------------------------------------
# cell enumeration (40 cells; long_500k skips per DESIGN.md)
# ---------------------------------------------------------------------------

def cells() -> list[tuple[str, str, str]]:
    """[(arch, shape, status)]; status in {run, skip:<reason>}."""
    out = []
    for arch, cfg in ARCHS.items():
        for sname in SHAPES:
            if sname == "long_500k" and not cfg.sub_quadratic:
                out.append((arch, sname, "skip:full-attention arch at 524k decode"))
            else:
                out.append((arch, sname, "run"))
    return out


# ---------------------------------------------------------------------------
# collective parsing from (per-device) optimized HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d,]+\}(?:,\{[\d,]+\})*)\}")


def parse_inter_node_bytes(hlo_text: str, node_of) -> dict:
    """Classify every collective's traffic as intra- vs inter-node from
    optimized (per-device) HLO. ``node_of`` maps global device id ->
    node id (e.g. ``[g // D for g in range(N * D)]`` for a node-major
    (N, D) mesh).

    For each collective replica group, every member receives one
    per-peer operand chunk from every other member; chunks whose sender
    sits on a different node are inter-node bytes. This measures the
    *compiled program* — the gate in ``benchmarks/bench_hierarchy.py``
    uses it so an aggregation regression in the exchange kernels fails
    CI even though the analytic accounting formula would not notice.

    Conservative on fused/async variants: ``*-done`` lines are skipped
    (their ``*-start`` carries the shape) and unknown group syntax is
    counted in ``unparsed``.
    """
    inter = 0
    intra = 0
    ops = 0
    unparsed = 0
    for line in hlo_text.splitlines():
        coll = next(
            (c for c in _COLLECTIVES
             if f" {c}(" in line or f" {c}-start(" in line),
            None,
        )
        if coll is None:
            continue
        m = _GROUPS_RE.search(line)
        if not m:
            unparsed += 1
            continue
        groups = [
            [int(x) for x in grp.split(",")]
            for grp in m.group(1)[1:-1].split("},{")
        ]
        lhs = line.split(f" {coll}", 1)[0]
        shapes = _SHAPE_RE.findall(lhs)
        res_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
        gsize = max(len(groups[0]), 1)
        if coll in ("all-gather", "all-to-all"):
            per_peer = res_bytes // gsize
        else:  # all-reduce / reduce-scatter / collective-permute: one
            per_peer = res_bytes  # operand per peer exchange (lower bound)
        ops += 1
        for grp in groups:
            for p in grp:
                for q in grp:
                    if q == p:
                        continue
                    if node_of[q] != node_of[p]:
                        inter += per_peer
                    else:
                        intra += per_peer
    return {
        "inter_node_bytes": inter,
        "intra_node_bytes": intra,
        "collectives": ops,
        "unparsed": unparsed,
    }


def parse_collectives(hlo_text: str) -> dict:
    """Sum *operand* bytes of every collective op, tracking while-loop trip
    counts so collectives inside scanned layers are multiplied out.

    Loop handling: XLA names fusion/while computations; instructions inside
    a while body appear inside `%while_body_N { ... }` computations. We
    detect trip counts from jax scan patterns: the loop condition compares
    the induction variable against a constant `s32[] constant(K)`. When a
    trip count can't be inferred, the multiplier defaults to 1 and the op
    is flagged (count_uncertain).
    """
    # map computation name -> text
    comps: dict[str, str] = {}
    for m in re.finditer(r"^%?([\w\.\-]+) (?:\([^\n]*\) -> [^\n]*)?\{", hlo_text, re.M):
        name = m.group(1)
        start = m.end()
        depth = 1
        i = start
        while depth and i < len(hlo_text):
            if hlo_text[i] == "{":
                depth += 1
            elif hlo_text[i] == "}":
                depth -= 1
            i += 1
        comps[name.strip()] = hlo_text[start:i]

    # find while ops: `while(...)`, with body=%name, condition=%name
    trip: dict[str, int] = {}  # body computation -> trip count
    for m in re.finditer(r"while\([^\)]*\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", hlo_text):
        cond_name, body_name = m.group(1), m.group(2)
        cond = comps.get(cond_name, "")
        k = None
        cm = re.findall(r"constant\((\d+)\)", cond)
        if cm:
            k = max(int(c) for c in cm)
        trip[body_name] = k if k else 1

    totals = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    uncertain = 0

    group_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    group_re2 = re.compile(r"replica_groups=\{\{([\d,]+)\}")

    def _group_size(line: str) -> int:
        m = group_re.search(line)
        if m:
            return max(int(m.group(2)), 1)
        m = group_re2.search(line)
        if m:
            return max(len(m.group(1).split(",")), 1)
        return 1

    def scan_text(text: str, mult: int):
        nonlocal uncertain
        for line in text.splitlines():
            for coll in _COLLECTIVES:
                # the optimized HLO prints operands as bare names
                # (`all-gather(%fusion.12)`), so we read the RESULT type
                # on the lhs and convert to operand bytes per op
                # semantics: all-gather operand = result/group;
                # reduce-scatter operand = result*group; others equal.
                if f" {coll}(" not in line and f" {coll}-start(" not in line:
                    continue
                lhs = line.split(f" {coll}", 1)[0]
                shapes = _SHAPE_RE.findall(lhs)
                res_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
                g = _group_size(line)
                if coll == "all-gather":
                    op_bytes = res_bytes // g
                elif coll == "reduce-scatter":
                    op_bytes = res_bytes * g
                else:  # all-reduce / all-to-all / collective-permute
                    op_bytes = res_bytes
                totals[coll] += op_bytes * mult
                counts[coll] += mult
                break

    # main entry computation: anything not a while body runs once
    body_names = set(trip)
    for name, text in comps.items():
        mult = trip.get(name, 1)
        if name in body_names:
            scan_text(text, mult)
    # top-level lines (entry computation may not be captured above)
    entry = hlo_text
    for name in comps:
        pass
    # lines outside any tracked while body: approximate by scanning whole
    # text once and subtracting the bodies' single-count contribution,
    # which we already added with multipliers. Simpler: scan only the
    # entry computation (ENTRY marker).
    em = re.search(r"ENTRY [^\{]*\{(.*)$", hlo_text, re.S)
    if em:
        entry = em.group(1)
        scan_text(entry, 1)

    totals["total_bytes"] = sum(totals[c] for c in _COLLECTIVES)
    totals["counts"] = counts
    totals["uncertain"] = uncertain
    return totals


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

# grad-accumulation microbatch per arch for train cells: the remat stash
# and MoE dispatch buffers scale with the live microbatch, not the global
# batch (memory-roofline lever; see EXPERIMENTS.md §Perf)
MICROBATCH = {
    "deepseek-coder-33b": 64,
    "deepseek-7b": 128,
    "zamba2-7b": 32,     # peak plateaus below mb=32 (batch-independent SSD transients)
    "mixtral-8x22b": 32,  # argument-bound (7.2 GiB fp32 Adam); multi-pod halves it
    "qwen3-moe-30b-a3b": 64,
    "paligemma-3b": 64,
}


def build_cell_fn(
    cfg: ModelConfig, shape: ShapeConfig, mesh, rules: ShardingRules,
    *, microbatch: int | None | str = "default",
):
    """Returns (fn, example_args_with_shardings, out_shardings).

    ``microbatch=None`` disables grad accumulation (roofline probes must:
    the accumulation scan body is counted once by cost analysis).
    """
    sds = M.input_specs(cfg, shape)
    if microbatch == "default":
        microbatch = MICROBATCH.get(cfg.name) if shape.kind == "train" else None
    run = RunConfig(model=cfg, shape=shape, rules=rules, microbatch=microbatch)

    params_shapes = jax.eval_shape(
        lambda: M.get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    )
    psh = shd.param_shardings(mesh, cfg, rules, params_shapes)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw.init, params_shapes)
        osh = shd.opt_state_shardings(mesh, cfg, rules, opt_shapes, psh)
        bsh = shd.batch_shardings(mesh, cfg, rules, sds)
        step = train_step_mod.make_train_step(run)
        in_sh = (psh, osh, bsh)
        out_sh = (psh, osh, None)
        args = (params_shapes, opt_shapes, sds)
        return step, args, in_sh, out_sh
    if shape.kind == "prefill":
        rules = replace(rules, blocked_attn=False)  # fwd-only: GSPMD's layout wins
        bsh = shd.batch_shardings(mesh, cfg, rules, sds)
        fn = M.prefill_fn(cfg)
        S_total = shape.seq_len + (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
        logits_sh = shd.logits_sharding(
            mesh, cfg, rules, (shape.global_batch, S_total, cfg.padded_vocab_size)
        )
        return fn, (params_shapes, sds), (psh, bsh), logits_sh
    # decode
    bsh = shd.batch_shardings(mesh, cfg, rules, sds)
    fn = M.serve_step_fn(cfg)
    out_sh = {"logits": None, "cache": bsh["cache"]}
    return fn, (params_shapes, sds), (psh, bsh), out_sh


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rules: ShardingRules | None = None,
    hlo_probe: bool = False,
) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or ShardingRules()
    t0 = time.time()
    fn, args, in_sh, out_sh = build_cell_fn(cfg, shape, mesh, rules)
    # donation: train updates (params, opt) in place; decode updates the KV
    # cache in place. Without it the cache exists twice (measured +16
    # GiB/device at deepseek-7b decode_32k).
    donate = (0, 1) if shape.kind == "train" else ((1,) if shape.kind == "decode" else ())
    with shd.activation_mesh(mesh, rules):
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": mesh.devices.size,
        "compile_s": round(t1 - t0, 1),
        "flops_per_device": float(cost.get("flops", -1)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1)),
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes": int(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
        ),
    }
    if hlo_probe:
        rec["collectives"] = parse_collectives(compiled.as_text())
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--hlo", action="store_true", help="parse collective bytes")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    todo = [
        (a, s, st)
        for (a, s, st) in cells()
        if (args.arch is None or a == args.arch)
        and (args.shape is None or s == args.shape)
    ]
    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r.get("arch"), r.get("shape"), r.get("mesh")) for r in results}

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape_name, status in todo:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            if (arch, shape_name, mesh_name) in done:
                continue
            if status != "run":
                results.append(
                    {"arch": arch, "shape": shape_name, "mesh": mesh_name, "status": status}
                )
                print(f"[skip] {arch} {shape_name} {mesh_name}: {status}")
                continue
            try:
                rec = run_cell(arch, shape_name, multi_pod=mp, hlo_probe=args.hlo)
                rec["status"] = "ok"
                print(
                    f"[ok]   {arch:22s} {shape_name:12s} {mesh_name:8s} "
                    f"compile={rec['compile_s']:6.1f}s peak={rec['peak_bytes']/2**30:7.2f}GiB "
                    f"flops/dev={rec['flops_per_device']:.3e}"
                )
            except Exception as e:  # noqa: BLE001
                rec = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": f"error: {type(e).__name__}: {e}",
                }
                print(f"[ERR]  {arch} {shape_name} {mesh_name}: {e}")
                traceback.print_exc()
            results.append(rec)
            json.dump(results, open(args.out, "w"), indent=1)
    json.dump(results, open(args.out, "w"), indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{n_ok} ok / {len(results)} records -> {args.out}")


if __name__ == "__main__":
    main()
