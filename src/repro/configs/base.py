"""Config system: model/shape/run configs for every assigned architecture.

Configs are frozen dataclasses (hashable -> usable as jit static args).
``--arch <id>`` in the launchers resolves through ``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- hybrid (zamba2): shared attention block every k ssm blocks ---
    attn_every: int = 0
    # --- sliding-window attention (mixtral) ---
    window: int = 0
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0          # fixed encoder memory length for serving
    # --- vlm (paligemma) ---
    num_prefix_tokens: int = 0    # image patch tokens (stub frontend)
    frontend: str = ""            # "audio_stub" | "vision_stub" | ""
    # --- common ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"
    # --- compilation strategy ---
    scan_layers: bool = True
    remat: bool = True
    dtype: str = "bfloat16"

    # embedding tables are padded to a TP-shardable multiple; odd vocab
    # sizes (minicpm 122,753; whisper 51,865) otherwise force replicated
    # (B, S, V) fp32 logits — measured 61.9 GiB/device at minicpm
    # prefill_32k. Padded slots are masked to -inf in the loss/decode.
    vocab_pad_multiple: int = 256

    @property
    def padded_vocab_size(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM state, hybrid, or
        sliding-window KV cap — see DESIGN.md long_500k skip rule.)"""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        mlp = 3 * D * F
        if self.family == "moe":
            mlp = 3 * D * F * self.num_experts + D * self.num_experts
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, N, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            ssm = D * (2 * di + 2 * N + nh) + di * D + di  # in/out proj + dt/B/C
        blocks = 0
        if self.family == "ssm":
            blocks = L * (ssm + 2 * D)
        elif self.family == "hybrid":
            n_attn_apps = L // max(self.attn_every, 1)
            blocks = L * (ssm + 2 * D) + (attn + mlp + 2 * D)  # one SHARED attn block
            del n_attn_apps
        elif self.family == "encdec":
            enc = self.encoder_layers * (attn + mlp + 2 * D)
            dec = L * (2 * attn + mlp + 3 * D)  # self + cross attention
            blocks = enc + dec
        else:
            blocks = L * (attn + mlp + 2 * D)
        embed = V * D * (1 if self.tie_embeddings else 2)
        return embed + blocks + D

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of experts)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        dense_like = self.param_count() - L * 3 * D * F * self.num_experts
        return dense_like + L * 3 * D * F * self.num_experts_per_tok


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""

    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis mapping (the hillclimbable knob).

    Values are mesh axis names or None. ``fsdp`` shards the non-TP param
    dim; ``tp`` shards heads / ffn / vocab; batch shards over (pod, data).
    """

    batch: tuple = ("pod", "data")
    fsdp: str | None = "data"     # param dim sharded FSDP-style
    tp: str | None = "model"      # tensor-parallel param dim
    seq: str | None = "model"     # sequence parallelism: shards the layer-scan
    #                               remat stash (B,S,D) over TP at block edges
    expert: str | None = "model"  # MoE expert dim (EP)
    cache_batch: tuple = ("pod", "data")
    cache_heads: str | None = "model"
    # pin blocked-flash-attention tensor shardings: fixes an involuntary
    # full-remat reshard in the BACKWARD pass (+23% train roofline) but
    # perturbs GSPMD's better forward-only layout — so train-only.
    blocked_attn: bool = True


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    rules: ShardingRules = ShardingRules()
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    schedule: str = "cosine"       # "cosine" | "wsd"
    grad_clip: float = 1.0
    microbatch: int | None = None  # grad-accum microbatch (None = whole batch)
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family (used by tests)."""
    small = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        scan_layers=cfg.scan_layers,
        remat=False,
    )
    if cfg.family == "moe":
        small.update(num_experts=min(cfg.num_experts, 4), num_experts_per_tok=2)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.family == "hybrid":
        small.update(attn_every=2)
    if cfg.family == "encdec":
        small.update(encoder_layers=2, encoder_seq=32)
    if cfg.family == "vlm":
        small.update(num_prefix_tokens=8)
    if cfg.window:
        small.update(window=16)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
