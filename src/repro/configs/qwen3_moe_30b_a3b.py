"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8.

head_dim is 128 (explicit in the HF config; q-proj expands 2048 -> 4096)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    num_experts=128, num_experts_per_tok=8,
    rope_theta=1000000.0,
)
