"""paligemma-3b [arXiv:2407.07726] — SigLIP stub + gemma backbone.

gemma-2b geometry: 8 heads x head_dim 256, 1 KV head, GeGLU d_ff=16384.
num_prefix_tokens=256 (224px / 14px patches); prefix-LM masking."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216, tie_embeddings=True,
    num_prefix_tokens=256, frontend="vision_stub", act="gelu",
)
