"""Config registry: --arch <id> resolves here."""
from repro.configs import (
    deepseek_7b,
    deepseek_coder_33b,
    mamba2_130m,
    minicpm_2b,
    mixtral_8x22b,
    paligemma_3b,
    qwen3_moe_30b_a3b,
    smollm_135m,
    whisper_base,
    zamba2_7b,
)
from repro.configs.base import SHAPES, ModelConfig, RunConfig, ShapeConfig, ShardingRules, reduced

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_coder_33b, smollm_135m, deepseek_7b, minicpm_2b, zamba2_7b,
        whisper_base, mixtral_8x22b, qwen3_moe_30b_a3b, paligemma_3b, mamba2_130m,
    )
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
