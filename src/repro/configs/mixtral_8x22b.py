"""mixtral-8x22b [arXiv:2401.04088] — 8 experts top-2, sliding-window attn."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768,
    num_experts=8, num_experts_per_tok=2,
    window=4096,  # SWA caps the decode KV cache -> long_500k runs
    rope_theta=1000000.0,
)
