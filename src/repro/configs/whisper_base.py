"""whisper-base [arXiv:2212.04356] — enc-dec; conv/mel frontend is a stub
(precomputed frame embeddings). 6 encoder + 6 decoder layers."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    encoder_layers=6, encoder_seq=1500, act="gelu", tie_embeddings=True,
    frontend="audio_stub",
)
