"""minicpm-2b [arXiv:2404.06395] — llama-like, trained with the WSD
(warmup-stable-decay) schedule, implemented in repro.optim.schedule."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122753, tie_embeddings=True,
)
