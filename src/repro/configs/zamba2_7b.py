"""zamba2-7b [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    attn_every=6,  # one shared attention application per 6 mamba blocks
)
