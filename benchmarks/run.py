"""Benchmark harness entrypoint (deliverable d): one function per paper
table/figure. Prints ``name,us_per_call,derived`` CSV.

The roofline analysis (deliverable g) is a separate entrypoint —
``python -m benchmarks.roofline`` — because it needs the 512-fake-device
environment, which must not leak into these CPU benchmarks.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_hierarchy,
        bench_mesh,
        bench_moe,
        bench_partitioner,
        bench_spmv,
    )

    suites = [
        ("kdtree (paper Figs 2-5)", bench_partitioner.bench_kdtree_build),
        ("sfc traversal (Figs 8-10)", bench_partitioner.bench_sfc_traversal),
        ("knapsack (SIII-C)", bench_partitioner.bench_knapsack),
        ("tree vs point partition (SIII-B)", bench_partitioner.bench_tree_vs_point_partition),
        ("dynamic trees (Table I)", bench_partitioner.bench_dynamic),
        ("queries (Figs 12-13)", bench_partitioner.bench_queries),
        ("incremental LB (SIV)", bench_partitioner.bench_migration),
        ("hierarchical reslice (nodes x devices)", bench_hierarchy.bench_hierarchy_rows),
        ("AMR mesh stencil loop (SI, SIV)", bench_mesh.bench_mesh_rows),
        ("spmv tables (Tables II-VII)", bench_spmv.bench_spmv_tables),
        ("spmv execution", bench_spmv.bench_spmv_execution),
        ("moe dispatch (DESIGN S3)", bench_moe.bench_moe_dispatch),
        ("sequence packing", bench_moe.bench_packing),
        ("amortized controller (Alg 3)", bench_moe.bench_amortized_controller),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for title, fn in suites:
        print(f"# --- {title}")
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SUITE FAILED: {title}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
