"""Benchmark harness entrypoint (deliverable d): one function per paper
table/figure. Prints ``name,us_per_call,derived`` CSV.

``--compare OLD.json [NEW.json]`` instead diffs two ``BENCH_*.json``
artifacts metric by metric (old, new, delta, percent) — the perf
trajectory check for a PR: run the smoke suite, then compare its fresh
artifact against the committed one. NEW defaults to ``BENCH_<name>.json``
in the current directory, with ``<name>`` taken from OLD's payload.

The roofline analysis (deliverable g) is a separate entrypoint —
``python -m benchmarks.roofline`` — because it needs the 512-fake-device
environment, which must not leak into these CPU benchmarks.
"""
from __future__ import annotations

import json
import os
import sys
import traceback


def compare_artifacts(old_path: str, new_path: str | None = None) -> int:
    """Print per-metric deltas between two benchmark artifacts.

    Numeric metrics get old/new/delta/percent columns; non-numeric ones
    (bools, lists) print old -> new and are flagged when they changed.
    A key present in only one artifact prints ``n/a`` for the missing
    side and no delta — suites gain and retire metrics across PRs, and
    a comparison against an older artifact must stay readable.
    Returns 1 when either artifact records a failed smoke gate, else 0 —
    regressions in individual metrics are reported, not gated, because
    what counts as "worse" is metric-specific (the suites' own gates
    hold the hard lines)."""
    with open(old_path) as f:
        old = json.load(f)
    if new_path is None:
        if "name" not in old:
            print(f"ERROR: {old_path} has no 'name'; pass NEW.json explicitly",
                  file=sys.stderr)
            return 2
        new_path = f"BENCH_{old['name']}.json"
    with open(new_path) as f:
        new = json.load(f)
    if old.get("name") != new.get("name"):
        print(
            f"WARNING: comparing different suites "
            f"({old.get('name')!r} vs {new.get('name')!r})"
        )
    om, nm = old.get("metrics", {}), new.get("metrics", {})
    keys = sorted(set(om) | set(nm))
    width = max((len(k) for k in keys), default=4)
    print(f"# {old.get('name', '?')}: {old_path} -> {new_path}")
    print(f"{'metric':<{width}}  {'old':>14}  {'new':>14}  {'delta':>14}  {'pct':>8}")
    for k in keys:
        a, b = om.get(k), nm.get(k)
        if k not in om or k not in nm:
            lhs = "n/a" if k not in om else f"{a!r}"
            rhs = "n/a" if k not in nm else f"{b!r}"
            print(f"{k:<{width}}  {lhs:>14}  {rhs:>14}  {'n/a':>14}  {'n/a':>8}")
            continue
        num = (
            isinstance(a, (int, float)) and not isinstance(a, bool)
            and isinstance(b, (int, float)) and not isinstance(b, bool)
        )
        if num:
            d = b - a
            pct = f"{100.0 * d / a:+8.1f}%" if a else "     n/a"
            print(f"{k:<{width}}  {a:>14.6g}  {b:>14.6g}  {d:>+14.6g}  {pct}")
        else:
            mark = "" if a == b else "  CHANGED"
            print(f"{k:<{width}}  {a!r:>14}  {b!r:>14}{mark}")
    po, pn = old.get("passed"), new.get("passed")
    if po is not None or pn is not None:
        print(f"passed: {po} -> {pn}")
    return 0 if pn in (True, None) and po in (True, None) else 1


def main() -> None:
    if "--compare" in sys.argv:
        i = sys.argv.index("--compare")
        paths = sys.argv[i + 1 : i + 3]
        if not paths:
            print("usage: run.py --compare OLD.json [NEW.json]", file=sys.stderr)
            sys.exit(2)
        sys.exit(compare_artifacts(paths[0], paths[1] if len(paths) > 1 else None))
    from benchmarks import (
        bench_hierarchy,
        bench_mesh,
        bench_moe,
        bench_particles,
        bench_partitioner,
        bench_plans,
        bench_spmv,
    )

    suites = [
        ("kdtree (paper Figs 2-5)", bench_partitioner.bench_kdtree_build),
        ("sfc traversal (Figs 8-10)", bench_partitioner.bench_sfc_traversal),
        ("knapsack (SIII-C)", bench_partitioner.bench_knapsack),
        ("tree vs point partition (SIII-B)", bench_partitioner.bench_tree_vs_point_partition),
        ("dynamic trees (Table I)", bench_partitioner.bench_dynamic),
        ("queries (Figs 12-13)", bench_partitioner.bench_queries),
        ("incremental LB (SIV)", bench_partitioner.bench_migration),
        ("hierarchical reslice (nodes x devices)", bench_hierarchy.bench_hierarchy_rows),
        ("AMR mesh stencil loop (SI, SIV)", bench_mesh.bench_mesh_rows),
        ("particle N-body + coupled PIC (SV-C)", bench_particles.bench_particles_rows),
        ("plan construction (vectorized vs legacy)", bench_plans.bench_plans_rows),
        ("spmv tables (Tables II-VII)", bench_spmv.bench_spmv_tables),
        ("spmv execution", bench_spmv.bench_spmv_execution),
        ("moe dispatch (DESIGN S3)", bench_moe.bench_moe_dispatch),
        ("sequence packing", bench_moe.bench_packing),
        ("amortized controller (Alg 3)", bench_moe.bench_amortized_controller),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for title, fn in suites:
        print(f"# --- {title}")
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SUITE FAILED: {title}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
