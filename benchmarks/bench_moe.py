"""Partitioner-in-the-framework benchmarks: MoE dispatch balance,
sequence packing, serving batcher (the paper's technique applied to the
LM stack; DESIGN.md §3)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.dynamic import AmortizedController
from repro.data import pipeline as dp
from repro.models import moe as Mo


def bench_moe_dispatch() -> list[tuple]:
    rows = []
    cfg = reduced(ARCHS["qwen3-moe-30b-a3b"], num_experts=16, num_experts_per_tok=4)
    key = jax.random.PRNGKey(0)
    p = Mo.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (8, 256, cfg.d_model), jnp.float32)
    fn = jax.jit(lambda pp, xx: Mo.moe_apply(pp, xx, cfg))
    y, aux = fn(p, x)
    t0 = time.perf_counter()
    for _ in range(3):
        y, aux = fn(p, x)
        y.block_until_ready()
    us = (time.perf_counter() - t0) / 3 * 1e6
    load = np.asarray(Mo.expert_load(p, x, cfg))
    rows.append(
        (
            "moe_dispatch/16e_top4/T=2048", us,
            f"aux={float(aux):.4f};load_cv={load.std()/max(load.mean(),1):.3f}",
        )
    )
    # knapsack expert re-placement plan quality
    part, plan = Mo.rebalance_expert_placement(jnp.asarray(load, jnp.float32), 4)
    shard_loads = np.bincount(np.asarray(part), weights=load, minlength=4)
    rows.append(
        (
            "moe_replacement/16e_to_4shards", 0.0,
            f"shard_imbalance={int(shard_loads.max()-shard_loads.min())};moved={plan.total_moved}",
        )
    )
    return rows


def bench_packing() -> list[tuple]:
    cfg = dp.DataConfig(vocab_size=1000, seq_len=4096, global_batch=8)
    lens = dp.sample_doc_lengths(cfg, step=0, count=4000)
    t0 = time.perf_counter()
    bins = dp.pack_documents(lens, 4096)
    us = (time.perf_counter() - t0) * 1e6
    eff = dp.packing_efficiency(lens, bins, 4096)
    base = dp.padded_baseline_efficiency(lens, 4096)
    return [
        (
            "packing/docs=4000/seq=4096", us,
            f"efficiency={eff:.3f};padded_baseline={base:.3f};gain={eff/base:.2f}x",
        )
    ]


def bench_amortized_controller() -> list[tuple]:
    """Alg 3 behaviour: rebalance count vs naive every-step rebalance."""
    rng = np.random.default_rng(0)
    drift = 0.01 + 0.001 * rng.random(500).cumsum()
    c = AmortizedController()
    c.balanced(lb_cost=5.0, num_buckets=100, timeop=drift[0])
    rebalances = 0
    for t in drift[1:]:
        if c.observe(t, 100):
            c.balanced(lb_cost=5.0, num_buckets=100, timeop=t)
            rebalances += 1
    return [
        (
            "amortized_lb/500_iters", 0.0,
            f"rebalances={rebalances};naive=500;reduction={500/max(rebalances,1):.0f}x",
        )
    ]
