"""Benchmark artifact writer: one ``BENCH_<name>.json`` per suite run.

Every ``--smoke`` benchmark writes its headline numbers here so the
nightly CI job can upload them and the perf trajectory is tracked as
data, not just as pass/fail gate output. The schema is deliberately
flat: a few identifying fields plus whatever metrics the suite measured
(all JSON scalars), so a downstream plotter can concat files across
runs without suite-specific parsing.

Destination directory: ``REPRO_BENCH_ARTIFACT_DIR`` (default: current
working directory — the repo root in CI, where the upload step globs
``BENCH_*.json``).
"""
from __future__ import annotations

import json
import os
import platform
import time


def write_artifact(
    name: str, metrics: dict, *, passed: bool | None = None, echo: bool = False
) -> str:
    """Write ``BENCH_<name>.json`` and return its path.

    ``metrics`` values must be JSON-serializable scalars (floats in
    seconds/bytes/ratios as measured); ``passed`` records the smoke
    gate's verdict when the suite has one. ``echo=True`` prints the
    whole summary as one ``BENCH_<name>.json {...}`` stdout line —
    every ``--smoke`` entrypoint emits this as its FINAL line so CI and
    the trajectory tooling can scrape the numbers from the log even
    when the artifact files are not downloaded.
    """
    payload = {
        "name": name,
        "unix_time": time.time(),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "metrics": metrics,
    }
    if passed is not None:
        payload["passed"] = bool(passed)
    out_dir = os.environ.get("REPRO_BENCH_ARTIFACT_DIR", ".")
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    if echo:
        print(f"BENCH_{name}.json {json.dumps(payload, sort_keys=True)}", flush=True)
    return path
