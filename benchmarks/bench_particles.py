"""Particle workload: distributed N-body correctness + incremental
re-slice economics, plus the coupled particle-mesh loop, on the shared
partition core.

The claims under test (paper §V-C applications):

* **correctness** — the distributed leapfrog (cutoff interaction plans
  compiled per partition event, ghost-position exchange overlapped with
  the interior pair kernel, state migrated between partitions on
  device) is BIT-EQUAL to the single-device reference after the full
  simulation, across every repartition, registration and migration
  event. Equality is exact (``np.array_equal`` on position AND
  velocity), not a tolerance. The coupled particle-mesh run holds the
  same gate on the mesh field as well — ONE partition, ONE interaction
  plan and ONE migration carrying both entity kinds.
* **economics** — answering load drift (per-particle interaction degree
  as the cost model) with the hierarchical engine's incremental
  re-slice plus moved-rows-only migration must beat a full rebuild plus
  full redistribute on measured walltime, on the same trajectory, same
  devices, warm executors.

``--smoke`` (nightly CI) runs at 8 fake host devices arranged 2 nodes x
4 devices, gates both claims plus a >= 10 combined repartition-event
floor, writes ``BENCH_particles.json`` and prints the summary as the
final stdout line. Runs each driver twice and times the second pass so
jit compiles (shared through the lru-cached executors) don't pollute
the comparison.

    PYTHONPATH=src python benchmarks/bench_particles.py [events] [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

SMOKE = "--smoke" in sys.argv
if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    # fake devices must be requested before jax initializes; under
    # run.py the flag must NOT leak into single-device suites, so rows
    # report SKIPPED there unless devices already exist
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

try:
    from benchmarks._artifact import write_artifact
except ImportError:  # run as a script: the benchmarks dir itself is on sys.path
    from _artifact import write_artifact

_argv = [a for a in sys.argv[1:] if not a.startswith("--")]
EVENTS = int(_argv[0]) if _argv else 12
NODES, DEV = 2, 4


def _configs():
    from repro.particles import pic, simulate

    nbody = simulate.ParticleSimConfig(n=512, events=EVENTS, substeps=4)
    coupled = pic.PICSimConfig(n=256, events=max(EVENTS * 2 // 3, 4),
                               substeps=2, mesh_level=3)
    return nbody, coupled


def _run(events_cfg=None):
    import jax

    from repro.core import partitioner as pt
    from repro.distributed import sharding as shd
    from repro.particles import pic, simulate

    nshards = NODES * DEV
    if len(jax.devices()) < nshards:
        return [(f"particles/SKIPPED(<{nshards} devices)", 0.0, "")], None

    cfg, ccfg = events_cfg or _configs()
    t0 = time.perf_counter()
    ref = simulate.run_reference(cfg)
    ref_s = time.perf_counter() - t0

    hplan = pt.HierarchyPlan(num_nodes=NODES, devices_per_node=DEV)
    mesh = shd.make_node_device_mesh(NODES, DEV)

    results = {}
    for driver in ("incremental", "rebuild"):
        # two passes: executors are lru-cached, the second is warm
        for _ in range(2):
            out, st = simulate.run_distributed(cfg, mesh, hplan, driver=driver)
        results[driver] = (out, st)

    # coupled particle-mesh: one partition carries cells + particles
    u_ref, ps_ref = pic.run_reference_coupled(ccfg)
    u, ps, cst = pic.run_distributed_coupled(
        ccfg, mesh, hplan, driver="incremental"
    )
    bit_pic = bool(
        np.array_equal(u_ref, u)
        and np.array_equal(ps_ref.pos, ps.pos)
        and np.array_equal(ps_ref.vel, ps.vel)
    )

    inc, reb = results["incremental"][1], results["rebuild"][1]
    bit_inc = bool(
        np.array_equal(ref.pos, results["incremental"][0].pos)
        and np.array_equal(ref.vel, results["incremental"][0].vel)
    )
    bit_reb = bool(
        np.array_equal(ref.pos, results["rebuild"][0].pos)
        and np.array_equal(ref.vel, results["rebuild"][0].vel)
    )
    t_inc = inc.engine_s + inc.move_s
    t_reb = reb.engine_s + reb.move_s
    repart_events = inc.repartition_events + cst.repartition_events

    rows = [
        (
            f"particles/reference/n={cfg.n}", ref_s * 1e6,
            f"events={cfg.events};substeps={cfg.substeps};k_max={inc.k_max}",
        ),
        (
            "particles/incremental_reslice+migrate", t_inc * 1e6,
            f"bit_equal={bit_inc};repart_events={inc.repartition_events};"
            f"registrations={inc.registration_events};"
            f"crossers={inc.crossers_total};"
            f"node_local_moves={inc.node_local_moves}",
        ),
        (
            "particles/rebuild+redistribute", t_reb * 1e6,
            f"bit_equal={bit_reb};rebuilds={reb.rebuilds};"
            f"speedup={t_reb / max(t_inc, 1e-9):.1f}x",
        ),
        (
            "particles/coupled_pic",
            (cst.engine_s + cst.move_s + cst.force_s) * 1e6,
            f"bit_equal={bit_pic};cells={cst.n_cells};n={ccfg.n};"
            f"repart_events={cst.repartition_events};"
            f"registrations={cst.registration_events}",
        ),
    ]
    hm = inc.halo_metrics
    stats = {
        "n": cfg.n,
        "events": cfg.events,
        "substeps": cfg.substeps,
        "radius": cfg.radius,
        "nodes": NODES,
        "devices_per_node": DEV,
        "bit_equal_incremental": bit_inc,
        "bit_equal_rebuild": bit_reb,
        "bit_equal_coupled": bit_pic,
        "repartition_events": inc.repartition_events,
        "coupled_repartition_events": cst.repartition_events,
        "repartition_events_total": repart_events,
        "registration_events": inc.registration_events,
        "crossers_total": inc.crossers_total,
        "intra_reslices": inc.intra_reslices,
        "inter_reslices": inc.inter_reslices,
        "incremental_rebuilds": inc.rebuilds,
        "node_local_moves": inc.node_local_moves,
        "moved_total_incremental": inc.moved_total,
        "moved_inter_node_incremental": inc.moved_inter_node,
        "moved_total_rebuild": reb.moved_total,
        "k_max": inc.k_max,
        "incremental_engine_s": inc.engine_s,
        "incremental_move_s": inc.move_s,
        "incremental_force_s": inc.force_s,
        "incremental_neighbor_s": inc.neighbor_s,
        "incremental_plan_build_s": inc.plan_build_s,
        "rebuild_plan_build_s": reb.plan_build_s,
        "incremental_plan_cache_hits": inc.plan_cache_hits,
        "incremental_plan_cache_misses": inc.plan_cache_misses,
        "rebuild_engine_s": reb.engine_s,
        "rebuild_move_s": reb.move_s,
        "rebuild_force_s": reb.force_s,
        "incremental_total_s": t_inc,
        "rebuild_total_s": t_reb,
        "speedup": t_reb / max(t_inc, 1e-9),
        "reference_s": ref_s,
        "coupled_n_cells": cst.n_cells,
        "coupled_registration_events": cst.registration_events,
        "coupled_crossers_total": cst.crossers_total,
        "coupled_engine_s": cst.engine_s,
        "coupled_move_s": cst.move_s,
        "coupled_force_s": cst.force_s,
        "max_surface_index": hm.get("MaxSurfaceIndex"),
        "max_edge_cut": hm.get("MaxEdgeCut"),
        "max_degree": hm.get("MaxDegree"),
        "inter_node_ghosts": hm.get("InterNodeGhosts"),
        "intra_node_ghosts": hm.get("IntraNodeGhosts"),
        "interior_cells": hm.get("InteriorCells"),
        "boundary_cells": hm.get("BoundaryCells"),
    }
    return rows, stats


def bench_particles_rows() -> list[tuple]:
    """CSV rows (name, us_per_call, derived); SKIPPED row on < 8 devices."""
    rows, _ = _run()
    return rows


def smoke_main() -> int:
    rows, stats = _run()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if stats is None:
        print("WARNING: particles gate skipped (< 8 devices)")
        return 0
    ok_bits = (
        stats["bit_equal_incremental"]
        and stats["bit_equal_rebuild"]
        and stats["bit_equal_coupled"]
    )
    ok_events = stats["repartition_events_total"] >= 10
    ok_speed = stats["speedup"] > 1.0
    passed = ok_bits and ok_events and ok_speed
    if not passed:
        print(
            f"FAIL: bit_equal={ok_bits} "
            f"(inc={stats['bit_equal_incremental']}, "
            f"reb={stats['bit_equal_rebuild']}, "
            f"pic={stats['bit_equal_coupled']}), "
            f"repartition_events_total={stats['repartition_events_total']} "
            f"(need >=10), "
            f"incremental {stats['incremental_total_s']*1e3:.1f} ms vs "
            f"rebuild {stats['rebuild_total_s']*1e3:.1f} ms "
            f"(speedup={stats['speedup']:.2f}x, need >1.0)"
        )
    else:
        print(
            f"PASS: distributed leapfrog bit-equal to reference across "
            f"{stats['repartition_events_total']} repartition events "
            f"({stats['coupled_repartition_events']} in the coupled "
            f"particle-mesh run, {stats['registration_events']} "
            f"registration events, {stats['crossers_total']} crossers); "
            f"incremental re-slice + migration {stats['speedup']:.1f}x "
            f"faster than rebuild+redistribute "
            f"({stats['incremental_total_s']*1e3:.1f} ms vs "
            f"{stats['rebuild_total_s']*1e3:.1f} ms)"
        )
    write_artifact("particles", stats, passed=passed, echo=True)
    return 0 if passed else 1


if __name__ == "__main__":
    if SMOKE:
        sys.exit(smoke_main())
    print("name,us_per_call,derived")
    for name, us, derived in bench_particles_rows():
        print(f"{name},{us:.1f},{derived}")
