"""Benchmarks for the core partitioner, one per paper figure/table.

Each function returns a list of CSV rows ``(name, us_per_call, derived)``.
CPU wall-times are indicative (the container is 1-core); the *derived*
column carries the paper-comparable quality metrics, which are
machine-independent.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic, kdtree, knapsack, metrics, migration, partitioner, queries, sfc, spmv


def _timeit(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out
        )
    return (time.perf_counter() - t0) / reps * 1e6, out


# Fig 2-5: static kd-tree construction across splitters and distributions
def bench_kdtree_build() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    for n in (50_000, 200_000):
        pts_u = jnp.asarray(rng.random((n, 3)), jnp.float32)
        clu = np.concatenate(
            [rng.normal(0.05, 0.005, (n // 2, 3)), rng.random((n - n // 2, 3))]
        ).astype(np.float32)
        pts_c = jnp.asarray(clu)
        for dist, pts in (("uniform", pts_u), ("cluster", pts_c)):
            for splitter in ("midpoint", "median", "median_selection"):
                us, tree = _timeit(
                    kdtree.build, pts, None,
                    max_depth=12, bucket_size=32, splitter=splitter, reps=1,
                )
                depth = float(jnp.mean(tree.leaf_depth()))
                rows.append(
                    (f"kdtree_build/{dist}/{splitter}/n={n}", us, f"mean_leaf_depth={depth:.2f}")
                )
    return rows


# Fig 8-10: SFC traversal throughput (keys + sort), Morton vs Hilbert-like
def bench_sfc_traversal() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(1)
    for n in (500_000, 2_000_000):
        pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
        for curve in ("morton", "hilbert"):
            us, (perm, keys) = _timeit(
                jax.jit(
                    lambda p, c=curve: sfc.sfc_order(p, curve=c),
                ), pts,
            )
            loc = float(sfc.locality_score(pts, perm))
            rows.append((f"sfc_traverse/{curve}/n={n}", us, f"locality={loc:.5f}"))
    # Pallas kernel path vs jnp reference (key generation only)
    from repro.kernels import ops as kops

    pts = jnp.asarray(rng.random((1_000_000, 3)), jnp.float32)
    us_j, _ = _timeit(jax.jit(lambda p: sfc.morton_key(p, 10)), pts)
    us_p, _ = _timeit(lambda p: kops.morton_key(p, 10), pts)
    rows.append(("sfc_keys/morton/jnp/n=1e6", us_j, ""))
    rows.append(("sfc_keys/morton/pallas_interpret/n=1e6", us_p, "validated-vs-ref"))
    return rows


# §III-C: knapsack slicing quality + imbalance bound
def bench_knapsack() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(2)
    for n, p in ((500_000, 256), (500_000, 4096)):
        w = jnp.asarray((rng.random(n) + 0.1).astype(np.float32))
        us, part = _timeit(lambda w_: knapsack.slice_weighted_curve(w_, p), w)
        loads = np.asarray(knapsack.part_loads(w, part, p))
        rows.append(
            (
                f"knapsack/n={n}/P={p}", us,
                f"imbalance={loads.max()-loads.min():.3f};maxw={float(w.max()):.3f}",
            )
        )
    return rows


# Table I analogue: dynamic tree build / insert / delete / adjust
def bench_dynamic() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(3)
    for n, d in ((50_000, 3), (50_000, 10)):
        pts = jnp.asarray(rng.random((n, d)), jnp.float32)
        t0 = time.perf_counter()
        dps = dynamic.from_points(pts, max_depth=14, bucket_size=32)
        jax.block_until_ready(dps.tree.count)
        t_build = (time.perf_counter() - t0) * 1e6
        new = jnp.asarray(rng.random((n // 10, d)), jnp.float32)
        t0 = time.perf_counter()
        dps = dynamic.insert(dps, new, jnp.ones(n // 10, jnp.float32))
        jax.block_until_ready(dps.tree.count)
        t_ins = (time.perf_counter() - t0) * 1e6
        kill = jnp.asarray(rng.choice(n, n // 10, replace=False))
        t0 = time.perf_counter()
        dps = dynamic.delete(dps, kill)
        jax.block_until_ready(dps.tree.count)
        t_del = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        dps = dynamic.adjustments(dps, max_sweeps=2)
        jax.block_until_ready(dps.tree.count)
        t_adj = (time.perf_counter() - t0) * 1e6
        nb = int(dynamic.num_buckets(dps))
        rows.append(
            (
                f"dynamic/n={n}/d={d}", t_build + t_ins + t_del + t_adj,
                f"build={t_build:.0f};ins={t_ins:.0f};del={t_del:.0f};adj={t_adj:.0f};buckets={nb}",
            )
        )
    return rows


# Fig 12: exact point location; Fig 13: approximate k-NN
def bench_queries() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(4)
    for n in (500_000, 1_000_000):
        pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
        idx = queries.build_index(pts, bucket_size=32)
        q = pts[jnp.asarray(rng.choice(n, 50_000, replace=False))]
        us, (found, _, _) = _timeit(lambda qq: queries.point_location(idx, qq), q)
        rows.append(
            (f"point_location/n={n}/q=1e5", us, f"found={float(found.mean()):.4f}")
        )
    pts = jnp.asarray(rng.random((500_000, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=32)
    qq = jnp.asarray(rng.random((10_000, 3)), jnp.float32)
    us, (dist, ids) = _timeit(lambda q: queries.knn(idx, q, k=3, cutoff_buckets=1), qq)
    d_b, id_b = queries.knn_bruteforce(pts[:200_000], qq[:512], k=3)
    rows.append((f"knn/k=3/n=1e6/q=1e4", us, f"mean_d={float(dist.mean()):.4f}"))
    return rows


# §IV incremental LB: migration locality + bounded rounds
def bench_migration() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(5)
    n, P = 500_000, 256
    w0 = np.ones(n, np.float32)
    old = np.asarray(knapsack.slice_weighted_curve(jnp.asarray(w0), P))
    w1 = w0.copy()
    w1[rng.choice(n, 25_000, replace=False)] *= 2.0
    t0 = time.perf_counter()
    new, _ = knapsack.incremental_reslice(jnp.asarray(w1), jnp.asarray(old), P)
    jax.block_until_ready(new)
    us = (time.perf_counter() - t0) * 1e6
    plan = migration.migration_plan(old, np.asarray(new), P, max_msg_bytes=1 << 20)
    rows.append(
        (
            "incremental_lb/n=1e6/P=256", us,
            f"moved={plan.total_moved};neighbor_frac={migration.neighbor_locality(plan):.3f};rounds={plan.rounds}",
        )
    )
    return rows
