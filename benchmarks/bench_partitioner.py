"""Benchmarks for the core partitioner, one per paper figure/table.

Each function returns a list of CSV rows ``(name, us_per_call, derived)``.
CPU wall-times are indicative (the container is 1-core); the *derived*
column carries the paper-comparable quality metrics, which are
machine-independent.

``--smoke`` (nightly CI) spins up 8 fake host devices and gates the
bucket-statistics economics: the distributed bucket-summary recompute
hot loop must beat the sample-sort recompute (exit non-zero otherwise).

    PYTHONPATH=src python benchmarks/bench_partitioner.py --smoke
"""
from __future__ import annotations

import os
import sys
import time

SMOKE = "--smoke" in sys.argv
if SMOKE and "XLA_FLAGS" not in os.environ:
    # the smoke gate compares distributed paths; fake devices must be
    # requested before jax initializes
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic, kdtree, knapsack, metrics, migration, partitioner, queries, sfc, spmv


def _timeit(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out
        )
    return (time.perf_counter() - t0) / reps * 1e6, out


# Fig 2-5: static kd-tree construction across splitters and distributions
def bench_kdtree_build() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(0)
    for n in (50_000, 200_000):
        pts_u = jnp.asarray(rng.random((n, 3)), jnp.float32)
        clu = np.concatenate(
            [rng.normal(0.05, 0.005, (n // 2, 3)), rng.random((n - n // 2, 3))]
        ).astype(np.float32)
        pts_c = jnp.asarray(clu)
        for dist, pts in (("uniform", pts_u), ("cluster", pts_c)):
            for splitter in ("midpoint", "median", "median_selection"):
                us, tree = _timeit(
                    kdtree.build, pts, None,
                    max_depth=12, bucket_size=32, splitter=splitter, reps=1,
                )
                depth = float(jnp.mean(tree.leaf_depth()))
                rows.append(
                    (f"kdtree_build/{dist}/{splitter}/n={n}", us, f"mean_leaf_depth={depth:.2f}")
                )
    return rows


# Fig 8-10: SFC traversal throughput (keys + sort), Morton vs Hilbert-like
def bench_sfc_traversal() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(1)
    for n in (500_000, 2_000_000):
        pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
        for curve in ("morton", "hilbert"):
            us, (perm, keys) = _timeit(
                jax.jit(
                    lambda p, c=curve: sfc.sfc_order(p, curve=c),
                ), pts,
            )
            loc = float(sfc.locality_score(pts, perm))
            rows.append((f"sfc_traverse/{curve}/n={n}", us, f"locality={loc:.5f}"))
    # Pallas kernel path vs jnp reference (key generation only)
    from repro.kernels import ops as kops

    pts = jnp.asarray(rng.random((1_000_000, 3)), jnp.float32)
    us_j, _ = _timeit(jax.jit(lambda p: sfc.morton_key(p, 10)), pts)
    us_p, _ = _timeit(lambda p: kops.morton_key(p, 10), pts)
    rows.append(("sfc_keys/morton/jnp/n=1e6", us_j, ""))
    rows.append(("sfc_keys/morton/pallas_interpret/n=1e6", us_p, "validated-vs-ref"))
    return rows


# §III-C: knapsack slicing quality + imbalance bound
def bench_knapsack() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(2)
    for n, p in ((500_000, 256), (500_000, 4096)):
        w = jnp.asarray((rng.random(n) + 0.1).astype(np.float32))
        us, part = _timeit(lambda w_: knapsack.slice_weighted_curve(w_, p), w)
        loads = np.asarray(knapsack.part_loads(w, part, p))
        rows.append(
            (
                f"knapsack/n={n}/P={p}", us,
                f"imbalance={loads.max()-loads.min():.3f};maxw={float(w.max()):.3f}",
            )
        )
    return rows


# Table I analogue: dynamic tree build / insert / delete / adjust
def bench_dynamic() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(3)
    for n, d in ((50_000, 3), (50_000, 10)):
        pts = jnp.asarray(rng.random((n, d)), jnp.float32)
        t0 = time.perf_counter()
        dps = dynamic.from_points(pts, max_depth=14, bucket_size=32)
        jax.block_until_ready(dps.tree.count)
        t_build = (time.perf_counter() - t0) * 1e6
        new = jnp.asarray(rng.random((n // 10, d)), jnp.float32)
        t0 = time.perf_counter()
        dps = dynamic.insert(dps, new, jnp.ones(n // 10, jnp.float32))
        jax.block_until_ready(dps.tree.count)
        t_ins = (time.perf_counter() - t0) * 1e6
        kill = jnp.asarray(rng.choice(n, n // 10, replace=False))
        t0 = time.perf_counter()
        dps = dynamic.delete(dps, kill)
        jax.block_until_ready(dps.tree.count)
        t_del = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        dps = dynamic.adjustments(dps, max_sweeps=2)
        jax.block_until_ready(dps.tree.count)
        t_adj = (time.perf_counter() - t0) * 1e6
        nb = int(dynamic.num_buckets(dps))
        rows.append(
            (
                f"dynamic/n={n}/d={d}", t_build + t_ins + t_del + t_adj,
                f"build={t_build:.0f};ins={t_ins:.0f};del={t_del:.0f};adj={t_adj:.0f};buckets={nb}",
            )
        )
    return rows


# Fig 12: exact point location; Fig 13: approximate k-NN
def bench_queries() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(4)
    for n in (500_000, 1_000_000):
        pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
        idx = queries.build_index(pts, bucket_size=32)
        q = pts[jnp.asarray(rng.choice(n, 50_000, replace=False))]
        us, (found, _, _) = _timeit(lambda qq: queries.point_location(idx, qq), q)
        rows.append(
            (f"point_location/n={n}/q=1e5", us, f"found={float(found.mean()):.4f}")
        )
    pts = jnp.asarray(rng.random((500_000, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=32)
    qq = jnp.asarray(rng.random((10_000, 3)), jnp.float32)
    us, (dist, ids) = _timeit(lambda q: queries.knn(idx, q, k=3, cutoff_buckets=1), qq)
    d_b, id_b = queries.knn_bruteforce(pts[:200_000], qq[:512], k=3)
    rows.append((f"knn/k=3/n=1e6/q=1e4", us, f"mean_d={float(dist.mean()):.4f}"))
    return rows


# Bucket-statistics pipeline: tree path vs point path on one host
def bench_tree_vs_point_partition(n: int = 50_000) -> list[tuple]:
    rows = []
    rng = np.random.default_rng(6)
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    w = jnp.asarray((0.5 + rng.random(n)).astype(np.float32))
    for use_tree in (False, True):
        cfg = partitioner.PartitionerConfig(use_tree=use_tree, max_depth=10)
        us, res = _timeit(partitioner.partition, pts, w, 64, cfg)
        loads = np.asarray(res.loads)
        gran = (
            float(np.asarray(res.summary.weight).max())
            if use_tree
            else float(np.asarray(w).max())
        )
        rows.append(
            (
                f"partition/{'tree' if use_tree else 'point'}/n={n}/P=64", us,
                f"spread={loads.max()-loads.min():.3f};granularity={gran:.3f}",
            )
        )
    return rows


# The headline economics: distributed partition-recompute hot loop,
# bucket-summary exchange vs sample-sort. Needs >= 8 devices.
def bench_bucket_vs_sample_recompute(
    n: int = 16_384, steps: int = 4, num_parts: int = 16
) -> list[tuple]:
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.repartition import DistributedBucketRepartitioner
    from repro.launch.mesh import make_mesh

    nshards = 8
    if len(jax.devices()) < nshards:
        return [("bucket_vs_sample/SKIPPED(<8 devices)", 0.0, "")]
    mesh = make_mesh((nshards,), ("data",))
    sh = NamedSharding(mesh, P("data"))
    rng = np.random.default_rng(7)
    n = (n // nshards) * nshards
    pts_h = rng.random((n, 3)).astype(np.float32)
    base = (0.5 + rng.random(n)).astype(np.float32)
    pts = jax.device_put(jnp.asarray(pts_h), sh)
    traces = []
    for t in range(steps):
        c = np.array([0.2 + 0.1 * t, 0.5, 0.5], np.float32)
        hot = np.exp(-np.sum((pts_h - c) ** 2, axis=1) / 0.02)
        traces.append(jax.device_put(jnp.asarray(base * (1 + 4 * hot)), sh))

    cfg_pt = partitioner.PartitionerConfig(curve="hilbert")
    cfg_tr = partitioner.PartitionerConfig(
        use_tree=True, curve="hilbert", max_depth=8, bucket_size=32
    )

    # sample-sort recompute: full distributed_partition every step
    def sample_step(w):
        return partitioner.distributed_partition(
            mesh, "data", pts, w, num_parts, cfg=cfg_pt
        )[2]

    jax.block_until_ready(sample_step(traces[0]))  # compile
    t0 = time.perf_counter()
    for w in traces:
        jax.block_until_ready(sample_step(w))
    sample_ms = (time.perf_counter() - t0) / steps * 1e3

    # bucket-summary recompute: cached trees, O(B) exchange per step
    eng = DistributedBucketRepartitioner(mesh, "data", num_parts, cfg_tr)
    jax.block_until_ready(eng.partition(pts, traces[0]))   # cold + compile
    jax.block_until_ready(eng.rebalance(traces[0]))        # compile hot path
    t0 = time.perf_counter()
    for w in traces:
        part = jax.block_until_ready(eng.rebalance(w))
    bucket_ms = (time.perf_counter() - t0) / steps * 1e3

    loads = np.zeros(num_parts)
    np.add.at(loads, np.asarray(part), np.asarray(traces[-1]))
    speedup = sample_ms / max(bucket_ms, 1e-9)
    return [
        (f"recompute/sample_sort/n={n}", sample_ms * 1e3, ""),
        (
            f"recompute/bucket_summary/n={n}", bucket_ms * 1e3,
            f"speedup={speedup:.1f}x;imbalance={loads.max()/loads.mean():.4f}",
        ),
    ]


def smoke_main() -> int:
    """CI smoke gate: bucket-summary recompute must beat sample-sort.

    Wall-clock gates are noisy on shared runners: the comparison runs at
    n=32k where the asymptotic gap dominates dispatch noise (at 8k the
    margin is genuinely unstable on a contended 2-core box), and
    re-measures up to 3 times, failing only if the bucket path never
    wins (executors are lru_cached, so retries pay no recompile)."""
    try:
        from benchmarks._artifact import write_artifact
    except ImportError:
        from _artifact import write_artifact

    rows = bench_tree_vs_point_partition(n=8_000)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    def _artifact(by_name, passed):
        # the BENCH_<name>.json summary is the FINAL stdout line (CI
        # scrapes it): callers invoke this after their PASS/FAIL print
        write_artifact(
            "partitioner",
            {
                "n": 32_768,
                "sample_sort_us": by_name.get("sample_sort"),
                "bucket_summary_us": by_name.get("bucket_summary"),
                "speedup": by_name["sample_sort"] / by_name["bucket_summary"]
                if "bucket_summary" in by_name else None,
            },
            passed=passed,
            echo=True,
        )

    for attempt in range(3):
        rows = bench_bucket_vs_sample_recompute(n=32_768, steps=3)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        by_name = {
            name.split("/")[1]: us for name, us, _ in rows if "recompute/" in name
        }
        if "bucket_summary" not in by_name:
            print("WARNING: distributed gate skipped (< 8 devices)")
            return 0
        if by_name["bucket_summary"] < by_name["sample_sort"]:
            print(
                f"PASS: bucket-summary recompute beats sample-sort "
                f"({by_name['sample_sort'] / by_name['bucket_summary']:.1f}x, "
                f"attempt {attempt + 1})"
            )
            _artifact(by_name, True)
            return 0
        print(f"# attempt {attempt + 1}: bucket path not faster, retrying")
    print(
        "FAIL: bucket-summary recompute "
        f"({by_name['bucket_summary']:.0f}us) not faster than "
        f"sample-sort ({by_name['sample_sort']:.0f}us) in 3 attempts"
    )
    _artifact(by_name, False)
    return 1


# §IV incremental LB: migration locality + bounded rounds
def bench_migration() -> list[tuple]:
    rows = []
    rng = np.random.default_rng(5)
    n, P = 500_000, 256
    w0 = np.ones(n, np.float32)
    old = np.asarray(knapsack.slice_weighted_curve(jnp.asarray(w0), P))
    w1 = w0.copy()
    w1[rng.choice(n, 25_000, replace=False)] *= 2.0
    t0 = time.perf_counter()
    new, _ = knapsack.incremental_reslice(jnp.asarray(w1), jnp.asarray(old), P)
    jax.block_until_ready(new)
    us = (time.perf_counter() - t0) * 1e6
    plan = migration.migration_plan(old, np.asarray(new), P, max_msg_bytes=1 << 20)
    rows.append(
        (
            "incremental_lb/n=1e6/P=256", us,
            f"moved={plan.total_moved};neighbor_frac={migration.neighbor_locality(plan):.3f};rounds={plan.rounds}",
        )
    )
    return rows


if __name__ == "__main__":
    if SMOKE:
        sys.exit(smoke_main())
    print("name,us_per_call,derived")
    for fn in (
        bench_kdtree_build,
        bench_sfc_traversal,
        bench_knapsack,
        bench_tree_vs_point_partition,
        bench_dynamic,
        bench_queries,
        bench_migration,
        bench_bucket_vs_sample_recompute,
    ):
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")
