"""Roofline analysis (harness deliverable g).

Derives the three roofline terms per (arch x shape) on the single-pod
mesh from compiled dry-run artifacts:

    compute term    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = collective_bytes / (chips x 50e9 B/s ICI link)

**Loop-count correction.** XLA's HloCostAnalysis counts a while-loop body
ONCE (verified empirically), so a scanned-layers model under-reports by
~L x. We therefore *probe*: compile shallow UNROLLED variants of each
arch (1 and 3 layers; 3 probes for hybrid/enc-dec which have two depth
parameters) at the full input shape, fit flops/bytes/collectives as an
affine function of depth, and extrapolate to the real depth. The probes
use the exact same sharding rules and input specs as the real cell.

MODEL_FLOPS uses the 6*N*D convention (2*N*D for inference kinds), N =
active params; the ratio MODEL_FLOPS / HLO_FLOPs shows how much compiled
compute is "useful" (attention quadratic terms, remat recompute and
head-padding all push it below 1).

Run:  PYTHONPATH=src python -m benchmarks.roofline --out roofline.json
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time

import jax

from repro.configs import ARCHS, SHAPES
from repro.configs.base import ModelConfig, ShardingRules
from repro.distributed import sharding as shd
from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh

PEAK_FLOPS = 197e12       # bf16 / chip (v5e)
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / ICI link
CHIPS = 256               # single pod 16x16


def _compile_probe(cfg: ModelConfig, shape_name: str, rules: ShardingRules):
    """Compile one unrolled shallow config; return per-device measures."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    # microbatch=None: grad-accumulation is a scan whose body the cost
    # analysis counts once — probes must compute the whole batch inline
    fn, args, in_sh, out_sh = dryrun.build_cell_fn(
        cfg, shape, mesh, rules, microbatch=None
    )
    donate = (0, 1) if shape.kind == "train" else ((1,) if shape.kind == "decode" else ())
    with shd.activation_mesh(mesh, rules):
        compiled = (
            jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
            .lower(*args)
            .compile()
        )
    cost = compiled.cost_analysis()
    coll = dryrun.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total_bytes"]),
    }


def _probe_configs(cfg: ModelConfig) -> tuple[list[ModelConfig], callable]:
    """Returns (probe configs, combine(measures) -> totals at real depth)."""
    base = dict(scan_layers=False)
    if cfg.family == "hybrid":
        # f(L, sites) = c + a*L + b*sites ; probes (2,1),(4,2),(4,1)
        p1 = dataclasses.replace(cfg, num_layers=2, attn_every=2, **base)
        p2 = dataclasses.replace(cfg, num_layers=4, attn_every=2, **base)
        p3 = dataclasses.replace(cfg, num_layers=4, attn_every=4, **base)
        L = cfg.num_layers
        S = max(1, cfg.num_layers // cfg.attn_every)

        def combine(ms):
            out = {}
            for k in ("flops", "bytes", "coll"):
                f1, f2, f3 = ms[0][k], ms[1][k], ms[2][k]
                a = (f3 - f1) / 2.0        # per mamba layer
                b = f2 - f3                # per attention site
                c = f1 - 2 * a - b
                out[k] = c + a * L + b * S
            return out

        return [p1, p2, p3], combine
    if cfg.family == "encdec":
        p1 = dataclasses.replace(cfg, encoder_layers=1, num_layers=1, **base)
        p2 = dataclasses.replace(cfg, encoder_layers=3, num_layers=1, **base)
        p3 = dataclasses.replace(cfg, encoder_layers=1, num_layers=3, **base)
        E, D = cfg.encoder_layers, cfg.num_layers

        def combine(ms):
            out = {}
            for k in ("flops", "bytes", "coll"):
                f1, f2, f3 = ms[0][k], ms[1][k], ms[2][k]
                ae = (f2 - f1) / 2.0
                ad = (f3 - f1) / 2.0
                c = f1 - ae - ad
                out[k] = c + ae * E + ad * D
            return out

        return [p1, p2, p3], combine
    # single depth parameter
    p1 = dataclasses.replace(cfg, num_layers=1, **base)
    p2 = dataclasses.replace(cfg, num_layers=3, **base)
    L = cfg.num_layers

    def combine(ms):
        out = {}
        for k in ("flops", "bytes", "coll"):
            f1, f2 = ms[0][k], ms[1][k]
            a = (f2 - f1) / 2.0
            c = f1 - a
            out[k] = c + a * L
        return out

    return [p1, p2], combine


def model_flops_per_device(cfg: ModelConfig, shape_name: str) -> float:
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / CHIPS


def analyze_cell(arch: str, shape_name: str, rules: ShardingRules | None = None) -> dict:
    cfg = ARCHS[arch]
    rules = rules or ShardingRules()
    probes, combine = _probe_configs(cfg)
    t0 = time.time()
    measures = [_compile_probe(p, shape_name, rules) for p in probes]
    totals = combine(measures)
    mf = model_flops_per_device(cfg, shape_name)
    t_comp = totals["flops"] / PEAK_FLOPS
    t_mem = totals["bytes"] / HBM_BW
    t_coll = totals["coll"] / LINK_BW
    dominant = max(
        ("compute", t_comp), ("memory", t_mem), ("collective", t_coll), key=lambda kv: kv[1]
    )[0]
    bound = max(t_comp, t_mem, t_coll)
    return {
        "arch": arch,
        "shape": shape_name,
        "flops_per_device": totals["flops"],
        "bytes_per_device": totals["bytes"],
        "collective_bytes_per_device": totals["coll"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / totals["flops"] if totals["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "probe_time_s": round(time.time() - t0, 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="roofline.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()
    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"]) for r in results}
    for arch, shape_name, status in dryrun.cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape_name != args.shape:
            continue
        if status != "run" or (arch, shape_name) in done:
            continue
        try:
            rec = analyze_cell(arch, shape_name)
            print(
                f"{arch:22s} {shape_name:12s} dom={rec['dominant']:10s} "
                f"tc={rec['t_compute_s']:.3e} tm={rec['t_memory_s']:.3e} "
                f"tx={rec['t_collective_s']:.3e} useful={rec['useful_flops_ratio']:.2f} "
                f"roofline={rec['roofline_fraction']:.2f}"
            )
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape_name, "error": str(e)}
            print(f"[ERR] {arch} {shape_name}: {e}")
        results.append(rec)
        json.dump(results, open(args.out, "w"), indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
