"""Hierarchical (node -> device) vs flat distributed reslice.

The claim under test (paper's hybrid model; ROADMAP's multi-host north
star): on a 2-D mesh the partition-recompute hot loop should exchange
node-aggregated summaries across nodes — O(B * nodes) inter-node bytes —
instead of the flat path's raw all_gather over every device —
O(B * devices). This script drives both engines over the same skewed
drift workload (a hot region walking through one node's half of the
curve, the regime where the two-level trigger economics matter) on 8
fake host devices arranged as 2 nodes x 4 devices, and measures the
inter-node bytes of each reslice from the COMPILED programs: every
collective's replica groups are classified by node
(`launch.dryrun.parse_inter_node_bytes`), so the gate fails if the
two-stage aggregation ever regresses — the closed-form model
(`distributed.sharding.summary_exchange_bytes`) is reported alongside
for drift visibility, but it is not the gate.

``--smoke`` (nightly CI) gates: the two-level reslice must move
*strictly fewer* inter-node summary bytes than the flat reslice, both
assignments must conserve the weight mass, and both must stay balanced
at their granularity. Exit non-zero otherwise. Also writes the
``BENCH_hierarchy.json`` artifact.

    PYTHONPATH=src python benchmarks/bench_hierarchy.py [n] [steps] [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

SMOKE = "--smoke" in sys.argv
if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    # the whole comparison is distributed; fake devices must be requested
    # before jax initializes. Script runs only — when run.py imports this
    # module the flag must NOT leak into the other (single-device) suites,
    # so under run.py the rows report SKIPPED unless devices already exist
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

try:
    from benchmarks._artifact import write_artifact
except ImportError:  # run as a script: the benchmarks dir itself is on sys.path
    from _artifact import write_artifact

_argv = [a for a in sys.argv[1:] if not a.startswith("--")]
N = int(_argv[0]) if len(_argv) > 0 else (16_384 if SMOKE else 65_536)
STEPS = int(_argv[1]) if len(_argv) > 1 else 4
NODES, DEV = 2, 4


def _drift_traces(rng, pts_h, base, steps):
    """Skewed drift: a hot gaussian walking through x in [0.1, 0.4] —
    mass concentrates inside one node's half of the curve, so the flat
    path keeps paying full-mesh exchanges for what is mostly a
    node-local rebalance."""
    out = []
    for t in range(steps):
        c = np.array([0.1 + 0.1 * t, 0.5, 0.5], np.float32)
        hot = np.exp(-np.sum((pts_h - c) ** 2, axis=1) / 0.01)
        out.append((base * (1.0 + 6.0 * hot)).astype(np.float32))
    return out


def bench_hierarchy_rows(n: int = N, steps: int = STEPS) -> list[tuple]:
    """CSV rows (name, us_per_call, derived); SKIPPED row on < 8 devices."""
    rows, _ = _run(n, steps)
    return rows


def _run(n: int, steps: int):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import partitioner as pt
    from repro.core.repartition import DistributedBucketRepartitioner
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_mesh

    nshards = NODES * DEV
    if len(jax.devices()) < nshards:
        return [(f"hierarchy/SKIPPED(<{nshards} devices)", 0.0, "")], None

    rng = np.random.default_rng(11)
    n = (n // nshards) * nshards
    pts_h = rng.random((n, 3)).astype(np.float32)
    base = (0.5 + rng.random(n)).astype(np.float32)
    traces = _drift_traces(rng, pts_h, base, steps)

    cfg = pt.PartitionerConfig(use_tree=True, curve="hilbert", max_depth=8, bucket_size=32)
    plan = pt.HierarchyPlan(num_nodes=NODES, devices_per_node=DEV)

    mesh_f = make_mesh((nshards,), ("data",))
    mesh_h = shd.make_node_device_mesh(NODES, DEV)
    sh_f = NamedSharding(mesh_f, P("data"))
    sh_h = NamedSharding(mesh_h, P(("node", "device")))

    def run_engine(eng, sh):
        pts = jax.device_put(jnp.asarray(pts_h), sh)
        wts = [jax.device_put(jnp.asarray(w), sh) for w in traces]
        jax.block_until_ready(eng.partition(pts, wts[0]))  # cold + compile
        jax.block_until_ready(eng.rebalance(wts[0]))       # compile hot path
        t0 = time.perf_counter()
        for w in wts:
            part = jax.block_until_ready(eng.rebalance(w))
        ms = (time.perf_counter() - t0) / steps * 1e3
        loads = np.zeros(nshards)
        np.add.at(loads, np.asarray(part), traces[-1])
        return ms, loads, np.asarray(part), wts[-1]

    eng_f = DistributedBucketRepartitioner(mesh_f, "data", nshards, cfg)
    eng_h = DistributedBucketRepartitioner(mesh_h, cfg=cfg, plan=plan)
    flat_ms, flat_loads, flat_part, wlast_f = run_engine(eng_f, sh_f)
    hier_ms, hier_loads, hier_part, wlast_h = run_engine(eng_h, sh_h)

    # MEASURED inter-node bytes: parse the collectives of the exact
    # compiled reslice programs and classify each replica group's
    # traffic by the node each device belongs to. This is the gate's
    # primary signal — unlike the analytic formula below, a regression
    # in the two-stage aggregation (e.g. raw summaries leaking into the
    # inter-node exchange) shows up here
    from repro.core import partitioner as _ptmod
    from repro.launch import dryrun

    node_of = [g // DEV for g in range(nshards)]
    meas = {}
    for label, eng, w in (("flat", eng_f, wlast_f), ("two_level", eng_h, wlast_h)):
        hlo = (
            _ptmod._hier_bucket_reslice_fn(eng.mesh, eng.plan)
            .lower(eng.leaf_id, w, eng.node_keys)
            .compile()
            .as_text()
        )
        meas[label] = dryrun.parse_inter_node_bytes(hlo, node_of)
    flat_bytes = meas["flat"]["inter_node_bytes"]
    two_bytes = meas["two_level"]["inter_node_bytes"]

    # analytic accounting (records per shard = node-table length of one
    # local tree; node_keys is (S*M,)) — reported alongside so drift
    # between model and measurement is visible in the artifact
    m_per_shard = int(np.asarray(eng_h.node_keys).shape[0]) // nshards
    acct = shd.summary_exchange_bytes(plan, m_per_shard)

    # node-level element motion of the final step (reported, not gated:
    # both paths answer drift with full re-slices here; the *engine*
    # level intra-node trigger is exercised by the repartition tests)
    hier_node = hier_part // DEV
    flat_node = flat_part // DEV  # flat parts cover the same curve slices

    imb = lambda l: float(l.max() / max(l.mean(), 1e-12))
    rows = [
        (
            f"reslice/flat/n={n}", flat_ms * 1e3,
            f"inter_node_bytes={flat_bytes};imbalance={imb(flat_loads):.4f}",
        ),
        (
            f"reslice/two_level/n={n}", hier_ms * 1e3,
            f"inter_node_bytes={two_bytes};imbalance={imb(hier_loads):.4f};"
            f"bytes_ratio={flat_bytes / max(two_bytes, 1):.1f}x",
        ),
    ]
    stats = {
        "n": n,
        "steps": steps,
        "nodes": NODES,
        "devices_per_node": DEV,
        "records_per_shard": m_per_shard,
        "flat_inter_node_bytes": flat_bytes,
        "two_level_inter_node_bytes": two_bytes,
        "flat_intra_node_bytes": meas["flat"]["intra_node_bytes"],
        "two_level_intra_node_bytes": meas["two_level"]["intra_node_bytes"],
        "flat_collectives": meas["flat"]["collectives"],
        "two_level_collectives": meas["two_level"]["collectives"],
        "analytic_flat_inter_node_bytes": acct["flat_inter_node_bytes"],
        "analytic_two_level_inter_node_bytes": acct["two_level_inter_node_bytes"],
        "flat_reslice_ms": flat_ms,
        "two_level_reslice_ms": hier_ms,
        "flat_imbalance": imb(flat_loads),
        "two_level_imbalance": imb(hier_loads),
        "flat_mass": float(flat_loads.sum()),
        "two_level_mass": float(hier_loads.sum()),
        "expected_mass": float(traces[-1].sum()),
        "flat_node_spread": float(np.ptp(np.bincount(flat_node, weights=traces[-1], minlength=NODES))),
        "two_level_node_spread": float(np.ptp(np.bincount(hier_node, weights=traces[-1], minlength=NODES))),
    }
    return rows, stats


def smoke_main() -> int:
    rows, stats = _run(N, STEPS)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if stats is None:
        print("WARNING: hierarchy gate skipped (< 8 devices)")
        return 0
    # primary gate: bytes measured from the compiled programs' replica
    # groups — strictly fewer, and the flat program must actually cross
    # nodes (a 0-vs-0 comparison would mean the measurement broke)
    ok_bytes = (
        0 < stats["two_level_inter_node_bytes"] < stats["flat_inter_node_bytes"]
    )
    ok_mass = all(
        abs(stats[k] - stats["expected_mass"]) < 1e-3 * stats["expected_mass"]
        for k in ("flat_mass", "two_level_mass")
    )
    # bucket-granular balance: generous static bound — the real per-run
    # numbers land in the artifact for trajectory tracking
    ok_bal = stats["two_level_imbalance"] < 1.5 and stats["flat_imbalance"] < 1.5
    passed = ok_bytes and ok_mass and ok_bal
    if not passed:
        print(
            f"FAIL: bytes two_level<{'' if ok_bytes else 'NOT '}flat "
            f"({stats['two_level_inter_node_bytes']} vs "
            f"{stats['flat_inter_node_bytes']}), mass ok={ok_mass}, "
            f"balance ok={ok_bal}"
        )
    else:
        print(
            f"PASS: two-level reslice moves "
            f"{stats['flat_inter_node_bytes'] / max(stats['two_level_inter_node_bytes'], 1):.1f}x "
            f"fewer inter-node summary bytes than flat "
            f"(imbalance {stats['two_level_imbalance']:.3f} vs "
            f"{stats['flat_imbalance']:.3f})"
        )
    # the BENCH_<name>.json summary is the FINAL stdout line (CI scrapes it)
    write_artifact("hierarchy", stats, passed=passed, echo=True)
    return 0 if passed else 1


if __name__ == "__main__":
    if SMOKE:
        sys.exit(smoke_main())
    print("name,us_per_call,derived")
    for name, us, derived in bench_hierarchy_rows():
        print(f"{name},{us:.1f},{derived}")
