"""AMR mesh workload: distributed stencil correctness + incremental
re-slice economics on the closed partition loop.

The claims under test (paper §I "dynamic applications" + §IV):

* **correctness** — the distributed stencil (halo exchange over compiled
  send/recv plans, state migrated between partitions on device) is
  BIT-EQUAL to the single-device reference after the full simulation,
  including >= 3 repartition events and the AMR refine/coarsen steps in
  between. Equality is exact (``np.array_equal``), not a tolerance.
* **economics** — answering load drift with the hierarchical engine's
  incremental re-slice plus moved-rows-only (node-local when certified)
  migration must beat a full rebuild plus full redistribute on measured
  walltime, on the same trajectory, same devices, warm executors.
* **stencil overlap** — the overlapped + fused stencil executor
  (interior/boundary split, fused row update, fori_loop step loop — ONE
  compile for every sweep length) must be bit-equal to the pre-split
  serialize-everything executor AND beat it on the walltime of a
  varied sweep-length schedule, where the pre-split executor pays a
  recompile per distinct ``steps`` (the compile churn this executor
  eliminates; per-sweep warm time is also reported, as
  ``stencil_warm_sweep_ratio``).

``--smoke`` (nightly CI) runs at 8 fake host devices arranged 2 nodes x
4 devices, gates both claims, writes ``BENCH_mesh.json`` and prints the
summary as the final stdout line. Runs each driver twice and times the
second pass so jit compiles (shared through the lru-cached executors)
don't pollute the comparison.

    PYTHONPATH=src python benchmarks/bench_mesh.py [events] [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

SMOKE = "--smoke" in sys.argv
if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    # fake devices must be requested before jax initializes; under
    # run.py the flag must NOT leak into single-device suites, so rows
    # report SKIPPED there unless devices already exist
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

try:
    from benchmarks._artifact import write_artifact
except ImportError:  # run as a script: the benchmarks dir itself is on sys.path
    from _artifact import write_artifact

_argv = [a for a in sys.argv[1:] if not a.startswith("--")]
EVENTS = int(_argv[0]) if _argv else 12
NODES, DEV = 2, 4


def _config():
    from repro.mesh import simulate

    return simulate.SimConfig(
        events=EVENTS,
        amr_every=3,
        substeps=2,
        base_level=4,
        max_level=6,
        x0=0.15,
        x1=0.85,
    )


def _overlap_compare(cfg, mesh, hplan):
    """Overlapped+fused executor vs the pre-split baseline, one plan.

    Three measurements on the event-0 halo plan:

    * bit-equality of every executor variant (overlap jnp, overlap
      Pallas path, pre-split) against ``reference_stencil`` for each
      distinct sweep length in the schedule;
    * walltime of a varied sweep-length schedule ([1,2,3,4] x 3) with
      both executors warmed at ``substeps`` only — the overlapped
      executor's ``fori_loop`` runs ONE compiled program throughout
      while the pre-split executor recompiles per distinct ``steps``
      (its lru key). This is the gated ``stencil_overlap_speedup``;
    * warm per-sweep time at fixed ``steps=substeps`` (both executors
      hot), reported as ``stencil_warm_sweep_ratio`` — informational:
      on CPU fake devices the collectives are memcpys, so there is no
      real async window for the interior update to hide in.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import partitioner as _pt
    from repro.core.repartition import HierarchicalRepartitioner
    from repro.mesh import halo as _halo
    from repro.mesh import simulate
    from repro.mesh import stencil as _st

    ev = simulate.build_trajectory(cfg)[0]
    u0 = simulate.initial_field(ev.mesh, cfg)
    rp = HierarchicalRepartitioner(
        jnp.asarray(ev.mesh.centers()),
        jnp.asarray(ev.weights),
        plan=hplan,
        cfg=_pt.PartitionerConfig(use_tree=True, curve="hilbert"),
        node_threshold=cfg.node_threshold,
        capacity=2 * ev.mesh.n,
        bucket_size=cfg.bucket_size,
        max_depth=cfg.engine_max_depth,
    )
    slots = np.arange(ev.mesh.n, dtype=np.int64)
    plan = _halo.build_halo_plan(
        slots, rp.partition_of(slots), ev.nbr, ev.coeff,
        hierarchy=hplan, weights=ev.weights,
    )
    args = _st.halo_args(mesh, plan)
    u_dev = _st.put_state(mesh, plan, u0)
    valid = ev.nbr >= 0
    schedule = [1, 2, 3, 4] * 3

    bit_equal = True
    for s in sorted(set(schedule)):
        ref = np.asarray(_st.reference_stencil(u0, ev.nbr, valid, ev.coeff, s))
        for kw in (
            {"overlap": True},
            {"overlap": True, "use_pallas": True},
            {"overlap": False},
        ):
            got = plan.unpack_cells(
                np.asarray(_st.stencil_steps(mesh, plan, u_dev, args, s, **kw)),
                ev.mesh.n,
            )
            bit_equal = bit_equal and bool(np.array_equal(ref, got))

    run_ov = lambda s: jax.block_until_ready(
        _st.stencil_steps(mesh, plan, u_dev, args, s)
    )
    run_ps = lambda s: jax.block_until_ready(
        _st.stencil_steps(mesh, plan, u_dev, args, s, overlap=False)
    )
    # the bit-equality pass above compiled the pre-split executor for
    # every length — drop those so the schedule measures the churn the
    # fori_loop executor eliminates; both warmed at substeps only
    _st._stencil_fn_presplit.cache_clear()
    run_ov(cfg.substeps)
    run_ps(cfg.substeps)
    t0 = time.perf_counter()
    for s in schedule:
        run_ov(s)
    t_ov = time.perf_counter() - t0
    t0 = time.perf_counter()
    for s in schedule:
        run_ps(s)
    t_ps = time.perf_counter() - t0

    reps = 20  # both hot at substeps: steady-state per-sweep comparison
    t0 = time.perf_counter()
    for _ in range(reps):
        run_ov(cfg.substeps)
    w_ov = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        run_ps(cfg.substeps)
    w_ps = time.perf_counter() - t0

    return {
        "overlap_bit_equal": bit_equal,
        "overlap_schedule_s": t_ov,
        "presplit_schedule_s": t_ps,
        "stencil_overlap_speedup": t_ps / max(t_ov, 1e-9),
        "stencil_warm_sweep_ratio": w_ps / max(w_ov, 1e-9),
        "overlap_schedule": schedule,
        "interior_cells": plan.metrics.get("InteriorCells"),
        "boundary_cells": plan.metrics.get("BoundaryCells"),
    }


def _run(events_cfg=None):
    import jax

    from repro.core import partitioner as pt
    from repro.distributed import sharding as shd
    from repro.mesh import simulate

    nshards = NODES * DEV
    if len(jax.devices()) < nshards:
        return [(f"mesh/SKIPPED(<{nshards} devices)", 0.0, "")], None

    cfg = events_cfg or _config()
    events = simulate.build_trajectory(cfg)
    u0 = simulate.initial_field(events[0].mesh, cfg)
    t0 = time.perf_counter()
    uref = simulate.run_reference(events, u0, cfg.substeps)
    ref_s = time.perf_counter() - t0

    hplan = pt.HierarchyPlan(num_nodes=NODES, devices_per_node=DEV)
    mesh = shd.make_node_device_mesh(NODES, DEV)

    results = {}
    for driver in ("incremental", "rebuild"):
        # two passes: executors are lru-cached, the second is warm; the
        # incremental pass also attributes sweep time to its phases via
        # the single-phase probes (probe calls sit outside every timed
        # region, so the economics comparison is unaffected)
        for _ in range(2):
            u, st = simulate.run_distributed(
                events, u0, cfg.substeps, mesh, hplan, driver=driver,
                cfg=cfg, phase_probes=driver == "incremental",
            )
        results[driver] = (u, st)
    overlap = _overlap_compare(cfg, mesh, hplan)

    inc, reb = results["incremental"][1], results["rebuild"][1]
    bit_inc = bool(np.array_equal(uref, results["incremental"][0]))
    bit_reb = bool(np.array_equal(uref, results["rebuild"][0]))
    t_inc = inc.engine_s + inc.move_s
    t_reb = reb.engine_s + reb.move_s

    rows = [
        (
            f"mesh/reference/n={inc.cells_final}", ref_s * 1e6,
            f"events={len(events)};substeps={cfg.substeps}",
        ),
        (
            "mesh/incremental_reslice+migrate", t_inc * 1e6,
            f"bit_equal={bit_inc};repart_events={inc.repartition_events};"
            f"intra={inc.intra_reslices};node_local_moves={inc.node_local_moves}",
        ),
        (
            "mesh/rebuild+redistribute", t_reb * 1e6,
            f"bit_equal={bit_reb};rebuilds={reb.rebuilds};"
            f"speedup={t_reb / max(t_inc, 1e-9):.1f}x",
        ),
        (
            "mesh/stencil_overlap_schedule", overlap["overlap_schedule_s"] * 1e6,
            f"bit_equal={overlap['overlap_bit_equal']};"
            f"presplit_us={overlap['presplit_schedule_s'] * 1e6:.1f};"
            f"speedup={overlap['stencil_overlap_speedup']:.1f}x;"
            f"warm_ratio={overlap['stencil_warm_sweep_ratio']:.2f}",
        ),
    ]
    hm = inc.halo_metrics
    stats = {
        "events": len(events),
        "substeps": cfg.substeps,
        "nodes": NODES,
        "devices_per_node": DEV,
        "cells_final": inc.cells_final,
        "bit_equal_incremental": bit_inc,
        "bit_equal_rebuild": bit_reb,
        "repartition_events": inc.repartition_events,
        "amr_events": inc.amr_events,
        "intra_reslices": inc.intra_reslices,
        "inter_reslices": inc.inter_reslices,
        "incremental_rebuilds": inc.rebuilds,
        "node_local_moves": inc.node_local_moves,
        "moved_total_incremental": inc.moved_total,
        "moved_inter_node_incremental": inc.moved_inter_node,
        "moved_total_rebuild": reb.moved_total,
        "incremental_engine_s": inc.engine_s,
        "incremental_move_s": inc.move_s,
        "incremental_stencil_s": inc.stencil_s,
        "incremental_plan_build_s": inc.plan_build_s,
        "rebuild_plan_build_s": reb.plan_build_s,
        "incremental_plan_cache_hits": inc.plan_cache_hits,
        "incremental_plan_cache_misses": inc.plan_cache_misses,
        "incremental_plan_patched_rows": inc.plan_patched_rows,
        "stencil_exchange_s": inc.stencil_exchange_s,
        "stencil_interior_s": inc.stencil_interior_s,
        "stencil_boundary_s": inc.stencil_boundary_s,
        "rebuild_engine_s": reb.engine_s,
        "rebuild_move_s": reb.move_s,
        "rebuild_stencil_s": reb.stencil_s,
        "incremental_total_s": t_inc,
        "rebuild_total_s": t_reb,
        "speedup": t_reb / max(t_inc, 1e-9),
        "reference_s": ref_s,
        "max_surface_index": hm.get("MaxSurfaceIndex"),
        "max_edge_cut": hm.get("MaxEdgeCut"),
        "max_degree": hm.get("MaxDegree"),
        "inter_node_ghosts": hm.get("InterNodeGhosts"),
        "intra_node_ghosts": hm.get("IntraNodeGhosts"),
        "inter_node_halo_bytes_per_exchange": hm.get("InterNodeBytesPerExchange"),
        "interior_cells": hm.get("InteriorCells"),
        "boundary_cells": hm.get("BoundaryCells"),
    }
    stats.update(
        {k: v for k, v in overlap.items() if k not in ("interior_cells", "boundary_cells")}
    )
    return rows, stats


def bench_mesh_rows() -> list[tuple]:
    """CSV rows (name, us_per_call, derived); SKIPPED row on < 8 devices."""
    rows, _ = _run()
    return rows


def smoke_main() -> int:
    rows, stats = _run()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if stats is None:
        print("WARNING: mesh gate skipped (< 8 devices)")
        return 0
    ok_bits = stats["bit_equal_incremental"] and stats["bit_equal_rebuild"]
    ok_events = stats["repartition_events"] >= 3
    ok_speed = stats["incremental_total_s"] < stats["rebuild_total_s"]
    ok_overlap = (
        stats["overlap_bit_equal"] and stats["stencil_overlap_speedup"] > 1.0
    )
    passed = ok_bits and ok_events and ok_speed and ok_overlap
    if not passed:
        print(
            f"FAIL: bit_equal={ok_bits} "
            f"(inc={stats['bit_equal_incremental']}, reb={stats['bit_equal_rebuild']}), "
            f"repartition_events={stats['repartition_events']} (need >=3), "
            f"incremental {stats['incremental_total_s']*1e3:.1f} ms vs "
            f"rebuild {stats['rebuild_total_s']*1e3:.1f} ms, "
            f"overlap bit_equal={stats['overlap_bit_equal']} "
            f"speedup={stats['stencil_overlap_speedup']:.2f}x (need >1.0)"
        )
    else:
        print(
            f"PASS: distributed stencil bit-equal to reference across "
            f"{stats['repartition_events']} repartition events "
            f"({stats['amr_events']} AMR); incremental re-slice + "
            f"node-local migration {stats['speedup']:.1f}x faster than "
            f"rebuild+redistribute "
            f"({stats['incremental_total_s']*1e3:.1f} ms vs "
            f"{stats['rebuild_total_s']*1e3:.1f} ms); overlapped+fused "
            f"stencil bit-equal and "
            f"{stats['stencil_overlap_speedup']:.1f}x faster than the "
            f"pre-split executor on a varied sweep-length schedule "
            f"(warm per-sweep ratio "
            f"{stats['stencil_warm_sweep_ratio']:.2f})"
        )
    write_artifact("mesh", stats, passed=passed, echo=True)
    return 0 if passed else 1


if __name__ == "__main__":
    if SMOKE:
        sys.exit(smoke_main())
    print("name,us_per_call,derived")
    for name, us, derived in bench_mesh_rows():
        print(f"{name},{us:.1f},{derived}")
