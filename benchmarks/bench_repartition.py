"""Incremental-step vs full-rebuild cost over a drifting load trace.

The paper's economic claim (§IV): once the initial partition exists,
adapting to a changed load distribution must cost a fraction of a cold
partition. We replay a weight-drift trace over fixed geometry and time
three policies on the same inputs:

* cold      — `partitioner.partition` from scratch every step
              (key-gen + sort + knapsack slice)
* engine    — `Repartitioner.rebalance` (cached keys + cached order,
              knapsack re-slice only)
* distributed (optional, REPRO_BENCH_DIST=1, 8 fake host devices) —
  `distributed_partition` vs `distributed_reslice` on cached shard keys

    PYTHONPATH=src python benchmarks/bench_repartition.py [n] [steps]
"""
import os
import sys
import time

import numpy as np

if os.environ.get("REPRO_BENCH_DIST", "0") == "1" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.core import partitioner as pt
from repro.core.repartition import Repartitioner

SMOKE = "--smoke" in sys.argv
_argv = [a for a in sys.argv[1:] if not a.startswith("--")]
N = int(_argv[0]) if len(_argv) > 0 else (20_000 if SMOKE else 200_000)
STEPS = int(_argv[1]) if len(_argv) > 1 else (4 if SMOKE else 10)
PARTS = 16
CFG = pt.PartitionerConfig(curve="hilbert")


def drift_trace(rng, n, steps):
    """Multiplicative load drift: a moving hot region on the unit cube."""
    base = 1.0 + rng.random(n).astype(np.float32)
    pts = rng.random((n, 3)).astype(np.float32)
    out = []
    for t in range(steps):
        c = np.array([0.2 + 0.06 * t, 0.5, 0.5], np.float32)
        hot = np.exp(-np.sum((pts - c) ** 2, axis=1) / 0.02)
        out.append(base * (1.0 + 4.0 * hot).astype(np.float32))
    return pts, out


def timed(fn, *args, warmup=1, reps=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps, out


def main():
    rng = np.random.default_rng(0)
    pts_h, trace = drift_trace(rng, N, STEPS)
    pts = jnp.asarray(pts_h)

    # --- cold full rebuild every step ------------------------------------
    def cold(w):
        return pt.partition(pts, w, PARTS, CFG).part

    cold_ts = []
    for w in trace:
        dt, _ = timed(cold, jnp.asarray(w), warmup=0)
        cold_ts.append(dt)
    # first call pays compile; report the steady-state median
    cold_ms = float(np.median(cold_ts[1:]) * 1e3)

    # --- incremental engine ----------------------------------------------
    # fixed geometry: size storage exactly (capacity=2n only pays off when
    # the trace inserts points)
    engine = Repartitioner(pts, jnp.asarray(trace[0]), PARTS, CFG, max_depth=10, capacity=N)

    def incr(w):
        engine.update_weights(w)
        return engine.rebalance().part

    incr_ts = []
    for w in trace:
        dt, _ = timed(incr, jnp.asarray(w), warmup=0)
        incr_ts.append(dt)
    incr_ms = float(np.median(incr_ts[1:]) * 1e3)

    # same balance quality? (identical curve order => identical slices)
    wl = jnp.asarray(trace[-1])
    cold_part = np.asarray(cold(wl))
    engine.update_weights(wl)
    loads_c = np.bincount(cold_part, weights=trace[-1], minlength=PARTS)
    loads_i = np.asarray(engine.rebalance().loads)
    imb = lambda l: l.max() / l.mean()

    print(f"n={N} steps={STEPS} parts={PARTS} curve={CFG.curve}")
    print(f"cold full rebuild : {cold_ms:9.2f} ms/step   imbalance {imb(loads_c):.4f}")
    print(f"incremental engine: {incr_ms:9.2f} ms/step   imbalance {imb(loads_i):.4f}")
    print(f"speedup           : {cold_ms / max(incr_ms, 1e-9):9.1f}x")

    metrics = {
        "n": N, "steps": STEPS, "parts": PARTS, "distributed": False,
        "cold_ms": cold_ms, "incremental_ms": incr_ms,
        "speedup": cold_ms / max(incr_ms, 1e-9),
        "cold_imbalance": float(imb(loads_c)),
        "incremental_imbalance": float(imb(loads_i)),
    }

    if os.environ.get("REPRO_BENCH_DIST", "0") == "1" and len(jax.devices()) >= 8:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core.repartition import DistributedRepartitioner
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        sh = NamedSharding(mesh, P("data"))
        n8 = (N // 8) * 8
        dpts = jax.device_put(pts[:n8], sh)
        dwts = jax.device_put(jnp.asarray(trace[0][:n8]), sh)
        eng = DistributedRepartitioner(mesh, "data", PARTS, CFG)

        full_t, (_, wsrt, _) = timed(lambda: eng.partition(dpts, dwts))
        # drift the sorted-layout weights in place (weight-only change)
        w2 = jnp.where(wsrt >= 0, wsrt * 1.5, wsrt)
        res_t, _ = timed(lambda: eng.rebalance(w2))
        print(f"distributed full  : {full_t*1e3:9.2f} ms")
        print(f"distributed reslice: {res_t*1e3:8.2f} ms   "
              f"({full_t/max(res_t,1e-9):.1f}x)")
        metrics.update(
            distributed=True,
            distributed_full_ms=full_t * 1e3,
            distributed_reslice_ms=res_t * 1e3,
            distributed_speedup=full_t / max(res_t, 1e-9),
        )

    try:
        from benchmarks._artifact import write_artifact
    except ImportError:
        from _artifact import write_artifact
    if incr_ms >= cold_ms:
        print("WARNING: incremental step not cheaper than cold rebuild")
    # the BENCH_<name>.json summary is the FINAL stdout line (CI scrapes it)
    write_artifact(
        "repartition" + ("_dist" if metrics["distributed"] else ""),
        metrics,
        passed=incr_ms < cold_ms,
        echo=True,
    )
    return 1 if incr_ms >= cold_ms else 0


if __name__ == "__main__":
    sys.exit(main())
