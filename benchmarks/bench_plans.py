"""Plan-construction benchmark: vectorized vs per-part legacy builders.

Plans are rebuilt on every repartition event, so host-side construction
cost bounds how *dynamic* a dynamic workload can be (the paper's
"minimal partitioning cost" requirement). This suite measures exactly
that host cost — no jax devices are involved: `build_halo_plan` /
`build_move_plan` are pure-numpy compilations of the exchange tables,
so the "devices" here are plan shards.

Three cases:

* **smoke gate** — an adapted AMR mesh (~20k cells) on 8 shards
  (2 nodes x 4 devices, the two-hop plan with the heaviest legacy
  loops). Gates: vectorized output bit-identical to the legacy
  builders (spot check; `tests/test_plan_equivalence.py` holds the
  full matrix) AND vectorized-vs-legacy build speedup > 1 for both the
  halo and the move plan.
* **64 devices / ~1M cells** — a uniform level-10 mesh (1,048,576
  cells) on 64 shards (8 nodes x 8 devices), vectorized builders only:
  the regime ROADMAP names, where the legacy per-cell loops are not
  runnable in reasonable time. Reported, not compared.
* **event sequence** — the same 64-shard / ~1M-cell mesh driven
  through a 12-event reslice schedule shaped like BENCH_mesh's (mostly
  single-node intra reslices, a couple of global inter reslices, one
  large shift that exceeds the patch threshold). Every event is built
  twice: from scratch and through a `plan_cache.PlanCache`. Gates:
  bit-identical on EVERY event AND cached-vs-scratch build speedup > 1
  on the reslice-only (intra) events.

``--profile`` additionally emits the per-stage build breakdown (slot
sort / owned lexsort / owner gather / ghost dedup / tables / stage
packing seconds, and the patch-path analogues) into the artifact.

``--smoke`` runs all three, writes ``BENCH_plans.json`` and prints the
summary as the final stdout line (nightly CI).

    PYTHONPATH=src python benchmarks/bench_plans.py [--smoke] [--profile]
"""
from __future__ import annotations

import sys
import time

import numpy as np

try:
    from benchmarks._artifact import write_artifact
except ImportError:  # run as a script: the benchmarks dir itself is on sys.path
    from _artifact import write_artifact

SMOKE = "--smoke" in sys.argv
PROFILE = "--profile" in sys.argv


def _sfc_partition(mesh, num_parts: int) -> np.ndarray:
    """Equal-count contiguous slices of the packed-key (SFC-ish) cell
    order — the shape real partitions have (compact parts, node-major)."""
    from repro.mesh import amr

    order = np.argsort(amr._pack(mesh.level, mesh.ij), kind="stable")
    part = np.empty((mesh.n,), np.int32)
    bounds = (np.arange(num_parts + 1) * mesh.n) // num_parts
    for p in range(num_parts):
        part[order[bounds[p] : bounds[p + 1]]] = p
    return part


def _drift(part: np.ndarray, mesh, num_parts: int, frac: float = 0.06) -> np.ndarray:
    """Shift the slice boundaries by ``frac`` of a part — the moved-rows
    profile of an incremental re-slice answering load drift."""
    from repro.mesh import amr

    order = np.argsort(amr._pack(mesh.level, mesh.ij), kind="stable")
    shift = max(1, int(frac * mesh.n / num_parts))
    bounds = (np.arange(num_parts + 1) * mesh.n) // num_parts
    bounds[1:-1] = bounds[1:-1] + shift
    part2 = np.empty_like(part)
    for p in range(num_parts):
        part2[order[bounds[p] : bounds[p + 1]]] = p
    return part2


def _mesh_case(base_level: int, adapt_steps: int):
    from repro.mesh import amr

    mesh = amr.uniform_mesh(2, base_level, base_level + 2)
    for k in range(adapt_steps):
        c = amr.feature_center(0.3 + 0.2 * k, 2)
        ref, coar = amr.adapt_masks(mesh, c)
        mesh, _ = amr.refine_coarsen(mesh, ref, coar)
    nbr = amr.face_neighbors(mesh)
    coeff = amr.stencil_coeffs(mesh, nbr, amr.stable_dt(mesh))
    slot = np.arange(mesh.n, dtype=np.int64)
    return mesh, nbr, coeff, slot


def _median_time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _plans_equal(a, b) -> bool:
    arr = (
        "owned_idx", "owned_slot", "nbr_local", "nbr_valid", "coeff",
        "ghost_fetch", "interior_idx", "boundary_idx",
    )
    if any(not np.array_equal(getattr(a, f), getattr(b, f)) for f in arr):
        return False
    if (a.cap, a.gcap, a.axes, a.num_parts) != (b.cap, b.gcap, b.axes, b.num_parts):
        return False
    if a.stage_meta != b.stage_meta:
        return False
    return all(np.array_equal(sa.idx, sb.idx) for sa, sb in zip(a.stages, b.stages))


def _move_equal(a, b) -> bool:
    if (a.kind, a.axes, a.cap_old, a.cap_new, a.stage_meta) != (
        b.kind, b.axes, b.cap_old, b.cap_new, b.stage_meta
    ):
        return False
    if not np.array_equal(a.keep, b.keep):
        return False
    return all(np.array_equal(sa.idx, sb.idx) for sa, sb in zip(a.stages, b.stages))


def _compare_case(base_level: int, nodes: int, dev: int, reps: int = 5):
    """Vectorized vs legacy on one mesh: timings + bit-equality."""
    from repro.core import partitioner as pt
    from repro.mesh import halo

    hplan = pt.HierarchyPlan(num_nodes=nodes, devices_per_node=dev)
    mesh, nbr, coeff, slot = _mesh_case(base_level, adapt_steps=2)
    S = nodes * dev
    part = _sfc_partition(mesh, S)
    part2 = _drift(part, mesh, S)

    build_v = lambda p: halo.build_halo_plan(
        slot, p, nbr, coeff, hierarchy=hplan, with_metrics=False
    )
    build_l = lambda p: halo.build_halo_plan_legacy(
        slot, p, nbr, coeff, hierarchy=hplan, with_metrics=False
    )
    pv, pv2 = build_v(part), build_v(part2)
    pl, pl2 = build_l(part), build_l(part2)
    mv_v = halo.build_move_plan(pv, pv2, hierarchy=hplan)
    mv_l = halo.build_move_plan_legacy(pl, pl2, hierarchy=hplan)
    bit_equal = (
        _plans_equal(pv, pl) and _plans_equal(pv2, pl2) and _move_equal(mv_v, mv_l)
    )

    t_halo_v = _median_time(lambda: build_v(part), reps)
    t_halo_l = _median_time(lambda: build_l(part), max(reps // 2, 1))
    t_move_v = _median_time(
        lambda: halo.build_move_plan(pv, pv2, hierarchy=hplan), reps
    )
    t_move_l = _median_time(
        lambda: halo.build_move_plan_legacy(pl, pl2, hierarchy=hplan),
        max(reps // 2, 1),
    )
    return {
        "cells": mesh.n,
        "parts": S,
        "bit_equal": bit_equal,
        "halo_vec_s": t_halo_v,
        "halo_legacy_s": t_halo_l,
        "halo_build_speedup": t_halo_l / max(t_halo_v, 1e-9),
        "move_vec_s": t_move_v,
        "move_legacy_s": t_move_l,
        "move_build_speedup": t_move_l / max(t_move_v, 1e-9),
        "moved_rows": int(mv_v.migration.total_moved),
    }


def _large_case(base_level: int = 10, nodes: int = 8, dev: int = 8, mesh_data=None):
    """64 shards / ~1M cells, vectorized builders only (the legacy path
    is the wall PR 8 removed — it does not run here)."""
    from repro.core import partitioner as pt
    from repro.mesh import halo

    hplan = pt.HierarchyPlan(num_nodes=nodes, devices_per_node=dev)
    mesh, nbr, coeff, slot = mesh_data or _mesh_case(base_level, adapt_steps=0)
    S = nodes * dev
    part = _sfc_partition(mesh, S)
    part2 = _drift(part, mesh, S)
    t0 = time.perf_counter()
    pv = halo.build_halo_plan(slot, part, nbr, coeff, hierarchy=hplan, with_metrics=False)
    t_halo = time.perf_counter() - t0
    pv2 = halo.build_halo_plan(slot, part2, nbr, coeff, hierarchy=hplan, with_metrics=False)
    t0 = time.perf_counter()
    mv = halo.build_move_plan(pv, pv2, hierarchy=hplan)
    t_move = time.perf_counter() - t0
    return {
        "large_cells": mesh.n,
        "large_parts": S,
        "large_halo_build_s": t_halo,
        "large_move_build_s": t_move,
        "large_ghosts": int(
            pv.metrics["IntraNodeGhosts"] + pv.metrics["InterNodeGhosts"]
        ),
        "large_moved_rows": int(mv.migration.total_moved),
    }


def _event_sequence_case(
    nodes: int = 8, dev: int = 8, events: int = 12, mesh_data=None,
    profile: bool = False,
):
    """Reslice-event schedule at 64 shards / ~1M cells: every event is
    built from scratch AND through a ``PlanCache``; bit-equality is
    checked per event, speedup is gated on the intra (reslice-only)
    events — the profile BENCH_mesh's incremental driver produces
    (mostly single-node reslices)."""
    from repro.core import partitioner as pt
    from repro.mesh import amr, halo

    hplan = pt.HierarchyPlan(num_nodes=nodes, devices_per_node=dev)
    mesh, nbr, coeff, slot = mesh_data or _mesh_case(10, adapt_steps=0)
    S = nodes * dev
    n = mesh.n
    order = np.argsort(amr._pack(mesh.level, mesh.ij), kind="stable")
    bounds = (np.arange(S + 1) * n) // S
    rng = np.random.default_rng(0)
    cache = halo.PlanCache()
    prof_scratch: dict | None = {} if profile else None
    prof_cached: dict | None = {} if profile else None

    def part_from(b):
        part = np.empty((n,), np.int32)
        for p in range(S):
            part[order[b[p] : b[p + 1]]] = p
        return part

    bit_equal = True
    recs = []
    prev_s = prev_c = None
    for t in range(events):
        if t == 0:
            kind = "init"
        elif t == 6:
            kind = "large"        # > patch threshold: scratch-fallback path
        elif t % 5 == 0:
            kind = "inter"        # global, small: every boundary shifts
        else:
            kind = "intra"        # one node's internal boundaries only
        if kind == "intra":
            node = int(rng.integers(0, nodes))
            lo, hi = bounds[node * dev], bounds[(node + 1) * dev]
            j = slice(node * dev + 1, (node + 1) * dev)
            shift = rng.integers(-(n // (8 * S)), n // (8 * S) + 1, dev - 1)
            bounds[j] = np.sort(np.clip(bounds[j] + shift, lo, hi))
        elif kind == "inter":
            shift = rng.integers(-(n // (16 * S)), n // (16 * S) + 1, S - 1)
            bounds[1:-1] = np.sort(np.clip(bounds[1:-1] + shift, 1, n - 1))
        elif kind == "large":
            bounds[1:-1] = np.clip(bounds[1:-1] + n // (2 * S), 1, n - 1)
        part = part_from(bounds)

        t0 = time.perf_counter()
        ps = halo.build_halo_plan(
            slot, part, nbr, coeff, hierarchy=hplan, with_metrics=False,
            profile=prof_scratch,
        )
        t_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        pc = halo.build_halo_plan(
            slot, part, nbr, coeff, hierarchy=hplan, with_metrics=False,
            cache=cache, topo_token=0, profile=prof_cached,
        )
        t_c = time.perf_counter() - t0
        bit_equal = bit_equal and _plans_equal(ps, pc)
        rec = dict(kind=kind, halo_scratch_s=t_s, halo_cached_s=t_c,
                   patched=int(pc.metrics["PatchedRows"]))
        if prev_s is not None:
            t0 = time.perf_counter()
            ms = halo.build_move_plan(prev_s, ps, hierarchy=hplan)
            rec["move_scratch_s"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            mc = halo.build_move_plan(prev_c, pc, hierarchy=hplan, cache=cache)
            rec["move_cached_s"] = time.perf_counter() - t0
            bit_equal = bit_equal and _move_equal(ms, mc)
        recs.append(rec)
        prev_s, prev_c = ps, pc

    intra = [r for r in recs if r["kind"] == "intra"]
    med = lambda xs: float(np.median(xs)) if xs else 0.0
    out = {
        "ev_cells": n,
        "ev_parts": S,
        "ev_events": events,
        "ev_intra_events": len(intra),
        "ev_bit_equal": bit_equal,
        "ev_intra_scratch_s": med([r["halo_scratch_s"] for r in intra]),
        "ev_intra_cached_s": med([r["halo_cached_s"] for r in intra]),
        "ev_intra_speedup": med(
            [r["halo_scratch_s"] / max(r["halo_cached_s"], 1e-9) for r in intra]
        ),
        "ev_intra_patched_rows": med([r["patched"] for r in intra]),
        "ev_move_scratch_s": med([r["move_scratch_s"] for r in recs if "move_scratch_s" in r]),
        "ev_move_cached_s": med([r["move_cached_s"] for r in recs if "move_cached_s" in r]),
        "ev_cache_halo_hits": cache.stats.halo_hits,
        "ev_cache_halo_misses": cache.stats.halo_misses,
        "ev_cache_move_hits": cache.stats.move_hits,
        "ev_patched_rows_total": cache.stats.patched_rows,
    }
    if profile:
        for k, v in (prof_scratch or {}).items():
            out[f"prof_scratch_{k}"] = v
        for k, v in (prof_cached or {}).items():
            out[f"prof_cached_{k}"] = v
    return out


def _rows_from(c: dict) -> list[tuple]:
    return [
        (
            f"plans/halo_vectorized/n={c['cells']}/S={c['parts']}",
            c["halo_vec_s"] * 1e6,
            f"bit_equal={c['bit_equal']};legacy_us={c['halo_legacy_s'] * 1e6:.1f};"
            f"speedup={c['halo_build_speedup']:.1f}x",
        ),
        (
            f"plans/move_vectorized/n={c['cells']}/S={c['parts']}",
            c["move_vec_s"] * 1e6,
            f"moved={c['moved_rows']};legacy_us={c['move_legacy_s'] * 1e6:.1f};"
            f"speedup={c['move_build_speedup']:.1f}x",
        ),
    ]


def bench_plans_rows() -> list[tuple]:
    """CSV rows (name, us_per_call, derived) — the smoke-size comparison."""
    return _rows_from(_compare_case(base_level=7, nodes=2, dev=4))


def smoke_main() -> int:
    c = _compare_case(base_level=7, nodes=2, dev=4)
    if c["halo_build_speedup"] <= 1.0 or c["move_build_speedup"] <= 1.0:
        # marginal box: one retry at 4x the cells, where the asymptotic
        # gap cannot be hidden by constant factors
        c = _compare_case(base_level=8, nodes=2, dev=4)
    rows = _rows_from(c)
    big_mesh = _mesh_case(10, adapt_steps=0)  # shared: _large_case + events
    big = _large_case(mesh_data=big_mesh)
    ev = _event_sequence_case(mesh_data=big_mesh, profile=PROFILE)
    rows.append(
        (
            f"plans/halo_vectorized/n={big['large_cells']}/S={big['large_parts']}",
            big["large_halo_build_s"] * 1e6,
            f"ghosts={big['large_ghosts']};"
            f"move_us={big['large_move_build_s'] * 1e6:.1f};legacy=not-run",
        )
    )
    rows.append(
        (
            f"plans/halo_cached/n={ev['ev_cells']}/S={ev['ev_parts']}",
            ev["ev_intra_cached_s"] * 1e6,
            f"bit_equal={ev['ev_bit_equal']};"
            f"scratch_us={ev['ev_intra_scratch_s'] * 1e6:.1f};"
            f"speedup={ev['ev_intra_speedup']:.1f}x;"
            f"patched_rows={ev['ev_intra_patched_rows']:.0f}",
        )
    )
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    ok_bits = c["bit_equal"]
    ok_halo = c["halo_build_speedup"] > 1.0
    ok_move = c["move_build_speedup"] > 1.0
    ok_large = big["large_halo_build_s"] > 0 and big["large_cells"] >= 10**6
    ok_ev = ev["ev_bit_equal"] and ev["ev_intra_speedup"] > 1.0
    passed = ok_bits and ok_halo and ok_move and ok_large and ok_ev
    if passed:
        print(
            f"PASS: vectorized plans bit-identical to legacy at "
            f"n={c['cells']}/S={c['parts']}; build speedup halo "
            f"{c['halo_build_speedup']:.1f}x, move "
            f"{c['move_build_speedup']:.1f}x; 64-shard/"
            f"{big['large_cells']}-cell halo plan built in "
            f"{big['large_halo_build_s'] * 1e3:.0f} ms (move "
            f"{big['large_move_build_s'] * 1e3:.0f} ms); event cache "
            f"{ev['ev_intra_speedup']:.1f}x on reslice events, bit-equal "
            f"across {ev['ev_events']} events"
        )
    else:
        print(
            f"FAIL: bit_equal={ok_bits}, "
            f"halo_speedup={c['halo_build_speedup']:.2f}x (need >1), "
            f"move_speedup={c['move_build_speedup']:.2f}x (need >1), "
            f"large_case_ok={ok_large}, "
            f"ev_bit_equal={ev['ev_bit_equal']}, "
            f"ev_intra_speedup={ev['ev_intra_speedup']:.2f}x (need >1)"
        )
    stats = {**c, **big, **ev}
    write_artifact("plans", stats, passed=passed, echo=True)
    return 0 if passed else 1


if __name__ == "__main__":
    if SMOKE:
        sys.exit(smoke_main())
    print("name,us_per_call,derived")
    for name, us, derived in bench_plans_rows():
        print(f"{name},{us:.1f},{derived}")
    if PROFILE:
        # small-scale event sequence: per-stage scratch vs patch breakdown
        ev = _event_sequence_case(nodes=2, dev=4, mesh_data=_mesh_case(7, 0),
                                  profile=True)
        print("stage,seconds")
        for k in sorted(ev):
            if k.startswith("prof_"):
                print(f"{k[5:]},{ev[k]:.6f}")
