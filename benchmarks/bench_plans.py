"""Plan-construction benchmark: vectorized vs per-part legacy builders.

Plans are rebuilt on every repartition event, so host-side construction
cost bounds how *dynamic* a dynamic workload can be (the paper's
"minimal partitioning cost" requirement). This suite measures exactly
that host cost — no jax devices are involved: `build_halo_plan` /
`build_move_plan` are pure-numpy compilations of the exchange tables,
so the "devices" here are plan shards.

Two cases:

* **smoke gate** — an adapted AMR mesh (~20k cells) on 8 shards
  (2 nodes x 4 devices, the two-hop plan with the heaviest legacy
  loops). Gates: vectorized output bit-identical to the legacy
  builders (spot check; `tests/test_plan_equivalence.py` holds the
  full matrix) AND vectorized-vs-legacy build speedup > 1 for both the
  halo and the move plan.
* **64 devices / ~1M cells** — a uniform level-10 mesh (1,048,576
  cells) on 64 shards (8 nodes x 8 devices), vectorized builders only:
  the regime ROADMAP names, where the legacy per-cell loops are not
  runnable in reasonable time. Reported, not compared.

``--smoke`` runs both, writes ``BENCH_plans.json`` and prints the
summary as the final stdout line (nightly CI).

    PYTHONPATH=src python benchmarks/bench_plans.py [--smoke]
"""
from __future__ import annotations

import sys
import time

import numpy as np

try:
    from benchmarks._artifact import write_artifact
except ImportError:  # run as a script: the benchmarks dir itself is on sys.path
    from _artifact import write_artifact

SMOKE = "--smoke" in sys.argv


def _sfc_partition(mesh, num_parts: int) -> np.ndarray:
    """Equal-count contiguous slices of the packed-key (SFC-ish) cell
    order — the shape real partitions have (compact parts, node-major)."""
    from repro.mesh import amr

    order = np.argsort(amr._pack(mesh.level, mesh.ij), kind="stable")
    part = np.empty((mesh.n,), np.int32)
    bounds = (np.arange(num_parts + 1) * mesh.n) // num_parts
    for p in range(num_parts):
        part[order[bounds[p] : bounds[p + 1]]] = p
    return part


def _drift(part: np.ndarray, mesh, num_parts: int, frac: float = 0.06) -> np.ndarray:
    """Shift the slice boundaries by ``frac`` of a part — the moved-rows
    profile of an incremental re-slice answering load drift."""
    from repro.mesh import amr

    order = np.argsort(amr._pack(mesh.level, mesh.ij), kind="stable")
    shift = max(1, int(frac * mesh.n / num_parts))
    bounds = (np.arange(num_parts + 1) * mesh.n) // num_parts
    bounds[1:-1] = bounds[1:-1] + shift
    part2 = np.empty_like(part)
    for p in range(num_parts):
        part2[order[bounds[p] : bounds[p + 1]]] = p
    return part2


def _mesh_case(base_level: int, adapt_steps: int):
    from repro.mesh import amr

    mesh = amr.uniform_mesh(2, base_level, base_level + 2)
    for k in range(adapt_steps):
        c = amr.feature_center(0.3 + 0.2 * k, 2)
        ref, coar = amr.adapt_masks(mesh, c)
        mesh, _ = amr.refine_coarsen(mesh, ref, coar)
    nbr = amr.face_neighbors(mesh)
    coeff = amr.stencil_coeffs(mesh, nbr, amr.stable_dt(mesh))
    slot = np.arange(mesh.n, dtype=np.int64)
    return mesh, nbr, coeff, slot


def _median_time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _plans_equal(a, b) -> bool:
    arr = (
        "owned_idx", "owned_slot", "nbr_local", "nbr_valid", "coeff",
        "ghost_fetch", "interior_idx", "boundary_idx",
    )
    if any(not np.array_equal(getattr(a, f), getattr(b, f)) for f in arr):
        return False
    if (a.cap, a.gcap, a.axes, a.num_parts) != (b.cap, b.gcap, b.axes, b.num_parts):
        return False
    if a.stage_meta != b.stage_meta:
        return False
    return all(np.array_equal(sa.idx, sb.idx) for sa, sb in zip(a.stages, b.stages))


def _move_equal(a, b) -> bool:
    if (a.kind, a.axes, a.cap_old, a.cap_new, a.stage_meta) != (
        b.kind, b.axes, b.cap_old, b.cap_new, b.stage_meta
    ):
        return False
    if not np.array_equal(a.keep, b.keep):
        return False
    return all(np.array_equal(sa.idx, sb.idx) for sa, sb in zip(a.stages, b.stages))


def _compare_case(base_level: int, nodes: int, dev: int, reps: int = 5):
    """Vectorized vs legacy on one mesh: timings + bit-equality."""
    from repro.core import partitioner as pt
    from repro.mesh import halo

    hplan = pt.HierarchyPlan(num_nodes=nodes, devices_per_node=dev)
    mesh, nbr, coeff, slot = _mesh_case(base_level, adapt_steps=2)
    S = nodes * dev
    part = _sfc_partition(mesh, S)
    part2 = _drift(part, mesh, S)

    build_v = lambda p: halo.build_halo_plan(
        slot, p, nbr, coeff, hierarchy=hplan, with_metrics=False
    )
    build_l = lambda p: halo.build_halo_plan_legacy(
        slot, p, nbr, coeff, hierarchy=hplan, with_metrics=False
    )
    pv, pv2 = build_v(part), build_v(part2)
    pl, pl2 = build_l(part), build_l(part2)
    mv_v = halo.build_move_plan(pv, pv2, hierarchy=hplan)
    mv_l = halo.build_move_plan_legacy(pl, pl2, hierarchy=hplan)
    bit_equal = (
        _plans_equal(pv, pl) and _plans_equal(pv2, pl2) and _move_equal(mv_v, mv_l)
    )

    t_halo_v = _median_time(lambda: build_v(part), reps)
    t_halo_l = _median_time(lambda: build_l(part), max(reps // 2, 1))
    t_move_v = _median_time(
        lambda: halo.build_move_plan(pv, pv2, hierarchy=hplan), reps
    )
    t_move_l = _median_time(
        lambda: halo.build_move_plan_legacy(pl, pl2, hierarchy=hplan),
        max(reps // 2, 1),
    )
    return {
        "cells": mesh.n,
        "parts": S,
        "bit_equal": bit_equal,
        "halo_vec_s": t_halo_v,
        "halo_legacy_s": t_halo_l,
        "halo_build_speedup": t_halo_l / max(t_halo_v, 1e-9),
        "move_vec_s": t_move_v,
        "move_legacy_s": t_move_l,
        "move_build_speedup": t_move_l / max(t_move_v, 1e-9),
        "moved_rows": int(mv_v.migration.total_moved),
    }


def _large_case(base_level: int = 10, nodes: int = 8, dev: int = 8):
    """64 shards / ~1M cells, vectorized builders only (the legacy path
    is the wall this PR removes — it does not run here)."""
    from repro.core import partitioner as pt
    from repro.mesh import halo

    hplan = pt.HierarchyPlan(num_nodes=nodes, devices_per_node=dev)
    mesh, nbr, coeff, slot = _mesh_case(base_level, adapt_steps=0)
    S = nodes * dev
    part = _sfc_partition(mesh, S)
    part2 = _drift(part, mesh, S)
    t0 = time.perf_counter()
    pv = halo.build_halo_plan(slot, part, nbr, coeff, hierarchy=hplan, with_metrics=False)
    t_halo = time.perf_counter() - t0
    pv2 = halo.build_halo_plan(slot, part2, nbr, coeff, hierarchy=hplan, with_metrics=False)
    t0 = time.perf_counter()
    mv = halo.build_move_plan(pv, pv2, hierarchy=hplan)
    t_move = time.perf_counter() - t0
    return {
        "large_cells": mesh.n,
        "large_parts": S,
        "large_halo_build_s": t_halo,
        "large_move_build_s": t_move,
        "large_ghosts": int(
            pv.metrics["IntraNodeGhosts"] + pv.metrics["InterNodeGhosts"]
        ),
        "large_moved_rows": int(mv.migration.total_moved),
    }


def _rows_from(c: dict) -> list[tuple]:
    return [
        (
            f"plans/halo_vectorized/n={c['cells']}/S={c['parts']}",
            c["halo_vec_s"] * 1e6,
            f"bit_equal={c['bit_equal']};legacy_us={c['halo_legacy_s'] * 1e6:.1f};"
            f"speedup={c['halo_build_speedup']:.1f}x",
        ),
        (
            f"plans/move_vectorized/n={c['cells']}/S={c['parts']}",
            c["move_vec_s"] * 1e6,
            f"moved={c['moved_rows']};legacy_us={c['move_legacy_s'] * 1e6:.1f};"
            f"speedup={c['move_build_speedup']:.1f}x",
        ),
    ]


def bench_plans_rows() -> list[tuple]:
    """CSV rows (name, us_per_call, derived) — the smoke-size comparison."""
    return _rows_from(_compare_case(base_level=7, nodes=2, dev=4))


def smoke_main() -> int:
    c = _compare_case(base_level=7, nodes=2, dev=4)
    if c["halo_build_speedup"] <= 1.0 or c["move_build_speedup"] <= 1.0:
        # marginal box: one retry at 4x the cells, where the asymptotic
        # gap cannot be hidden by constant factors
        c = _compare_case(base_level=8, nodes=2, dev=4)
    rows = _rows_from(c)
    big = _large_case()
    rows.append(
        (
            f"plans/halo_vectorized/n={big['large_cells']}/S={big['large_parts']}",
            big["large_halo_build_s"] * 1e6,
            f"ghosts={big['large_ghosts']};"
            f"move_us={big['large_move_build_s'] * 1e6:.1f};legacy=not-run",
        )
    )
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    ok_bits = c["bit_equal"]
    ok_halo = c["halo_build_speedup"] > 1.0
    ok_move = c["move_build_speedup"] > 1.0
    ok_large = big["large_halo_build_s"] > 0 and big["large_cells"] >= 10**6
    passed = ok_bits and ok_halo and ok_move and ok_large
    if passed:
        print(
            f"PASS: vectorized plans bit-identical to legacy at "
            f"n={c['cells']}/S={c['parts']}; build speedup halo "
            f"{c['halo_build_speedup']:.1f}x, move "
            f"{c['move_build_speedup']:.1f}x; 64-shard/"
            f"{big['large_cells']}-cell halo plan built in "
            f"{big['large_halo_build_s'] * 1e3:.0f} ms (move "
            f"{big['large_move_build_s'] * 1e3:.0f} ms)"
        )
    else:
        print(
            f"FAIL: bit_equal={ok_bits}, "
            f"halo_speedup={c['halo_build_speedup']:.2f}x (need >1), "
            f"move_speedup={c['move_build_speedup']:.2f}x (need >1), "
            f"large_case_ok={ok_large}"
        )
    stats = {**c, **big}
    write_artifact("plans", stats, passed=passed, echo=True)
    return 0 if passed else 1


if __name__ == "__main__":
    if SMOKE:
        sys.exit(smoke_main())
    print("name,us_per_call,derived")
    for name, us, derived in bench_plans_rows():
        print(f"{name},{us:.1f},{derived}")
