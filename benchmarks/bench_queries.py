"""Query-serving benchmark: batched point-location / kNN throughput and
incremental index refresh vs cold build (paper §V-A economics).

Two claims measured on the same inputs:

* **Serving throughput** — batched exact point location and kNN through
  the `DistributedQueryEngine` (local path by default; set
  REPRO_BENCH_DIST=1 for the 8-fake-device sharded path with all_to_all
  query routing).
* **Refresh vs cold** — after a weight-only repartition step the engine's
  `curve_index()` refresh reuses cached keys + order (directory re-carve
  only) and must be >=5x cheaper than a cold `queries.build_index`
  (key-gen + sort + carve). Also reported: the memoized-hit cost (what a
  serving layer actually pays when nothing changed) and the refresh after
  a delta insert (re-carve over the re-sorted cached keys).
* **Skew robustness** (8+ devices; smoke forces 8 fake ones) — a
  Zipf-hot workload under a tight per-lane budget pays multi-round
  routing on the contiguous partition; replicating the hot buckets must
  recover >1x throughput with bit-equal answers (gated), plus request
  p50/p99 through the admission batcher and one elastic reshard
  (device-count change with zero cold rebuilds).

    PYTHONPATH=src python benchmarks/bench_queries.py [n] [q] [--smoke]
"""
import os
import sys
import time

import numpy as np

_SMOKE = "--smoke" in sys.argv
if _SMOKE or os.environ.get("REPRO_BENCH_DIST", "0") == "1":
    # before the jax import; append so user-provided flags survive — the
    # skew/elastic gates need 8 shards in BOTH CI smoke invocations
    _flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        ).strip()

import jax
import jax.numpy as jnp

from repro.core import partitioner as pt
from repro.core import queries
from repro.core.repartition import Repartitioner
from repro.runtime.elastic import ElasticServingController
from repro.serve.query_engine import DistributedQueryEngine, QueryRequest

SMOKE = _SMOKE
argv = [a for a in sys.argv[1:] if not a.startswith("--")]
N = int(argv[0]) if len(argv) > 0 else (20_000 if SMOKE else 200_000)
Q = int(argv[1]) if len(argv) > 1 else (2_048 if SMOKE else 16_384)
PARTS = 16
CFG = pt.PartitionerConfig(curve="morton")
MIN_REFRESH_SPEEDUP = 5.0


def timed(fn, *args, warmup=1, reps=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


LANE_ROWS = 16          # tight per-(src,dst) lane budget: skew hurts
ZIPF_TOPK = 12          # hot buckets to replicate


def skew_scenario(rp, rng):
    """Zipf-hot point-location on an 8-shard mesh: the fixed lane budget
    turns bucket skew into extra routing rounds; replicating the hottest
    buckets serves them from the local annex instead. Ends with one
    elastic device-count change (8 -> 6) under the live engine."""
    from repro.launch.mesh import make_mesh

    idx = rp.curve_index()
    mesh8 = make_mesh((8,), ("data",))
    eng = DistributedQueryEngine(idx, mesh8, "data",
                                 lane_rows=LANE_ROWS, hit_decay=1.0)

    # queries drawn from stored rows, buckets weighted Zipf(1) in a
    # random bucket order (hot set is adversarial, not curve-contiguous)
    B = idx.num_buckets
    starts = np.asarray(idx.bucket_starts)
    zipf = 1.0 / np.arange(1, B + 1)
    bw = np.zeros(B)
    bw[rng.permutation(B)] = zipf / zipf.sum()
    rows = []
    for b in rng.choice(B, min(Q, 4096), p=bw):
        lo, hi = int(starts[b]), int(starts[b + 1])
        if hi > lo:
            rows.append(int(rng.integers(lo, hi)))
    qz = jnp.asarray(np.asarray(idx.points)[rows], jnp.float32)
    ref = queries.point_location(idx, qz, bucket_cap=eng._scan_cap)

    t_contig = timed(lambda: eng.point_location(qz))
    r0 = eng.stats.route_rounds
    eng.point_location(qz)
    rounds_contig = eng.stats.route_rounds - r0

    hot = eng.replicate_hot(top_k=ZIPF_TOPK)
    t_repl = timed(lambda: eng.point_location(qz))
    r0 = eng.stats.route_rounds
    got = eng.point_location(qz)
    rounds_repl = eng.stats.route_rounds - r0
    bit_equal = all(
        bool(np.array_equal(np.asarray(a), np.asarray(b)))
        for a, b in zip(got, ref)
    )

    # request latency through the admission batcher (p50/p99)
    step = max(1, qz.shape[0] // 16)
    reqs = [QueryRequest(i, np.asarray(qz[o : o + step]), "pl")
            for i, o in enumerate(range(0, qz.shape[0], step))]
    eng.round_rows = 4 * step     # ~4 requests/round: latencies stagger
    eng.run(reqs)
    lat = np.asarray(eng.stats.request_latency_s)
    p50, p99 = float(np.percentile(lat, 50)), float(np.percentile(lat, 99))

    # elastic: shrink the serving pool 8 -> 6 under the live engine
    ctl = ElasticServingController(rp, eng, devices=jax.devices()[:8])
    ev = ctl.apply_device_change(jax.devices()[:6])
    got6 = eng.point_location(qz)
    elastic_equal = all(
        bool(np.array_equal(np.asarray(a), np.asarray(b)))
        for a, b in zip(got6, ref)
    )

    ratio = t_contig / max(t_repl, 1e-9)
    print(f"zipf pl (contiguous)        : {t_contig*1e3:8.2f} ms/batch  "
          f"rounds={rounds_contig}")
    print(f"zipf pl (hot replicated)    : {t_repl*1e3:8.2f} ms/batch  "
          f"rounds={rounds_repl}  {ratio:5.2f}x  hot={len(hot)}")
    print(f"zipf request latency        : p50 {p50*1e3:.2f} ms   "
          f"p99 {p99*1e3:.2f} ms")
    print(f"elastic reshard 8->6        : {ev.seconds*1e3:8.2f} ms  "
          f"moved={ev.moved_units}  rebuilds={ev.rebuilds_during}")
    return {
        "zipf_q": int(qz.shape[0]), "zipf_lane_rows": LANE_ROWS,
        "zipf_contig_s": t_contig, "zipf_repl_s": t_repl,
        "zipf_speedup": ratio,
        "zipf_rounds_contig": int(rounds_contig),
        "zipf_rounds_repl": int(rounds_repl),
        "zipf_p50_s": p50, "zipf_p99_s": p99,
        "zipf_bit_equal": bool(bit_equal and elastic_equal),
        "annex_served": int(eng.stats.annex_served),
        "elastic_reshard_s": ev.seconds,
        "elastic_rebuilds_during": int(ev.rebuilds_during),
    }


def main():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.random((N, 3)), jnp.float32)
    wts = jnp.asarray(0.5 + rng.random(N), jnp.float32)
    sel = rng.choice(N, Q, replace=True)
    q_hit = pts[jnp.asarray(sel)]
    q_rand = jnp.asarray(rng.random((Q, 3)), jnp.float32)

    extra_n = max(Q // 16, 1)
    rp = Repartitioner(pts, wts, PARTS, CFG, capacity=N + extra_n, max_depth=10)
    print(f"n={N} q={Q} parts={PARTS} curve={CFG.curve} "
          f"dist={os.environ.get('REPRO_BENCH_DIST', '0')}")

    # --- serving throughput ------------------------------------------------
    mesh = None
    if os.environ.get("REPRO_BENCH_DIST", "0") == "1" and len(jax.devices()) >= 8:
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
    eng = DistributedQueryEngine(rp.curve_index(), mesh, "data")

    t_pl = timed(lambda: eng.point_location(q_hit))
    t_knn = timed(lambda: eng.knn(q_rand, 3))
    label = "8-shard all_to_all" if mesh is not None else "local"
    print(f"point_location ({label:18s}): {t_pl*1e3:8.2f} ms/batch  "
          f"{Q/t_pl/1e6:8.2f} Mq/s")
    print(f"knn k=3        ({label:18s}): {t_knn*1e3:8.2f} ms/batch  "
          f"{Q/t_knn/1e6:8.2f} Mq/s")

    # --- incremental refresh vs cold build ---------------------------------
    def cold():
        idx = queries.build_index(pts, bucket_size=32)
        return idx.keys

    t_cold = timed(cold)

    # weight-only repartition step: cached keys/order untouched
    rp.update_weights(jnp.asarray(0.5 + rng.random(N), jnp.float32))
    rp.rebalance()

    def refresh():
        rp._index_cache = None  # force the real from_sorted work
        return rp.curve_index().keys

    t_refresh = timed(refresh)
    t_hit = timed(lambda: rp.curve_index().keys)  # memoized: the steady state

    # delta insert: key-gen for the batch only, then re-carve
    extra = jnp.asarray(rng.random((extra_n, 3)), jnp.float32)

    def insert_refresh():
        slots = rp.insert(extra, jnp.ones(extra.shape[0]))
        keys = rp.curve_index().keys
        rp.delete(slots)  # restore for the next rep
        return keys

    t_ins = timed(insert_refresh, warmup=1, reps=1)

    speedup = t_cold / max(t_refresh, 1e-9)
    print(f"cold build_index            : {t_cold*1e3:8.2f} ms")
    print(f"refresh (weight-only step)  : {t_refresh*1e3:8.2f} ms   {speedup:6.1f}x")
    print(f"refresh (memoized hit)      : {t_hit*1e6:8.2f} us")
    print(f"insert {extra.shape[0]:6d} + refresh     : {t_ins*1e3:8.2f} ms")

    # --- adversarial skew: contiguous vs hot-bucket-replicated -------------
    skew = skew_scenario(rp, rng) if len(jax.devices()) >= 8 else None
    zipf_ok = skew is None or (skew["zipf_speedup"] > 1.0 and skew["zipf_bit_equal"])

    try:
        from benchmarks._artifact import write_artifact
    except ImportError:
        from _artifact import write_artifact
    if speedup < MIN_REFRESH_SPEEDUP:
        print(f"WARNING: refresh speedup {speedup:.1f}x "
              f"< required {MIN_REFRESH_SPEEDUP}x")
    if not zipf_ok:
        print(f"WARNING: replication speedup {skew['zipf_speedup']:.2f}x "
              f"(need >1x with bit-equal answers)")
    # the BENCH_<name>.json summary is the FINAL stdout line (CI scrapes it)
    write_artifact(
        "queries" + ("_dist" if mesh is not None else ""),
        {
            "n": N, "q": Q, "parts": PARTS, "distributed": mesh is not None,
            "point_location_s": t_pl, "knn_s": t_knn,
            "cold_build_s": t_cold, "refresh_s": t_refresh,
            "memoized_hit_s": t_hit, "insert_refresh_s": t_ins,
            "refresh_speedup": speedup,
            **(skew or {}),
        },
        passed=speedup >= MIN_REFRESH_SPEEDUP and zipf_ok,
        echo=True,
    )
    return 0 if (speedup >= MIN_REFRESH_SPEEDUP and zipf_ok) else 1


if __name__ == "__main__":
    sys.exit(main())
