"""Query-serving benchmark: batched point-location / kNN throughput and
incremental index refresh vs cold build (paper §V-A economics).

Two claims measured on the same inputs:

* **Serving throughput** — batched exact point location and kNN through
  the `DistributedQueryEngine` (local path by default; set
  REPRO_BENCH_DIST=1 for the 8-fake-device sharded path with all_to_all
  query routing).
* **Refresh vs cold** — after a weight-only repartition step the engine's
  `curve_index()` refresh reuses cached keys + order (directory re-carve
  only) and must be >=5x cheaper than a cold `queries.build_index`
  (key-gen + sort + carve). Also reported: the memoized-hit cost (what a
  serving layer actually pays when nothing changed) and the refresh after
  a delta insert (re-carve over the re-sorted cached keys).

    PYTHONPATH=src python benchmarks/bench_queries.py [n] [q] [--smoke]
"""
import os
import sys
import time

import numpy as np

if os.environ.get("REPRO_BENCH_DIST", "0") == "1" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.core import partitioner as pt
from repro.core import queries
from repro.core.repartition import Repartitioner
from repro.serve.query_engine import DistributedQueryEngine

SMOKE = "--smoke" in sys.argv
argv = [a for a in sys.argv[1:] if not a.startswith("--")]
N = int(argv[0]) if len(argv) > 0 else (20_000 if SMOKE else 200_000)
Q = int(argv[1]) if len(argv) > 1 else (2_048 if SMOKE else 16_384)
PARTS = 16
CFG = pt.PartitionerConfig(curve="morton")
MIN_REFRESH_SPEEDUP = 5.0


def timed(fn, *args, warmup=1, reps=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.random((N, 3)), jnp.float32)
    wts = jnp.asarray(0.5 + rng.random(N), jnp.float32)
    sel = rng.choice(N, Q, replace=True)
    q_hit = pts[jnp.asarray(sel)]
    q_rand = jnp.asarray(rng.random((Q, 3)), jnp.float32)

    extra_n = max(Q // 16, 1)
    rp = Repartitioner(pts, wts, PARTS, CFG, capacity=N + extra_n, max_depth=10)
    print(f"n={N} q={Q} parts={PARTS} curve={CFG.curve} "
          f"dist={os.environ.get('REPRO_BENCH_DIST', '0')}")

    # --- serving throughput ------------------------------------------------
    mesh = None
    if os.environ.get("REPRO_BENCH_DIST", "0") == "1" and len(jax.devices()) >= 8:
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
    eng = DistributedQueryEngine(rp.curve_index(), mesh, "data")

    t_pl = timed(lambda: eng.point_location(q_hit))
    t_knn = timed(lambda: eng.knn(q_rand, 3))
    label = "8-shard all_to_all" if mesh is not None else "local"
    print(f"point_location ({label:18s}): {t_pl*1e3:8.2f} ms/batch  "
          f"{Q/t_pl/1e6:8.2f} Mq/s")
    print(f"knn k=3        ({label:18s}): {t_knn*1e3:8.2f} ms/batch  "
          f"{Q/t_knn/1e6:8.2f} Mq/s")

    # --- incremental refresh vs cold build ---------------------------------
    def cold():
        idx = queries.build_index(pts, bucket_size=32)
        return idx.keys

    t_cold = timed(cold)

    # weight-only repartition step: cached keys/order untouched
    rp.update_weights(jnp.asarray(0.5 + rng.random(N), jnp.float32))
    rp.rebalance()

    def refresh():
        rp._index_cache = None  # force the real from_sorted work
        return rp.curve_index().keys

    t_refresh = timed(refresh)
    t_hit = timed(lambda: rp.curve_index().keys)  # memoized: the steady state

    # delta insert: key-gen for the batch only, then re-carve
    extra = jnp.asarray(rng.random((extra_n, 3)), jnp.float32)

    def insert_refresh():
        slots = rp.insert(extra, jnp.ones(extra.shape[0]))
        keys = rp.curve_index().keys
        rp.delete(slots)  # restore for the next rep
        return keys

    t_ins = timed(insert_refresh, warmup=1, reps=1)

    speedup = t_cold / max(t_refresh, 1e-9)
    print(f"cold build_index            : {t_cold*1e3:8.2f} ms")
    print(f"refresh (weight-only step)  : {t_refresh*1e3:8.2f} ms   {speedup:6.1f}x")
    print(f"refresh (memoized hit)      : {t_hit*1e6:8.2f} us")
    print(f"insert {extra.shape[0]:6d} + refresh     : {t_ins*1e3:8.2f} ms")

    try:
        from benchmarks._artifact import write_artifact
    except ImportError:
        from _artifact import write_artifact
    if speedup < MIN_REFRESH_SPEEDUP:
        print(f"WARNING: refresh speedup {speedup:.1f}x "
              f"< required {MIN_REFRESH_SPEEDUP}x")
    # the BENCH_<name>.json summary is the FINAL stdout line (CI scrapes it)
    write_artifact(
        "queries" + ("_dist" if mesh is not None else ""),
        {
            "n": N, "q": Q, "parts": PARTS, "distributed": mesh is not None,
            "point_location_s": t_pl, "knn_s": t_knn,
            "cold_build_s": t_cold, "refresh_s": t_refresh,
            "memoized_hit_s": t_hit, "insert_refresh_s": t_ins,
            "refresh_speedup": speedup,
        },
        passed=speedup >= MIN_REFRESH_SPEEDUP,
        echo=True,
    )
    return 1 if speedup < MIN_REFRESH_SPEEDUP else 0


if __name__ == "__main__":
    sys.exit(main())
