"""Paper Tables II-VII: SFC vs row-wise partitions of power-law graphs.

SNAP datasets are unavailable offline; three synthetic power-law graphs
stand in for Google / Orkut / Twitter at reduced scale (same degree-law
shape, alpha=2.1). The qualitative claims under test: SFC partitions get
(a) near-perfect load balance, (b) MaxDegree far below the row-wise
P-1, (c) competitive-or-lower MaxEdgeCut, at sub-second partition time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import spmv

GRAPHS = {
    "google-like": dict(n=60_000, avg_degree=6, seed=10),
    "orkut-like": dict(n=90_000, avg_degree=12, seed=11),
    "twitter-like": dict(n=120_000, avg_degree=16, seed=12),
}


def bench_spmv_tables() -> list[tuple]:
    rows = []
    for gname, g in GRAPHS.items():
        src, dst = spmv.powerlaw_graph(**g)
        n = g["n"]
        for P in (16, 64, 256):
            prow = spmv.rowwise_partition(src, n, P)
            m_r = spmv.communication_metrics(prow, src, dst, n, P, improve=False)
            t0 = time.perf_counter()
            psfc = spmv.sfc_partition(src, dst, n, P)
            t_part = time.perf_counter() - t0
            m_s = spmv.communication_metrics(psfc, src, dst, n, P)
            rows.append(
                (
                    f"spmv/{gname}/P={P}/rowwise", 0.0,
                    f"AvgLoad={m_r['AvgLoad']};MaxLoad={m_r['MaxLoad']};"
                    f"MaxDegree={m_r['MaxDegree']};MaxEdgeCut={m_r['MaxEdgeCut']}",
                )
            )
            rows.append(
                (
                    f"spmv/{gname}/P={P}/sfc", t_part * 1e6,
                    f"AvgLoad={m_s['AvgLoad']};MaxLoad={m_s['MaxLoad']};"
                    f"MaxDegree={m_s['MaxDegree']};MaxEdgeCut={m_s['MaxEdgeCut']}",
                )
            )
    return rows


def bench_spmv_execution() -> list[tuple]:
    """Executable reduce-scatter SpMV vs dense oracle (correctness + time)."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_mesh

    rows = []
    src, dst = spmv.powerlaw_graph(30_000, 8, seed=13)
    n = 30_000
    rng = np.random.default_rng(0)
    vals = rng.random(src.shape[0]).astype(np.float32)
    x = jnp.asarray(rng.random(n), jnp.float32)
    P = min(8, jax.device_count())
    mesh = make_mesh((P,), ("parts",))
    part = spmv.sfc_partition(src, dst, n, P)
    t0 = time.perf_counter()
    y = spmv.distributed_spmv(mesh, "parts", src, dst, vals, part, x, n)
    y.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    yref = spmv.spmv_reference(src, dst, vals, x, n)
    err = float(jnp.max(jnp.abs(y - yref)))
    rows.append((f"spmv_exec/n=3e4/P={P}", us, f"max_err={err:.2e}"))
    return rows
