"""Render roofline.json + dryrun JSONs into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m benchmarks.roofline_report > roofline_table.md
"""
from __future__ import annotations

import json
import os


def main() -> None:
    rows = json.load(open("roofline.json")) if os.path.exists("roofline.json") else []
    print("### §Roofline table — 16x16 mesh, per (arch x shape)\n")
    print(
        "| arch | shape | t_compute s | t_memory s | t_collective s | dominant "
        "| MODEL/HLO flops | roofline fraction |"
    )
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if "error" in r:
            print(f"| {r['arch']} | {r['shape']} | error |  |  |  |  |  |")
            continue
        print(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |"
        )
    # one-line "what would move the dominant term" notes
    notes = {
        "compute": "triangular blockwise-attention schedule (causal skips) / head-count padding removal",
        "memory": "fuse decode cache streaming; larger decode batch per chip; bf16 optimizer bandwidth",
        "collective": "overlap FSDP all-gathers with layer compute (latency-hiding scheduler); hierarchical DCN reduce + int8 EF compression cross-pod",
    }
    print("\nDominant-term reduction notes: ")
    for k, v in notes.items():
        print(f"- **{k}**: {v}")

    for f, name in (("dryrun_16x16.json", "16x16 (256 chips)"), ("dryrun_2x16x16.json", "2x16x16 (512 chips)")):
        if not os.path.exists(f):
            continue
        rs = json.load(open(f))
        ok = sum(1 for r in rs if r.get("status") == "ok")
        print(f"\n### §Dry-run — mesh {name}: {ok} compiled / {len(rs)} cells\n")
        print("| arch | shape | peak GiB/dev | args GiB | temp GiB |")
        print("|---|---|---|---|---|")
        for r in rs:
            if r.get("status") == "ok":
                print(
                    f"| {r['arch']} | {r['shape']} | {r['peak_bytes']/2**30:.2f} | "
                    f"{r['argument_bytes']/2**30:.2f} | {r['temp_bytes']/2**30:.2f} |"
                )
            else:
                print(f"| {r['arch']} | {r['shape']} | {r['status'][:48]} |  |  |")


if __name__ == "__main__":
    main()
