"""Particle application layer: cutoff interaction lists, the fused pair
kernel, slot-tracked registration, and the distributed N-body / coupled
particle-mesh loops' bit-equality to their single-device references.

Local tests cover the host-side table construction and kernel physics;
the closed distributed loops run in a subprocess with 8 fake host
devices (see test_distributed.py for why the flag must be set before
jax initializes).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.particles import interact, state

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
        " --xla_backend_optimization_level=0"
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def _dense_table(n: int) -> np.ndarray:
    """The O(n^2) oracle table: every j != i, ascending, K = n-1 padded."""
    K = interact._roundup(n - 1, 8)
    nbr = np.full((n, K), -1, np.int32)
    for i in range(n):
        row = np.delete(np.arange(n, dtype=np.int32), i)
        nbr[i, : n - 1] = row
    return nbr


# ---------------------------------------------------------------------------
# cutoff neighbor lists vs the brute-force oracle
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    d=st.sampled_from([2, 3]),
    n=st.integers(24, 96),
    seed=st.integers(0, 7),
    radius=st.sampled_from([0.08, 0.12, 0.2, 0.35, 0.5]),
)
def test_cutoff_neighbors_complete_and_symmetric(d, n, seed, radius):
    """Every strictly-in-range pair appears (the probe-walk coverage
    claim), the table is symmetric, deterministic lane order holds, and
    no self pairs leak in."""
    rng = np.random.default_rng(seed)
    pos = rng.random((n, d)).astype(np.float32)
    nbr = interact.cutoff_neighbors(pos, radius)
    assert nbr.dtype == np.int32 and nbr.shape[0] == n and nbr.shape[1] % 8 == 0

    diff = pos[:, None, :].astype(np.float64) - pos[None, :, :].astype(np.float64)
    d2 = np.einsum("ijk,ijk->ij", diff, diff)
    in_range = (d2 < radius * radius) & ~np.eye(n, dtype=bool)

    pairs = {(i, int(j)) for i in range(n) for j in nbr[i] if j >= 0}
    for i, j in zip(*np.nonzero(in_range)):
        assert (int(i), int(j)) in pairs, "in-range pair missing from table"
    assert all((j, i) in pairs for (i, j) in pairs), "table not symmetric"
    assert all(i != j for (i, j) in pairs), "self pair leaked"
    for i in range(n):
        lane = nbr[i][nbr[i] >= 0]
        assert (np.diff(lane) > 0).all(), "lanes not in ascending id order"


@settings(max_examples=8, deadline=None)
@given(d=st.sampled_from([2, 3]), seed=st.integers(0, 7))
def test_cutoff_forces_match_dense_oracle(d, seed):
    """Accelerations through the cutoff table agree with the full O(n^2)
    table: out-of-range lanes weigh exactly 0, so only accumulation
    order can differ — allclose at float32 tightness."""
    rng = np.random.default_rng(seed)
    n, radius = 48, 0.3
    pos = rng.random((n, d)).astype(np.float32)
    mass = (0.5 + rng.random(n)).astype(np.float32)
    rc2 = np.float32(radius * radius)

    nbr = interact.cutoff_neighbors(pos, radius)
    dense = _dense_table(n)
    a_cut = np.asarray(interact._ops.pair_accel(
        pos, mass, pos, nbr, nbr >= 0, rc2))
    a_all = np.asarray(interact._ops.pair_accel(
        pos, mass, pos, dense, dense >= 0, rc2))
    np.testing.assert_allclose(a_cut, a_all, rtol=1e-5, atol=1e-6)


def test_cutoff_neighbors_rejects_bad_radius():
    pos = np.random.default_rng(0).random((8, 2)).astype(np.float32)
    for r in (0.0, -0.1, 0.6):
        with pytest.raises(ValueError, match="radius"):
            interact.cutoff_neighbors(pos, r)


# ---------------------------------------------------------------------------
# pair kernel physics
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(d=st.sampled_from([2, 3]), seed=st.integers(0, 7))
def test_pair_accel_antisymmetric_two_body(d, seed):
    """Equal masses, one pair: a_i is the exact bitwise negation of a_j
    (IEEE: (xj - xi) == -(xi - xj) and both rows see the identical d2)."""
    rng = np.random.default_rng(seed)
    pos = (0.45 + 0.1 * rng.random((2, d))).astype(np.float32)
    mass = np.full(2, np.float32(1.25))
    nbr = np.full((2, 8), -1, np.int32)
    nbr[0, 0], nbr[1, 0] = 1, 0
    acc = np.asarray(interact._ops.pair_accel(
        pos, mass, pos, nbr, nbr >= 0, np.float32(0.25)))
    assert np.array_equal(acc[0], -acc[1])
    assert (acc[0] != 0).any(), "pair out of range — test config broken"


@settings(max_examples=6, deadline=None)
@given(d=st.sampled_from([2, 3]), seed=st.integers(0, 7))
def test_pair_kick_conserves_momentum(d, seed):
    """General masses: the pairwise impulse m_i * a_i sums to ~0 (the
    force law is antisymmetric in (i, j), so momentum transfers cancel
    up to float32 accumulation)."""
    rng = np.random.default_rng(seed)
    n, radius = 64, 0.25
    pos = rng.random((n, d)).astype(np.float32)
    mass = (0.5 + rng.random(n)).astype(np.float32)
    nbr = interact.cutoff_neighbors(pos, radius)
    acc = np.asarray(interact._ops.pair_accel(
        pos, mass, pos, nbr, nbr >= 0, np.float32(radius * radius)))
    impulse = (mass[:, None].astype(np.float64) * acc.astype(np.float64)).sum(0)
    scale = np.abs(mass[:, None] * acc).sum()
    assert np.abs(impulse).max() <= 1e-5 * max(scale, 1.0)


def test_pair_accel_pallas_bit_equal_to_jnp():
    """The Pallas kernel (interpret mode) and the jnp fallback are the
    same expression — bit-equal on random tables, pads included, when
    compared in the same jit context (the executors' regime; eager
    dispatch would fuse fma differently and is not the contract)."""
    import jax

    fn = jax.jit(interact._ops.pair_accel, static_argnames=("use_pallas",))
    rng = np.random.default_rng(3)
    for d in (2, 3):
        n = 96
        pos = rng.random((n, d)).astype(np.float32)
        mass = (0.5 + rng.random(n)).astype(np.float32)
        nbr = interact.cutoff_neighbors(pos, 0.2)
        rc2 = np.float32(0.04)
        a_j = np.asarray(fn(pos, mass, pos, nbr, nbr >= 0, rc2,
                            use_pallas=False))
        a_p = np.asarray(fn(pos, mass, pos, nbr, nbr >= 0, rc2,
                            use_pallas=True))
        assert np.array_equal(a_j, a_p)


def test_leapfrog_momentum_drift_small_away_from_walls():
    """A short reference trajectory with generous wall clearance: total
    momentum (float64) drifts only at float32 accumulation scale."""
    ps = state.random_particles(128, 2, seed=5, v0=0.05, margin=0.35)
    nbr = interact.cutoff_neighbors(ps.pos, 0.15)
    x, v = interact.reference_leapfrog(
        ps.pos, ps.vel, ps.mass, nbr, 4, 0.005, 0.15)
    p0 = (ps.mass[:, None].astype(np.float64) * ps.vel.astype(np.float64)).sum(0)
    p1 = (ps.mass[:, None].astype(np.float64) * np.asarray(v, np.float64)).sum(0)
    assert np.abs(p1 - p0).max() <= 1e-4
    assert (np.asarray(x) >= 0).all() and (np.asarray(x) <= 1).all()


# ---------------------------------------------------------------------------
# slot-tracked registration
# ---------------------------------------------------------------------------

def test_particle_engine_reregisters_crossers_and_keeps_anchor_prefix():
    """Moving particles across part boundaries re-registers exactly the
    crossers through delete+insert, reuses only particle slots (anchors
    are never recycled), and leaves partition() consistent with the
    engine's own directory."""
    from repro.core import partitioner as pt
    from repro.mesh import halo

    rng = np.random.default_rng(0)
    n_anchor, n = 32, 96
    anchors = rng.random((n_anchor, 2)).astype(np.float32)
    ps = state.random_particles(n, 2, seed=1)
    pts = np.concatenate([anchors, ps.pos])
    eng = state.ParticleEngine(
        pts, np.ones(n_anchor + n, np.float32),
        plan=pt.HierarchyPlan(num_nodes=2, devices_per_node=4),
        n_anchor=n_anchor, capacity=2 * (n_anchor + n),
    )
    assert np.array_equal(eng.slots, np.arange(n_anchor + n))

    # drag a third of the particles into the far-x band — most cross.
    # (A band, not a point cluster: near-identical positions can share a
    # curve bucket that a re-slice cut later splits, making directory
    # ownership legitimately coarser than the per-slot assignment.)
    pos2 = ps.pos.copy()
    pos2[: n // 3, 0] = 0.85 + 0.13 * rng.random(n // 3).astype(np.float32)
    w = np.ones(n, np.float32)
    moved = eng.reregister(pos2, w)
    assert 0 < moved <= n // 3 + 5
    assert eng.registrations == 1 and eng.crossers_total == moved
    assert eng.particle_slots.min() >= n_anchor
    assert np.array_equal(eng.slots[:n_anchor], np.arange(n_anchor))
    assert np.unique(eng.slots).size == eng.slots.size

    # after the next engine step emits a fresh assignment (the driver's
    # sequencing), the directory view and the slot assignment agree up
    # to bucket granularity: the band's worth of crossers is re-homed,
    # leaving at most a cut-straddling-bucket residue. The detector is a
    # placement heuristic — trajectory bit-equality never depends on it.
    eng.step()
    idx = eng.rp.curve_index(eng.bucket_size)
    owner = halo.owners_from_index(idx, np.asarray(eng.rp.part), pos2)
    mismatch = int((owner != eng.rp.partition_of(eng.particle_slots)).sum())
    assert mismatch < moved // 2
    # a second pass re-registers only that residue, not the band again
    assert eng.reregister(pos2, w) == mismatch


# ---------------------------------------------------------------------------
# distributed execution (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

def test_distributed_nbody_bit_equal_both_drivers():
    out = _run("""
        import numpy as np
        from repro.core import partitioner as pt
        from repro.distributed import sharding as shd
        from repro.particles import simulate

        cfg = simulate.ParticleSimConfig(n=192, events=6, substeps=2)
        ref = simulate.run_reference(cfg)
        hplan = pt.HierarchyPlan(num_nodes=2, devices_per_node=4)
        mesh = shd.make_node_device_mesh(2, 4)
        for driver in ("incremental", "rebuild"):
            out, st = simulate.run_distributed(cfg, mesh, hplan, driver=driver)
            assert np.array_equal(ref.pos, out.pos), driver
            assert np.array_equal(ref.vel, out.vel), driver
            assert st.events == 6
            assert st.repartition_events >= 1
            assert st.registration_events >= 1 and st.crossers_total >= 1
        print("OK", st.repartition_events)
    """)
    assert "OK" in out


def test_distributed_pic_coupled_bit_equal():
    out = _run("""
        import numpy as np
        from repro.core import partitioner as pt
        from repro.distributed import sharding as shd
        from repro.particles import pic

        cfg = pic.PICSimConfig(n=128, events=5, substeps=2, mesh_level=3)
        u_ref, ps_ref = pic.run_reference_coupled(cfg)
        hplan = pt.HierarchyPlan(num_nodes=2, devices_per_node=4)
        mesh = shd.make_node_device_mesh(2, 4)
        u, ps, st = pic.run_distributed_coupled(
            cfg, mesh, hplan, driver="incremental")
        assert np.array_equal(u_ref, u)
        assert np.array_equal(ps_ref.pos, ps.pos)
        assert np.array_equal(ps_ref.vel, ps.vel)
        # mass is carried through every migration untouched
        assert np.array_equal(ps_ref.mass, ps.mass)
        assert st.n_cells == 64 and st.events == 5
        assert st.registration_events >= 1
        print("OK")
    """)
    assert "OK" in out
