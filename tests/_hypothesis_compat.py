"""Hypothesis compatibility shim for bare environments.

The property tests use only ``integers`` / ``floats`` / ``sampled_from``
strategies with ``@given`` + ``@settings``. When the real ``hypothesis``
package is installed we re-export it untouched and get full shrinking /
example databases. When it is absent (the minimal CI container), a tiny
fixed-example fallback runs each property on a deterministic seeded
sample of the strategy space, so the suite still collects and exercises
the invariants instead of erroring at import time.

The fallback deliberately runs fewer examples than hypothesis
(``REPRO_COMPAT_EXAMPLES``, default 4) because every distinct shape
triggers an XLA recompile; the full budget only pays off under real
hypothesis where shrinking needs it.
"""
from __future__ import annotations

import os

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _FALLBACK_EXAMPLES = int(os.environ.get("REPRO_COMPAT_EXAMPLES", "2"))

    class _Strategy:
        def __init__(self, sample_fn, label):
            self._sample = sample_fn
            self._label = label

        def sample(self, rng: random.Random):
            return self._sample(rng)

        def __repr__(self):
            return f"_Strategy({self._label})"

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            lo, hi = int(min_value), int(max_value)

            def sample(rng):
                # bias toward the endpoints: boundary values find the
                # off-by-one bugs that uniform draws usually miss
                r = rng.random()
                if r < 0.15:
                    return lo
                if r < 0.3:
                    return hi
                return rng.randint(lo, hi)

            return _Strategy(sample, f"integers({lo}, {hi})")

        @staticmethod
        def floats(min_value, max_value, **_kw):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(lambda rng: rng.uniform(lo, hi), f"floats({lo}, {hi})")

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: rng.choice(elems), f"sampled_from({elems})")

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")

    strategies = _Strategies()

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kw):
        def deco(fn):
            n_examples = min(
                getattr(fn, "_compat_max_examples", _FALLBACK_EXAMPLES),
                _FALLBACK_EXAMPLES,
            )

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for ex in range(n_examples):
                    # deterministic per-test, per-example seed
                    rng = random.Random(f"{fn.__name__}:{ex}")
                    drawn = {k: s.sample(rng) for k, s in strategy_kw.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:  # noqa: BLE001 - re-raise with context
                        raise AssertionError(
                            f"falsifying example ({fn.__name__}, #{ex}): {drawn!r}"
                        ) from e

            # hide strategy-drawn params from pytest's fixture resolution:
            # only the remaining (fixture) params stay in the signature
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items() if name not in strategy_kw]
            wrapper.__signature__ = sig.replace(parameters=keep)
            del wrapper.__wrapped__  # stop inspect from following to fn
            return wrapper

        return deco
