"""Mesh application layer: AMR invariants, halo-plan properties, and the
distributed stencil's bit-equality to the single-device reference.

Local tests cover the host-side mesh/plan machinery; the distributed
stencil + closed simulation loop run in a subprocess with 8 fake host
devices (see test_distributed.py for why the flag must be set before
jax initializes).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core import metrics, migration, partitioner
from repro.mesh import amr, halo

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
        " --xla_backend_optimization_level=0"
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def _adapted_mesh(d=2, rounds=2, base=3, maxl=5, cx=0.3):
    m = amr.uniform_mesh(d, base, maxl)
    for r in range(rounds):
        c = np.full((d,), 0.5)
        c[0] = cx + 0.1 * r
        m, _ = amr.refine_coarsen(
            m, *amr.adapt_masks(m, c, r_refine=0.18, r_coarsen=0.35)
        )
    return m


# ---------------------------------------------------------------------------
# AMR mesh invariants
# ---------------------------------------------------------------------------

def test_uniform_mesh_tiles_domain():
    for d in (2, 3):
        m = amr.uniform_mesh(d, 2, 4)
        assert m.n == (1 << (2 * d))
        assert m.volumes().sum() == pytest.approx(1.0, abs=0)
        nbr = amr.face_neighbors(m)
        # interior cells have exactly 2d same-level neighbors
        assert (nbr >= 0).sum(axis=1).max() == 2 * d
    # levels that would overflow the packed int64 cell key are rejected
    # up front (a d=3 level >= 8 aliases other cells' keys)
    with pytest.raises(ValueError, match="overflow"):
        amr.uniform_mesh(3, 2, 8)


@settings(max_examples=6, deadline=None)
@given(
    d=st.sampled_from([2, 3]),
    rounds=st.integers(1, 3),
    seed=st.integers(0, 5),
)
def test_refine_coarsen_invariants(d, rounds, seed):
    """Adaptation conserves the tiling exactly, keeps 2:1 balance, keeps
    the neighbor table symmetric, and its transfer conserves mass."""
    rng = np.random.default_rng(seed)
    m = amr.uniform_mesh(d, 2, 4)
    u = rng.random(m.n).astype(np.float32)
    for r in range(rounds):
        c = rng.random(d)
        m2, tr = amr.refine_coarsen(
            m, *amr.adapt_masks(m, c, r_refine=0.25, r_coarsen=0.45)
        )
        # exact dyadic tiling
        assert m2.volumes().sum() == 1.0
        # transfer covers every new cell and conserves volume-weighted mass
        assert (tr.cnt >= 1).all() and (tr.src[:, 0] >= 0).all()
        u2 = amr.apply_transfer(u, tr)
        mass = float((u.astype(np.float64) * m.volumes()).sum())
        mass2 = float((u2.astype(np.float64) * m2.volumes()).sum())
        assert mass2 == pytest.approx(mass, rel=1e-6)
        # cell-count bookkeeping: kept + born == new
        assert tr.born.sum() + (m.n - tr.died_idx.size) == m2.n
        m, u = m2, u2
    nbr = amr.face_neighbors(m)
    lv = m.level.astype(int)
    edges = set()
    for i in range(m.n):
        for j in nbr[i]:
            if j >= 0:
                assert abs(lv[i] - lv[int(j)]) <= 1  # 2:1 balance
                edges.add((i, int(j)))
    assert all((b, a) in edges for (a, b) in edges)  # symmetry


def test_stencil_coeffs_masked_and_stable():
    m = _adapted_mesh()
    nbr = amr.face_neighbors(m)
    dt = amr.stable_dt(float(m.sizes().min()))
    coeff = amr.stencil_coeffs(m, nbr, dt)
    assert coeff.shape == nbr.shape and coeff.dtype == np.float32
    assert (coeff[nbr < 0] == 0).all()
    # row sums bounded by 1 => explicit step is a convex combination
    assert coeff.sum(axis=1).max() <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# halo plans
# ---------------------------------------------------------------------------

def _plan_for(m, num_nodes=2, dev=4, weights=None):
    nbr = amr.face_neighbors(m)
    coeff = amr.stencil_coeffs(m, nbr, amr.stable_dt(float(m.sizes().min())))
    w = np.ones(m.n, np.float32) if weights is None else weights
    hplan = partitioner.HierarchyPlan(num_nodes=num_nodes, devices_per_node=dev)
    import jax.numpy as jnp

    res = partitioner.hierarchical_partition(
        jnp.asarray(m.centers()), jnp.asarray(w), hplan,
        partitioner.PartitionerConfig(use_tree=True, max_depth=8, bucket_size=8),
    )
    part = np.asarray(res.part)
    slots = np.arange(m.n, dtype=np.int64)
    plan = halo.build_halo_plan(
        slots, part, nbr, coeff, hierarchy=hplan, weights=w
    )
    return plan, part, nbr, hplan, slots


@settings(max_examples=4, deadline=None)
@given(rounds=st.integers(1, 2), nodes=st.sampled_from([1, 2]), seed=st.integers(0, 3))
def test_halo_ghost_sets_symmetric(rounds, nodes, seed):
    """i ghosts j's cells iff j sends them: every ghost_fetch entry is
    backed by exactly one staged send of the right cell, and every
    staged send is fetched by its requester — the plan's send and recv
    sides describe the same (owner, cell, requester) set."""
    rng = np.random.default_rng(seed)
    m = _adapted_mesh(rounds=rounds, cx=0.25 + 0.1 * rng.random())
    plan, part, nbr, hplan, slots = _plan_for(m, num_nodes=nodes, dev=8 // nodes)
    S = plan.owned_idx.shape[0]
    # replay the routing on host with cell ids as the payload
    owned_cells = np.where(plan.owned_idx >= 0, plan.owned_idx, -1)
    prev = owned_cells.astype(np.int64)  # (S, cap)
    for stg in plan.stages:
        buf = np.full((S, stg.lanes, stg.cap), -1, np.int64)
        for s in range(S):
            sel = stg.idx[s] >= 0
            buf[s][sel] = prev[s][np.maximum(stg.idx[s], 0)[sel]]
        # all_to_all: device s lane l slot t -> device group... emulate by
        # swapping within the axis groups
        recv = np.full((S, stg.lanes * stg.cap), -1, np.int64)
        if stg.axis == plan.axes[-1] and len(plan.axes) == 2:
            # device-axis exchange: my lane l goes to (node, l); I receive
            # block b from (node, b)'s lane dev_
            D = stg.lanes
            for s in range(S):
                node, dev_ = s // D, s % D
                for b in range(D):
                    recv[s, b * stg.cap:(b + 1) * stg.cap] = buf[node * D + b, dev_]
        elif len(plan.axes) == 2:
            N = stg.lanes
            D = S // N
            for s in range(S):
                node, dev_ = s // D, s % D
                for b in range(N):
                    recv[s, b * stg.cap:(b + 1) * stg.cap] = buf[b * D + dev_, node]
        else:
            for s in range(S):
                for b in range(S):
                    recv[s, b * stg.cap:(b + 1) * stg.cap] = buf[b, s]
        prev = recv
    # every requester fetches exactly the cells of its ghost set
    for p in range(S):
        nb = nbr[owned_cells[p][owned_cells[p] >= 0]]
        want = np.unique(nb[nb >= 0])
        want = set(want[part[want] != p].tolist())
        got = set()
        for g in range(plan.gcap):
            f = plan.ghost_fetch[p, g]
            if f >= 0:
                cell = prev[p, f]
                assert cell >= 0, "fetch points at an unstaged slot"
                got.add(int(cell))
        assert got == want


@settings(max_examples=4, deadline=None)
@given(rounds=st.integers(1, 2), seed=st.integers(0, 3))
def test_halo_conserves_cells_under_refine_coarsen(rounds, seed):
    """Owned sets tile the (changing) cell set: after every adaptation
    round, each cell appears in exactly one part's owned list and ghost
    lists reference only existing cells."""
    rng = np.random.default_rng(seed)
    m = amr.uniform_mesh(2, 3, 5)
    for r in range(rounds + 1):
        plan, part, nbr, hplan, slots = _plan_for(m)
        owned = plan.owned_idx[plan.owned_idx >= 0]
        assert owned.size == m.n
        assert np.array_equal(np.sort(owned), np.arange(m.n))
        # slot layout is ascending per device (the canonical merge order)
        for p in range(plan.owned_idx.shape[0]):
            s = plan.owned_slot[p][plan.owned_slot[p] >= 0]
            assert (np.diff(s) > 0).all()
        c = rng.random(2)
        m, _ = amr.refine_coarsen(
            m, *amr.adapt_masks(m, c, r_refine=0.2, r_coarsen=0.4)
        )


def test_halo_and_migration_stay_node_local_for_in_node_drift():
    """The feature drifting within ONE node's curve span: intra-node
    re-slices only, migration plans certify zero inter-node movement,
    and the move plan compiles to the device-axis-only hop."""
    import jax.numpy as jnp

    from repro.core.repartition import HierarchicalRepartitioner

    m = _adapted_mesh(rounds=1, base=4, maxl=5)
    nbr = amr.face_neighbors(m)
    coeff = amr.stencil_coeffs(m, nbr, amr.stable_dt(float(m.sizes().min())))
    hplan = partitioner.HierarchyPlan(num_nodes=2, devices_per_node=4)
    w0 = np.ones(m.n, np.float32)
    rp = HierarchicalRepartitioner(
        jnp.asarray(m.centers()), jnp.asarray(w0), plan=hplan,
        node_threshold=1.6, bucket_size=8,
    )
    slots = np.arange(m.n, dtype=np.int64)
    prev_plan = None
    saw_move = False
    for t in range(4):
        # mild drift confined to x < 0.35 — one node's half of the curve
        c = np.array([0.1 + 0.06 * t, 0.5])
        w = amr.feature_weights(m.centers(), c, amp=1.5, sigma=0.1)
        rp.update_weights(jnp.asarray(w), slot_ids=jnp.asarray(slots))
        step = rp.rebalance()
        assert step.level == "intra"
        assert isinstance(step.plan, migration.HierarchicalMigrationPlan)
        assert step.plan.inter_moved == 0
        assert step.plan.stay_fraction_node == 1.0
        part = np.asarray(step.part)[slots]
        plan = halo.build_halo_plan(slots, part, nbr, coeff, hierarchy=hplan)
        if prev_plan is not None:
            mv = halo.build_move_plan(prev_plan, plan, hierarchy=hplan)
            assert mv.kind in ("none", "device")  # no node-axis hop compiled
            assert mv.migration.inter_moved == 0
            saw_move = saw_move or mv.kind == "device"
        prev_plan = plan
    assert rp.stats.intra_reslices == 4 and rp.stats.inter_reslices == 0
    assert saw_move, "drift never moved a cell — test workload too mild"


def test_ghost_owners_resolved_through_curve_index_directory():
    """The halo layer's routing view — face-neighbor keys against the
    CurveIndex directory — agrees with the engine's direct per-slot
    assignment for every cell."""
    import jax.numpy as jnp

    from repro.core.repartition import HierarchicalRepartitioner

    m = _adapted_mesh(rounds=2, base=4, maxl=6)
    hplan = partitioner.HierarchyPlan(num_nodes=2, devices_per_node=4)
    w = amr.feature_weights(m.centers(), np.array([0.3, 0.5]))
    rp = HierarchicalRepartitioner(
        jnp.asarray(m.centers()), jnp.asarray(w), plan=hplan, bucket_size=8,
    )
    idx = rp.curve_index()
    part_by_slot = np.asarray(rp.part)
    owners = halo.owners_from_index(idx, part_by_slot, m.centers())
    direct = part_by_slot[np.arange(m.n)]
    np.testing.assert_array_equal(owners, direct)


def test_partition_of_validates_slots():
    import jax.numpy as jnp

    from repro.core.repartition import Repartitioner

    rng = np.random.default_rng(0)
    rp = Repartitioner(jnp.asarray(rng.random((256, 2)), jnp.float32), num_parts=4)
    part = rp.partition_of(np.arange(256))
    assert part.shape == (256,) and (part >= 0).all()
    with pytest.raises(ValueError, match="inactive"):
        rp.partition_of(np.array([rp.capacity - 1]))  # free slot
    with pytest.raises(ValueError, match="out of range"):
        rp.partition_of(np.array([-1]))  # would wrap to the tail slot


def test_simulate_rounds_hierarchical_caps_levels_independently():
    send = np.zeros((4, 4), np.int64)
    send[0, 1] = 10_000   # intra-node pair (D=2: parts 0,1 on node 0)
    send[0, 2] = 6_000    # inter-node pair
    plan = migration.plan_from_counts(
        send, max_msg_bytes=16 << 10, bytes_per_elem=16,
        hierarchy=partitioner.HierarchyPlan(2, 2, inter_node_cost=4.0),
    )
    rounds = migration.simulate_rounds(plan)
    assert len(rounds) == plan.rounds
    same = np.array([[True, True, False, False]] * 2 + [[False, False, True, True]] * 2)
    for r in rounds:
        assert r[same].max() <= plan.chunk
        assert r[~same].max() <= plan.inter_chunk
    assert sum(r.sum() for r in rounds) == 16_000


def test_spmv_metrics_delegate_to_shared_implementation():
    """Satellite regression: communication_metrics now reports through
    metrics.spanning_communication_metrics — same numbers as computing
    the structure by hand."""
    from repro.core import spmv

    src, dst = spmv.powerlaw_graph(2_000, 6, seed=3)
    P = 4
    part = spmv.rowwise_partition(src, 2_000, P)
    got = spmv.communication_metrics(part, src, dst, 2_000, P)
    bounds = spmv.vector_chunks(2_000, P)
    needs, prod = spmv._needs_matrix(part, src, dst, bounds, P)
    owner = spmv.improve_spanning_set(needs, prod, P)
    want = metrics.spanning_communication_metrics(part, needs, prod, owner, P)
    for k in ("AvgLoad", "MaxLoad", "MaxDegree", "MaxEdgeCut", "TotalVolume"):
        assert got[k] == want[k]


def test_surface_index_metric():
    si = metrics.surface_index(np.array([10, 20]), np.array([5, 5]))
    assert si["MaxSurfaceIndex"] == pytest.approx(0.5)
    assert si["TotalGhosts"] == 10


# ---------------------------------------------------------------------------
# distributed execution (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

def test_distributed_stencil_bit_equal_and_loop_closes():
    out = _run("""
        import numpy as np
        from repro.core import partitioner as pt
        from repro.distributed import sharding as shd
        from repro.mesh import simulate

        cfg = simulate.SimConfig(events=8, amr_every=3, substeps=2,
                                 base_level=3, max_level=5)
        events = simulate.build_trajectory(cfg)
        u0 = simulate.initial_field(events[0].mesh, cfg)
        uref = simulate.run_reference(events, u0, cfg.substeps)
        hplan = pt.HierarchyPlan(num_nodes=2, devices_per_node=4)
        mesh = shd.make_node_device_mesh(2, 4)
        for driver in ("incremental", "rebuild"):
            u, st = simulate.run_distributed(
                events, u0, cfg.substeps, mesh, hplan, driver=driver, cfg=cfg)
            assert np.array_equal(uref, u), (driver, np.abs(uref - u).max())
            assert st.events == 8 and st.amr_events == 2
            assert st.repartition_events >= 1
            # the plan cache sees every event; the t=0 build is a miss
            # and cache-path plans stayed bit-equal (or u would differ)
            assert st.plan_cache_misses >= 1
            assert st.plan_cache_hits + st.plan_cache_misses >= st.repartition_events
        print("OK", st.repartition_events)
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# overlapped stencil executor: plan split, compile caching, bit-equality
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(rounds=st.integers(1, 2), nodes=st.sampled_from([1, 2]), seed=st.integers(0, 3))
def test_halo_plan_interior_boundary_split(rounds, nodes, seed):
    """The plan's interior/boundary classification is a disjoint cover of
    the real rows, and interior rows provably read no ghosts: every
    valid nbr_local entry of an interior row is an owned slot (< cap)."""
    rng = np.random.default_rng(seed)
    m = _adapted_mesh(rounds=rounds, cx=0.25 + 0.1 * rng.random())
    plan, part, nbr, hplan, slots = _plan_for(m, num_nodes=nodes, dev=8 // nodes)
    S, cap = plan.owned_idx.shape
    for p in range(S):
        real = set(np.flatnonzero(plan.owned_idx[p] >= 0).tolist())
        interior = set(plan.interior_idx[p][plan.interior_idx[p] >= 0].tolist())
        boundary = set(plan.boundary_idx[p][plan.boundary_idx[p] >= 0].tolist())
        assert interior | boundary == real
        assert not (interior & boundary)
        for r in sorted(interior):
            nl, nv = plan.nbr_local[p, r], plan.nbr_valid[p, r]
            assert (nl[nv] < cap).all(), "interior row reads a ghost slot"
        for r in sorted(boundary):
            nl, nv = plan.nbr_local[p, r], plan.nbr_valid[p, r]
            assert (nl[nv] >= cap).any(), "boundary row reads no ghost"
    mets = plan.metrics
    assert mets["InteriorCells"] + mets["BoundaryCells"] == m.n


def test_stencil_executor_not_keyed_on_steps():
    """ONE compiled overlapped executor serves every sweep length (steps
    is traced through the fori_loop), while the pre-split baseline's
    cache is keyed on steps — and both stay bit-equal to the reference
    at every length."""
    import jax
    from repro.distributed import sharding as shd
    from repro.mesh import stencil as _st

    m = _adapted_mesh(rounds=1)
    plan, part, nbr, hplan, slots = _plan_for(m, num_nodes=1, dev=1)
    mesh = shd.make_node_device_mesh(1, 1)
    rng = np.random.default_rng(0)
    u0 = rng.standard_normal(m.n).astype(np.float32)
    coeff = amr.stencil_coeffs(m, nbr, amr.stable_dt(float(m.sizes().min())))
    args = _st.halo_args(mesh, plan)
    u_dev = _st.put_state(mesh, plan, u0)

    _st._stencil_fn.cache_clear()
    _st._stencil_fn_presplit.cache_clear()
    for steps in (1, 3, 5):
        ref = np.asarray(_st.reference_stencil(u0, nbr, nbr >= 0, coeff, steps))
        ov = plan.unpack_cells(
            np.asarray(_st.stencil_steps(mesh, plan, u_dev, args, steps)), m.n
        )
        ps = plan.unpack_cells(
            np.asarray(
                _st.stencil_steps(mesh, plan, u_dev, args, steps, overlap=False)
            ),
            m.n,
        )
        assert np.array_equal(ref, ov), steps
        assert np.array_equal(ref, ps), steps
    assert _st._stencil_fn.cache_info().misses == 1
    assert _st._stencil_fn_presplit.cache_info().misses == 3


def test_distributed_overlap_variants_bit_equal():
    """8-device mesh: the overlapped executor (jnp and Pallas row
    update) and the pre-split baseline all produce the reference bits
    on a real two-level plan with inter-node ghosts."""
    out = _run("""
        import numpy as np
        import jax.numpy as jnp
        from repro.core import partitioner as pt
        from repro.core.repartition import HierarchicalRepartitioner
        from repro.distributed import sharding as shd
        from repro.mesh import halo, simulate
        from repro.mesh import stencil as _st

        cfg = simulate.SimConfig(events=4, amr_every=0, substeps=2,
                                 base_level=3, max_level=5)
        ev = simulate.build_trajectory(cfg)[0]
        u0 = simulate.initial_field(ev.mesh, cfg)
        hplan = pt.HierarchyPlan(num_nodes=2, devices_per_node=4)
        mesh = shd.make_node_device_mesh(2, 4)
        rp = HierarchicalRepartitioner(
            jnp.asarray(ev.mesh.centers()), jnp.asarray(ev.weights),
            plan=hplan, cfg=pt.PartitionerConfig(use_tree=True, curve="hilbert"),
            capacity=2 * ev.mesh.n, bucket_size=cfg.bucket_size)
        slots = np.arange(ev.mesh.n, dtype=np.int64)
        plan = halo.build_halo_plan(
            slots, rp.partition_of(slots), ev.nbr, ev.coeff,
            hierarchy=hplan, weights=ev.weights)
        assert plan.metrics["BoundaryCells"] > 0
        args = _st.halo_args(mesh, plan)
        u_dev = _st.put_state(mesh, plan, u0)
        valid = ev.nbr >= 0
        for steps in (1, 3):
            ref = np.asarray(
                _st.reference_stencil(u0, ev.nbr, valid, ev.coeff, steps))
            for kw in ({}, {"use_pallas": True}, {"overlap": False}):
                got = plan.unpack_cells(np.asarray(
                    _st.stencil_steps(mesh, plan, u_dev, args, steps, **kw)),
                    ev.mesh.n)
                assert np.array_equal(ref, got), (steps, kw)
        print("OK")
    """)
    assert "OK" in out
