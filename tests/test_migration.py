"""Level-aware migration planning over block-structured count matrices.

`plan_from_counts(hierarchy=...)` treats the (P, P) count matrix as
N x N blocks of D x D (``part = node * D + device``): diagonal blocks
ride the intra-node fabric, off-block entries cross nodes. Properties
run through the hypothesis compat shim (fixed examples in bare
containers)."""
import numpy as np

from repro.core import migration
from repro.core.partitioner import HierarchyPlan

from _hypothesis_compat import given, settings, strategies as st


def _block_counts(rng: np.random.Generator, nodes: int, dpn: int,
                  intra_scale: int, inter_scale: int) -> np.ndarray:
    """Node-grouped matrix: heavy diagonal blocks (intra-node churn),
    lighter off-block mass (cross-node drift) — the shape a two-level
    re-slice produces."""
    P = nodes * dpn
    node_of = np.arange(P) // dpn
    same = node_of[:, None] == node_of[None, :]
    send = rng.integers(0, max(inter_scale, 1), (P, P))
    send[same] = rng.integers(0, max(intra_scale, 1), (P, P))[same]
    np.fill_diagonal(send, rng.integers(0, 10 * max(intra_scale, 1), P))
    return send.astype(np.int64)


@settings(max_examples=20, deadline=None)
@given(
    nodes=st.integers(1, 4),
    dpn=st.integers(1, 4),
    intra=st.integers(1, 5000),
    inter=st.integers(1, 5000),
    seed=st.integers(0, 10),
)
def test_hierarchical_plan_conserves_and_classifies(nodes, dpn, intra, inter, seed):
    rng = np.random.default_rng(seed)
    send = _block_counts(rng, nodes, dpn, intra, inter)
    hier = HierarchyPlan(nodes, dpn)
    plan = migration.plan_from_counts(send, hierarchy=hier)
    flat = migration.plan_from_counts(send)
    # conservation: every off-diagonal element is exactly one of
    # intra-node or inter-node, never both, never dropped
    assert plan.intra_moved + plan.inter_moved == flat.total_moved
    assert plan.total_moved == flat.total_moved
    stay = np.trace(send)
    assert plan.intra_moved + plan.inter_moved + stay == send.sum()
    # per-level stay fractions bracket correctly
    assert 0.0 <= plan.stay_fraction <= plan.stay_fraction_node <= 1.0
    # with one node there IS no inter level
    if nodes == 1:
        assert plan.inter_moved == 0 and plan.inter_rounds == 0


@settings(max_examples=20, deadline=None)
@given(
    nodes=st.integers(2, 4),
    dpn=st.integers(1, 4),
    max_msg=st.integers(64, 4096),
    seed=st.integers(0, 10),
)
def test_per_level_round_counts_are_exact(nodes, dpn, max_msg, seed):
    rng = np.random.default_rng(seed)
    send = _block_counts(rng, nodes, dpn, 3000, 800)
    hier = HierarchyPlan(nodes, dpn, inter_node_cost=2.0)
    plan = migration.plan_from_counts(
        send, hierarchy=hier, max_msg_bytes=max_msg, bytes_per_elem=16
    )
    # round capping is applied per level against each level's own chunk
    chunk = max(1, max_msg // 16)
    inter_chunk = max(1, int(max_msg / (16 * 2.0)))
    assert plan.chunk == chunk and plan.inter_chunk == inter_chunk
    exp_intra = -(-plan.max_intra_pair // chunk) if plan.max_intra_pair else 0
    exp_inter = -(-plan.max_inter_pair // inter_chunk) if plan.max_inter_pair else 0
    assert plan.intra_rounds == exp_intra
    assert plan.inter_rounds == exp_inter
    assert plan.rounds == max(exp_intra, exp_inter)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 20),
    lo=st.floats(1.0, 4.0),
)
def test_inter_node_cost_monotonicity(seed, lo):
    """Raising the inter-node cost multiplier can only increase the
    weighted cost and the inter-node round count, and touches neither
    the classification nor the intra level."""
    rng = np.random.default_rng(seed)
    send = _block_counts(rng, 2, 4, 2000, 1500)
    hier = HierarchyPlan(2, 4)
    plans = [
        migration.plan_from_counts(
            send, hierarchy=hier, inter_node_cost=m, max_msg_bytes=1 << 14
        )
        for m in (lo, 2 * lo, 8 * lo)
    ]
    costs = [p.cost() for p in plans]
    rounds = [p.inter_rounds for p in plans]
    assert costs == sorted(costs)
    if plans[0].inter_moved > 0:
        assert costs[0] < costs[-1]  # strictly: inter bytes exist
        assert rounds[0] <= rounds[-1]
    for p in plans:
        assert p.intra_rounds == plans[0].intra_rounds
        assert p.intra_moved == plans[0].intra_moved
        assert p.inter_moved == plans[0].inter_moved


def test_flat_plan_unchanged_without_hierarchy():
    """No hierarchy -> the historical MigrationPlan, byte-for-byte."""
    send = np.array([[5, 2], [3, 7]], np.int64)
    plan = migration.plan_from_counts(send, max_msg_bytes=32, bytes_per_elem=16)
    assert isinstance(plan, migration.MigrationPlan)
    assert plan.total_moved == 5 and plan.max_pair == 3
    assert plan.chunk == 2 and plan.rounds == 2


def test_hierarchy_shape_mismatch_raises():
    send = np.zeros((6, 6), np.int64)
    try:
        migration.plan_from_counts(send, hierarchy=HierarchyPlan(2, 4))
    except ValueError as e:
        assert "8 parts" in str(e)
    else:
        raise AssertionError("shape mismatch accepted")
