import os

# Tier-1 runs on CPU where XLA compile time dominates the suite (~2x the
# runtime). Optimization level 0 halves compile cost without changing any
# test outcome; set it before jax initializes its backend (conftest runs
# before test-module imports). Opt-out: REPRO_TEST_XLA_OPT=1.
if os.environ.get("REPRO_TEST_XLA_OPT", "0") != "1":
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_backend_optimization_level" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_backend_optimization_level=0"
        ).strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
