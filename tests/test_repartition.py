"""Incremental repartitioning engine (repro.core.repartition)."""
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic
from repro.core.repartition import Repartitioner


def _mk(rng, n=1024, parts=8, **kw):
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    w = jnp.asarray(1.0 + rng.random(n), jnp.float32)
    kw.setdefault("max_depth", 8)
    return pts, w, Repartitioner(pts, w, parts, **kw)


def _active_parts(rp):
    part = np.asarray(rp.part)
    act = np.asarray(rp.dps.active)
    return part, act


# --- cached-key reuse ---------------------------------------------------------

def test_incremental_matches_cold_rebuild(rng):
    """A weight-only incremental re-slice must produce exactly the parts a
    cold engine built from the same (points, weights) produces — cached
    keys change nothing about the result, only about the cost."""
    n = 1024
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    w0 = jnp.ones((n,), jnp.float32)
    w1 = jnp.asarray(1.0 + 3.0 * rng.random(n), jnp.float32)

    warm = Repartitioner(pts, w0, 8, max_depth=8)
    keygen_before = warm.stats.keygen_points
    warm.update_weights(w1)
    step = warm.rebalance()
    assert step.reused_keys and warm.stats.keygen_points == keygen_before

    cold = Repartitioner(pts, w1, 8, max_depth=8)
    np.testing.assert_array_equal(np.asarray(step.part), np.asarray(cold.part))


def test_weight_update_never_regenerates_keys(rng):
    _, _, rp = _mk(rng)
    before = rp.stats.keygen_points
    for i in range(5):
        rp.update_weights(jnp.asarray(1.0 + np.random.default_rng(i).random(1024), jnp.float32))
        rp.rebalance()
    assert rp.stats.keygen_points == before
    assert rp.stats.incremental_steps == 5


def test_insert_only_keygens_the_delta(rng):
    _, _, rp = _mk(rng)
    before = rp.stats.keygen_points
    rp.insert(jnp.asarray(rng.random((64, 3)), jnp.float32), jnp.ones(64, jnp.float32))
    assert rp.stats.keygen_points == before + 64  # delta batch only
    part, act = _active_parts(rp)
    assert rp.num_active() == 1024 + 64


def test_topology_version_tracks_point_population_only(rng):
    """`topology_version` is the plan caches' invalidation key: it must
    bump on insert/delete (the tracked population changed) and stay put
    across re-slices and rebuilds (same cells, new owners)."""
    _, _, rp = _mk(rng)
    assert rp.topology_version == 0
    rp.update_weights(jnp.asarray(1.0 + rng.random(1024), jnp.float32))
    rp.rebalance()
    assert rp.topology_version == 0          # re-slice: same population
    rp.rebuild()
    assert rp.topology_version == 0          # rebuild: same population
    slots = rp.insert(jnp.asarray(rng.random((16, 3)), jnp.float32),
                      jnp.ones(16, jnp.float32))
    assert rp.topology_version == 1
    rp.delete(slots[:4])
    assert rp.topology_version == 2


# --- amortized controller (Alg. 3) -------------------------------------------

def test_controller_triggers_rebuild_exactly_on_credit_exhaustion(rng):
    """Drive `step` with a scripted timeop sequence: the rebuild must fire
    on exactly the step where spent excess exceeds banked credits."""
    _, _, rp = _mk(rng, rebuild_cost=10.0)
    nb = int(dynamic.num_buckets(rp.dps))
    rp.controller.balanced(lb_cost=9.0, num_buckets=nb, timeop=1.0)
    # base cost = nb; timeop 1 + 2/nb costs nb+2 -> excess 2.0/step; credits 9
    kinds = [rp.step(timeop=1.0 + 2.0 / nb).kind for _ in range(5)]
    # delta after k steps: 2k; fires when 2k > 9 -> k=5 (and not before:
    # the credit boundary sits between integers, so float jitter is safe)
    assert kinds == ["incremental"] * 4 + ["rebuild"], kinds


def test_rebuild_rebanks_credits(rng):
    _, _, rp = _mk(rng, rebuild_cost=4.5)
    nb = int(dynamic.num_buckets(rp.dps))
    rp.controller.balanced(lb_cost=4.5, num_buckets=nb, timeop=1.0)
    # excess 1/step, credits 4.5 (a non-integer boundary, safe under float
    # jitter): first rebuild on the 5th step...
    fired = [rp.step(timeop=1.0 + 1.0 / nb).kind for _ in range(5)]
    assert fired == ["incremental"] * 4 + ["rebuild"], fired
    # ...and the cycle repeats after the rebuild re-banks credits
    nb2 = int(dynamic.num_buckets(rp.dps))
    base2 = rp.controller.base_timeop
    fired2 = [rp.step(timeop=base2 + 1.0 / nb2).kind for _ in range(5)]
    assert "rebuild" in fired2, fired2
    assert rp.stats.rebuilds >= 3  # constructor build + two credit exhaustions


def test_step_default_timeop_uses_live_imbalance(rng):
    """Without a measured timeop, sustained weight drift alone must
    eventually exhaust credits and trigger a rebuild."""
    n = 1024
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    rp = Repartitioner(pts, jnp.ones((n,), jnp.float32), 8, max_depth=8,
                       rebuild_cost=2.0)
    kinds = []
    for t in range(12):
        hot = np.zeros(n, np.float32)
        hot[: n // 4] = 40.0 * (t + 1)  # one region heats up without bound
        rp.update_weights(jnp.asarray(1.0 + hot))
        kinds.append(rp.step().kind)
    assert "rebuild" in kinds


# --- migration plans ----------------------------------------------------------

def test_migration_plans_conserve_elements(rng):
    _, _, rp = _mk(rng)
    w = 1.0 + 5.0 * rng.random(1024).astype(np.float32)
    rp.update_weights(jnp.asarray(w))
    step = rp.rebalance()
    send = step.plan.send_counts
    # every active element is accounted for exactly once in the send matrix
    assert send.sum() == rp.num_active()
    part, act = _active_parts(rp)
    new_loads = np.bincount(part[act], minlength=rp.num_parts)
    np.testing.assert_array_equal(send.sum(axis=0), new_loads)


def test_migration_restricted_to_neighbors_for_small_drift(rng):
    """Curve order is preserved, so a small weight delta moves elements
    only between rank-adjacent parts (paper's locality claim)."""
    from repro.core.migration import neighbor_locality

    _, w, rp = _mk(rng)
    rp.update_weights(w * jnp.asarray(1.0 + 0.05 * rng.random(1024), jnp.float32))
    step = rp.rebalance()
    if step.plan.total_moved:
        assert neighbor_locality(step.plan) == 1.0


def test_guards_reject_silent_corruption(rng):
    """The fixed-shape kernels silently misroute out-of-contract inputs
    (scatter into slot 0 / last slot), so the engine must reject them."""
    import pytest as _pytest

    _, _, rp = _mk(rng)
    with _pytest.raises(ValueError, match="exceeds free capacity"):
        rp.insert(jnp.asarray(rng.random((2000, 3)), jnp.float32),
                  jnp.ones(2000, jnp.float32))
    with _pytest.raises(ValueError, match="matches neither"):
        rp.update_weights(jnp.ones(100, jnp.float32))


def test_double_delete_is_noop(rng):
    _, _, rp = _mk(rng)
    rp.delete(jnp.arange(10))
    rp.delete(jnp.arange(10))           # repeat across calls
    rp.delete(jnp.asarray([20, 20, 20]))  # duplicates within one call
    assert rp.num_active() == 1024 - 11
    # tree counters track storage exactly (no unconditional decrements)
    assert int(rp.dps.tree.count[0]) == rp.num_active()


def test_insert_delete_keep_assignment_total(rng):
    _, _, rp = _mk(rng)
    slots = rp.insert(jnp.asarray(rng.random((100, 3)), jnp.float32),
                      jnp.ones(100, jnp.float32))
    rp.delete(slots[:50])
    rp.rebalance()
    part, act = _active_parts(rp)
    assert (part[act] >= 0).all()
    assert (part[~act] == -1).all()
    assert act.sum() == 1024 + 50
    # tree counters stayed consistent with storage
    assert int(rp.dps.tree.count[0]) == 1024 + 50


# --- full rebuild path --------------------------------------------------------

def test_rebuild_refreshes_frame_and_repairs_buckets():
    rng = np.random.default_rng(7)  # local: the repair bound depends on draws
    _, _, rp = _mk(rng, bucket_size=32)
    # dense burst into one region makes buckets heavy (0.3 wide: resolvable
    # within max_depth=8; narrower clusters legally stay heavy, see
    # dynamic.adjustments)
    burst = jnp.asarray(0.4 + 0.3 * rng.random((600, 3)), jnp.float32)
    rp.insert(burst, jnp.ones(600, jnp.float32))
    assert int(dynamic.max_bucket_occupancy(rp.dps)) > 2 * 32
    token_before = rp.cache_token
    step = rp.rebuild()
    assert step.kind == "rebuild" and not step.reused_keys
    assert rp.cache_token == token_before + 1  # cached keys invalidated
    assert int(dynamic.max_bucket_occupancy(rp.dps)) <= 2 * 32


# --- tree-backed mode (bucket-statistics substrate) ---------------------------

def _mk_tree(rng, n=1024, parts=8, **kw):
    from repro.core import partitioner as pt

    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    w = jnp.asarray(1.0 + rng.random(n), jnp.float32)
    kw.setdefault("max_depth", 8)
    cfg = pt.PartitionerConfig(use_tree=True)
    return pts, w, Repartitioner(pts, w, parts, cfg, **kw)


def test_tree_mode_never_keygens_points(rng):
    """The bucket substrate generates keys for O(B) bucket centroids
    only — across build, weight drift, insert, delete and rebuild, zero
    storage slots go through point key generation."""
    _, _, rp = _mk_tree(rng)
    assert rp.stats.keygen_points == 0 and rp.stats.keygen_buckets > 0
    rp.update_weights(jnp.asarray(1.0 + rng.random(1024), jnp.float32))
    rp.rebalance()
    slots = rp.insert(jnp.asarray(rng.random((64, 3)), jnp.float32),
                      jnp.ones(64, jnp.float32))
    rp.delete(slots[:16])
    rp.rebuild()
    assert rp.stats.keygen_points == 0
    assert rp.stats.summary_refreshes == 64 + 16  # dirtied deltas only


def test_tree_mode_points_follow_their_bucket(rng):
    _, w, rp = _mk_tree(rng)
    rp.update_weights(w * jnp.asarray(1.0 + 2.0 * rng.random(1024), jnp.float32))
    step = rp.rebalance()
    part = np.asarray(step.part)
    act = np.asarray(rp.dps.active)
    leaf = np.asarray(rp.dps.leaf_id)
    assert (part[act] >= 0).all() and (part[~act] == -1).all()
    for l in np.unique(leaf[act]):
        assert len(np.unique(part[act & (leaf == l)])) == 1
    # loads equal exact point-weight sums per part
    oracle = np.zeros(rp.num_parts)
    np.add.at(oracle, part[act], np.asarray(rp.dps.weights)[act])
    np.testing.assert_allclose(step.loads, oracle, rtol=1e-4)


def test_tree_mode_summary_tracks_deltas(rng):
    _, _, rp = _mk_tree(rng)
    s0 = rp.summary()
    assert int(np.asarray(s0.count).sum()) == 1024
    new = jnp.asarray(rng.random((50, 3)), jnp.float32)
    slots = rp.insert(new, jnp.full((50,), 2.0, jnp.float32))
    s1 = rp.summary()
    assert int(np.asarray(s1.count).sum()) == 1074
    np.testing.assert_allclose(
        float(np.asarray(s1.weight).sum()),
        float(np.asarray(s0.weight).sum()) + 100.0, rtol=1e-5,
    )
    rp.delete(slots)
    rp.delete(slots)  # double delete is a no-op in the summary too
    s2 = rp.summary()
    assert int(np.asarray(s2.count).sum()) == 1024
    np.testing.assert_allclose(
        float(np.asarray(s2.weight).sum()),
        float(np.asarray(s0.weight).sum()), rtol=1e-5,
    )
    # summaries agree with the tree's own counters at the leaves
    np.testing.assert_array_equal(
        np.asarray(s2.count).sum(), int(rp.dps.tree.count[0])
    )


def test_tree_mode_matches_cold_tree_engine(rng):
    """Weight-only drift: the incremental bucket re-slice must equal a
    cold tree-mode engine built from the same state (same tree, same
    bucket order => identical knapsack input)."""
    from repro.core import partitioner as pt

    n = 1024
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    w1 = jnp.asarray(1.0 + 3.0 * rng.random(n), jnp.float32)
    cfg = pt.PartitionerConfig(use_tree=True)
    warm = Repartitioner(pts, jnp.ones((n,), jnp.float32), 8, cfg, max_depth=8)
    warm.update_weights(w1)
    step = warm.rebalance()
    cold = Repartitioner(pts, w1, 8, cfg, max_depth=8)
    np.testing.assert_array_equal(np.asarray(step.part), np.asarray(cold.part))


def test_tree_mode_curve_index_serves_queries(rng):
    from repro.core import queries

    _, _, rp = _mk_tree(rng)
    slots = rp.insert(jnp.asarray(rng.random((32, 3)), jnp.float32),
                      jnp.ones(32, jnp.float32))
    rp.delete(slots[:8])
    v0 = rp.index_version
    idx = rp.curve_index()
    assert idx.tree is not None and int(idx.version) == v0
    assert rp.curve_index() is idx  # memoized per version
    act = np.asarray(rp.dps.active)
    live = np.flatnonzero(act)[:200]
    q = jnp.asarray(np.asarray(rp.dps.points)[live])
    found, ids, ok = queries.point_location(idx, q, bucket_cap=256)
    assert bool(np.asarray(found).all())
    # deleted slots are not found
    dq = jnp.asarray(np.asarray(rp.dps.points)[np.asarray(slots[:8])])
    f2, _, _ = queries.point_location(idx, dq, bucket_cap=2048)
    assert not bool(np.asarray(f2).any())
    # controller still drives incremental-vs-rebuild
    kind = rp.step().kind
    assert kind in ("incremental", "rebuild")


def test_pallas_key_cache_token_roundtrip(rng):
    """kernels.ops key cache: same token hits, bumped token misses."""
    from repro.kernels import ops

    pts = jnp.asarray(rng.random((256, 3)), jnp.float32)
    ops.invalidate_key_cache()
    k1 = ops.cached_sfc_key(pts, token=0, curve="morton")
    k2 = ops.cached_sfc_key(pts, token=0, curve="morton")
    assert k1 is k2  # cache hit returns the same buffer
    k3 = ops.cached_sfc_key(pts, token=1, curve="morton")
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k3))
    assert ops.invalidate_key_cache(0) == 1  # token-scoped invalidation
    assert ops.key_cache_stats()["entries"] == 1
    ops.invalidate_key_cache()
