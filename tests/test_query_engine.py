"""DistributedQueryEngine: knapsack-batched serving, live index swaps,
and (in a fake-device subprocess) sharded all_to_all query routing."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import queries
from repro.core.partitioner import PartitionerConfig
from repro.core.repartition import Repartitioner
from repro.serve.query_engine import DistributedQueryEngine, QueryRequest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MORTON = PartitionerConfig(curve="morton")


def _engine(rng, n=2048, **kw):
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    rp = Repartitioner(pts, None, num_parts=8, capacity=2 * n, cfg=MORTON)
    return pts, rp, DistributedQueryEngine(rp.curve_index(), None, **kw)


def test_local_serving_matches_queries(rng):
    pts, rp, eng = _engine(rng)
    q = pts[:256]
    got = eng.point_location(q)
    want = queries.point_location(rp.curve_index(), q)
    np.testing.assert_array_equal(np.asarray(got.found), np.asarray(want.found))
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    qq = jnp.asarray(rng.random((64, 3)), jnp.float32)
    d_a, g_a = eng.knn(qq, k=3)
    d_b, g_b = queries.knn(rp.curve_index(), qq, k=3, cutoff_buckets=1)
    np.testing.assert_allclose(np.asarray(d_a), np.asarray(d_b), atol=1e-6)


def test_knapsack_batched_run_serves_all(rng):
    pts, rp, eng = _engine(rng, max_batch_rows=512)
    sizes = [700, 30, 301, 1200, 64, 256, 17, 903]
    reqs = []
    for i, m in enumerate(sizes):
        if i % 2:
            reqs.append(QueryRequest(i, rng.random((m, 3)).astype(np.float32), "knn", k=3))
        else:
            sel = rng.choice(2048, m, replace=True)
            reqs.append(QueryRequest(i, np.asarray(pts)[sel], "pl"))
    res = eng.run(reqs)
    assert set(res) == set(r.rid for r in reqs)
    for r in reqs:
        if r.kind == "pl":
            assert res[r.rid].found.shape == (r.rows,)
            assert bool(res[r.rid].found.all())  # stored points all located
        else:
            d, g = res[r.rid]
            assert d.shape == (r.rows, 3) and np.isfinite(np.asarray(d)).all()
    # admission actually split the queue into multiple balanced rounds
    assert eng.stats.rounds > 1
    assert eng.stats.queries_served == sum(sizes)


def test_submit_mid_flight_is_served(rng):
    """Work appended to the engine's live queue before/while running is
    admitted and answered — never silently dropped."""
    pts, rp, eng = _engine(rng, max_batch_rows=128)
    eng.submit([QueryRequest(100, np.asarray(pts[:50]), "pl")])
    res = eng.run([QueryRequest(101, rng.random((40, 3)).astype(np.float32), "knn")])
    assert set(res) == {100, 101}
    assert bool(res[100].found.all())
    assert not eng.queue  # drained


def test_duplicate_requests_do_not_crash(rng):
    """list.remove on the pending queue must match by identity — with
    dataclass __eq__, same-shaped ndarray fields raise ValueError."""
    pts, rp, eng = _engine(rng, max_batch_rows=64)
    q = rng.random((96, 3)).astype(np.float32)
    reqs = [QueryRequest(7, q.copy()), QueryRequest(7, rng.random((96, 3)).astype(np.float32))]
    res = eng.run(reqs)  # duplicates overwrite; must not raise
    assert 7 in res


def test_live_version_swap(rng):
    pts, rp, eng = _engine(rng)
    v0 = eng.version
    assert not eng.maybe_refresh(rp)  # fresh: no swap
    new_pts = jnp.asarray(rng.random((100, 3)), jnp.float32)
    slots = rp.insert(new_pts, jnp.ones(100))
    assert eng.maybe_refresh(rp)      # stale after geometry change
    assert eng.version == rp.index_version != v0
    f = eng.point_location(new_pts)
    assert bool(f.found.all())
    assert set(np.asarray(f.ids).tolist()) == set(np.asarray(slots).tolist())


def test_distributed_routing_subprocess():
    """Sharded serving on 8 fake devices: exact point location through
    the two-all_to_all route, certified misses, kNN recall, live swap."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8"
        " --xla_backend_optimization_level=0"
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import queries
        from repro.core.partitioner import PartitionerConfig
        from repro.core.repartition import Repartitioner
        from repro.launch.mesh import make_mesh
        from repro.serve.query_engine import DistributedQueryEngine
        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(3)
        n = 4096
        pts_h = rng.random((n, 3)).astype(np.float32)
        pts_h[: n // 2] = 0.45 + 0.1 * pts_h[: n // 2]   # routing skew
        pts = jnp.asarray(pts_h)
        rp = Repartitioner(pts, None, num_parts=8, capacity=n,
                           cfg=PartitionerConfig(curve='morton'))
        eng = DistributedQueryEngine(rp.curve_index(), mesh, 'data')
        # exact point location across shards (odd batch exercises padding)
        sel = rng.choice(n, 511, replace=False)
        q = pts[jnp.asarray(sel)]
        f, ids, ok = eng.point_location(q)
        assert bool(f.all()), int(f.sum())
        np.testing.assert_array_equal(np.asarray(pts)[np.asarray(ids)], np.asarray(q))
        # misses stay certified misses
        f2, i2, ok2 = eng.point_location(jnp.asarray(rng.random((128, 3)) + 2.0, jnp.float32))
        assert not bool(f2.any()) and bool(ok2.all())
        # kNN recall vs bruteforce + self-query exactness
        qq = jnp.asarray(rng.random((256, 3)), jnp.float32)
        d_e, g_e = eng.knn(qq, k=3)
        d_b, g_b = queries.knn_bruteforce(pts, qq, k=3)
        recall = float(np.mean(np.any(
            np.asarray(g_e)[:, :, None] == np.asarray(g_b)[:, None, :], axis=1)))
        assert recall > 0.6, recall
        d_s, _ = eng.knn(q[:64], k=1)
        assert float(np.asarray(d_s).max()) <= 1e-6
        # live swap after a full rebuild (fresh keys, fresh frame)
        rp.update_weights(jnp.asarray(0.5 + rng.random(n), jnp.float32))
        rp.rebuild()
        assert eng.maybe_refresh(rp)
        f3, i3, ok3 = eng.point_location(q)
        assert bool(f3.all())
        print('OK recall', recall)
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout


def test_tree_backed_index_serves_locally(rng):
    """Local serving path with a tree-backed index: swap must accept it
    (regression — it used to raise ValueError) and the run-scan cap must
    widen to the real max bucket length."""
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    rp = Repartitioner(pts, None, num_parts=8, capacity=4096,
                       cfg=PartitionerConfig(curve="morton", use_tree=True))
    idx = rp.curve_index()
    assert idx.tree is not None
    eng = DistributedQueryEngine(idx, None)      # no ValueError
    got = eng.point_location(pts[:256])
    want = queries.point_location(idx, pts[:256], bucket_cap=eng._scan_cap)
    np.testing.assert_array_equal(np.asarray(got.found), np.asarray(want.found))
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    assert bool(got.found.all())


def test_replicate_hot_requires_mesh(rng):
    pts, rp, eng = _engine(rng)
    with pytest.raises(ValueError):
        eng.replicate_hot(4)


def test_admission_queue_rejects_overflow(rng):
    pts, rp, eng = _engine(rng, max_queue_rows=200)
    ok = QueryRequest(1, rng.random((150, 3)).astype(np.float32), "pl")
    big = QueryRequest(2, rng.random((100, 3)).astype(np.float32), "pl")
    rejected = eng.submit([ok, big])             # 150 + 100 > 200
    assert rejected == [big] and eng.queue == [ok]
    assert eng.stats.rejected_requests == 1
    assert eng.stats.rejected_rows == 100
    res = eng.run([])                            # queue drains, bound frees
    assert set(res) == {1}
    assert eng.submit([big]) == []               # admitted now
    assert set(eng.run([])) == {2}


def test_adaptive_round_rows_and_latency_stats(rng):
    pts, rp, eng = _engine(
        rng, max_batch_rows=1024, min_batch_rows=64, target_round_s=1e-9
    )
    reqs = [QueryRequest(i, rng.random((200, 3)).astype(np.float32), "pl")
            for i in range(4)]
    res = eng.run(reqs)
    assert set(res) == {0, 1, 2, 3}
    # an absurdly tight latency target drives the round budget to the floor
    assert eng.round_rows == eng.min_batch_rows
    assert len(eng.stats.request_latency_s) == 4
    assert all(t >= 0.0 for t in eng.stats.request_latency_s)


def test_tree_backed_and_skew_replication_subprocess():
    """The headline fix plus the skew machinery on 8 fake devices:

    * a tree-backed (kd-bucket ordered) index serves on a mesh and
      matches the local tree walk bit for bit — hits, misses, certs;
    * Zipf-hot queries under a tight lane budget take many routing
      rounds; replicating the hot buckets collapses them and the annex
      answers are bit-identical;
    * padding rows never pollute the hit counters.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8"
        " --xla_backend_optimization_level=0"
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import queries
        from repro.core.partitioner import PartitionerConfig
        from repro.core.repartition import Repartitioner
        from repro.launch.mesh import make_mesh
        from repro.serve.query_engine import DistributedQueryEngine

        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(7)
        n = 4096
        pts_h = rng.random((n, 2)).astype(np.float32)
        pts_h[:64] = pts_h[0]        # duplicate run: key collisions
        pts = jnp.asarray(pts_h)

        # --- tree-backed index on the mesh vs the local tree walk -------
        rp = Repartitioner(pts, None, num_parts=8, capacity=n,
                           cfg=PartitionerConfig(curve='hilbert', use_tree=True))
        idx = rp.curve_index(32)
        assert idx.tree is not None
        eng = DistributedQueryEngine(idx, mesh, 'data', bucket_cap=32,
                                     hit_decay=1.0)
        sel = rng.choice(n, 300, replace=False)
        q = jnp.concatenate([pts[jnp.asarray(sel)],
                             jnp.asarray(rng.random((211, 2)) + 1.5, jnp.float32)])
        ref = queries.point_location(idx, q, bucket_cap=eng._scan_cap)
        got = eng.point_location(q)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # padding rows (511 -> 512) never reach the hit counters
        assert float(eng.bucket_hits.sum()) == float(q.shape[0])
        print('OK tree-backed')

        # --- Zipf skew: bounded lanes, then hot-bucket replication ------
        eng2 = DistributedQueryEngine(idx, mesh, 'data', bucket_cap=32,
                                      lane_rows=16, hit_decay=1.0)
        B = idx.num_buckets
        zipf = 1.0 / np.arange(1, B + 1)
        hot_bucket = rng.permutation(B)
        bw = np.zeros(B); bw[hot_bucket] = zipf / zipf.sum()
        starts = np.asarray(idx.bucket_starts)
        rows = []
        for b in rng.choice(B, 1024, p=bw):
            lo, hi = int(starts[b]), int(starts[b + 1])
            if hi > lo:
                rows.append(int(rng.integers(lo, hi)))
        qz = jnp.asarray(np.asarray(idx.points)[rows], jnp.float32)
        refz = queries.point_location(idx, qz, bucket_cap=eng2._scan_cap)

        gz = eng2.point_location(qz)
        rounds_contig = eng2.stats.route_rounds
        for a, b in zip(gz, refz):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert rounds_contig > 1     # lane overflow forced re-dispatch

        hot = eng2.replicate_hot(top_k=12)
        assert hot and eng2.stats.replications == 1
        gz2 = eng2.point_location(qz)
        rounds_repl = eng2.stats.route_rounds - rounds_contig
        for a, b in zip(gz2, refz):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert eng2.stats.annex_served > 0
        assert rounds_repl < rounds_contig
        eng2.replicate_hot(top_k=0)  # clears the annex
        gz3 = eng2.point_location(qz)
        for a, b in zip(gz3, refz):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('OK skew', rounds_contig, rounds_repl,
              int(eng2.stats.annex_served))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK tree-backed" in out.stdout and "OK skew" in out.stdout


def test_lane_subset_replication_subprocess():
    """``replicate_hot(shards=...)`` on 8 fake devices: lane-hit
    counters see the skewed traffic, a top-k lane subset annex serves
    only those lanes' queries bit-equal to the reference, to the
    engine-wide annex, and to plain routing; explicit lane ids work;
    and a reshard drops the placement-addressed subset annex."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8"
        " --xla_backend_optimization_level=0"
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import queries
        from repro.core.partitioner import PartitionerConfig
        from repro.core.repartition import Repartitioner
        from repro.launch.mesh import make_mesh
        from repro.serve.query_engine import DistributedQueryEngine

        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(11)
        n = 4096
        pts_h = rng.random((n, 2)).astype(np.float32)
        pts = jnp.asarray(pts_h)
        rp = Repartitioner(pts, None, num_parts=8, capacity=n,
                           cfg=PartitionerConfig(curve='hilbert', use_tree=True))
        idx = rp.curve_index(32)

        def fresh():
            return DistributedQueryEngine(idx, mesh, 'data', bucket_cap=32,
                                          lane_rows=16, hit_decay=1.0)

        # Zipf-hot traffic concentrated on a few buckets -> a few lanes
        B = idx.num_buckets
        zipf = 1.0 / np.arange(1, B + 1) ** 1.5
        hot_bucket = rng.permutation(B)
        bw = np.zeros(B); bw[hot_bucket] = zipf / zipf.sum()
        starts = np.asarray(idx.bucket_starts)
        rows = []
        for b in rng.choice(B, 1024, p=bw):
            lo, hi = int(starts[b]), int(starts[b + 1])
            if hi > lo:
                rows.append(int(rng.integers(lo, hi)))
        qz = jnp.asarray(np.asarray(idx.points)[rows], jnp.float32)
        ref = queries.point_location(idx, qz, bucket_cap=fresh()._scan_cap)

        def check(eng):
            got = eng.point_location(qz)
            for a, b in zip(got, ref):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            return got

        # 1) warm the counters, then annex the 2 hottest lanes only
        eng = fresh()
        check(eng)
        assert float(eng.lane_hits.sum()) == float(qz.shape[0])
        hot_lanes = np.argsort(eng.lane_hits)[::-1][:2]
        assert eng.replicate_hot(top_k=12, shards=2)
        assert set(eng._hot['lanes']) == set(int(l) for l in hot_lanes)
        served0 = eng.stats.annex_served
        check(eng)
        assert eng.stats.annex_served > served0
        # only selected lanes' copies exist, on those lanes' devices
        devs = eng._lane_devices()
        for l, copy in eng._hot['copies'].items():
            assert copy[0].devices() == {devs[l]}

        # 2) subset answers == engine-wide annex answers (bit-equal)
        eng_full = fresh()
        check(eng_full)
        eng_full.replicate_hot(top_k=12)
        check(eng_full)
        assert eng_full.stats.annex_served > 0
        assert eng_full._hot['lanes'] is None

        # 3) explicit lane ids; out-of-range rejected
        eng2 = fresh()
        check(eng2)
        assert eng2.replicate_hot(top_k=12, shards=[int(hot_lanes[0])])
        served0 = eng2.stats.annex_served
        check(eng2)
        assert eng2.stats.annex_served > served0
        try:
            eng2.replicate_hot(top_k=12, shards=[99])
        except ValueError:
            pass
        else:
            raise AssertionError('bad lane id accepted')

        # 4) reshard drops the placement-addressed subset annex but
        #    keeps serving correct; shards=0 selects no lanes
        eng2.reshard(mesh, 'data')
        assert eng2._hot is None
        check(eng2)
        assert eng2.replicate_hot(top_k=12, shards=0) == []
        check(eng2)
        print('OK lane subset', int(eng.stats.annex_served))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK lane subset" in out.stdout
