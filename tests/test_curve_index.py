"""The shared versioned CurveIndex: one key/bucket structure for queries,
repartitioning, and the partitioner (ISSUE 2 tentpole)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import curve_index as ci
from repro.core import partitioner as pt
from repro.core import queries
from repro.core.repartition import Repartitioner

MORTON = pt.PartitionerConfig(curve="morton")


def _cold_index_of(rp):
    """Cold-build an index over the engine's active slots, with slot ids
    and the engine's frozen frame — the oracle a refresh must agree with."""
    act = np.nonzero(np.asarray(rp.dps.active))[0]
    return ci.build(
        rp.dps.points[jnp.asarray(act)],
        jnp.asarray(act, jnp.int32),
        frame=(rp._frame_lo, rp._frame_hi),
        bits=rp.bits,
        curve=rp.cfg.curve,
    )


def _assert_queries_agree(idx_a, idx_b, q, pts_by_slot):
    fa = queries.point_location(idx_a, q)
    fb = queries.point_location(idx_b, q)
    np.testing.assert_array_equal(np.asarray(fa.found), np.asarray(fb.found))
    np.testing.assert_array_equal(np.asarray(fa.ids), np.asarray(fb.ids))
    da, ga = queries.knn(idx_a, q, k=3, cutoff_buckets=2)
    db, gb = queries.knn(idx_b, q, k=3, cutoff_buckets=2)
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), atol=1e-6)


def test_build_matches_queries_build_index(rng):
    pts = jnp.asarray(rng.random((1024, 3)), jnp.float32)
    a = ci.build(pts, bucket_size=32)
    b = queries.build_index(pts, bucket_size=32)
    np.testing.assert_array_equal(np.asarray(a.keys), np.asarray(b.keys))
    np.testing.assert_array_equal(np.asarray(a.bucket_starts), np.asarray(b.bucket_starts))
    assert a.bits == b.bits and a.curve == b.curve == "morton"
    assert int(a.valid_count()) == 1024


def test_from_partition_shares_keys_and_boundaries(rng):
    """partition_with_index: one key generation feeds both the partition
    and the query index; slice boundaries map onto the directory."""
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    w = jnp.asarray(0.5 + rng.random(2048), jnp.float32)
    res, idx = pt.partition_with_index(pts, w, 16, MORTON, bucket_size=32)
    # the index holds exactly the partition's keys, in the partition's order
    np.testing.assert_array_equal(
        np.asarray(idx.keys), np.asarray(res.keys)[np.asarray(res.perm)]
    )
    np.testing.assert_array_equal(np.asarray(idx.ids), np.asarray(res.perm))
    # directory buckets -> owning part: non-decreasing, full coverage
    bp = np.asarray(ci.bucket_parts(idx, res.boundaries))
    assert (np.diff(bp) >= 0).all()
    assert bp.min() == 0 and bp.max() == 15
    # bucket_parts agrees with the per-element assignment at bucket starts
    part_sorted = np.asarray(res.part)[np.asarray(res.perm)]
    np.testing.assert_array_equal(bp, part_sorted[np.asarray(idx.bucket_starts[:-1])])
    # and the index serves queries
    f = queries.point_location(idx, pts[:128])
    assert bool(f.found.all())
    np.testing.assert_array_equal(np.asarray(pts)[np.asarray(f.ids)], np.asarray(pts[:128]))


def test_rank_stats_rejected():
    pts = jnp.zeros((64, 3), jnp.float32)
    with pytest.raises(ValueError):
        pt.partition_with_index(pts, None, 4, pt.PartitionerConfig(stats="rank"))


def test_refresh_reuses_cached_keys(rng):
    """curve_index() must be the incremental path: no key generation, and
    the weight-only steady state is a memoized hit."""
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    rp = Repartitioner(pts, None, num_parts=8, capacity=4096, cfg=MORTON)
    kg0 = rp.stats.keygen_points
    i0 = rp.curve_index()
    assert rp.stats.keygen_points == kg0  # refresh generated no keys
    assert rp.curve_index() is i0         # memoized per version
    assert int(i0.version) == rp.index_version
    assert int(i0.token) == rp.cache_token
    # weight-only: no invalidation
    rp.update_weights(jnp.asarray(rng.random(2048), jnp.float32) + 0.5)
    rp.rebalance()
    assert rp.curve_index() is i0
    # the index's sorted keys ARE the engine's cached keys (shared, not rebuilt)
    np.testing.assert_array_equal(
        np.asarray(i0.keys), np.asarray(rp._keys[rp._order])
    )


def test_version_invalidation_insert_delete_migration(rng):
    """After insert/delete/update_weights + a migration-emitting step,
    queries against the refreshed index agree with a cold-built index."""
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    w = jnp.asarray(0.5 + rng.random(2048), jnp.float32)
    rp = Repartitioner(pts, w, num_parts=8, capacity=4096, cfg=MORTON)
    v0 = rp.index_version

    # insert: version bumps, refreshed == cold
    new_pts = jnp.asarray(rng.random((200, 3)), jnp.float32)
    slots = rp.insert(new_pts, jnp.ones(200))
    assert rp.index_version == v0 + 1
    step = rp.step()  # emits a migration plan over the new geometry
    assert step.plan is not None
    fresh = rp.curve_index()
    assert int(fresh.version) == rp.index_version
    q = jnp.concatenate([new_pts[:64], jnp.asarray(rng.random((64, 3)), jnp.float32)])
    _assert_queries_agree(fresh, _cold_index_of(rp), q, pts)
    # inserted points are found under their storage-slot ids
    f = queries.point_location(fresh, new_pts)
    assert bool(f.found.all())
    assert set(np.asarray(f.ids).tolist()) == set(np.asarray(slots).tolist())

    # delete: version bumps, deleted points disappear from queries
    v1 = rp.index_version
    rp.delete(slots[:100])
    assert rp.index_version == v1 + 1
    fresh2 = rp.curve_index()
    f2 = queries.point_location(fresh2, new_pts[:100])
    assert not bool(f2.found.any())
    _assert_queries_agree(fresh2, _cold_index_of(rp), q, pts)

    # update_weights alone never stales the index; a rebuild does
    v2 = rp.index_version
    rp.update_weights(jnp.asarray(rng.random(rp.capacity), jnp.float32))
    assert rp.index_version == v2
    rp.rebuild()
    assert rp.index_version > v2
    _assert_queries_agree(rp.curve_index(), _cold_index_of(rp), q, pts)


def test_key_cache_tokens_unique_across_engines(rng):
    """Two same-shaped engines must not share key-cache entries: with
    per-instance counters both starting at 0, the second engine read the
    first one's stale keys (regression for the token-collision bug)."""
    a = jnp.asarray(rng.random((512, 3)), jnp.float32)
    b = jnp.asarray(rng.random((512, 3)), jnp.float32)  # same shape, new data
    rp_a = Repartitioner(a, None, num_parts=4, capacity=512, cfg=MORTON)
    rp_b = Repartitioner(b, None, num_parts=4, capacity=512, cfg=MORTON)
    assert rp_a.cache_token != rp_b.cache_token
    f = queries.point_location(rp_b.curve_index(), b[:64])
    assert bool(f.found.all())  # fails if rp_b was served rp_a's keys


def test_refreshed_index_sentinel_tail_is_inert(rng):
    """Deleted slots sort to the sentinel tail and must never surface in
    query results (their stored coordinates are stale)."""
    pts = jnp.asarray(rng.random((512, 3)), jnp.float32)
    rp = Repartitioner(pts, None, num_parts=4, capacity=1024, cfg=MORTON)
    rp.delete(jnp.arange(256))
    idx = rp.curve_index()
    assert int(idx.valid_count()) == 256
    d, g = queries.knn(idx, pts[jnp.arange(256, 512)], k=3, cutoff_buckets=2)
    assert np.isfinite(np.asarray(d)).all()
    assert (np.asarray(g) >= 256).all()  # only live slots are returned
    f = queries.point_location(idx, pts[:256])
    assert not bool(f.found.any())
