"""runtime/: heartbeat failure detection, knapsack reslice conservation,
elastic mesh-shape planning, and (in a fake-device subprocess) a live
device-count change served through ElasticServingController with no cold
restart."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partitioner import HierarchyPlan, PartitionerConfig
from repro.core.repartition import HierarchicalRepartitioner, Repartitioner
from repro.runtime.elastic import replacement_plan, viable_mesh_shapes
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    reslice_for_stragglers,
    reslice_on_failure,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# HeartbeatMonitor (injected clock — fully deterministic)
# ---------------------------------------------------------------------------

def test_heartbeat_failure_detection():
    mon = HeartbeatMonitor(num_workers=4, timeout=10.0)
    for w in range(4):
        mon.beat(w, now=0.0)
    assert mon.failed(now=5.0) == []
    mon.beat(0, now=20.0)
    mon.beat(1, now=20.0)
    # 2 and 3 last seen at t=0: 25 - 0 > 10
    assert mon.failed(now=25.0) == [2, 3]


def test_heartbeat_stragglers_at_factor_of_median():
    mon = HeartbeatMonitor(num_workers=4, straggler_factor=2.0)
    for step in range(6):
        now = float(step)
        for w in range(4):
            mon.beat(w, now, step_time=0.5 if w == 3 else 0.1)
    assert mon.stragglers() == [3]
    # a single worker can never be a straggler (no population to compare)
    solo = HeartbeatMonitor(num_workers=1)
    solo.beat(0, 0.0, step_time=9.0)
    assert solo.stragglers() == []


# ---------------------------------------------------------------------------
# Reslice plans
# ---------------------------------------------------------------------------

def test_reslice_on_failure_conservation_and_survivors_only(rng):
    units = 256
    old = np.repeat(np.arange(8), units // 8)
    w = rng.random(units).astype(np.float32) + 0.1
    rp = reslice_on_failure(old, w, failed=[2, 5], num_workers=8)
    assert rp.survivors == [0, 1, 3, 4, 6, 7]
    # every unit lands on a survivor, none stranded on the failed ranks
    assert set(np.unique(rp.assignment)) <= set(rp.survivors)
    stay = int((old == rp.assignment).sum())
    assert stay + rp.plan.total_moved == units
    # everything on the failed ranks moved
    assert rp.plan.total_moved >= int(np.isin(old, [2, 5]).sum())


def test_reslice_for_stragglers_proportional(rng):
    w = np.ones(400, np.float32)
    tp = np.array([1.0, 1.0, 4.0, 1.0])
    part = reslice_for_stragglers(w, tp)
    counts = np.bincount(part, minlength=4)
    assert counts.sum() == 400
    # the 4x-throughput worker gets the biggest share, ~4x a slow one
    assert counts[2] == counts.max()
    assert counts[2] > 2.5 * counts[0]


def test_replacement_plan_shrink_conserves_units(rng):
    old = np.repeat(np.arange(8), 4)           # 32 units on 8 parts
    w = np.ones(32, np.float32)
    new, plan = replacement_plan(old, w, new_num_parts=3)
    assert new.max() == 2 and new.min() == 0
    stay = int((old == new).sum())
    assert stay + plan.total_moved == 32       # nothing lost leaving parts 3..7


def test_replacement_plan_empty_old_parts_is_fresh_placement():
    # regression: old_parts.max() used to crash on the empty bootstrap case
    new, plan = replacement_plan(np.array([], np.int64), np.ones(16, np.float32), 4)
    assert new.shape == (16,) and new.max() == 3
    assert plan.total_moved == 0               # nothing existed, nothing moves


def test_viable_mesh_shapes_products_and_preference():
    for n in (1, 6, 8, 12, 16):
        shapes = viable_mesh_shapes(n)
        assert all(a * b == n for a, b in shapes)
        assert len(set(shapes)) == len(shapes)
    assert viable_mesh_shapes(16)[0] == (4, 4)         # square-ish first
    assert set(viable_mesh_shapes(12)[0]) == {3, 4}
    assert viable_mesh_shapes(8, min_model=2)[0][1] >= 2


# ---------------------------------------------------------------------------
# Elastic resize on the repartitioners (single-device: pure re-slice math)
# ---------------------------------------------------------------------------

def _conserved(old, new, moved):
    act = old >= 0
    assert int(((old == new) & act).sum()) + moved == int(act.sum())


def test_flat_resize_conserves_and_bumps_version(rng):
    pts = jnp.asarray(rng.random((2000, 2)), jnp.float32)
    rp = Repartitioner(pts, None, num_parts=8, cfg=PartitionerConfig(curve="morton"))
    v0, old = rp.index_version, np.asarray(rp.part).copy()
    rebuilds0 = rp.stats.rebuilds            # the initial fit counts as one
    step = rp.resize(5)
    new = np.asarray(rp.part)
    assert new.max() == 4 and rp.num_parts == 5
    _conserved(old, new, step.plan.total_moved)
    assert rp.index_version == v0 + 1 and rp.stats.resizes == 1
    assert step.reused_keys and rp.stats.rebuilds == rebuilds0
    # growth after shrink round-trips
    step2 = rp.resize(8)
    _conserved(new, np.asarray(rp.part), step2.plan.total_moved)
    assert np.asarray(rp.part).max() == 7


def test_hierarchical_resize_is_hierarchy_aware(rng):
    import dataclasses

    pts = jnp.asarray(rng.random((3000, 2)), jnp.float32)
    plan = HierarchyPlan(num_nodes=4, devices_per_node=2)
    hrp = HierarchicalRepartitioner(pts, None, plan)
    v0, old = hrp.index_version, np.asarray(hrp.part).copy()
    rebuilds0 = hrp.stats.rebuilds
    step = hrp.resize(dataclasses.replace(plan, num_nodes=3))
    new = np.asarray(hrp.part)
    assert new.max() == 5 and hrp.plan.num_nodes == 3
    _conserved(old, new, step.plan.total_moved)
    assert hrp.index_version == v0 + 1 and hrp.stats.rebuilds == rebuilds0
    # the two-level slice re-ran: fresh node loads for the new node count
    assert step.node_loads.shape == (3,)
    assert step.node_imbalance < 1.5


def test_elastic_reshard_mid_serve_subprocess():
    """Drop two devices under a live serving engine: the controller
    re-slices hierarchy-aware, re-places chunks on the survivors, swaps
    the index version — answers stay bit-equal and the owner never cold
    rebuilds."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8"
        " --xla_backend_optimization_level=0"
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import queries
        from repro.core.partitioner import HierarchyPlan
        from repro.core.repartition import HierarchicalRepartitioner
        from repro.runtime.elastic import ElasticServingController, mesh_from_devices
        from repro.serve.query_engine import DistributedQueryEngine

        rng = np.random.default_rng(11)
        pts = jnp.asarray(rng.random((4096, 2)), jnp.float32)
        plan = HierarchyPlan(num_nodes=4, devices_per_node=2)
        hrp = HierarchicalRepartitioner(pts, None, plan)
        rebuilds0 = hrp.stats.rebuilds      # initial fit only
        idx = hrp.curve_index(32)
        mesh = mesh_from_devices(jax.devices(), (4, 2), ('node', 'device'))
        eng = DistributedQueryEngine(idx, mesh, ('node', 'device'), bucket_cap=32)

        sel = rng.choice(4096, 300, replace=False)
        q = jnp.concatenate([pts[jnp.asarray(sel)],
                             jnp.asarray(rng.random((212, 2)) + 1.5, jnp.float32)])
        ref = queries.point_location(idx, q, bucket_cap=eng._scan_cap)
        r0 = eng.point_location(q)
        for a, b in zip(r0, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        ctl = ElasticServingController(hrp, eng, heartbeat_timeout=10.0)
        for w in range(8):
            ctl.beat(w, now=0.0)
        for w in range(6):
            ctl.beat(w, now=20.0)          # 6 and 7 went silent
        ev = ctl.check(now=25.0)
        assert ev is not None and (ev.n_before, ev.n_after) == (8, 6)
        assert ev.mesh_shape[0] * ev.mesh_shape[1] == 6
        assert ev.rebuilds_during == 0      # live reshard, not a cold restart
        assert eng.stats.reshards == 1 and eng.stats.index_swaps >= 1
        assert ctl.check(now=26.0) is None  # fresh monitor: no double-fire

        r1 = eng.point_location(q)          # same data, smaller mesh
        for a, b in zip(r1, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        ev2 = ctl.apply_device_change(jax.devices())   # grow back to 8
        assert ev2.n_after == 8 and ev2.rebuilds_during == 0
        r2 = eng.point_location(q)
        for a, b in zip(r2, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert hrp.stats.rebuilds == rebuilds0

        # a slow-but-alive worker: throughput-weighted re-cut of the
        # live layout (run-aligned), answers stay bit-equal
        for step in range(6):
            for w in range(8):
                ctl.beat(w, 30.0 + step, step_time=0.6 if w == 7 else 0.1)
        assert ctl.monitor.stragglers() == [7]
        assert ctl.check(now=36.0) is None   # no reshard, just the re-cut
        assert eng.stats.weighted_reslices >= 1
        r3 = eng.point_location(q)
        for a, b in zip(r3, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('OK elastic', ev.mesh_shape, ev.moved_units)
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK elastic" in out.stdout


def test_straggler_mitigation_recuts_serving_layout():
    """Slow-but-alive workers trigger a throughput-weighted re-cut of
    the engine's chunk layout (no mesh change): the straggler's share of
    rows shrinks, the engine records a weighted reslice, and check()
    returns no ReshardEvent. Deterministic via the injected clock."""
    from repro.runtime.elastic import ElasticServingController
    from repro.serve.query_engine import DistributedQueryEngine

    rng = np.random.default_rng(5)
    pts = jnp.asarray(rng.random((2048, 2)), jnp.float32)
    hrp = HierarchicalRepartitioner(
        pts, None, HierarchyPlan(num_nodes=2, devices_per_node=2)
    )
    idx = hrp.curve_index(32)
    eng = DistributedQueryEngine(idx, None, ("node", "device"), bucket_cap=32)
    ctl = ElasticServingController(
        hrp, eng, devices=list(range(4)),
        heartbeat_timeout=100.0, straggler_factor=2.0,
    )

    # no straggler yet: mitigation is a no-op
    assert ctl.mitigate_stragglers() is None
    assert eng._row_targets is None

    for step in range(6):
        now = float(step)
        for w in range(4):
            ctl.beat(w, now, step_time=0.5 if w == 3 else 0.1)
    assert ctl.monitor.stragglers() == [3]
    assert ctl.check(now=5.0) is None       # alive => no reshard event

    assignment = ctl.mitigate_stragglers()
    counts = np.bincount(assignment, minlength=4)
    assert (np.diff(assignment) >= 0).all()  # contiguous shard runs
    assert counts.sum() == idx.bucket_starts.shape[0] - 1
    assert counts[3] == counts.min() < counts[:3].min()  # straggler holds least
    assert eng._row_targets is not None and eng._row_targets.shape == (3,)
    assert (np.diff(eng._row_targets) >= 0).all()
    # cuts land on directory bucket boundaries
    assert np.isin(eng._row_targets, np.asarray(idx.bucket_starts)).all()
    assert eng.stats.weighted_reslices >= 2   # check() fired one too

    # index swap (new version) drops the stale weighted cuts
    eng.swap(hrp.curve_index(32))
    assert eng._row_targets is None
