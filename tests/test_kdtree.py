"""kd-tree construction invariants across all four splitters (paper §III-A)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import kdtree

# tier-1 covers midpoint + exact median; the sampling/selection median
# variants ride the slow tier (same code path, heavier compiles)
# tier-1 keeps midpoint fast (median coverage via the hybrid-policy
# test); all pure-median variants ride the slow tier
SPLITTERS = [
    "midpoint",
    pytest.param("median", marks=pytest.mark.slow),
    pytest.param("median_sampled", marks=pytest.mark.slow),
    pytest.param("median_selection", marks=pytest.mark.slow),
]


@pytest.mark.parametrize("splitter", SPLITTERS)
def test_build_invariants_uniform(splitter, rng):
    pts = jnp.asarray(rng.random((1500, 3)), jnp.float32)
    tr = kdtree.build(pts, max_depth=10, bucket_size=32, splitter=splitter)
    rep = kdtree.validate(tr, pts)
    assert rep["ok"], rep["problems"]
    assert int(tr.count[0]) == 1500  # root holds everything


@pytest.mark.parametrize("splitter", ["midpoint", pytest.param("median", marks=pytest.mark.slow)])
def test_build_invariants_clustered(splitter, rng):
    clu = np.concatenate(
        [rng.normal(0.1, 0.01, (1000, 3)), rng.random((500, 3))]
    ).astype(np.float32)
    tr = kdtree.build(jnp.asarray(clu), max_depth=12, bucket_size=32, splitter=splitter)
    rep = kdtree.validate(tr, jnp.asarray(clu))
    assert rep["ok"], rep["problems"]


@pytest.mark.slow  # depth-14 median builds dominate compile time
def test_median_shorter_trees_on_clusters(rng):
    """Paper: 'For clustered distributions, median splitters produced
    shorter trees'."""
    clu = np.concatenate(
        [rng.normal(0.05, 0.005, (7000, 3)), rng.random((1000, 3))]
    ).astype(np.float32)
    depths = {}
    for splitter in ("midpoint", "median"):
        tr = kdtree.build(jnp.asarray(clu), max_depth=14, bucket_size=32, splitter=splitter)
        d = np.floor(np.log2(np.asarray(tr.leaf_id) + 1)).astype(int)
        depths[splitter] = d.mean()
    assert depths["median"] < depths["midpoint"]


def test_weighted_counts(rng):
    pts = jnp.asarray(rng.random((1000, 2)), jnp.float32)
    w = jnp.asarray(rng.random(1000).astype(np.float32))
    tr = kdtree.build(pts, w, max_depth=8, bucket_size=16)
    assert np.isclose(float(tr.weight[0]), float(w.sum()), rtol=1e-5)


def test_hybrid_splitter_policy(rng):
    pts = jnp.asarray(rng.random((1024, 3)), jnp.float32)
    tr = kdtree.build(
        pts, max_depth=10, bucket_size=32, splitter="median", median_top_levels=3
    )
    assert kdtree.validate(tr, pts)["ok"]


@given(
    n=st.integers(64, 1500),
    d=st.integers(1, 5),
    b=st.sampled_from([8, 32, 100]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=12, deadline=None)
def test_property_membership_and_occupancy(n, d, b, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.random((n, d)), jnp.float32)
    tr = kdtree.build(pts, max_depth=10, bucket_size=b, splitter="midpoint")
    rep = kdtree.validate(tr, pts)
    assert rep["ok"], rep["problems"]


def test_tree_order_is_permutation(rng):
    pts = jnp.asarray(rng.random((1500, 3)), jnp.float32)
    tr = kdtree.build(pts, max_depth=10, bucket_size=32)
    perm, _ = kdtree.tree_order(tr, pts)
    assert len(np.unique(np.asarray(perm))) == 1500
