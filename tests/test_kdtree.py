"""kd-tree construction invariants across all four splitters (paper §III-A)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import kdtree

# tier-1 covers midpoint + exact median; the sampling/selection median
# variants ride the slow tier (same code path, heavier compiles)
# tier-1 keeps midpoint fast (median coverage via the hybrid-policy
# test); all pure-median variants ride the slow tier
SPLITTERS = [
    "midpoint",
    pytest.param("median", marks=pytest.mark.slow),
    pytest.param("median_sampled", marks=pytest.mark.slow),
    pytest.param("median_selection", marks=pytest.mark.slow),
]


@pytest.mark.parametrize("splitter", SPLITTERS)
def test_build_invariants_uniform(splitter, rng):
    pts = jnp.asarray(rng.random((1500, 3)), jnp.float32)
    tr = kdtree.build(pts, max_depth=10, bucket_size=32, splitter=splitter)
    rep = kdtree.validate(tr, pts)
    assert rep["ok"], rep["problems"]
    assert int(tr.count[0]) == 1500  # root holds everything


@pytest.mark.parametrize("splitter", ["midpoint", pytest.param("median", marks=pytest.mark.slow)])
def test_build_invariants_clustered(splitter, rng):
    clu = np.concatenate(
        [rng.normal(0.1, 0.01, (1000, 3)), rng.random((500, 3))]
    ).astype(np.float32)
    tr = kdtree.build(jnp.asarray(clu), max_depth=12, bucket_size=32, splitter=splitter)
    rep = kdtree.validate(tr, jnp.asarray(clu))
    assert rep["ok"], rep["problems"]


@pytest.mark.slow  # depth-14 median builds dominate compile time
def test_median_shorter_trees_on_clusters(rng):
    """Paper: 'For clustered distributions, median splitters produced
    shorter trees'."""
    clu = np.concatenate(
        [rng.normal(0.05, 0.005, (7000, 3)), rng.random((1000, 3))]
    ).astype(np.float32)
    depths = {}
    for splitter in ("midpoint", "median"):
        tr = kdtree.build(jnp.asarray(clu), max_depth=14, bucket_size=32, splitter=splitter)
        d = np.floor(np.log2(np.asarray(tr.leaf_id) + 1)).astype(int)
        depths[splitter] = d.mean()
    assert depths["median"] < depths["midpoint"]


def test_weighted_counts(rng):
    pts = jnp.asarray(rng.random((1000, 2)), jnp.float32)
    w = jnp.asarray(rng.random(1000).astype(np.float32))
    tr = kdtree.build(pts, w, max_depth=8, bucket_size=16)
    assert np.isclose(float(tr.weight[0]), float(w.sum()), rtol=1e-5)


def test_hybrid_splitter_policy(rng):
    pts = jnp.asarray(rng.random((1024, 3)), jnp.float32)
    tr = kdtree.build(
        pts, max_depth=10, bucket_size=32, splitter="median", median_top_levels=3
    )
    assert kdtree.validate(tr, pts)["ok"]


@given(
    n=st.integers(64, 1500),
    d=st.integers(1, 5),
    b=st.sampled_from([8, 32, 100]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=12, deadline=None)
def test_property_membership_and_occupancy(n, d, b, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.random((n, d)), jnp.float32)
    tr = kdtree.build(pts, max_depth=10, bucket_size=b, splitter="midpoint")
    rep = kdtree.validate(tr, pts)
    assert rep["ok"], rep["problems"]


def test_tree_order_bucket_ranks(rng):
    """tree_order returns per-point bucket ranks/keys (gathers, no point
    sort); tree_perm materializes a valid bucket-major permutation."""
    pts = jnp.asarray(rng.random((1500, 3)), jnp.float32)
    tr = kdtree.build(pts, max_depth=10, bucket_size=32)
    rank, key = kdtree.tree_order(tr, pts)
    rank_h, key_h = np.asarray(rank), np.asarray(key)
    leaf = np.asarray(tr.leaf_id)
    # rank/key are constant within a bucket and distinct across buckets
    for l in np.unique(leaf)[:64]:
        assert len(np.unique(rank_h[leaf == l])) == 1
    assert len(np.unique(rank_h)) == len(np.unique(leaf))
    # materialized permutation is a permutation and groups buckets
    perm = np.asarray(kdtree.tree_perm(rank))
    assert len(np.unique(perm)) == 1500
    assert (np.diff(rank_h[perm]) >= 0).all()
    assert (np.diff(key_h[perm].astype(np.int64)) >= 0).all()


def test_bucket_summary_statistics(rng):
    pts = jnp.asarray(rng.random((800, 3)), jnp.float32)
    w = jnp.asarray((0.5 + rng.random(800)).astype(np.float32))
    tr = kdtree.build(pts, w, max_depth=8, bucket_size=32)
    s = kdtree.bucket_summary(tr, pts, w)
    cnt, leaf = np.asarray(s.count), np.asarray(tr.leaf_id)
    assert cnt.sum() == 800
    np.testing.assert_array_equal(cnt, np.bincount(leaf, minlength=tr.num_nodes))
    np.testing.assert_allclose(float(np.asarray(s.weight).sum()), float(w.sum()), rtol=1e-5)
    # spot-check one bucket's centroid/bbox against the member oracle
    l = leaf[0]
    members = np.asarray(pts)[leaf == l]
    np.testing.assert_allclose(np.asarray(s.centroid)[l], members.mean(0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s.bbox_lo)[l], members.min(0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s.bbox_hi)[l], members.max(0), rtol=1e-5)
    # bucket_order: starts are cumulative counts, ranks invert order
    bo = kdtree.bucket_order(
        s, frame_lo=tr.bbox_lo[0], frame_hi=tr.bbox_hi[0], bits=10, curve="hilbert"
    )
    order, starts = np.asarray(bo.order), np.asarray(bo.starts)
    np.testing.assert_array_equal(np.diff(starts), cnt[order])
    nb = int(bo.num_buckets)
    assert nb == (cnt > 0).sum()
    keys_rank = np.asarray(bo.node_keys)[order].astype(np.int64)
    assert (np.diff(keys_rank) >= 0).all()
