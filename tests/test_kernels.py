"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the harness kernel-validation contract)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sfc
from repro.kernels import bucket_search as bsk
from repro.kernels import hilbert as hk
from repro.kernels import knapsack_scan as kk
from repro.kernels import morton as mk
from repro.kernels import ref


@pytest.mark.parametrize(
    "n,d,bits",
    [
        (100, 2, 16),
        pytest.param(5000, 2, 16, marks=pytest.mark.slow),
        (2048, 2, 8),
        (100, 3, 10),
        pytest.param(5000, 3, 10, marks=pytest.mark.slow),
        (4096, 3, 5),
        (333, 5, 6),
        pytest.param(2047, 7, 4, marks=pytest.mark.slow),
        (1000, 10, 3),
    ],
)
def test_morton_kernel_sweep(n, d, bits, rng):
    pts = jnp.asarray(rng.random((n, d)), jnp.float32)
    cells = sfc.quantize(pts, bits)
    out = mk.morton_from_cells(cells, bits)
    expect = ref.morton_from_cells(cells, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize(
    "n,d,bits",
    [
        (100, 2, 16),
        pytest.param(3000, 2, 12, marks=pytest.mark.slow),
        (100, 3, 10),
        (3000, 3, 10),
        pytest.param(511, 4, 8, marks=pytest.mark.slow),
        (777, 6, 5),
        (1000, 10, 3),
    ],
)
def test_hilbert_kernel_sweep(n, d, bits, rng):
    pts = jnp.asarray(rng.random((n, d)), jnp.float32)
    cells = sfc.quantize(pts, bits)
    out = hk.hilbert_from_cells(cells, bits)
    expect = ref.hilbert_from_cells(cells, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("n", [64, 4096, pytest.param(5000, marks=pytest.mark.slow), pytest.param(16384, marks=pytest.mark.slow)])
@pytest.mark.parametrize("p", [2, 16, 63])
def test_knapsack_kernel_sweep(n, p, rng):
    w = jnp.asarray((rng.random(n) + 0.05).astype(np.float32))
    out = kk.knapsack_parts(w, p)
    expect = ref.knapsack_parts(w, p)
    out_h, exp_h = np.asarray(out), np.asarray(expect)
    if np.array_equal(out_h, exp_h):
        return
    # the blocked Pallas scan and the jnp cumsum associate float32 adds
    # differently; an element whose center of mass lands (numerically) on
    # a part boundary may legally flip one part. Anything else is a bug.
    mism = np.nonzero(out_h != exp_h)[0]
    assert np.abs(out_h[mism] - exp_h[mism]).max() <= 1, (n, p, mism[:8])
    w64 = np.asarray(w, np.float64)
    prefix = np.cumsum(w64) - w64
    ideal = w64.sum() / p
    frac = (prefix[mism] + 0.5 * w64[mism]) / ideal
    dist = np.abs(frac - np.round(frac))
    assert dist.max() < 1e-3, (n, p, dist.max())


@pytest.mark.parametrize("q,b", [(100, 17), (4096, 128), (2048, 1024), (100, 1)])
def test_bucket_search_kernel_sweep(q, b, rng):
    bk = jnp.sort(jnp.asarray(rng.integers(0, 2**31, b).astype(np.uint32)))
    qk = jnp.asarray(rng.integers(0, 2**31, q).astype(np.uint32))
    out = bsk.bucket_search(qk, bk)
    expect = ref.bucket_search(qk, bk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_bucket_search_exact_boundaries():
    bk = jnp.asarray([10, 20, 30], jnp.uint32)
    qk = jnp.asarray([5, 10, 15, 20, 29, 30, 31], jnp.uint32)
    out = np.asarray(bsk.bucket_search(qk, bk))
    expect = np.asarray(ref.bucket_search(qk, bk))
    np.testing.assert_array_equal(out, expect)


# ---------------------------------------------------------------------------
# fused stencil row update
# ---------------------------------------------------------------------------

from _hypothesis_compat import given, settings, strategies as st  # noqa: E402
from repro.kernels import stencil_update as su  # noqa: E402


def _stencil_case(rng, R, K, V, ghost_frac, invalid_rows):
    """Random row tables: V total values (owned+ghost), ghost_frac of
    neighbor slots pointing past the owned region, some rows all-invalid
    (pads) — the layouts the distributed executors feed the kernel."""
    vals_all = jnp.asarray(rng.standard_normal(V).astype(np.float32))
    u_rows = jnp.asarray(rng.standard_normal(R).astype(np.float32))
    nbr = rng.integers(0, V, (R, K))
    valid = rng.random((R, K)) < 0.8
    ghost = rng.random((R, K)) < ghost_frac
    cap = max(V // 2, 1)
    nbr = np.where(ghost, np.minimum(nbr % V, V - 1), nbr % cap)
    if invalid_rows:
        valid[rng.integers(0, R, max(R // 4, 1))] = False
    coeff = np.where(valid, rng.random((R, K)).astype(np.float32), 0.0)
    return (
        vals_all,
        u_rows,
        jnp.asarray(nbr.astype(np.int32)),
        jnp.asarray(valid),
        jnp.asarray(coeff.astype(np.float32)),
    )


@settings(max_examples=12, deadline=None)
@given(
    R=st.sampled_from([1, 7, 64, 1023, 1024, 1025]),
    K=st.sampled_from([4, 8]),
    ghost_frac=st.sampled_from([0.0, 0.3, 0.9]),
    invalid_rows=st.booleans(),
    seed=st.integers(0, 7),
)
def test_fused_stencil_update_bit_equal(R, K, ghost_frac, invalid_rows, seed):
    """Pallas kernel (interpret) vs the jnp definition: bit-equal across
    block-boundary row counts, K widths, ghost-heavy neighbor tables and
    all-invalid (pad) rows."""
    rng = np.random.default_rng(seed)
    V = max(2 * R, 8)
    case = _stencil_case(rng, R, K, V, ghost_frac, invalid_rows)
    expect = np.asarray(su.stencil_update_ref(*case))
    got = np.asarray(su.fused_stencil_update(*case, interpret=True))
    np.testing.assert_array_equal(got, expect)


def test_fused_stencil_update_pad_rows_identity():
    """An all-invalid row passes its center through up to +0.0 — pad
    slots must not acquire spurious values from the masked lanes."""
    rng = np.random.default_rng(3)
    vals_all, u_rows, nbr, valid, coeff = _stencil_case(rng, 16, 4, 32, 0.5, False)
    valid = jnp.zeros_like(valid)
    out = np.asarray(
        su.fused_stencil_update(vals_all, u_rows, nbr, valid, coeff, interpret=True)
    )
    np.testing.assert_array_equal(out, np.asarray(u_rows) + np.float32(0.0))
