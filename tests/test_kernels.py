"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the harness kernel-validation contract)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sfc
from repro.kernels import bucket_search as bsk
from repro.kernels import hilbert as hk
from repro.kernels import knapsack_scan as kk
from repro.kernels import morton as mk
from repro.kernels import ref


@pytest.mark.parametrize(
    "n,d,bits",
    [
        (100, 2, 16),
        pytest.param(5000, 2, 16, marks=pytest.mark.slow),
        (2048, 2, 8),
        (100, 3, 10),
        pytest.param(5000, 3, 10, marks=pytest.mark.slow),
        (4096, 3, 5),
        (333, 5, 6),
        pytest.param(2047, 7, 4, marks=pytest.mark.slow),
        (1000, 10, 3),
    ],
)
def test_morton_kernel_sweep(n, d, bits, rng):
    pts = jnp.asarray(rng.random((n, d)), jnp.float32)
    cells = sfc.quantize(pts, bits)
    out = mk.morton_from_cells(cells, bits)
    expect = ref.morton_from_cells(cells, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize(
    "n,d,bits",
    [
        (100, 2, 16),
        pytest.param(3000, 2, 12, marks=pytest.mark.slow),
        (100, 3, 10),
        (3000, 3, 10),
        pytest.param(511, 4, 8, marks=pytest.mark.slow),
        (777, 6, 5),
        (1000, 10, 3),
    ],
)
def test_hilbert_kernel_sweep(n, d, bits, rng):
    pts = jnp.asarray(rng.random((n, d)), jnp.float32)
    cells = sfc.quantize(pts, bits)
    out = hk.hilbert_from_cells(cells, bits)
    expect = ref.hilbert_from_cells(cells, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("n", [64, 4096, pytest.param(5000, marks=pytest.mark.slow), pytest.param(16384, marks=pytest.mark.slow)])
@pytest.mark.parametrize("p", [2, 16, 63])
def test_knapsack_kernel_sweep(n, p, rng):
    w = jnp.asarray((rng.random(n) + 0.05).astype(np.float32))
    out = kk.knapsack_parts(w, p)
    expect = ref.knapsack_parts(w, p)
    out_h, exp_h = np.asarray(out), np.asarray(expect)
    if np.array_equal(out_h, exp_h):
        return
    # the blocked Pallas scan and the jnp cumsum associate float32 adds
    # differently; an element whose center of mass lands (numerically) on
    # a part boundary may legally flip one part. Anything else is a bug.
    mism = np.nonzero(out_h != exp_h)[0]
    assert np.abs(out_h[mism] - exp_h[mism]).max() <= 1, (n, p, mism[:8])
    w64 = np.asarray(w, np.float64)
    prefix = np.cumsum(w64) - w64
    ideal = w64.sum() / p
    frac = (prefix[mism] + 0.5 * w64[mism]) / ideal
    dist = np.abs(frac - np.round(frac))
    assert dist.max() < 1e-3, (n, p, dist.max())


@pytest.mark.parametrize("q,b", [(100, 17), (4096, 128), (2048, 1024), (100, 1)])
def test_bucket_search_kernel_sweep(q, b, rng):
    bk = jnp.sort(jnp.asarray(rng.integers(0, 2**31, b).astype(np.uint32)))
    qk = jnp.asarray(rng.integers(0, 2**31, q).astype(np.uint32))
    out = bsk.bucket_search(qk, bk)
    expect = ref.bucket_search(qk, bk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_bucket_search_exact_boundaries():
    bk = jnp.asarray([10, 20, 30], jnp.uint32)
    qk = jnp.asarray([5, 10, 15, 20, 29, 30, 31], jnp.uint32)
    out = np.asarray(bsk.bucket_search(qk, bk))
    expect = np.asarray(ref.bucket_search(qk, bk))
    np.testing.assert_array_equal(out, expect)
