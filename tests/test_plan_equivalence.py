"""Vectorized plan builders vs the per-part legacy oracle — bit-identity.

The contract that makes the segment-op rewrite of `repro.mesh.halo` a
pure perf change: every output field of `build_halo_plan` /
`build_move_plan` is ``np.array_equal`` to the legacy loop builders'
(the ascending-slot canonical order and stable fills are deterministic,
so exact equality is the spec, not a tolerance). The matrix covers flat
and (N, D) hierarchies, scattered and SFC-compact partitions,
non-contiguous slot ids, empty-ghost and empty parts, cap-rounding
boundaries, and every move-plan kind (incremental / full /
``kind="none"`` / node-local device-certified).

No jax required: plan construction is host-side numpy.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.mesh import amr
from repro.mesh import halo


@dataclass(frozen=True)
class _Hier:
    """Hierarchy stand-in with the fields the halo/move builders read
    (matches `partitioner.HierarchyPlan` without importing jax)."""

    num_nodes: int
    devices_per_node: int
    node_axis: str = "node"
    device_axis: str = "device"
    inter_node_cost: float = 4.0

    @property
    def num_parts(self) -> int:
        return self.num_nodes * self.devices_per_node


def _mesh(seed: int, adapt_steps: int, base_level: int = 3):
    mesh = amr.uniform_mesh(2, base_level, base_level + 2)
    rng = np.random.default_rng(seed)
    for _ in range(adapt_steps):
        c = rng.random(2).astype(np.float64)
        ref, coar = amr.adapt_masks(mesh, c)
        mesh, _ = amr.refine_coarsen(mesh, ref, coar)
    nbr = amr.face_neighbors(mesh)
    coeff = amr.stencil_coeffs(mesh, nbr, amr.stable_dt(mesh))
    return mesh, nbr, coeff


def _slots(n: int, seed: int, contiguous: bool) -> np.ndarray:
    if contiguous:
        return np.arange(n, dtype=np.int64)
    rng = np.random.default_rng(seed + 1000)
    return rng.choice(3 * n, size=n, replace=False).astype(np.int64)


def _partition(mesh, S: int, seed: int, sfc: bool) -> np.ndarray:
    rng = np.random.default_rng(seed + 2000)
    if sfc:
        order = np.argsort(amr._pack(mesh.level, mesh.ij), kind="stable")
        part = np.empty((mesh.n,), np.int32)
        bounds = np.sort(rng.choice(mesh.n + 1, size=S - 1, replace=True))
        bounds = np.concatenate(([0], bounds, [mesh.n]))
        for p in range(S):
            part[order[bounds[p] : bounds[p + 1]]] = p
        return part
    return rng.integers(0, S, mesh.n).astype(np.int32)


# metric keys that legitimately differ between a cached and a scratch
# build of the same plan (timings + cache accounting)
_CACHE_METRICS = frozenset(
    {"PlanBuildSeconds", "PlanCacheHits", "PatchedRows"}
)


def assert_halo_equal(
    a: halo.HaloPlan, b: halo.HaloPlan, *, ignore=frozenset({"PlanBuildSeconds"})
) -> None:
    assert (a.axes, a.num_parts, a.cap, a.gcap, a.K) == (
        b.axes, b.num_parts, b.cap, b.gcap, b.K
    )
    for f in (
        "owned_idx", "owned_slot", "nbr_local", "nbr_valid", "coeff",
        "ghost_fetch", "interior_idx", "boundary_idx",
    ):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.stage_meta == b.stage_meta
    for sa, sb in zip(a.stages, b.stages):
        assert np.array_equal(sa.idx, sb.idx), sa.axis
    ma = {k: v for k, v in a.metrics.items() if k not in ignore}
    mb = {k: v for k, v in b.metrics.items() if k not in ignore}
    assert ma.keys() == mb.keys()
    for k in ma:
        assert np.allclose(ma[k], mb[k]), k


def assert_move_equal(a: halo.MovePlan, b: halo.MovePlan) -> None:
    assert (a.kind, a.axes, a.cap_old, a.cap_new) == (
        b.kind, b.axes, b.cap_old, b.cap_new
    )
    assert np.array_equal(a.keep, b.keep)
    assert a.stage_meta == b.stage_meta
    for sa, sb in zip(a.stages, b.stages):
        assert np.array_equal(sa.idx, sb.idx), sa.axis
    assert np.array_equal(a.migration.send_counts, b.migration.send_counts)
    assert a.migration.total_moved == b.migration.total_moved
    assert getattr(a.migration, "inter_moved", None) == getattr(
        b.migration, "inter_moved", None
    )


def _build_pair(slot, part, nbr, coeff, hier, S):
    kw = dict(hierarchy=hier) if hier is not None else dict(num_parts=S)
    return (
        halo.build_halo_plan(slot, part, nbr, coeff, **kw),
        halo.build_halo_plan_legacy(slot, part, nbr, coeff, **kw),
    )


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 5),
    adapt=st.integers(0, 2),
    nodes=st.sampled_from([1, 2]),
    dev=st.sampled_from([2, 4]),
    sfc=st.booleans(),
    contiguous=st.booleans(),
)
def test_halo_plan_bit_identical(seed, adapt, nodes, dev, sfc, contiguous):
    mesh, nbr, coeff = _mesh(seed, adapt)
    S = nodes * dev
    hier = _Hier(nodes, dev) if nodes > 1 else None
    slot = _slots(mesh.n, seed, contiguous)
    part = _partition(mesh, S, seed, sfc)
    pv, pl = _build_pair(slot, part, nbr, coeff, hier, S)
    assert_halo_equal(pv, pl)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 5),
    nodes=st.sampled_from([1, 2]),
    dev=st.sampled_from([2, 4]),
    full=st.booleans(),
    frac=st.floats(0.0, 0.4),
)
def test_move_plan_bit_identical(seed, nodes, dev, full, frac):
    mesh, nbr, coeff = _mesh(seed, 1)
    S = nodes * dev
    hier = _Hier(nodes, dev) if nodes > 1 else None
    slot = _slots(mesh.n, seed, contiguous=False)
    part = _partition(mesh, S, seed, sfc=True)
    rng = np.random.default_rng(seed + 3000)
    part2 = part.copy()
    sw = rng.random(mesh.n) < frac
    part2[sw] = rng.integers(0, S, int(sw.sum()))
    pv, pl = _build_pair(slot, part, nbr, coeff, hier, S)
    pv2, pl2 = _build_pair(slot, part2, nbr, coeff, hier, S)
    kw = dict(hierarchy=hier, full=full)
    assert_move_equal(
        halo.build_move_plan(pv, pv2, **kw),
        halo.build_move_plan_legacy(pl, pl2, **kw),
    )


def test_move_plan_kind_none():
    mesh, nbr, coeff = _mesh(0, 1)
    slot = _slots(mesh.n, 0, contiguous=True)
    part = _partition(mesh, 4, 0, sfc=True)
    pv, pl = _build_pair(slot, part, nbr, coeff, None, 4)
    mv, ml = halo.build_move_plan(pv, pv), halo.build_move_plan_legacy(pl, pl)
    assert mv.kind == ml.kind == "none"
    assert_move_equal(mv, ml)


def test_move_plan_node_local_device_certified():
    # moves stay within each part's node -> the single device-axis hop
    mesh, nbr, coeff = _mesh(1, 1)
    hier = _Hier(2, 4)
    slot = _slots(mesh.n, 1, contiguous=False)
    part = _partition(mesh, 8, 1, sfc=True)
    rng = np.random.default_rng(7)
    part2 = part.copy()
    sw = rng.random(mesh.n) < 0.2
    part2[sw] = (part[sw] // 4) * 4 + rng.integers(0, 4, int(sw.sum()))
    pv, pl = _build_pair(slot, part, nbr, coeff, hier, 8)
    pv2, pl2 = _build_pair(slot, part2, nbr, coeff, hier, 8)
    mv = halo.build_move_plan(pv, pv2, hierarchy=hier)
    ml = halo.build_move_plan_legacy(pl, pl2, hierarchy=hier)
    assert mv.kind == ml.kind
    if int(mv.migration.total_moved):
        assert mv.kind == "device"
    assert_move_equal(mv, ml)


def test_empty_ghost_and_empty_parts():
    # one part owns everything: other parts are empty, nobody has ghosts
    mesh, nbr, coeff = _mesh(2, 0)
    slot = np.arange(mesh.n, dtype=np.int64)
    part = np.zeros((mesh.n,), np.int32)
    pv, pl = _build_pair(slot, part, nbr, coeff, None, 4)
    assert_halo_equal(pv, pl)
    assert pv.metrics["InterNodeGhosts"] == 0
    assert pv.metrics["IntraNodeGhosts"] == 0
    # hierarchical shape of the same degenerate assignment
    pvh, plh = _build_pair(slot, part, nbr, coeff, _Hier(2, 2), 4)
    assert_halo_equal(pvh, plh)


@pytest.mark.parametrize("split", [(8, 8), (7, 9), (9, 7)])
def test_cap_rounding_boundaries(split):
    # 16 cells split right at / around the q=8 rounding quantum
    mesh = amr.uniform_mesh(2, 2, 4)   # 16 cells
    nbr = amr.face_neighbors(mesh)
    coeff = amr.stencil_coeffs(mesh, nbr, amr.stable_dt(mesh))
    slot = np.arange(mesh.n, dtype=np.int64)
    a, _ = split
    part = np.zeros((mesh.n,), np.int32)
    part[a:] = 1
    pv, pl = _build_pair(slot, part, nbr, coeff, None, 2)
    assert_halo_equal(pv, pl)


def test_with_metrics_false_identical_otherwise():
    mesh, nbr, coeff = _mesh(3, 1)
    hier = _Hier(2, 4)
    slot = _slots(mesh.n, 3, contiguous=False)
    part = _partition(mesh, 8, 3, sfc=True)
    full = halo.build_halo_plan(slot, part, nbr, coeff, hierarchy=hier)
    lean = halo.build_halo_plan(
        slot, part, nbr, coeff, hierarchy=hier, with_metrics=False
    )
    # quality report absent, everything else identical
    assert "MaxEdgeCut" in full.metrics and "MaxEdgeCut" not in lean.metrics
    for f in (
        "owned_idx", "owned_slot", "nbr_local", "nbr_valid", "coeff",
        "ghost_fetch", "interior_idx", "boundary_idx",
    ):
        assert np.array_equal(getattr(full, f), getattr(lean, f)), f
    assert full.stage_meta == lean.stage_meta
    for sa, sb in zip(full.stages, lean.stages):
        assert np.array_equal(sa.idx, sb.idx)
    # the cheap halo metrics stay, and the skipped report is recoverable
    for k in ("MaxSurfaceIndex", "InterNodeGhosts", "InterNodeBytesPerExchange"):
        assert lean.metrics[k] == full.metrics[k]
    rec = halo.plan_quality_metrics(part, nbr, 8)
    assert rec["MaxEdgeCut"] == full.metrics["MaxEdgeCut"]
    # the legacy builder honors the same flag
    lean_l = halo.build_halo_plan_legacy(
        slot, part, nbr, coeff, hierarchy=hier, with_metrics=False
    )
    assert_halo_equal(lean, lean_l)


def test_plan_build_seconds_recorded():
    mesh, nbr, coeff = _mesh(4, 0)
    slot = np.arange(mesh.n, dtype=np.int64)
    part = _partition(mesh, 4, 4, sfc=True)
    pv = halo.build_halo_plan(slot, part, nbr, coeff, num_parts=4)
    assert pv.metrics["PlanBuildSeconds"] > 0
    part2 = _partition(mesh, 4, 5, sfc=True)
    pv2 = halo.build_halo_plan(slot, part2, nbr, coeff, num_parts=4)
    mv = halo.build_move_plan(pv, pv2)
    assert mv.metrics["PlanBuildSeconds"] > 0
    # the "none" early return records it too
    assert halo.build_move_plan(pv, pv).metrics["PlanBuildSeconds"] > 0


# ---------------------------------------------------------------------------
# PlanCache: cached/patched builds vs fresh vectorized builds
# ---------------------------------------------------------------------------


def _amr_step(mesh, slot, next_id, rng):
    """One refine/coarsen step, tracking slot identity across it the way
    the simulation driver does: kept cells inherit their slot through the
    transfer map, born cells get fresh ids."""
    ref, coar = amr.adapt_masks(mesh, rng.random(2))
    mesh2, tr = amr.refine_coarsen(mesh, ref, coar)
    slot2 = np.empty(mesh2.n, np.int64)
    kept = ~tr.born
    slot2[kept] = slot[tr.src[kept, 0]]
    nb = int(tr.born.sum())
    slot2[tr.born] = next_id + np.arange(nb)
    nbr2 = amr.face_neighbors(mesh2)
    coeff2 = amr.stencil_coeffs(mesh2, nbr2, amr.stable_dt(mesh2))
    return mesh2, slot2, nbr2, coeff2, next_id + nb


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 5),
    nodes=st.sampled_from([1, 2]),
    dev=st.sampled_from([2, 4]),
)
def test_cached_event_sequence_bit_identical(seed, nodes, dev):
    """Randomized reslice / AMR / rebuild interleavings: every event's
    cached (patched) plan must be field-by-field identical to a fresh
    vectorized build, for both halo and move plans."""
    schedule = [
        "init", "reslice", "reslice", "amr", "reslice", "rebuild", "reslice",
    ]
    rng = np.random.default_rng(seed + 9000)
    mesh, nbr, coeff = _mesh(seed, 1)
    S = nodes * dev
    hier = _Hier(nodes, dev) if nodes > 1 else None
    kw = dict(hierarchy=hier) if hier is not None else dict(num_parts=S)
    mkw = dict(hierarchy=hier) if hier is not None else {}
    slot = _slots(mesh.n, seed, contiguous=False)
    next_id = int(slot.max()) + 1
    part = _partition(mesh, S, seed, sfc=True)
    cache = halo.PlanCache()
    token = 0
    prev_f = prev_c = None
    for op in schedule:
        if op == "reslice":
            part = part.copy()
            sw = rng.random(mesh.n) < 0.08
            part[sw] = rng.integers(0, S, int(sw.sum()))
        elif op == "rebuild":
            part = _partition(mesh, S, int(rng.integers(1 << 30)), sfc=True)
        elif op == "amr":
            mesh, slot, nbr, coeff, next_id = _amr_step(mesh, slot, next_id, rng)
            part = _partition(mesh, S, int(rng.integers(1 << 30)), sfc=True)
            token += 1  # cells were inserted/deleted
        fresh = halo.build_halo_plan(slot, part, nbr, coeff, **kw)
        cached = halo.build_halo_plan(
            slot, part, nbr, coeff, **kw, cache=cache, topo_token=token
        )
        assert_halo_equal(fresh, cached, ignore=_CACHE_METRICS)
        # move plans are only defined within one topology: across an AMR
        # event the driver moves state through the transfer map instead
        if prev_f is not None and op != "amr":
            for full in (False, True):
                assert_move_equal(
                    halo.build_move_plan(prev_f, fresh, full=full, **mkw),
                    halo.build_move_plan(
                        prev_c, cached, full=full, cache=cache, **mkw
                    ),
                )
        prev_f, prev_c = fresh, cached
    assert cache.stats.halo_hits + cache.stats.halo_misses == len(schedule)
    assert cache.stats.halo_hits >= 1          # small reslices take the patch path
    assert cache.stats.topo_refreshes >= 2     # init + each AMR step


def test_cache_pure_hit_and_reset():
    mesh, nbr, coeff = _mesh(0, 1)
    slot = _slots(mesh.n, 0, contiguous=False)
    part = _partition(mesh, 4, 0, sfc=True)
    cache = halo.PlanCache()
    kw = dict(num_parts=4, cache=cache, topo_token=0)
    p1 = halo.build_halo_plan(slot, part, nbr, coeff, **kw)
    assert (cache.stats.halo_misses, cache.stats.halo_hits) == (1, 0)
    # identical partition again: pure hit, nothing patched
    p2 = halo.build_halo_plan(slot, part, nbr, coeff, **kw)
    assert (cache.stats.halo_misses, cache.stats.halo_hits) == (1, 1)
    assert p2.metrics["PatchedRows"] == 0
    assert_halo_equal(p1, p2, ignore=_CACHE_METRICS)
    # reset drops both tiers: the next build is a miss again
    cache.reset()
    p3 = halo.build_halo_plan(slot, part, nbr, coeff, **kw)
    assert cache.stats.halo_misses == 2
    assert_halo_equal(p1, p3, ignore=_CACHE_METRICS)


def test_cache_topo_token_bump_refreshes_topology():
    mesh, nbr, coeff = _mesh(1, 1)
    slot = _slots(mesh.n, 1, contiguous=False)
    part = _partition(mesh, 4, 1, sfc=True)
    cache = halo.PlanCache()
    halo.build_halo_plan(
        slot, part, nbr, coeff, num_parts=4, cache=cache, topo_token=0
    )
    r0 = cache.stats.topo_refreshes
    # same arrays, bumped token: the topology tier must be rebuilt even
    # though nothing actually changed (the token is the authority)
    p = halo.build_halo_plan(
        slot, part, nbr, coeff, num_parts=4, cache=cache, topo_token=1
    )
    assert cache.stats.topo_refreshes == r0 + 1
    fresh = halo.build_halo_plan(slot, part, nbr, coeff, num_parts=4)
    assert_halo_equal(fresh, p, ignore=_CACHE_METRICS)


def test_cache_large_move_fraction_falls_back_to_scratch():
    mesh, nbr, coeff = _mesh(2, 1)
    slot = _slots(mesh.n, 2, contiguous=False)
    cache = halo.PlanCache(max_patch_frac=0.25)
    part = _partition(mesh, 4, 2, sfc=True)
    kw = dict(num_parts=4, cache=cache, topo_token=0)
    halo.build_halo_plan(slot, part, nbr, coeff, **kw)
    # rotate every cell's owner: 100% moved > 25% threshold
    part2 = ((part.astype(np.int64) + 1) % 4).astype(np.int32)
    p = halo.build_halo_plan(slot, part2, nbr, coeff, **kw)
    assert cache.stats.halo_misses == 2 and cache.stats.halo_hits == 0
    fresh = halo.build_halo_plan(slot, part2, nbr, coeff, num_parts=4)
    assert_halo_equal(fresh, p, ignore=_CACHE_METRICS)
    # ...and the scratch fallback still primes the cache for patching
    part3 = part2.copy()
    part3[:8] = (part3[:8] + 1) % 4
    p3 = halo.build_halo_plan(slot, part3, nbr, coeff, **kw)
    assert cache.stats.halo_hits == 1
    assert_halo_equal(
        halo.build_halo_plan(slot, part3, nbr, coeff, num_parts=4),
        p3, ignore=_CACHE_METRICS,
    )


def test_cache_shape_change_is_a_miss_but_equal():
    mesh, nbr, coeff = _mesh(3, 1)
    slot = _slots(mesh.n, 3, contiguous=False)
    part8 = _partition(mesh, 8, 3, sfc=True)
    cache = halo.PlanCache()
    halo.build_halo_plan(
        slot, part8, nbr, coeff, hierarchy=_Hier(2, 4), cache=cache, topo_token=0
    )
    # same cells, different hierarchy shape: partition tier can't patch
    p = halo.build_halo_plan(
        slot, part8, nbr, coeff, hierarchy=_Hier(4, 2), cache=cache, topo_token=0
    )
    assert cache.stats.halo_misses == 2
    fresh = halo.build_halo_plan(slot, part8, nbr, coeff, hierarchy=_Hier(4, 2))
    assert_halo_equal(fresh, p, ignore=_CACHE_METRICS)


def test_cache_cap_quantum_crossing_patched():
    # engineer a reslice that drags the max part population across the
    # cap rounding quantum in both directions; the patch must re-pad
    mesh = amr.uniform_mesh(2, 4, 6)   # 256 cells
    nbr = amr.face_neighbors(mesh)
    coeff = amr.stencil_coeffs(mesh, nbr, amr.stable_dt(mesh))
    slot = np.arange(mesh.n, dtype=np.int64)
    n = mesh.n
    cache = halo.PlanCache()
    kw = dict(num_parts=2, cache=cache, topo_token=0)
    for hi in (n // 2, n // 2 + 9, n // 2 - 7):   # 128 -> 137 -> 121 owned
        part = np.zeros((n,), np.int32)
        part[hi:] = 1
        p = halo.build_halo_plan(slot, part, nbr, coeff, **kw)
        fresh = halo.build_halo_plan(slot, part, nbr, coeff, num_parts=2)
        assert_halo_equal(fresh, p, ignore=_CACHE_METRICS)
        assert p.cap == fresh.cap
    assert cache.stats.halo_hits == 2   # both crossings took the patch path


def test_move_prologue_requires_cache_lineage():
    # a move between plans the cache has never seen must fall back to the
    # generic derivation (and still be correct)
    mesh, nbr, coeff = _mesh(4, 1)
    slot = _slots(mesh.n, 4, contiguous=False)
    part = _partition(mesh, 4, 4, sfc=True)
    part2 = part.copy()
    part2[:16] = (part2[:16] + 1) % 4
    old = halo.build_halo_plan(slot, part, nbr, coeff, num_parts=4)
    new = halo.build_halo_plan(slot, part2, nbr, coeff, num_parts=4)
    cache = halo.PlanCache()   # empty: no lineage for either plan
    mv = halo.build_move_plan(old, new, cache=cache)
    assert cache.stats.move_misses == 1 and cache.stats.move_hits == 0
    assert_move_equal(halo.build_move_plan(old, new), mv)
