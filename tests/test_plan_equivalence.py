"""Vectorized plan builders vs the per-part legacy oracle — bit-identity.

The contract that makes the segment-op rewrite of `repro.mesh.halo` a
pure perf change: every output field of `build_halo_plan` /
`build_move_plan` is ``np.array_equal`` to the legacy loop builders'
(the ascending-slot canonical order and stable fills are deterministic,
so exact equality is the spec, not a tolerance). The matrix covers flat
and (N, D) hierarchies, scattered and SFC-compact partitions,
non-contiguous slot ids, empty-ghost and empty parts, cap-rounding
boundaries, and every move-plan kind (incremental / full /
``kind="none"`` / node-local device-certified).

No jax required: plan construction is host-side numpy.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.mesh import amr
from repro.mesh import halo


@dataclass(frozen=True)
class _Hier:
    """Hierarchy stand-in with the fields the halo/move builders read
    (matches `partitioner.HierarchyPlan` without importing jax)."""

    num_nodes: int
    devices_per_node: int
    node_axis: str = "node"
    device_axis: str = "device"
    inter_node_cost: float = 4.0

    @property
    def num_parts(self) -> int:
        return self.num_nodes * self.devices_per_node


def _mesh(seed: int, adapt_steps: int, base_level: int = 3):
    mesh = amr.uniform_mesh(2, base_level, base_level + 2)
    rng = np.random.default_rng(seed)
    for _ in range(adapt_steps):
        c = rng.random(2).astype(np.float64)
        ref, coar = amr.adapt_masks(mesh, c)
        mesh, _ = amr.refine_coarsen(mesh, ref, coar)
    nbr = amr.face_neighbors(mesh)
    coeff = amr.stencil_coeffs(mesh, nbr, amr.stable_dt(mesh))
    return mesh, nbr, coeff


def _slots(n: int, seed: int, contiguous: bool) -> np.ndarray:
    if contiguous:
        return np.arange(n, dtype=np.int64)
    rng = np.random.default_rng(seed + 1000)
    return rng.choice(3 * n, size=n, replace=False).astype(np.int64)


def _partition(mesh, S: int, seed: int, sfc: bool) -> np.ndarray:
    rng = np.random.default_rng(seed + 2000)
    if sfc:
        order = np.argsort(amr._pack(mesh.level, mesh.ij), kind="stable")
        part = np.empty((mesh.n,), np.int32)
        bounds = np.sort(rng.choice(mesh.n + 1, size=S - 1, replace=True))
        bounds = np.concatenate(([0], bounds, [mesh.n]))
        for p in range(S):
            part[order[bounds[p] : bounds[p + 1]]] = p
        return part
    return rng.integers(0, S, mesh.n).astype(np.int32)


def assert_halo_equal(a: halo.HaloPlan, b: halo.HaloPlan) -> None:
    assert (a.axes, a.num_parts, a.cap, a.gcap, a.K) == (
        b.axes, b.num_parts, b.cap, b.gcap, b.K
    )
    for f in (
        "owned_idx", "owned_slot", "nbr_local", "nbr_valid", "coeff",
        "ghost_fetch", "interior_idx", "boundary_idx",
    ):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.stage_meta == b.stage_meta
    for sa, sb in zip(a.stages, b.stages):
        assert np.array_equal(sa.idx, sb.idx), sa.axis
    ma = {k: v for k, v in a.metrics.items() if k != "PlanBuildSeconds"}
    mb = {k: v for k, v in b.metrics.items() if k != "PlanBuildSeconds"}
    assert ma.keys() == mb.keys()
    for k in ma:
        assert np.allclose(ma[k], mb[k]), k


def assert_move_equal(a: halo.MovePlan, b: halo.MovePlan) -> None:
    assert (a.kind, a.axes, a.cap_old, a.cap_new) == (
        b.kind, b.axes, b.cap_old, b.cap_new
    )
    assert np.array_equal(a.keep, b.keep)
    assert a.stage_meta == b.stage_meta
    for sa, sb in zip(a.stages, b.stages):
        assert np.array_equal(sa.idx, sb.idx), sa.axis
    assert np.array_equal(a.migration.send_counts, b.migration.send_counts)
    assert a.migration.total_moved == b.migration.total_moved
    assert getattr(a.migration, "inter_moved", None) == getattr(
        b.migration, "inter_moved", None
    )


def _build_pair(slot, part, nbr, coeff, hier, S):
    kw = dict(hierarchy=hier) if hier is not None else dict(num_parts=S)
    return (
        halo.build_halo_plan(slot, part, nbr, coeff, **kw),
        halo.build_halo_plan_legacy(slot, part, nbr, coeff, **kw),
    )


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 5),
    adapt=st.integers(0, 2),
    nodes=st.sampled_from([1, 2]),
    dev=st.sampled_from([2, 4]),
    sfc=st.booleans(),
    contiguous=st.booleans(),
)
def test_halo_plan_bit_identical(seed, adapt, nodes, dev, sfc, contiguous):
    mesh, nbr, coeff = _mesh(seed, adapt)
    S = nodes * dev
    hier = _Hier(nodes, dev) if nodes > 1 else None
    slot = _slots(mesh.n, seed, contiguous)
    part = _partition(mesh, S, seed, sfc)
    pv, pl = _build_pair(slot, part, nbr, coeff, hier, S)
    assert_halo_equal(pv, pl)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 5),
    nodes=st.sampled_from([1, 2]),
    dev=st.sampled_from([2, 4]),
    full=st.booleans(),
    frac=st.floats(0.0, 0.4),
)
def test_move_plan_bit_identical(seed, nodes, dev, full, frac):
    mesh, nbr, coeff = _mesh(seed, 1)
    S = nodes * dev
    hier = _Hier(nodes, dev) if nodes > 1 else None
    slot = _slots(mesh.n, seed, contiguous=False)
    part = _partition(mesh, S, seed, sfc=True)
    rng = np.random.default_rng(seed + 3000)
    part2 = part.copy()
    sw = rng.random(mesh.n) < frac
    part2[sw] = rng.integers(0, S, int(sw.sum()))
    pv, pl = _build_pair(slot, part, nbr, coeff, hier, S)
    pv2, pl2 = _build_pair(slot, part2, nbr, coeff, hier, S)
    kw = dict(hierarchy=hier, full=full)
    assert_move_equal(
        halo.build_move_plan(pv, pv2, **kw),
        halo.build_move_plan_legacy(pl, pl2, **kw),
    )


def test_move_plan_kind_none():
    mesh, nbr, coeff = _mesh(0, 1)
    slot = _slots(mesh.n, 0, contiguous=True)
    part = _partition(mesh, 4, 0, sfc=True)
    pv, pl = _build_pair(slot, part, nbr, coeff, None, 4)
    mv, ml = halo.build_move_plan(pv, pv), halo.build_move_plan_legacy(pl, pl)
    assert mv.kind == ml.kind == "none"
    assert_move_equal(mv, ml)


def test_move_plan_node_local_device_certified():
    # moves stay within each part's node -> the single device-axis hop
    mesh, nbr, coeff = _mesh(1, 1)
    hier = _Hier(2, 4)
    slot = _slots(mesh.n, 1, contiguous=False)
    part = _partition(mesh, 8, 1, sfc=True)
    rng = np.random.default_rng(7)
    part2 = part.copy()
    sw = rng.random(mesh.n) < 0.2
    part2[sw] = (part[sw] // 4) * 4 + rng.integers(0, 4, int(sw.sum()))
    pv, pl = _build_pair(slot, part, nbr, coeff, hier, 8)
    pv2, pl2 = _build_pair(slot, part2, nbr, coeff, hier, 8)
    mv = halo.build_move_plan(pv, pv2, hierarchy=hier)
    ml = halo.build_move_plan_legacy(pl, pl2, hierarchy=hier)
    assert mv.kind == ml.kind
    if int(mv.migration.total_moved):
        assert mv.kind == "device"
    assert_move_equal(mv, ml)


def test_empty_ghost_and_empty_parts():
    # one part owns everything: other parts are empty, nobody has ghosts
    mesh, nbr, coeff = _mesh(2, 0)
    slot = np.arange(mesh.n, dtype=np.int64)
    part = np.zeros((mesh.n,), np.int32)
    pv, pl = _build_pair(slot, part, nbr, coeff, None, 4)
    assert_halo_equal(pv, pl)
    assert pv.metrics["InterNodeGhosts"] == 0
    assert pv.metrics["IntraNodeGhosts"] == 0
    # hierarchical shape of the same degenerate assignment
    pvh, plh = _build_pair(slot, part, nbr, coeff, _Hier(2, 2), 4)
    assert_halo_equal(pvh, plh)


@pytest.mark.parametrize("split", [(8, 8), (7, 9), (9, 7)])
def test_cap_rounding_boundaries(split):
    # 16 cells split right at / around the q=8 rounding quantum
    mesh = amr.uniform_mesh(2, 2, 4)   # 16 cells
    nbr = amr.face_neighbors(mesh)
    coeff = amr.stencil_coeffs(mesh, nbr, amr.stable_dt(mesh))
    slot = np.arange(mesh.n, dtype=np.int64)
    a, _ = split
    part = np.zeros((mesh.n,), np.int32)
    part[a:] = 1
    pv, pl = _build_pair(slot, part, nbr, coeff, None, 2)
    assert_halo_equal(pv, pl)


def test_with_metrics_false_identical_otherwise():
    mesh, nbr, coeff = _mesh(3, 1)
    hier = _Hier(2, 4)
    slot = _slots(mesh.n, 3, contiguous=False)
    part = _partition(mesh, 8, 3, sfc=True)
    full = halo.build_halo_plan(slot, part, nbr, coeff, hierarchy=hier)
    lean = halo.build_halo_plan(
        slot, part, nbr, coeff, hierarchy=hier, with_metrics=False
    )
    # quality report absent, everything else identical
    assert "MaxEdgeCut" in full.metrics and "MaxEdgeCut" not in lean.metrics
    for f in (
        "owned_idx", "owned_slot", "nbr_local", "nbr_valid", "coeff",
        "ghost_fetch", "interior_idx", "boundary_idx",
    ):
        assert np.array_equal(getattr(full, f), getattr(lean, f)), f
    assert full.stage_meta == lean.stage_meta
    for sa, sb in zip(full.stages, lean.stages):
        assert np.array_equal(sa.idx, sb.idx)
    # the cheap halo metrics stay, and the skipped report is recoverable
    for k in ("MaxSurfaceIndex", "InterNodeGhosts", "InterNodeBytesPerExchange"):
        assert lean.metrics[k] == full.metrics[k]
    rec = halo.plan_quality_metrics(part, nbr, 8)
    assert rec["MaxEdgeCut"] == full.metrics["MaxEdgeCut"]
    # the legacy builder honors the same flag
    lean_l = halo.build_halo_plan_legacy(
        slot, part, nbr, coeff, hierarchy=hier, with_metrics=False
    )
    assert_halo_equal(lean, lean_l)


def test_plan_build_seconds_recorded():
    mesh, nbr, coeff = _mesh(4, 0)
    slot = np.arange(mesh.n, dtype=np.int64)
    part = _partition(mesh, 4, 4, sfc=True)
    pv = halo.build_halo_plan(slot, part, nbr, coeff, num_parts=4)
    assert pv.metrics["PlanBuildSeconds"] > 0
    part2 = _partition(mesh, 4, 5, sfc=True)
    pv2 = halo.build_halo_plan(slot, part2, nbr, coeff, num_parts=4)
    mv = halo.build_move_plan(pv, pv2)
    assert mv.metrics["PlanBuildSeconds"] > 0
    # the "none" early return records it too
    assert halo.build_move_plan(pv, pv).metrics["PlanBuildSeconds"] > 0
