"""Substrate tests: optimizer, schedules, compression, data pipeline,
checkpoint, fault tolerance, serving batcher."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import migration
from repro.data import pipeline as dp
from repro.optim import adamw, compression, schedule
from repro.runtime import elastic, fault_tolerance as ft


# --- optimizer --------------------------------------------------------------

def test_adamw_converges_quadratic():
    w = {"a": jnp.full((4, 4), 5.0, jnp.bfloat16)}
    st = adamw.init(w)
    for _ in range(300):
        g = jax.tree.map(lambda p: p.astype(jnp.float32) * 2, w)  # d/dw w^2
        w, st = adamw.update(g, st, jnp.float32(0.05), weight_decay=0.0)
    assert float(jnp.abs(w["a"].astype(jnp.float32)).max()) < 0.3


def test_clip_global_norm():
    g = {"x": jnp.ones((10,)) * 100.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert np.isclose(float(adamw.global_norm(clipped)), 1.0, rtol=1e-4)


def test_wsd_schedule_shape():
    lr = [float(schedule.wsd(s, peak_lr=1.0, warmup=10, total=100)) for s in range(100)]
    assert lr[0] < 0.2            # warmup start
    assert np.isclose(lr[50], 1.0)  # stable plateau
    assert lr[99] < 0.2           # decay tail
    # plateau is flat
    assert np.allclose(lr[15:85], 1.0)


def test_cosine_schedule_monotone_tail():
    lr = [float(schedule.cosine(s, peak_lr=1.0, warmup=5, total=50)) for s in range(50)]
    assert all(a >= b - 1e-9 for a, b in zip(lr[5:], lr[6:]))


# --- gradient compression ---------------------------------------------------

def test_int8_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (64, 64)).astype(np.float32))
    resid = None
    acc_true = np.zeros((64, 64), np.float32)
    acc_comp = np.zeros((64, 64), np.float32)
    for _ in range(50):
        comp, resid, info = compression.ef_apply({"g": g}, resid, mode="int8")
        acc_true += np.asarray(g)
        acc_comp += np.asarray(comp["g"])
    # residual carries the missing mass: totals converge
    drift = np.abs(acc_true - acc_comp - np.asarray(resid["g"])).max()
    assert drift < 1e-2


def test_topk_keeps_largest():
    g = {"g": jnp.asarray(np.arange(100, dtype=np.float32))}
    comp, resid, info = compression.ef_apply(g, None, mode="topk", topk_frac=0.1)
    kept = np.asarray(comp["g"])
    assert (kept[:90] == 0).all() and (kept[90:] > 0).all()


# --- data pipeline -----------------------------------------------------------

def test_stream_deterministic_and_shard_disjoint():
    cfg = dp.DataConfig(vocab_size=1000, seq_len=64, global_batch=8, num_shards=2)
    a1 = dp.synthetic_tokens(cfg, step=3, shard=0)
    a2 = dp.synthetic_tokens(cfg, step=3, shard=0)
    b = dp.synthetic_tokens(cfg, step=3, shard=1)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])  # replayable
    assert not np.array_equal(a1["tokens"], b["tokens"])        # shards differ
    assert a1["tokens"].shape == (4, 64)


def test_packing_beats_padding():
    cfg = dp.DataConfig(vocab_size=10, seq_len=2048, global_batch=8)
    lens = dp.sample_doc_lengths(cfg, step=0, count=500)
    bins = dp.pack_documents(lens, 2048)
    packed = dp.packing_efficiency(lens, bins, 2048)
    padded = dp.padded_baseline_efficiency(lens, 2048)
    assert packed > padded * 1.5
    assert packed > 0.8
    # no bin overflows
    for b in bins:
        assert sum(int(lens[i]) for i in b) <= 2048


# --- checkpoint --------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "n": {"b": jnp.ones(5, jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree, extra={"data_step": 7})
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, extra = ckpt.restore(str(tmp_path), 7, like)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["n"]["b"].dtype == jnp.bfloat16
    assert extra["data_step"] == 7


def test_checkpoint_atomic_commit(tmp_path):
    tree = {"w": jnp.ones(4)}
    ckpt.save(str(tmp_path), 1, tree)
    # a stale tmp dir from a "crashed" save must not be visible
    os.makedirs(tmp_path / ".tmp_step_2", exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    acp = ckpt.AsyncCheckpointer(str(tmp_path))
    acp.save(3, {"w": jnp.ones(8)})
    acp.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


# --- fault tolerance ----------------------------------------------------------

def test_heartbeat_failure_and_straggler():
    mon = ft.HeartbeatMonitor(num_workers=4, timeout=10.0)
    for w in range(4):
        mon.beat(w, now=0.0, step_time=1.0 if w != 2 else 3.5)
    assert mon.failed(now=5.0) == []
    mon.beat(0, 11.0), mon.beat(1, 11.0), mon.beat(3, 11.0)
    assert mon.failed(now=12.0) == [2]
    assert mon.stragglers() == [2]


def test_reslice_on_failure_locality():
    W = 8
    units = np.ones(1024, np.float32)
    old = np.asarray(np.repeat(np.arange(W), 128))
    plan = ft.reslice_on_failure(old, units, failed=[3], num_workers=W)
    assert 3 not in plan.assignment
    loads = np.bincount(plan.assignment, minlength=W)
    live = loads[loads > 0]
    assert live.max() - live.min() <= 1
    # bulk of data does not move (incremental locality)
    assert plan.plan.stay_fraction > 0.5


def test_straggler_weighted_reslice():
    units = np.ones(1000, np.float32)
    thr = np.array([1.0, 1.0, 0.25, 1.0])  # worker 2 is 4x slower
    a = ft.reslice_for_stragglers(units, thr)
    loads = np.bincount(a, minlength=4)
    assert loads[2] < loads[0] * 0.5  # slow worker gets much less


def test_elastic_mesh_shapes():
    shapes = elastic.viable_mesh_shapes(12)
    assert (4, 3) in shapes or (3, 4) in shapes
    new, plan = elastic.replacement_plan(
        np.repeat(np.arange(4), 10), np.ones(40, np.float32), 3
    )
    assert new.max() == 2
    assert plan.send_counts.sum() == 40


# --- serving batcher ----------------------------------------------------------

def test_knapsack_batches_balanced():
    from repro.serve.engine import Request, knapsack_batches

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=np.arange(rng.integers(4, 60)), max_new_tokens=4)
        for i in range(33)
    ]
    batches = knapsack_batches(reqs, batch_size=8)
    assert sum(len(b) for b in batches) == 33
    tot = [sum(r.length for r in b) for b in batches]
    assert max(tot) - min(tot) <= 64  # within one max request length
