"""Distributed-path tests: run in a subprocess with 8 fake host devices
(the fake-device flag must be set before jax initializes, so these cannot
run in the main pytest process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
        " --xla_backend_optimization_level=0"  # match conftest: compile-bound
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_distributed_partition_sample_sort():
    """Properties of `distributed_partition` through the fixed-capacity
    all_to_all, on *clustered*, non-uniformly weighted input (the regime
    that stresses the ~2x fair-share lane capacity):

      1. element conservation — no silent drops at capacity
      2. weight conservation — the global weight mass survives the exchange
      3. non-decreasing global key order across shards
      4. near-ideal weighted load balance from the knapsack slice
    """
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import partitioner as pt
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(0)
        n = 4096
        # half the mass in a tight cluster: many shards route to few lanes
        pts_h = rng.random((n,3)).astype(np.float32)
        pts_h[: n // 2] = 0.45 + 0.1 * pts_h[: n // 2]
        wts_h = (0.1 + rng.random(n)).astype(np.float32)
        pts = jax.device_put(jnp.asarray(pts_h), NamedSharding(mesh, P('data')))
        wts = jax.device_put(jnp.asarray(wts_h), NamedSharding(mesh, P('data')))
        keys, w, part = pt.distributed_partition(mesh, 'data', pts, wts, num_parts=16)
        keys_h, w_h, part_h = np.asarray(keys), np.asarray(w), np.asarray(part)
        valid = part_h >= 0
        assert valid.sum() == n, (valid.sum(), n)                    # (1)
        np.testing.assert_allclose(                                  # (2)
            w_h[valid].sum(), wts_h.sum(), rtol=1e-5)
        ks = keys_h.reshape(8, -1)
        prev = -1
        for s in range(8):
            kv = ks[s][ks[s] != 0xFFFFFFFF].astype(np.int64)
            assert (np.diff(kv) >= 0).all()                          # (3)
            if kv.size:
                assert kv[0] >= prev
                prev = kv[-1]
        loads = np.zeros(16); np.add.at(loads, part_h[valid], w_h[valid])
        assert loads.max() / loads.mean() < 1.05                     # (4)
        print('OK')
    """)
    assert "OK" in out


def test_distributed_reslice_matches_full_repartition():
    """Weight-only rebalance on cached keys must produce the same slice as
    a full re-partition with the new weights (and the engine must count it
    as a reslice, not a key-gen)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import partitioner as pt
        from repro.core.repartition import DistributedRepartitioner
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(3)
        n = 2048
        sh = NamedSharding(mesh, P('data'))
        pts = jax.device_put(jnp.asarray(rng.random((n,3)), jnp.float32), sh)
        wts_h = (0.5 + rng.random(n)).astype(np.float32)
        wts = jax.device_put(jnp.asarray(wts_h), sh)
        eng = DistributedRepartitioner(mesh, 'data', num_parts=16)
        keys, w_sorted, part0 = eng.partition(pts, wts)
        # weight-only drift, applied in the cached sorted layout
        w2 = jnp.where(w_sorted >= 0, w_sorted * (1.0 + 2.0 * (np.asarray(keys) % 7 == 0)), 0.0)
        part1 = eng.rebalance(w2)
        valid = np.asarray(w_sorted) >= 0
        p1 = np.asarray(part1)
        assert (p1[valid] >= 0).all() and (p1[~valid] == -1).all()
        # exact oracle: the global curve order is unchanged, so the slice
        # must equal the single-process knapsack over the valid weights
        from repro.core import knapsack
        w2_h = np.asarray(w2)
        expect = np.asarray(knapsack.slice_weighted_curve(jnp.asarray(w2_h[valid]), 16))
        # float32 prefix-sum association differs between the sharded and
        # host scans: tolerate a +-1 part flip on a vanishing fraction of
        # boundary elements, nothing else
        mism = p1[valid] != expect
        assert np.abs(p1[valid] - expect).max() <= 1
        assert mism.mean() < 1e-2, mism.mean()
        # conservation + balance of the resliced assignment
        loads = np.zeros(16); np.add.at(loads, p1[valid], w2_h[valid])
        assert abs(loads.sum() - w2_h[valid].sum()) < 1e-3 * max(loads.sum(), 1)
        assert loads.max() / loads.mean() < 1.1
        assert eng.reslices == 1 and eng.full_partitions == 1
        print('OK')
    """)
    assert "OK" in out


def test_distributed_bucket_summary_matches_sample_sort():
    """The bucket-summary exchange path vs the sample-sort path on the
    same clustered, non-uniformly weighted input:

      1. every element is assigned a valid part in the ORIGINAL layout
         (the bucket path moves no points)
      2. both paths conserve the global weight mass exactly
      3. both meet the knapsack balance bound for their granularity
         (element weight for sample-sort, bucket weight for summaries)
      4. the cached-tree reslice equals a fresh bucket partition on the
         drifted weights (same trees => identical knapsack input)
    """
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import partitioner as pt
        from repro.core.repartition import DistributedBucketRepartitioner
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(0)
        n, PARTS = 4096, 16
        pts_h = rng.random((n,3)).astype(np.float32)
        pts_h[: n // 2] = 0.45 + 0.1 * pts_h[: n // 2]
        wts_h = (0.1 + rng.random(n)).astype(np.float32)
        sh = NamedSharding(mesh, P('data'))
        pts = jax.device_put(jnp.asarray(pts_h), sh)
        wts = jax.device_put(jnp.asarray(wts_h), sh)
        cfg = pt.PartitionerConfig(use_tree=True, max_depth=8, bucket_size=16)
        part, leaf_id, node_keys = pt.distributed_bucket_partition(
            mesh, 'data', pts, wts, PARTS, cfg=cfg)
        p = np.asarray(part)
        assert p.shape[0] == n and (p >= 0).all() and (p < PARTS).all()   # (1)
        loads_b = np.zeros(PARTS); np.add.at(loads_b, p, wts_h)
        np.testing.assert_allclose(loads_b.sum(), wts_h.sum(), rtol=1e-5) # (2)
        # (3) bucket-granularity balance: spread <= 2 * max bucket weight
        lid = np.asarray(leaf_id).reshape(8, -1)
        maxbw = 0.0
        wsh = wts_h.reshape(8, -1)
        for s in range(8):
            bw = np.zeros(lid[s].max() + 1); np.add.at(bw, lid[s], wsh[s])
            maxbw = max(maxbw, bw.max())
        assert loads_b.max() - loads_b.min() <= 2 * maxbw + 1e-3
        # sample-sort on the same input meets its per-element bound
        keys, w_srt, part_srt = pt.distributed_partition(
            mesh, 'data', pts, wts, PARTS)
        w_h, ps_h = np.asarray(w_srt), np.asarray(part_srt)
        valid = ps_h >= 0
        loads_s = np.zeros(PARTS); np.add.at(loads_s, ps_h[valid], w_h[valid])
        np.testing.assert_allclose(loads_s.sum(), wts_h.sum(), rtol=1e-5) # (2)
        assert loads_s.max() / loads_s.mean() < 1.05
        assert loads_b.max() / loads_b.mean() < 1.25
        # (4) cached-tree reslice == fresh bucket partition on new weights
        w2_h = wts_h * (1.0 + 2.0 * (np.arange(n) % 5 == 0))
        w2 = jax.device_put(jnp.asarray(w2_h), sh)
        eng = DistributedBucketRepartitioner(mesh, 'data', PARTS, cfg)
        eng.partition(pts, wts)
        p_re = np.asarray(eng.rebalance(w2))
        p_fresh = np.asarray(pt.distributed_bucket_partition(
            mesh, 'data', pts, w2, PARTS, cfg=cfg)[0])
        np.testing.assert_array_equal(p_re, p_fresh)
        assert eng.reslices == 1 and eng.full_partitions == 1
        print('OK')
    """)
    assert "OK" in out


def test_shard_exchange_conserves():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import migration
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(1)
        n = 8 * 128
        payload = jax.device_put(jnp.arange(n, dtype=jnp.float32)[:, None], NamedSharding(mesh, P('data')))
        dest = jax.device_put(jnp.asarray(rng.integers(0, 8, n), jnp.int32), NamedSharding(mesh, P('data')))
        recv, valid = migration.execute_shard_exchange(mesh, 'data', payload, dest, capacity=64)
        got = np.asarray(recv)[np.asarray(valid)]
        want_count = sum(min(int((np.asarray(dest).reshape(8,-1)[s]==d).sum()), 64) for s in range(8) for d in range(8))
        assert got.shape[0] == want_count

        # apply_repartition: default capacity must never drop a row, and
        # invalid rows (part < 0) must park on their current shard
        from repro.distributed import sharding as shd
        part = jnp.where(jnp.arange(n) % 11 == 0, -1, dest)
        recv2, valid2 = shd.apply_repartition(mesh, 'data', payload, part)
        got2 = np.asarray(recv2)[np.asarray(valid2)]
        assert got2.shape[0] == n, (got2.shape[0], n)   # full conservation
        assert sorted(got2[:, 0].astype(int).tolist()) == list(range(n))
        print('OK', got.shape[0])
    """)
    assert "OK" in out


@pytest.mark.slow
def test_train_step_sharded_small_mesh():
    """A real sharded train step executes (not just lowers) on 8 devices."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced
        from repro.configs.base import RunConfig, ShapeConfig, ShardingRules
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.train import step as ts
        from repro.models import model as M
        mesh = make_mesh((4, 2), ('data', 'model'))
        cfg = reduced(ARCHS['smollm-135m'])
        run = RunConfig(model=cfg, shape=ShapeConfig('t', 32, 8, 'train'))
        rules = ShardingRules(batch=('data',))
        params, opt = ts.init_all(run, jax.random.PRNGKey(0))
        pshapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        psh = shd.param_shardings(mesh, cfg, rules, pshapes)
        params = jax.device_put(params, psh)
        osh = shd.opt_state_shardings(mesh, cfg, rules, None, psh)
        opt = jax.device_put(opt, osh)
        batch = M.synthetic_batch(cfg, 8, 32, jax.random.PRNGKey(1))
        bsh = shd.batch_shardings(mesh, cfg, rules, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
        batch = jax.device_put(batch, bsh)
        with shd.activation_mesh(mesh, rules):
            # no donation here: zeros-dedup can alias m/v buffers at runtime;
            # compile-time donation is exercised by the dry-run tests
            step = jax.jit(ts.make_train_step(run, 100), in_shardings=(psh, osh, bsh))
            params, opt, metrics = step(params, opt, batch)
        loss = float(metrics['loss'])
        assert np.isfinite(loss) and loss > 0
        print('OK loss', loss)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_entry_on_8_devices():
    """dryrun.build_cell_fn lowers+compiles a reduced cell on a small mesh
    (the full 512-device sweep runs out-of-band; results in EXPERIMENTS.md)."""
    out = _run("""
        import jax, dataclasses
        from repro.configs import ARCHS, SHAPES, reduced
        from repro.configs.base import ShapeConfig, ShardingRules
        from repro.launch import dryrun
        from repro.launch.mesh import make_mesh
        from repro.distributed import sharding as shd
        import repro.launch.dryrun as dr
        mesh = make_mesh((4, 2), ('data', 'model'))
        cfg = reduced(ARCHS['qwen3-moe-30b-a3b'])
        shape = ShapeConfig('t', 64, 8, 'train')
        rules = ShardingRules(batch=('data',))
        fn, args, in_sh, out_sh = dr.build_cell_fn(cfg, shape, mesh, rules)
        with shd.activation_mesh(mesh, rules):
            compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
            cost = cost[0]
        assert cost.get('flops', 0) > 0
        coll = dr.parse_collectives(compiled.as_text())
        print('OK flops', cost['flops'], 'coll', coll['total_bytes'])
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_restore_to_different_mesh(tmp_path):
    out = _run(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpoint as ckpt
        from repro.launch.mesh import make_mesh
        mesh8 = make_mesh((8,), ('data',))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8), NamedSharding(mesh8, P('data')))
        ckpt.save({tmp_path.as_posix()!r}, 5, {{'w': w}})
        # restore onto a 4-device mesh (elastic shrink)
        mesh4 = make_mesh((4,), ('data',))
        like = {{'w': jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        sh = {{'w': NamedSharding(mesh4, P('data'))}}
        tree, _ = ckpt.restore({tmp_path.as_posix()!r}, 5, like, shardings=sh)
        assert tree['w'].sharding.num_devices == 4
        np.testing.assert_array_equal(np.asarray(tree['w']), np.arange(64.0).reshape(8, 8))
        print('OK')
    """)
    assert "OK" in out
