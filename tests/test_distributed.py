"""Distributed-path tests: run in a subprocess with 8 fake host devices
(the fake-device flag must be set before jax initializes, so these cannot
run in the main pytest process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_distributed_partition_sample_sort():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import partitioner as pt
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(0)
        n = 16384
        pts = jax.device_put(jnp.asarray(rng.random((n,3)), jnp.float32), NamedSharding(mesh, P('data')))
        wts = jax.device_put(jnp.ones((n,), jnp.float32), NamedSharding(mesh, P('data')))
        keys, w, part = pt.distributed_partition(mesh, 'data', pts, wts, num_parts=16)
        keys_h, part_h = np.asarray(keys), np.asarray(part)
        valid = part_h >= 0
        assert valid.sum() == n, (valid.sum(), n)
        ks = keys_h.reshape(8, -1)
        prev = -1
        for s in range(8):
            kv = ks[s][ks[s] != 0xFFFFFFFF].astype(np.int64)
            assert (np.diff(kv) >= 0).all()
            if kv.size:
                assert kv[0] >= prev
                prev = kv[-1]
        loads = np.bincount(part_h[valid], minlength=16)
        assert loads.max() - loads.min() <= 2
        print('OK')
    """)
    assert "OK" in out


def test_shard_exchange_conserves():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import migration
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('data',))
        rng = np.random.default_rng(1)
        n = 8 * 128
        payload = jax.device_put(jnp.arange(n, dtype=jnp.float32)[:, None], NamedSharding(mesh, P('data')))
        dest = jax.device_put(jnp.asarray(rng.integers(0, 8, n), jnp.int32), NamedSharding(mesh, P('data')))
        recv, valid = migration.execute_shard_exchange(mesh, 'data', payload, dest, capacity=64)
        got = np.asarray(recv)[np.asarray(valid)]
        want_count = sum(min(int((np.asarray(dest).reshape(8,-1)[s]==d).sum()), 64) for s in range(8) for d in range(8))
        assert got.shape[0] == want_count
        print('OK', got.shape[0])
    """)
    assert "OK" in out


def test_train_step_sharded_small_mesh():
    """A real sharded train step executes (not just lowers) on 8 devices."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ARCHS, reduced
        from repro.configs.base import RunConfig, ShapeConfig, ShardingRules
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.train import step as ts
        from repro.models import model as M
        mesh = make_mesh((4, 2), ('data', 'model'))
        cfg = reduced(ARCHS['smollm-135m'])
        run = RunConfig(model=cfg, shape=ShapeConfig('t', 32, 8, 'train'))
        rules = ShardingRules(batch=('data',))
        params, opt = ts.init_all(run, jax.random.PRNGKey(0))
        pshapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        psh = shd.param_shardings(mesh, cfg, rules, pshapes)
        params = jax.device_put(params, psh)
        osh = shd.opt_state_shardings(mesh, cfg, rules, None, psh)
        opt = jax.device_put(opt, osh)
        batch = M.synthetic_batch(cfg, 8, 32, jax.random.PRNGKey(1))
        bsh = shd.batch_shardings(mesh, cfg, rules, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
        batch = jax.device_put(batch, bsh)
        with shd.activation_mesh(mesh, rules):
            # no donation here: zeros-dedup can alias m/v buffers at runtime;
            # compile-time donation is exercised by the dry-run tests
            step = jax.jit(ts.make_train_step(run, 100), in_shardings=(psh, osh, bsh))
            params, opt, metrics = step(params, opt, batch)
        loss = float(metrics['loss'])
        assert np.isfinite(loss) and loss > 0
        print('OK loss', loss)
    """)
    assert "OK" in out


def test_dryrun_entry_on_8_devices():
    """dryrun.build_cell_fn lowers+compiles a reduced cell on a small mesh
    (the full 512-device sweep runs out-of-band; results in EXPERIMENTS.md)."""
    out = _run("""
        import jax, dataclasses
        from repro.configs import ARCHS, SHAPES, reduced
        from repro.configs.base import ShapeConfig, ShardingRules
        from repro.launch import dryrun
        from repro.launch.mesh import make_mesh
        from repro.distributed import sharding as shd
        import repro.launch.dryrun as dr
        mesh = make_mesh((4, 2), ('data', 'model'))
        cfg = reduced(ARCHS['qwen3-moe-30b-a3b'])
        shape = ShapeConfig('t', 64, 8, 'train')
        rules = ShardingRules(batch=('data',))
        fn, args, in_sh, out_sh = dr.build_cell_fn(cfg, shape, mesh, rules)
        with shd.activation_mesh(mesh, rules):
            compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        assert cost.get('flops', 0) > 0
        coll = dr.parse_collectives(compiled.as_text())
        print('OK flops', cost['flops'], 'coll', coll['total_bytes'])
    """)
    assert "OK" in out


def test_elastic_restore_to_different_mesh(tmp_path):
    out = _run(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpoint as ckpt
        from repro.launch.mesh import make_mesh
        mesh8 = make_mesh((8,), ('data',))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8), NamedSharding(mesh8, P('data')))
        ckpt.save({tmp_path.as_posix()!r}, 5, {{'w': w}})
        # restore onto a 4-device mesh (elastic shrink)
        mesh4 = make_mesh((4,), ('data',))
        like = {{'w': jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        sh = {{'w': NamedSharding(mesh4, P('data'))}}
        tree, _ = ckpt.restore({tmp_path.as_posix()!r}, 5, like, shardings=sh)
        assert tree['w'].sharding.num_devices == 4
        np.testing.assert_array_equal(np.asarray(tree['w']), np.arange(64.0).reshape(8, 8))
        print('OK')
    """)
    assert "OK" in out
