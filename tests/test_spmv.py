"""SpMV partitioning: paper Tables II-VII metrics + executable check."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spmv


@pytest.fixture(scope="module")
def graph():
    src, dst = spmv.powerlaw_graph(3000, 10, seed=4)
    return src, dst


def test_sfc_load_balance_near_perfect(graph):
    src, dst = graph
    P = 16
    part = spmv.sfc_partition(src, dst, 3000, P)
    m = spmv.communication_metrics(part, src, dst, 3000, P)
    assert m["MaxLoad"] - m["AvgLoad"] <= 2  # knapsack guarantee, unit weights


def test_sfc_partition_curve_cfg_conflict_raises(graph):
    """Regression: an explicit ``cfg`` replaces the configuration
    wholesale, so a simultaneous explicit ``curve=`` used to be silently
    ignored — now it is a hard conflict. Each argument alone still works
    (and cfg alone carries its own curve)."""
    from repro.core import partitioner as pt

    src, dst = graph
    cfg = pt.PartitionerConfig(curve="morton", bits=16)
    with pytest.raises(ValueError, match="not both"):
        spmv.sfc_partition(src, dst, 3000, 4, curve="hilbert", cfg=cfg)
    a = spmv.sfc_partition(src, dst, 3000, 4, curve="morton")
    b = spmv.sfc_partition(src, dst, 3000, 4, cfg=cfg)
    np.testing.assert_array_equal(a, b)


def test_rowwise_has_full_degree(graph):
    """Paper Tables II/IV/VI: row-wise MaxDegree == P-1."""
    src, dst = graph
    P = 16
    part = spmv.rowwise_partition(src, 3000, P)
    m = spmv.communication_metrics(part, src, dst, 3000, P, improve=False)
    assert m["MaxDegree"] >= P - 2


def test_sfc_degree_lower_than_rowwise(graph):
    src, dst = graph
    P = 16
    prow = spmv.rowwise_partition(src, 3000, P)
    psfc = spmv.sfc_partition(src, dst, 3000, P)
    mrow = spmv.communication_metrics(prow, src, dst, 3000, P, improve=False)
    msfc = spmv.communication_metrics(psfc, src, dst, 3000, P)
    assert msfc["MaxDegree"] < mrow["MaxDegree"]


def test_spanning_set_improvement_reduces_volume(graph):
    src, dst = graph
    P = 8
    part = spmv.sfc_partition(src, dst, 3000, P)
    m0 = spmv.communication_metrics(part, src, dst, 3000, P, improve=False)
    m1 = spmv.communication_metrics(part, src, dst, 3000, P, improve=True)
    assert m1["TotalVolume"] <= m0["TotalVolume"]


def test_distributed_spmv_matches_reference(graph):
    src, dst = graph
    n = 3000
    rng = np.random.default_rng(0)
    vals = rng.random(src.shape[0]).astype(np.float32)
    x = jnp.asarray(rng.random(n), jnp.float32)
    ndev = jax.device_count()
    P = min(8, ndev)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((P,), ("parts",))
    part = spmv.sfc_partition(src, dst, n, P)
    y = spmv.distributed_spmv(mesh, "parts", src, dst, vals, part, x, n)
    yref = spmv.spmv_reference(src, dst, vals, x, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-3, rtol=1e-4)
