"""Point location + k-NN (paper §V-A)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import queries


def test_point_location_exact(rng):
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=32)
    sel = rng.choice(2048, 256, replace=False)
    q = pts[jnp.asarray(sel)]
    found, gid = queries.point_location(idx, q)
    assert bool(found.all())
    # returned ids identify coordinates equal to the query
    np.testing.assert_array_equal(np.asarray(pts)[np.asarray(gid)], np.asarray(q))


def test_point_location_misses(rng):
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=32)
    q = jnp.asarray(rng.random((256, 3)) + 2.0, jnp.float32)  # outside bbox
    found, gid = queries.point_location(idx, q)
    assert not bool(found.any())
    assert (np.asarray(gid) == -1).all()


@pytest.mark.parametrize("k", [pytest.param(1, marks=pytest.mark.slow), 3, pytest.param(5, marks=pytest.mark.slow)])
def test_knn_recall(k, rng):
    pts = jnp.asarray(rng.random((4096, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=32)
    q = jnp.asarray(rng.random((128, 3)), jnp.float32)
    d_a, id_a = queries.knn(idx, q, k=k, cutoff_buckets=2)
    d_b, id_b = queries.knn_bruteforce(pts, q, k=k)
    recall = float(
        jnp.mean(jnp.any(id_a[:, :, None] == id_b[:, None, :], axis=1).astype(jnp.float32))
    )
    assert recall > 0.7, f"recall@{k}: {recall}"  # CUTOFF-bounded approximate k-NN


def test_knn_distances_sorted_and_valid(rng):
    pts = jnp.asarray(rng.random((2048, 2)), jnp.float32)
    idx = queries.build_index(pts)
    q = jnp.asarray(rng.random((64, 2)), jnp.float32)
    d, ids = queries.knn(idx, q, k=3)
    d = np.asarray(d)
    assert (np.diff(d, axis=1) >= -1e-6).all()
    assert np.isfinite(d).all()


@given(n=st.integers(100, 2000), seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_property_self_query_returns_self(n, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=16)
    q = pts[:64]
    d, ids = queries.knn(idx, q, k=1, cutoff_buckets=1)
    assert float(d.max()) <= 1e-6  # nearest neighbor of a stored point is itself
